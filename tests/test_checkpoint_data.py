"""Checkpointing (atomic/keep-k/async/restore) and data-pipeline tests."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.data import MemmapLM, Prefetcher, SyntheticLM


def _tree(step):
    return {"params": {"w": jnp.full((4, 3), float(step)),
                       "b": jnp.arange(3.0)},
            "step": jnp.asarray(step)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 7, _tree(7), extra={"train_step": 7})
    tree, extra = ckpt.restore(d, 7, _tree(0))
    assert extra["train_step"] == 7
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]),
                                  np.full((4, 3), 7.0))


def test_keep_last_k_gc(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, _tree(s), keep_last=2)
    assert ckpt.all_steps(d) == [4, 5]
    assert ckpt.latest_step(d) == 5


def test_atomic_no_tmp_leftover(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_async_save(tmp_path):
    d = str(tmp_path)
    t = ckpt.save_async(d, 3, _tree(3))
    t.join(timeout=30)
    assert ckpt.latest_step(d) == 3


def test_restore_rejects_structure_mismatch(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(d, 1, {"different": jnp.zeros(3)})


def test_synthetic_deterministic_per_step():
    src = SyntheticLM(vocab_size=100, batch=4, seq_len=16, seed=3)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_memmap_reader(tmp_path):
    path = str(tmp_path / "tokens.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    ds = MemmapLM(path, batch=2, seq_len=8)
    b0 = ds.batch_at(0)
    assert b0["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b0["labels"], b0["tokens"] + 1)


def test_prefetcher_orders_steps():
    src = SyntheticLM(vocab_size=50, batch=2, seq_len=4, seed=0)
    pf = Prefetcher(src, start_step=10, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(4)]
        assert steps == [10, 11, 12, 13]
        np.testing.assert_array_equal(pf.next()[1]["tokens"],
                                      src.batch_at(14)["tokens"])
    finally:
        pf.close()
