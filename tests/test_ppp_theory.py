"""Paper §4.1 / example 12: SIR distribution of a PPP network vs the exact
stochastic-geometry result.

For a homogeneous PPP of base stations, nearest-BS association, power-law
pathloss with exponent alpha, Rayleigh fading and no noise (sigma^2 = 0),
the SIR CCDF is (Andrews-Baccelli-Ganti / Haenggi):

    P(SIR > t) = 1 / (1 + rho(t, alpha)),
    rho(t, a)  = t^(2/a) * integral_{t^(-2/a)}^{inf} du / (1 + u^(a/2)).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim import deploy

ALPHA = 3.5


def ppp_sir_ccdf_theory(theta, alpha=ALPHA):
    out = []
    for t in np.atleast_1d(theta):
        lo = t ** (-2.0 / alpha)
        u = np.linspace(lo, lo + 2000.0, 400_000)
        rho = t ** (2.0 / alpha) * np.trapezoid(
            1.0 / (1.0 + u ** (alpha / 2.0)), u)
        out.append(1.0 / (1.0 + rho))
    return np.asarray(out)


def simulate_sir(n_bs=4000, n_ue=800, extent=10_000.0, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    C = deploy.ppp_points(k1, n_bs, extent, z=0.0)
    # sample UEs in the interior to avoid edge effects
    U = deploy.ppp_points(k2, n_ue, extent * 0.5, z=0.0) \
        + jnp.asarray([extent * 0.25, extent * 0.25, 0.0])
    params = CRRM_parameters(
        n_ues=n_ue, ue_positions=np.asarray(U), cell_positions=np.asarray(C),
        pathloss_model_name="power_law",
        pathloss_params={"alpha": ALPHA},
        power_W=1.0, noise_power_W=0.0, rayleigh_fading=True, seed=seed)
    sim = CRRM(params)
    return np.asarray(sim.get_SINR())[:, 0]


def test_ppp_sir_matches_analytic_ccdf():
    sir = simulate_sir()
    thetas_db = np.array([-5.0, 0.0, 5.0, 10.0])
    thetas = 10 ** (thetas_db / 10)
    emp = np.array([(sir > t).mean() for t in thetas])
    theo = ppp_sir_ccdf_theory(thetas)
    err = np.abs(emp - theo)
    assert err.max() < 0.05, (
        f"CCDF mismatch: empirical {emp}, theory {theo}")


def test_attachment_is_strongest_bs():
    """With fading disabled, each UE must attach to its max-RSRP BS."""
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    C = np.asarray(deploy.ppp_points(k1, 100, 3000.0, z=10.0))
    U = np.asarray(deploy.ppp_points(k2, 50, 3000.0, z=1.5))
    sim = CRRM(CRRM_parameters(
        n_ues=50, ue_positions=U, cell_positions=C,
        pathloss_model_name="power_law", power_W=1.0))
    R = np.asarray(sim.get_RSRP()).sum(axis=2)
    np.testing.assert_array_equal(np.asarray(sim.get_attachment()),
                                  R.argmax(axis=1))
