"""Opt-in int8 KV cache: decode matches the bf16-cache path within
quantization tolerance; memory halves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import make_arch


@pytest.mark.parametrize("arch_id", ["yi-6b", "deepseek-moe-16b"])
def test_int8_cache_decode_close_to_fp(arch_id):
    cfg_fp = get_config(arch_id, reduced=True)
    cfg_q = dataclasses.replace(cfg_fp, kv_cache_dtype="int8")
    arch_fp, arch_q = make_arch(cfg_fp), make_arch(cfg_q)
    params = arch_fp.init(jax.random.PRNGKey(0))
    b, sp, ex = 1, 6, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, sp + ex), 0,
                              cfg_fp.vocab_size)
    _, c_fp = arch_fp.prefill(params, {"tokens": toks[:, :sp]}, sp + ex)
    _, c_q = arch_q.prefill(params, {"tokens": toks[:, :sp]}, sp + ex)
    assert c_q["k"].dtype == jnp.int8
    assert c_q["k"].size == c_fp["k"].size           # same shape, half bytes
    for j in range(ex):
        step = {"tokens": toks[:, sp + j:sp + j + 1]}
        o_fp, c_fp = arch_fp.decode_step(params, step, c_fp, sp + j)
        o_q, c_q = arch_q.decode_step(params, step, c_q, sp + j)
        np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_fp),
                                   atol=0.35)


def test_quantize_roundtrip():
    from repro.models.transformer import _dequantize_kv, _quantize_kv
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 4, 32)) * 3.0
    q, s = _quantize_kv(x)
    err = jnp.abs(_dequantize_kv(q, s, jnp.float32) - x)
    # max error bounded by half a quantization step per (pos, head)
    step = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert bool((err <= 0.51 * step + 1e-6).all())
