"""Optimizer + loss substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim
from repro.train.loss import chunked_cross_entropy, cross_entropy


def _quadratic_params(key):
    return {"a": jax.random.normal(key, (8, 8)), "b": jnp.ones((8,)) * 3.0}


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_converge_on_quadratic(name):
    opt = optim.OPTIMIZERS[name](optim.constant_lr(0.1))
    params = _quadratic_params(jax.random.PRNGKey(0))
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, metrics = opt.update(grads, state, params)
    assert float(loss(params)) < 0.05 * l0
    assert np.isfinite(float(metrics["grad_norm"]))


def test_adafactor_state_is_factored_and_small():
    opt = optim.adafactor(optim.constant_lr(1e-3))
    params = {"w": jnp.zeros((256, 512)), "tiny": jnp.zeros((4, 4))}
    st = opt.init(params)
    assert set(st["m"]["w"].keys()) == {"vr", "vc"}
    assert st["m"]["w"]["vr"].shape == (256,)
    assert st["m"]["w"]["vc"].shape == (512,)
    assert set(st["m"]["tiny"].keys()) == {"v"}
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(st["m"]))
    n_param = 256 * 512 + 16
    assert n_state < 0.02 * n_param


def test_grad_clipping():
    grads = {"w": jnp.full((10,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 100.0
    np.testing.assert_allclose(float(optim.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_warmup_cosine_schedule():
    lr = optim.warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1e-3, rtol=1e-5)
    assert float(lr(5)) == pytest.approx(5e-4, rel=1e-4)
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


def test_chunked_ce_matches_full():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 16, 8, 32
    feats = jax.random.normal(key, (b, s, d))
    W = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.3
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)

    def full(f):
        return cross_entropy(f @ W, labels)[0]

    def chunked(f):
        return chunked_cross_entropy(lambda x: x @ W, f, labels, chunk=4)[0]

    np.testing.assert_allclose(float(full(feats)), float(chunked(feats)),
                               rtol=1e-6)
    g1 = jax.grad(full)(feats)
    g2 = jax.grad(chunked)(feats)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-5,
                               atol=1e-6)


def test_ce_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    loss, m = cross_entropy(logits, labels, mask=mask, z_loss=0.0)
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)
