"""ISSUE 7: digital-twin serving -- birth-death churn invariants, in-flight
checkpoint/restore resume-equivalence, live no-recompile control updates,
and the measured MAC/dirtiness hot-spot rewrites (segment-rank rr,
custom-vmap segment reductions, top-k dirty-index compaction) against
brute-force oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.mac import engine as mac_engine
from repro.mac import scheduler as mac_sched
from repro.mac import segments
from repro.obs.profile import CompileCounter
from repro.sim import mobility, radio
from repro.sim.mobility import ChurnConfig
from repro.twin.server import TwinServer


def _params(**kw):
    base = dict(n_ues=48, n_cells=7, n_sectors=1, seed=11,
                pathloss_model_name="UMa", power_W=10.0,
                traffic_model="poisson", scheduler_policy="pf",
                traffic_params=dict(arrival_rate_hz=300.0,
                                    packet_size_bits=12_000.0))
    base.update(kw)
    return CRRM_parameters(**base)


CHURN = ChurnConfig(arrival_rate_hz=400.0, mean_lifetime_s=0.1,
                    max_arrivals_per_tti=6)


def _churn_setup(params=None, churn=CHURN, **fns_kw):
    sim = CRRM(params or _params())
    fns = sim.episode_fns(churn=churn, telemetry=True, **fns_kw)
    static = sim.episode_static()
    state = mac_engine.seed_churn_state(sim.init_episode_state(), static,
                                        sim.params)
    return sim, fns, static, state


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- hot spots
def _rr_oracle(active, a, n_cells, n_rb, cursor):
    """The O(n_ue x n_cell) within-cell rank formulation, re-derived
    brute-force: rank = active same-cell UEs at lower index."""
    active, a = np.asarray(active), np.asarray(a)
    n, K = active.shape
    rank = np.zeros((n, K), np.int64)
    count = np.zeros((n, K), np.int64)
    for i in range(n):
        same = a == a[i]
        rank[i] = active[:i][same[:i]].sum(axis=0)
        count[i] = active[same].sum(axis=0)
    n_act = np.maximum(count, 1)
    base = n_rb // n_act
    extra = ((rank - cursor) % n_act) < (n_rb % n_act)
    return np.where(active, (base + extra).astype(np.float32), 0.0)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rr_segment_rank_matches_cumsum_oracle(seed):
    """S1 acceptance: the segment-rank rr allocation is bitwise the
    within-cell rank-cumsum formulation, for every row incl. inactive."""
    rng = np.random.default_rng(seed)
    n, n_cells, K, n_rb = 41, 6, 3, 13
    active = jnp.asarray(rng.random((n, K)) < 0.6)
    a = jnp.asarray(rng.integers(0, n_cells, n), dtype=jnp.int32)
    cursor = jnp.int32(rng.integers(0, 100))
    got = mac_sched.allocate_rr(active, a, n_cells, n_rb, cursor)
    want = _rr_oracle(active, a, n_cells, n_rb, int(cursor))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_segment_reductions_vmap_bitwise():
    """The custom_vmap rule equals the per-element unbatched scatter --
    bitwise, which is what lets the schedulers keep their exactness
    claims under a batched env."""
    rng = np.random.default_rng(3)
    B, n, n_seg = 5, 37, 9
    data = jnp.asarray(rng.normal(size=(B, n, 2)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, n_seg, (B, n)), dtype=jnp.int32)
    vsum = jax.vmap(lambda d, s: segments.segment_sum(d, s, n_seg))
    vmax = jax.vmap(lambda d, s: segments.segment_max(d, s, n_seg))
    got_s, got_m = vsum(data, seg), vmax(data, seg)
    for b in range(B):
        np.testing.assert_array_equal(
            np.asarray(got_s[b]),
            np.asarray(segments.segment_sum(data[b], seg[b], n_seg)))
        np.testing.assert_array_equal(
            np.asarray(got_m[b]),
            np.asarray(segments.segment_max(data[b], seg[b], n_seg)))
    # unbatched segment ops ARE the scatter they replaced
    np.testing.assert_array_equal(
        np.asarray(segments.segment_sum(data[0], seg[0], n_seg)),
        np.asarray(jnp.zeros((n_seg, 2)).at[seg[0]].add(data[0])))


@pytest.mark.parametrize("n,budget", [(16, 4), (16, 16), (8, 12), (16, 0)])
def test_dirty_indices_topk_semantics(n, budget):
    """S2 acceptance: the top-k compaction keeps THE convention --
    ascending True indices, row-0 padding -- for every mask/budget."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        mask = rng.random(n) < 0.3
        idx = np.asarray(radio.dirty_indices(jnp.asarray(mask), budget))
        true_idx = np.flatnonzero(mask)[:budget]
        assert idx.shape == (budget,)
        np.testing.assert_array_equal(idx[:true_idx.size], true_idx)
        assert set(idx[true_idx.size:].tolist()) <= {0}


def test_radio_update_window_matches_mask_path():
    """``radio_update(window=...)`` (O(n_move) enumeration) equals the
    generic mask compaction, bitwise, through a full smart update."""
    sim = CRRM(_params(n_ues=32))
    static = sim.radio_static()
    U = np.asarray(sim.U._data)
    rs = radio.radio_init(static.cfg, jnp.asarray(U), static.C, static.bore,
                          None, static.P)
    start, n_win = 29, 6           # wraps around the axis end
    rows = (start + np.arange(n_win)) % 32
    U2 = U.copy()
    U2[rows, :2] += 40.0
    mask = np.zeros(32, bool)
    mask[rows] = True
    via_mask = radio.radio_update(static, rs, jnp.asarray(U2),
                                  jnp.asarray(mask), budget=8)
    via_win = radio.radio_update(static, rs, jnp.asarray(U2), None,
                                 budget=8, window=(jnp.int32(start), n_win))
    _leaves_equal(via_mask, via_win)


# ----------------------------------------------------------- churn process
def test_birth_death_step_invariants():
    key = jax.random.PRNGKey(0)
    act = jnp.ones(32, bool)
    # stationary occupancy 800 * 0.02 = 16 of 32 slots: both births and
    # free capacity are visible within the 50-TTI window
    churn = ChurnConfig(arrival_rate_hz=800.0, mean_lifetime_s=0.02,
                        max_arrivals_per_tti=4)
    for t in range(50):
        k_b, k_d, _, _ = radio.churn_keys(key, t)
        prev = act
        act, born, n_born = mobility.birth_death_step(k_b, k_d, prev,
                                                      1e-3, churn)
        born, n_born = np.asarray(born), int(n_born)
        assert born.sum() == n_born <= churn.max_arrivals_per_tti
        # newborns take only previously-free (or just-freed) slots, and
        # every newborn is active afterwards
        assert not np.any(born & ~np.asarray(act))
    assert 0 < int(act.sum()) < 32          # churn actually happened


def test_inactive_ues_zero_rb_zero_tput():
    """Tentpole invariant: a capacity slot outside the active mask draws
    zero RBs and zero throughput, every TTI, on both radio modes."""
    for mode in ("dense", "incremental"):
        _, fns, static, state = _churn_setup(radio_mode=mode)
        saw_inactive = False
        for _ in range(30):
            state, tput, telem = fns.step(static, state)
            inact = ~np.asarray(state.active)
            saw_inactive |= bool(inact.any())
            assert np.all(np.asarray(tput)[inact] == 0.0)
            assert int(telem.active_ues) == int(np.asarray(
                state.active).sum())
        assert saw_inactive


def test_telemetry_counts_only_active_ues():
    _, fns, static, state = _churn_setup()
    state, _, telem = fns.rollout(static, state, 40)
    active_traj = np.asarray(telem.active_ues)
    assert active_traj.shape == (40,)
    assert active_traj.min() < 48          # departures visible
    assert int(active_traj[-1]) == int(np.asarray(state.active).sum())
    # summarize() publishes the mean live population
    from repro.obs.telemetry import summarize
    kpis = summarize(telem)
    assert kpis["mean_active_ues"] == pytest.approx(active_traj.mean())


def test_churn_incremental_matches_dense_bitwise():
    """The carried-RadioState churn path reproduces the dense recompute
    bitwise (static geometry, newborn rows patched through the state)."""
    _, fns_d, static, state = _churn_setup()
    _, fns_i, _, _ = _churn_setup(radio_mode="incremental")
    sd, td, teld = fns_d.rollout(static, state, 30)
    si, ti, teli = fns_i.rollout(static, state, 30)
    np.testing.assert_array_equal(np.asarray(td), np.asarray(ti))
    _leaves_equal(sd, si)
    np.testing.assert_array_equal(np.asarray(teld.served_bits),
                                  np.asarray(teli.served_bits))


def test_churn_trajectory_chunk_invariant():
    """Absolute-TTI PRNG folds make the trajectory partition-invariant:
    3 chunks of 10 == one 30-TTI run, bitwise."""
    _, fns, static, state = _churn_setup()
    s_whole, t_whole, _ = fns.rollout(static, state, 30)
    s, parts = state, []
    for _ in range(3):
        s, t, _ = fns.rollout(static, s, 10)
        parts.append(np.asarray(t))
    np.testing.assert_array_equal(np.vstack(parts), np.asarray(t_whole))
    _leaves_equal(s, s_whole)


def test_legacy_state_and_trajectory_untouched():
    """Churn off: the new EpisodeState leaves default to None (legacy
    treedef) and run_episode is bitwise the pre-churn program."""
    sim = CRRM(_params())
    state = sim.init_episode_state()
    assert state.active is None and state.fad is None
    t0 = mac_engine.run_episode(sim, 20, sync_state=False)
    t1 = mac_engine.run_episode(CRRM(_params()), 20, sync_state=False)
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


def test_scatter_born_duplicate_safety():
    """Padded slots must not corrupt row 0: zero births is a bitwise
    no-op, and duplicate writes are identical."""
    dst = jnp.arange(12.0).reshape(6, 2)
    idx = radio.dirty_indices(jnp.zeros(6, bool), 4)
    out = mac_engine.scatter_born(dst, idx, jnp.full((4, 2), 99.0),
                                  jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dst))
    born = jnp.asarray([False, False, True, False, True, False])
    idx = radio.dirty_indices(born, 4)
    fresh = jnp.asarray([[1.0, 1.0], [2.0, 2.0], [3.0, 3.0], [4.0, 4.0]])
    out = np.asarray(mac_engine.scatter_born(dst, idx, fresh, jnp.int32(2)))
    np.testing.assert_array_equal(out[2], [1.0, 1.0])
    np.testing.assert_array_equal(out[4], [2.0, 2.0])
    np.testing.assert_array_equal(out[0], np.asarray(dst)[0])   # untouched


def test_churn_mesh_raises():
    sim = CRRM(_params())
    devs = jax.devices()
    mesh = jax.sharding.Mesh(np.array(devs[:1]), ("ue",))
    with pytest.raises(ValueError, match="single-host"):
        sim.episode_fns(churn=CHURN, mesh=mesh)


# ------------------------------------------------------------- twin server
def _server(tmp_path, **kw):
    sim = CRRM(_params())
    return TwinServer(sim, CHURN, chunk_tti=10, ckpt_dir=str(tmp_path),
                      **kw)


def test_twin_restore_bitwise_resume(tmp_path):
    """Tentpole acceptance: kill after N TTIs, restore, and the resumed
    KPI trajectory + final state are bitwise the uninterrupted run's."""
    srv = _server(tmp_path)
    srv.step_chunk()
    srv.checkpoint()
    k_ref = [srv.step_chunk() for _ in range(2)]
    tput_ref = np.asarray(srv.last_tput)
    final_ref = jax.tree_util.tree_map(np.asarray, srv.state)

    srv2 = _server(tmp_path)               # fresh process, same ckpt dir
    step = srv2.restore()
    assert step == 10 == srv2.t
    k_res = [srv2.step_chunk() for _ in range(2)]
    assert k_res == k_ref
    np.testing.assert_array_equal(np.asarray(srv2.last_tput), tput_ref)
    _leaves_equal(srv2.state, final_ref)


def test_twin_restore_async_and_controls(tmp_path):
    """save_async snapshots are restore-equivalent, and live control
    updates (power, fairness) are part of the checkpointed tuple."""
    srv = _server(tmp_path)
    srv.step_chunk()
    srv.set_power(np.asarray(srv.power) * 0.5)
    srv.set_fairness(0.9)
    thread = srv.checkpoint(block=False)
    thread.join()
    k_ref = srv.step_chunk()

    srv2 = _server(tmp_path)
    srv2.restore()
    np.testing.assert_array_equal(np.asarray(srv2.power),
                                  np.asarray(srv.power))
    assert float(srv2.fairness) == pytest.approx(0.9)
    assert srv2.step_chunk() == k_ref


def test_twin_control_updates_do_not_recompile(tmp_path):
    """Live power/fairness swaps are traced-argument updates: after
    warmup, N chunks with changing controls trigger zero compiles."""
    srv = _server(tmp_path)
    srv.step_chunk()                       # warmup compile
    counter = CompileCounter()
    if not counter.supported:              # pragma: no cover
        pytest.skip("jax.monitoring events unavailable")
    with counter as c:
        for i in range(3):
            srv.set_power(np.asarray(srv.power) * (1.0 + 0.01 * i))
            srv.set_fairness(0.5 + 0.1 * i)
            srv.step_chunk()
    assert c.count == 0, f"control updates recompiled {c.count}x"
