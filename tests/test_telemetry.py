"""ISSUE 6 tentpole: in-scan KPI telemetry.

The load-bearing claim is *structural no-op when off*: building the
episode functions with ``telemetry=True`` must reproduce the
``telemetry=False`` trajectory bit-exactly -- across every registry
scenario, under ``vmap`` and on a 2-device mesh -- while returning the
per-TTI KPI stack.  Plus the KPI semantics themselves (dirty-row counts,
HARQ/handover/fairness bounds) and the retrace/compile counter.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.obs import CompileCounter, RetraceWatch, Telemetry, summarize
from repro.sim import scenarios


def _shrink(name, **kw):
    base = dict(n_ues=24, n_cells=6)
    base.update(kw)
    return scenarios.make_scenario(name, **base)


def _pair(params):
    return CRRM(params), CRRM(params)


# ------------------------------------------ on == off, bitwise, everywhere
@pytest.mark.parametrize("name", scenarios.scenario_names())
def test_telemetry_is_structural_noop_across_scenarios(name):
    """Acceptance: telemetry=True reproduces the telemetry=False
    trajectory AND final state bit-exactly on every registry scenario,
    with the per-TTI KPI stack returned."""
    a, b = _pair(_shrink(name))
    key = jax.random.PRNGKey(0)
    f_off, f_on = a.episode_fns(), b.episode_fns(telemetry=True)
    s0a = a.init_episode_state(key)
    s0b = b.init_episode_state(key)
    s1, t1 = f_off.rollout(a.episode_static(), s0a, 15)
    s2, t2, telem = f_on.rollout(b.episode_static(), s0b, 15)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t1))
    for l1, l2 in zip(jax.tree_util.tree_leaves(s1),
                      jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert isinstance(telem, Telemetry)
    assert telem.jain.shape == (15,)
    assert telem.served_bits.shape == (15, b.n_cells)
    assert telem.granted_rb.shape == (15, b.n_cells)


def test_telemetry_kpis_are_consistent_with_trajectory():
    """Per-cell served bits must sum to the delivered throughput, buffer
    occupancy must equal the finite backlog, Jain stays in (0, 1]."""
    sim = CRRM(_shrink("dense_urban"))
    key = jax.random.PRNGKey(3)
    fns = sim.episode_fns(telemetry=True)
    state, tput, telem = fns.rollout(sim.episode_static(),
                                     sim.init_episode_state(key), 20)
    tti_s = sim.params.tti_s
    np.testing.assert_allclose(
        np.asarray(telem.served_bits).sum(axis=1),
        np.asarray(tput).sum(axis=1) * tti_s, rtol=1e-5)
    backlog = np.asarray(state.backlog)
    occupancy = np.where(np.isfinite(backlog), backlog, 0.0).sum()
    np.testing.assert_allclose(np.asarray(telem.buffer_bits)[-1],
                               occupancy, rtol=1e-6)
    jain = np.asarray(telem.jain)
    assert ((jain >= 0.0) & (jain <= 1.0 + 1e-6)).all()
    # poisson traffic at these shapes delivers something every TTI
    assert (np.asarray(telem.harq_acks) >= 0).all()
    kpis = summarize(telem, tti_s=tti_s)
    assert kpis["served_mbits"] > 0.0
    assert 0.0 <= kpis["mean_jain"] <= 1.0


def test_telemetry_harq_counters():
    """With the stop-and-wait machine on, NACKs and retx must both occur
    at bler=0.3 over a long window, and acks+nacks bounds the attempts."""
    sim = CRRM(CRRM_parameters(
        n_ues=16, n_cells=4, seed=3, pathloss_model_name="UMa",
        power_W=10.0, harq_bler=0.3, harq_max_retx=2))
    fns = sim.episode_fns(telemetry=True)
    _, _, telem = fns.rollout(sim.episode_static(),
                              sim.init_episode_state(jax.random.PRNGKey(0)),
                              60)
    nacks = np.asarray(telem.harq_nacks).sum()
    retx = np.asarray(telem.harq_retx).sum()
    assert nacks > 0, "bler=0.3 x 60 TTIs must NACK"
    assert retx > 0, "NACKed TBs must retransmit"
    # every retx attempt was once a pending (previously NACKed) TB
    assert retx <= nacks


def test_telemetry_handover_counter_fires_under_mobility():
    """Fast walkers + zero hysteresis + 1-TTI TTT: A3 must fire, and the
    counter must match the serving-cell trajectory's change count."""
    sim = CRRM(CRRM_parameters(
        n_ues=32, n_cells=6, seed=3, pathloss_model_name="UMa",
        power_W=10.0, ho_enabled=True, ho_hysteresis_db=0.0, ho_ttt_tti=1,
        mobility_step_m=150.0))
    fns = sim.episode_fns(telemetry=True)
    state0 = sim.init_episode_state(jax.random.PRNGKey(2))
    state, _, telem = fns.rollout(sim.episode_static(), state0, 80)
    ho = np.asarray(telem.ho_events)
    assert (ho >= 0).all()
    assert ho.sum() > 0, "fast walkers at 0 dB hysteresis must hand over"
    # cross-check against a stepwise serving-cell trajectory
    step_state, changes = state0, 0
    for _ in range(80):
        prev = np.asarray(step_state.serving)
        step_state, _, _ = fns.step(sim.episode_static(), step_state)
        changes += int((np.asarray(step_state.serving) != prev).sum())
    assert int(ho.sum()) == changes


# ------------------------------------------------------------- dirty rows
def test_dirty_row_counter_equals_mover_window():
    """radio_mode=incremental with mobility_move_frac: every TTI's
    dirty_rows equals the window size max(1, round(frac * n_ues))."""
    n_ues = 24
    for frac in (0.1, 0.25):
        sim = CRRM(_shrink("dense_urban_twin", n_ues=n_ues,
                           mobility_move_frac=frac))
        fns = sim.episode_fns(telemetry=True)
        _, _, telem = fns.rollout(
            sim.episode_static(),
            sim.init_episode_state(jax.random.PRNGKey(0)), 10)
        expect = max(1, int(round(frac * n_ues)))
        assert telem.dirty_rows is not None
        np.testing.assert_array_equal(np.asarray(telem.dirty_rows),
                                      np.full(10, expect, np.int32))


def test_dirty_rows_is_none_outside_incremental_mode():
    sim = CRRM(_shrink("dense_urban"))
    fns = sim.episode_fns(telemetry=True)
    _, _, telem = fns.rollout(sim.episode_static(),
                              sim.init_episode_state(jax.random.PRNGKey(0)),
                              5)
    assert telem.dirty_rows is None


# ------------------------------------------------------------ env + vmap
def test_env_step_returns_info_dict_and_matches_plain_env():
    from repro.env import CrrmEnv

    mk = dict(scenario="dense_urban",
              scenario_overrides=dict(n_ues=24, n_cells=6),
              episode_tti=20, tti_per_step=10)
    env0 = CrrmEnv(**mk)
    env1 = CrrmEnv(telemetry=True, **mk)
    key = jax.random.PRNGKey(0)
    s0, _ = env0.reset(key)
    s1, _ = env1.reset(key)
    s0, obs0, rew0, done0 = env0.step(s0)
    s1, obs1, rew1, done1, info = env1.step(s1)
    np.testing.assert_array_equal(np.asarray(obs1.tput),
                                  np.asarray(obs0.tput))
    assert float(rew1) == float(rew0)
    telem = info["telemetry"]
    assert telem.jain.shape == (10,)


def test_env_batched_telemetry_under_vmap_is_structural_noop():
    """vmapped batch: telemetry leaves gain the batch axis and the
    trajectory still matches the telemetry-off batch bit-exactly."""
    from repro.env import CrrmEnv

    mk = dict(scenario="dense_urban_mobile",
              scenario_overrides=dict(n_ues=24, n_cells=6),
              episode_tti=16, tti_per_step=8)
    env0 = CrrmEnv(**mk)
    env1 = CrrmEnv(telemetry=True, **mk)
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    s0, _ = env0.reset_batch(keys)
    s1, _ = env1.reset_batch(keys)
    s0, obs0, rew0, _ = env0.step_batch(s0)
    s1, obs1, rew1, _, info = env1.step_batch(s1)
    np.testing.assert_array_equal(np.asarray(obs1.tput),
                                  np.asarray(obs0.tput))
    np.testing.assert_array_equal(np.asarray(rew1), np.asarray(rew0))
    telem = info["telemetry"]
    assert telem.jain.shape == (4, 8)
    assert telem.served_bits.shape == (4, 8, 6)
    kpis = summarize(telem, tti_s=env1.params.tti_s)
    assert kpis["served_mbits"] > 0.0


def test_gym_adapter_surfaces_kpis_in_info():
    gymnasium = pytest.importorskip("gymnasium")  # noqa: F841
    from repro.env import CrrmEnv
    from repro.env.gym_adapter import make_gym_env

    env = CrrmEnv(scenario="dense_urban",
                  scenario_overrides=dict(n_ues=16, n_cells=4),
                  episode_tti=10, tti_per_step=5, telemetry=True)
    genv = make_gym_env(env, seed=0)
    genv.reset()
    _, _, _, _, info = genv.step(genv.action_space.sample())
    assert "kpis" in info and "telemetry" in info
    assert isinstance(info["kpis"]["served_mbits"], float)


# ------------------------------------------------------------- 2-dev mesh
_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

mesh = jax.make_mesh((2,), ("ue",))
base = dict(n_ues=64, n_cells=7, seed=3, pathloss_model_name="UMa",
            power_W=10.0, scheduler_policy="rr", harq_bler=0.1,
            traffic_model="poisson",
            traffic_params=dict(arrival_rate_hz=300.0,
                                packet_size_bits=12_000.0))
kw = dict(mobility_step_m=20.0, mobility_move_frac=0.125,
          radio_mode="incremental")
key = jax.random.PRNGKey(0)

# sharded: telemetry on == off bitwise (the structural-no-op claim holds
# under shard_map too)
a, b = CRRM(CRRM_parameters(**base)), CRRM(CRRM_parameters(**base))
f_off = a.episode_fns(mesh=mesh, **kw)
f_on = b.episode_fns(mesh=mesh, telemetry=True, **kw)
s1, t1 = f_off.rollout(a.episode_static(), a.init_episode_state(key), 30)
s2, t2, telem = f_on.rollout(b.episode_static(),
                             b.init_episode_state(key), 30)
np.testing.assert_array_equal(np.asarray(t2), np.asarray(t1))
for l1, l2 in zip(jax.tree_util.tree_leaves(s1),
                  jax.tree_util.tree_leaves(s2)):
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
print("OK sharded noop")

# psum correctness: the sharded KPIs are GLOBAL -- they match the
# single-device telemetry (integer counters bitwise; float KPIs to the
# sharded suite's usual 1e-5, pf-free rr regime here)
c = CRRM(CRRM_parameters(**base))
_, t3, telem1 = c.episode_fns(telemetry=True, **kw).rollout(
    c.episode_static(), c.init_episode_state(key), 30)
np.testing.assert_array_equal(np.asarray(t3), np.asarray(t1))
for name in ("harq_acks", "harq_nacks", "harq_retx", "ho_events",
             "dirty_rows"):
    np.testing.assert_array_equal(
        np.asarray(getattr(telem, name)), np.asarray(getattr(telem1, name)),
        err_msg=name)
# 12.5% of 64 UEs -> 8 dirty rows per TTI, globally, on both layouts
np.testing.assert_array_equal(np.asarray(telem.dirty_rows),
                              np.full(30, 8, np.int32))
for name in ("served_bits", "granted_rb", "dropped_bits", "buffer_bits",
             "jain"):
    np.testing.assert_allclose(
        np.asarray(getattr(telem, name)), np.asarray(getattr(telem1, name)),
        rtol=1e-5, atol=1e-3, err_msg=name)
print("ALL_OK")
"""


@pytest.mark.slow
def test_telemetry_on_two_device_mesh():
    """Acceptance: telemetry under shard_map is (a) still a structural
    no-op and (b) psum-reduced to the same global KPIs as one device."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_OK" in out.stdout


# -------------------------------------------------------- retrace counter
def test_compile_counter_catches_shape_polymorphic_calls():
    """The profiling satellite: a jitted fn fed varying shapes recompiles
    per call and the counter must see it; steady-state calls must not."""
    f = jax.jit(lambda x: (x * 2.0).sum())
    f(jnp.zeros(4))                       # pay the first compile outside
    with CompileCounter() as steady:
        for _ in range(3):
            f(jnp.ones(4))
    with CompileCounter() as poly:
        for n in (5, 6, 7):               # classic silent-retrace bug
            f(jnp.ones(n))
    if not steady.supported:
        pytest.skip("jax.monitoring compile events unavailable")
    assert steady.count == 0
    assert poly.count >= 3


def test_retrace_watch_on_engine_executables():
    sim = CRRM(_shrink("dense_urban"))
    fns = sim.episode_fns(telemetry=True)
    static, state = sim.episode_static(), sim.init_episode_state()
    fns.rollout(static, state, 5)         # warm the one expected entry
    watch = RetraceWatch(rollout=fns.rollout)
    for _ in range(3):
        state, _, _ = fns.rollout(static, state, 5)
    watch.assert_stable()                 # steady state: no new traces
    fns.rollout(static, state, 7)         # a new n_tti IS a new trace
    assert watch.retraces().get("rollout", 0) >= 1
