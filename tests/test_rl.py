"""ISSUE 8: PPO baselines + differentiable-CRRM acceptance tests.

The two pillars of ``repro.rl`` and their contracts:

* differentiability -- ``jax.grad`` through the relaxed engine matches
  central finite differences to <= 1e-3 relative error on two registry
  scenarios, and turning every relaxation flag off reproduces the legacy
  engine BITWISE (the relax machinery must be a pure trace-time switch);
* PPO -- the train step is finite and learns, the whole training state
  checkpoints and resumes bitwise, and the env surfaces the per-cell
  reward components / KPI telemetry the policy consumes (under vmap).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.env import CrrmEnv
from repro.env.crrm_env import expand_action
from repro.sim.radio import RelaxConfig
from repro.sim.scenarios import make_scenario


def _uniform_grid(sim):
    """The engine-shaped (n_cells, n_freq) uniform power action."""
    p = sim.params
    a = jnp.full((sim.n_cells, p.n_subbands), p.power_W / p.n_subbands,
                 jnp.float32)
    return expand_action(p, a)


def _objective(sim, relax, n_tti):
    fns = sim.episode_fns(radio_mode="dense", relax=relax)
    static = sim.episode_static()
    state0 = sim.init_episode_state(jax.random.PRNGKey(0))

    def f(P):
        _, tput = fns.rollout(static, state0, n_tti, P)
        return tput.mean() / 1e6

    return f


# ---------------------------------------------------------------- gradients
@pytest.mark.parametrize("scenario", ["dense_urban", "handover_stress"])
def test_grad_matches_finite_differences(scenario):
    """Directional derivative of grad(rollout) vs central differences.

    Per-coordinate FD is hopeless on the tiny components of a rollout
    gradient (the quantised engine's surrogate is only piecewise
    smooth), but the directional derivative along a fixed random
    direction is the standard well-conditioned check: best-over-eps
    relative error must be <= 1e-3 (ISSUE 8 acceptance).
    """
    sim = CRRM(make_scenario(scenario, n_ues=12))
    f = _objective(sim, RelaxConfig(), n_tti=8)
    P0 = _uniform_grid(sim)
    g = jax.grad(f)(P0)
    assert bool(jnp.isfinite(g).all()), "non-finite gradient"
    v = jax.random.normal(jax.random.PRNGKey(1), P0.shape, jnp.float32)
    v = v / jnp.linalg.norm(v) * jnp.linalg.norm(P0)
    gv = float(jnp.vdot(g, v))
    best = float("inf")
    for releps in (1e-1, 3e-2, 1e-2, 3e-3):
        eps = releps
        fd = (f(P0 + eps * v) - f(P0 - eps * v)) / (2 * eps)
        err = abs(gv - float(fd)) / max(abs(float(fd)), 1e-12)
        best = min(best, err)
    assert best <= 1e-3, (f"{scenario}: grad/FD directional mismatch "
                          f"{best:.2e} (g.v={gv:.4g})")


def test_relax_flags_off_is_bitwise_legacy():
    """Every relaxation off => the forward pass is the legacy engine,
    bitwise.  This is the trace-time-switch contract: the differentiable
    plumbing (plain-scatter segment reductions, finite -inf sentinels,
    served-bits floor) must be exact rewrites of the hard path."""
    sim = CRRM(make_scenario("dense_urban", n_ues=10))
    off = RelaxConfig(soft_attach=False, cqi_mode="hard",
                      soft_sched=False)
    fns_off = sim.episode_fns(radio_mode="dense", relax=off)
    fns_legacy = sim.episode_fns(radio_mode="dense")
    static = sim.episode_static()
    state0 = sim.init_episode_state(jax.random.PRNGKey(2))
    P = _uniform_grid(sim)
    s_off, t_off = fns_off.rollout(static, state0, 6, P)
    s_leg, t_leg = fns_legacy.rollout(static, state0, 6, P)
    assert bool((t_off == t_leg).all())
    for a, b in zip(jax.tree_util.tree_leaves(s_off),
                    jax.tree_util.tree_leaves(s_leg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ste_forward_matches_hard_with_nonzero_grad():
    """Straight-through CQI: forward ~= the hard staircase (exact up to
    the a+(b-a) float round-trip) while the backward pass carries the
    soft surrogate's nonzero gradient."""
    sim = CRRM(make_scenario("dense_urban", n_ues=10))
    ste = RelaxConfig(soft_attach=False, cqi_mode="ste",
                      soft_sched=False)
    f_ste = _objective(sim, ste, n_tti=4)
    f_hard = _objective(sim, None, n_tti=4)
    P0 = _uniform_grid(sim)
    np.testing.assert_allclose(float(f_ste(P0)), float(f_hard(P0)),
                               rtol=1e-6)
    g = jax.grad(f_ste)(P0)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0.0, "STE gradient vanished"


def test_soft_max_cqi_allocator_properties():
    """The softmax share allocator: full n_rb budget split over the
    active attached UEs of each nonempty cell, nothing to inactive UEs,
    and -> the hard argmax allocation as tau -> 0."""
    from repro.mac import scheduler as mac_sched

    n_ue, n_cells, n_rb = 8, 3, 12
    key = jax.random.PRNGKey(0)
    se = jax.random.uniform(key, (n_ue,), jnp.float32, 0.1, 5.0)
    a = jnp.array([0, 0, 0, 1, 1, 2, 2, 2], jnp.int32)
    active = jnp.array([1, 1, 1, 1, 0, 1, 1, 1], bool)
    alloc = mac_sched.allocate_max_cqi_soft(active, se, a, n_cells, n_rb,
                                            tau=1.0)
    assert bool((alloc[~active] == 0.0).all())
    per_cell = jnp.zeros(n_cells).at[a].add(alloc)
    np.testing.assert_allclose(np.asarray(per_cell),
                               np.full(n_cells, float(n_rb)), rtol=1e-5)
    # tau -> 0 recovers winner-takes-all on each cell's best active UE
    sharp = mac_sched.allocate_max_cqi_soft(active, se, a, n_cells, n_rb,
                                            tau=1e-4)
    hard = np.zeros(n_ue, np.float32)
    for c in range(n_cells):
        ues = [u for u in range(n_ue) if int(a[u]) == c and bool(active[u])]
        hard[max(ues, key=lambda u: float(se[u]))] = n_rb
    np.testing.assert_allclose(np.asarray(sharp), hard, atol=1e-3)


# ---------------------------------------------------------- engine guards
def test_mesh_churn_errors_at_construction():
    from jax.sharding import Mesh

    sim = CRRM(make_scenario("dense_urban", n_ues=8))
    from repro.sim.mobility import ChurnConfig
    churn = ChurnConfig(arrival_rate_hz=10.0, mean_lifetime_s=1.0,
                        max_arrivals_per_tti=2)
    mesh = Mesh(np.array(jax.devices()[:1]), ("ue",))
    with pytest.raises(ValueError,
                       match="mesh.*churn.*unsupported|cross-shard"):
        sim.episode_fns(mesh=mesh, churn=churn)


def test_relax_combination_guards():
    from jax.sharding import Mesh

    from repro.sim.mobility import ChurnConfig

    sim = CRRM(make_scenario("dense_urban", n_ues=8))
    mesh = Mesh(np.array(jax.devices()[:1]), ("ue",))
    churn = ChurnConfig(arrival_rate_hz=10.0, mean_lifetime_s=1.0,
                        max_arrivals_per_tti=2)
    with pytest.raises(ValueError, match="relax"):
        sim.episode_fns(mesh=mesh, relax=RelaxConfig())
    with pytest.raises(ValueError, match="relax"):
        sim.episode_fns(churn=churn, relax=RelaxConfig())
    with pytest.raises(ValueError, match="dense"):
        sim.episode_fns(radio_mode="incremental", relax=RelaxConfig())


# ------------------------------------------------------------------- env
def _tiny_env(**kw):
    kw.setdefault("scenario", "dense_urban")
    kw.setdefault("scenario_overrides", dict(n_ues=8))
    kw.setdefault("episode_tti", 6)
    kw.setdefault("tti_per_step", 3)
    kw.setdefault("telemetry", True)
    return CrrmEnv(**kw)


def test_batched_kpis_and_reward_components():
    """Satellite 1 regression: telemetry KPIs + per-cell reward
    components flow through step_batch (vmap) with a leading batch axis,
    and summarize() reduces them to the logger KPIs."""
    from repro.obs import summarize

    env = _tiny_env()
    B = 3
    states, _ = env.reset_batch(jax.random.split(jax.random.PRNGKey(0), B))
    acts = jnp.stack([env.uniform_action()] * B)
    states, obs, rew, done, info = env.step_batch(states, acts)
    telem = info["telemetry"]
    assert telem.served_bits.shape == (B, env.tti_per_step, env.n_cells)
    rc = info["reward_components"]
    assert rc["cell_tput_mbps"].shape == (B, env.n_cells)
    assert rc["cell_granted_rb"].shape == (B, env.n_cells)
    assert rc["goodput_term"].shape == (B,)
    assert bool(jnp.isfinite(rc["cell_tput_mbps"]).all())
    # the two scalar terms ARE the default reward, per batch element
    np.testing.assert_allclose(
        np.asarray(rc["goodput_term"] - rc["queue_penalty"]),
        np.asarray(rew), rtol=1e-5)
    kpis = summarize(telem, tti_s=env.params.tti_s)
    assert "mean_jain" in kpis and 0.0 <= kpis["mean_jain"] <= 1.0


def test_churn_env_exposes_mean_active_ues():
    from repro.obs import summarize
    from repro.sim.mobility import ChurnConfig

    env = _tiny_env(scenario_overrides=dict(n_ues=12),
                    churn=ChurnConfig(arrival_rate_hz=100.0,
                                      mean_lifetime_s=0.05,
                                      max_arrivals_per_tti=2))
    B = 2
    states, _ = env.reset_batch(jax.random.split(jax.random.PRNGKey(0), B))
    for _ in range(3):
        states, obs, rew, done, info = env.step_batch(
            states, jnp.stack([env.uniform_action()] * B))
    kpis = summarize(info["telemetry"], tti_s=env.params.tti_s)
    assert "mean_active_ues" in kpis
    assert 0.0 < kpis["mean_active_ues"] <= 12.0


def test_gym_adapter_kpis_include_components():
    gym = pytest.importorskip("gymnasium")
    del gym
    from repro.env.gym_adapter import make_gym_env

    genv = make_gym_env(_tiny_env(), seed=0)
    genv.reset()
    _, _, _, truncated, info = genv.step(
        np.asarray(_tiny_env().uniform_action()))
    kpis = info["kpis"]
    assert "mean_jain" in kpis
    assert isinstance(kpis["reward/goodput_term"], float)
    assert kpis["reward/cell_tput_mbps"].shape == (21,)


def test_step_autoreset_wraps_episode():
    env = _tiny_env(telemetry=False)
    state, _ = env.reset(jax.random.PRNGKey(0))
    rkey = jax.random.PRNGKey(9)
    state, _, _, done = env.step_autoreset(state, env.uniform_action(),
                                           rkey)
    assert not bool(done) and int(state.t) == 3
    state, _, _, done = env.step_autoreset(state, env.uniform_action(),
                                           rkey)
    # horizon hit: done reported, carried state already reset
    assert bool(done) and int(state.t) == 0
    assert bool((state.key == env.reset(rkey)[0].key).all())


# ------------------------------------------------------------------- ppo
def _ppo_fixture():
    from repro import rl
    from repro.rl import policy as pol

    env = _tiny_env(episode_tti=8, tti_per_step=4)
    pcfg = pol.PolicyConfig(n_cells=env.n_cells,
                            n_subbands=env.n_subbands,
                            power_W=env.max_cell_power_W)
    cfg = rl.PPOConfig(n_envs=2, n_steps=4)
    return env, pcfg, cfg


def test_ppo_train_step_finite():
    from repro import rl

    env, pcfg, cfg = _ppo_fixture()
    ts = rl.ppo_init(env, pcfg, cfg, seed=0)
    step = rl.make_train_step(env, pcfg, cfg)
    for _ in range(2):
        ts, metrics = step(ts)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["mean_reward"]))
    assert int(ts.iteration) == 2
    uplift, learned, fixed = rl.evaluate_uplift(
        env, pcfg, ts.params, jax.random.PRNGKey(1), n_steps=2)
    assert learned > 0.0 and fixed > 0.0 and uplift > 0.0


def test_ppo_checkpoint_resume_is_bitwise(tmp_path):
    """4 uninterrupted iterations == 2 + save/restore + 2, bitwise: the
    whole TrainState (params, Adam moments, env states, PRNG) is the
    checkpoint, so preemption cannot perturb training."""
    from repro import rl

    env, pcfg, cfg = _ppo_fixture()
    ts_a, _ = rl.train(env, pcfg, cfg, iterations=4, seed=0)

    d = str(tmp_path / "ckpt")
    rl.train(env, pcfg, cfg, iterations=2, seed=0, ckpt_dir=d,
             ckpt_every=1)
    ts_b, _ = rl.train(env, pcfg, cfg, iterations=4, seed=0, ckpt_dir=d,
                       ckpt_every=1)
    assert int(ts_b.iteration) == 4
    for a, b in zip(jax.tree_util.tree_leaves(ts_a),
                    jax.tree_util.tree_leaves(ts_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_power_baseline_smoke(tmp_path):
    """The bench recipe end-to-end at micro shapes: eval selection,
    checkpointing, and the result-dict contract of BENCH_rl.json."""
    from repro.rl import ppo

    out = ppo.train_power_baseline(
        "dense_urban", n_ues=8, iterations=2, eval_every=1, n_envs=2,
        n_steps=2, tti_per_step=3, episode_tti=6,
        ckpt_dir=str(tmp_path / "ck"))
    assert len(out["history"]) == 2
    assert "uplift" in out["history"][-1]
    assert out["best_uplift"] >= out["final_uplift"] - 1e-9
    assert out["fixed_mbits"] > 0.0
    # the checkpoint landed and a re-call resumes instead of retraining
    from repro.train import checkpoint
    assert checkpoint.latest_step(str(tmp_path / "ck")) == 2
    out2 = ppo.train_power_baseline(
        "dense_urban", n_ues=8, iterations=2, eval_every=1, n_envs=2,
        n_steps=2, tti_per_step=3, episode_tti=6,
        ckpt_dir=str(tmp_path / "ck"))
    assert out2["history"] == []          # nothing left to train


def test_collect_requires_telemetry():
    from repro import rl
    from repro.rl import policy as pol

    env = _tiny_env(telemetry=False)
    pcfg = pol.PolicyConfig(n_cells=env.n_cells,
                            n_subbands=env.n_subbands,
                            power_W=env.max_cell_power_W)
    with pytest.raises(ValueError, match="telemetry"):
        rl.make_collect_fn(env, pcfg, 4)


# --------------------------------------------------------------- diffopt
def test_diffopt_improves_soft_objective():
    from repro.rl import diffopt

    sim = CRRM(make_scenario("dense_urban", n_ues=10))
    res = diffopt.optimize_power_plan(sim, n_segments=2,
                                      tti_per_segment=4, steps=6,
                                      lr=0.3, score_every=0)
    assert res.u_plan.shape == (2, sim.n_cells, sim.params.n_subbands)
    soft = [h["soft_mbps"] for h in res.history]
    assert all(np.isfinite(soft))
    assert soft[-1] >= soft[0] - 1e-6, (
        f"gradient ascent went downhill: {soft[0]:.4f} -> {soft[-1]:.4f}")
    # power plans are feasible: within budget after the clamp
    per_cell = np.asarray(res.power_plan).sum(axis=-1)
    assert (per_cell <= sim.params.power_W * (1 + 1e-5)).all()
