"""Mobility models: teleport moves and bounded random walks."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.mobility import random_moves, random_walk


def test_random_moves_is_teleport_no_step_param():
    """Regression: random_moves used to accept (and ignore) a step_m arg."""
    assert "step_m" not in inspect.signature(random_moves).parameters
    idx, xyz = random_moves(jax.random.PRNGKey(0), 100, 10, 3000.0)
    idx, xyz = np.asarray(idx), np.asarray(xyz)
    assert idx.shape == (10,) and xyz.shape == (10, 3)
    assert len(set(idx.tolist())) == 10          # distinct UEs
    assert (xyz[:, :2] >= 0.0).all() and (xyz[:, :2] <= 3000.0).all()


def test_random_walk_respects_step_bounds_and_clipping():
    key = jax.random.PRNGKey(1)
    pos = jnp.asarray(np.column_stack([
        np.random.default_rng(0).uniform(0, 1000, (50, 2)),
        np.full(50, 1.5)]).astype(np.float32))
    idx = jnp.arange(50)
    step = 30.0
    new = np.asarray(random_walk(key, pos, idx, step, 1000.0))
    d = new[:, :2] - np.asarray(pos)[:, :2]
    assert (np.abs(d) <= step + 1e-4).all()
    np.testing.assert_allclose(new[:, 2], 1.5)

    # clipping at the border: start in a corner, huge step
    corner = jnp.asarray([[0.5, 0.5, 1.5]], dtype=jnp.float32)
    out = np.asarray(random_walk(key, corner, jnp.arange(1), 5000.0, 1000.0))
    assert (out[:, :2] >= 0.0).all() and (out[:, :2] <= 1000.0).all()
