"""Paper example 13: smart update must be numerically identical to the full
recalculation, and faster in the 10% mobility regime."""
import time

import jax
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim.mobility import random_moves


def _pair(n_ues=80, n_cells=24, **kw):
    common = dict(n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=11,
                  pathloss_model_name="UMa", power_W=10.0, **kw)
    return (CRRM(CRRM_parameters(smart=True, **common)),
            CRRM(CRRM_parameters(smart=False, **common)))


def test_identical_results_over_random_mutation_sequence():
    smart, full = _pair(n_subbands=2, fairness_p=0.5)
    key = jax.random.PRNGKey(0)
    for step in range(6):
        key, k = jax.random.split(key)
        idx, xyz = random_moves(k, 80, 8, 3000.0)
        smart.move_UEs(np.asarray(idx), np.asarray(xyz))
        full.move_UEs(np.asarray(idx), np.asarray(xyz))
        np.testing.assert_allclose(np.asarray(smart.get_UE_throughputs()),
                                   np.asarray(full.get_UE_throughputs()),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(smart.get_SINR()),
                                   np.asarray(full.get_SINR()),
                                   rtol=1e-3)
        assert (np.asarray(smart.get_attachment())
                == np.asarray(full.get_attachment())).all()


def test_power_change_propagates():
    smart, full = _pair()
    smart.get_UE_throughputs()
    full.get_UE_throughputs()
    for sim in (smart, full):
        sim.set_cell_power(0, 0, 0.01)
    np.testing.assert_allclose(np.asarray(smart.get_UE_throughputs()),
                               np.asarray(full.get_UE_throughputs()),
                               rtol=1e-4, atol=1e-3)


def test_update_counters_show_row_reuse():
    smart, _ = _pair()
    smart.get_UE_throughputs()
    smart.move_UE(3, (10.0, 20.0, 1.5))
    smart.get_UE_throughputs()
    counts = smart.update_counts()
    assert counts["D"] == (1, 1)      # one full, one row update
    assert counts["G"] == (1, 1)
    assert counts["Shannon"] == (0, 0)  # lazy: never queried


@pytest.mark.slow
def test_speedup_at_ten_percent_mobility():
    """Wall-clock reproduction of the paper's >=2x claim (CI-safe bound)."""
    def run(smart):
        sim = CRRM(CRRM_parameters(
            n_ues=3000, n_cells=300, n_sectors=1, seed=3, smart=smart,
            pathloss_model_name="UMa", power_W=10.0))
        sim.get_UE_throughputs()
        key = jax.random.PRNGKey(42)
        moves = []
        for _ in range(8):
            key, k = jax.random.split(key)
            i, x = random_moves(k, 3000, 300, 3000.0)
            moves.append((np.asarray(i), np.asarray(x)))
        for i, x in moves[:2]:   # warm the row-bucket compile
            sim.move_UEs(i, x)
            sim.get_UE_throughputs().block_until_ready()
        t0 = time.perf_counter()
        for i, x in moves[2:]:
            sim.move_UEs(i, x)
            out = sim.get_UE_throughputs()
        out.block_until_ready()
        return time.perf_counter() - t0

    t_smart = run(True)
    t_full = run(False)
    assert t_full / t_smart > 1.5, \
        f"smart update speedup only x{t_full/t_smart:.2f}"
