"""Elastic scaling: a checkpoint written on one mesh restores onto another.

Saved leaves are host-gathered full arrays, so restore re-device_puts onto
whatever mesh the resumed job runs -- here a 1-device save restored onto a
(2, 2) fake mesh in a subprocess (device count must be fixed pre-jax-init).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt

mesh = jax.make_mesh((2, 2), ("data", "model"))
target = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
sh = {"w": NamedSharding(mesh, P("data", "model")),
      "b": NamedSharding(mesh, P("model"))}
tree, extra = ckpt.restore(sys.argv[1], 5, target, sh)
assert extra["note"] == "elastic"
assert tree["w"].sharding.num_devices == 4
np.testing.assert_array_equal(np.asarray(tree["w"]),
                              np.arange(32.).reshape(8, 4))
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_checkpoint_restores_onto_larger_mesh(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.arange(32.0).reshape(8, 4), "b": jnp.ones((4,))}
    ckpt.save(d, 5, tree, extra={"note": "elastic"})
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT, d], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + "\n" + r.stderr
