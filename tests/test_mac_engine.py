"""Engine-level invariants: HARQ state machine, A3 handover, per-RB link
adaptation, determinism, and wideband-equivalence regressions (ISSUE 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.mac import engine as mac_engine


def _sim(**kw):
    base = dict(n_ues=30, n_cells=4, seed=7, pathloss_model_name="UMa",
                power_W=10.0)
    base.update(kw)
    return CRRM(CRRM_parameters(**base))


# ------------------------------------------------------------------- HARQ
def test_harq_fail_prob_monotone_in_retx():
    """Soft combining: conditional BLER non-increasing, delivery monotone."""
    retx = jnp.arange(8)
    p = np.asarray(mac_engine.harq_fail_prob(0.6, 3.0, retx))
    assert (np.diff(p) < 0).all(), p           # strictly better per combine
    assert ((0 <= p) & (p <= 1)).all()
    # zero combining gain: plain stop-and-wait, constant conditional BLER
    p0 = np.asarray(mac_engine.harq_fail_prob(0.6, 0.0, retx))
    np.testing.assert_allclose(p0, 0.6, rtol=1e-6)


def test_harq_soft_combining_raises_delivered_throughput():
    """More combining gain -> fewer residual losses -> more delivered bits."""
    t_lo = np.asarray(_sim(harq_bler=0.5, harq_comb_gain_db=0.0,
                           harq_max_retx=3).run_episode(300)).mean()
    t_hi = np.asarray(_sim(harq_bler=0.5, harq_comb_gain_db=6.0,
                           harq_max_retx=3).run_episode(300)).mean()
    assert t_hi > t_lo * 1.05, (t_lo, t_hi)


def test_harq_served_bits_never_exceed_offered_traffic():
    """Delivered bits <= offered bits, with every loss path engaged."""
    sim = _sim(traffic_model="poisson", harq_bler=0.4, harq_max_retx=2,
               traffic_params=dict(arrival_rate_hz=0.0))
    offered = np.full(30, 5e4, np.float32)
    sim.set_backlog(offered)
    tput = np.asarray(sim.run_episode(n_tti=300))
    delivered = tput.sum(axis=0) * sim.params.tti_s
    # in-flight/dropped TBs make delivery strictly partial, never excess
    assert (delivered <= offered + 1.0).all()
    assert delivered.sum() > 0.0


def test_harq_retx_count_never_exceeds_max():
    """Walk the machine TTI by TTI; the carried retx state stays bounded."""
    for max_retx in (0, 1, 3):
        sim = _sim(n_ues=20, harq_bler=0.7, harq_max_retx=max_retx, seed=3)
        key = jax.random.PRNGKey(0)
        for t in range(40):
            sim.run_episode(n_tti=1, key=jax.random.fold_in(key, t))
            retx = np.asarray(sim._harq_retx)
            assert ((0 <= retx) & (retx <= max_retx)).all(), (max_retx, retx)
            if max_retx == 0:      # no retx allowed: nothing ever pending
                assert (np.asarray(sim._harq_bits) == 0).all()


def test_harq_bler_zero_machine_is_bitexact_with_fast_path():
    """The HARQ machine at bler=0 must reproduce the HARQ-free (PR-1)
    episode bit-exactly -- same grants, same drains, same PRNG streams."""
    key = jax.random.PRNGKey(42)
    a = _sim(traffic_model="poisson", seed=5)
    b = _sim(traffic_model="poisson", seed=5)
    t_fast = np.asarray(a.run_episode(n_tti=100, key=key, use_harq=False))
    t_machine = np.asarray(b.run_episode(n_tti=100, key=key, use_harq=True))
    np.testing.assert_array_equal(t_fast, t_machine)
    np.testing.assert_array_equal(np.asarray(a.get_backlog()),
                                  np.asarray(b.get_backlog()))


def test_harq_ungranted_retx_waits_without_delivering():
    """A pending TB needs RBs to retransmit: under max_cqi winner-take-all
    the losing UE's process must stall -- no zero-resource delivery, no
    retx-count advance."""
    ue = np.array([[50.0, 0.0, 1.5], [400.0, 0.0, 1.5]], np.float32)
    cell = np.array([[0.0, 0.0, 25.0]], np.float32)
    sim = CRRM(CRRM_parameters(
        n_ues=2, ue_positions=ue, cell_positions=cell,
        pathloss_model_name="UMa", power_W=10.0, scheduler_policy="max_cqi",
        harq_bler=0.5, harq_max_retx=3))
    # UE 1 (far, lower CQI) has a pending TB; UE 0 wins the whole grid
    sim._harq_bits = jnp.asarray([0.0, 1234.0], jnp.float32)
    sim._harq_retx = jnp.asarray([0, 2], jnp.int32)
    tput = np.asarray(sim.run_episode(n_tti=1))
    assert tput[0, 1] == 0.0                       # no bits without a grant
    assert float(np.asarray(sim._harq_bits)[1]) == 1234.0   # still pending
    assert int(np.asarray(sim._harq_retx)[1]) == 2          # no attempt


def test_power_mutators_respect_rb_subband_grid():
    """set_power_matrix / set_cell_power keep the documented per-subband
    semantics when the grid is split into CQI subbands."""
    kw = dict(n_ues=12, n_cells=3, n_subbands=2, n_rb=12, n_rb_subbands=4,
              seed=4, pathloss_model_name="UMa")
    sim = CRRM(CRRM_parameters(power_W=10.0, **kw))
    pw = np.full((3, 2), 7.0, np.float32)
    sim.set_power_matrix(pw)                       # documented shape
    ref = CRRM(CRRM_parameters(power_matrix=pw, **kw))
    np.testing.assert_allclose(np.asarray(sim.P._data),
                               np.asarray(ref.P._data))
    np.testing.assert_allclose(np.asarray(sim.get_UE_throughputs()),
                               np.asarray(ref.get_UE_throughputs()),
                               rtol=1e-6)
    sim.set_cell_power(1, 1, 3.0)                  # subband index, not chunk
    P = np.asarray(sim.P._data)
    np.testing.assert_allclose(P[1, 4:], 3.0 / 4)  # subband 1 -> chunks 4..7
    np.testing.assert_allclose(P[1, :4], 7.0 / 4)   # subband 0 untouched
    with pytest.raises(ValueError, match="power matrix"):
        sim.set_power_matrix(np.ones((3, 3), np.float32))


def test_harq_legacy_lite_path_still_selectable():
    """use_harq=False keeps PR-1's Bernoulli HARQ-lite thinning."""
    sim = _sim(n_ues=40, harq_bler=0.5, seed=9)
    ref = _sim(n_ues=40, harq_bler=0.0, seed=9)
    t = float(np.asarray(sim.run_episode(400, use_harq=False)).mean())
    t0 = float(np.asarray(ref.run_episode(400)).mean())
    assert 0.35 < t / t0 < 0.65


def test_harq_recovers_throughput_vs_no_retx():
    """Retransmissions recover most of what Bernoulli dropping loses."""
    kw = dict(n_ues=40, seed=9, harq_bler=0.6, harq_comb_gain_db=6.0)
    t_machine = float(np.asarray(
        _sim(harq_max_retx=3, **kw).run_episode(400)).mean())
    t_drop = float(np.asarray(
        _sim(harq_max_retx=0, **kw).run_episode(400)).mean())
    assert t_machine > t_drop * 1.2, (t_machine, t_drop)


# -------------------------------------------------------------- handover
def test_a3_handover_hysteresis_and_ttt():
    """Unit semantics: margin gates entry, TTT gates firing, reset works."""
    a = jnp.zeros(1, jnp.int32)
    ttt = jnp.zeros(1, jnp.int32)
    weak = jnp.asarray([[1.0, 1.5]])     # +1.8 dB < 3 dB hysteresis
    strong = jnp.asarray([[1.0, 2.5]])   # +4 dB  > 3 dB hysteresis

    a1, t1 = mac_engine.a3_handover(a, ttt, weak, 3.0, 2)
    assert int(a1[0]) == 0 and int(t1[0]) == 0   # below margin: no entry

    a1, t1 = mac_engine.a3_handover(a, ttt, strong, 3.0, 2)
    assert int(a1[0]) == 0 and int(t1[0]) == 1   # entered, not yet fired
    a2, t2 = mac_engine.a3_handover(a1, t1, strong, 3.0, 2)
    assert int(a2[0]) == 1 and int(t2[0]) == 0   # fired after TTT TTIs
    # condition lapses mid-TTT: counter resets
    a3, t3 = mac_engine.a3_handover(a1, t1, weak, 3.0, 2)
    assert int(a3[0]) == 0 and int(t3[0]) == 0


def test_handover_fires_in_scan_and_respects_hysteresis():
    """A UE parked next to cell B but serving from cell A hands over inside
    the episode iff the A3 margin clears the hysteresis."""
    ue = np.array([[900.0, 0.0, 1.5]], np.float32)       # close to cell B
    cells = np.array([[0.0, 0.0, 25.0], [1000.0, 0.0, 25.0]], np.float32)

    def run(hyst_db):
        sim = CRRM(CRRM_parameters(
            n_ues=1, ue_positions=ue, cell_positions=cells,
            pathloss_model_name="UMa", power_W=10.0, ho_enabled=True,
            ho_hysteresis_db=hyst_db, ho_ttt_tti=3))
        sim._ho_serving = jnp.zeros(1, jnp.int32)        # pin serving to A
        sim.run_episode(n_tti=10)
        return int(np.asarray(sim._ho_serving)[0])

    assert run(3.0) == 1        # B is ~20+ dB stronger: hands over
    assert run(80.0) == 0       # absurd hysteresis: never triggers


def test_handover_ttt_delays_the_switch():
    ue = np.array([[900.0, 0.0, 1.5]], np.float32)
    cells = np.array([[0.0, 0.0, 25.0], [1000.0, 0.0, 25.0]], np.float32)
    sim = CRRM(CRRM_parameters(
        n_ues=1, ue_positions=ue, cell_positions=cells,
        pathloss_model_name="UMa", power_W=10.0, ho_enabled=True,
        ho_hysteresis_db=3.0, ho_ttt_tti=6))
    sim._ho_serving = jnp.zeros(1, jnp.int32)
    sim.run_episode(n_tti=5)                  # < TTT: must not have fired
    assert int(np.asarray(sim._ho_serving)[0]) == 0
    sim.run_episode(n_tti=5)                  # TTT satisfied across episodes
    assert int(np.asarray(sim._ho_serving)[0]) == 1


def test_handover_off_keeps_legacy_attachment():
    """ho_enabled=False episodes never deviate from the PR-1 engine."""
    key = jax.random.PRNGKey(3)
    a = _sim(seed=2)
    b = _sim(seed=2, ho_enabled=True, ho_hysteresis_db=0.0, ho_ttt_tti=1)
    t_off = np.asarray(a.run_episode(50, key=key))
    t_on = np.asarray(b.run_episode(50, key=key))
    # static channel, serving already the argmax: HO never fires, and the
    # HO-enabled program must converge on the same fixed point
    np.testing.assert_allclose(t_on, t_off, rtol=1e-5)


# ------------------------------------------- determinism and equivalence
def test_run_episode_is_bitwise_reproducible():
    key = jax.random.PRNGKey(123)
    kw = dict(n_ues=25, n_cells=4, seed=1, traffic_model="poisson",
              rayleigh_fading=True, harq_bler=0.3, ho_enabled=True,
              n_rb_subbands=4, pathloss_model_name="UMa", power_W=10.0)
    t1 = np.asarray(CRRM(CRRM_parameters(**kw)).run_episode(
        60, key=key, per_tti_fading=True, sync_state=False))
    t2 = np.asarray(CRRM(CRRM_parameters(**kw)).run_episode(
        60, key=key, per_tti_fading=True, sync_state=False))
    np.testing.assert_array_equal(t1, t2)


def test_per_rb_flat_channel_matches_wideband():
    """n_rb_subbands > 1 on a flat channel == the wideband engine (1e-5):
    the per-RB machinery must cost resolution only, not change physics.
    Full-buffer traffic keeps every UE active, so the comparison is free of
    the chaotic active-mask flips that 1-ulp backlog residues cause."""
    key = jax.random.PRNGKey(7)
    kw = dict(n_ues=30, n_cells=4, seed=7, scheduler_policy="pf",
              fairness_p=0.5, pathloss_model_name="UMa", power_W=10.0)
    wb = CRRM(CRRM_parameters(n_rb_subbands=1, **kw))
    rb = CRRM(CRRM_parameters(n_rb_subbands=4, **kw))
    t_wb = np.asarray(wb.run_episode(80, key=key))
    t_rb = np.asarray(rb.run_episode(80, key=key))
    np.testing.assert_allclose(t_rb, t_wb, rtol=1e-5, atol=1e-2)


def test_wideband_special_case_reproduces_legacy_fixed_point():
    """n_rb_subbands=1 + harq_bler=0 + handover off: the tentpole's
    acceptance gate -- the engine still lands on the PR-1 (legacy
    ThroughputNode) full-buffer PF fixed point."""
    sim = _sim(n_ues=50, n_cells=7, n_rb_subbands=1, harq_bler=0.0)
    legacy = np.asarray(sim.get_UE_throughputs())
    tput = np.asarray(sim.run_episode(n_tti=50))
    np.testing.assert_allclose(tput[-1], legacy, rtol=1e-5, atol=1e-2)


def test_per_rb_max_cqi_exploits_frequency_selectivity():
    """The point of per-RB CQI: on a frequency-selective channel the
    opportunistic scheduler rides each chunk's fading peak, while a
    channel-blind equal split averages over the fades."""
    kw = dict(n_ues=20, n_cells=3, seed=5, rayleigh_fading=True,
              n_rb_subbands=12, coherence_rb=1,
              pathloss_model_name="UMa", power_W=10.0)
    key = jax.random.PRNGKey(11)
    mx = CRRM(CRRM_parameters(scheduler_policy="max_cqi", **kw))
    rr = CRRM(CRRM_parameters(scheduler_policy="rr", **kw))
    t_mx = np.asarray(mx.run_episode(150, key=key, per_tti_fading=True))
    t_rr = np.asarray(rr.run_episode(150, key=key, per_tti_fading=True))
    assert t_mx.mean() > t_rr.mean() * 1.2, (t_rr.mean(), t_mx.mean())


def test_per_rb_episode_is_one_compiled_scan():
    sim = _sim(n_ues=20, n_rb_subbands=4, rayleigh_fading=True,
               harq_bler=0.2, ho_enabled=True)
    sim.get_served_throughputs()
    before = sim.update_counts()
    sim.run_episode(n_tti=50, per_tti_fading=True)
    after = sim.update_counts()
    assert after == before, "episode leaked per-TTI graph updates"


def test_everything_on_episode_is_finite_and_syncs_state():
    """Mobility + per-TTI selective fading + HARQ + handover + per-RB in
    one scan: finite output, bounded HARQ state, serving cells valid."""
    sim = _sim(n_ues=25, n_cells=7, n_rb_subbands=6, coherence_rb=2,
               rayleigh_fading=True, harq_bler=0.3, ho_enabled=True,
               traffic_model="poisson", seed=1)
    tput = np.asarray(sim.run_episode(n_tti=40, mobility_step_m=50.0,
                                      per_tti_fading=True))
    assert tput.shape == (40, 25) and np.isfinite(tput).all()
    assert (tput >= 0).all()
    serving = np.asarray(sim._ho_serving)
    assert ((0 <= serving) & (serving < sim.n_cells)).all()
    retx = np.asarray(sim._harq_retx)
    assert ((0 <= retx) & (retx <= sim.params.harq_max_retx)).all()


def test_add_traffic_accumulates_duplicate_indices():
    """Duplicate UE indices in one add_traffic call must sum, not last-win."""
    sim = _sim(n_ues=10, traffic_model="poisson")
    sim.set_backlog(np.zeros(10, np.float32))
    sim.add_traffic([4, 4, 7], [100.0, 200.0, 50.0])
    backlog = np.asarray(sim.get_backlog())
    assert backlog[4] == 300.0 and backlog[7] == 50.0
    assert backlog.sum() == 350.0


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        from repro.mac import scheduler as mac_sched
        mac_sched.allocate("bogus", jnp.ones((2, 1), bool),
                           jnp.ones((2, 1), jnp.int32),
                           jnp.zeros(2, jnp.int32), 1, 4, 0,
                           jnp.zeros((2, 1)))


def test_graph_sees_per_rb_spectral_efficiency():
    """Graph blocks resolve SE/CQI/alloc on the (n_ue, n_freq) grid and the
    RB budget is conserved at chunk granularity."""
    sim = _sim(n_ues=24, n_cells=3, n_rb=12, n_rb_subbands=4,
               coherence_rb=3, rayleigh_fading=True)
    se = np.asarray(sim.get_spectral_efficiency())
    assert se.shape == (24, 4)
    # frequency selectivity is visible: chunks differ for some UE
    assert (se.std(axis=1) > 0).any()
    alloc = np.asarray(sim.get_schedule())
    a = np.asarray(sim.get_attachment())
    for j in range(sim.n_cells):
        got = alloc[a == j].sum(axis=0)
        assert (got <= sim.params.rb_per_chunk + 1e-3).all()
