"""End-to-end training loop: loss decreases, checkpoint/resume is exact,
preemption-safe."""
import os

import jax
import numpy as np
import pytest

from test_archs_smoke import needs_optbar_grad

from repro.configs import get_config
from repro.models.registry import make_arch
from repro.parallel.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train import optim
from repro.train.data import SyntheticLM
from repro.train.loop import train


def _setup():
    cfg = get_config("qwen1.5-0.5b", reduced=True)
    arch = make_arch(cfg)
    opt = optim.adamw(optim.warmup_cosine(3e-3, 5, 60), weight_decay=0.0)
    mesh = make_host_mesh(1, 1)
    data = SyntheticLM(cfg.vocab_size, batch=4, seq_len=32, seed=0)
    return arch, opt, mesh, data


@needs_optbar_grad
@pytest.mark.slow
def test_loss_decreases_and_resume_is_exact(tmp_path):
    arch, opt, mesh, data = _setup()
    d = str(tmp_path / "ckpt")

    state, hist = train(arch, opt, mesh, data, steps=30, ckpt_dir=d,
                        ckpt_every=10, log_every=5)
    assert hist[-1] < hist[0] * 0.9, f"loss did not decrease: {hist}"
    assert ckpt.latest_step(d) == 30

    # resume from step 30 and continue to 40: must equal an uninterrupted
    # 40-step run (deterministic data + optimizer)
    state_resumed, _ = train(arch, opt, mesh, data, steps=40, ckpt_dir=d,
                             ckpt_every=100, log_every=5, resume=True)
    d2 = str(tmp_path / "ckpt2")
    state_full, _ = train(arch, opt, mesh, data, steps=40, ckpt_dir=d2,
                          ckpt_every=100, log_every=5, resume=False)
    pa = jax.tree_util.tree_leaves(state_resumed["params"])
    pb = jax.tree_util.tree_leaves(state_full["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
