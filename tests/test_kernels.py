"""Pallas kernel validation: interpret-mode vs the pure-jnp oracles,
sweeping shapes/dtypes (+ hypothesis property sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.sim.antenna import sector_boresights
from repro.sim.pathloss import make_pathloss


def _net(key, n, m, k, extent=5000.0):
    k1, k2 = jax.random.split(key)
    U = jnp.concatenate([jax.random.uniform(k1, (n, 2), maxval=extent),
                         jnp.full((n, 1), 1.5)], 1)
    C = jnp.concatenate([jax.random.uniform(k2, (m, 2), maxval=extent),
                         jnp.full((m, 1), 25.0)], 1)
    return U, C, jnp.full((m, k), 5.0)


@pytest.mark.parametrize("n,m", [(16, 16), (100, 37), (256, 130), (33, 257)])
def test_pairwise_dist_vs_ref(n, m):
    U, C, _ = _net(jax.random.PRNGKey(n * m), n, m, 1)
    d2a, d3a = ops.pairwise_dist(U, C, bn=32, bm=64)
    d2r, d3r = ref.pairwise_dist_ref(U, C)
    np.testing.assert_allclose(np.asarray(d3a), np.asarray(d3r),
                               rtol=1e-4, atol=0.2)
    np.testing.assert_allclose(np.asarray(d2a), np.asarray(d2r),
                               rtol=1e-4, atol=0.2)


@pytest.mark.parametrize("model", ["power_law", "UMa", "RMa", "InH"])
@pytest.mark.parametrize("n,m,k", [(64, 32, 1), (100, 67, 3)])
def test_fused_sinr_vs_ref(model, n, m, k):
    U, C, Pw = _net(jax.random.PRNGKey(7), n, m, k)
    pm = make_pathloss(model)
    noise = 1e-12
    g_a, a_a, w_a, u_a = ops.fused_sinr(
        U, C, Pw, pathgain_fn=pm.get_pathgain, noise_w=noise, bn=32, bm=32)
    g_r, a_r, w_r, u_r = ref.fused_sinr_ref(U, C, Pw, pm.get_pathgain, noise)
    assert bool((a_a == a_r).all())
    np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_r), rtol=2e-4)
    np.testing.assert_allclose(np.asarray(w_a), np.asarray(w_r), rtol=2e-4)


def test_fused_sinr_sectored():
    n, m, k = 48, 12, 2
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    U = jnp.concatenate([jax.random.uniform(k1, (n, 2), maxval=3000.0),
                         jnp.full((n, 1), 1.5)], 1)
    sites = jnp.concatenate([jax.random.uniform(k2, (4, 2), maxval=3000.0),
                             jnp.full((4, 1), 25.0)], 1)
    C = jnp.repeat(sites, 3, axis=0)
    bore = sector_boresights(4, 3)
    Pw = jnp.full((m, k), 5.0)
    pm = make_pathloss("UMa")
    g_a, a_a, _, _ = ops.fused_sinr(
        U, C, Pw, pathgain_fn=pm.get_pathgain, noise_w=1e-12,
        boresight=bore, n_sectors=3, bn=16, bm=16)
    # oracle with antenna applied
    from repro.sim.antenna import Antenna_gain
    ant = Antenna_gain()
    d2, d3 = ref.pairwise_dist_ref(U, C)
    az = jnp.arctan2(U[:, None, 1] - C[None, :, 1],
                     U[:, None, 0] - C[None, :, 0])
    g = pm.get_pathgain(d2, d3, C[None, :, 2], U[:, None, 2]) \
        * ant.gain_linear(az, bore)
    r = g[:, :, None] * Pw[None]
    a_r = jnp.argmax(r.sum(2), 1)
    assert bool((a_a == a_r.astype(a_a.dtype)).all())


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 70), m=st.integers(3, 70),
       k=st.integers(1, 4), seed=st.integers(0, 2 ** 16))
def test_fused_sinr_property(n, m, k, seed):
    """Property sweep: attachment always equals the oracle argmax; the
    interference vector is non-negative; SINR is finite and positive."""
    U, C, Pw = _net(jax.random.PRNGKey(seed), n, m, k)
    pm = make_pathloss("power_law", alpha=3.0)
    g_a, a_a, w_a, u_a = ops.fused_sinr(
        U, C, Pw, pathgain_fn=pm.get_pathgain, noise_w=1e-13, bn=16, bm=16)
    g_r, a_r, *_ = ref.fused_sinr_ref(U, C, Pw, pm.get_pathgain, 1e-13)
    assert bool((a_a == a_r).all())
    assert bool((np.asarray(u_a) > -1e-12).all())
    assert bool(np.isfinite(np.asarray(g_a)).all())
    assert bool((np.asarray(g_a) > 0).all())


def test_mxu_variant_documented_tolerance():
    """The MXU distance decomposition trades ~1e-3 relative gain error for
    matrix-unit throughput; assert the documented bound holds."""
    U, C, Pw = _net(jax.random.PRNGKey(9), 128, 64, 1)
    pm = make_pathloss("UMa")
    g_a, a_a, _, _ = ops.fused_sinr(U, C, Pw, pathgain_fn=pm.get_pathgain,
                                    noise_w=1e-12, bn=32, bm=32, mxu=True)
    g_r, a_r, *_ = ref.fused_sinr_ref(U, C, Pw, pm.get_pathgain, 1e-12)
    rel = np.abs(np.asarray(g_a) - np.asarray(g_r)) \
        / np.maximum(np.abs(np.asarray(g_r)), 1e-30)
    assert rel.max() < 5e-2
