"""End-to-end behaviour tests for the paper's system.

A CRRM network lives through a mobility episode with power reconfiguration;
the smart engine must agree with the full-recompute engine at every step,
while doing strictly less work (the paper's core claim), and the serving
engine must generate deterministically (the LM side of the framework).
"""
import jax
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim.mobility import random_moves


def test_full_episode_smart_vs_full():
    common = dict(n_ues=60, n_cells=21, n_sectors=3, n_subbands=2,
                  pathloss_model_name="UMa", power_W=10.0, seed=4,
                  fairness_p=0.3)
    smart = CRRM(CRRM_parameters(smart=True, **common))
    full = CRRM(CRRM_parameters(smart=False, **common))
    key = jax.random.PRNGKey(1)
    for step in range(5):
        key, k = jax.random.split(key)
        idx, xyz = random_moves(k, 60, 6, 3000.0)
        for sim in (smart, full):
            sim.move_UEs(np.asarray(idx), np.asarray(xyz))
        if step == 2:  # interference coordination event
            for sim in (smart, full):
                sim.set_cell_power(0, 0, 0.1)
        np.testing.assert_allclose(
            np.asarray(smart.get_UE_throughputs()),
            np.asarray(full.get_UE_throughputs()), rtol=1e-4, atol=1e-3)
    # the smart engine did row updates where the full engine recomputed
    s_counts = smart.update_counts()
    f_counts = full.update_counts()
    assert s_counts["D"][1] > 0          # row updates happened
    assert f_counts["D"][1] == 0         # control never row-updates
    assert s_counts["D"][0] < f_counts["D"][0]


def test_serving_engine_generates():
    from repro.configs import get_config
    from repro.models.registry import make_arch
    from repro.parallel.mesh import make_host_mesh
    from repro.serve.engine import ServeEngine

    cfg = get_config("qwen1.5-0.5b", reduced=True)
    arch = make_arch(cfg)
    eng = ServeEngine(arch, make_host_mesh(1, 1), batch_slots=2, max_len=64)
    r1 = eng.submit(np.arange(5) % cfg.vocab_size, max_new_tokens=6)
    r2 = eng.submit(np.arange(9) % cfg.vocab_size, max_new_tokens=4)
    out = eng.run()
    assert len(out["results"][r1.rid]) == 6
    assert len(out["results"][r2.rid]) == 4
    assert out["tokens_per_s"] > 0

    # greedy decoding is deterministic
    eng2 = ServeEngine(arch, make_host_mesh(1, 1), batch_slots=2, max_len=64)
    r1b = eng2.submit(np.arange(5) % cfg.vocab_size, max_new_tokens=6)
    r2b = eng2.submit(np.arange(9) % cfg.vocab_size, max_new_tokens=4)
    out2 = eng2.run()
    assert out2["results"][r1b.rid] == out["results"][r1.rid]
    assert out2["results"][r2b.rid] == out["results"][r2.rid]
