"""ISSUE 6: compiled-step reports, perf trajectory, and the bench-harness
satellites (run.py --json / stderr tracebacks, check_regressions --strict).
"""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.core.crrm import CRRM
from repro.sim import scenarios

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH = os.path.join(REPO, "benchmarks")


def _sim(**kw):
    base = dict(n_ues=24, n_cells=6)
    base.update(kw)
    return CRRM(scenarios.make_scenario("dense_urban", **base))


# ---------------------------------------------------------------- reports
def test_episode_report_artifact_and_roofline_table(tmp_path):
    from repro.obs import report

    sim = _sim()
    art = report.episode_report(sim, 10, scenario="dense_urban")
    for key in ("n_devices", "model_flops", "n_ues", "backend"):
        assert key in art, key
    if not art.get("skipped"):
        assert art["hlo_flops"] > 0 and art["hlo_bytes"] > 0
        assert art["collective_wire_bytes"] == 0.0   # single device
    table = report.write_report(str(tmp_path), {"dense_urban": art})
    assert "dense_urban" in table
    assert (tmp_path / "roofline.md").exists()
    with open(tmp_path / "dense_urban.json") as f:
        assert json.load(f)["n_tti"] == 10


def test_report_cli_writes_artifacts(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", "--scenario",
         "dense_urban", "--n-ues", "16", "--n-tti", "5",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=600, cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert (tmp_path / "roofline.md").exists()
    assert "| dense_urban |" in out.stdout


def test_skipped_artifact_renders_as_skipped_row():
    from repro.obs import report

    table = report.roofline_table(
        {"broken": {"skipped": True, "reason": "no cost analysis"}})
    assert "skipped" in table


# ------------------------------------------------------------- trajectory
def test_provenance_stamp_fields():
    from benchmarks import trajectory

    p = trajectory.provenance()
    for key in ("git_sha", "git_dirty", "timestamp_utc", "jax_version",
                "backend", "device_kind"):
        assert key in p, key
    assert p["jax_version"] == jax.__version__
    assert len(p["git_sha"]) in (7, 40) or p["git_sha"] == "unknown"


def test_trajectory_table_covers_all_records():
    from benchmarks import trajectory

    table = trajectory.render_table()
    for path in trajectory.record_paths():
        assert os.path.basename(path) in table
    # every committed record carries a gated metric by now
    assert "(no gated metric)" not in table
    assert "Rendered at" in table


def test_trajectory_cli_and_stamping(tmp_path):
    src = os.path.join(BENCH, "BENCH_mac.json")
    with open(src) as f:
        rec = json.load(f)
    rec.pop("provenance", None)
    with open(tmp_path / "BENCH_mac.json", "w") as f:
        json.dump(rec, f)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.trajectory", "--stamp",
         "--dir", str(tmp_path), "--out", str(tmp_path / "traj.md")],
        capture_output=True, text=True, timeout=300, cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    with open(tmp_path / "BENCH_mac.json") as f:
        stamped = json.load(f)
    assert "provenance" in stamped
    assert (tmp_path / "traj.md").exists()
    with open(tmp_path / "traj.md") as f:
        assert "per_rb_cost" in f.read()


def test_seeded_records_write_records_with_provenance(tmp_path, monkeypatch):
    """_write_record stamps provenance into every record it writes."""
    from benchmarks import paper_benches

    monkeypatch.setattr(paper_benches, "__file__",
                        str(tmp_path / "paper_benches.py"))
    paper_benches._write_record("BENCH_x.json", {"bench": "x"})
    with open(tmp_path / "BENCH_x.json") as f:
        rec = json.load(f)
    assert rec["provenance"]["jax_version"] == jax.__version__


# -------------------------------------------------------- run.py satellite
@pytest.mark.slow
def test_run_json_mode_is_machine_readable():
    """--json: stdout parses as one JSON document; bench detail lines and
    tracebacks go to stderr."""
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--json", "--only", "fig4"],
        capture_output=True, text=True, timeout=600, cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    doc = json.loads(out.stdout)            # pure JSON or this throws
    assert doc["failures"] == 0
    assert doc["results"][0]["ok"] is True
    assert "# fig4" in out.stderr           # detail rerouted off stdout


def test_run_failures_traceback_on_stderr_csv_intact(tmp_path):
    """A failing bench must not interleave its traceback with the CSV."""
    driver = tmp_path / "driver.py"
    driver.write_text(
        "import sys; sys.path.insert(0, 'src'); sys.path.insert(0, '.')\n"
        "from benchmarks import paper_benches, run\n"
        "def boom():\n"
        "    raise RuntimeError('synthetic bench failure')\n"
        "paper_benches.ALL = [boom]\n"
        "run.main(['--only', ''])\n")
    out = subprocess.run([sys.executable, str(driver)], capture_output=True,
                         text=True, timeout=120, cwd=REPO)
    assert out.returncode != 0
    assert "Traceback" not in out.stdout
    assert "boom,FAILED,-" in out.stdout
    assert "synthetic bench failure" in out.stderr


# --------------------------------------------- check_regressions satellite
def _checker(args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regressions", *args],
        capture_output=True, text=True, timeout=600, cwd=cwd,
        env={**os.environ, "PYTHONPATH": "src"})


def test_strict_fails_on_unregistered_bench(tmp_path):
    with open(tmp_path / "BENCH_orphan.json", "w") as f:
        json.dump({"bench": "no_such_bench", "gated_metric": "r",
                   "gate": 1.0}, f)
    lenient = _checker(["--dir", str(tmp_path)])
    assert lenient.returncode == 0, lenient.stdout + lenient.stderr
    assert "SKIPPED" in lenient.stdout
    strict = _checker(["--strict", "--dir", str(tmp_path)])
    assert strict.returncode != 0
    assert "STRICT" in strict.stderr + strict.stdout


def test_strict_fails_on_missing_gated_metric(tmp_path):
    with open(tmp_path / "BENCH_nometric.json", "w") as f:
        json.dump({"bench": "mac_episode", "gate": 3.0}, f)
    strict = _checker(["--strict", "--dir", str(tmp_path)])
    assert strict.returncode != 0
    assert "gated_metric" in strict.stdout + strict.stderr


def test_full_rerun_missing_metric_errors_cleanly(tmp_path, monkeypatch):
    """The full-shape KeyError path: a re-seeded record that lost its
    gated metric must produce the diagnostic, not a bare KeyError."""
    from benchmarks import check_regressions as cr

    path = tmp_path / "BENCH_weird.json"
    with open(path, "w") as f:
        json.dump({"bench": "mac_episode", "gated_metric": "vanished",
                   "gate": 3.0, "gate_direction": "max"}, f)
    # stub the rerun so no heavy bench executes and no record is re-seeded
    monkeypatch.setattr(cr, "_reruns",
                        lambda: {"mac_episode":
                                 lambda: ("stub", 0.0, 1.0)})
    with pytest.raises(AssertionError, match="WITHOUT its gated metric"):
        cr.check(str(path), smoke=False)
