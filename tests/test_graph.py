"""Dependency-graph engine unit tests: the smart-update mechanics."""
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ALL, Graph, Node, RootNode, pad_indices


class Doubler(Node):
    supports_row_update = True

    def __init__(self, src):
        super().__init__("double")
        self.watch(src)
        self.src = src

    def update_data(self):
        return self.src._data * 2.0

    def update_rows(self, idx):
        return self._data.at[jnp.asarray(idx)].set(
            self.src._data[jnp.asarray(idx)] * 2.0)


class Summer(Node):
    def __init__(self, src):
        super().__init__("sum")
        self.watch(src)
        self.src = src

    def propagate_rows(self, rows):
        return ALL

    def update_data(self):
        return self.src._data.sum()


def _chain():
    g = Graph()
    root = g.add(RootNode("x", jnp.arange(8, dtype=jnp.float32)))
    mid = g.add(Doubler(root))
    out = g.add(Summer(mid))
    return g, root, mid, out


def test_invalidation_floods_downstream_without_compute():
    g, root, mid, out = _chain()
    out.update()
    assert mid.up_to_date and out.up_to_date
    root.set_rows([3], jnp.asarray([10.0]))
    # invalidation only -- nothing recomputed yet
    assert not mid.up_to_date and not out.up_to_date
    assert mid.n_full_updates == 1 and mid.n_row_updates == 0


def test_row_local_update():
    g, root, mid, out = _chain()
    out.update()
    root.set_rows([3], jnp.asarray([10.0]))
    assert float(out.update()) == float((2 * jnp.arange(8)).sum()
                                        + 20.0 - 6.0)
    assert mid.n_row_updates == 1 and mid.n_full_updates == 1


def test_lazy_no_query_no_compute():
    g, root, mid, out = _chain()
    root.set_rows([1], jnp.asarray([5.0]))
    root.set_rows([2], jnp.asarray([6.0]))
    assert mid.n_full_updates == 0 and mid.n_row_updates == 0


def test_repeated_queries_hit_cache():
    g, root, mid, out = _chain()
    out.update()
    out.update()
    out.update()
    assert out.n_full_updates == 1


def test_dirty_rows_merge():
    g, root, mid, out = _chain()
    out.update()
    root.set_rows([1], jnp.asarray([5.0]))
    root.set_rows([4], jnp.asarray([6.0]))
    assert mid.dirty_rows == {1, 4}
    out.update()
    assert mid.n_row_updates == 1  # one merged row pass


def test_full_set_floods_all():
    g, root, mid, out = _chain()
    out.update()
    root.set(jnp.ones(8))
    assert mid.dirty_rows is ALL
    out.update()
    assert mid.n_full_updates == 2


def test_non_smart_graph_always_full():
    g = Graph(smart=False)
    root = g.add(RootNode("x", jnp.arange(8, dtype=jnp.float32)))
    mid = g.add(Doubler(root))
    out = g.add(Summer(mid))
    out.update()
    root.set_rows([3], jnp.asarray([9.0]))
    out.update()
    assert mid.n_row_updates == 0 and mid.n_full_updates == 2


def test_pad_indices_buckets():
    assert len(pad_indices({1})) == 1
    assert len(pad_indices({1, 2})) == 2
    assert len(pad_indices({1, 2, 3})) == 4
    idx = pad_indices({5, 1, 9})
    assert sorted(set(idx.tolist())) == [1, 5, 9]
    assert len(idx) == 4  # padded with duplicates
