"""ISSUE 5: the incremental (smart-update) radio path inside the compiled
TTI engine -- dense-vs-incremental equivalence across registry scenarios,
under vmap and on a 2-device mesh, the shared dirtiness convention, and
the window-mover mobility regime."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim import mobility, radio, scenarios


def _shrink(name, **kw):
    base = dict(n_ues=24, n_cells=6)
    base.update(kw)
    return scenarios.make_scenario(name, **base)


def _pair(params):
    """Two identical sims (separate graphs, shared nothing)."""
    return CRRM(params), CRRM(params)


# -------------------------------------------- dense == incremental (scan)
@pytest.mark.parametrize("name", scenarios.scenario_names())
def test_incremental_matches_dense_across_scenarios(name):
    """Tentpole acceptance: the incremental rollout reproduces the dense
    rollout on every registry scenario at 25% per-TTI dirtiness (the
    sharded gate's 1e-5, bit-exact positions)."""
    a, b = _pair(_shrink(name))
    key = jax.random.PRNGKey(0)
    kw = dict(mobility_step_m=25.0, mobility_move_frac=0.25)
    f1 = a.episode_fns(radio_mode="dense", **kw)
    f2 = b.episode_fns(radio_mode="incremental", **kw)
    s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key), 20)
    s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key), 20)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(s2.U), np.asarray(s1.U))
    np.testing.assert_allclose(np.asarray(s2.pf_avg), np.asarray(s1.pf_avg),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(s2.serving),
                                  np.asarray(s1.serving))


def test_incremental_full_mobility_matches_legacy_dense():
    """mobility_move_frac=None: every UE moves on the legacy PR-4 draw;
    the incremental path must consume the identical stream (all rows
    dirty every TTI) and reproduce the dense trajectory."""
    a, b = _pair(CRRM_parameters(
        n_ues=16, n_cells=4, seed=3, pathloss_model_name="UMa",
        power_W=10.0, scheduler_policy="rr"))
    key = jax.random.PRNGKey(1)
    f1 = a.episode_fns(mobility_step_m=20.0)
    f2 = b.episode_fns(mobility_step_m=20.0, radio_mode="incremental")
    s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key), 10)
    s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key), 10)
    np.testing.assert_array_equal(np.asarray(s2.U), np.asarray(s1.U))
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                               rtol=1e-5, atol=1e-2)


def test_incremental_action_matches_dense_per_tti_recompute():
    """A scan-constant power action through the incremental path (one
    prepare-time radio_init) equals the dense per-TTI recompute."""
    a, b = _pair(CRRM_parameters(
        n_ues=20, n_cells=5, seed=3, pathloss_model_name="UMa",
        power_W=10.0, traffic_model="poisson", scheduler_policy="pf",
        traffic_params=dict(arrival_rate_hz=300.0,
                            packet_size_bits=12_000.0)))
    key = jax.random.PRNGKey(0)
    act = jnp.asarray(a.P._data) * 0.6
    f1, f2 = a.episode_fns(), b.episode_fns(radio_mode="incremental")
    s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key),
                        15, act)
    s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key),
                        15, act)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                               rtol=1e-5, atol=1e-2)
    # step() agrees too (per-call init, same values)
    _, o1 = f1.step(a.episode_static(), a.init_episode_state(key), act)
    _, o2 = f2.step(b.episode_static(), b.init_episode_state(key), act)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               rtol=1e-5, atol=1e-2)


def test_incremental_vmaps_over_batched_episodes():
    """N seeds, one vmapped incremental program == N dense episodes."""
    p = _shrink("dense_urban_twin", n_ues=16, n_cells=6)
    a, b = _pair(p)
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    st_a, st_b = a.episode_static(), b.episode_static()
    f1, f2 = a.episode_fns(radio_mode="dense"), b.episode_fns()
    batch = jax.tree_util.tree_map(
        lambda *x: jnp.stack(x), *[a.init_episode_state(k) for k in keys])
    _, t1 = jax.jit(jax.vmap(lambda s: f1.rollout(st_a, s, 10)))(batch)
    _, t2 = jax.jit(jax.vmap(lambda s: f2.rollout(st_b, s, 10)))(batch)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                               rtol=1e-5, atol=1e-2)


def test_incremental_rejects_per_tti_fading():
    sim = CRRM(CRRM_parameters(n_ues=8, n_cells=2, rayleigh_fading=True,
                               pathloss_model_name="UMa"))
    with pytest.raises(ValueError, match="per_tti_fading"):
        sim.episode_fns(per_tti_fading=True, radio_mode="incremental")
    with pytest.raises(ValueError, match="radio_mode"):
        sim.episode_fns(radio_mode="fancy")


def test_env_incremental_action_matches_dense_env():
    """CrrmEnv(radio_mode='incremental'): the action step that is 3x the
    passive cost on the dense path costs one chain init here -- same
    observations/rewards either way."""
    from repro.env import CrrmEnv
    kw = dict(n_ues=24, n_cells=4, seed=3, pathloss_model_name="UMa",
              power_W=10.0, traffic_model="poisson", scheduler_policy="pf",
              traffic_params=dict(arrival_rate_hz=300.0,
                                  packet_size_bits=12_000.0))
    e1 = CrrmEnv(CRRM_parameters(**kw), episode_tti=40, tti_per_step=20)
    e2 = CrrmEnv(CRRM_parameters(**kw), episode_tti=40, tti_per_step=20,
                 radio_mode="incremental")
    key = jax.random.PRNGKey(0)
    s1, _ = e1.reset(key)
    s2, _ = e2.reset(key)
    act = 0.8 * e1.uniform_action()
    for _ in range(2):
        s1, o1, r1, d1 = e1.step(s1, act)
        s2, o2, r2, d2 = e2.step(s2, act)
        np.testing.assert_allclose(np.asarray(o2.tput), np.asarray(o1.tput),
                                   rtol=1e-5, atol=1e-2)
        np.testing.assert_allclose(float(r2), float(r1), rtol=1e-5)
        assert bool(d1) == bool(d2)


# ------------------------------------------------ the dirtiness convention
def test_dirty_indices_matches_pad_indices_convention():
    """The traced mask compaction and the host-side power-of-two buckets
    are two faces of one convention: valid-index padding, idempotent
    recompute, no masking."""
    mask = jnp.zeros(16, bool).at[jnp.array([3, 7, 11])].set(True)
    idx = radio.dirty_indices(mask, 8)
    assert idx.shape == (8,)
    np.testing.assert_array_equal(np.asarray(idx[:3]), [3, 7, 11])
    assert set(np.asarray(idx[3:]).tolist()) == {0}      # valid-row padding
    host = radio.pad_indices([3, 7, 11])
    assert host.shape == (4,) and host[-1] == 3          # repeated valid idx
    from repro.core.graph import pad_indices as graph_pad
    assert graph_pad is radio.pad_indices                # ONE implementation


def test_radio_update_rows_is_idempotent_under_padding():
    """Padded (repeated / row-0) indices scatter bit-identical values --
    the property both smart-update surfaces rely on."""
    sim = CRRM(_shrink("indoor_hotspot", n_ues=12, n_cells=4))
    rs = sim.radio_static()
    U, fad = sim.U._data, sim.fading._data
    st = radio.radio_init(rs.cfg, U, rs.C, rs.bore, fad, rs.P)
    idx = jnp.array([5, 5, 0, 0, 0, 0], jnp.int32)       # pure padding
    st2 = radio.radio_update_rows(rs.cfg, st, U, rs.C, rs.bore, fad,
                                  rs.P, idx)
    for a, b in zip(st, st2):
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_radio_update_cell_mask_applies_power_delta():
    """Dirty cell columns re-derive every UE's outputs from the carried
    gains -- equal to a full init under the new power matrix; an all-False
    mask is a branch-free no-op."""
    sim = CRRM(_shrink("rural_macro", n_ues=12, n_cells=4))
    rs = sim.radio_static()
    U, fad = sim.U._data, sim.fading._data
    st = radio.radio_init(rs.cfg, U, rs.C, rs.bore, fad, rs.P,
                          with_gain=True)
    P2 = rs.P.at[1].mul(0.25)
    mask = jnp.zeros(rs.P.shape[0], bool).at[1].set(True)
    got = radio.radio_update(rs, st, U, jnp.zeros(U.shape[0], bool),
                             dirty_cell_mask=mask, budget=1, fad=fad, P=P2)
    want = radio.radio_init(rs.cfg, U, rs.C, rs.bore, fad, P2,
                            with_gain=True)
    np.testing.assert_allclose(np.asarray(got.se), np.asarray(want.se),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got.a), np.asarray(want.a))
    noop = radio.radio_update(rs, st, U, jnp.zeros(U.shape[0], bool),
                              dirty_cell_mask=jnp.zeros_like(mask),
                              budget=1, fad=fad, P=P2)
    np.testing.assert_array_equal(np.asarray(noop.se), np.asarray(st.se))


# ------------------------------------------------------ window-mover regime
def test_window_movers_exact_count_and_bounds():
    """At most round(frac * n) movers per TTI; non-movers hold position;
    movers stay inside the region."""
    p = CRRM_parameters(n_ues=40, n_cells=3, seed=1, extent_m=500.0,
                        pathloss_model_name="UMa", power_W=10.0,
                        mobility_step_m=10.0, mobility_move_frac=0.2,
                        scheduler_policy="rr")
    sim = CRRM(p)
    fns = sim.episode_fns()
    state = sim.init_episode_state(jax.random.PRNGKey(0))
    st = sim.episode_static()
    for _ in range(4):
        U0 = np.asarray(state.U)
        state, _ = fns.step(st, state)
        U1 = np.asarray(state.U)
        moved = (np.abs(U1[:, :2] - U0[:, :2]).sum(axis=1) > 0)
        assert moved.sum() <= 8                     # = round(0.2 * 40)
        assert (U1[:, :2] >= 0).all() and (U1[:, :2] <= 500.0).all()
    start, d = mobility.window_movers(jax.random.PRNGKey(7), 40, 8, 10.0)
    rows = jnp.arange(40)
    disp, mask = mobility.window_displacements(start, d, rows, 40)
    assert int(mask.sum()) == 8
    np.testing.assert_array_equal(np.asarray(disp[~np.asarray(mask)]), 0.0)


# ---------------------------------------- fused dirty-row backend (ISSUE 9)
@pytest.mark.parametrize("name", scenarios.scenario_names())
def test_fused_rows_match_xla_rows_across_scenarios(name):
    """The dirty-row Pallas kernel variant (interpret mode on CPU) patches
    rows bitwise-identically to ``radio_update_rows`` on every registry
    scenario's O(n_ue) chain -- same gather, same scatter, the gain/RSRP
    math fused into VMEM tiles in between."""
    sim = CRRM(_shrink(name))
    rs = sim.radio_static()
    U, fad = sim.U._data, sim.fading._data
    st = radio.radio_init(rs.cfg, U, rs.C, rs.bore, fad, rs.P)
    idx = jnp.array([3, 7, 11, 19, 19, 0, 0, 0], jnp.int32)  # padded
    U2 = U.at[jnp.array([3, 7, 11, 19])].add(
        jnp.array([30.0, -12.0, 0.0], U.dtype))
    got_x = radio.radio_update_rows(rs.cfg, st, U2, rs.C, rs.bore, fad,
                                    rs.P, idx)
    got_f = radio.radio_update_rows_fused(rs.cfg, st, U2, rs.C, rs.bore,
                                          fad, rs.P, idx)
    for field, x, f in zip(radio.RadioState._fields, got_x, got_f):
        assert (x is None) == (f is None), field
        if x is not None:
            np.testing.assert_array_equal(np.asarray(f), np.asarray(x),
                                          err_msg=field)


def test_fused_rows_reject_table_and_gain_carries():
    """HO tables / carried gains need O(n_cell)-per-row outputs the
    streaming accumulator never materialises; the fused variant refuses
    rather than silently dropping them."""
    sim = CRRM(_shrink("handover_stress"))
    rs = sim.radio_static()
    U, fad = sim.U._data, sim.fading._data
    idx = jnp.zeros(4, jnp.int32)
    st = radio.radio_init(rs.cfg, U, rs.C, rs.bore, fad, rs.P,
                          with_tables=True)
    with pytest.raises(ValueError, match="se_all"):
        radio.radio_update_rows_fused(rs.cfg, st, U, rs.C, rs.bore, fad,
                                      rs.P, idx)


def test_engine_inc_backend_pallas_matches_xla():
    """inc_backend="pallas" (the fused dirty-row kernel, interpret mode on
    CPU) rolls out bitwise-identically to the XLA row recompute; "pallas"
    raises on inexpressible configurations (handover tables) with a
    diagnostic, and "auto" falls back to XLA there instead."""
    a, b = _pair(_shrink("dense_urban"))
    kw = dict(mobility_step_m=25.0, mobility_move_frac=0.25)
    key = jax.random.PRNGKey(0)
    f1 = a.episode_fns(radio_mode="incremental", inc_backend="xla", **kw)
    f2 = b.episode_fns(radio_mode="incremental", inc_backend="pallas", **kw)
    s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key), 8)
    s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key), 8)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t1))
    np.testing.assert_array_equal(np.asarray(s2.U), np.asarray(s1.U))

    ho = CRRM(_shrink("handover_stress"))
    with pytest.raises(ValueError, match="cannot express"):
        ho.episode_fns(radio_mode="incremental", inc_backend="pallas")
    ho.episode_fns(radio_mode="incremental", inc_backend="auto")  # falls back


def test_cell_axis_requires_mesh():
    sim = CRRM(_shrink("dense_urban"))
    with pytest.raises(ValueError, match="mesh"):
        sim.episode_fns(cell_axis=("cell",))


# ------------------------------------- donated rollout executable (ISSUE 9)
def test_rollout_donated_matches_rollout_and_does_not_retrace():
    """``rollout_donated`` is the same program with the state buffers
    donated: bitwise-equal outputs, and re-invoking it with the returned
    (same-shape) state compiles nothing new."""
    from repro.obs.profile import CompileCounter
    a, b = _pair(_shrink("dense_urban_twin"))
    key = jax.random.PRNGKey(0)
    fns = a.episode_fns()
    static = a.episode_static()
    _, t_ref = fns.rollout(static, a.init_episode_state(key), 8)
    state, t1 = fns.rollout_donated(static, b.init_episode_state(key), 8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t_ref))
    with CompileCounter() as c:
        state, t2 = fns.rollout_donated(static, state, 8)
        jax.block_until_ready((state, t2))
    if c.supported:
        assert c.count == 0, f"donated rollout retraced: {c.count} compiles"


# -------------------------------------------------- 2-device mesh equivalence
_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

mesh = jax.make_mesh((2,), ("ue",))
base = dict(n_ues=64, n_cells=7, seed=3, pathloss_model_name="UMa",
            power_W=10.0, rayleigh_fading=True, attach_ignores_fading=True,
            scheduler_policy="rr", ho_enabled=True,
            traffic_model="poisson",
            traffic_params=dict(arrival_rate_hz=300.0,
                                packet_size_bits=12_000.0))
kw = dict(mobility_step_m=20.0, mobility_move_frac=0.125)
a, b = CRRM(CRRM_parameters(**base)), CRRM(CRRM_parameters(**base))
key = jax.random.PRNGKey(0)
f1 = a.episode_fns(radio_mode="incremental", **kw)
f2 = b.episode_fns(radio_mode="incremental", mesh=mesh, **kw)
s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key), 40)
s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key), 40)
np.testing.assert_allclose(np.asarray(t2), np.asarray(t1), rtol=1e-5,
                           atol=1e-2)
for l1, l2 in zip(jax.tree_util.tree_leaves(s1),
                  jax.tree_util.tree_leaves(s2)):
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-3)
print("OK incremental sharded")
# sharded incremental == sharded dense (same mesh)
c = CRRM(CRRM_parameters(**base))
f3 = c.episode_fns(radio_mode="dense", mesh=mesh, **kw)
s3, t3 = f3.rollout(c.episode_static(), c.init_episode_state(key), 40)
np.testing.assert_allclose(np.asarray(t2), np.asarray(t3), rtol=1e-5,
                           atol=1e-2)
print("ALL_OK")
"""


@pytest.mark.slow
def test_incremental_on_two_device_mesh_matches_single_device():
    """Acceptance: the incremental path under shard_map on a 2-device
    host mesh matches both the single-device incremental rollout and the
    sharded dense rollout (subprocess: device count must be forced before
    jax initialises)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_OK" in out.stdout


# ----------------------------------------- UE x cell mesh (ISSUE 9 tentpole)
_UECELL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro.core.crrm import CRRM
from repro.sim import scenarios

mesh = jax.make_mesh((1, 2), ("ue", "cell"))
kw = dict(mobility_step_m=20.0, mobility_move_frac=0.25)
key = jax.random.PRNGKey(0)

def roll(sim, n_tti, **ekw):
    fns = sim.episode_fns(**ekw)
    return fns.rollout(sim.episode_static(), sim.init_episode_state(key),
                       n_tti)

def check(name, mode, n_tti=8):
    base = scenarios.make_scenario(name, n_ues=24, n_cells=6)
    s1, t1 = roll(CRRM(base), n_tti, radio_mode=mode, **kw)
    s2, t2 = roll(CRRM(base), n_tti, radio_mode=mode, mesh=mesh,
                  cell_axis=("cell",), **kw)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(s2.U), np.asarray(s1.U))
    np.testing.assert_array_equal(np.asarray(s2.serving),
                                  np.asarray(s1.serving))
    for l1, l2 in zip(jax.tree_util.tree_leaves(s1),
                      jax.tree_util.tree_leaves(s2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-3)
    print("OK", name, mode)

# the tentpole contract: every registry scenario, incremental (the
# dirty-row chain runs radio_init AND radio_update_rows under cell
# sharding); one dense case covers the dense cell-sharded chain
for name in scenarios.scenario_names():
    check(name, "incremental")
check("dense_urban", "dense")
print("ALL_OK")
"""


@pytest.mark.slow
def test_episode_on_ue_by_cell_mesh_matches_single_device():
    """ISSUE 9 acceptance: a UE x cell mesh episode (cells sharded over a
    2-device host mesh) reproduces the single-device rollout on every
    registry scenario within the established equivalence contract
    (throughput/state 1e-5, attachment/serving/positions bitwise)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _UECELL_MESH_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_OK" in out.stdout
