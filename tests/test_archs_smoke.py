"""Per-architecture smoke tests: reduced config, one forward + one decode
step on CPU, asserting output shapes and the absence of NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) -- see repro.launch.dryrun.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCH_IDS, get_config
from repro.models.registry import make_arch


def _optbar_grad_supported():
    """The remat'd backward pass needs jax to differentiate through
    lax.optimization_barrier; older pinned jax (e.g. 0.4.37, this
    container) has no rule for it.  Probe the capability instead of
    pinning a version."""
    try:
        jax.grad(lambda x: jax.lax.optimization_barrier(x * x))(1.0)
        return True
    except NotImplementedError:
        return False


needs_optbar_grad = pytest.mark.skipif(
    not _optbar_grad_supported(),
    reason="environment: installed jax lacks the differentiation rule for "
           "lax.optimization_barrier (backward pass through the remat'd "
           "scan); forward-only tests still run")


def _batch(cfg, key, b=2, s=16):
    if cfg.family == "vlm":
        return {"embeds": jax.random.normal(key, (b, s, cfg.d_model)),
                "positions": jnp.tile(jnp.arange(s)[None, None], (3, b, 1))}
    if cfg.family == "encdec":
        return {"src_embeds": jax.random.normal(key, (b, s, cfg.d_model)),
                "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch_id", LM_ARCH_IDS)
def test_forward_shapes_no_nans(arch_id):
    cfg = get_config(arch_id, reduced=True)
    arch = make_arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, jax.random.PRNGKey(1), b, s)
    logits = arch.forward(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())


@needs_optbar_grad
@pytest.mark.parametrize("arch_id", LM_ARCH_IDS)
def test_train_step_reduces_loss(arch_id):
    """One SGD step on a tiny batch must produce a finite, positive loss and
    finite grads (checks the backward pass through every family)."""
    cfg = get_config(arch_id, reduced=True)
    arch = make_arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch(cfg, jax.random.PRNGKey(1), b, s)
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)

    def loss_fn(p):
        logits = arch.forward(p, batch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[..., None],
                                             axis=-1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0.0
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)


@pytest.mark.parametrize("arch_id", LM_ARCH_IDS)
def test_prefill_decode_consistency(arch_id):
    """prefill's last-token logits == forward's last position, and a decode
    step runs against the caches (the serving smart-update path)."""
    cfg = get_config(arch_id, reduced=True)
    arch = make_arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, jax.random.PRNGKey(1), b, s)
    logits = arch.forward(params, batch)
    last, caches = arch.prefill(params, batch, s + 8)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
    if cfg.family == "vlm":
        db = {"embeds": jax.random.normal(jax.random.PRNGKey(9),
                                          (b, 1, cfg.d_model)),
              "positions": jnp.full((3, b, 1), s, jnp.int32)}
    else:
        db = {"tokens": jnp.full((b, 1), 3, jnp.int32)}
    dl, _ = arch.decode_step(params, db, caches, s)
    assert dl.shape == (b, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(dl).any())


@pytest.mark.parametrize("arch_id", LM_ARCH_IDS)
def test_decode_matches_forward_teacher_forced(arch_id):
    """Greedy decode logits must match teacher-forced forward logits
    position by position (validates cache correctness end to end).

    MoE note: the equivalence only holds when no token is capacity-dropped
    (drops depend on how many tokens co-occur in the pass), so we pin a
    capacity factor large enough that nothing drops.
    """
    import dataclasses
    cfg = get_config(arch_id, reduced=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    arch = make_arch(cfg)
    params = arch.init(jax.random.PRNGKey(0))
    b, s_prompt, n_extra = 1, 6, 3
    s_total = s_prompt + n_extra
    key = jax.random.PRNGKey(1)
    full = _batch(cfg, key, b, s_total)
    if cfg.family == "vlm":
        prompt = {"embeds": full["embeds"][:, :s_prompt],
                  "positions": full["positions"][:, :, :s_prompt]}
        steps = [{"embeds": full["embeds"][:, i:i + 1],
                  "positions": full["positions"][:, :, i:i + 1]}
                 for i in range(s_prompt, s_total)]
    elif cfg.family == "encdec":
        prompt = {"src_embeds": full["src_embeds"],
                  "tokens": full["tokens"][:, :s_prompt]}
        steps = [{"tokens": full["tokens"][:, i:i + 1]}
                 for i in range(s_prompt, s_total)]
    else:
        prompt = {"tokens": full["tokens"][:, :s_prompt]}
        steps = [{"tokens": full["tokens"][:, i:i + 1]}
                 for i in range(s_prompt, s_total)]
    ref = np.asarray(arch.forward(params, full))

    last, caches = arch.prefill(params, prompt, s_total)
    np.testing.assert_allclose(np.asarray(last[:, 0]), ref[:, s_prompt - 1],
                               rtol=2e-2, atol=2e-2)
    for j, sb in enumerate(steps):
        pos = s_prompt + j
        out, caches = arch.decode_step(params, sb, caches, pos)
        np.testing.assert_allclose(np.asarray(out[:, 0]), ref[:, pos],
                                   rtol=2e-2, atol=2e-2)
