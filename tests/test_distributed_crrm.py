"""Distributed CRRM (shard_map) vs the single-host engine.

These tests need >1 device, which requires XLA_FLAGS before jax initialises;
the main pytest process must keep 1 device (per the dry-run isolation rule),
so each test runs in a fresh subprocess.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np

mesh = jax.make_mesh((4, 2), ("data", "model"))
from repro.core.distributed import (make_incremental_rows_step,
                                    make_materialized_step,
                                    make_streaming_step)
from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim.pathloss import make_pathloss

n_ue, n_cell, K = 64, 16, 2
pl = make_pathloss("UMa")
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
U = jnp.concatenate([jax.random.uniform(k1, (n_ue, 2), minval=0., maxval=3000.),
                     jnp.full((n_ue, 1), 1.5)], 1)
C = jnp.concatenate([jax.random.uniform(k2, (n_cell, 2), minval=0., maxval=3000.),
                     jnp.full((n_cell, 1), 25.)], 1)
Pw = jnp.full((n_cell, K), 5.0)
params = CRRM_parameters(n_ues=n_ue, ue_positions=np.asarray(U),
                         cell_positions=np.asarray(C),
                         power_matrix=np.asarray(Pw), n_subbands=K,
                         pathloss_model_name="UMa")
ref = CRRM(params)
g_ref = np.asarray(ref.get_SINR())
a_ref = np.asarray(ref.get_attachment())
t_ref = np.asarray(ref.throughput.update())
noise = params.subband_noise_W
bw = params.subband_bandwidth_Hz

for maker in (make_materialized_step, make_streaming_step):
    f = maker(mesh, pl.get_pathgain, noise, n_cell, bw, 0.0)
    gamma, a, tput = jax.jit(f)(U, C, Pw)
    assert np.allclose(np.asarray(gamma), g_ref, rtol=1e-3), maker.__name__
    assert (np.asarray(a) == a_ref).all(), maker.__name__
    assert np.allclose(np.asarray(tput), t_ref, rtol=1e-3, atol=1.0)

# incremental smart update at scale
finc = make_incremental_rows_step(mesh, pl.get_pathgain, noise, n_cell, bw, 0.0)
w_ref = np.asarray(ref.w.update()); u_ref = np.asarray(ref.u.update())
R = np.asarray(ref.get_RSRP()); bv = R.sum(2).max(1).astype(np.float32)
idx = jnp.asarray([3, 17, 40], dtype=jnp.int32)
newp = jnp.asarray([[10., 10., 1.5], [2900., 100., 1.5], [1500., 1500., 1.5]])
out = jax.jit(finc)(U, C, Pw, jnp.asarray(w_ref), jnp.asarray(u_ref),
                    jnp.asarray(a_ref), jnp.asarray(bv), idx, newp)
U2, w2, u2, a2, bv2, tput2 = out
ref.move_UEs(np.asarray(idx), np.asarray(newp))
assert (np.asarray(a2) == np.asarray(ref.get_attachment())).all()
assert np.allclose(np.asarray(tput2), np.asarray(ref.throughput.update()),
                   rtol=1e-3, atol=1.0)
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_crrm_matches_single_host():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + "\n" + r.stderr
