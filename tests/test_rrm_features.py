"""Radio-resource-management feature tests: subbands (example 06), the
fairness parameter p (Fig. 4), and sectored antennas (Fig. 3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim.antenna import Antenna_gain


# -- example 06: subband interference coordination -----------------------------
def _two_cell_params(power_matrix, noise_w):
    # one UE equidistant between two cells 1 km apart
    return CRRM_parameters(
        n_ues=1, ue_positions=np.array([[500.0, 0.0, 1.5]], np.float32),
        cell_positions=np.array([[0.0, 0.0, 25.0], [1000.0, 0.0, 25.0]],
                                np.float32),
        power_matrix=np.asarray(power_matrix, np.float32),
        n_subbands=np.asarray(power_matrix).shape[1],
        pathloss_model_name="power_law",
        pathloss_params={"alpha": 3.5},
        noise_power_W=noise_w, power_W=1.0)


def test_subband_coordination_0db_to_20db():
    """Same subband -> SINR 0 dB; orthogonal subbands -> 20 dB (noise set so
    the single-cell SNR is 20 dB, as in the paper's example)."""
    # received power from one cell at 500 m, alpha 3.5, P=1 W
    p_rx = 500.0 ** -3.5
    noise = p_rx / 100.0          # SNR = 20 dB
    shared = _two_cell_params([[1.0], [1.0]], noise)
    sim = CRRM(shared)
    sinr_db = float(np.asarray(sim.get_SINR_dB()).max())
    assert abs(sinr_db - 0.0) < 0.1, f"co-channel SINR {sinr_db} dB != 0 dB"

    coord = _two_cell_params([[2.0, 0.0], [0.0, 2.0]], noise)
    sim2 = CRRM(coord)
    sinr2_db = float(np.asarray(sim2.get_SINR_dB()).max())
    # serving subband now interference-free: SINR == SNR == 20 dB (2 W into
    # one subband, noise split per subband -> 2/(noise/2)/100 ... exact:
    # p_rx*2 / (noise/2) = 400 -> 26 dB; with equal split 1 W: 23 dB.
    assert sinr2_db > 19.0, f"coordinated SINR only {sinr2_db} dB"


# -- Fig. 4: fairness parameter --------------------------------------------------
def _fairness_sim(p):
    rng = np.random.default_rng(5)
    ue = np.column_stack([rng.uniform(50, 1500, 12), rng.uniform(50, 1500, 12),
                          np.full(12, 1.5)]).astype(np.float32)
    return CRRM(CRRM_parameters(
        n_ues=12, ue_positions=ue,
        cell_positions=np.array([[0.0, 0.0, 25.0]], np.float32),
        pathloss_model_name="UMa", power_W=10.0, fairness_p=p))


def test_fairness_p0_proportional():
    sim = _fairness_sim(0.0)
    t = np.asarray(sim.get_UE_throughputs())
    se = np.asarray(sim.get_spectral_efficiency()).sum(axis=1)
    active = se > 0
    ratio = t[active] / se[active]
    np.testing.assert_allclose(ratio, ratio[0], rtol=1e-4)  # T ~ S


def test_fairness_p1_equal_throughput():
    sim = _fairness_sim(1.0)
    t = np.asarray(sim.get_UE_throughputs())
    se = np.asarray(sim.get_spectral_efficiency()).sum(axis=1)
    t = t[se > 0]
    np.testing.assert_allclose(t, t[0], rtol=1e-3)


def test_fairness_redistributes_monotonically():
    """Raising p must lower the strongest user's share and raise the
    weakest active user's (Fig. 4's crossing fan)."""
    t0 = np.asarray(_fairness_sim(0.0).get_UE_throughputs())
    t1 = np.asarray(_fairness_sim(1.0).get_UE_throughputs())
    active = t0 > 0
    strongest, weakest = t0[active].argmax(), t0[active].argmin()
    assert t1[active][strongest] < t0[active][strongest]
    assert t1[active][weakest] > t0[active][weakest]


def test_cell_airtime_conserved():
    """The fairness allocation is an airtime split: throughput must equal
    bandwidth * sum(share_i * SE_i) with sum(share) = 1 per active cell."""
    sim = _fairness_sim(0.37)
    t = np.asarray(sim.get_UE_throughputs())
    se = np.asarray(sim.get_spectral_efficiency()).sum(axis=1)
    bw = sim.params.bandwidth_Hz
    active = se > 0
    shares = t[active] / (bw * se[active])
    np.testing.assert_allclose(shares.sum(), 1.0, rtol=1e-4)


# -- Fig. 3: sector antennas -----------------------------------------------------
def test_three_sector_lobes_vs_omni():
    angles = np.linspace(-np.pi, np.pi, 73)
    r = 800.0
    ue = np.column_stack([r * np.cos(angles), r * np.sin(angles),
                          np.full(angles.size, 1.5)]).astype(np.float32)

    def tput(n_sectors):
        cells = np.array([[0.0, 0.0, 25.0]] * n_sectors, np.float32)
        sim = CRRM(CRRM_parameters(
            n_ues=angles.size, ue_positions=ue, cell_positions=cells,
            n_sectors=n_sectors, pathloss_model_name="UMa", power_W=10.0,
            fairness_p=1.0))
        g = np.asarray(sim.get_pathgains())
        return g

    g1 = tput(1)
    assert np.allclose(g1[:, 0], g1[0, 0], rtol=1e-4)  # omni: flat

    g3 = tput(3)
    best = g3.max(axis=1)
    # boresight (0 deg) vs crossover (60 deg): distinct lobes
    i_bore = np.argmin(np.abs(angles - 0.0))
    i_cross = np.argmin(np.abs(angles - np.pi / 3))
    assert best[i_bore] / best[i_cross] > 2.0
    # pattern has three-fold symmetry
    i_120 = np.argmin(np.abs(angles - 2 * np.pi / 3))
    np.testing.assert_allclose(best[i_bore], best[i_120], rtol=0.05)


def test_antenna_pattern_properties():
    ant = Antenna_gain()
    phi = jnp.linspace(-jnp.pi, jnp.pi, 181)
    att = -np.asarray(ant.pattern_dB(phi))
    assert att.min() >= 0.0 and att.max() <= 30.0  # A_max cap
    half_power = np.deg2rad(65.0) / 2
    i = np.argmin(np.abs(np.asarray(phi) - half_power))
    assert abs(att[i] - 3.0) < 0.3  # 3 dB at half the HPBW
