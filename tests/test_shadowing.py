"""TR 38.901 LOS probability + shadow fading tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim import shadowing
from repro.sim.pathloss import UMa_pathloss


@pytest.mark.parametrize("scenario", ["RMa", "UMa", "UMi", "InH"])
def test_los_probability_shape_and_monotonicity(scenario):
    d = jnp.linspace(1.0, 3000.0, 300)
    p = np.asarray(shadowing.los_probability(scenario, d))
    assert ((0.0 <= p) & (p <= 1.0)).all()
    # close-in links are (almost) surely LOS, far links rarely
    assert p[0] > 0.99
    assert p[-1] < 0.2
    # non-increasing up to numerical wiggle
    assert (np.diff(p) <= 1e-6).all()


def test_sample_los_matches_probability():
    key = jax.random.PRNGKey(0)
    d = jnp.full((2000, 50), 100.0)
    mask = np.asarray(shadowing.sample_los(key, "UMa", d))
    expect = float(shadowing.los_probability("UMa", jnp.asarray(100.0)))
    assert abs(mask.mean() - expect) < 0.02


def test_shadow_fading_statistics():
    key = jax.random.PRNGKey(1)
    los = jnp.zeros((400, 60), bool)  # all NLOS: sigma = 6 dB (UMa)
    g = np.asarray(shadowing.shadow_fading_gain(key, "UMa", los,
                                                n_sectors=3))
    db = -10.0 * np.log10(g)
    assert abs(db.mean()) < 0.5            # zero-mean in dB
    assert abs(db.std() - 6.0) < 0.5       # sigma_SF respected


def test_shadow_fading_site_correlation():
    """Co-sited sectors must see correlated shadowing; distinct sites not."""
    key = jax.random.PRNGKey(2)
    los = jnp.zeros((3000, 6), bool)       # 2 sites x 3 sectors
    g = np.asarray(shadowing.shadow_fading_gain(key, "UMa", los,
                                                n_sectors=3,
                                                site_corr=0.5))
    db = -10.0 * np.log10(g)
    same_site = np.corrcoef(db[:, 0], db[:, 1])[0, 1]
    diff_site = np.corrcoef(db[:, 0], db[:, 4])[0, 1]
    assert same_site > 0.3
    assert abs(diff_site) < 0.1


def test_mixed_pathgain_between_los_and_nlos():
    los_m = UMa_pathloss(LOS=True)
    nlos_m = UMa_pathloss(LOS=False)
    d2d = jnp.full((4, 4), 800.0)
    d3d = jnp.sqrt(d2d ** 2 + 23.5 ** 2)
    mask = jnp.eye(4, dtype=bool)
    g = shadowing.mixed_pathgain(los_m, nlos_m, mask, d2d, d3d, 25.0, 1.5)
    g_l = los_m.get_pathgain(d2d, d3d, 25.0, 1.5)
    g_n = nlos_m.get_pathgain(d2d, d3d, 25.0, 1.5)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(g)),
                               np.asarray(jnp.diagonal(g_l)))
    assert float(g[0, 1]) == float(g_n[0, 1])
    assert float(g_l[0, 0]) > float(g_n[0, 0])  # LOS stronger
