"""Hypothesis property tests on system-level invariants of the simulator."""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim import phy


def _sim(seed, n_ues, n_cells, p, k):
    return CRRM(CRRM_parameters(
        n_ues=n_ues, n_cells=n_cells, seed=seed, fairness_p=p,
        n_subbands=k, pathloss_model_name="UMa", power_W=10.0))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_ues=st.integers(3, 40),
       n_cells=st.integers(2, 12), p=st.floats(0.0, 1.0),
       k=st.integers(1, 3))
def test_invariants(seed, n_ues, n_cells, p, k):
    sim = _sim(seed, n_ues, n_cells, p, k)
    gains = np.asarray(sim.get_pathgains())
    assert (gains > 0).all() and (gains < 1).all()

    sinr = np.asarray(sim.get_SINR())
    assert np.isfinite(sinr).all() and (sinr > 0).all()

    a = np.asarray(sim.get_attachment())
    assert ((0 <= a) & (a < sim.n_cells)).all()
    rsrp = np.asarray(sim.get_RSRP()).sum(axis=2)
    np.testing.assert_array_equal(a, rsrp.argmax(axis=1))

    cqi = np.asarray(sim.get_CQI())
    mcs = np.asarray(sim.get_MCS())
    assert ((0 <= cqi) & (cqi <= 15)).all()
    assert ((0 <= mcs) & (mcs <= 28)).all()

    # Shannon bound dominates the MCS-rate throughput
    tput = np.asarray(sim.get_UE_throughputs())
    shannon = np.asarray(sim.get_shannon_capacities()).sum(axis=1)
    assert (tput <= shannon + 1e-3).all()

    # airtime conservation per active cell
    se = np.asarray(sim.get_spectral_efficiency())
    for j in range(sim.n_cells):
        for band in range(k):
            users = (a == j) & (se[:, band] > 0)
            if users.any():
                shares = (np.asarray(sim.throughput.update())[users, band]
                          / (sim.params.subband_bandwidth_Hz
                             * se[users, band]))
                np.testing.assert_allclose(shares.sum(), 1.0, rtol=1e-3)


@settings(max_examples=30, deadline=None)
@given(sinr_db=st.floats(-30.0, 50.0))
def test_cqi_mcs_monotone_chain(sinr_db):
    import jax.numpy as jnp
    lo = phy.sinr_db_to_cqi(jnp.asarray(sinr_db))
    hi = phy.sinr_db_to_cqi(jnp.asarray(sinr_db + 3.0))
    assert int(hi) >= int(lo)
    assert 0 <= int(phy.cqi_to_mcs(lo)) <= 28
    se = float(phy.spectral_efficiency(jnp.asarray(10 ** (sinr_db / 10))))
    assert 0.0 <= se <= 5.5547
