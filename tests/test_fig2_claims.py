"""Figure 2 quantitative claims: single-UE throughput vs distance for the
propagation models (RMa ~ 67 Mb/s at 2 km NLOS; UMa < 10 Mb/s)."""
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters


# 52 dBm EIRP (incl. antenna gain) at 2 GHz: a plausible rural macro setup;
# the paper does not print its exact link budget, so the asserted claim is
# the qualitative Figure-2 statement (RMa tens of Mb/s at 2 km, UMa an
# order of magnitude below) rather than the literal 67 vs <10 figures.
def tput_at(model, d, h_bs, power=160.0, fc=2.0):
    kw = {"fc_GHz": fc} if model != "power_law" else {}
    sim = CRRM(CRRM_parameters(
        n_ues=1, ue_positions=np.array([[d, 0.0, 1.5]], np.float32),
        cell_positions=np.array([[0.0, 0.0, h_bs]], np.float32),
        pathloss_model_name=model, pathloss_params=kw,
        power_W=power, bandwidth_Hz=20e6))
    return float(np.asarray(sim.get_UE_throughputs())[0])


def test_rma_vs_uma_at_2km():
    rma = tput_at("RMa", 2000.0, 35.0)
    uma = tput_at("UMa", 2000.0, 25.0)
    assert rma > 25e6, f"RMa@2km = {rma/1e6:.1f} Mb/s"
    assert uma < 15e6, f"UMa@2km = {uma/1e6:.1f} Mb/s"
    assert rma > 3 * uma


def test_throughput_decays_with_distance():
    for model, h in [("RMa", 35.0), ("UMa", 25.0), ("UMi", 10.0),
                     ("power_law", 25.0)]:
        ts = [tput_at(model, d, h) for d in (200.0, 800.0, 3200.0)]
        assert ts[0] >= ts[1] >= ts[2], (model, ts)
    # and the near-cell throughput hits the top MCS bound
    assert tput_at("RMa", 100.0, 35.0) > 80e6  # 5.55 b/s/Hz * 20 MHz
