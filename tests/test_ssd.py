"""Mamba-2 SSD (matmul dual form) vs the associative-scan recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import mamba


@pytest.mark.parametrize("s,chunk", [(24, 8), (16, 16), (9, 4)])
def test_ssd_matches_scan(s, chunk):
    cfg = get_config("zamba2-1.2b", reduced=True)
    p = mamba.mamba2_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model))
    c_scan = dataclasses.replace(cfg, ssm_impl="scan", ssm_chunk=chunk)
    c_ssd = dataclasses.replace(cfg, ssm_impl="ssd", ssm_chunk=chunk)
    y1, h1, _ = mamba.mamba2_forward(p, x, c_scan, jnp.float32,
                                     return_state=True)
    y2, h2, _ = mamba.mamba2_forward(p, x, c_ssd, jnp.float32,
                                     return_state=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5)
    g1 = jax.grad(lambda xx: mamba.mamba2_forward(
        p, xx, c_scan, jnp.float32).sum())(x)
    g2 = jax.grad(lambda xx: mamba.mamba2_forward(
        p, xx, c_ssd, jnp.float32).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-3,
                               atol=2e-4)


def test_ssd_decode_unaffected():
    """Decode (s=1) always uses the recurrence path; cache semantics hold."""
    cfg = dataclasses.replace(get_config("zamba2-1.2b", reduced=True),
                              ssm_impl="ssd")
    p = mamba.mamba2_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, cfg.d_model))
    y_full, h_full, _ = mamba.mamba2_forward(p, x, cfg, jnp.float32,
                                             return_state=True)
    # token-by-token decode must reproduce the full pass
    h = jnp.zeros_like(h_full)
    conv = jnp.zeros((1, cfg.ssm_conv - 1, cfg.d_inner))
    outs = []
    for t in range(6):
        y, h, conv = mamba.mamba2_decode(p, x[:, t:t + 1], cfg,
                                         jnp.float32, h, conv)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), atol=3e-5)
