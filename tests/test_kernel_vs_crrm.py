"""Cross-validation: the fused Pallas pipeline vs the CRRM facade.

The kernel is the TPU-native replacement for the simulator's full-recompute
path; on the same network it must reproduce the dependency graph's SINR,
attachment and wanted/unwanted powers (modulo documented f32 tolerance).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.kernels import ops
from repro.sim.antenna import sector_boresights


def test_fused_kernel_matches_crrm_facade():
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    n_ue, n_cell, K = 96, 24, 2
    U = np.column_stack([
        np.asarray(jax.random.uniform(k1, (n_ue, 2), minval=0.0,
                                      maxval=4000.0)),
        np.full((n_ue, 1), 1.5)]).astype(np.float32)
    C = np.column_stack([
        np.asarray(jax.random.uniform(k2, (n_cell, 2), minval=0.0,
                                      maxval=4000.0)),
        np.full((n_cell, 1), 25.0)]).astype(np.float32)
    Pw = np.full((n_cell, K), 5.0, np.float32)

    sim = CRRM(CRRM_parameters(
        n_ues=n_ue, ue_positions=U, cell_positions=C, power_matrix=Pw,
        n_subbands=K, pathloss_model_name="UMa", noise_power_W=1e-11))

    gamma_k, a_k, w_k, u_k = ops.fused_sinr(
        jnp.asarray(U), jnp.asarray(C), jnp.asarray(Pw),
        pathgain_fn=sim.pathloss_model.get_pathgain,
        noise_w=sim.params.subband_noise_W, bn=32, bm=32)

    np.testing.assert_array_equal(np.asarray(a_k),
                                  np.asarray(sim.get_attachment()))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(sim.w.update()),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gamma_k),
                               np.asarray(sim.get_SINR()), rtol=1e-3)


def test_fused_kernel_matches_crrm_sectored():
    """3-sector network: kernel's inlined antenna pattern vs the graph."""
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    n_sites, n_sec = 5, 3
    n_ue = 60
    n_cell = n_sites * n_sec
    U = np.column_stack([
        np.asarray(jax.random.uniform(k1, (n_ue, 2), maxval=3000.0)),
        np.full((n_ue, 1), 1.5)]).astype(np.float32)
    sites = np.column_stack([
        np.asarray(jax.random.uniform(k2, (n_sites, 2), maxval=3000.0)),
        np.full((n_sites, 1), 25.0)]).astype(np.float32)
    C = np.repeat(sites, n_sec, axis=0)
    Pw = np.full((n_cell, 1), 8.0, np.float32)

    sim = CRRM(CRRM_parameters(
        n_ues=n_ue, ue_positions=U, cell_positions=C, power_matrix=Pw,
        n_subbands=1, n_sectors=n_sec, pathloss_model_name="UMa",
        noise_power_W=1e-11))
    bore = sector_boresights(n_sites, n_sec)

    gamma_k, a_k, _, _ = ops.fused_sinr(
        jnp.asarray(U), jnp.asarray(C), jnp.asarray(Pw),
        pathgain_fn=sim.pathloss_model.get_pathgain,
        noise_w=sim.params.subband_noise_W, boresight=bore,
        n_sectors=n_sec, bn=16, bm=16)
    np.testing.assert_array_equal(np.asarray(a_k),
                                  np.asarray(sim.get_attachment()))
    np.testing.assert_allclose(np.asarray(gamma_k),
                               np.asarray(sim.get_SINR()), rtol=1e-3)
