"""Cross-validation: the fused Pallas pipeline vs the CRRM facade.

The kernel is the TPU-native replacement for the simulator's full-recompute
path; on the same network it must reproduce the dependency graph's SINR,
attachment and wanted/unwanted powers (modulo documented f32 tolerance).
Since PR 5 the kernel is also the ``backend="pallas"`` branch of
``radio.radio_forward`` -- the parity suite below runs it (interpret mode
on CPU) against the XLA branch across every registry scenario.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.kernels import ops, ref
from repro.sim import radio, scenarios
from repro.sim.antenna import sector_boresights


def test_fused_kernel_matches_crrm_facade():
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    n_ue, n_cell, K = 96, 24, 2
    U = np.column_stack([
        np.asarray(jax.random.uniform(k1, (n_ue, 2), minval=0.0,
                                      maxval=4000.0)),
        np.full((n_ue, 1), 1.5)]).astype(np.float32)
    C = np.column_stack([
        np.asarray(jax.random.uniform(k2, (n_cell, 2), minval=0.0,
                                      maxval=4000.0)),
        np.full((n_cell, 1), 25.0)]).astype(np.float32)
    Pw = np.full((n_cell, K), 5.0, np.float32)

    sim = CRRM(CRRM_parameters(
        n_ues=n_ue, ue_positions=U, cell_positions=C, power_matrix=Pw,
        n_subbands=K, pathloss_model_name="UMa", noise_power_W=1e-11))

    gamma_k, a_k, w_k, u_k = ops.fused_sinr(
        jnp.asarray(U), jnp.asarray(C), jnp.asarray(Pw),
        pathgain_fn=sim.pathloss_model.get_pathgain,
        noise_w=sim.params.subband_noise_W, bn=32, bm=32)

    np.testing.assert_array_equal(np.asarray(a_k),
                                  np.asarray(sim.get_attachment()))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(sim.w.update()),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gamma_k),
                               np.asarray(sim.get_SINR()), rtol=1e-3)


def test_fused_kernel_matches_crrm_sectored():
    """3-sector network: kernel's inlined antenna pattern vs the graph."""
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    n_sites, n_sec = 5, 3
    n_ue = 60
    n_cell = n_sites * n_sec
    U = np.column_stack([
        np.asarray(jax.random.uniform(k1, (n_ue, 2), maxval=3000.0)),
        np.full((n_ue, 1), 1.5)]).astype(np.float32)
    sites = np.column_stack([
        np.asarray(jax.random.uniform(k2, (n_sites, 2), maxval=3000.0)),
        np.full((n_sites, 1), 25.0)]).astype(np.float32)
    C = np.repeat(sites, n_sec, axis=0)
    Pw = np.full((n_cell, 1), 8.0, np.float32)

    sim = CRRM(CRRM_parameters(
        n_ues=n_ue, ue_positions=U, cell_positions=C, power_matrix=Pw,
        n_subbands=1, n_sectors=n_sec, pathloss_model_name="UMa",
        noise_power_W=1e-11))
    bore = sector_boresights(n_sites, n_sec)

    gamma_k, a_k, _, _ = ops.fused_sinr(
        jnp.asarray(U), jnp.asarray(C), jnp.asarray(Pw),
        pathgain_fn=sim.pathloss_model.get_pathgain,
        noise_w=sim.params.subband_noise_W, boresight=bore,
        n_sectors=n_sec, bn=16, bm=16)
    np.testing.assert_array_equal(np.asarray(a_k),
                                  np.asarray(sim.get_attachment()))
    np.testing.assert_allclose(np.asarray(gamma_k),
                               np.asarray(sim.get_SINR()), rtol=1e-3)


# ----------------------- radio_forward backend dispatch (ISSUE 5 satellite)
@pytest.mark.parametrize("name", scenarios.scenario_names())
def test_fused_backend_parity_with_radio_forward(name):
    """The fused-kernel dense backend (interpret mode on CPU) reproduces
    the XLA branch of ``radio_forward`` on every registry scenario's
    unfaded chain."""
    sim = CRRM(scenarios.make_scenario(name, n_ues=24, n_cells=6))
    rs = sim.radio_static()
    U = sim.U._data
    out_x = radio.radio_forward(rs, U, backend="xla")
    out_p = radio.radio_forward(rs, U, backend="pallas")
    assert out_p.G is None and out_p.rsrp is None   # never materialised
    np.testing.assert_array_equal(np.asarray(out_p.a), np.asarray(out_x.a))
    np.testing.assert_allclose(np.asarray(out_p.gamma),
                               np.asarray(out_x.gamma), rtol=1e-4)
    # CQI/SE quantise the (1e-6-close) SINR: identical except at exact
    # quantisation boundaries, which these seeds do not hit
    np.testing.assert_array_equal(np.asarray(out_p.cqi),
                                  np.asarray(out_x.cqi))
    np.testing.assert_array_equal(np.asarray(out_p.se),
                                  np.asarray(out_x.se))


@pytest.mark.parametrize("name", scenarios.scenario_names())
def test_fused_backend_parity_on_faded_chain(name):
    """Per-link fading (ISSUE 9): the kernel streams the fading tensor
    through its tile pipeline -- explicit ``backend="pallas"`` with a
    ``fad`` tensor (wideband or per-RB, including the
    ``attach_ignores_fading`` association regime) now reproduces the XLA
    branch instead of raising."""
    sim = CRRM(scenarios.make_scenario(name, n_ues=24, n_cells=6))
    rs = sim.radio_static()
    U = sim.U._data
    fad = sim.fading._data
    out_x = radio.radio_forward(rs, U, fad=fad, backend="xla")
    out_p = radio.radio_forward(rs, U, fad=fad, backend="pallas")
    assert out_p.G is None and out_p.rsrp is None
    np.testing.assert_array_equal(np.asarray(out_p.a), np.asarray(out_x.a))
    np.testing.assert_allclose(np.asarray(out_p.gamma),
                               np.asarray(out_x.gamma), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(out_p.cqi),
                                  np.asarray(out_x.cqi))
    np.testing.assert_array_equal(np.asarray(out_p.se),
                                  np.asarray(out_x.se))


def test_pallas_backend_rejects_nonstock_antenna():
    """The remaining kernel gap: a non-stock sector pattern (the kernel
    inlines the 3GPP 65-deg/30-dB pattern).  Explicit backend='pallas'
    raises with a diagnostic naming the offending knob; backend='auto'
    silently stays on XLA."""
    from repro.sim.antenna import Antenna_gain
    sim = CRRM(scenarios.make_scenario("dense_urban", n_ues=12, n_cells=6))
    rs = sim.radio_static()
    odd = rs.cfg._replace(antenna=Antenna_gain(phi_3dB_deg=70.0))
    rs_odd = radio.RadioStatic(rs.C, rs.P, rs.bore, odd)
    with pytest.raises(ValueError, match="phi_3dB_deg"):
        radio.radio_forward(rs_odd, sim.U._data, backend="pallas")
    out = radio.radio_forward(rs_odd, sim.U._data, backend="auto")
    assert out.G is not None                        # XLA branch ran


def test_ref_delegates_to_radio_chain():
    """kernels.ref is a thin view over sim.radio (no third math copy):
    its fused reference equals the radio functions composed directly."""
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    U = jnp.concatenate([jax.random.uniform(k1, (17, 2), maxval=2000.0),
                         jnp.full((17, 1), 1.5)], 1)
    C = jnp.concatenate([jax.random.uniform(k2, (5, 2), maxval=2000.0),
                         jnp.full((5, 1), 25.0)], 1)
    Pw = jnp.full((5, 3), 4.0)
    from repro.sim.pathloss import make_pathloss
    pg = make_pathloss("UMa").get_pathgain
    gamma, a, w, u = ref.fused_sinr_ref(U, C, Pw, pg, 1e-12)
    d2d, d3d, _ = radio.compute_distances(U, C)
    g = pg(d2d, d3d, C[None, :, 2], U[:, None, 2])
    R = radio.rsrp(g, Pw)
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(radio.attachment(R)))
    gamma2, w2, u2 = radio.sinr(R, radio.attachment(R), 1e-12)
    np.testing.assert_array_equal(np.asarray(gamma), np.asarray(gamma2))
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))
    d2d_r, d3d_r = ref.pairwise_dist_ref(U, C)
    np.testing.assert_array_equal(np.asarray(d2d_r), np.asarray(d2d))
    np.testing.assert_array_equal(np.asarray(d3d_r), np.asarray(d3d))
