"""Property tests for the RB-scheduler invariants (hypothesis + fixed sweep).

The invariants (ISSUE 2 / DESIGN.md §scheduler):

* per-(cell, chunk) RB allocations sum to exactly ``n_rb`` for every cell
  with at least one active attached UE on that chunk, and to 0 otherwise;
* inactive / empty-buffer UEs never receive RBs;
* PF with equal rates and equal average throughput degenerates to the
  round-robin equal split.

Each invariant is checked by one shared verifier driven two ways: a
hypothesis ``@given`` sweep (runs where hypothesis is installed, e.g. CI)
and a deterministic seed sweep that exercises the same verifier in minimal
environments.  The verifier calls ``mac_sched.allocate`` directly, so the
shapes (n_ues, n_cells, n_rb, n_chunks) are unconstrained by any simulator
topology -- exactly the shape-polymorphism the engine relies on when it
re-resolves the grid at CQI-subband granularity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.mac import scheduler as mac_sched

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # container without hypothesis: seed sweep only
    HAVE_HYPOTHESIS = False

POLICIES = list(mac_sched.SCHEDULER_POLICIES)


def _random_state(seed, n_ues, n_cells, n_chunks):
    """Random attachment / activity / CQI / PF-weight state."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, n_cells, n_ues), jnp.int32)
    active = jnp.asarray(rng.random((n_ues, n_chunks)) < 0.7)
    cqi = jnp.asarray(rng.integers(0, 16, (n_ues, n_chunks)), jnp.int32)
    log_w = jnp.asarray(rng.normal(0.0, 2.0, (n_ues, n_chunks)), jnp.float32)
    cursor = jnp.int32(rng.integers(0, 1000))
    return a, active, cqi, log_w, cursor


def check_scheduler_invariants(policy, seed, n_ues, n_cells, n_rb, n_chunks):
    a, active, cqi, log_w, cursor = _random_state(seed, n_ues, n_cells,
                                                  n_chunks)
    alloc = np.asarray(mac_sched.allocate(policy, active, cqi, a, n_cells,
                                          n_rb, cursor, log_w))
    active_np, a_np = np.asarray(active), np.asarray(a)

    # non-negativity and the inactive-UEs-get-nothing invariant
    assert (alloc >= -1e-6).all()
    assert (alloc[~active_np] == 0).all(), \
        f"{policy}: inactive UEs received RBs"

    # conservation: each (cell, chunk) grid fully used iff someone is active
    for j in range(n_cells):
        mine = a_np == j
        got = alloc[mine].sum(axis=0) if mine.any() else np.zeros(n_chunks)
        has_active = active_np[mine].any(axis=0) if mine.any() \
            else np.zeros(n_chunks, bool)
        np.testing.assert_allclose(
            got[has_active], float(n_rb), rtol=1e-5,
            err_msg=f"{policy}: cell {j} grid not fully allocated")
        assert (got[~has_active] == 0).all(), \
            f"{policy}: cell {j} granted RBs with no active UE"


def check_pf_equal_rates_is_round_robin(seed, n_ues, n_cells, n_rb,
                                        n_chunks):
    """Equal rate + equal average -> PF collapses to the equal split."""
    a, active, cqi, _, cursor = _random_state(seed, n_ues, n_cells, n_chunks)
    log_w = jnp.zeros((n_ues, n_chunks), jnp.float32)   # identical weights
    alloc = np.asarray(mac_sched.allocate("pf", active, cqi, a, n_cells,
                                          n_rb, cursor, log_w))
    active_np, a_np = np.asarray(active), np.asarray(a)
    rr = np.asarray(mac_sched.allocate("rr", active, cqi, a, n_cells, n_rb,
                                       cursor, log_w))
    for j in range(n_cells):
        mine = a_np == j
        for k in range(n_chunks):
            users = mine & active_np[:, k]
            n_act = int(users.sum())
            if not n_act:
                continue
            np.testing.assert_allclose(
                alloc[users, k], n_rb / n_act, rtol=1e-5,
                err_msg="pf with equal weights is not the equal split")
            if n_rb % n_act == 0:   # rr has no rotating remainder: exact
                np.testing.assert_allclose(alloc[users, k], rr[users, k],
                                           rtol=1e-5)


# ------------------------------------------------- deterministic seed sweep
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed,n_ues,n_cells,n_rb,n_chunks", [
    (0, 1, 1, 1, 1),           # degenerate minimum
    (1, 17, 3, 12, 4),         # chunked grid
    (2, 40, 8, 5, 1),          # wideband, indivisible n_rb
    (3, 64, 2, 52, 13),        # wide grid, many chunks
    (4, 9, 11, 7, 2),          # more cells than UEs: some cells empty
])
def test_scheduler_invariants_sweep(policy, seed, n_ues, n_cells, n_rb,
                                    n_chunks):
    check_scheduler_invariants(policy, seed, n_ues, n_cells, n_rb, n_chunks)


@pytest.mark.parametrize("seed,n_ues,n_cells,n_rb,n_chunks", [
    (0, 12, 3, 12, 1), (1, 30, 5, 8, 4), (2, 6, 2, 13, 1),
])
def test_pf_equal_rates_degenerates_to_rr_sweep(seed, n_ues, n_cells, n_rb,
                                                n_chunks):
    check_pf_equal_rates_is_round_robin(seed, n_ues, n_cells, n_rb, n_chunks)


def test_empty_buffer_ues_never_scheduled_through_graph():
    """End-to-end flavour of the invariant: zero-backlog UEs get no grant."""
    from repro.core.crrm import CRRM
    from repro.core.params import CRRM_parameters
    for policy in POLICIES:
        sim = CRRM(CRRM_parameters(
            n_ues=24, n_cells=3, seed=11, traffic_model="poisson",
            scheduler_policy=policy, pathloss_model_name="UMa",
            power_W=10.0))
        backlog = np.zeros(24, np.float32)
        backlog[5:12] = 1e6
        sim.set_backlog(backlog)
        alloc = np.asarray(sim.get_schedule())
        assert (alloc[backlog == 0] == 0).all(), policy


# ----------------------------------------------------- hypothesis sweeps
if HAVE_HYPOTHESIS:
    SHAPES = dict(seed=st.integers(0, 2 ** 16), n_ues=st.integers(1, 64),
                  n_cells=st.integers(1, 12), n_rb=st.integers(1, 64),
                  n_chunks=st.integers(1, 16))

    @settings(max_examples=25, deadline=None)
    @given(policy=st.sampled_from(POLICIES), **SHAPES)
    def test_scheduler_invariants_hypothesis(policy, seed, n_ues, n_cells,
                                             n_rb, n_chunks):
        check_scheduler_invariants(policy, seed, n_ues, n_cells, n_rb,
                                   n_chunks)

    @settings(max_examples=15, deadline=None)
    @given(**SHAPES)
    def test_pf_equal_rates_degenerates_to_rr_hypothesis(
            seed, n_ues, n_cells, n_rb, n_chunks):
        check_pf_equal_rates_is_round_robin(seed, n_ues, n_cells, n_rb,
                                            n_chunks)
