"""3GPP TR 38.901 pathloss model unit tests (+ the paper's RMa variants)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim import pathloss as pl


D2D = jnp.array([50.0, 200.0, 1000.0, 2000.0, 5000.0])
H_BS, H_UT = 35.0, 1.5


def _d3d(d2d, h_bs, h_ut):
    return jnp.sqrt(d2d ** 2 + (h_bs - h_ut) ** 2)


@pytest.mark.parametrize("name", ["RMa", "UMa", "UMi", "InH", "power_law"])
def test_gain_bounds_and_monotonicity(name):
    model = pl.make_pathloss(name)
    d2d = jnp.linspace(20.0, 4000.0, 200)
    g = model.get_pathgain(d2d, _d3d(d2d, 25.0, 1.5), 25.0, 1.5)
    assert bool((g > 0).all()) and bool((g < 1).all())  # 0 <= G < 1
    # pathloss increases with distance
    assert bool((jnp.diff(g) < 1e-12).all())


def test_rma_los_vs_nlos():
    los = pl.RMa_pathloss(LOS=True)
    nlos = pl.RMa_pathloss(LOS=False)
    d2d = jnp.array([100.0, 500.0, 2000.0])
    d3 = _d3d(d2d, H_BS, H_UT)
    assert bool((nlos.get_pathloss_dB(d2d, d3, H_BS, H_UT)
                 >= los.get_pathloss_dB(d2d, d3, H_BS, H_UT)).all())


def test_uma_more_obstructive_than_rma():
    """Figure 2's ordering: UMa NLOS attenuates far more than RMa at 2 km."""
    rma = pl.make_pathloss("RMa")
    uma = pl.make_pathloss("UMa")
    d2d = jnp.array([2000.0])
    pl_rma = rma.get_pathloss_dB(d2d, _d3d(d2d, 35.0, 1.5), 35.0, 1.5)
    pl_uma = uma.get_pathloss_dB(d2d, _d3d(d2d, 25.0, 1.5), 25.0, 1.5)
    assert float(pl_uma[0]) > float(pl_rma[0]) + 10.0  # >10 dB gap


def test_rma_constant_height_matches_full():
    full = pl.RMa_pathloss()
    const = pl.RMa_pathloss_constant_height(h_bs=H_BS, h_ut=H_UT)
    d2d = jnp.linspace(30.0, 3000.0, 50)
    d3 = _d3d(d2d, H_BS, H_UT)
    np.testing.assert_allclose(
        np.asarray(const.get_pathloss_dB(d2d, d3)),
        np.asarray(full.get_pathloss_dB(d2d, d3, H_BS, H_UT)), rtol=1e-6)


def test_rma_discretised_rmse():
    """Paper claim: the discretised LUT model has RMSE ~= 0.16 dB vs the
    full model in NLOS.  Our 0.25 m height bins must stay within 0.2 dB."""
    full = pl.RMa_pathloss()
    disc = pl.RMa_pathloss_discretised()
    rng = np.random.default_rng(0)
    d2d = jnp.asarray(rng.uniform(50.0, 5000.0, 400).astype(np.float32))
    h_ut = jnp.asarray(rng.uniform(1.0, 2.5, 400).astype(np.float32))
    d3 = _d3d(d2d, H_BS, h_ut)
    a = np.asarray(full.get_pathloss_dB(d2d, d3, H_BS, h_ut))
    b = np.asarray(disc.get_pathloss_dB(d2d, d3, H_BS, h_ut))
    rmse = float(np.sqrt(np.mean((a - b) ** 2)))
    assert rmse <= 0.2, f"discretised RMa RMSE {rmse:.3f} dB"


def test_power_law_exponent():
    m = pl.make_pathloss("power_law", alpha=3.5)
    g1 = m.get_pathgain(jnp.array([100.0]), jnp.array([100.0]))
    g2 = m.get_pathgain(jnp.array([200.0]), jnp.array([200.0]))
    np.testing.assert_allclose(float(g1[0] / g2[0]), 2 ** 3.5, rtol=1e-5)


def test_strategy_factory_rejects_unknown():
    with pytest.raises(ValueError):
        pl.make_pathloss("nonexistent-model")
