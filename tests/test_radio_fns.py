"""ISSUE 4: the pure-functional radio chain (sim.radio), graph-vs-
radio_forward bit-exactness, the unified fading/key conventions, the
mesh-sharded episode engine, and topology-batched env resets."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.mac import engine as mac_engine
from repro.sim import fading as fading_mod
from repro.sim import radio, scenarios


def _shrink(name, **kw):
    """Scenario shrunk for CI; keeps the preset's sectoring/fading knobs."""
    base = dict(n_ues=20, n_cells=6)
    base.update(kw)
    return scenarios.make_scenario(name, **base)


# ----------------------------------------------- graph == radio_forward
@pytest.mark.parametrize("name", scenarios.scenario_names())
@pytest.mark.parametrize("n_rb_subbands", [1, 4])
def test_radio_forward_bitexact_with_graph(name, n_rb_subbands):
    """The tentpole acceptance: one pure radio_forward call reproduces
    every graph-node query BIT-exactly, for every registered scenario at
    wideband and per-RB fading resolution.  (Both paths dispatch the
    shared radio.*_jit executables, so this is equality by construction,
    not tolerance.)"""
    sim = CRRM(_shrink(name, n_rb_subbands=n_rb_subbands))
    out = radio.radio_forward(sim.radio_static(), sim.U._data,
                              fad=sim.fading._data)
    for got, want in [(out.G, sim.get_pathgains()),
                      (out.rsrp, sim.get_RSRP()),
                      (out.a, sim.get_attachment()),
                      (out.gamma, sim.get_SINR()),
                      (out.cqi, sim.get_CQI()),
                      (out.mcs, sim.get_MCS()),
                      (out.se, sim.get_spectral_efficiency())]:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_radio_forward_wideband_reporting_matches_graph():
    """The cqi_report knob flows through RadioConfig identically."""
    kw = dict(n_ues=16, n_cells=3, seed=5, pathloss_model_name="UMa",
              power_W=10.0, rayleigh_fading=True, n_rb_subbands=4,
              coherence_rb=1, cqi_report="wideband")
    sim = CRRM(CRRM_parameters(**kw))
    out = radio.radio_forward(sim.radio_static(), sim.U._data,
                              fad=sim.fading._data)
    np.testing.assert_array_equal(np.asarray(out.cqi),
                                  np.asarray(sim.get_CQI()))
    np.testing.assert_array_equal(np.asarray(out.se),
                                  np.asarray(sim.get_spectral_efficiency()))


def test_radio_forward_power_override_and_jit_vmap():
    """P= overrides the static power matrix; the call jits and vmaps."""
    sim = CRRM(_shrink("dense_urban"))
    rs = sim.radio_static()
    half = rs.P * 0.5
    out = radio.radio_forward(rs, sim.U._data, fad=sim.fading._data, P=half)
    sim.set_power_matrix(half)
    np.testing.assert_allclose(np.asarray(out.se),
                               np.asarray(sim.get_spectral_efficiency()))
    # vmap over a batch of position fields = batched topologies
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    Us = jax.vmap(lambda k: jnp.concatenate(
        [jax.random.uniform(k, (sim.n_ues, 2), maxval=1000.0),
         jnp.full((sim.n_ues, 1), 1.5)], axis=1))(keys)
    batched = jax.jit(jax.vmap(lambda U: radio.radio_forward(rs, U)))(Us)
    assert batched.se.shape == (3, sim.n_ues, rs.P.shape[1])
    assert np.isfinite(np.asarray(batched.se)).all()


def test_radio_static_is_a_pytree_with_static_config():
    sim = CRRM(_shrink("indoor_hotspot"))
    rs = sim.radio_static()
    leaves, treedef = jax.tree_util.tree_flatten(rs)
    assert len(leaves) == 3                       # C, P, bore
    rs2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rs2.cfg == rs.cfg                      # config rides the treedef


# -------------------------------------------- fading / key conventions
def test_resample_fading_uses_the_one_documented_draw():
    """CRRM.resample_fading == radio.draw_fading == the legacy stream
    (seeded benches must not move)."""
    for kw, legacy in [
        (dict(rayleigh_fading=True),
         lambda k, s: fading_mod.rayleigh_power(k, (s.n_ues, s.n_cells))),
        (dict(rayleigh_fading=True, n_rb_subbands=4, coherence_rb=3),
         lambda k, s: fading_mod.subband_rayleigh_power(
             k, s.n_ues, s.n_cells,
             s.params.n_subbands * s.params.n_rb, s.params.coherence_rb,
             s.params.n_freq)),
    ]:
        sim = CRRM(CRRM_parameters(n_ues=8, n_cells=3, seed=1,
                                   pathloss_model_name="UMa", **kw))
        key = jax.random.PRNGKey(9)
        sim.resample_fading(key)
        want = legacy(key, sim)
        np.testing.assert_array_equal(np.asarray(sim.fading._data),
                                      np.asarray(want))
        got = radio.draw_fading(sim.radio_config(), key, sim.n_ues,
                                sim.n_cells)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tti_and_episode_key_conventions_are_pinned():
    """The documented key-splitting convention must never drift: seeded
    episodes (and the committed BENCH records) depend on these streams."""
    key = jax.random.PRNGKey(3)
    for t in (0, 7):
        got = radio.tti_keys(key, t)
        want = [jax.random.fold_in(key, 4 * t + i) for i in range(4)]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(radio.episode_key(5)),
        np.asarray(jax.random.fold_in(jax.random.PRNGKey(5), 0x6d6163)))
    k1, k2, k3 = radio.reset_keys(key)
    np.testing.assert_array_equal(
        np.stack([np.asarray(k1), np.asarray(k2), np.asarray(k3)]),
        np.asarray(jax.random.split(key, 3)))


def test_stationary_served_tput_matches_graph_seed():
    """The pure PF-seed twin == what init_episode_state reads off the
    graph (what topology-resampling resets rely on)."""
    for name in ("dense_urban", "rural_macro", "indoor_hotspot"):
        sim = CRRM(_shrink(name))
        pure = mac_engine.stationary_served_tput(
            sim.params, sim.n_cells, sim.get_spectral_efficiency(),
            sim.get_CQI(), sim.get_attachment(), sim.get_backlog())
        np.testing.assert_allclose(np.asarray(pure),
                                   np.asarray(sim.get_served_throughputs()),
                                   rtol=1e-6)


# -------------------------------------------------- mesh-sharded engine
def test_mesh_episode_on_trivial_mesh_matches_plain_rollout():
    """The shard_map code path (collectives and all) on a 1-device mesh
    must reproduce the plain rollout -- in-process coverage of the mesh
    branches; the real 2-device equivalence runs in a subprocess below."""
    mesh = jax.make_mesh((1,), ("ue",))
    for kw in (dict(scheduler_policy="rr", harq_bler=0.3),
               dict(scheduler_policy="max_cqi", rayleigh_fading=True,
                    n_rb_subbands=4),
               dict(scheduler_policy="pf", fairness_p=0.5, ho_enabled=True,
                    mobility_step_m=20.0)):
        base = dict(n_ues=16, n_cells=3, seed=3, pathloss_model_name="UMa",
                    power_W=10.0, traffic_model="poisson",
                    traffic_params=dict(arrival_rate_hz=300.0,
                                        packet_size_bits=12_000.0))
        base.update(kw)
        a, b = CRRM(CRRM_parameters(**base)), CRRM(CRRM_parameters(**base))
        key = jax.random.PRNGKey(0)
        f1, f2 = a.episode_fns(), b.episode_fns(mesh=mesh)
        s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key),
                            20)
        s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key),
                            20)
        np.testing.assert_allclose(np.asarray(t1), np.asarray(t2),
                                   rtol=1e-5, atol=1e-2)
        _, o1 = f1.step(a.episode_static(), a.init_episode_state(key))
        _, o2 = f2.step(b.episode_static(), b.init_episode_state(key))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-5, atol=1e-2)


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

mesh = jax.make_mesh((2,), ("ue",))
po = dict(traffic_model="poisson",
          traffic_params=dict(arrival_rate_hz=300.0,
                              packet_size_bits=12_000.0))

# (desc, bitwise?, per_tti_fading, param overrides).  rr / max_cqi are
# integer-exact across shards -> bitwise; pf's cross-shard psum reorders a
# float sum -> 1e-5 on the non-chaotic full-buffer regime (see engine
# docstring).
CASES = [
    ("rr_poisson_harq", True, False,
     dict(scheduler_policy="rr", harq_bler=0.3, **po)),
    ("max_cqi_selective", True, True,
     dict(scheduler_policy="max_cqi", rayleigh_fading=True,
          n_rb_subbands=4)),
    ("ho_mobility_rr", True, False,
     dict(scheduler_policy="rr", ho_enabled=True, rayleigh_fading=True,
          mobility_step_m=20.0, **po)),
    ("pf_full_buffer_fading", False, True,
     dict(scheduler_policy="pf", fairness_p=0.5, rayleigh_fading=True)),
]
for desc, bitwise, ptf, kw in CASES:
    base = dict(n_ues=64, n_cells=7, seed=3, pathloss_model_name="UMa",
                power_W=10.0)
    base.update(kw)
    a, b = CRRM(CRRM_parameters(**base)), CRRM(CRRM_parameters(**base))
    key = jax.random.PRNGKey(0)
    f1 = a.episode_fns(per_tti_fading=ptf)
    f2 = b.episode_fns(per_tti_fading=ptf, mesh=mesh)
    s1, t1 = f1.rollout(a.episode_static(), a.init_episode_state(key), 50)
    s2, t2 = f2.rollout(b.episode_static(), b.init_episode_state(key), 50)
    t1, t2 = np.asarray(t1), np.asarray(t2)
    if bitwise:
        np.testing.assert_array_equal(t1, t2, err_msg=desc)
    else:
        np.testing.assert_allclose(t2, t1, rtol=1e-5, atol=1e-2,
                                   err_msg=desc)
    for l1, l2 in zip(jax.tree_util.tree_leaves(s1),
                      jax.tree_util.tree_leaves(s2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-3, err_msg=desc)
    print("OK", desc)

# an indivisible UE count must be rejected up front
sim = CRRM(CRRM_parameters(n_ues=9, n_cells=3, pathloss_model_name="UMa"))
try:
    sim.episode_fns(mesh=mesh)
except ValueError as e:
    assert "divide evenly" in str(e)
    print("OK divisibility")
else:
    raise AssertionError("indivisible n_ues accepted")
print("ALL_OK")
"""


@pytest.mark.slow
def test_sharded_episode_matches_single_device_two_device_mesh():
    """ISSUE-4 acceptance: shard_mapped episodes on a 2-device host mesh
    match the single-device rollout (bitwise for rr/max_cqi, 1e-5 for
    pf).  XLA device count must be forced before jax initialises, so this
    runs in a fresh subprocess (same pattern as test_distributed_crrm)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_OK" in out.stdout


# ------------------------------------------- scenario mobility preset
def test_dense_urban_mobile_bakes_in_mobility():
    """The preset carries its trajectory: run_episode moves every UE
    without an explicit mobility_step_m argument."""
    p = scenarios.make_scenario("dense_urban_mobile", n_ues=12, n_cells=3,
                                n_sectors=1)
    assert p.mobility_step_m == 5.0 and p.ho_enabled
    sim = CRRM(p)
    U0 = np.asarray(sim.U._data).copy()
    tput = np.asarray(sim.run_episode(n_tti=10))
    assert np.isfinite(tput).all()
    U1 = np.asarray(sim.U._data)                  # synced back (moved)
    assert (np.abs(U1[:, :2] - U0[:, :2]) > 0).any()
    assert np.abs(U1[:, :2] - U0[:, :2]).max() <= 10 * 5.0 + 1e-4
    # an explicit 0 forces the static-geometry program back on
    sim2 = CRRM(scenarios.make_scenario("dense_urban_mobile", n_ues=12,
                                        n_cells=3, n_sectors=1))
    U2 = np.asarray(sim2.U._data).copy()
    sim2.run_episode(n_tti=5, mobility_step_m=0)
    np.testing.assert_array_equal(np.asarray(sim2.U._data), U2)
