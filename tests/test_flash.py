"""Flash attention (custom VJP) vs the naive oracle: values and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import chunked_attention, naive_attention


def _qkv(key, b, s, h, kv, hd, skv=None):
    k1, k2, k3 = jax.random.split(key, 3)
    skv = skv or s
    return (jax.random.normal(k1, (b, s, h, hd)),
            jax.random.normal(k2, (b, skv, kv, hd)),
            jax.random.normal(k3, (b, skv, kv, hd)))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,s,h,kv,hd,cq,ckv", [
    (2, 64, 4, 4, 16, 16, 32),
    (1, 48, 4, 2, 16, 16, 16),
    (2, 33, 4, 1, 8, 8, 8),       # GQA extreme + padding
])
def test_flash_matches_naive(causal, b, s, h, kv, hd, cq, ckv):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, s, h, kv, hd)
    do = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, hd))

    out_f = chunked_attention(q, k, v, causal=causal, chunk_q=cq,
                              chunk_kv=ckv)
    out_n = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_n),
                               atol=2e-5)

    gf = jax.grad(lambda *a: (chunked_attention(
        *a, causal=causal, chunk_q=cq, chunk_kv=ckv) * do).sum(),
        argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(lambda *a: (naive_attention(
        *a, causal=causal) * do).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-5)


def test_q_offset_decode_window():
    """q_offset shifts the causal mask for cached decode prefixes."""
    b, s, h, hd = 1, 8, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(1), b, s, h, h, hd, skv=16)
    out = chunked_attention(q, k, v, causal=True, chunk_q=4, chunk_kv=4,
                            q_offset=8)
    ref = naive_attention(q, k, v, causal=True, q_offset=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 40), hd=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 1000))
def test_flash_property(s, hd, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, s, 2, 2, hd)
    out = chunked_attention(q, k, v, causal=True, chunk_q=8, chunk_kv=8)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
    # rows are convex combinations of V rows: bounded by V extrema
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4
