"""MAC subsystem: traffic sources, RB scheduling, the scan TTI engine."""
import jax
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.mac.traffic import make_traffic


def _sim(**kw):
    base = dict(n_ues=30, n_cells=4, n_subbands=2, seed=7,
                pathloss_model_name="UMa", power_W=10.0)
    base.update(kw)
    return CRRM(CRRM_parameters(**base))


def _jain(t):
    t = np.asarray(t, np.float64)
    return float(t.sum() ** 2 / (t.size * (t * t).sum()))


# --------------------------------------------------------------- conservation
@pytest.mark.parametrize("policy", ["pf", "rr", "max_cqi"])
def test_rb_conservation(policy):
    """allocated RBs per (cell, subband) never exceed the grid size."""
    sim = _sim(scheduler_policy=policy, fairness_p=0.5 if policy == "pf"
               else 0.0)
    alloc = np.asarray(sim.get_schedule())
    a = np.asarray(sim.get_attachment())
    assert (alloc >= -1e-6).all()
    for j in range(sim.n_cells):
        per_subband = alloc[a == j].sum(axis=0)
        assert (per_subband <= sim.params.n_rb + 1e-3).all(), (j, per_subband)


def test_rb_conservation_with_partial_backlog():
    """Idle UEs get nothing; the grid still is not oversubscribed."""
    sim = _sim(traffic_model="poisson", scheduler_policy="rr")
    backlog = np.zeros(30, np.float32)
    backlog[::3] = 1e6                       # only a third of UEs have data
    sim.set_backlog(backlog)
    alloc = np.asarray(sim.get_schedule())
    assert (alloc[backlog == 0] == 0).all()
    a = np.asarray(sim.get_attachment())
    for j in range(sim.n_cells):
        assert (alloc[a == j].sum(axis=0) <= sim.params.n_rb + 1e-3).all()


# ------------------------------------------------------------------- fairness
def test_fairness_ordering_pf_rr_maxcqi():
    """Jain index: pf (p>0) > rr (equal airtime) > max_cqi (winner-take-all)."""
    ue = np.column_stack([np.linspace(100, 1200, 6), np.zeros(6),
                          np.full(6, 1.5)]).astype(np.float32)
    cell = np.array([[0.0, 0.0, 25.0]], np.float32)

    def served(policy, p=0.0):
        sim = CRRM(CRRM_parameters(
            n_ues=6, ue_positions=ue, cell_positions=cell,
            pathloss_model_name="UMa", power_W=10.0,
            scheduler_policy=policy, fairness_p=p))
        return np.asarray(sim.get_served_throughputs())

    j_pf = _jain(served("pf", p=0.5))
    j_rr = _jain(served("rr"))
    j_max = _jain(served("max_cqi"))
    assert j_pf > j_rr + 0.01, (j_pf, j_rr)
    assert j_rr > j_max + 0.05, (j_rr, j_max)


# ------------------------------------------------- legacy equivalence (tentpole)
@pytest.mark.parametrize("p", [0.0, 0.3, 1.0])
def test_full_buffer_pf_matches_legacy_throughput(p):
    """ServedThroughputNode == legacy ThroughputNode for full_buffer + pf."""
    sim = _sim(fairness_p=p, scheduler_policy="pf")
    legacy = np.asarray(sim.get_UE_throughputs())
    served = np.asarray(sim.get_served_throughputs())
    np.testing.assert_allclose(served, legacy, rtol=1e-5, atol=1e-2)


# -------------------------------------------------------------- smart update
def test_buffer_mutation_dirties_only_mac_subgraph():
    sim = _sim(traffic_model="poisson")
    sim.set_backlog(np.full(30, 1e6, np.float32))
    sim.get_served_throughputs()
    before = sim.update_counts()
    sim.add_traffic([4], [5e5])
    sim.get_served_throughputs()
    after = sim.update_counts()
    for name in ("D", "G", "RSRP", "a", "w", "u", "gamma", "CQI", "MCS",
                 "SE"):
        assert after[name] == before[name], \
            f"{name} recomputed on a buffer-only mutation"
    assert after["alloc"][0] == before["alloc"][0] + 1
    assert after["T_served"][0] == before["T_served"][0] + 1


# -------------------------------------------------------------------- traffic
def test_traffic_models_statistics():
    key = jax.random.PRNGKey(0)
    tti = 1e-3
    init, step = make_traffic("poisson", 2000, tti, arrival_rate_hz=500.0,
                              packet_size_bits=1000.0)
    assert float(np.asarray(init()).sum()) == 0.0
    bits = np.asarray(step(key, 0))
    mean = bits.mean()
    assert 300.0 < mean < 700.0          # E[bits/TTI] = 500 * 1e-3 * 1000
    init, step = make_traffic("ftp3", 500, tti, file_rate_hz=100.0,
                              file_size_bits=4e6)
    bits = np.asarray(step(key, 1))
    assert (np.mod(bits, 4e6) == 0).all()    # whole files only
    init, step = make_traffic("full_buffer", 10, tti)
    assert np.isinf(np.asarray(init())).all()
    assert float(np.asarray(step(key, 2)).sum()) == 0.0


def test_backlog_drains_when_arrivals_stop():
    sim = _sim(traffic_model="poisson", n_subbands=1,
               traffic_params=dict(arrival_rate_hz=0.0))
    sim.set_backlog(np.full(30, 2e4, np.float32))
    tput = sim.run_episode(n_tti=200)
    covered = np.asarray(sim.get_spectral_efficiency()).sum(axis=1) > 0
    backlog = np.asarray(sim.get_backlog())
    assert (backlog[covered] <= 1.0).all()
    assert (backlog >= 0.0).all()
    # served integrates to exactly the initial backlog
    served_bits = np.asarray(tput).sum(axis=0) * sim.params.tti_s
    np.testing.assert_allclose(served_bits[covered], 2e4, rtol=1e-3)


# --------------------------------------------------------------------- engine
def test_episode_full_buffer_pf_reproduces_legacy_fixed_point():
    sim = _sim(n_ues=50, n_cells=7)
    legacy = np.asarray(sim.get_UE_throughputs())
    tput = np.asarray(sim.run_episode(n_tti=50))
    assert tput.shape == (50, 50)
    np.testing.assert_allclose(tput[-1], legacy, rtol=1e-3)
    np.testing.assert_allclose(tput.mean(axis=0), legacy, rtol=1e-3)


def test_episode_is_one_compiled_scan():
    """No per-TTI Python dispatch: graph node counters must not advance."""
    sim = _sim(n_ues=40)
    sim.get_served_throughputs()          # settle the single-shot graph
    before = sim.update_counts()
    sim.run_episode(n_tti=100)
    after = sim.update_counts()
    assert after == before, "episode leaked per-TTI graph updates"


def test_episode_rr_rotation_is_fair():
    """n_rb=5 over 3 UEs: the remainder must rotate, equalising airtime."""
    ue = np.array([[300.0, 0.0, 1.5], [0.0, 300.0, 1.5],
                   [-300.0, 0.0, 1.5]], np.float32)
    cell = np.array([[0.0, 0.0, 25.0]], np.float32)
    sim = CRRM(CRRM_parameters(
        n_ues=3, ue_positions=ue, cell_positions=cell, n_rb=5,
        pathloss_model_name="UMa", power_W=10.0, scheduler_policy="rr"))
    tput = np.asarray(sim.run_episode(n_tti=6))
    se = np.asarray(sim.get_spectral_efficiency())[:, 0]
    airtime = tput.mean(axis=0) / (se * sim.params.subband_bandwidth_Hz
                                   / sim.params.n_rb)
    np.testing.assert_allclose(airtime, airtime.mean(), rtol=1e-5)


def test_episode_harq_scales_served_rate():
    sim = _sim(n_ues=40, harq_bler=0.5, seed=9)
    ref = _sim(n_ues=40, harq_bler=0.0, seed=9)
    t_harq = float(np.asarray(sim.run_episode(n_tti=400)).mean())
    t_ref = float(np.asarray(ref.run_episode(n_tti=400)).mean())
    assert 0.35 < t_harq / t_ref < 0.65      # ~ (1 - bler)


def test_episode_mobility_changes_positions_and_syncs_back():
    sim = _sim(n_ues=25)
    U0 = np.asarray(sim.U._data).copy()
    sim.run_episode(n_tti=10, mobility_step_m=20.0)
    U1 = np.asarray(sim.U._data)
    assert not np.allclose(U0[:, :2], U1[:, :2])
    step_bound = 10 * 20.0 * np.sqrt(2) + 1e-3
    assert (np.abs(U1[:, :2] - U0[:, :2]) <= step_bound).all()
    np.testing.assert_allclose(U1[:, 2], U0[:, 2])   # heights preserved
