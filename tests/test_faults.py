"""ISSUE 10: fault injection and self-healing -- the in-scan cell
outage/sleep Markov process (fault-free bitwise pin, dark-cell physics,
reattachment, dense==incremental under outages, churn/vmap/mesh
composition) and the crash-safe twin server (guard, watchdog rollback
with bitwise resume, checkpoint CRC validation + corrupt-step fallback,
backend degradation, graceful TwinServerDown)."""
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.mac import engine as mac_engine
from repro.robust import guard
from repro.robust.watchdog import (ChunkTimeout, TwinServerDown,
                                   WatchdogConfig, run_with_timeout)
from repro.sim import scenarios
from repro.sim.faults import DOWN, SLEEP, UP, FaultConfig, tx_multiplier
from repro.sim.mobility import ChurnConfig
from repro.train import checkpoint as ckpt
from repro.twin.server import TwinServer

STORM = FaultConfig(outage_rate_hz=20.0, mean_outage_s=0.03,
                    sleep_rate_hz=20.0, mean_sleep_s=0.02,
                    sleep_atten_db=10.0)
FROZEN = FaultConfig(outage_rate_hz=0.0, mean_outage_s=1.0,
                     sleep_rate_hz=0.0, mean_sleep_s=1.0)


def _params(**kw):
    base = dict(n_ues=24, n_cells=6, n_sectors=1, seed=5,
                pathloss_model_name="UMa", power_W=10.0,
                scheduler_policy="pf", traffic_model="poisson",
                traffic_params=dict(arrival_rate_hz=300.0,
                                    packet_size_bits=12_000.0))
    base.update(kw)
    return CRRM_parameters(**base)


def _roll(params, n_tti=20, key=None, telemetry=False, **fns_kw):
    sim = CRRM(params)
    fns = sim.episode_fns(telemetry=telemetry, **fns_kw)
    state = sim.init_episode_state(
        key if key is not None else jax.random.PRNGKey(0))
    return fns.rollout(sim.episode_static(), state, n_tti)


# ------------------------------------------------- fault-process invariants
def test_zero_rate_faults_bitwise_equal_off():
    """The fault PRNG lineage is its own stream: arming the fault process
    at zero transition rates must leave the trajectory BITWISE identical
    to faults-off (the compensation path never fires, and no other
    stream shifted)."""
    p = _params(mobility_step_m=10.0)
    s_off, t_off = _roll(p, faults=None)
    s_on, t_on = _roll(p, faults=FROZEN)
    np.testing.assert_array_equal(np.asarray(t_on), np.asarray(t_off))
    for name in ("U", "backlog", "pf_avg", "serving", "harq_bits"):
        np.testing.assert_array_equal(np.asarray(getattr(s_on, name)),
                                      np.asarray(getattr(s_off, name)))
    assert s_off.cell_state is None
    np.testing.assert_array_equal(np.asarray(s_on.cell_state),
                                  np.full(p.n_cells, UP))


def test_scenario_faults_off_override_restores_legacy_treedef():
    """faults=None override on a faulted preset compiles the legacy
    program: no cell_state leaf, same treedef as any pre-fault episode."""
    base = scenarios.make_scenario("outage_storm", n_ues=16, n_cells=6,
                                   faults=None)
    assert base.faults is None
    s, _ = _roll(base, n_tti=4)
    assert s.cell_state is None


def test_down_cell_is_dark():
    """A cell seeded DOWN (frozen chain: it never repairs) serves zero
    bits, is granted zero RBs and is nobody's serving cell -- the outage
    acts purely through the tx-power mask and the existing radio path."""
    p = _params(n_ues=32, n_cells=5)
    sim = CRRM(p)
    fns = sim.episode_fns(telemetry=True, faults=FROZEN)
    state = sim.init_episode_state(jax.random.PRNGKey(1))
    dark = 2
    cs = np.full(p.n_cells, UP)
    cs[dark] = DOWN
    state = mac_engine.seed_fault_state(state, cell_state=cs)
    s, t, telem = fns.rollout(sim.episode_static(), state, 25)
    served = np.asarray(telem.served_bits)       # (n_tti, n_cells)
    granted = np.asarray(telem.granted_rb)
    assert served[:, dark].sum() == 0.0, "a DOWN cell delivered bits"
    assert granted[:, dark].sum() == 0.0, "a DOWN cell was granted RBs"
    assert not (np.asarray(s.serving) == dark).any(), \
        "a UE ended the episode attached to a DOWN cell"
    assert served.sum() > 0.0, "the network died with one cell out"
    np.testing.assert_array_equal(np.asarray(s.cell_state), cs)


def test_sleep_cell_attenuated_not_dark():
    """SLEEP is a soft fault: the cell keeps serving (it can still be
    attached) but at sleep_atten_db lower tx power -- its served share
    drops vs the fault-free run instead of vanishing."""
    p = _params(n_ues=48, n_cells=5, seed=2)
    sim = CRRM(p)
    asleep = 1
    cs = np.full(p.n_cells, UP)
    cs[asleep] = SLEEP
    deep = FaultConfig(outage_rate_hz=0.0, mean_outage_s=1.0,
                       sleep_rate_hz=0.0, mean_sleep_s=1.0,
                       sleep_atten_db=30.0)

    def served_share(cell_state):
        fns = sim.episode_fns(telemetry=True, faults=deep)
        state = sim.init_episode_state(jax.random.PRNGKey(0))
        state = mac_engine.seed_fault_state(state, cell_state=cell_state)
        _, _, telem = fns.rollout(sim.episode_static(), state, 25)
        served = np.asarray(telem.served_bits)
        return served[:, asleep].sum(), served.sum()

    awake_bits, awake_total = served_share(np.full(p.n_cells, UP))
    sleep_bits, sleep_total = served_share(cs)
    assert awake_bits > 0.0 and sleep_total > 0.0
    assert sleep_bits < awake_bits, \
        "a 30 dB sleeping cell served no less than awake"
    m = np.asarray(tx_multiplier(jnp.asarray(cs), deep))
    assert m[asleep] == pytest.approx(1e-3)
    assert m[[0, 2, 3, 4]].tolist() == [1.0] * 4


def test_reattachment_conservation_under_storm():
    """Per-TTI attachment (non-HO) must never leave a UE on a DOWN cell:
    the zeroed RSRP column loses every argmax while any cell is up.
    Stepped TTI-by-TTI so each TTI's serving is checked against that
    TTI's fault state."""
    p = _params(n_ues=32, n_cells=5, seed=3)
    sim = CRRM(p)
    fns = sim.episode_fns(telemetry=True, faults=STORM)
    static = sim.episode_static()
    state = sim.init_episode_state(jax.random.PRNGKey(4))
    saw_down = 0
    for _ in range(60):
        state, _, _ = fns.step(static, state)
        cs = np.asarray(state.cell_state)
        srv = np.asarray(state.serving)
        if (cs == DOWN).any() and (cs != DOWN).any():
            saw_down += 1
            assert not (cs[srv] == DOWN).any(), \
                "a UE stayed attached to a DOWN cell"
    assert saw_down > 5, "storm never produced a mixed up/down TTI"


def test_dense_equals_incremental_under_storm():
    """The engine equivalence contract holds with the fault process on:
    the incremental path's gain-carry fault update reproduces the dense
    recompute (cell_state bitwise -- same single fault stream)."""
    base = scenarios.make_scenario("outage_storm", n_ues=24, n_cells=6)
    kw = dict(key=jax.random.PRNGKey(0), n_tti=20)
    s1, t1 = _roll(base, radio_mode="dense", **kw)
    s2, t2 = _roll(base, radio_mode="incremental", **kw)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(s2.cell_state),
                                  np.asarray(s1.cell_state))
    np.testing.assert_array_equal(np.asarray(s2.serving),
                                  np.asarray(s1.serving))
    np.testing.assert_array_equal(np.asarray(s2.U), np.asarray(s1.U))


def test_faults_compose_with_churn_and_vmap():
    """Faults + birth-death churn in one compiled scan, vmapped over a
    batch of episodes: batched cell_state, per-episode divergence."""
    p = _params(n_ues=16, n_cells=4)
    sim = CRRM(p)
    churn = ChurnConfig(arrival_rate_hz=300.0, mean_lifetime_s=0.1,
                        max_arrivals_per_tti=4)
    fns = sim.episode_fns(churn=churn, faults=STORM)
    static = sim.episode_static()
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    states = jax.vmap(lambda k: mac_engine.seed_churn_state(
        sim.init_episode_state(k), static, sim.params))(keys)
    roll = jax.vmap(lambda s: fns.rollout(static, s, 15))
    s, t = roll(states)
    assert s.cell_state.shape == (3, p.n_cells)
    assert np.asarray(t).shape == (3, 15, p.n_ues)
    assert not np.array_equal(np.asarray(t)[0], np.asarray(t)[1]), \
        "vmapped episodes did not diverge"


def test_faults_rejected_with_relax():
    with pytest.raises(ValueError, match="relax"):
        CRRM(_params()).episode_fns(faults=STORM, relax=0.5)


def test_fault_params_validation():
    with pytest.raises(ValueError, match="FaultConfig"):
        _params(faults="storm")
    with pytest.raises(ValueError):
        _params(faults=FaultConfig(outage_rate_hz=-1.0))
    with pytest.raises(ValueError):
        # per-TTI probability above 1 at tti_s=1ms
        _params(faults=FaultConfig(outage_rate_hz=2000.0))


_MESH_FAULTS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro.core.crrm import CRRM
from repro.sim import scenarios

key = jax.random.PRNGKey(0)
base = scenarios.make_scenario("outage_storm", n_ues=24, n_cells=6)

def roll(n_tti=10, **ekw):
    sim = CRRM(base)
    fns = sim.episode_fns(**ekw)
    return fns.rollout(sim.episode_static(), sim.init_episode_state(key),
                       n_tti)

for mode in ("dense", "incremental"):
    s1, t1 = roll(radio_mode=mode)
    for mesh, cell_axis in (
            (jax.make_mesh((2,), ("ue",)), None),
            (jax.make_mesh((1, 2), ("ue", "cell")), ("cell",))):
        s2, t2 = roll(radio_mode=mode, mesh=mesh, cell_axis=cell_axis)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(t1),
                                   rtol=1e-5, atol=1e-2)
        np.testing.assert_array_equal(np.asarray(s2.cell_state),
                                      np.asarray(s1.cell_state))
        np.testing.assert_array_equal(np.asarray(s2.serving),
                                      np.asarray(s1.serving))
        print("OK", mode, cell_axis)
print("ALL_OK")
"""


@pytest.mark.slow
def test_faults_on_mesh_match_single_device():
    """The fault process composes with UE sharding and the UE x cell
    mesh: the replicated cell_state chain and the compensated
    attachment match the single-device rollout bitwise/1e-5."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MESH_FAULTS_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL_OK" in out.stdout


# --------------------------------------------------------- guard invariants
def test_guard_accepts_healthy_carry():
    sim = CRRM(_params())
    fns = sim.episode_fns()
    s, _ = fns.rollout(sim.episode_static(),
                       sim.init_episode_state(jax.random.PRNGKey(0)), 5)
    assert bool(guard.carry_ok(s))
    assert guard.carry_violations(s) == []
    assert not guard.tree_has_nan(s)


def test_guard_trips_on_each_invariant():
    sim = CRRM(_params())
    s0 = sim.init_episode_state(jax.random.PRNGKey(0))
    for poisoned in (
            s0._replace(U=s0.U.at[0, 0].set(jnp.nan)),
            s0._replace(pf_avg=s0.pf_avg.at[1].set(-1.0)),
            s0._replace(harq_bits=s0.harq_bits.at[0].set(jnp.inf)),
            s0._replace(backlog=s0.backlog.at[2].set(-5.0)),
            s0._replace(t=jnp.int32(-1))):
        assert not bool(guard.carry_ok(poisoned))
        assert guard.carry_violations(poisoned) != []


def test_guard_allows_inf_backlog():
    """+inf backlog is the engine's legal full-buffer sentinel."""
    sim = CRRM(_params(traffic_model="full_buffer"))
    s0 = sim.init_episode_state(jax.random.PRNGKey(0))
    s = s0._replace(backlog=jnp.full_like(s0.backlog, jnp.inf))
    assert bool(guard.carry_ok(s))
    assert not guard.tree_has_nan(s)


def test_run_with_timeout():
    assert run_with_timeout(lambda: 41 + 1, None) == 42
    assert run_with_timeout(lambda: "fast", 5.0) == "fast"
    with pytest.raises(ZeroDivisionError):
        run_with_timeout(lambda: 1 / 0, 5.0)
    import time as _time
    with pytest.raises(ChunkTimeout):
        run_with_timeout(lambda: _time.sleep(2.0), 0.05)


# ----------------------------------------------- checkpoint hardening
def _tree(v):
    return {"w": jnp.full((4, 3), float(v)), "step": jnp.asarray(v)}


def test_save_refuses_nan_and_preserves_good_checkpoint(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    bad = {"w": jnp.full((4, 3), jnp.nan), "step": jnp.asarray(2)}
    with pytest.raises(ValueError, match="NaN"):
        ckpt.save(d, 2, bad, keep_last=1)
    with pytest.raises(ValueError, match="NaN"):
        ckpt.save_async(d, 2, bad, keep_last=1)
    # the refusal happened before any byte moved: step 1 intact + valid
    assert ckpt.all_steps(d) == [1]
    tree, _, step = ckpt.restore_latest_valid(d, _tree(0))
    assert step == 1


def test_save_allows_inf(tmp_path):
    """+inf is legal state (full-buffer backlog) -- only NaN is refused."""
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.full(3, jnp.inf), "step": jnp.asarray(1)})
    assert ckpt.all_steps(d) == [1]


def _corrupt(d, step, nbytes=8, leaf="00000.npy"):
    path = os.path.join(d, f"step_{step:010d}", leaf)
    with open(path, "r+b") as f:
        f.seek(-nbytes, os.SEEK_END)
        f.write(b"\xff" * nbytes)


def test_restore_detects_crc_corruption(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 3, _tree(3))
    ckpt.restore(d, 3, _tree(0))                 # validates clean
    # hit data bytes of the (4, 3) leaf: the npy parses fine, only the
    # CRC can tell the payload was flipped
    _corrupt(d, 3, leaf="00001.npy")
    with pytest.raises(ckpt.CheckpointCorrupt, match="CRC"):
        ckpt.restore(d, 3, _tree(0))


def test_restore_detects_truncated_leaf(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    leaf = os.path.join(d, "step_0000000001", "00000.npy")
    data = open(leaf, "rb").read()
    with open(leaf, "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(d, 1, _tree(0))


def test_restore_latest_valid_falls_back_past_corrupt(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3):
        ckpt.save(d, s, _tree(s), keep_last=0)
    _corrupt(d, 3)
    tree, _, step = ckpt.restore_latest_valid(d, _tree(0))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.full((4, 3), 2.0))
    # every step corrupt -> CheckpointCorrupt, not silence
    _corrupt(d, 1)
    _corrupt(d, 2)
    with pytest.raises(ckpt.CheckpointCorrupt, match="no valid"):
        ckpt.restore_latest_valid(d, _tree(0))


# --------------------------------------------------- twin server watchdog
def _twin(tmpdir, watchdog=None, **kw):
    p = _params(n_ues=32, n_cells=5, seed=9, **kw.pop("params_kw", {}))
    churn = ChurnConfig(arrival_rate_hz=300.0, mean_lifetime_s=0.2,
                        max_arrivals_per_tti=4)
    return TwinServer(CRRM(p), churn, chunk_tti=10,
                      ckpt_dir=None if tmpdir is None else str(tmpdir),
                      watchdog=watchdog, **kw)


def test_watchdog_requires_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt_dir"):
        _twin(None, watchdog=True)


def test_watchdog_nan_rollback_resumes_bitwise(tmp_path):
    """The self-healing acceptance: kill a chunk with a poisoned carry;
    the watchdog rolls back and the recovered trajectory is BITWISE the
    uninterrupted reference run."""
    ref = _twin(tmp_path / "ref")
    for _ in range(3):
        k_ref = ref.step_chunk()

    srv = _twin(tmp_path / "wd",
                watchdog=WatchdogConfig(max_retries=2, backoff_s=0.0,
                                        ckpt_every_chunks=1))
    srv.step_chunk()
    # poison between chunks: the next guarded chunk must trip + recover
    srv.state = srv.state._replace(U=srv.state.U.at[:, 0].set(jnp.nan))
    srv.step_chunk()
    k = srv.step_chunk()
    assert any("GuardViolation" in line for line in srv.fault_history)
    assert srv.t == ref.t
    assert k == k_ref, "recovered KPI summary diverged from reference"
    for a, b in zip(jax.tree_util.tree_leaves(srv.state),
                    jax.tree_util.tree_leaves(ref.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_survives_corrupt_latest_checkpoint(tmp_path):
    """Rollback falls through a corrupted newest step to the previous
    valid one and still resumes on the uninterrupted trajectory."""
    ref = _twin(tmp_path / "ref")
    for _ in range(3):
        ref.step_chunk()

    srv = _twin(tmp_path / "wd",
                watchdog=WatchdogConfig(max_retries=2, backoff_s=0.0,
                                        ckpt_every_chunks=1))
    srv.step_chunk()
    srv.step_chunk()
    _corrupt(srv.ckpt_dir, srv.t)                # newest checkpoint bad
    srv.state = srv.state._replace(U=srv.state.U.at[:, 0].set(jnp.nan))
    # rollback skips the corrupt step_20 to step_10; the recovery chunk
    # re-runs [10, 20), so one more chunk reaches the reference's t=30
    srv.step_chunk()
    assert srv.t == ref.t - srv.chunk_tti
    assert any("rolled back to t=10" in line for line in srv.fault_history)
    srv.step_chunk()
    assert srv.t == ref.t
    for a, b in zip(jax.tree_util.tree_leaves(srv.state),
                    jax.tree_util.tree_leaves(ref.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_chunk_timeout_recovers(tmp_path):
    """A hung chunk is abandoned at the wall-clock timeout, rolled back
    and re-run -- and the abandoned attempt's late result must never
    clobber the recovered trajectory (generation fencing)."""
    # warm the compile cache un-guarded, then arm the watchdog: the
    # timeout must measure a chunk, not the first-call compilation
    srv = _twin(tmp_path)
    srv.step_chunk()
    srv.watchdog = WatchdogConfig(max_retries=2, backoff_s=0.0,
                                  chunk_timeout_s=2.0,
                                  ckpt_every_chunks=1)
    srv.checkpoint()                             # rollback target
    t0 = srv.t
    real, armed = srv._chunk, {"on": True}

    def slow(static, state, power, fairness):
        if armed["on"]:
            armed["on"] = False
            import time as _t
            _t.sleep(4.0)
        return real(static, state, power, fairness)

    srv._chunk = slow
    srv.step_chunk()
    assert any("ChunkTimeout" in line for line in srv.fault_history)
    assert srv.t == t0 + srv.chunk_tti
    # the abandoned worker wakes mid-service and must be fenced off:
    # serve more chunks across its wake-up, then check continuity
    expect = srv.t
    for _ in range(3):
        import time as _t
        _t.sleep(0.6)
        srv.step_chunk()
        expect += srv.chunk_tti
        assert srv.t == expect, "an abandoned chunk clobbered the state"


def test_watchdog_gives_up_gracefully(tmp_path):
    srv = _twin(tmp_path,
                watchdog=WatchdogConfig(max_retries=1, backoff_s=0.0))
    srv.step_chunk()

    def explode(*a):
        raise RuntimeError("persistent kernel failure")

    srv._chunk = explode
    with pytest.raises(TwinServerDown) as ei:
        srv.step_chunk()
    assert len(ei.value.history) >= 2
    assert "persistent kernel failure" in str(ei.value)


def test_watchdog_degrades_pallas_to_xla(tmp_path):
    """A genuine chunk exception under inc_backend='auto' walks the
    degradation ladder: the chunk program is rebuilt on the XLA route
    (which also clears the injected failure) and serving continues."""
    srv = _twin(tmp_path, radio_mode="incremental", inc_backend="auto",
                watchdog=WatchdogConfig(max_retries=2, backoff_s=0.0,
                                        ckpt_every_chunks=1),
                params_kw=dict(mobility_step_m=10.0,
                               mobility_move_frac=0.25))
    t0 = srv.t
    srv.step_chunk()

    def explode(*a):
        raise RuntimeError("fused kernel fell over")

    srv._chunk = explode
    k = srv.step_chunk()                         # degrade + rollback
    assert srv.inc_backend == "xla"
    assert any("degrading" in line for line in srv.fault_history)
    assert srv.t == t0 + 2 * srv.chunk_tti
    assert all(math.isfinite(v) for v in k.values())


def test_twin_serves_fault_kpis(tmp_path):
    """A faulted twin (scenario-resolved FaultConfig) surfaces the
    outage KPIs in its chunk summaries and checkpoints/restores the
    fault leaf bitwise."""
    base = scenarios.make_scenario("outage_storm", n_ues=32, n_cells=6,
                                   faults=STORM)
    churn = ChurnConfig(arrival_rate_hz=300.0, mean_lifetime_s=0.2,
                        max_arrivals_per_tti=4)
    srv = TwinServer(CRRM(base), churn, chunk_tti=15,
                     ckpt_dir=str(tmp_path))
    k1 = srv.step_chunk()
    assert "mean_cells_down" in k1 and "reattach_events" in k1
    srv.checkpoint()
    k2 = srv.step_chunk()
    cs = np.asarray(srv.state.cell_state)
    srv.restore()
    k2b = srv.step_chunk()
    assert k2 == k2b, "restored faulted twin diverged"
    np.testing.assert_array_equal(np.asarray(srv.state.cell_state), cs)
