"""ISSUE 3: functional episode-state API, batched CrrmEnv, scenario
registry, the cqi_report knob, and the RootNode.set_at mutator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.env import CrrmEnv
from repro.sim import scenarios


def _params(**kw):
    base = dict(n_ues=20, n_cells=4, seed=7, pathloss_model_name="UMa",
                power_W=10.0, traffic_model="poisson",
                traffic_params=dict(arrival_rate_hz=300.0,
                                    packet_size_bits=12_000.0))
    base.update(kw)
    return CRRM_parameters(**base)


def _env(**kw):
    env_kw = dict(episode_tti=30, tti_per_step=10)
    for k in ("episode_tti", "tti_per_step", "per_tti_fading", "reward_fn"):
        if k in kw:
            env_kw[k] = kw.pop(k)
    return CrrmEnv(_params(**kw), **env_kw)


# ---------------------------------------------------- functional episode API
def test_run_episode_is_thin_wrapper_over_rollout():
    """The tentpole acceptance: run_episode == init_episode_state ->
    rollout, bit-exactly (same program, same PRNG streams)."""
    kw = dict(harq_bler=0.3, ho_enabled=True, n_rb_subbands=4,
              rayleigh_fading=True)
    a, b = CRRM(_params(**kw)), CRRM(_params(**kw))
    t_wrapper = np.asarray(a.run_episode(40, sync_state=False))
    fns = b.episode_fns()
    state, tput = fns.rollout(b.episode_static(), b.init_episode_state(), 40)
    np.testing.assert_array_equal(t_wrapper, np.asarray(tput))


def test_episode_state_is_a_flat_pytree():
    """EpisodeState must be a pytree of arrays -- vmap/checkpoint-able."""
    sim = CRRM(_params())
    state = sim.init_episode_state()
    leaves = jax.tree_util.tree_leaves(state)
    assert len(leaves) == 10
    assert all(hasattr(x, "dtype") for x in leaves)
    # round-trips through flatten/unflatten (what checkpointing does)
    flat, treedef = jax.tree_util.tree_flatten(state)
    state2 = jax.tree_util.tree_unflatten(treedef, flat)
    assert type(state2) is type(state)


def test_sync_episode_state_resumes_where_rollout_ended():
    """Functional threading == the legacy write-back path."""
    a, b = CRRM(_params()), CRRM(_params())
    key = jax.random.PRNGKey(5)
    a.run_episode(20, key=key)                       # legacy: sync_state
    fns = b.episode_fns()
    state, _ = fns.rollout(b.episode_static(), b.init_episode_state(key), 20)
    b.sync_episode_state(state)
    np.testing.assert_array_equal(np.asarray(a.get_backlog()),
                                  np.asarray(b.get_backlog()))
    np.testing.assert_array_equal(np.asarray(a._pf_avg),
                                  np.asarray(b._pf_avg))
    assert a.sched.cursor == b.sched.cursor


def test_reset_episode_state_reseeds_from_graph():
    sim = CRRM(_params())
    sim.run_episode(10)
    assert hasattr(sim, "_pf_avg")
    sim.reset_episode_state()
    assert not hasattr(sim, "_pf_avg")
    # next init re-seeds the PF average at the stationary point
    state = sim.init_episode_state()
    np.testing.assert_array_equal(np.asarray(state.pf_avg),
                                  np.asarray(sim.get_served_throughputs()))


def test_step_action_overrides_power_and_none_keeps_static():
    """A power action must change the radio chain; action=None must
    reproduce the static-power program exactly."""
    env = _env()
    state0, _ = env.reset(jax.random.PRNGKey(0))
    s_none, o_none, _, _ = env.step(state0, None)
    s_base, o_base, _, _ = env.step(state0, env.uniform_action())
    s_off, o_off, _, _ = env.step(state0, 0.01 * env.uniform_action())
    # 1000x less power -> radically less delivered throughput
    assert float(o_off.tput.sum()) < 0.8 * float(o_base.tput.sum())
    # uniform action == the construction-time power plan (same physics,
    # recomputed chain): throughputs agree to float tolerance
    np.testing.assert_allclose(np.asarray(o_none.tput),
                               np.asarray(o_base.tput), rtol=1e-4, atol=1.0)


def test_step_enforces_per_cell_power_budget():
    """Actions are requests: a cell asking for more than its budget is
    scaled down, so an over-budget plan cannot out-reward the baseline
    by cheating physics (10x uniform projects back onto uniform)."""
    env = _env()
    state0, _ = env.reset(jax.random.PRNGKey(0))
    _, o_base, r_base, _ = env.step(state0, env.uniform_action())
    _, o_cheat, r_cheat, _ = env.step(state0, 10.0 * env.uniform_action())
    np.testing.assert_allclose(np.asarray(o_cheat.tput),
                               np.asarray(o_base.tput), rtol=1e-5)
    np.testing.assert_allclose(float(r_cheat), float(r_base), rtol=1e-5)


# ----------------------------------------------------------- batched CrrmEnv
def test_batched_reset_step_is_deterministic():
    """Same seeds -> bit-identical batched trajectories, run to run."""
    env = _env(rayleigh_fading=True, harq_bler=0.2)
    keys = jax.random.split(jax.random.PRNGKey(3), 8)
    acts = jnp.stack([env.uniform_action()] * 8)

    def run():
        states, obs = env.reset_batch(keys)
        outs = []
        for _ in range(3):
            states, obs, rew, done = env.step_batch(states, acts)
            outs.append(np.asarray(rew))
        return np.stack(outs), np.asarray(obs.tput), np.asarray(done)

    r1, t1, d1 = run()
    r2, t2, d2 = run()
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(t1, t2)
    assert d1.shape == (8,) and d1.all() and (d1 == d2).all()


def test_batch_row_matches_single_episode():
    """vmap semantics: batch element i IS the single-env episode i."""
    env = _env(harq_bler=0.3)
    keys = jax.random.split(jax.random.PRNGKey(11), 8)
    acts = jnp.stack([env.uniform_action()] * 8)
    states, _ = env.reset_batch(keys)
    states, obs, rew, _ = env.step_batch(states, acts)

    s, _ = env.reset(keys[5])
    s, o, r, _ = env.step(s, env.uniform_action())
    np.testing.assert_allclose(np.asarray(obs.tput)[5], np.asarray(o.tput),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(float(np.asarray(rew)[5]), float(r),
                               rtol=1e-5)


def test_batched_step_traces_once_for_n_envs():
    """jit cache stability: a batch of N episodes is ONE trace/program,
    and re-stepping reuses it."""
    env = _env()
    calls = []

    def counted_step(state, action):
        calls.append(1)
        return env.step(state, action)

    stepped = jax.jit(jax.vmap(counted_step))
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    states, _ = env.reset_batch(keys)
    acts = jnp.stack([env.uniform_action()] * 8)
    out = stepped(states, acts)
    out = stepped(out[0], acts)
    jax.block_until_ready(out[1].tput)
    assert len(calls) == 1, f"{len(calls)} traces for one batch shape"


def test_env_done_fires_at_horizon():
    env = _env(episode_tti=25, tti_per_step=10)
    state, _ = env.reset(jax.random.PRNGKey(0))
    dones = []
    for _ in range(3):
        state, _, _, done = env.step(state, env.uniform_action())
        dones.append(bool(done))
    assert dones == [False, False, True]
    assert int(state.t) == 30


def test_env_rejects_bad_construction():
    with pytest.raises(ValueError, match="exactly one"):
        CrrmEnv()
    with pytest.raises(ValueError, match="exactly one"):
        CrrmEnv(_params(), scenario="dense_urban")
    with pytest.raises(ValueError, match="scenario_overrides"):
        CrrmEnv(_params(), scenario_overrides=dict(n_ues=3))
    with pytest.raises(ValueError, match=">= 1"):
        CrrmEnv(_params(), episode_tti=0)


# -------------------------------------------------------- scenario registry
def test_scenario_registry_round_trips():
    names = scenarios.scenario_names()
    assert {"dense_urban", "rural_macro", "indoor_hotspot",
            "handover_stress"} <= set(names)
    for name in names:
        p = scenarios.make_scenario(name, n_ues=8, n_cells=3)
        assert isinstance(p, CRRM_parameters)
        assert p.n_ues == 8 and p.n_cells == 3    # overrides apply
        assert scenarios.scenario_description(name)
        sim = CRRM(p)                              # constructs and queries
        assert np.isfinite(np.asarray(sim.get_UE_throughputs())).all()
    # factories return fresh objects: mutating one must not leak
    a = scenarios.make_scenario("dense_urban")
    b = scenarios.make_scenario("dense_urban")
    assert a is not b and a.n_ues == b.n_ues


def test_scenario_unknown_and_duplicate_registration():
    with pytest.raises(ValueError, match="unknown scenario"):
        scenarios.make_scenario("atlantis")
    with pytest.raises(ValueError, match="already registered"):
        scenarios.register_scenario(
            "dense_urban", "dup", lambda **kw: CRRM_parameters(**kw))
    scenarios.register_scenario(
        "test_tmp", "a test preset",
        lambda **kw: CRRM_parameters(n_ues=5, **kw))
    try:
        assert scenarios.make_scenario("test_tmp").n_ues == 5
    finally:
        scenarios._REGISTRY.pop("test_tmp")


def test_env_from_scenario_name():
    env = CrrmEnv(scenario="indoor_hotspot",
                  scenario_overrides=dict(n_ues=10, n_cells=2),
                  episode_tti=10, tti_per_step=5)
    assert env.scenario == "indoor_hotspot" and env.n_ues == 10
    state, _ = env.reset(jax.random.PRNGKey(0))
    _, obs, reward, _ = env.step(state, env.uniform_action())
    assert np.isfinite(float(reward))
    assert (np.asarray(obs.tput) >= 0).all()


# --------------------------------------------------- topology batching
def _topo_env(**kw):
    env_kw = dict(episode_tti=30, tti_per_step=10, resample_topology=True)
    for k in ("episode_tti", "tti_per_step", "per_tti_fading"):
        if k in kw:
            env_kw[k] = kw.pop(k)
    return CrrmEnv(_params(**kw), **env_kw)


def test_topology_reset_redraws_ue_field_per_seed():
    """resample_topology: each reset seed owns its own UE positions,
    fading draw and recomputed radio chain; equal seeds reproduce."""
    env = _topo_env(rayleigh_fading=True, n_rb_subbands=4)
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    states, obs = env.reset_batch(keys)
    U = np.asarray(states.ep.U)
    assert U.shape == (4, env.n_ues, 3)
    for i in range(1, 4):                      # distinct topologies
        assert not np.allclose(U[0], U[i])
    se = np.asarray(states.static.se)
    assert not np.allclose(se[0], se[1])       # chains recomputed per-topo
    s_again, _ = env.reset(keys[2])            # determinism
    np.testing.assert_array_equal(np.asarray(s_again.ep.U), U[2])
    np.testing.assert_array_equal(np.asarray(s_again.static.fad),
                                  np.asarray(states.static.fad)[2])


def test_topology_reset_chain_matches_fresh_graph():
    """The radio chain recomputed inside reset is BIT-exact with a CRRM
    graph constructed at the drawn positions with the drawn fading -- the
    pure in-reset chain is the same physics, not an approximation."""
    env = _topo_env(rayleigh_fading=True, n_rb_subbands=4)
    state, _ = env.reset(jax.random.PRNGKey(5))
    ref = CRRM(_params(rayleigh_fading=True, n_rb_subbands=4,
                       ue_positions=np.asarray(state.ep.U)))
    ref.fading.set(state.static.fad)
    np.testing.assert_array_equal(np.asarray(ref.get_spectral_efficiency()),
                                  np.asarray(state.static.se))
    np.testing.assert_array_equal(np.asarray(ref.get_CQI()),
                                  np.asarray(state.static.cqi))
    np.testing.assert_array_equal(np.asarray(ref.get_attachment()),
                                  np.asarray(state.static.a))
    # and the PF seed is that topology's stationary alpha-fair point
    np.testing.assert_allclose(np.asarray(ref.get_served_throughputs()),
                               np.asarray(state.ep.pf_avg), rtol=1e-6)


def test_topology_batched_step_runs_and_varies_across_topologies():
    env = _topo_env(harq_bler=0.2)
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    states, _ = env.reset_batch(keys)
    acts = jnp.stack([env.uniform_action()] * 6)
    states, obs, rew, done = env.step_batch(states, acts)
    tput = np.asarray(obs.tput)
    assert tput.shape == (6, env.n_ues) and np.isfinite(tput).all()
    assert np.asarray(rew).std() > 0           # topologies really differ
    assert not np.asarray(done).any()
    for _ in range(2):
        states, obs, rew, done = env.step_batch(states, acts)
    assert np.asarray(done).all()              # horizon still fires


def test_topology_reset_off_keeps_legacy_state_type():
    """Default envs still thread a bare EpisodeState (no wrapper), so all
    pre-ISSUE-4 callers and the gym adapter are untouched."""
    from repro.mac.engine import EpisodeState
    env = _env()
    state, _ = env.reset(jax.random.PRNGKey(0))
    assert isinstance(state, EpisodeState)
    topo = _topo_env()
    tstate, _ = topo.reset(jax.random.PRNGKey(0))
    from repro.env import TopoEnvState
    assert isinstance(tstate, TopoEnvState)


# ------------------------------------------------------- gymnasium adapter
def test_gym_adapter_protocol():
    gymnasium = pytest.importorskip("gymnasium")
    from repro.env.gym_adapter import make_gym_env
    env = _env(episode_tti=20, tti_per_step=10)
    genv = make_gym_env(env, seed=4)
    assert isinstance(genv, gymnasium.Env)
    obs, info = genv.reset()
    assert obs.shape == (2 * env.n_ues,) and obs.dtype == np.float32
    obs, reward, terminated, truncated, _ = genv.step(
        np.asarray(env.uniform_action()))
    assert not terminated and not truncated
    assert genv.observation_space.contains(obs)
    _, _, _, truncated, _ = genv.step(np.asarray(env.uniform_action()))
    assert truncated                               # horizon reached


def test_gym_adapter_reset_varies_and_seeds_reproduce():
    """gymnasium contract: reset() continues the RNG stream (fresh
    stochastic episodes), reset(seed=s) restarts it reproducibly."""
    pytest.importorskip("gymnasium")
    from repro.env.gym_adapter import make_gym_env
    env = _env(episode_tti=20, tti_per_step=10, harq_bler=0.3)
    genv = make_gym_env(env, seed=4)
    act = np.asarray(env.uniform_action())

    def episode_obs():
        genv.reset()
        obs, *_ = genv.step(act)
        return obs

    o1, o2 = episode_obs(), episode_obs()
    assert not np.array_equal(o1, o2)              # unseeded: varied
    genv.reset(seed=9)
    oa, *_ = genv.step(act)
    genv.reset(seed=9)
    ob, *_ = genv.step(act)
    np.testing.assert_array_equal(oa, ob)          # seeded: reproducible


# ---------------------------------------------------------- cqi_report knob
def test_wideband_report_is_noop_at_one_rb_subband():
    """The ROADMAP equivalence gate: with n_rb_subbands=1 the reporting
    knob must not change a single bit, graph or episode."""
    kw = dict(n_rb_subbands=1, n_subbands=2, rayleigh_fading=True)
    sub = CRRM(_params(cqi_report="subband", **kw))
    wb = CRRM(_params(cqi_report="wideband", **kw))
    np.testing.assert_array_equal(np.asarray(sub.get_CQI()),
                                  np.asarray(wb.get_CQI()))
    key = jax.random.PRNGKey(2)
    np.testing.assert_array_equal(
        np.asarray(sub.run_episode(30, key=key)),
        np.asarray(wb.run_episode(30, key=key)))


def test_wideband_report_decouples_reporting_from_fading():
    """cqi_report='wideband': the channel stays frequency selective but
    every chunk of a power subband reports the same CQI."""
    kw = dict(n_ues=16, n_cells=3, n_subbands=2, n_rb_subbands=4,
              coherence_rb=1, rayleigh_fading=True)
    sim = CRRM(_params(cqi_report="wideband", **kw))
    cqi = np.asarray(sim.get_CQI()).reshape(16, 2, 4)
    assert (cqi == cqi[:, :, :1]).all()           # flat within a subband
    # the underlying SINR is still selective
    gamma = np.asarray(sim.get_SINR())
    assert (gamma.std(axis=1) > 0).any()
    # and the subband-reporting twin sees selective CQI for some UE
    ref = CRRM(_params(cqi_report="subband", **kw))
    cqi_sub = np.asarray(ref.get_CQI())
    assert (cqi_sub.std(axis=1) > 0).any()


def test_wideband_report_loses_frequency_opportunism():
    """The physics the knob models: an opportunistic scheduler fed
    wideband CQI cannot ride per-chunk fading peaks, so it delivers less
    than one fed subband CQI on the same selective channel."""
    kw = dict(n_ues=20, n_cells=3, seed=5, rayleigh_fading=True,
              n_rb_subbands=12, coherence_rb=1, scheduler_policy="max_cqi",
              traffic_model="full_buffer", traffic_params={})
    key = jax.random.PRNGKey(11)
    sub = CRRM(_params(cqi_report="subband", **kw))
    wb = CRRM(_params(cqi_report="wideband", **kw))
    t_sub = np.asarray(sub.run_episode(150, key=key, per_tti_fading=True))
    t_wb = np.asarray(wb.run_episode(150, key=key, per_tti_fading=True))
    assert t_sub.mean() > t_wb.mean() * 1.1, (t_sub.mean(), t_wb.mean())


# ------------------------------------------------------- RootNode.set_at
def test_rootnode_set_at_floods_dependents():
    """The public element setter must invalidate downstream nodes exactly
    like a whole-array set (P's rows are cells, not UEs)."""
    sim = CRRM(_params(n_subbands=2))
    t0 = np.asarray(sim.get_UE_throughputs())
    sim.P.set_at((0, jnp.arange(2)), 0.001)
    t1 = np.asarray(sim.get_UE_throughputs())
    assert not np.allclose(t0, t1)
    # equivalent fresh-constructed power plan agrees
    P = np.full((4, 2), 5.0, np.float32)
    P[0] = 0.001
    ref = CRRM(_params(n_subbands=2, power_matrix=P))
    np.testing.assert_allclose(t1, np.asarray(ref.get_UE_throughputs()),
                               rtol=1e-6)
