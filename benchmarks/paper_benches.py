"""One benchmark per paper figure/table (CRRM 2025).

Each function returns (name, us_per_call, derived) where ``derived`` is the
figure's headline quantity.  ``python -m benchmarks.run`` prints them as CSV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim.mobility import random_moves


def _timeit(fn, reps=3):
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    return (time.perf_counter() - t0) / reps * 1e6


# -- Figure 2: throughput vs distance per propagation model --------------------
def fig2_pathloss_throughput():
    distances = np.array([100, 250, 500, 1000, 2000, 4000], np.float32)
    rows = {}
    for model, h_bs in [("RMa", 35.0), ("UMa", 25.0), ("UMi", 10.0),
                        ("power_law", 25.0)]:
        tput = []
        for d in distances:
            kw = {"fc_GHz": 2.0} if model != "power_law" else {}
            sim = CRRM(CRRM_parameters(
                n_ues=1, ue_positions=np.array([[d, 0.0, 1.5]], np.float32),
                cell_positions=np.array([[0.0, 0.0, h_bs]], np.float32),
                pathloss_model_name=model, pathloss_params=kw,
                power_W=160.0, bandwidth_Hz=20e6))
            tput.append(float(np.asarray(sim.get_UE_throughputs())[0]))
        rows[model] = tput
    print("# fig2: distance_m," + ",".join(rows))
    for i, d in enumerate(distances):
        print(f"# fig2: {d:.0f},"
              + ",".join(f"{rows[m][i]/1e6:.1f}" for m in rows))
    us = _timeit(lambda: CRRM(CRRM_parameters(
        n_ues=1, ue_positions=np.array([[2000.0, 0.0, 1.5]], np.float32),
        cell_positions=np.array([[0.0, 0.0, 35.0]], np.float32),
        pathloss_model_name="RMa",
        power_W=160.0)).get_UE_throughputs())
    ratio = rows["RMa"][4] / max(rows["UMa"][4], 1.0)
    return "fig2_rma_over_uma_at_2km", us, ratio


# -- Figure 3: 1-sector vs 3-sector angular throughput --------------------------
def fig3_sectors():
    angles = np.linspace(-np.pi, np.pi, 73)
    ue = np.column_stack([800 * np.cos(angles), 800 * np.sin(angles),
                          np.full(angles.size, 1.5)]).astype(np.float32)

    def gains(n_sectors):
        cells = np.array([[0.0, 0.0, 25.0]] * n_sectors, np.float32)
        sim = CRRM(CRRM_parameters(
            n_ues=angles.size, ue_positions=ue, cell_positions=cells,
            n_sectors=n_sectors, pathloss_model_name="UMa", power_W=10.0))
        return np.asarray(sim.get_pathgains()).max(axis=1)

    g3 = gains(3)
    us = _timeit(lambda: gains(3))
    lobe_ratio = float(g3.max() / g3.min())
    return "fig3_sector_lobe_ratio", us, lobe_ratio


# -- Figure 4: fairness parameter sweep -----------------------------------------
def fig4_fairness():
    rng = np.random.default_rng(5)
    ue = np.column_stack([rng.uniform(50, 1500, 12),
                          rng.uniform(50, 1500, 12),
                          np.full(12, 1.5)]).astype(np.float32)

    def spread(p):
        sim = CRRM(CRRM_parameters(
            n_ues=12, ue_positions=ue,
            cell_positions=np.array([[0.0, 0.0, 25.0]], np.float32),
            pathloss_model_name="UMa", power_W=10.0, fairness_p=p))
        t = np.asarray(sim.get_UE_throughputs())
        t = t[t > 0]
        return float(t.max() / max(t.min(), 1.0))

    ps = [0.0, 0.25, 0.5, 0.75, 1.0]
    spreads = [spread(p) for p in ps]
    print("# fig4: p=" + ",".join(map(str, ps)))
    print("# fig4: max/min=" + ",".join(f"{s:.2f}" for s in spreads))
    us = _timeit(lambda: spread(0.5))
    return "fig4_equalization_at_p1", us, spreads[-1]


# -- Figure 5: PPP SIR CCDF vs analytic theory ------------------------------------
def fig5_ppp_validation():
    import sys
    sys.path.insert(0, "tests")
    from test_ppp_theory import ppp_sir_ccdf_theory, simulate_sir

    t0 = time.perf_counter()
    sir = simulate_sir(n_bs=4000, n_ue=800)
    us = (time.perf_counter() - t0) * 1e6
    thetas = 10 ** (np.array([-5.0, 0.0, 5.0, 10.0]) / 10)
    emp = np.array([(sir > t).mean() for t in thetas])
    theo = ppp_sir_ccdf_theory(thetas)
    print("# fig5: theta_dB=-5,0,5,10")
    print("# fig5: empirical=" + ",".join(f"{e:.3f}" for e in emp))
    print("# fig5: theory=   " + ",".join(f"{t:.3f}" for t in theo))
    return "fig5_ppp_ccdf_max_err", us, float(np.abs(emp - theo).max())


# -- example 13 / §4.2: the smart-update speed-up ---------------------------------
def tab_smart_update(n_ues=5000, n_cells=500, frac=0.10, n_steps=12,
                     scenario=None):
    """``scenario`` runs the sweep on a named registry preset (shrunk to
    ``n_ues``/``n_cells``) instead of the paper's bare UMa grid -- the
    registry-portable variant examples/mobility_speedup.py uses."""
    def run(smart):
        if scenario is not None:
            from repro.sim.scenarios import make_scenario
            params = make_scenario(scenario, n_ues=n_ues, n_cells=n_cells,
                                   seed=3, smart=smart)
        else:
            params = CRRM_parameters(
                n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=3,
                smart=smart, pathloss_model_name="UMa", power_W=10.0)
        sim = CRRM(params)
        sim.get_UE_throughputs()
        key = jax.random.PRNGKey(42)
        moves = []
        for _ in range(n_steps + 2):
            key, k = jax.random.split(key)
            i, x = random_moves(k, n_ues, int(frac * n_ues),
                                params.extent_m)
            moves.append((np.asarray(i), np.asarray(x)))
        for i, x in moves[:2]:
            sim.move_UEs(i, x)
            sim.get_UE_throughputs().block_until_ready()
        t0 = time.perf_counter()
        for i, x in moves[2:]:
            sim.move_UEs(i, x)
            out = sim.get_UE_throughputs()
        out.block_until_ready()
        return (time.perf_counter() - t0) / n_steps, np.asarray(out)

    t_smart, o1 = run(True)
    t_full, o2 = run(False)
    assert np.allclose(o1, o2, rtol=1e-4), "smart != full"
    print(f"# smart_update: smart {t_smart*1e3:.1f} ms/step, "
          f"full {t_full*1e3:.1f} ms/step (identical results verified)")
    return "tab_smart_update_speedup", t_smart * 1e6, t_full / t_smart


def tab_mobility_sweep():
    """The design's operational boundary: speed-up vs mobility fraction."""
    factors = []
    for frac in (0.01, 0.10, 0.5, 1.0):
        _, us, spd = _speedup_at(frac)
        factors.append((frac, spd))
    print("# mobility_sweep: " + ", ".join(
        f"{f:.0%}->x{s:.2f}" for f, s in factors))
    return "tab_speedup_at_full_mobility", 0.0, factors[-1][1]


def _speedup_at(frac):
    name, us, spd = tab_smart_update(n_ues=2500, n_cells=250, frac=frac,
                                     n_steps=6)
    return name, us, spd


# -- kernels: fused pipeline vs materialised reference ------------------------------
def kernel_fused_sinr():
    from repro.kernels import ops, ref
    from repro.sim.pathloss import make_pathloss

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    n, m, k = 512, 256, 2
    U = jnp.concatenate([jax.random.uniform(k1, (n, 2), maxval=5000.0),
                         jnp.full((n, 1), 1.5)], 1)
    C = jnp.concatenate([jax.random.uniform(k2, (m, 2), maxval=5000.0),
                         jnp.full((m, 1), 25.0)], 1)
    Pw = jnp.full((m, k), 5.0)
    pm = make_pathloss("UMa")

    ref_fn = jax.jit(lambda: ref.fused_sinr_ref(U, C, Pw, pm.get_pathgain,
                                                1e-12))
    us = _timeit(lambda: ref_fn())
    g_a, a_a, _, _ = ops.fused_sinr(U, C, Pw, pathgain_fn=pm.get_pathgain,
                                    noise_w=1e-12)
    g_r, a_r, _, _ = ref_fn()
    err = float((jnp.abs(g_a - g_r) / jnp.maximum(jnp.abs(g_r),
                                                  1e-30)).max())
    assert bool((a_a == a_r).all())
    return "kernel_fused_sinr_max_rel_err", us, err


# -- MAC: scan-compiled TTI engine vs per-TTI graph dispatch ---------------------
#: ``benchmarks.run --smoke`` flips this: shrunken shapes, no graph-loop
#: comparison, but the per-RB-cost regression gate still asserts (CI).
SMOKE = False

#: per-RB episode must stay within this factor of the wideband per-TTI time
#: (ISSUE 2 acceptance); the bench asserts so CI fails loudly on regression.
#: The smoke gate is looser: tiny shapes on shared CI runners are dominated
#: by dispatch overhead and timer jitter, so 3.0 would flake -- 5.0 still
#: catches the real regression mode (an un-hoisted per-TTI radio chain is
#: >10x).
PER_RB_MAX_SLOWDOWN = 3.0
PER_RB_MAX_SLOWDOWN_SMOKE = 5.0

#: rr episode vs pf episode at the same shapes (ISSUE 7): the sort-based
#: segment-rank rr allocator is O(n log n) like pf's scatter floor, so the
#: ratio should be ~1x; the old masked-cumsum rank was O(n_ue x n_cell)
#: and blows past 2x as shapes grow.  Looser in smoke for dispatch noise.
RR_VS_PF_MAX_RATIO = 2.0
RR_VS_PF_MAX_RATIO_SMOKE = 3.0


def _episode_us_per_tti(sim, n_tti, key, reps=1, **kw):
    """Best-of-``reps`` us/TTI (min filters scheduler/GC noise)."""
    sim.run_episode(n_tti=n_tti, key=key, **kw)      # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = sim.run_episode(n_tti=n_tti, key=key, **kw)
        out.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / n_tti * 1e6


def _write_record(filename, record):
    """Persist a seeded benchmark record next to this module.

    Every record is self-describing for the CI regression gate
    (``benchmarks.check_regressions``): ``gated_metric`` names the ratio
    field, ``gate``/``smoke_gate`` bound it at full/smoke shapes, and
    ``gate_direction`` says which side is healthy ("max" = must stay
    below, "min" = must stay above).  Each write is provenance-stamped
    (git SHA, UTC timestamp, jax/device -- ``benchmarks.trajectory``) so
    ``python -m benchmarks.trajectory`` can render the per-PR perf table.
    """
    import json
    import os

    try:
        from benchmarks.trajectory import provenance
    except ImportError:          # benchmarks/ imported as a bare dir
        from trajectory import provenance

    record = dict(record, provenance=provenance())
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        filename)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# {record['bench']}: wrote {path}")


def mac_episode(n_ues=1000, n_cells=57, n_tti=100):
    """us/TTI for a Poisson-traffic PF episode: lax.scan engine vs a Python
    per-TTI loop over the (smart) graph, plus the per-RB link-adaptation
    cost (fully frequency-selective CQI + HARQ vs the wideband path).
    Seeds/updates ``benchmarks/BENCH_mac.json`` (full mode only)."""
    from repro.obs import StageTimer

    if SMOKE:
        n_ues, n_cells, n_tti = 200, 19, 20
    common = dict(n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=3,
                  pathloss_model_name="UMa", power_W=10.0,
                  traffic_model="poisson", scheduler_policy="pf",
                  traffic_params=dict(arrival_rate_hz=300.0,
                                      packet_size_bits=12_000.0))
    key = jax.random.PRNGKey(0)
    reps = 3          # best-of-N: the ratio gate must not eat timer noise
    gate = PER_RB_MAX_SLOWDOWN_SMOKE if SMOKE else PER_RB_MAX_SLOWDOWN
    prof = StageTimer()            # compile+measure wall share per stage

    sim = CRRM(CRRM_parameters(**common))
    with prof.stage("wideband_scan"):
        us_scan = _episode_us_per_tti(sim, n_tti, key, reps=reps)

    # per-RB: 12 CQI subbands, block fading, HARQ machine, A3 handover --
    # the full ISSUE-2 feature set in the same (static) channel regime as
    # the wideband baseline, so the ratio isolates the per-RB cost
    rb = CRRM(CRRM_parameters(
        n_rb_subbands=12, coherence_rb=4, rayleigh_fading=True,
        harq_bler=0.1, ho_enabled=True, **common))
    with prof.stage("per_rb_scan"):
        us_rb = _episode_us_per_tti(rb, n_tti, key, reps=reps)
    rb_cost = us_rb / us_scan
    print(f"# mac_episode: per-RB+HARQ+HO scan {us_rb:.1f} us/TTI "
          f"({rb_cost:.2f}x wideband; gate {gate:.0f}x)")
    assert rb_cost < gate, (
        f"per-RB episode {rb_cost:.2f}x slower than wideband "
        f"(gate {gate}x)")

    # rr parity (ISSUE 7): round-robin's within-cell rank is a sort-based
    # segment rank (O(n log n)), not the old O(n_ue x n_cell) masked
    # cumsum that cost 52 ms/TTI at 100k UEs x 57 cells -- it must stay
    # within a small factor of pf's scatter-add floor at the same shapes
    rr_gate = RR_VS_PF_MAX_RATIO_SMOKE if SMOKE else RR_VS_PF_MAX_RATIO
    rr = CRRM(CRRM_parameters(**{**common, "scheduler_policy": "rr"}))
    with prof.stage("rr_scan"):
        us_rr = _episode_us_per_tti(rr, n_tti, key, reps=reps)
    rr_cost = us_rr / us_scan
    print(f"# mac_episode: rr scan {us_rr:.1f} us/TTI "
          f"({rr_cost:.2f}x pf; gate {rr_gate:.0f}x)")
    assert rr_cost < rr_gate, (
        f"rr episode {rr_cost:.2f}x slower than pf (gate {rr_gate}x): "
        "the segment-rank allocator regressed to a per-cell cumsum")

    if SMOKE:
        print(f"# mac_episode: smoke mode, scan {us_scan:.1f} us/TTI "
              f"({n_ues} UEs x {n_tti} TTIs)")
        print(prof.report(prefix="# profile: "))
        return "mac_episode_per_rb_cost", us_scan, rb_cost

    loop = CRRM(CRRM_parameters(**common))
    with prof.stage("graph_loop"):
        loop.get_served_throughputs()                # warm the graph
        keys = jax.random.split(jax.random.PRNGKey(1), n_tti + 2)
        for t in range(2):                           # warm row buckets
            loop.step_traffic(keys[t], t)
            loop.get_served_throughputs().block_until_ready()
        t0 = time.perf_counter()
        for t in range(n_tti):
            loop.step_traffic(keys[t + 2], t)
            out = loop.get_served_throughputs()
        out.block_until_ready()
        us_loop = (time.perf_counter() - t0) / n_tti * 1e6

    print(f"# mac_episode: scan {us_scan:.1f} us/TTI, "
          f"graph loop {us_loop:.1f} us/TTI "
          f"({n_ues} UEs x {n_tti} TTIs, poisson+pf)")
    print(prof.report(prefix="# profile: "))
    _write_record("BENCH_mac.json", {
        "bench": "mac_episode", "n_ues": n_ues, "n_cells": n_cells,
        "n_tti": n_tti, "us_per_tti_scan": round(us_scan, 2),
        "us_per_tti_per_rb": round(us_rb, 2),
        "us_per_tti_rr": round(us_rr, 2),
        "us_per_tti_graph_loop": round(us_loop, 2),
        "scan_speedup_vs_graph_loop": round(us_loop / us_scan, 3),
        "per_rb_cost": round(rb_cost, 3),
        "rr_vs_pf_cost": round(rr_cost, 3),
        "gated_metric": "per_rb_cost", "gate_direction": "max",
        "gate": PER_RB_MAX_SLOWDOWN,
        "smoke_gate": PER_RB_MAX_SLOWDOWN_SMOKE})
    return "mac_episode_scan_speedup", us_scan, us_loop / us_scan


# -- env: batched CrrmEnv episodes vs sequential run_episode ---------------------
#: acceptance gate (ISSUE 3): a vmapped batch of >= 8 CrrmEnv episodes must
#: cost <= this factor per episode-TTI vs a single run_episode TTI.  The
#: batch runs the same per-episode math with the Python/dispatch overhead
#: amortised, so a healthy vmap is ~1x; >1.5x means the batch re-traced or
#: fell off the one-program path.
ENV_BATCH_MAX_SLOWDOWN = 1.5
ENV_BATCH = 8


def env_episode(n_ues=500, n_cells=19, n_tti=200):
    """us/TTI for the gym-style env: a vmapped batch of ENV_BATCH parallel
    episodes (one compiled program) vs the same episode run sequentially
    through ``run_episode``; plus a sweep of the named scenario presets.
    Seeds/updates ``benchmarks/BENCH_env.json``."""
    from repro.env import CrrmEnv
    from repro.sim.scenarios import make_scenario, scenario_names

    if SMOKE:
        n_ues, n_cells, n_tti = 100, 7, 50
    batch = ENV_BATCH
    common = dict(n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=3,
                  pathloss_model_name="UMa", power_W=10.0,
                  traffic_model="poisson", scheduler_policy="pf",
                  traffic_params=dict(arrival_rate_hz=300.0,
                                      packet_size_bits=12_000.0))
    key = jax.random.PRNGKey(0)
    reps = 3

    # sequential baseline: one sim, run_episode per episode
    sim = CRRM(CRRM_parameters(**common))
    us_single = _episode_us_per_tti(sim, n_tti, key, reps=reps)

    # batched: ENV_BATCH seeds, one vmapped program, no power action (the
    # same static-channel regime as the baseline, so the ratio isolates
    # the batching overhead)
    env = CrrmEnv(CRRM_parameters(**common), episode_tti=n_tti,
                  tti_per_step=n_tti)
    keys = jax.random.split(key, batch)

    def roll_batch():
        states, _ = env.reset_batch(keys)
        states, obs, rew, done = env.step_batch(states)
        return obs.tput

    roll_batch().block_until_ready()                 # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        roll_batch().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    us_batched = best / (n_tti * batch) * 1e6
    ratio = us_batched / us_single
    print(f"# env_episode: single {us_single:.1f} us/TTI, batched x{batch} "
          f"{us_batched:.1f} us/TTI/episode ({ratio:.2f}x; gate "
          f"{ENV_BATCH_MAX_SLOWDOWN}x)")
    assert ratio < ENV_BATCH_MAX_SLOWDOWN, (
        f"batched env episode {ratio:.2f}x slower per TTI than a single "
        f"run_episode (gate {ENV_BATCH_MAX_SLOWDOWN}x)")

    # with a power action the radio chain recomputes per TTI -- report the
    # cost (ungated: it is a different, heavier program by design)
    acts = jnp.stack([env.uniform_action()] * batch)

    def roll_batch_action():
        states, _ = env.reset_batch(keys)
        states, obs, _, _ = env.step_batch(states, acts)
        return obs.tput

    def _best_of(fn):
        fn().block_until_ready()                     # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn().block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best / (n_tti * batch) * 1e6

    us_batched_act = _best_of(roll_batch_action)
    print(f"# env_episode: batched with power action "
          f"{us_batched_act:.1f} us/TTI/episode")

    # the incremental radio mode holds the scan-constant action's chain in
    # one prepare-time init instead of a per-TTI dense recompute -- the
    # action step must get cheaper (ISSUE 5 acceptance: beat the dense
    # action cost, which was 3x the passive step)
    env_inc = CrrmEnv(CRRM_parameters(**common), episode_tti=n_tti,
                      tti_per_step=n_tti, radio_mode="incremental")

    def roll_batch_action_inc():
        states, _ = env_inc.reset_batch(keys)
        states, obs, _, _ = env_inc.step_batch(states, acts)
        return obs.tput

    np.testing.assert_allclose(np.asarray(roll_batch_action_inc()),
                               np.asarray(roll_batch_action()),
                               rtol=1e-4, atol=1.0)
    us_batched_act_inc = _best_of(roll_batch_action_inc)
    print(f"# env_episode: batched action, incremental radio mode "
          f"{us_batched_act_inc:.1f} us/TTI/episode "
          f"({us_batched_act_inc / us_batched_act:.2f}x of dense action)")
    assert us_batched_act_inc < us_batched_act, (
        f"incremental action step ({us_batched_act_inc:.1f} us/TTI) must "
        f"beat the dense per-TTI recompute ({us_batched_act:.1f} us/TTI)")

    # scenario sweep: every named preset steps as an env (shrunk shapes)
    shrink = dict(n_ues=min(n_ues, 60), n_cells=7, n_sectors=1)
    sweep = {}
    for name in scenario_names():
        p = make_scenario(name, **shrink)
        senv = CrrmEnv(p, episode_tti=20, tti_per_step=20)
        states, _ = senv.reset_batch(jax.random.split(key, batch))
        _, obs, rew, _ = senv.step_batch(states)
        sweep[name] = {
            "mean_tput_mbps": round(float(np.asarray(obs.tput).mean())
                                    / 1e6, 3),
            "mean_reward": round(float(np.asarray(rew).mean()), 3)}
        print(f"# env_episode: scenario {name}: "
              f"{sweep[name]['mean_tput_mbps']} Mbit/s/UE, "
              f"reward {sweep[name]['mean_reward']}")

    if SMOKE:
        # smoke shapes are CI-gate material, not benchmark data: never
        # clobber the committed full-scale BENCH_env.json record
        return "env_episode_batched_cost", us_batched, ratio

    record = {"bench": "env_episode", "smoke": SMOKE, "n_ues": n_ues,
              "n_cells": n_cells, "n_tti": n_tti, "batch": batch,
              "us_per_tti_single": round(us_single, 2),
              "us_per_tti_per_episode_batched": round(us_batched, 2),
              "batched_vs_single_ratio": round(ratio, 3),
              "gated_metric": "batched_vs_single_ratio",
              "gate_direction": "max",
              "gate": ENV_BATCH_MAX_SLOWDOWN,
              "smoke_gate": ENV_BATCH_MAX_SLOWDOWN,
              "us_per_tti_per_episode_batched_action":
                  round(us_batched_act, 2),
              "us_per_tti_per_episode_batched_action_incremental":
                  round(us_batched_act_inc, 2),
              "scenarios": sweep}
    _write_record("BENCH_env.json", record)
    return "env_episode_batched_cost", us_batched, ratio


# -- mesh-sharded episode engine: shard_map over the UE axis ----------------
#: acceptance gate (ISSUE 4): a shard_mapped episode on a host-platform
#: 2-device mesh must stay within this factor per TTI of the single-device
#: rollout.  Host "devices" are slices of one CPU, so sharding buys
#: parallelism only up to the collective overhead; the gate catches the
#: real regression mode (a per-TTI all-gather of an O(N x M) tensor, or
#: per-shard re-tracing, is >>3x).  The smoke gate is looser for shared CI
#: runners.
SHARDED_MAX_SLOWDOWN = 2.0
SHARDED_MAX_SLOWDOWN_SMOKE = 4.0

_SHARDED_BENCH_SCRIPT = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%d "
                           + os.environ.get("XLA_FLAGS", ""))
import jax, numpy as np
sys.path.insert(0, "src")
from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

n_ues, n_cells, n_tti, n_dev, reps = %d, %d, %d, %d, 3
# full-buffer PF: every UE active every TTI, so the pf psum (the one
# cross-shard float reduction) is exercised without the chaotic
# active-mask flips of bursty traffic -- the 1e-5 equivalence regime.
kw = dict(n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=3,
          pathloss_model_name="UMa", power_W=10.0,
          scheduler_policy="pf", fairness_p=0.5)
key = jax.random.PRNGKey(0)

def time_rollout(fns, sim):
    static, state = sim.episode_static(), sim.init_episode_state(key)
    out = fns.rollout(static, state, n_tti)           # compile + warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fns.rollout(static, state, n_tti)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best / n_tti * 1e6, np.asarray(out[1])

single = CRRM(CRRM_parameters(**kw))
us_single, t_single = time_rollout(single.episode_fns(), single)

mesh = jax.make_mesh((n_dev,), ("ue",))
shard = CRRM(CRRM_parameters(**kw))
us_shard, t_shard = time_rollout(shard.episode_fns(mesh=mesh), shard)

rel = float(np.abs(t_shard - t_single).max()
            / max(np.abs(t_single).max(), 1.0))
print(json.dumps(dict(us_per_tti_single=us_single,
                      us_per_tti_sharded=us_shard,
                      ratio=us_shard / us_single, max_rel_err=rel)))
"""


def sharded_episode(n_ues=100_000, n_cells=19, n_tti=50, n_dev=2):
    """us/TTI for a shard_mapped full-buffer PF episode on a forced
    host-platform mesh vs the single-device rollout; equivalence asserted
    to 1e-5 and the per-TTI cost ratio gated.  Seeds/updates
    ``benchmarks/BENCH_sharded.json`` (full mode only)."""
    import json
    import os
    import subprocess
    import sys

    if SMOKE:
        n_ues, n_tti = 4096, 20
    gate = SHARDED_MAX_SLOWDOWN_SMOKE if SMOKE else SHARDED_MAX_SLOWDOWN
    script = _SHARDED_BENCH_SCRIPT % (n_dev, n_ues, n_cells, n_tti, n_dev)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", script], text=True, env=env,
                         capture_output=True, timeout=3600, cwd=root)
    if out.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n"
                           f"{out.stdout}\n{out.stderr}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    print(f"# sharded_episode: {n_ues} UEs x {n_tti} TTIs on {n_dev} "
          f"host devices: single {rec['us_per_tti_single']:.1f} us/TTI, "
          f"sharded {rec['us_per_tti_sharded']:.1f} us/TTI "
          f"({rec['ratio']:.2f}x; gate {gate}x), "
          f"max rel err {rec['max_rel_err']:.2e}")
    assert rec["max_rel_err"] < 1e-5, (
        f"sharded rollout deviates from single device: "
        f"{rec['max_rel_err']:.3e}")
    assert rec["ratio"] < gate, (
        f"sharded episode {rec['ratio']:.2f}x slower per TTI than single "
        f"device (gate {gate}x)")
    if not SMOKE:
        _write_record("BENCH_sharded.json", {
            "bench": "sharded_episode", "n_ues": n_ues,
            "n_cells": n_cells, "n_tti": n_tti, "n_devices": n_dev,
            "us_per_tti_single": round(rec["us_per_tti_single"], 2),
            "us_per_tti_sharded": round(rec["us_per_tti_sharded"], 2),
            "sharded_vs_single_ratio": round(rec["ratio"], 3),
            "max_rel_err": rec["max_rel_err"],
            "gated_metric": "sharded_vs_single_ratio",
            "gate_direction": "max", "gate": gate,
            "smoke_gate": SHARDED_MAX_SLOWDOWN_SMOKE})
    return "sharded_episode_cost_ratio", rec["us_per_tti_sharded"], \
        rec["ratio"]


# -- smart update INSIDE the compiled TTI engine (ISSUE 5 tentpole) ----------
#: a 100k-UE episode with 10% of UEs moving per TTI must run >= this factor
#: faster per TTI in radio_mode="incremental" than the dense recompute
#: (stored-record gate; the measured speedup target is 3x).
SMART_UPDATE_MIN_SPEEDUP = 2.0
#: CI smoke shapes are small enough that dispatch overhead narrows the gap;
#: the smoke gate only requires the incremental path to win at all.
SMART_UPDATE_MIN_SPEEDUP_SMOKE = 1.05


def smart_update_scan(n_ues=100_000, n_cells=127, n_tti=20, frac=0.10):
    """us/TTI for the digital-twin mobility regime (10% of UEs walk per
    TTI): radio_mode="incremental" (dirty rows only, inside the scan) vs
    the dense full-chain recompute, trajectories asserted equal to 1e-5.
    A 127-cell metro grid: the dense-interference regime where the
    O(n_ue x n_cell) chain recompute dominates the per-TTI budget.
    Seeds/updates ``benchmarks/BENCH_smart_update.json`` (full mode)."""
    if SMOKE:
        n_ues, n_cells, n_tti = 4096, 57, 10
    gate = SMART_UPDATE_MIN_SPEEDUP_SMOKE if SMOKE \
        else SMART_UPDATE_MIN_SPEEDUP
    # full-buffer pf: the O(n_ue) scatter-add scheduler keeps the MAC
    # floor low, so the ratio isolates the radio-chain recompute the
    # smart update elides; single-device float reductions keep
    # dense-vs-incremental bitwise-clean
    kw = dict(n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=3,
              pathloss_model_name="UMa", power_W=10.0,
              scheduler_policy="pf", fairness_p=0.5,
              mobility_step_m=20.0, mobility_move_frac=frac)
    key = jax.random.PRNGKey(0)
    reps = 3

    def run(mode):
        sim = CRRM(CRRM_parameters(radio_mode=mode, **kw))
        fns = sim.episode_fns()
        static, state = sim.episode_static(), sim.init_episode_state(key)
        out = fns.rollout(static, state, n_tti)       # compile + warm
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fns.rollout(static, state, n_tti)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best / n_tti * 1e6, np.asarray(out[1])

    us_dense, t_dense = run("dense")
    us_inc, t_inc = run("incremental")
    rel = float(np.abs(t_inc - t_dense).max()
                / max(np.abs(t_dense).max(), 1.0))
    assert rel < 1e-5, (
        f"incremental trajectory deviates from dense: {rel:.3e}")
    speedup = us_dense / us_inc
    print(f"# smart_update_scan: {n_ues} UEs x {n_cells} cells x {n_tti} "
          f"TTIs at {frac:.0%} dirty: dense {us_dense:.1f} us/TTI, "
          f"incremental {us_inc:.1f} us/TTI -> x{speedup:.2f} "
          f"(gate {gate}x), max rel err {rel:.2e}")
    assert speedup > gate, (
        f"incremental path only x{speedup:.2f} vs dense (gate {gate}x)")
    if not SMOKE:
        _write_record("BENCH_smart_update.json", {
            "bench": "smart_update_scan", "n_ues": n_ues,
            "n_cells": n_cells, "n_tti": n_tti, "dirty_frac": frac,
            "us_per_tti_dense": round(us_dense, 2),
            "us_per_tti_incremental": round(us_inc, 2),
            "incremental_speedup": round(speedup, 3),
            "max_rel_err": rel,
            "gated_metric": "incremental_speedup",
            "gate_direction": "min", "gate": SMART_UPDATE_MIN_SPEEDUP,
            "smoke_gate": SMART_UPDATE_MIN_SPEEDUP_SMOKE})
    return "smart_update_scan_speedup", us_inc, speedup


# -- digital-twin serving: steady-state per-TTI cost under churn -----------------
#: acceptance gate (ISSUE 7): birth-death churn runs the same dense
#: dynamic-geometry chain as a mobility rollout plus O(n_ue) mask
#: maintenance and an O(max_arrivals) newborn row scatter, so the
#: steady-state per-TTI serving cost must stay within this factor of the
#: churn-free mobility rollout of the same scenario.  >2x means the churn
#: path fell off the one-program scan (per-chunk re-tracing) or a newborn
#: scatter went dense over the capacity axis.  Smoke shapes are
#: dispatch-dominated, hence the looser smoke gate.
TWIN_CHURN_MAX_OVERHEAD = 2.0
TWIN_CHURN_MAX_OVERHEAD_SMOKE = 3.0


def twin_serve(n_ues=20_000, n_cells=57, chunk_tti=50, n_chunks=4):
    """us/TTI for digital-twin serving (ISSUE 7): a chunked rollout under
    the birth-death UE process (arrivals/departures inside the compiled
    scan) vs the churn-free mobility rollout of the same scenario, plus
    the full TwinServer serving cost (chunk + KPI summarize + host
    transfer).  Seeds/updates ``benchmarks/BENCH_twin.json`` (full mode
    only)."""
    from repro.mac import engine as mac_engine
    from repro.sim.mobility import ChurnConfig
    from repro.twin import TwinServer

    if SMOKE:
        n_ues, n_cells, chunk_tti, n_chunks = 2048, 19, 10, 3
    gate = TWIN_CHURN_MAX_OVERHEAD_SMOKE if SMOKE \
        else TWIN_CHURN_MAX_OVERHEAD
    # churn on top of a walking metro scenario: the realistic twin regime.
    # The baseline drops only the churn, so the gated ratio isolates the
    # birth-death machinery itself.
    kw = dict(n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=3,
              pathloss_model_name="UMa", power_W=10.0,
              scheduler_policy="pf", fairness_p=0.5,
              mobility_step_m=20.0, mobility_move_frac=0.10,
              traffic_model="poisson", radio_mode="dense",
              traffic_params=dict(arrival_rate_hz=300.0,
                                  packet_size_bits=12_000.0))
    # stationary occupancy = rate x lifetime = 0.7 x capacity
    churn = ChurnConfig(arrival_rate_hz=0.35 * n_ues, mean_lifetime_s=2.0,
                        max_arrivals_per_tti=max(8, n_ues // 512))
    key = jax.random.PRNGKey(0)
    reps = 3

    def rollout_us(churn_cfg):
        sim = CRRM(CRRM_parameters(**kw))
        fns = sim.episode_fns(churn=churn_cfg)
        static, state = sim.episode_static(), sim.init_episode_state(key)
        if churn_cfg is not None:
            state = mac_engine.seed_churn_state(state, static, sim.params)
        out = fns.rollout(static, state, chunk_tti)   # compile + warm
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fns.rollout(static, state, chunk_tti)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best / chunk_tti * 1e6

    us_plain = rollout_us(None)
    us_churn = rollout_us(churn)
    overhead = us_churn / us_plain

    # the serving layer end to end: donated-state chunk + KPI summary
    srv = TwinServer(CRRM(CRRM_parameters(**kw)), churn,
                     chunk_tti=chunk_tti)
    kpis = srv.step_chunk()                           # compile + warm
    best = float("inf")
    for _ in range(n_chunks):
        t0 = time.perf_counter()
        kpis = srv.step_chunk()
        best = min(best, time.perf_counter() - t0)
    us_serve = best / chunk_tti * 1e6
    assert 0.0 < kpis["active_ues"] < n_ues, (
        f"churn never engaged: {kpis['active_ues']} of {n_ues} active")
    assert kpis["served_mbits"] > 0.0

    print(f"# twin_serve: {n_ues} UEs x {n_cells} cells, chunks of "
          f"{chunk_tti} TTIs: plain {us_plain:.1f} us/TTI, churn "
          f"{us_churn:.1f} us/TTI -> x{overhead:.2f} overhead (gate "
          f"{gate}x), serving {us_serve:.1f} us/TTI")
    assert overhead < gate, (
        f"churn rollout x{overhead:.2f} vs churn-free (gate {gate}x)")
    if not SMOKE:
        _write_record("BENCH_twin.json", {
            "bench": "twin_serve", "n_ues": n_ues, "n_cells": n_cells,
            "chunk_tti": chunk_tti,
            "arrival_rate_hz": churn.arrival_rate_hz,
            "mean_lifetime_s": churn.mean_lifetime_s,
            "us_per_tti_plain": round(us_plain, 2),
            "us_per_tti_churn": round(us_churn, 2),
            "us_per_tti_serving": round(us_serve, 2),
            "churn_overhead": round(overhead, 3),
            "gated_metric": "churn_overhead", "gate_direction": "max",
            "gate": TWIN_CHURN_MAX_OVERHEAD,
            "smoke_gate": TWIN_CHURN_MAX_OVERHEAD_SMOKE})
    return "twin_serve_churn_overhead", us_serve, overhead


# -- RL: PPO power-control baselines (ISSUE 8) -----------------------------------
#: the learned policy's eval-selected served-throughput uplift over the
#: uniform fixed-power plan on dense_urban must stay above this
#: ("gate_direction": "min" -- learning must keep working).  The smoke
#: run trains fewer iterations at the same tiny shapes; the pinned-seed
#: trajectory peaks ~x1.15, so 1.05 absorbs cross-machine float drift.
RL_UPLIFT_MIN = 1.05
RL_UPLIFT_MIN_SMOKE = 1.05

#: per-scenario training budgets of the seeded baselines (full mode)
RL_BASELINE_SCENARIOS = ("dense_urban", "handover_stress",
                        "dense_urban_twin")


def rl_learning():
    """PPO power-control baselines + rollout-collection cost (ISSUE 8).

    Trains the tiny pinned-seed PPO recipe of
    ``repro.rl.ppo.train_power_baseline`` and gates the dense_urban
    served-throughput uplift of the learned (eval-selected) policy over
    the uniform fixed-power plan.  Also times the jit(vmap) rollout
    collection (us per env-step, each env-step = ``tti_per_step``
    engine TTIs) -- the cost axis of population-batched training.  Full
    mode additionally trains the handover_stress and dense_urban_twin
    baselines and seeds ``benchmarks/BENCH_rl.json``.
    """
    import jax

    from repro import rl
    from repro.rl import ppo as rl_ppo

    gate = RL_UPLIFT_MIN_SMOKE if SMOKE else RL_UPLIFT_MIN
    iterations = 45 if SMOKE else 80
    scenarios = RL_BASELINE_SCENARIOS[:1] if SMOKE \
        else RL_BASELINE_SCENARIOS

    results = {}
    for scenario in scenarios:
        out = rl_ppo.train_power_baseline(scenario, n_ues=12,
                                          iterations=iterations, seed=0)
        results[scenario] = out
        print(f"# rl_learning[{scenario}]: best uplift "
              f"x{out['best_uplift']:.3f} (iter {out['best_iteration']}"
              f"/{iterations}), final x{out['final_uplift']:.3f}, "
              f"fixed {out['fixed_mbits']:.2f} Mbit")

    # rollout-collection cost: one compiled batch of n_envs streams
    dense = results["dense_urban"]
    env, pcfg, cfg = dense["env"], dense["pcfg"], dense["cfg"]
    ts = dense["train_state"]
    collect = rl.make_collect_fn(env, pcfg, cfg.n_steps)
    key = jax.random.PRNGKey(7)
    out = collect(ts.params, ts.env_states, ts.feats, key)  # warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        out = collect(ts.params, ts.env_states, ts.feats, key)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    us_per_env_step = best / (cfg.n_envs * cfg.n_steps) * 1e6

    uplift = results["dense_urban"]["best_uplift"]
    print(f"# rl_learning: collection {us_per_env_step:.1f} us/env-step "
          f"({cfg.n_envs} envs x {cfg.n_steps} steps x "
          f"{env.tti_per_step} TTIs), dense_urban uplift x{uplift:.3f} "
          f"(gate >= {gate})")
    assert uplift >= gate, (
        f"PPO stopped learning: dense_urban uplift x{uplift:.3f} "
        f"< {gate}")
    if not SMOKE:
        _write_record("BENCH_rl.json", {
            "bench": "rl_learning", "iterations": iterations,
            "n_envs": cfg.n_envs, "n_steps": cfg.n_steps,
            "n_ues": 12, "us_per_env_step": round(us_per_env_step, 2),
            "baselines": {
                s: {"best_uplift": round(r["best_uplift"], 4),
                    "final_uplift": round(r["final_uplift"], 4),
                    "best_iteration": r["best_iteration"],
                    "fixed_mbits": round(r["fixed_mbits"], 3)}
                for s, r in results.items()},
            "uplift": round(uplift, 4),
            "gated_metric": "uplift", "gate_direction": "min",
            "gate": RL_UPLIFT_MIN, "smoke_gate": RL_UPLIFT_MIN_SMOKE})
    return "rl_learning_uplift", us_per_env_step, uplift


# -- million-UE episodes (ISSUE 9): the scale ceiling of the scan engine ------
#: the incremental episode at the equivalence scale (dense is still feasible
#: there) must beat the dense recompute by this factor; the headline 1M-UE
#: run is incremental-only (a dense 1M x 127 chain materialises the O(N x M)
#: matrices the incremental path exists to avoid).
MILLION_MIN_SPEEDUP = 2.0
#: smoke shapes (the ISSUE's reduced --smoke recipe, 50k x 57) narrow the
#: gap with dispatch overhead; the incremental path must still win.
MILLION_MIN_SPEEDUP_SMOKE = 1.05


def million_episode(n_ues=1_000_000, n_cells=127, n_tti=5,
                    eq_ues=100_000, frac=0.10):
    """Million-UE episodes (ISSUE 9 tentpole): per-TTI cost of the
    incremental engine at 1M UEs x 127 cells, its dense-vs-incremental
    speed-up and 1e-5 equivalence at the feasible comparison scale
    (100k x 127, where the dense chain still fits), and the donated-state
    rollout (``rollout_donated``) with a CompileCounter no-retrace gate.
    ``inc_backend="auto"`` routes dirty rows through the fused Pallas
    kernel on TPU and the XLA row recompute on CPU hosts.
    Seeds/updates ``benchmarks/BENCH_million.json`` (full mode only);
    smoke runs the reduced 50k x 57 recipe and gates the speed-up."""
    from repro.obs.profile import CompileCounter

    if SMOKE:
        n_ues, n_cells = 50_000, 57
        eq_ues = n_ues
    gate = MILLION_MIN_SPEEDUP_SMOKE if SMOKE else MILLION_MIN_SPEEDUP
    # full-buffer pf + 10% window movers: the smart_update_scan regime at
    # the scale ceiling -- the MAC floor is O(n_ue log n_ue), so the gated
    # ratio isolates the radio-chain recompute the dirty-row path elides
    kw = dict(n_cells=n_cells, n_sectors=1, seed=3,
              pathloss_model_name="UMa", power_W=10.0,
              scheduler_policy="pf", fairness_p=0.5,
              mobility_step_m=20.0, mobility_move_frac=frac)
    key = jax.random.PRNGKey(0)
    reps = 3

    def run(n, mode):
        """us/TTI via the donated rollout, threading the consumed state."""
        sim = CRRM(CRRM_parameters(n_ues=n, radio_mode=mode, **kw))
        fns = sim.episode_fns(
            inc_backend="auto" if mode == "incremental" else None)
        # fresh key per run: donation consumes every state buffer,
        # including the embedded PRNG key -- a shared key array would be
        # deleted for the next caller
        static = sim.episode_static()
        state = sim.init_episode_state(jax.random.PRNGKey(0))
        state, out = fns.rollout_donated(static, state, n_tti)  # compile
        jax.block_until_ready((state, out))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            with CompileCounter() as c:
                state, out = fns.rollout_donated(static, state, n_tti)
                jax.block_until_ready((state, out))
            best = min(best, time.perf_counter() - t0)
            if c.supported:
                assert c.count == 0, (
                    f"donated {mode} rollout retraced ({c.count} compiles) "
                    f"-- donation must reuse the one compiled program")
        return best / n_tti * 1e6

    def run_pair(n):
        """Dense-vs-incremental trajectories (undonated: reps need the
        same initial state) at a scale where dense is feasible."""
        outs = {}
        for mode in ("dense", "incremental"):
            sim = CRRM(CRRM_parameters(n_ues=n, radio_mode=mode, **kw))
            fns = sim.episode_fns(
                inc_backend="auto" if mode == "incremental" else None)
            static = sim.episode_static()
            _, t = fns.rollout(static, sim.init_episode_state(key), n_tti)
            outs[mode] = np.asarray(t)
        rel = float(np.abs(outs["incremental"] - outs["dense"]).max()
                    / max(np.abs(outs["dense"]).max(), 1.0))
        return rel

    rel = run_pair(eq_ues)
    assert rel < 1e-5, (
        f"incremental trajectory deviates from dense at {eq_ues} UEs: "
        f"{rel:.3e}")
    us_dense_eq = run(eq_ues, "dense")
    us_inc_eq = run(eq_ues, "incremental")
    speedup = us_dense_eq / us_inc_eq
    print(f"# million_episode: {eq_ues} UEs x {n_cells} cells x {n_tti} "
          f"TTIs: dense {us_dense_eq:.1f} us/TTI, incremental "
          f"{us_inc_eq:.1f} us/TTI -> x{speedup:.2f} (gate {gate}x), "
          f"max rel err {rel:.2e}")
    assert speedup > gate, (
        f"incremental episode only x{speedup:.2f} vs dense at {eq_ues} "
        f"UEs (gate {gate}x)")
    if SMOKE:
        return "million_episode_speedup", us_inc_eq, speedup

    # the headline: a full million-UE incremental episode, end to end
    us_inc_1m = run(n_ues, "incremental")
    print(f"# million_episode: {n_ues} UEs x {n_cells} cells x {n_tti} "
          f"TTIs incremental: {us_inc_1m:.1f} us/TTI "
          f"({us_inc_1m / 1e3:.1f} ms/TTI)")
    _write_record("BENCH_million.json", {
        "bench": "million_episode", "n_ues": n_ues, "n_cells": n_cells,
        "n_tti": n_tti, "dirty_frac": frac, "eq_n_ues": eq_ues,
        "us_per_tti_dense_eq": round(us_dense_eq, 2),
        "us_per_tti_incremental_eq": round(us_inc_eq, 2),
        "us_per_tti_incremental_million": round(us_inc_1m, 2),
        "incremental_speedup": round(speedup, 3),
        "max_rel_err": rel,
        "gated_metric": "incremental_speedup",
        "gate_direction": "min", "gate": MILLION_MIN_SPEEDUP,
        "smoke_gate": MILLION_MIN_SPEEDUP_SMOKE})
    return "million_episode_us_per_tti", us_inc_1m, speedup


# -- fault injection + self-healing (ISSUE 10) -----------------------------------
#: the ``outage_storm`` rollout (in-scan Markov cell outages + A3
#: reattachment) vs the identical scenario with faults off.  The fault
#: machinery is one uniform draw, two selects and a tx-power mask per
#: TTI riding a dense mobility chain that recomputes anyway, so the
#: storm must stay near-free; >1.5x means the fault path fell off the
#: fused program (e.g. a host sync or a per-transition retrace).  Smoke
#: shapes are dispatch-dominated, hence the looser smoke bound.
FAULT_STORM_MAX_OVERHEAD = 1.5
FAULT_STORM_MAX_OVERHEAD_SMOKE = 2.5

#: the watchdog checkpoints every chunk in this recipe, so recovering
#: from a poisoned carry must cost exactly one re-run chunk of work
#: (rollback target = the previous chunk boundary) -- asserted, and the
#: measured recovery latency is recorded in the seeded record.
FAULT_RECOVERY_MAX_CHUNKS = 1


def fault_storm(n_ues=20_000, n_cells=57, n_tti=200, chunk_tti=50):
    """Fault-injection overhead + self-healing recovery latency (ISSUE 10).

    Times the ``outage_storm`` scenario (cells walking the in-scan
    outage/sleep Markov chain, A3 reattachment compensating) against the
    same scenario with ``faults=None`` and gates the ratio.  Then drills
    the self-healing serving path: a watchdog-armed ``TwinServer`` gets
    a NaN injected into its carry and must recover by rollback, losing
    at most ``FAULT_RECOVERY_MAX_CHUNKS`` chunks of re-run work; the
    recovery wall-clock is recorded in units of a healthy chunk.
    Seeds/updates ``benchmarks/BENCH_faults.json`` (full mode only)."""
    import jax.numpy as jnp

    from repro.robust.watchdog import WatchdogConfig
    from repro.sim.mobility import ChurnConfig
    from repro.sim.scenarios import make_scenario
    from repro.twin import TwinServer

    if SMOKE:
        n_ues, n_cells, n_tti, chunk_tti = 2048, 19, 30, 10
    gate = FAULT_STORM_MAX_OVERHEAD_SMOKE if SMOKE \
        else FAULT_STORM_MAX_OVERHEAD
    key = jax.random.PRNGKey(0)

    def rollout_us(faulted):
        sim = CRRM(make_scenario(
            "outage_storm", n_ues=n_ues, n_cells=n_cells,
            **({} if faulted else {"faults": None})))
        return _episode_us_per_tti(sim, n_tti, key, reps=3)

    us_plain = rollout_us(False)
    us_storm = rollout_us(True)
    overhead = us_storm / us_plain

    # the self-healing drill: healthy chunk timing, then a poisoned
    # carry -> guard trip -> rollback -> bitwise re-run, timed.  The
    # drill runs hotter fault rates than the preset so even its short
    # smoke chunks see outage TTIs.
    from repro.sim.faults import FaultConfig
    sim = CRRM(make_scenario(
        "outage_storm", n_ues=n_ues, n_cells=n_cells,
        faults=FaultConfig(outage_rate_hz=20.0, mean_outage_s=0.05,
                           sleep_rate_hz=20.0, mean_sleep_s=0.05)))
    churn = ChurnConfig(arrival_rate_hz=0.35 * n_ues, mean_lifetime_s=2.0,
                        max_arrivals_per_tti=max(8, n_ues // 512))
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        srv = TwinServer(sim, churn, chunk_tti=chunk_tti, ckpt_dir=td,
                         watchdog=WatchdogConfig(max_retries=2,
                                                 backoff_s=0.0,
                                                 ckpt_every_chunks=1))
        down = srv.step_chunk()["mean_cells_down"]    # compile + warm
        t0 = time.perf_counter()
        down += srv.step_chunk()["mean_cells_down"]
        us_chunk = time.perf_counter() - t0
        t_before = srv.t
        srv.state = srv.state._replace(
            U=srv.state.U.at[:, 0].set(jnp.nan))      # poison the carry
        t0 = time.perf_counter()
        down += srv.step_chunk()["mean_cells_down"]   # guarded recovery
        recovery_s = time.perf_counter() - t0
        assert srv.t == t_before + chunk_tti, "recovery lost TTIs"
        rollbacks = sum("rolled back" in s for s in srv.fault_history)
        assert rollbacks <= FAULT_RECOVERY_MAX_CHUNKS, (
            f"recovery took {rollbacks} rollbacks (max "
            f"{FAULT_RECOVERY_MAX_CHUNKS}): the per-chunk checkpoint "
            f"cadence stopped bounding lost work")
        assert down > 0.0, "storm produced no outages across the drill"
    recovery_chunks = recovery_s / us_chunk

    print(f"# fault_storm: {n_ues} UEs x {n_cells} cells x {n_tti} TTIs: "
          f"fault-free {us_plain:.1f} us/TTI, storm {us_storm:.1f} "
          f"us/TTI -> x{overhead:.2f} overhead (gate {gate}x); recovery "
          f"from poisoned carry: {recovery_s * 1e3:.0f} ms = "
          f"{recovery_chunks:.1f} healthy chunks ({rollbacks} rollback)")
    assert overhead < gate, (
        f"outage storm x{overhead:.2f} vs fault-free (gate {gate}x)")
    if not SMOKE:
        _write_record("BENCH_faults.json", {
            "bench": "fault_storm", "n_ues": n_ues, "n_cells": n_cells,
            "n_tti": n_tti, "chunk_tti": chunk_tti,
            "us_per_tti_plain": round(us_plain, 2),
            "us_per_tti_storm": round(us_storm, 2),
            "fault_overhead": round(overhead, 3),
            "recovery_rollbacks": rollbacks,
            "recovery_latency_chunks": round(recovery_chunks, 2),
            "gated_metric": "fault_overhead", "gate_direction": "max",
            "gate": FAULT_STORM_MAX_OVERHEAD,
            "smoke_gate": FAULT_STORM_MAX_OVERHEAD_SMOKE})
    return "fault_storm_overhead", us_storm, overhead


ALL = [fig2_pathloss_throughput, fig3_sectors, fig4_fairness,
       fig5_ppp_validation, tab_smart_update, tab_mobility_sweep,
       kernel_fused_sinr, mac_episode, env_episode, sharded_episode,
       smart_update_scan, twin_serve, million_episode, rl_learning,
       fault_storm]
