"""Bench-regression gate: rerun every recorded benchmark, fail on regression.

Loads every ``benchmarks/BENCH_*.json`` seeded record, re-runs the
benchmark that produced it (``--smoke`` shrinks shapes for CI) and fails
if the rerun's gated ratio regresses past the record's stored gate.  The
records are self-describing (written by ``paper_benches._write_record``):

* ``gated_metric``      -- the name of the ratio the gate bounds
* ``gate``              -- the bound at full benchmark shapes
* ``smoke_gate``        -- the bound a smoke-shape rerun must meet (CI
                           runners + tiny shapes are noisier, so some
                           benches store a looser smoke bound)
* ``gate_direction``    -- "max": healthy ratios stay BELOW the gate
                           (cost ratios); "min": healthy ratios stay
                           ABOVE it (speed-ups)

so adding a new gated benchmark needs no checker change beyond the
``RERUNS`` name -> function entry.  Each bench also asserts its own
internal gates (equivalence tolerances etc.) while re-running, so this
step subsumes the per-bench smoke invocations CI used to carry.

Records that cannot be checked -- no registered rerun for their
``bench``, or no ``gated_metric`` -- are reported as SKIPPED.  That is
the right default for a half-migrated checkout, but in CI a skip is a
silently-disabled gate: ``--strict`` turns every skip into a failure, so
adding a record without wiring its rerun (or dropping a metric from a
re-seed) fails the build instead of passing vacuously.

Run:  PYTHONPATH=src python -m benchmarks.check_regressions \
          [--smoke] [--strict]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def _reruns():
    from benchmarks import paper_benches as pb
    return {
        "mac_episode": pb.mac_episode,
        "env_episode": pb.env_episode,
        "sharded_episode": pb.sharded_episode,
        "smart_update_scan": pb.smart_update_scan,
        "twin_serve": pb.twin_serve,
        "million_episode": pb.million_episode,
        "rl_learning": pb.rl_learning,
        "fault_storm": pb.fault_storm,
    }


def check(record_path: str, smoke: bool) -> str:
    """Rerun one record's bench; returns a human-readable verdict line.

    Raises ``AssertionError`` on a regression past the stored gate.
    Unverifiable records return a verdict containing ``SKIPPED`` --
    ``main`` fails on those under ``--strict``.
    """
    with open(record_path) as f:
        record = json.load(f)
    bench = record.get("bench")
    reruns = _reruns()
    if bench not in reruns:
        return (f"{os.path.basename(record_path)}: no rerun registered "
                f"for bench {bench!r} -- SKIPPED")
    metric = record.get("gated_metric")
    if metric is None:
        return (f"{os.path.basename(record_path)}: record carries no "
                f"gated_metric -- SKIPPED (re-seed with a full bench run)")
    gate = record["smoke_gate"] if smoke and "smoke_gate" in record \
        else record["gate"]
    direction = record.get("gate_direction", "max")
    name, us, derived = reruns[bench]()    # internal gates assert here too
    if smoke:
        # every bench's smoke return value IS its gated ratio (no record
        # is written at smoke shapes)
        ratio = derived
    else:
        # a full-shape rerun re-seeds the record file; its gated metric
        # is authoritative (some benches return a different headline
        # number in full mode, e.g. mac_episode's scan-vs-graph speedup)
        with open(record_path) as f:
            reseeded = json.load(f)
        if metric not in reseeded:
            raise AssertionError(
                f"{bench}: full-shape rerun re-seeded "
                f"{os.path.basename(record_path)} WITHOUT its gated "
                f"metric {metric!r} -- the bench stopped writing the "
                f"field the gate reads (fix _write_record's payload or "
                f"the record's gated_metric)")
        ratio = reseeded[metric]
    healthy = ratio < gate if direction == "max" else ratio > gate
    verdict = (f"{bench}: {metric} rerun={ratio:.3f} vs stored "
               f"{record.get(metric)} (gate {'<' if direction == 'max' else '>'}"
               f" {gate}{' smoke' if smoke else ''})")
    assert healthy, f"REGRESSION {verdict}"
    return verdict + " OK"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken shapes + smoke gates (CI)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on SKIPPED records too: every committed "
                         "record must actually be gated (CI)")
    ap.add_argument("--only", default="",
                    help="check only records whose filename contains SUBSTR")
    ap.add_argument("--dir", default=None,
                    help="directory holding BENCH_*.json (default: next "
                         "to this module)")
    args = ap.parse_args(argv)
    from benchmarks import paper_benches
    paper_benches.SMOKE = args.smoke

    here = args.dir or os.path.dirname(os.path.abspath(__file__))
    records = sorted(glob.glob(os.path.join(here, "BENCH_*.json")))
    records = [r for r in records if args.only in os.path.basename(r)]
    if not records:
        raise SystemExit(f"no BENCH_*.json records match {args.only!r}")
    failures, skips = [], []
    for path in records:
        try:
            verdict = check(path, args.smoke)
            if "SKIPPED" in verdict:
                skips.append(verdict)
            print(f"== {verdict}")
        except AssertionError as e:
            failures.append(str(e))
            print(f"== {e}")
        sys.stdout.flush()
    if skips and args.strict:
        failures.append(
            f"STRICT: {len(skips)} record(s) skipped -- every committed "
            f"BENCH_*.json must be verifiable:\n  " + "\n  ".join(skips))
    if failures:
        raise SystemExit("\n".join(failures))
    checked = len(records) - len(skips)
    print(f"all {checked} checked benchmarks within their gates"
          + (f" ({len(skips)} skipped)" if skips else ""))


if __name__ == "__main__":
    main()
