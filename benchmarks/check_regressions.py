"""Bench-regression gate: rerun every recorded benchmark, fail on regression.

Loads every ``benchmarks/BENCH_*.json`` seeded record, re-runs the
benchmark that produced it (``--smoke`` shrinks shapes for CI) and fails
if the rerun's gated ratio regresses past the record's stored gate.  The
records are self-describing (written by ``paper_benches._write_record``):

* ``gated_metric``      -- the name of the ratio the gate bounds
* ``gate``              -- the bound at full benchmark shapes
* ``smoke_gate``        -- the bound a smoke-shape rerun must meet (CI
                           runners + tiny shapes are noisier, so some
                           benches store a looser smoke bound)
* ``gate_direction``    -- "max": healthy ratios stay BELOW the gate
                           (cost ratios); "min": healthy ratios stay
                           ABOVE it (speed-ups)

so adding a new gated benchmark needs no checker change beyond the
``RERUNS`` name -> function entry.  Each bench also asserts its own
internal gates (equivalence tolerances etc.) while re-running, so this
step subsumes the per-bench smoke invocations CI used to carry.

Run:  PYTHONPATH=src python -m benchmarks.check_regressions [--smoke]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def _reruns():
    from benchmarks import paper_benches as pb
    return {
        "mac_episode": pb.mac_episode,
        "env_episode": pb.env_episode,
        "sharded_episode": pb.sharded_episode,
        "smart_update_scan": pb.smart_update_scan,
    }


def check(record_path: str, smoke: bool) -> str:
    """Rerun one record's bench; returns a human-readable verdict line.

    Raises ``AssertionError`` on a regression past the stored gate.
    """
    with open(record_path) as f:
        record = json.load(f)
    bench = record.get("bench")
    reruns = _reruns()
    if bench not in reruns:
        return (f"{os.path.basename(record_path)}: no rerun registered "
                f"for bench {bench!r} -- SKIPPED")
    metric = record.get("gated_metric")
    if metric is None:
        return (f"{os.path.basename(record_path)}: record carries no "
                f"gated_metric -- SKIPPED (re-seed with a full bench run)")
    gate = record["smoke_gate"] if smoke and "smoke_gate" in record \
        else record["gate"]
    direction = record.get("gate_direction", "max")
    name, us, derived = reruns[bench]()    # internal gates assert here too
    if smoke:
        # every bench's smoke return value IS its gated ratio (no record
        # is written at smoke shapes)
        ratio = derived
    else:
        # a full-shape rerun re-seeds the record file; its gated metric
        # is authoritative (some benches return a different headline
        # number in full mode, e.g. mac_episode's scan-vs-graph speedup)
        with open(record_path) as f:
            ratio = json.load(f)[metric]
    healthy = ratio < gate if direction == "max" else ratio > gate
    verdict = (f"{bench}: {metric} rerun={ratio:.3f} vs stored "
               f"{record.get(metric)} (gate {'<' if direction == 'max' else '>'}"
               f" {gate}{' smoke' if smoke else ''})")
    assert healthy, f"REGRESSION {verdict}"
    return verdict + " OK"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken shapes + smoke gates (CI)")
    ap.add_argument("--only", default="",
                    help="check only records whose filename contains SUBSTR")
    args = ap.parse_args(argv)
    from benchmarks import paper_benches
    paper_benches.SMOKE = args.smoke

    here = os.path.dirname(os.path.abspath(__file__))
    records = sorted(glob.glob(os.path.join(here, "BENCH_*.json")))
    records = [r for r in records if args.only in os.path.basename(r)]
    if not records:
        raise SystemExit(f"no BENCH_*.json records match {args.only!r}")
    failures = []
    for path in records:
        try:
            print(f"== {check(path, args.smoke)}")
        except AssertionError as e:
            failures.append(str(e))
            print(f"== {e}")
        sys.stdout.flush()
    if failures:
        raise SystemExit("\n".join(failures))
    print(f"all {len(records)} recorded benchmarks within their gates")


if __name__ == "__main__":
    main()
