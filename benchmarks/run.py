"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus commented detail lines).
Run:  PYTHONPATH=src python -m benchmarks.run [--only SUBSTR]

Registered benches (see benchmarks.paper_benches.ALL): fig2..fig5, the
smart-update tables, the fused-SINR kernel check, and ``mac_episode``
(scan-compiled TTI engine vs per-TTI graph dispatch).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    from benchmarks import paper_benches

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="run only benchmarks whose name contains SUBSTR")
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken shapes for CI: fast, but regression "
                         "gates (per-RB episode cost) still assert")
    args = ap.parse_args(argv)
    paper_benches.SMOKE = args.smoke
    benches = [b for b in paper_benches.ALL if args.only in b.__name__]
    if not benches:
        ap.error(f"no benchmark name contains {args.only!r}; have: "
                 + ", ".join(b.__name__ for b in paper_benches.ALL))

    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            name, us, derived = bench()
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{bench.__name__},FAILED,-")
            traceback.print_exc()
    # roofline summary from dry-run artifacts, if present
    try:
        import os
        if os.path.isdir("artifacts/dryrun"):
            from repro.analysis import roofline
            print("# --- roofline table (artifacts/dryrun) ---")
            roofline.main("artifacts/dryrun")
    except Exception:
        traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
