"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus commented detail lines).
Run:  PYTHONPATH=src python -m benchmarks.run [--only SUBSTR] [--json]

``--json`` emits ONE machine-readable JSON document on stdout instead of
CSV; everything else (bench detail prints, tracebacks) goes to stderr, so
``python -m benchmarks.run --json | jq .`` just works.  Tracebacks go to
stderr in both modes -- stdout stays parseable.

Registered benches (see benchmarks.paper_benches.ALL): fig2..fig5, the
smart-update tables, the fused-SINR kernel check, and ``mac_episode``
(scan-compiled TTI engine vs per-TTI graph dispatch).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
import traceback


def main(argv=None) -> None:
    from benchmarks import paper_benches

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="run only benchmarks whose name contains SUBSTR")
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken shapes for CI: fast, but regression "
                         "gates (per-RB episode cost) still assert")
    ap.add_argument("--json", action="store_true",
                    help="one JSON document on stdout (detail -> stderr)")
    args = ap.parse_args(argv)
    paper_benches.SMOKE = args.smoke
    benches = [b for b in paper_benches.ALL if args.only in b.__name__]
    if not benches:
        ap.error(f"no benchmark name contains {args.only!r}; have: "
                 + ", ".join(b.__name__ for b in paper_benches.ALL))

    # --json: stdout must be pure JSON, so the benches' own detail prints
    # ("# fig2: ..." lines) are rerouted to stderr for the whole run
    detail = contextlib.redirect_stdout(sys.stderr) if args.json \
        else contextlib.nullcontext()
    results = []
    failures = 0
    if not args.json:
        print("name,us_per_call,derived")
    with detail:
        for bench in benches:
            try:
                name, us, derived = bench()
                results.append({"bench": bench.__name__, "name": name,
                                "us_per_call": round(float(us), 2),
                                "derived": derived, "ok": True})
                if not args.json:
                    print(f"{name},{us:.1f},{derived}")
                    sys.stdout.flush()
            except Exception as e:
                failures += 1
                results.append({"bench": bench.__name__,
                                "name": bench.__name__,
                                "us_per_call": None, "derived": None,
                                "ok": False,
                                "error": f"{type(e).__name__}: {e}"})
                if not args.json:
                    print(f"{bench.__name__},FAILED,-")
                traceback.print_exc(file=sys.stderr)
        # roofline summary from dry-run artifacts, if present
        try:
            import os
            if os.path.isdir("artifacts/dryrun"):
                from repro.analysis import roofline
                print("# --- roofline table (artifacts/dryrun) ---")
                roofline.main("artifacts/dryrun")
        except Exception:
            traceback.print_exc(file=sys.stderr)
    if args.json:
        json.dump({"smoke": bool(args.smoke), "failures": failures,
                   "results": results}, sys.stdout, indent=2,
                  sort_keys=True, default=str)
        sys.stdout.write("\n")
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
