"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus commented detail lines).
Run:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import paper_benches

    print("name,us_per_call,derived")
    failures = 0
    for bench in paper_benches.ALL:
        try:
            name, us, derived = bench()
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{bench.__name__},FAILED,-")
            traceback.print_exc()
    # roofline summary from dry-run artifacts, if present
    try:
        import os
        if os.path.isdir("artifacts/dryrun"):
            from repro.analysis import roofline
            print("# --- roofline table (artifacts/dryrun) ---")
            roofline.main("artifacts/dryrun")
    except Exception:
        traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
