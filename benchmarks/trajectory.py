"""Provenance stamps and the per-PR perf trajectory over BENCH_*.json.

Every seeded benchmark record (``paper_benches._write_record``) carries a
``provenance`` block -- git SHA, UTC timestamp, jax version, backend and
device -- so a number can always be traced to the commit and machine that
produced it.  This module owns that stamp (:func:`provenance`) and renders
the trajectory the ROADMAP asks to publish: for each record's gated
metric, the value at every commit that touched the record, oldest to
newest (``git log`` + ``git show`` -- no checkout needed).

CLI::

    python -m benchmarks.trajectory                 # table to stdout
    python -m benchmarks.trajectory --out artifacts/obs/perf_trajectory.md
    python -m benchmarks.trajectory --stamp         # backfill provenance
                                                    # into unstamped records
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
from datetime import datetime, timezone
from typing import Optional

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(BENCH_DIR)


def _git(*args: str) -> Optional[str]:
    """stdout of ``git <args>`` in the repo root; None when unavailable."""
    try:
        out = subprocess.run(["git", *args], cwd=REPO_ROOT, text=True,
                             capture_output=True, timeout=60)
    except (OSError, subprocess.SubprocessError):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


def provenance() -> dict:
    """The stamp written into every benchmark record at seed time.

    Answers "which code, when, on what" for any committed number: the
    producing commit (plus a dirty flag when the working tree had
    uncommitted changes), a UTC timestamp, and the jax version / backend /
    device kind the measurement ran on.  Degrades gracefully: outside a
    git checkout the SHA reads ``"unknown"``; without jax importable the
    runtime fields do.
    """
    sha = _git("rev-parse", "HEAD") or "unknown"
    dirty = bool(_git("status", "--porcelain")) if sha != "unknown" else False
    stamp = {
        "git_sha": sha,
        "git_dirty": dirty,
        "timestamp_utc": datetime.now(timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
    }
    try:
        import jax
        stamp["jax_version"] = jax.__version__
        stamp["backend"] = jax.default_backend()
        stamp["device_kind"] = jax.devices()[0].device_kind
    except Exception:               # pragma: no cover - jax-less tooling env
        stamp.update(jax_version="unavailable", backend="unavailable",
                     device_kind="unavailable")
    return stamp


def record_paths(bench_dir: str = BENCH_DIR) -> list:
    return sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))


def stamp_records(bench_dir: str = BENCH_DIR, force: bool = False) -> list:
    """Backfill ``provenance`` into records missing it; returns the paths
    touched.  ``force`` restamps even already-stamped records (after a
    manual edit, say) -- the normal path is seed-time stamping in
    ``paper_benches._write_record``."""
    stamped = []
    for path in record_paths(bench_dir):
        with open(path) as f:
            rec = json.load(f)
        if "provenance" in rec and not force:
            continue
        rec["provenance"] = provenance()
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
            f.write("\n")
        stamped.append(path)
    return stamped


def metric_history(path: str, limit: int = 50) -> list:
    """The gated metric's value at every commit touching ``path``.

    ``git log --follow``-free on purpose (records never move), reading
    each historic version with ``git show sha:relpath`` -- no checkout,
    no worktree.  Returns ``[(short_sha, date, value), ...]`` oldest to
    newest; commits whose version predates the gated-metric convention
    (or fails to parse) are skipped.  Empty outside a git checkout.
    """
    rel = os.path.relpath(path, REPO_ROOT)
    log = _git("log", f"--max-count={limit}", "--format=%h %cs", "--", rel)
    if not log:
        return []
    out = []
    for line in reversed(log.splitlines()):
        sha, _, date = line.strip().partition(" ")
        blob = _git("show", f"{sha}:{rel}")
        if blob is None:
            continue
        try:
            rec = json.loads(blob)
            value = rec[rec["gated_metric"]]
        except (ValueError, KeyError, TypeError):
            continue
        out.append((sha, date, float(value)))
    return out


def _fmt(v: float) -> str:
    return f"{v:.3g}"


def render_table(bench_dir: str = BENCH_DIR) -> str:
    """The perf-trajectory markdown: one row per record's gated metric,
    the committed value at each touching commit (oldest -> newest), and
    the live provenance stamp of the current working tree."""
    lines = ["# Perf trajectory", "",
             "Gated benchmark metrics across the commits that re-seeded "
             "each record (oldest -> newest; `*` marks the current "
             "working-tree value when the record is unstamped history).",
             "",
             "| record | metric | healthy | gate | trajectory | current |",
             "|---|---|---|---|---|---|"]
    for path in record_paths(bench_dir):
        with open(path) as f:
            rec = json.load(f)
        name = os.path.basename(path)
        metric = rec.get("gated_metric")
        if metric is None or metric not in rec:
            lines.append(f"| {name} | (no gated metric) | - | - | - | - |")
            continue
        cur = float(rec[metric])
        direction = rec.get("gate_direction", "max")
        healthy = "<=" if direction == "max" else ">="
        hist = metric_history(path)
        if not hist or abs(hist[-1][2] - cur) > 1e-12:
            # freshly seeded (no committed history yet) or re-seeded since
            # the last commit: the worktree value is part of the trajectory
            hist.append(("worktree", "*", cur))
        traj = " -> ".join(f"{_fmt(v)} ({d})" for _, d, v in hist)
        lines.append(f"| {name} | `{metric}` | {healthy} | "
                     f"{_fmt(float(rec.get('gate', float('nan'))))} | "
                     f"{traj} | **{_fmt(cur)}** |")
    p = provenance()
    lines += ["",
              f"_Rendered at {p['timestamp_utc']} on "
              f"{p['backend']}/{p['device_kind']} (jax {p['jax_version']}), "
              f"commit `{p['git_sha'][:12]}`"
              + (" (dirty)" if p["git_dirty"] else "") + "._"]
    return "\n".join(lines)


def main(argv: Optional[list] = None) -> str:
    ap = argparse.ArgumentParser(
        description="render the per-PR perf trajectory over BENCH_*.json")
    ap.add_argument("--dir", default=BENCH_DIR,
                    help="directory holding the BENCH_*.json records")
    ap.add_argument("--out", default=None,
                    help="write the markdown here instead of stdout")
    ap.add_argument("--stamp", action="store_true",
                    help="backfill provenance into unstamped records")
    args = ap.parse_args(argv)
    if args.stamp:
        for path in stamp_records(args.dir):
            print(f"# stamped {path}")
    table = render_table(args.dir)
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                    exist_ok=True)
        with open(args.out, "w") as f:
            f.write(table + "\n")
        print(f"# wrote {args.out}")
    else:
        print(table)
    return table


if __name__ == "__main__":
    main()
