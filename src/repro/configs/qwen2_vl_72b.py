"""qwen2-vl-72b [vlm]: M-RoPE backbone, stub vision frontend.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064
[arXiv:2409.12191; hf].  input_specs() provides precomputed patch
embeddings + (t, h, w) position ids; mrope_sections=(16, 24, 24)
(sums to head_dim/2 = 64).
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab_size=152064,
        mrope_sections=(16, 24, 24), embed_inputs=False, qkv_bias=True,
    )
