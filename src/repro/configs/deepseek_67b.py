"""deepseek-67b [dense]: llama arch, deep GQA.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400
[arXiv:2401.02954; hf].
"""
from repro.models.config import ModelConfig

ARCH_ID = "deepseek-67b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=102400,
    )
