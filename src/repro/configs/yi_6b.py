"""yi-6b [dense]: llama arch GQA kv=4.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 [arXiv:2403.04652; hf].
"""
from repro.models.config import ModelConfig

ARCH_ID = "yi-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000,
    )
