"""qwen1.5-0.5b [dense]: QKV bias, large vocab.

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936
[hf:Qwen/Qwen1.5-0.5B; hf].
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen1.5-0.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab_size=151936, qkv_bias=True,
    )
