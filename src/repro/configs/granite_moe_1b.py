"""granite-moe-1b-a400m [moe]: 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""
from repro.models.config import ModelConfig

ARCH_ID = "granite-moe-1b-a400m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab_size=49155,
        n_experts=32, n_experts_per_token=8,
    )
