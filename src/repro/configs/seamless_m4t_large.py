"""seamless-m4t-large-v2 [audio]: enc-dec backbone, stub speech frontend.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206
[arXiv:2308.11596; hf].  24 encoder + 24 decoder layers; input_specs()
provides precomputed frame embeddings for the encoder.
"""
from repro.models.config import ModelConfig

ARCH_ID = "seamless-m4t-large-v2"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=8192, vocab_size=256206,
    )
