"""Assigned-architecture configs (exact shapes from public literature).

``get_config(arch_id)`` resolves by the public arch id (with dashes);
``--arch`` flags across launch/ use these ids.
"""
from __future__ import annotations

import importlib

_MODULES = [
    "zamba2_1p2b", "deepseek_moe_16b", "granite_moe_1b", "codeqwen1p5_7b",
    "deepseek_67b", "yi_6b", "qwen1p5_0p5b", "qwen2_vl_72b",
    "falcon_mamba_7b", "seamless_m4t_large", "crrm_ppp",
]

ARCH_IDS = []
_BY_ID = {}
for _m in _MODULES:
    _mod = importlib.import_module(f"repro.configs.{_m}")
    if hasattr(_mod, "ARCH_ID"):
        ARCH_IDS.append(_mod.ARCH_ID)
        _BY_ID[_mod.ARCH_ID] = _mod

LM_ARCH_IDS = [a for a in ARCH_IDS if a != "crrm-ppp"]


def get_config(arch_id: str, reduced: bool = False):
    mod = _BY_ID[arch_id]
    cfg = mod.config()
    return cfg.reduced() if reduced else cfg
