"""crrm-ppp: the paper\'s own workload as a dry-run architecture.

A large PPP network (the example-12 validation scaled up) run through the
distributed CRRM engine: materialized (paper-faithful) and streaming
(TPU-native) variants.  Not an LM arch; sized so the materialized form
stresses HBM while the streaming form stays O(N+M).
"""
ARCH_ID = "crrm-ppp"

# (n_ues, n_cells, n_subbands) per "shape"
SHAPES = {
    "net_256k": dict(n_ues=262_144, n_cells=4096, n_subbands=2,
                     variant="materialized"),
    "net_4m": dict(n_ues=4_194_304, n_cells=65_536, n_subbands=2,
                   variant="streaming"),
    "net_4m_inc": dict(n_ues=4_194_304, n_cells=65_536, n_subbands=2,
                       variant="incremental", max_moves=4096),
}


def config():
    return None  # not an LM; handled specially by launch.dryrun
