"""zamba2-1.2b [hybrid]: Mamba2 + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  Shared attention block invoked every 6 mamba2
blocks on concat([x, x_embed]) (Zamba-style weight sharing).
"""
from repro.models.config import ModelConfig

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_variant="mamba2", ssm_head_dim=64,
        hybrid_attn_every=6,
        # d_state=64 makes the per-chunk state expansion (b, Q, H, P, n)
        # 64x the activation size; Q=64 keeps the transient ~1 GiB/device
        ssm_chunk=64,
        # SSD matmul dual form: the intra-chunk work becomes two (Q x Q)
        # matmuls per head on the MXU instead of an elementwise
        # (b,Q,H,P,n) associative scan (validated bit-close in tests)
        ssm_impl="ssd",
    )
