"""deepseek-moe-16b [moe]: fine-grained experts, 2 shared + 64 routed top-6.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400 [arXiv:2401.06066; hf].
"""
from repro.models.config import ModelConfig

ARCH_ID = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        n_experts=64, n_experts_per_token=6, n_shared_experts=2,
    )
