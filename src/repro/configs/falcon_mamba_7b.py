"""falcon-mamba-7b [ssm]: attention-free mamba1 stack.

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16 [arXiv:2410.05355].
"""
from repro.models.config import ModelConfig

ARCH_ID = "falcon-mamba-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=65024,
        ssm_state=16, ssm_variant="mamba1",
    )
