"""codeqwen1.5-7b [dense]: qwen1.5 arch (QKV bias).

32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf].
"""
from repro.models.config import ModelConfig

ARCH_ID = "codeqwen1.5-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab_size=92416, qkv_bias=True,
    )
