"""Production mesh definition (a FUNCTION, so importing this module never
touches jax device state -- the dry-run sets device-count flags first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_named_mesh(name: str):
    """Mesh presets: 'pod' (16x16), 'multipod' (2x16x16), plus tiny local
    variants for CPU-device testing of the same code paths."""
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "tiny":
        return jax.make_mesh((2, 4), ("data", "model"))
    if name == "tinypod":
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    raise ValueError(f"unknown mesh {name!r}")
