import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Optional local-testing override -- must still precede any jax import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces artifacts/dryrun/<mesh>/<arch>/<shape>.json with:
  * memory_analysis (bytes/device -- proves the cell fits),
  * cost_analysis FLOPs/bytes (per device and global),
  * collective wire bytes parsed from the partitioned HLO,
  * MODEL_FLOPS (6*N_active*D train / 2*N_active*D decode) for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --arch crrm-ppp  # paper engine
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_stats
from repro.configs import LM_ARCH_IDS, get_config
from repro.launch.mesh import make_named_mesh
from repro.models.registry import SHAPES, input_specs, make_arch, \
    shape_applicable
from repro.parallel import sharding as shd
from repro.parallel.mesh import axis_size, batch_axes
from repro.train import optim
from repro.train.step import jit_train_step, state_specs


def _param_counts(cfg) -> dict:
    """Total/active/non-embedding parameter counts from eval_shape."""
    arch = make_arch(cfg)
    shapes = jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0)))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = emb = routed = 0
    for path, leaf in flat:
        names = [str(getattr(p, "key", p)) for p in path]
        n = int(np.prod(leaf.shape))
        total += n
        if names[-1] in ("embedding", "kernel"):
            emb += n
        if ("moe" in names and names[-1] in ("wi_gate", "wi_up", "wo")
                and len(leaf.shape) >= 3):
            routed += n
    n_body = total - emb
    if cfg.n_experts:
        active = (n_body - routed
                  + routed * cfg.n_experts_per_token / cfg.n_experts)
    else:
        active = n_body
    return {"total": total, "non_embedding": n_body, "active": active}


def _model_flops(cfg, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    tokens = sh["global_batch"] * (1 if sh["kind"] == "decode"
                                   else sh["seq_len"])
    n_active = _param_counts(cfg)["active"]
    if sh["kind"] == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens   # fwd-only (prefill / decode)


def _lower_cell(cfg, shape_name, mesh):
    """Build + lower the right step function for this cell."""
    from repro.parallel import act_sharding
    from repro.parallel.mesh import set_strategy
    kind = SHAPES[shape_name]["kind"]
    # train cells for <=8B non-MoE archs: ZeRO-3 full data parallelism
    # (batch 256 covers the whole mesh; per-layer bf16 weight gathers beat
    # TP's activation reshards at 1M-token batches: yi-6b 507->140 GB/dev).
    # MoE and the 67-72B giants keep the 2-D layout: under pure dp GSPMD
    # replicated the expert einsums / head matmuls (measured 138x per-dev
    # FLOPs, 310 GiB/dev) -- hypothesis refuted there, see §Perf.
    n_total = _param_counts(cfg)["total"] if cfg else 0
    # hybrid excluded too: the shared-block/x0 pattern replicates under dp
    # (200 GiB/dev measured) -- 2d keeps it at 13.5 GiB.  dp also requires
    # the global batch to cover every device (on the 512-chip multipod
    # mesh batch 256 < 512 -> 2-D layout there).
    use_dp = (kind == "train" and cfg.family not in ("moe", "hybrid")
              and n_total <= 8e9
              and SHAPES[shape_name]["global_batch"] % mesh.devices.size == 0)
    set_strategy("dp" if use_dp else "2d")
    act_sharding.set_mesh_shardings(mesh)
    arch = make_arch(cfg)
    batch_shapes, cache_shapes = input_specs(cfg, shape_name)

    if kind == "train":
        opt = optim.adafactor(optim.constant_lr(1e-4))
        # microbatch accumulation for the widest models: shrinks the live
        # activation set per pass (production memory lever, recorded here)
        accum = 4 if cfg.d_ff >= 24000 else (
            2 if (cfg.d_model >= 8192 or cfg.family in ("hybrid", "moe"))
            else 1)
        fn, shapes, state_sh, batch_sh = jit_train_step(
            arch, opt, mesh, batch_shapes, accum_steps=accum)
        state_shapes = {"params": shapes["params"], "opt": shapes["opt"],
                        "step": jax.ShapeDtypeStruct((), jnp.int32)}
        return fn.lower(state_shapes, batch_shapes)

    # serving runs in bf16 params (production dtype): halves the FSDP
    # weight-gather wire and the parameter footprint
    import dataclasses as _dc
    cfg = _dc.replace(cfg, param_dtype="bfloat16")
    arch = make_arch(cfg)
    batch_shapes, cache_shapes = input_specs(cfg, shape_name)
    params_shape = jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0)))
    param_sh = shd.named(mesh, shd.infer_param_specs(params_shape, mesh))
    batch_sh = shd.named(mesh, shd.batch_specs(cfg, batch_shapes, mesh))

    if kind == "prefill":
        S = SHAPES[shape_name]["seq_len"]

        def prefill_fn(params, batch):
            return arch.prefill(params, batch, S)

        cache_shape = jax.eval_shape(prefill_fn, params_shape,
                                     batch_shapes)[1]
        cache_sh = shd.named(mesh, shd.cache_specs(cfg, cache_shape, mesh))
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh),
                     out_shardings=(None, cache_sh))
        return fn.lower(params_shape, batch_shapes)

    # decode: one token against a full cache
    cache_sh = shd.named(mesh, shd.cache_specs(cfg, cache_shapes, mesh))

    def decode_fn(params, batch, caches, pos):
        return arch.decode_step(params, batch, caches, pos)

    fn = jax.jit(decode_fn,
                 in_shardings=(param_sh, batch_sh, cache_sh, None),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(2,))
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    return fn.lower(params_shape, batch_shapes, cache_shapes, pos_spec)


def _analyse(lowered, mesh, model_flops: float) -> dict:
    n_dev = mesh.devices.size
    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = int(getattr(ma, k, 0))
            mem["total_bytes_per_device"] = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)

    text = compiled.as_text()
    col = collective_stats(text, default_group=axis_size(mesh, ("model",)))

    return {
        "n_devices": int(n_dev),
        "compile_seconds": compile_s,
        "hlo_flops_per_device": flops_dev,
        "hlo_flops": flops_dev * n_dev,
        "hlo_bytes_per_device": bytes_dev,
        "hlo_bytes": bytes_dev * n_dev,
        "collective_wire_bytes": col.total_wire_bytes,
        "collective_counts": col.counts,
        "collective_bytes_by_kind": col.bytes_by_kind,
        "memory_analysis": mem,
        "model_flops": model_flops,
    }


def run_lm_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
                out_dir: str, force: bool = False) -> dict:
    os.makedirs(f"{out_dir}/{mesh_name}/{arch_id}", exist_ok=True)
    path = f"{out_dir}/{mesh_name}/{arch_id}/{shape_name}.json"
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch_id)
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        art = {"skipped": True, "reason": reason, "arch": arch_id,
               "shape": shape_name, "mesh": mesh_name}
    else:
        try:
            from repro.analysis.flops import step_bytes, step_flops
            lowered = _lower_cell(cfg, shape_name, mesh)
            art = _analyse(lowered, mesh, _model_flops(cfg, shape_name))
            counts = _param_counts(cfg)
            fl = step_flops(cfg, shape_name)
            by = step_bytes(cfg, shape_name, counts["total"])
            art.update({"arch": arch_id, "shape": shape_name,
                        "mesh": mesh_name, "param_counts": counts,
                        "analytic_flops": fl["total"],
                        "analytic_flops_fwd": fl["fwd"],
                        "analytic_bytes": by["total"],
                        "analytic_bytes_breakdown": by})
        except Exception as e:
            art = {"failed": True, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:],
                   "arch": arch_id, "shape": shape_name, "mesh": mesh_name}
    with open(path, "w") as f:
        json.dump(art, f, indent=1, default=float)
    return art


# ---------------------------------------------------------------------------
# the paper's own engine as a dry-run workload
# ---------------------------------------------------------------------------
def run_crrm_cell(shape_name: str, mesh, mesh_name: str, out_dir: str,
                  force: bool = False) -> dict:
    from repro.configs.crrm_ppp import SHAPES as CRRM_SHAPES
    from repro.core import distributed as dcrrm
    from repro.sim.pathloss import make_pathloss
    from jax.sharding import PartitionSpec as P

    os.makedirs(f"{out_dir}/{mesh_name}/crrm-ppp", exist_ok=True)
    path = f"{out_dir}/{mesh_name}/crrm-ppp/{shape_name}.json"
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    sh = CRRM_SHAPES[shape_name]
    N, M, K = sh["n_ues"], sh["n_cells"], sh["n_subbands"]
    ba = batch_axes(mesh)
    pl_model = make_pathloss("power_law", alpha=3.5)
    common = dict(mesh=mesh, pathgain_fn=pl_model.get_pathgain,
                  noise_w=1e-15, n_cells=M, subband_bw=1e7 / K,
                  fairness_p=0.0, ue_axis=ba, cell_axis=("model",))
    f = jax.ShapeDtypeStruct
    U = f((N, 3), jnp.float32)
    C = f((M, 3), jnp.float32)
    Pw = f((M, K), jnp.float32)
    try:
        if sh["variant"] == "materialized":
            fn = dcrrm.make_materialized_step(**common)
            lowered = jax.jit(fn).lower(U, C, Pw)
        elif sh["variant"] == "streaming":
            fn = dcrrm.make_streaming_step(**common)
            lowered = jax.jit(fn).lower(U, C, Pw)
        else:
            fn = dcrrm.make_incremental_rows_step(**common)
            m = sh["max_moves"]
            lowered = jax.jit(fn).lower(
                U, C, Pw, f((N, K), jnp.float32), f((N, K), jnp.float32),
                f((N,), jnp.int32), f((N,), jnp.float32),
                f((m,), jnp.int32), f((m, 3), jnp.float32))
        # analytic model: ~60 executed flops per (ue, cell) pair (distance
        # 10, power-law pathgain ~15, RSRP/argmax/accum ~35), K subbands
        # fold into the accumulation; bytes: materialized variant writes/
        # reads the (N, M) D/G/R matrices (the paper's layout), streaming
        # touches O(N + M) per cell tile pass.
        rows = sh.get("max_moves", N)
        pair_flops = 60.0
        work = rows * M * pair_flops + rows * K * 30.0
        if sh["variant"] == "materialized":
            byts = rows * M * 4.0 * (3 + 2 + 2 * K) + rows * K * 4.0 * 8
        else:
            tiles = max(1, M // 512)
            byts = (rows * 3 * 4.0 * tiles      # U re-read per cell tile
                    + M * (3 + K) * 4.0         # C, P once
                    + rows * K * 4.0 * 10)      # O(N) state rw
        art = _analyse(lowered, mesh, work)
        art.update({"arch": "crrm-ppp", "shape": shape_name,
                    "mesh": mesh_name, "variant": sh["variant"],
                    "analytic_flops": work, "analytic_bytes": byts})
    except Exception as e:
        art = {"failed": True, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:],
               "arch": "crrm-ppp", "shape": shape_name, "mesh": mesh_name}
    with open(path, "w") as f2:
        json.dump(art, f2, indent=1, default=float)
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "tiny", "tinypod"])
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on --mesh (or both prod "
                         "meshes with --both-meshes)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.both_meshes else [args.mesh]
    for mesh_name in meshes:
        mesh = make_named_mesh(mesh_name)
        archs = ([args.arch] if args.arch else
                 (LM_ARCH_IDS + ["crrm-ppp"] if args.all else []))
        for arch_id in archs:
            if arch_id == "crrm-ppp":
                from repro.configs.crrm_ppp import SHAPES as CRRM_SHAPES
                shapes = ([args.shape] if args.shape
                          else list(CRRM_SHAPES))
                for s in shapes:
                    t0 = time.perf_counter()
                    art = run_crrm_cell(s, mesh, mesh_name, args.out,
                                        args.force)
                    _report(arch_id, s, mesh_name, art, t0)
            else:
                shapes = [args.shape] if args.shape else list(SHAPES)
                for s in shapes:
                    t0 = time.perf_counter()
                    art = run_lm_cell(arch_id, s, mesh, mesh_name,
                                      args.out, args.force)
                    _report(arch_id, s, mesh_name, art, t0)


def _report(arch_id, shape, mesh_name, art, t0):
    dt = time.perf_counter() - t0
    if art.get("skipped"):
        print(f"[dryrun] {mesh_name}/{arch_id}/{shape}: SKIP "
              f"({art['reason'][:60]})", flush=True)
    elif art.get("failed"):
        print(f"[dryrun] {mesh_name}/{arch_id}/{shape}: FAIL "
              f"{art['error'][:120]}", flush=True)
    else:
        mem = art["memory_analysis"].get("total_bytes_per_device")
        mem_s = f"{mem/2**30:.2f} GiB/dev" if mem else "?"
        print(f"[dryrun] {mesh_name}/{arch_id}/{shape}: OK "
              f"flops/dev={art['hlo_flops_per_device']:.3e} "
              f"wire={art['collective_wire_bytes']/1e9:.3f}GB {mem_s} "
              f"compile={art['compile_seconds']:.1f}s wall={dt:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
