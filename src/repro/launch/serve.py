"""Serving launcher: batched generation with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_config
    from repro.models.registry import make_arch
    from repro.parallel.mesh import make_host_mesh
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch, reduced=args.reduced)
    arch = make_arch(cfg)
    eng = ServeEngine(arch, make_host_mesh(1, 1),
                      batch_slots=args.batch_slots, max_len=args.max_len,
                      temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 24))
        reqs.append(eng.submit(prompt, max_new_tokens=args.max_new))
    out = eng.run()
    print(f"# served {len(out['results'])} requests, "
          f"{out['n_tokens']} tokens at {out['tokens_per_s']:.1f} tok/s")
    for rid, toks in sorted(out["results"].items())[:4]:
        print(f"request {rid}: {toks}")


if __name__ == "__main__":
    main()
