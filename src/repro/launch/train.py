"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 300 --ckpt-dir ckpts/q05

On the CPU container use --reduced (a ~small-M-param same-family config);
on real hardware drop it and pick --mesh.  Fault tolerance: --resume picks
up the latest atomic checkpoint; SIGTERM triggers a final save.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgdm"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--data", default=None,
                    help="path to an int32 .bin token file (memmap); "
                         "synthetic stream if omitted")
    ap.add_argument("--mesh", default="host",
                    help="host | host:<data>x<model>")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models.registry import make_arch
    from repro.parallel.mesh import make_host_mesh
    from repro.train import optim
    from repro.train.data import MemmapLM, SyntheticLM
    from repro.train.loop import train

    cfg = get_config(args.arch, reduced=args.reduced)
    arch = make_arch(cfg)
    if args.mesh.startswith("host:"):
        d, m = args.mesh.split(":")[1].split("x")
        mesh = make_host_mesh(int(d), int(m))
    else:
        mesh = make_host_mesh(1, 1)

    lr = optim.warmup_cosine(args.lr, max(args.steps // 20, 5), args.steps)
    optimizer = optim.OPTIMIZERS[args.optimizer](lr)
    if args.data:
        data = MemmapLM(args.data, args.batch, args.seq_len)
    else:
        data = SyntheticLM(cfg.vocab_size, args.batch, args.seq_len,
                           seed=args.seed)

    from repro.models.transformer import param_count
    n = param_count(jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0))))
    print(f"# arch={cfg.name} params={n/1e6:.1f}M mesh={mesh.shape} "
          f"optimizer={args.optimizer}")
    train(arch, optimizer, mesh, data, steps=args.steps,
          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          accum_steps=args.accum, seed=args.seed,
          resume=not args.no_resume)


if __name__ == "__main__":
    main()
