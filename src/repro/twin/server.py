"""The digital-twin simulation server: continuous runs, served in chunks.

A network digital twin is not an episode: it runs for as long as the live
network it mirrors, absorbs measurement-driven control updates while
running, and must survive process death without losing (or worse,
perturbing) its trajectory.  :class:`TwinServer` provides exactly that over
the existing pure episode engine (DESIGN.md §Digital-twin-serving):

* **Chunked stepping** -- one jit-compiled ``rollout`` of ``chunk_tti``
  TTIs per call, with the carried :class:`~repro.mac.engine.EpisodeState`
  buffer *donated* back to the next chunk: steady-state serving allocates
  no new state per chunk.  Because every per-TTI PRNG stream folds on the
  *absolute* TTI counter (``radio.tti_keys`` / ``radio.churn_keys``), the
  trajectory is chunk-partition-invariant: N chunks of M TTIs reproduce
  one N*M-TTI run bitwise.
* **Birth-death churn** -- the engine's capacity-padded active-mask regime
  (``sim.mobility.ChurnConfig``): UEs arrive and depart inside the
  compiled scan, no retracing.
* **Live control** -- the per-cell power matrix and the scheduler fairness
  exponent are *always* passed as traced arguments of the chunk program,
  so :meth:`set_power` / :meth:`set_fairness` take effect at the next
  chunk boundary with **zero recompilation** (asserted with
  ``obs.profile.CompileCounter`` in tests/test_twin.py).
* **Checkpoint/restore** -- ``train.checkpoint`` (atomic, keep-k,
  optionally async) snapshots the full serving tuple: episode state +
  PRNG stream + TTI counter + the live controls.  A server killed
  mid-run and restored continues *bitwise* on the uninterrupted
  trajectory -- the resume-equivalence contract (tested in
  tests/test_twin.py, smoke-checked in CI via ``python -m
  repro.twin.server --smoke``).
* **Fault injection** -- pass ``faults=sim.faults.FaultConfig(...)`` (or
  bake it into the scenario preset, e.g. ``outage_storm``) and cells walk
  a Markov outage/sleep chain *inside* the compiled chunk; the twin's
  KPI summaries then carry ``mean_cells_down`` / ``reattach_events``.
* **Self-healing** -- arm ``watchdog=WatchdogConfig(...)`` (or ``True``)
  and :meth:`step_chunk` becomes a guarded loop
  (DESIGN.md §Fault-injection-and-self-healing): each chunk runs under an
  optional wall-clock timeout, the resulting carry is validated by the
  fused ``robust.guard.carry_ok`` check, and success auto-checkpoints on
  a cadence.  On NaN, exception or timeout the server recovers: if the
  failure is a genuine chunk exception (not a guard/timeout verdict)
  and a fused incremental backend is armed, it first degrades
  ``pallas -> xla``, rebuilding the chunk program (the capability probe
  passed but the kernel failed at runtime); it then rolls back to the
  newest checkpoint that still validates (``restore_latest_valid`` -- a
  corrupted latest step falls through to the previous good one), sleeps
  an exponential backoff and retries; ``max_retries`` consecutive failures stop the server
  gracefully with a :class:`~repro.robust.watchdog.TwinServerDown`
  carrying the full failure history.  Because every per-TTI PRNG stream
  folds on the absolute TTI counter, a recovered twin resumes *bitwise*
  on the uninterrupted trajectory (tests/test_faults.py; chaos drill:
  ``python -m repro.robust.chaos --smoke``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.mac import engine as mac_engine
from repro.obs import telemetry as obs_telemetry
from repro.robust import guard as robust_guard
from repro.robust.watchdog import (GuardViolation, TwinFault,
                                   TwinServerDown, WatchdogConfig,
                                   run_with_timeout)
from repro.sim.mobility import ChurnConfig
from repro.train import checkpoint as ckpt


class TwinServer:
    """A continuously-running simulation twin, stepped in compiled chunks.

    ``sim`` is a built ``CRRM``; ``churn`` the birth-death process config
    (its ``max_arrivals_per_tti`` is also the per-TTI birth dirty-row
    budget).  ``chunk_tti`` sets the serving granularity: KPI summaries
    stream once per chunk, and control updates land at chunk boundaries.
    ``ckpt_dir`` enables :meth:`checkpoint` / :meth:`restore`.

    ``faults`` arms the in-scan cell fault process (defaults to the
    scenario's ``params.faults``; pass ``0`` to force it off).
    ``inc_backend`` routes the incremental radio mode's dirty-row
    recompute exactly as in ``episode_fns``; under a watchdog it is also
    the degradation ladder's starting rung.  ``watchdog`` (a
    :class:`~repro.robust.watchdog.WatchdogConfig`, or ``True`` for the
    defaults) turns :meth:`step_chunk` into the guarded self-healing loop
    -- it requires ``ckpt_dir`` (rollback needs somewhere to roll back
    to) and writes an initial checkpoint at t=0.
    """

    def __init__(self, sim, churn: ChurnConfig, *, chunk_tti: int = 100,
                 ckpt_dir=None, keep_last: int = 3,
                 per_tti_fading: bool = False, radio_mode=None, key=None,
                 faults=None, inc_backend=None, watchdog=None):
        self.sim, self.churn, self.chunk_tti = sim, churn, int(chunk_tti)
        self.ckpt_dir, self.keep_last = ckpt_dir, keep_last
        if faults is None:
            faults = getattr(sim.params, "faults", None)
        self.faults = faults or None
        self._fns_kw = dict(per_tti_fading=per_tti_fading,
                            radio_mode=radio_mode, telemetry=True,
                            churn=churn, faults=faults)
        self.inc_backend = inc_backend
        self._build(inc_backend)
        self.static = sim.episode_static()
        state = sim.init_episode_state(key)
        state = mac_engine.seed_churn_state(
            state, self.static, sim.params, per_tti_fading=per_tti_fading)
        if self.faults is not None:
            # seed the fault leaf eagerly so every checkpoint of this
            # server shares one tree structure (restore reads structure)
            state = mac_engine.seed_fault_state(state, sim.params.n_cells)
        self.state = state
        # live controls, always traced chunk inputs: updating them swaps
        # an array, never the compiled program
        self.power = jnp.asarray(self.static.P)
        self.fairness = jnp.float32(sim.params.fairness_p)

        if watchdog is True:
            watchdog = WatchdogConfig()
        self.watchdog = watchdog
        self.fault_history: list = []
        self._chunks_since_ckpt = 0
        # bumped by every rollback/restore: a timed-out chunk abandoned
        # on its worker thread must never commit a result computed from
        # pre-rollback state
        self._gen = 0
        if watchdog is not None:
            if ckpt_dir is None:
                raise ValueError("watchdog requires ckpt_dir: rollback "
                                 "needs a checkpoint to roll back to")
            self.checkpoint()            # the t=0 rollback target

    def _build(self, inc_backend) -> None:
        """(Re)build the episode fns + chunk program for ``inc_backend``.

        Called at construction and again by the watchdog's degradation
        ladder (``pallas -> xla``): the serving state is untouched, only
        the compiled program changes, so a degraded twin continues the
        same trajectory (dense == incremental == fused is an engine
        equivalence contract).
        """
        self.inc_backend = inc_backend
        self.fns = self.sim.episode_fns(inc_backend=inc_backend,
                                        **self._fns_kw)
        rollout, n = self.fns.rollout, self.chunk_tti

        def _chunk(static, state, power, fairness):
            return rollout(static, state, n, power, fairness)

        # donate the carried state: steady-state serving reuses the same
        # device buffers chunk after chunk
        self._chunk = jax.jit(_chunk, donate_argnums=(1,))

    # ------------------------------------------------------------- stepping
    @property
    def t(self) -> int:
        """The absolute TTI counter (drives every per-TTI PRNG fold)."""
        return int(self.state.t)

    def step_chunk(self):
        """Advance ``chunk_tti`` TTIs; return the chunk's KPI summary dict.

        The summary is ``obs.telemetry.summarize`` over the chunk's
        per-TTI telemetry stack plus the serving counters (``t``,
        ``active_ues``).  The returned dict is plain host data -- what a
        dashboard or calibration loop consumes.

        With a ``watchdog`` armed this is the guarded loop: timeout-
        wrapped chunk, fused carry validation, auto-checkpoint cadence,
        and on failure the degrade/rollback/backoff/retry ladder
        (module docstring) -- raising
        :class:`~repro.robust.watchdog.TwinServerDown` only after
        ``max_retries`` consecutive recoveries also failed.
        """
        if self.watchdog is None:
            return self._step_chunk_raw()
        return self._step_chunk_guarded()

    def _step_chunk_raw(self):
        gen = self._gen
        state, tput, telem = self._chunk(
            self.static, self.state, self.power, self.fairness)
        if gen != self._gen:
            # a rollback superseded this attempt while it ran (it timed
            # out and was abandoned): its result must not clobber the
            # restored state the retry is serving from
            raise RuntimeError("stale chunk result discarded "
                               "(superseded by a rollback)")
        self.state = state
        kpis = obs_telemetry.summarize(telem, tti_s=self.sim.params.tti_s)
        kpis["t"] = float(self.state.t)
        kpis["active_ues"] = float(self.state.active.sum())
        self.last_tput, self.last_telem = tput, telem
        return kpis

    def _step_chunk_guarded(self):
        wd = self.watchdog
        delay = wd.backoff_s
        for attempt in range(wd.max_retries + 1):
            try:
                kpis = run_with_timeout(self._step_chunk_raw,
                                        wd.chunk_timeout_s)
                if not bool(robust_guard.carry_ok(self.state)):
                    raise GuardViolation(
                        "carry invariants violated after chunk: "
                        + "; ".join(robust_guard.carry_violations(self.state)
                                    or ["(guard tripped, no host detail)"]))
            except Exception as e:  # noqa: BLE001 -- the watchdog's job
                self.fault_history.append(
                    f"attempt {attempt}: {type(e).__name__}: {e}")
                if (not isinstance(e, TwinFault)
                        and self.inc_backend in ("pallas", "auto")):
                    # degradation ladder: the fused kernel failed outside
                    # the capability probe -- rebuild on the XLA route
                    # before retrying (same trajectory, slower program)
                    self.fault_history.append(
                        f"degrading inc_backend={self.inc_backend!r} "
                        "-> 'xla'")
                    self._build("xla")
                step = self._rollback()
                self.fault_history.append(f"rolled back to t={step}")
                if attempt < wd.max_retries:
                    time.sleep(delay)
                    delay *= wd.backoff_factor
            else:
                self._chunks_since_ckpt += 1
                if self._chunks_since_ckpt >= wd.ckpt_every_chunks:
                    self.checkpoint()
                    self._chunks_since_ckpt = 0
                return kpis
        raise TwinServerDown(
            f"{wd.max_retries + 1} consecutive chunk attempts failed at "
            f"t={self.t}; stopping gracefully", history=self.fault_history)

    def _rollback(self) -> int:
        """Restore the newest *valid* checkpoint (skipping corrupt steps).

        Only the current tree's structure is read, never its leaf values,
        so rolling back over buffers invalidated by a failed donated
        chunk is safe -- restore rebuilds fresh device arrays from the
        host snapshot.
        """
        tree, _, step = ckpt.restore_latest_valid(
            self.ckpt_dir, self._tree())
        self._gen += 1
        self.state, self.power = tree["state"], tree["power"]
        self.fairness = tree["fairness"]
        self._chunks_since_ckpt = 0
        return step

    def serve(self, n_chunks: int):
        """Generator: stream ``n_chunks`` KPI summaries, one per chunk."""
        for _ in range(n_chunks):
            yield self.step_chunk()

    # ------------------------------------------------------- live controls
    def set_power(self, P) -> None:
        """Swap the per-cell/(subband) tx power grid; next chunk uses it.

        Accepts the engine's resolved (n_cells, n_freq) grid.  A pure
        array swap: the chunk program traced ``power`` as an argument, so
        no recompilation happens.
        """
        self.power = jnp.asarray(P, jnp.float32)

    def set_fairness(self, p) -> None:
        """Swap the PF fairness exponent ``p``; next chunk uses it."""
        self.fairness = jnp.float32(p)

    # -------------------------------------------------- checkpoint/restore
    def _tree(self):
        # the full serving tuple: state (incl. PRNG key + TTI counter +
        # active mask + carried fading) and the live controls
        return {"state": self.state, "power": self.power,
                "fairness": self.fairness}

    def checkpoint(self, block: bool = True):
        """Snapshot the serving state at the current TTI (atomic, keep-k).

        ``block=False`` uses ``train.checkpoint.save_async``: leaves are
        snapshotted to host synchronously (so later donated-buffer reuse
        cannot corrupt the write) and the directory write happens on a
        daemon thread, returned for joining.
        """
        if self.ckpt_dir is None:
            raise ValueError("TwinServer built without ckpt_dir")
        step = self.t
        extra = {"chunk_tti": self.chunk_tti}
        if block:
            ckpt.save(self.ckpt_dir, step, self._tree(),
                      keep_last=self.keep_last, extra=extra)
            return step
        return ckpt.save_async(self.ckpt_dir, step, self._tree(),
                               keep_last=self.keep_last, extra=extra)

    def restore(self, step=None) -> int:
        """Rewind to a checkpointed TTI (default: the newest valid one).

        Restores state *and* controls, so the resumed trajectory is
        bitwise the uninterrupted one -- including any control updates
        that were live at checkpoint time.  Only the current tree's
        *structure* is read (never its leaf values), so restoring over
        donated buffers is safe.  With ``step=None`` a corrupt or
        truncated latest step falls back to the previous valid one
        (``train.checkpoint.restore_latest_valid``); an explicit ``step``
        raises ``CheckpointCorrupt`` if that step fails validation.
        """
        if self.ckpt_dir is None:
            raise ValueError("TwinServer built without ckpt_dir")
        if step is None:
            tree, _, step = ckpt.restore_latest_valid(
                self.ckpt_dir, self._tree())
        else:
            tree, _ = ckpt.restore(self.ckpt_dir, step, self._tree())
        self._gen += 1
        self.state, self.power = tree["state"], tree["power"]
        self.fairness = tree["fairness"]
        self._chunks_since_ckpt = 0
        return step


def _smoke(tmpdir: str, n_ues: int = 96, n_cells: int = 7,
           chunk: int = 25) -> None:
    """CI smoke: arrivals happen, one kill/restore cycle resumes bitwise."""
    import numpy as np

    from repro.core.crrm import CRRM
    from repro.core.params import CRRM_parameters

    sim = CRRM(CRRM_parameters(
        n_ues=n_ues, n_cells=n_cells, n_sectors=1, seed=7,
        pathloss_model_name="UMa", power_W=10.0, traffic_model="poisson",
        scheduler_policy="pf",
        traffic_params=dict(arrival_rate_hz=300.0,
                            packet_size_bits=12_000.0)))
    churn = ChurnConfig(arrival_rate_hz=400.0, mean_lifetime_s=0.15,
                        max_arrivals_per_tti=8)
    srv = TwinServer(sim, churn, chunk_tti=chunk, ckpt_dir=tmpdir)

    k1 = srv.step_chunk()
    srv.set_power(np.asarray(srv.power) * 1.1)   # live control update
    srv.checkpoint()
    k2 = srv.step_chunk()
    tail = np.asarray(srv.last_tput)
    final = jax.tree_util.tree_map(np.asarray, srv.state)

    srv.restore()                                # "kill" + resume
    k2b = srv.step_chunk()
    tail_b = np.asarray(srv.last_tput)
    final_b = jax.tree_util.tree_map(np.asarray, srv.state)

    assert k1["mean_active_ues"] < n_ues, "no departures ever happened"
    assert k1["served_mbits"] > 0.0
    np.testing.assert_array_equal(tail, tail_b)
    for a, b in zip(jax.tree_util.tree_leaves(final),
                    jax.tree_util.tree_leaves(final_b)):
        np.testing.assert_array_equal(a, b)
    assert k2 == k2b, "restored KPI summary diverged"
    print("twin smoke OK: t=%d active=%d served=%.3f Mbit" %
          (int(final.t), int(final.active.sum()), k2["served_mbits"]))


def main(argv=None) -> None:
    """CLI: run a twin server and stream KPI lines (or the CI smoke)."""
    import argparse
    import tempfile

    from repro.obs.telemetry import format_summary

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny scenario, one restore cycle, "
                         "bitwise resume assertion")
    ap.add_argument("--ues", type=int, default=1000)
    ap.add_argument("--cells", type=int, default=19)
    ap.add_argument("--chunk", type=int, default=100)
    ap.add_argument("--chunks", type=int, default=10)
    ap.add_argument("--arrival-hz", type=float, default=2000.0)
    ap.add_argument("--lifetime-s", type=float, default=0.4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        with tempfile.TemporaryDirectory() as td:
            _smoke(td)
        return

    from repro.core.crrm import CRRM
    from repro.core.params import CRRM_parameters

    sim = CRRM(CRRM_parameters(
        n_ues=args.ues, n_cells=args.cells, n_sectors=1, seed=0,
        pathloss_model_name="UMa", power_W=10.0, traffic_model="poisson",
        scheduler_policy="pf",
        traffic_params=dict(arrival_rate_hz=300.0,
                            packet_size_bits=12_000.0)))
    churn = ChurnConfig(
        arrival_rate_hz=args.arrival_hz, mean_lifetime_s=args.lifetime_s,
        max_arrivals_per_tti=max(
            4, int(4 * args.arrival_hz * sim.params.tti_s)))
    srv = TwinServer(sim, churn, chunk_tti=args.chunk,
                     ckpt_dir=args.ckpt_dir)
    for i, kpis in enumerate(srv.serve(args.chunks)):
        print(f"chunk {i} (t={int(kpis.pop('t'))}):")
        print(format_summary(kpis))


if __name__ == "__main__":
    main()
