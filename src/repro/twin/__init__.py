"""Digital-twin serving: a long-running simulation server over the engine.

``repro.twin.server`` hosts :class:`TwinServer` -- chunked stepping of the
scan-compiled TTI engine under a birth-death UE process, with streaming KPI
summaries, live control updates (cell power, scheduler fairness) and
in-flight checkpoint/restore (DESIGN.md §Digital-twin-serving).
"""

__all__ = ["TwinServer"]


def __getattr__(name):
    # lazy: keeps ``python -m repro.twin.server`` free of the runpy
    # double-import warning while preserving ``from repro.twin import
    # TwinServer``
    if name == "TwinServer":
        from repro.twin.server import TwinServer
        return TwinServer
    raise AttributeError(name)
