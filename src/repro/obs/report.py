"""Compiled-step reports: cost analysis + roofline over the TTI engine.

The seed repo shipped ``analysis/hlo.py`` (collective wire bytes from HLO
text) and ``analysis/roofline.py`` (the three-term roofline) pointed at
nothing.  This module points them at the thing that matters: the compiled
episode rollout.  ``jax``'s AOT path gives everything without running a
single TTI:

    lowered  = fns.rollout.lower(static, state, n_tti)
    compiled = lowered.compile()
    compiled.cost_analysis()     # XLA FLOPs + bytes accessed
    compiled.as_text()           # post-SPMD HLO -> collective wire bytes

:func:`episode_report` wraps that for one simulator configuration and
returns the artifact dict :mod:`repro.analysis.roofline` consumes
(``n_devices`` / ``hlo_flops`` / ``hlo_bytes`` / ``collective_wire_bytes``
/ ``model_flops``), plus the raw collective counts.  ``model_flops`` is
the *useful* Figure-1 physics estimate (:func:`model_flops_episode`), so
the roofline's useful/HLO column reads as "how much compiled compute is
radio math vs overhead".

Run as a module to write per-scenario JSON artifacts and the markdown
roofline table CI uploads:

    PYTHONPATH=src python -m repro.obs.report --scenario dense_urban \
        --n-tti 20 --out artifacts/obs
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import jax

from repro.analysis import hlo, roofline


def model_flops_episode(n_ues: int, n_cells: int, n_freq: int,
                        n_tti: int) -> float:
    """Useful-physics FLOPs of ``n_tti`` dense radio TTIs (an estimate).

    Per (UE, cell) link and TTI the Figure-1 chain costs roughly:
    geometry + pathloss + antenna ~ 40 flops, then RSRP/interference/SINR
    ~ 6 per frequency chunk; the MAC adds ~ 10 per (UE, chunk).  The
    point is a stable order-of-magnitude yardstick for the roofline's
    useful/HLO ratio, not an exact count (XLA's own number IS the exact
    executed count; dividing by this shows overhead factors).
    """
    radio = n_ues * n_cells * (40.0 + 6.0 * n_freq)
    mac = 10.0 * n_ues * n_freq
    return float(n_tti) * (radio + mac)


def _cost_dict(compiled) -> dict:
    """Normalise ``compiled.cost_analysis()`` across jax versions."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def compiled_cost(compiled, n_devices: int = 1) -> dict:
    """FLOPs / HBM bytes / collective wire bytes of one executable.

    ``compiled`` is a ``jax.stages.Compiled`` (``fn.lower(...).compile()``).
    Wire bytes come from :func:`repro.analysis.hlo.collective_stats` over
    the post-partitioning HLO text -- including the trip-count correction
    for collectives inside the scan body, which XLA's cost analysis counts
    once.

    Caveat: that same limitation applies to ``hlo_flops``/``hlo_bytes`` --
    XLA counts a while/scan body ONCE, not times its trip count, so an
    episode's numbers are closer to "per-TTI program cost" than "episode
    cost".  Compare artifacts at equal ``n_tti``.
    """
    cost = _cost_dict(compiled)
    stats = hlo.collective_stats(compiled.as_text(),
                                 default_group=max(n_devices, 1))
    return {
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_wire_bytes": stats.total_wire_bytes,
        "collective_counts": dict(stats.counts),
    }


def episode_report(sim, n_tti: int, *, mesh=None, scenario: str = "",
                   telemetry: bool = False, action=None) -> dict:
    """Cost-analyse the compiled episode rollout of one simulator.

    AOT only -- lowers and compiles ``episode_fns().rollout`` for this
    configuration without executing it, then reads XLA's cost analysis
    and the HLO collective traffic.  Returns a roofline-ready artifact
    dict (see :func:`repro.analysis.roofline.from_artifact`); on a
    backend whose cost analysis is unavailable the artifact carries
    ``skipped`` + ``reason`` instead (the roofline table renders those
    as skipped rows).
    """
    fns = sim.episode_fns(mesh=mesh)
    static = sim.episode_static()
    state = sim.init_episode_state()
    n_dev = 1
    if mesh is not None:
        n_dev = int(mesh.devices.size)
    art = {
        "scenario": scenario, "n_ues": sim.n_ues, "n_cells": sim.n_cells,
        "n_tti": int(n_tti), "n_devices": n_dev,
        "model_flops": model_flops_episode(
            sim.n_ues, sim.n_cells, sim.params.n_freq, n_tti),
        "backend": jax.default_backend(),
    }
    try:
        args = (static, state, n_tti) if action is None else \
            (static, state, n_tti, action)
        compiled = fns.rollout.lower(*args).compile()
        art.update(compiled_cost(compiled, n_dev))
    except Exception as e:          # pragma: no cover - backend dependent
        art.update(skipped=True, reason=f"{type(e).__name__}: {e}")
        return art
    if not art["hlo_flops"] and not art["hlo_bytes"]:
        art.update(skipped=True,
                   reason="cost analysis returned no flops/bytes")
    return art


def roofline_table(artifacts: dict) -> str:
    """Markdown roofline table over ``{name: artifact}`` dicts."""
    lines = ["| cell | compute ms | memory ms | collective ms | dominant "
             "| useful/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|"]
    for name in sorted(artifacts):
        art = artifacts[name]
        if art.get("skipped"):
            lines.append(f"| {name} | - | - | - | skipped: "
                         f"{art.get('reason', '')[:40]} | - | - |")
        else:
            lines.append(roofline.format_row(name, art))
    return "\n".join(lines)


def write_report(out_dir: str, artifacts: dict) -> str:
    """Write per-name JSON artifacts + ``roofline.md``; returns the table."""
    os.makedirs(out_dir, exist_ok=True)
    for name, art in artifacts.items():
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(art, f, indent=2, sort_keys=True)
            f.write("\n")
    table = roofline_table(artifacts)
    with open(os.path.join(out_dir, "roofline.md"), "w") as f:
        f.write("# Compiled TTI-step roofline\n\n" + table + "\n")
    return table


def main(argv: Optional[list] = None) -> None:
    from repro.core.crrm import CRRM
    from repro.sim.scenarios import make_scenario, scenario_names

    ap = argparse.ArgumentParser(
        description="cost-analyse the compiled episode step per scenario")
    ap.add_argument("--scenario", action="append", default=None,
                    help="registry preset (repeatable; default: all)")
    ap.add_argument("--n-tti", type=int, default=20)
    ap.add_argument("--n-ues", type=int, default=None,
                    help="override the preset's UE count (CI shrink)")
    ap.add_argument("--out", default="artifacts/obs")
    args = ap.parse_args(argv)
    names = args.scenario or list(scenario_names())
    arts = {}
    for name in names:
        overrides = {} if args.n_ues is None else {"n_ues": args.n_ues}
        sim = CRRM(make_scenario(name, **overrides))
        arts[name] = episode_report(sim, args.n_tti, scenario=name)
    print(write_report(args.out, arts))


if __name__ == "__main__":
    main()
