"""Observability: in-scan KPI telemetry, profiling hooks, compiled reports.

Three orthogonal windows into an otherwise-opaque compiled episode
(DESIGN.md §Observability):

* :mod:`repro.obs.telemetry` -- the :class:`~repro.obs.telemetry.Telemetry`
  pytree accumulated as a ``lax.scan`` *output* inside the TTI engine:
  per-TTI/per-cell served bits, granted RBs, HARQ ACK/NACK/retx/drop
  counters, A3 handover events, buffer occupancy, Jain fairness and (in
  the incremental radio mode) dirty-row counts.  A trace-time switch: off
  (the default) compiles the exact legacy program.
* :mod:`repro.obs.profile` -- ``jax.profiler`` trace/annotation context
  managers, a compile/retrace counter that catches unintended
  recompilation of engine and env executables, and the per-stage
  wall-time breakdown helper the benchmark harness uses.
* :mod:`repro.obs.report` -- AOT cost analysis of the compiled TTI step:
  HLO FLOPs/bytes, collective wire bytes (``analysis/hlo.py``) and the
  roofline table (``analysis/roofline.py``), written as JSON + markdown
  artifacts.
"""
from repro.obs.telemetry import Telemetry, summarize, format_summary  # noqa: F401
from repro.obs.profile import (  # noqa: F401
    CompileCounter, RetraceWatch, StageTimer, annotate, trace)
