"""In-scan KPI telemetry: the :class:`Telemetry` pytree and its reducers.

The scan-compiled TTI engine returns throughput and nothing else; every
other KPI a measurement-driven consumer needs (digital-twin calibration,
RL diagnostics, load dashboards -- PAPERS.md) lives in intermediates that
die inside the compiled program.  This module defines the ONE convention
for getting them out:

* :class:`Telemetry` is a NamedTuple pytree of per-TTI KPIs.  The engine
  computes one per TTI (:func:`tti_telemetry`, called from
  ``mac.engine.tti_step``) and stacks them as a ``lax.scan`` *output* --
  never a carry, so telemetry adds zero carry growth and cannot perturb
  the trajectory.
* The switch is trace-time (``make_episode_fns(..., telemetry=True)``):
  off compiles the exact legacy program (structural no-op); on computes
  KPIs purely from values the step already produced -- the trajectory is
  bit-identical either way (asserted across every registry scenario, under
  ``vmap`` and on a 2-device mesh in tests/test_telemetry.py).
* Under a mesh, every KPI is ``psum``-reduced over the UE axis inside the
  ``shard_map`` body, so a sharded rollout reports the same *global*
  numbers as a single device.

Optional leaves are ``None`` when a regime cannot produce them (same
trace-time-constant-treedef convention as ``radio.RadioState``):
``dirty_rows`` exists only in ``radio_mode="incremental"``;
``active_ues`` only under a birth-death churn process (where the UE axis
is capacity-padded and KPIs must count the *live* population, not the
slot capacity).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.mac import segments


class Telemetry(NamedTuple):
    """Per-TTI KPIs of one engine step (stacked to (n_tti, ...) by scan).

    Cell-indexed tensors are aggregated over the *serving* attachment of
    the TTI; scalar counters are network-wide totals.  Under a mesh every
    field is already psum-reduced -- global numbers on every shard.
    """

    served_bits: Any    # (n_cells,) f32 bits delivered per serving cell
    granted_rb: Any     # (n_cells,) f32 resource blocks granted per cell
    harq_acks: Any      # i32 transport blocks delivered this TTI
    harq_nacks: Any     # i32 failed HARQ attempts this TTI
    harq_retx: Any      # i32 retransmission attempts this TTI
    dropped_bits: Any   # f32 TB bits dropped at harq_max_retx exhaustion
    ho_events: Any      # i32 A3 handovers fired this TTI
    buffer_bits: Any    # f32 total finite backlog after the TTI
    jain: Any           # f32 Jain fairness of per-UE delivered throughput
    dirty_rows: Any     # i32 radio rows recomputed | None (dense modes)
    active_ues: Any = None   # i32 live UEs this TTI | None (no churn)
    cells_down: Any = None   # i32 cells in outage this TTI | None (no faults)
    reattach_events: Any = None  # i32 serving/attachment changes | None


def tti_telemetry(n_cells: int, n_ues: int, a, alloc, bits, tput, backlog,
                  harq_stats, ho_events, n_dirty, ue_axes=None,
                  active_count=None, cells_down=None,
                  reattached=None) -> Telemetry:
    """Assemble one TTI's :class:`Telemetry` from step intermediates.

    Pure: reads the serving attachment ``a``, the allocation matrix, the
    delivered ``bits``/``tput`` and post-drain ``backlog`` the step already
    computed -- no extra PRNG draws, no state, so enabling telemetry cannot
    change the trajectory.  ``ue_axes`` names the shard_map mesh axes the
    UE dimension is sharded over: all reductions then ``psum`` so every
    shard carries the global KPI (None = single device, no collectives).

    ``active_count`` is the live-population size of a birth-death churn
    episode: KPIs normalised per UE (Jain) then count the active
    population instead of the padded slot capacity, and the count itself
    is published as the ``active_ues`` leaf (None = fixed population).

    ``cells_down`` / ``reattached`` are the fault-process KPIs
    (DESIGN.md §Fault-injection-and-self-healing): the outage count is
    computed from the *replicated* cell fault state, so it must NOT psum
    (every shard already holds the global number); the reattachment
    count is a per-UE event count and psums like the other per-UE KPIs.

    Jain's fairness index over the per-UE delivered throughput:
    ``(sum x)^2 / (n * sum x^2)`` -- 1.0 when perfectly equal, ``1/n``
    when one UE takes everything, 0.0 defined for an idle TTI.
    """
    acks, nacks, retx, dropped = harq_stats
    # per-cell scatters as segment reductions: identical unbatched, and
    # they keep their one-flat-scatter lowering under a vmapped env batch
    served = segments.segment_sum(bits.astype(jnp.float32), a, n_cells)
    granted = segments.segment_sum(
        alloc.sum(axis=-1).astype(jnp.float32), a, n_cells)
    occupancy = jnp.where(jnp.isfinite(backlog), backlog, 0.0).sum()
    s = tput.sum()
    ss = (tput * tput).sum()
    if ue_axes is not None:
        psum = lambda x: jax.lax.psum(x, ue_axes)
        served, granted, occupancy, s, ss = map(
            psum, (served, granted, occupancy, s, ss))
        acks, nacks, retx, dropped, ho_events = map(
            psum, (acks, nacks, retx, dropped, ho_events))
        if n_dirty is not None:
            n_dirty = psum(n_dirty)
        if active_count is not None:
            active_count = psum(active_count)
        if reattached is not None:
            reattached = psum(reattached)
        # cells_down intentionally NOT psummed: replicated global value
    denom = n_ues if active_count is None else jnp.maximum(active_count, 1)
    jain = jnp.where(ss > 0.0, s * s / (denom * ss), 0.0)
    return Telemetry(served_bits=served, granted_rb=granted,
                     harq_acks=acks, harq_nacks=nacks, harq_retx=retx,
                     dropped_bits=dropped, ho_events=ho_events,
                     buffer_bits=occupancy, jain=jain, dirty_rows=n_dirty,
                     active_ues=active_count, cells_down=cells_down,
                     reattach_events=reattached)


def summarize(telem: Telemetry, tti_s: float | None = None) -> dict:
    """Reduce a telemetry stack to a flat dict of python-float KPIs.

    Accepts per-TTI stacks of any leading shape -- a rollout's
    ``(n_tti, ...)``, an env batch's ``(batch, n_tti, ...)``, or a single
    step -- and aggregates over all leading axes.  ``tti_s`` converts the
    served-bits total into a mean offered-load figure (Mbit/s per cell).
    The dict is plain host data: what ``CrrmEnv``'s gym adapter exposes in
    its info dict and ``examples/quickstart.py`` prints.
    """
    import numpy as np

    t = jax.tree_util.tree_map(np.asarray, telem)
    n_tti = max(1, int(np.prod(t.jain.shape))) if t.jain.ndim else 1
    attempts = float(t.harq_acks.sum() + t.harq_nacks.sum())
    out = {
        "served_mbits": float(t.served_bits.sum()) / 1e6,
        "mean_cell_load_rb": float(t.granted_rb.mean()),
        "harq_acks": float(t.harq_acks.sum()),
        "harq_nacks": float(t.harq_nacks.sum()),
        "harq_nack_rate": (float(t.harq_nacks.sum()) / attempts
                           if attempts else 0.0),
        "harq_retx": float(t.harq_retx.sum()),
        "dropped_mbits": float(t.dropped_bits.sum()) / 1e6,
        "ho_events": float(t.ho_events.sum()),
        "mean_buffer_mbits": float(t.buffer_bits.mean()) / 1e6,
        "mean_jain": float(t.jain.mean()),
    }
    if tti_s is not None:
        busiest = t.served_bits.sum(axis=tuple(range(t.served_bits.ndim - 1)))
        out["busiest_cell_mbps"] = float(busiest.max()) / (n_tti * tti_s) / 1e6
    if t.dirty_rows is not None:
        out["mean_dirty_rows"] = float(t.dirty_rows.mean())
    if t.active_ues is not None:
        out["mean_active_ues"] = float(t.active_ues.mean())
    if t.cells_down is not None:
        out["mean_cells_down"] = float(t.cells_down.mean())
    if t.reattach_events is not None:
        out["reattach_events"] = float(t.reattach_events.sum())
    return out


def format_summary(kpis: dict) -> str:
    """One aligned line per KPI -- the quickstart's printable view."""
    width = max(len(k) for k in kpis)
    return "\n".join(f"  {k:<{width}}  {v:,.3f}" for k, v in kpis.items())
