"""Profiling hooks: traces, annotations, compile counters, stage timers.

Four small tools, all safe on any backend (every one degrades to a no-op
when the underlying jax facility is missing):

* :func:`trace` -- context manager around ``jax.profiler.trace``: dumps a
  TensorBoard/perfetto trace of everything launched inside it;
* :func:`annotate` -- named ``TraceAnnotation`` scope so engine phases
  (prepare / rollout / sync) are legible inside that trace;
* :class:`CompileCounter` -- counts *XLA backend compilations* process-wide
  via the ``jax.monitoring`` event stream.  Wrapping a steady-state loop in
  one is the retrace detector: a loop that re-enters XLA per iteration is
  the classic silent 100x (shape-polymorphic arguments, python-hashed
  statics, fresh closures);
* :class:`RetraceWatch` -- per-executable jit-cache-size snapshots for the
  engine/env functions (``EpisodeFns.step``/``rollout``,
  ``CrrmEnv._vmapped``): asserts that *these* callables did not pick up new
  specialisations across a region, which is sharper than the global count;
* :class:`StageTimer` -- the per-stage wall-time breakdown used by
  ``benchmarks/paper_benches.py``: blocks on stage outputs and renders an
  aligned table of stage -> (calls, total ms, share).
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Dict, Optional

import jax

#: process-wide XLA backend-compile count, fed by the jax.monitoring
#: duration event '/jax/core/compile/backend_compile_duration' (one per
#: compilation).  Registered lazily, once; CompileCounter reads deltas.
_COMPILE_EVENTS = {"count": 0}
_LISTENER_STATE = {"registered": False, "available": None}


def _on_duration(name: str, secs: float, **kw) -> None:
    if name.endswith("backend_compile_duration"):
        _COMPILE_EVENTS["count"] += 1


def _ensure_listener() -> bool:
    """Register the compile-event listener once; False if unsupported."""
    if not _LISTENER_STATE["registered"]:
        try:
            import jax.monitoring
            jax.monitoring.register_event_duration_secs_listener(
                _on_duration)
            _LISTENER_STATE["available"] = True
        except Exception:           # pragma: no cover - jax without events
            _LISTENER_STATE["available"] = False
        _LISTENER_STATE["registered"] = True
    return bool(_LISTENER_STATE["available"])


@contextlib.contextmanager
def trace(log_dir: str, create_perfetto_trace: bool = False):
    """``jax.profiler.trace`` as a guarded context manager.

    Collects a device/host trace of everything dispatched inside the
    block into ``log_dir`` (TensorBoard's profile plugin reads it).  A
    backend without profiler support degrades to a no-op rather than
    failing the caller's run.
    """
    try:
        cm = jax.profiler.trace(log_dir,
                                create_perfetto_trace=create_perfetto_trace)
    except Exception:               # pragma: no cover - no profiler backend
        yield
        return
    with cm:
        yield


def annotate(name: str):
    """A named ``TraceAnnotation`` scope (no-op without profiler support).

    Wrap engine phases so a :func:`trace` dump shows them as labelled
    spans:  ``with annotate("rollout"): fns.rollout(...)``.
    """
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:               # pragma: no cover - no profiler backend
        return contextlib.nullcontext()


class CompileCounter:
    """Counts XLA backend compilations inside a ``with`` region.

    >>> with CompileCounter() as c:
    ...     fns.rollout(static, state, 50)   # steady state: compiles == 0
    >>> assert c.count == 0, f"unexpected retrace: {c.count} compiles"

    The canonical failure it catches is the *shape-polymorphic call*: a
    caller feeding varying shapes (or fresh static arguments) into a
    jitted function recompiles per call, silently trading the one-program
    scan for per-call tracing.  ``supported`` is False on jax builds
    without the monitoring event stream -- the count then stays 0 and
    callers should skip the assertion (tests do).
    """

    def __init__(self):
        self.supported = _ensure_listener()
        self._base = 0
        self.count = 0

    def __enter__(self) -> "CompileCounter":
        self._base = _COMPILE_EVENTS["count"]
        self.count = 0
        return self

    def __exit__(self, *exc) -> None:
        self.count = _COMPILE_EVENTS["count"] - self._base


def executable_cache_size(fn) -> Optional[int]:
    """Number of compiled specialisations a ``jax.jit`` callable holds.

    None when the callable does not expose a jit cache (non-jit
    functions, older jax).  Growth across two calls with "the same"
    arguments is a retrace -- the thing :class:`RetraceWatch` asserts
    never happens to the engine executables.
    """
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:               # pragma: no cover - version dependent
        return None


class RetraceWatch:
    """Snapshot named executables' jit caches; report growth as retraces.

    >>> watch = RetraceWatch(step=fns.step, rollout=fns.rollout)
    >>> run_many_episodes()
    >>> watch.retraces()            # {} -- or {'rollout': 2} on a bug
    >>> watch.assert_stable()       # raises listing the offenders

    The engine bakes its trace-time switches into ``make_episode_fns``,
    so in steady state every ``step``/``rollout`` call must hit an
    existing specialisation; any growth here means a caller is feeding
    shape- or static-polymorphic arguments (new ``n_tti`` values are the
    one *expected* specialisation axis -- snapshot after warm-up).
    """

    def __init__(self, **executables):
        self._fns: Dict[str, Any] = dict(executables)
        self._base = {name: executable_cache_size(f) or 0
                      for name, f in self._fns.items()}

    def retraces(self) -> Dict[str, int]:
        """name -> number of new specialisations since construction."""
        out = {}
        for name, f in self._fns.items():
            now = executable_cache_size(f)
            if now is not None and now > self._base[name]:
                out[name] = now - self._base[name]
        return out

    def assert_stable(self) -> None:
        grew = self.retraces()
        assert not grew, (
            f"unintended recompilation: {grew} (an executable picked up "
            f"new jit specialisations in a region expected to be steady "
            f"state -- check for shape-polymorphic or fresh-static "
            f"arguments)")


class StageTimer:
    """Accumulating per-stage wall-clock breakdown (host-side, blocking).

    ``time(stage, fn, *args)`` runs ``fn`` and blocks on its output (so
    async dispatch cannot leak one stage's device time into the next);
    ``stage(name)`` is the context-manager spelling for arbitrary blocks.
    ``report()`` renders stage -> (calls, total ms, share) aligned rows --
    the breakdown ``benchmarks/paper_benches.py`` prints as ``# profile:``
    comment lines.
    """

    def __init__(self):
        self._total: Dict[str, float] = {}
        self._calls: Dict[str, int] = {}

    @contextlib.contextmanager
    def stage(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._total[name] = self._total.get(name, 0.0) + dt
            self._calls[name] = self._calls.get(name, 0) + 1

    def time(self, name: str, fn: Callable, *args, **kw):
        """Run ``fn`` under ``stage(name)``, blocking on its output."""
        with self.stage(name):
            out = fn(*args, **kw)
            jax.block_until_ready(out)
        return out

    def total_s(self, name: str) -> float:
        return self._total.get(name, 0.0)

    def report(self, prefix: str = "") -> str:
        if not self._total:
            return f"{prefix}(no stages timed)"
        grand = sum(self._total.values())
        width = max(len(n) for n in self._total)
        rows = []
        for name, tot in sorted(self._total.items(), key=lambda kv: -kv[1]):
            share = tot / grand if grand else 0.0
            rows.append(f"{prefix}{name:<{width}}  x{self._calls[name]:<4d} "
                        f"{tot * 1e3:9.1f} ms  {share:6.1%}")
        return "\n".join(rows)
