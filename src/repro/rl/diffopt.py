"""First-order power-plan optimisation through the differentiable engine.

``jax.grad`` flows end-to-end through the MAC engine's ``rollout`` when
it is built with a ``repro.sim.radio.RelaxConfig``: hard argmax
attachment becomes a temperature softmax over log-RSRP, the CQI
staircase a sigmoid-sum surrogate (or straight-through), the max-CQI
scheduler a softmax share (each relaxation individually flag-gated;
``relax=None`` compiles the exact legacy program -- tests/test_rl.py
pins both the bitwise-off claim and the finite-difference match of the
gradients).

This module packages that into an optimizer over an *action trajectory*
``u_plan`` of shape (n_segments, n_cells, n_subbands): segment ``i``'s
unconstrained entries are squashed to watts (sigmoid x budget clamp, the
env's own convention) and held for ``tti_per_segment`` TTIs of the
scanned rollout.  Ascent happens on the relaxed objective; progress is
*scored* on the un-relaxed engine (same seeds), so the number reported
is the real simulator's throughput, not the surrogate's.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.env.crrm_env import expand_action
from repro.sim.radio import RelaxConfig
from repro.train import optim


def plan_to_power(params, u_plan):
    """Unconstrained (..., n_cells, n_subbands) -> engine power grids.

    ``power_W * sigmoid(u)`` per entry, then the shared budget clamp +
    subband-chunk split (``repro.env.crrm_env.expand_action``) --
    differentiable everywhere the clamp is inactive, and almost
    everywhere on it.
    """
    watts = params.power_W * jax.nn.sigmoid(u_plan)
    return expand_action(params, watts)


def make_power_objective(sim, *, tti_per_segment: int = 10,
                         relax: RelaxConfig | None = RelaxConfig(),
                         seed: int = 0):
    """Build ``objective(u_plan) -> mean served Mbit/s`` for ``sim``.

    Returns ``(soft_objective, hard_objective)``: the first runs the
    relaxed engine (differentiable -- feed to ``jax.grad``), the second
    the exact legacy engine on the same seeds (the scoreboard).  Both
    are jitted, scan the plan's segments, and share the scenario's
    initial state, so their values coincide as ``relax`` tightens.
    """
    fns_soft = sim.episode_fns(radio_mode="dense", relax=relax)
    fns_hard = sim.episode_fns(radio_mode="dense")
    static = sim.episode_static()
    state0 = sim.init_episode_state(jax.random.PRNGKey(seed))

    def build(fns):
        def objective(u_plan):
            def segment(state, u):
                power = plan_to_power(sim.params, u)
                state, tput = fns.rollout(static, state,
                                          tti_per_segment, power)
                return state, tput.mean()

            _, seg_tput = jax.lax.scan(segment, state0, u_plan)
            return seg_tput.mean() / 1e6     # Mbit/s, O(1) for stable FD

        return jax.jit(objective)

    return build(fns_soft), build(fns_hard)


class DiffOptResult(NamedTuple):
    u_plan: Any         # optimised unconstrained trajectory
    power_plan: Any     # its (n_segments, n_cells, n_freq) watt grids
    history: list       # per-step dicts: soft/hard objective, grad norm


def optimize_power_plan(sim, *, n_segments: int = 4,
                        tti_per_segment: int = 10, steps: int = 40,
                        lr: float = 0.1,
                        relax: RelaxConfig | None = RelaxConfig(),
                        seed: int = 0, score_every: int = 5,
                        verbose: bool = False) -> DiffOptResult:
    """Gradient-ascend a power-plan trajectory for ``sim``.

    Starts from the uniform plan (``u = 0`` -> half budget per subband,
    clamp inactive: a strict interior point), takes ``steps`` Adam steps
    on the relaxed served-throughput objective, and scores the exact
    engine every ``score_every`` steps.  CPU-sized problems converge in
    tens of steps (examples/diff_power_plan.py).
    """
    soft_obj, hard_obj = make_power_objective(
        sim, tti_per_segment=tti_per_segment, relax=relax, seed=seed)
    grad_fn = jax.jit(jax.value_and_grad(soft_obj))
    opt = optim.adamw(optim.constant_lr(lr), weight_decay=0.0,
                      grad_clip=10.0)
    u = jnp.zeros((n_segments, sim.n_cells, sim.params.n_subbands),
                  jnp.float32)
    opt_state = opt.init(u)
    history = []
    for step in range(steps):
        value, grads = grad_fn(u)
        # ascent: the optimizer minimises, so feed it the negated grad
        u, opt_state, stats = opt.update(
            jax.tree_util.tree_map(jnp.negative, grads), opt_state, u)
        rec = {"step": step, "soft_mbps": float(value),
               "grad_norm": float(stats["grad_norm"])}
        if score_every and step % score_every == 0:
            rec["hard_mbps"] = float(hard_obj(u))
        history.append(rec)
        if verbose and "hard_mbps" in rec:
            print(f"# diffopt step {step}: soft {rec['soft_mbps']:.3f} "
                  f"hard {rec['hard_mbps']:.3f} Mbit/s "
                  f"|g| {rec['grad_norm']:.2e}")
    history.append({"step": steps, "soft_mbps": float(soft_obj(u)),
                    "hard_mbps": float(hard_obj(u)), "grad_norm": 0.0})
    return DiffOptResult(u_plan=u,
                         power_plan=jax.vmap(
                             lambda uu: plan_to_power(sim.params, uu))(u),
                         history=history)
