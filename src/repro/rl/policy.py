"""MLP actor-critic over the CRRM power-control surface.

Everything here is a pure function of an explicit ``params`` pytree
(jit/vmap/grad-compatible; ``train.checkpoint`` snapshots it directly).

The observation the network sees (:func:`features`) is deliberately
cheap and size-stable: per-cell serving KPIs of the *previous* decision
window (delivered Mbit/s and granted-RB share per cell -- the credit-
assignment signal a power plan can actually move, taken from the env's
``reward_components``) plus four global UE-population statistics of the
raw :class:`~repro.env.crrm_env.EnvObs`.  At an episode start the
per-cell block is zero -- the policy learns its own prior for the first
window.

The Gaussian policy lives in an *unconstrained* space ``u``; actions are
deterministic squashes of the sample (:func:`squash_power` maps to
``(0, power_W)`` per cell/subband, :func:`squash_fairness` to the
alpha-fairness interval).  PPO ratios are computed on ``u`` itself, so
the squash Jacobians cancel between behaviour and target policies and
never need evaluating.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class PolicyConfig(NamedTuple):
    """Hashable trace-time description of the actor-critic.

    ``learn_fairness`` appends the PF alpha-fairness exponent to the
    action vector (squashed into ``fairness_range``); off, the action is
    the (n_cells, n_subbands) power matrix alone.
    """

    n_cells: int
    n_subbands: int
    power_W: float
    hidden: tuple = (64, 64)
    learn_fairness: bool = False
    fairness_range: tuple = (0.0, 2.0)
    init_log_std: float = -0.5


def action_dim(cfg: PolicyConfig) -> int:
    return cfg.n_cells * cfg.n_subbands + (1 if cfg.learn_fairness else 0)


def feature_dim(cfg: PolicyConfig) -> int:
    return 2 * cfg.n_cells + 4


def features(cfg: PolicyConfig, obs, cell_tput_mbps=None,
             cell_granted_rb=None):
    """Build the policy input vector for one (unbatched) episode.

    ``cell_tput_mbps`` / ``cell_granted_rb`` are the previous window's
    per-cell reward components (None at episode start -> zeros).
    """
    zc = jnp.zeros((cfg.n_cells,), jnp.float32)
    ct = zc if cell_tput_mbps is None else cell_tput_mbps
    cg = zc if cell_granted_rb is None else cell_granted_rb
    log_t = jnp.log1p(jnp.maximum(obs.tput, 0.0) / 1e6)
    finite = jnp.isfinite(obs.backlog)
    log_b = jnp.where(finite, jnp.log1p(
        jnp.where(finite, obs.backlog, 0.0) / 1e4), 0.0)
    return jnp.concatenate([
        jnp.log1p(jnp.maximum(ct, 0.0)),
        cg / 100.0,
        jnp.stack([log_t.mean(), log_t.std(), log_b.mean(),
                   finite.mean(dtype=jnp.float32)]),
    ]).astype(jnp.float32)


def init_policy(key, cfg: PolicyConfig):
    """Orthogonal-ish (scaled normal) init; small actor head so the
    initial policy stays near the uniform plan."""
    sizes = (feature_dim(cfg),) + tuple(cfg.hidden)
    params = {"layers": [], "log_std": jnp.full((action_dim(cfg),),
                                                cfg.init_log_std,
                                                jnp.float32)}
    keys = jax.random.split(key, len(sizes) + 1)
    for i, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(keys[i], (n_in, n_out),
                              jnp.float32) * math.sqrt(2.0 / n_in)
        params["layers"].append({"w": w, "b": jnp.zeros((n_out,),
                                                        jnp.float32)})
    n_last = sizes[-1]
    k_pi, k_v = jax.random.split(keys[-1])
    params["actor"] = {
        "w": jax.random.normal(k_pi, (n_last, action_dim(cfg)),
                               jnp.float32) * 0.01,
        "b": jnp.zeros((action_dim(cfg),), jnp.float32)}
    params["critic"] = {
        "w": jax.random.normal(k_v, (n_last, 1), jnp.float32) * 0.1,
        "b": jnp.zeros((1,), jnp.float32)}
    return params


def policy_apply(cfg: PolicyConfig, params, feat):
    """feat (feature_dim,) -> (mean_u (action_dim,), log_std, value)."""
    h = feat
    for layer in params["layers"]:
        h = jnp.tanh(h @ layer["w"] + layer["b"])
    mean_u = h @ params["actor"]["w"] + params["actor"]["b"]
    value = (h @ params["critic"]["w"] + params["critic"]["b"])[0]
    log_std = jnp.clip(params["log_std"], -5.0, 1.0)
    return mean_u, log_std, value


def squash_power(cfg: PolicyConfig, u_power):
    """Unconstrained (n_cells*n_subbands,) -> (n_cells, n_subbands) watts.

    Per-entry ``power_W * sigmoid(u)``; the env's budget clamp
    (``repro.env.crrm_env.expand_action``) then enforces the per-cell
    total, so every sampled action is feasible.
    """
    p = cfg.power_W * jax.nn.sigmoid(u_power)
    return p.reshape(cfg.n_cells, cfg.n_subbands)


def squash_fairness(cfg: PolicyConfig, u_fair):
    lo, hi = cfg.fairness_range
    return lo + (hi - lo) * jax.nn.sigmoid(u_fair)


def split_action(cfg: PolicyConfig, u):
    """u (action_dim,) -> (power (n_cells, n_subbands), fairness|None)."""
    n_p = cfg.n_cells * cfg.n_subbands
    power = squash_power(cfg, u[:n_p])
    fair = squash_fairness(cfg, u[n_p]) if cfg.learn_fairness else None
    return power, fair


def _gauss_logp(u, mean_u, log_std):
    z = (u - mean_u) * jnp.exp(-log_std)
    return jnp.sum(-0.5 * z * z - log_std
                   - 0.5 * math.log(2.0 * math.pi))


def sample_action(cfg: PolicyConfig, params, feat, key):
    """Sample the behaviour action: ``(u, power, fairness, logp, value)``."""
    mean_u, log_std, value = policy_apply(cfg, params, feat)
    u = mean_u + jnp.exp(log_std) * jax.random.normal(key, mean_u.shape)
    power, fair = split_action(cfg, u)
    return u, power, fair, _gauss_logp(u, mean_u, log_std), value


def logp_entropy(cfg: PolicyConfig, params, feat, u):
    """Re-evaluate a stored sample under (new) params: PPO's ratio path."""
    mean_u, log_std, value = policy_apply(cfg, params, feat)
    logp = _gauss_logp(u, mean_u, log_std)
    entropy = jnp.sum(log_std + 0.5 * math.log(2.0 * math.pi * math.e))
    return logp, entropy, value


def mean_action(cfg: PolicyConfig, params, feat):
    """The deterministic (evaluation-time) action: squashed mean."""
    mean_u, _, _ = policy_apply(cfg, params, feat)
    return split_action(cfg, mean_u)
