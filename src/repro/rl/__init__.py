"""repro.rl: learned RRM policies over the CRRM engine.

Two pillars (DESIGN.md §RL-and-differentiability):

* **PPO baselines** -- an MLP actor-critic over the per-cell/subband
  transmit-power action (optionally plus the PF alpha-fairness scalar),
  trained on population-batched ``CrrmEnv`` rollouts: ``policy``
  (network + action squash), ``rollout`` (jit(vmap) auto-resetting
  collection), ``ppo`` (GAE + clipped surrogate + checkpointed loop).
* **Differentiable CRRM** -- ``diffopt`` differentiates the engine's
  ``rollout`` w.r.t. the power-action trajectory through the
  flag-gated soft relaxations (``repro.sim.radio.RelaxConfig``) and
  runs first-order power-plan optimisation.
"""
from repro.rl.policy import (PolicyConfig, init_policy, policy_apply,
                             features, feature_dim, sample_action,
                             logp_entropy, mean_action, squash_power,
                             squash_fairness)
from repro.rl.rollout import Trajectory, make_collect_fn
from repro.rl.ppo import (PPOConfig, TrainState, ppo_init, make_train_step,
                          train, evaluate_uplift)
from repro.rl.diffopt import (make_power_objective, optimize_power_plan,
                              plan_to_power)

__all__ = [
    "PolicyConfig", "init_policy", "policy_apply", "features",
    "feature_dim", "sample_action", "logp_entropy", "mean_action",
    "squash_power", "squash_fairness",
    "Trajectory", "make_collect_fn",
    "PPOConfig", "TrainState", "ppo_init", "make_train_step", "train",
    "evaluate_uplift",
    "make_power_objective", "optimize_power_plan", "plan_to_power",
]
