"""Population-batched, auto-resetting rollout collection.

One compiled program collects the whole PPO batch: a ``lax.scan`` over
the decision steps of ``n_envs`` vmapped episode streams, each stream
restarting itself at its horizon through the env's pure
``step_autoreset`` (terminal transitions stay visible for GAE; the
carried state jumps to a fresh seed).  The scan carries the policy
*features* alongside the env states, so the behaviour policy always acts
on the previous window's KPIs without re-deriving them.

The env must be constructed with ``telemetry=True`` (the per-cell reward
components feed :func:`repro.rl.policy.features`) and
``resample_topology=False`` (auto-reset contract).  An optional UE-axis
``mesh`` env is supported only unbatched (``n_envs == 1`` without vmap)
-- the sharded program already spans the devices.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.rl import policy as pol


class Trajectory(NamedTuple):
    """One collection batch, time-major: every leaf (n_steps, n_envs, ...)."""

    feat: Any     # (T, B, feature_dim) what the behaviour policy saw
    u: Any        # (T, B, action_dim) unconstrained action samples
    logp: Any     # (T, B) behaviour log-probs of u
    value: Any    # (T, B) critic estimates
    reward: Any   # (T, B)
    done: Any     # (T, B) bool episode boundaries (pre-reset)


def _next_features(cfg, obs, info, done, feat0):
    rc = info["reward_components"]
    nf = pol.features(cfg, obs, rc["cell_tput_mbps"],
                      rc["cell_granted_rb"])
    # a finished stream restarts: its first decision of the fresh episode
    # must see the reset features, not the dead episode's terminal KPIs
    return jnp.where(done, feat0, nf)


def make_collect_fn(env, cfg: pol.PolicyConfig, n_steps: int):
    """Build ``collect(params, env_states, feats, key)`` for ``env``.

    Returns a jitted pure function
    ``(params, env_states, feats, key) ->
    (env_states', feats', Trajectory, last_value)`` where the batch axis
    of ``env_states``/``feats`` is ``n_envs`` and ``last_value`` is the
    critic bootstrap at the post-rollout features.  Pair it with
    ``env.reset_batch`` + :func:`initial_features` for the first call;
    thereafter thread the returned carry (collection is a continuous
    stream across train iterations, the PPO convention).
    """
    if not env.telemetry:
        raise ValueError("rollout collection needs CrrmEnv(telemetry="
                         "True): the per-cell reward components are the "
                         "policy's input features")
    if env.resample_topology:
        raise ValueError("rollout collection auto-resets in-scan, which "
                         "requires resample_topology=False")

    # the reset observation is seed-independent under a fixed topology
    # (zero tput, template backlog), so the reset features are a constant
    _, obs0 = env.reset(jax.random.PRNGKey(0))
    feat0 = pol.features(cfg, obs0)

    def one_env_step(params, state, feat, key):
        k_act, k_reset = jax.random.split(key)
        u, power, fair, logp, value = pol.sample_action(cfg, params, feat,
                                                        k_act)
        state, obs, reward, done, info = env.step_autoreset(
            state, power, k_reset, fair)
        nf = _next_features(cfg, obs, info, done, feat0)
        return state, nf, (feat, u, logp, value, reward, done)

    def collect(params, env_states, feats, key):
        n_envs = feats.shape[0]

        def scan_step(carry, k):
            states, feats = carry
            keys = jax.random.split(k, n_envs)
            states, feats, out = jax.vmap(
                lambda s, f, kk: one_env_step(params, s, f, kk)
            )(states, feats, keys)
            return (states, feats), out

        keys = jax.random.split(key, n_steps)
        (env_states, feats), outs = jax.lax.scan(
            scan_step, (env_states, feats), keys)
        traj = Trajectory(*outs)
        last_value = jax.vmap(
            lambda f: pol.policy_apply(cfg, params, f)[2])(feats)
        return env_states, feats, traj, last_value

    return jax.jit(collect)


def initial_features(env, cfg: pol.PolicyConfig, obs_batch):
    """Features for a fresh ``reset_batch`` observation (zero KPI block)."""
    return jax.vmap(lambda o: pol.features(cfg, o))(obs_batch)
