"""PPO over population-batched CRRM rollouts.

The textbook recipe (GAE advantages, clipped surrogate, a few epochs of
full-batch gradient steps) with the repo's own plumbing: rollout
collection is ONE compiled program (``repro.rl.rollout``), the optimizer
is ``repro.train.optim.adamw``, and the *entire* training state --
policy params, Adam moments, the live env states and features, the PRNG
key, the iteration counter -- is one pytree snapshotted by
``repro.train.checkpoint``.  Because every random draw is threaded
through that state, restoring a checkpoint and continuing reproduces the
uninterrupted run bitwise (asserted in tests/test_rl.py): preemption is
free.

CLI (the CI smoke step and the bench seed path)::

    PYTHONPATH=src python -m repro.rl.ppo --scenario dense_urban --smoke
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.rl import policy as pol
from repro.rl import rollout as ro
from repro.train import optim


class PPOConfig(NamedTuple):
    """Hashable PPO hyper-parameters (trace-time constants)."""

    n_envs: int = 8           # parallel episode streams (vmap axis)
    n_steps: int = 16         # decision steps collected per iteration
    gamma: float = 0.95       # discount per decision step
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 1e-3
    lr: float = 3e-3
    epochs: int = 4           # full-batch passes per iteration
    grad_clip: float = 0.5


class TrainState(NamedTuple):
    """Everything PPO threads -- one checkpointable pytree."""

    params: Any       # policy/critic weights
    opt_state: Any    # Adam moments
    env_states: Any   # live batched EpisodeState carry
    feats: Any        # (n_envs, feature_dim) current policy inputs
    key: Any          # PRNG carry
    iteration: Any    # i32 scalar


def _optimizer(cfg: PPOConfig):
    return optim.adamw(optim.constant_lr(cfg.lr), weight_decay=0.0,
                       grad_clip=cfg.grad_clip)


def ppo_init(env, pcfg: pol.PolicyConfig, cfg: PPOConfig,
             seed: int = 0) -> TrainState:
    """Fresh training state: policy init + ``n_envs`` reset episodes."""
    k_init, k_env, k_run = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = pol.init_policy(k_init, pcfg)
    states, obs = env.reset_batch(jax.random.split(k_env, cfg.n_envs))
    feats = ro.initial_features(env, pcfg, obs)
    return TrainState(params=params,
                      opt_state=_optimizer(cfg).init(params),
                      env_states=states, feats=feats, key=k_run,
                      iteration=jnp.zeros((), jnp.int32))


def gae(reward, value, done, last_value, gamma: float, lam: float):
    """Generalised advantage estimation over a time-major batch.

    ``done`` masks the bootstrap across episode boundaries (the env's
    horizon is a truncation, but the discounted objective is defined
    per episode, so boundaries cut the credit flow).  Returns
    ``(advantages, returns)`` of shape (T, B).
    """
    def scan_back(adv_next, inp):
        r, v, v_next, d = inp
        mask = 1.0 - d.astype(jnp.float32)
        delta = r + gamma * v_next * mask - v
        adv = delta + gamma * lam * mask * adv_next
        return adv, adv

    v_next = jnp.concatenate([value[1:], last_value[None]], axis=0)
    _, adv = jax.lax.scan(scan_back, jnp.zeros_like(last_value),
                          (reward, value, v_next, done), reverse=True)
    return adv, adv + value


def ppo_loss(params, pcfg: pol.PolicyConfig, cfg: PPOConfig, batch):
    """Clipped-surrogate + value + entropy loss over flattened samples."""
    feat, u, logp_old, adv, ret = batch
    logp, ent, value = jax.vmap(
        lambda f, uu: pol.logp_entropy(pcfg, params, f, uu))(feat, u)
    ratio = jnp.exp(logp - logp_old)
    adv_n = (adv - adv.mean()) / (adv.std() + 1e-8)
    surrogate = jnp.minimum(
        ratio * adv_n,
        jnp.clip(ratio, 1.0 - cfg.clip_eps, 1.0 + cfg.clip_eps) * adv_n)
    pi_loss = -surrogate.mean()
    v_loss = jnp.square(value - ret).mean()
    loss = pi_loss + cfg.vf_coef * v_loss - cfg.ent_coef * ent.mean()
    return loss, {"loss": loss, "pi_loss": pi_loss, "v_loss": v_loss,
                  "entropy": ent.mean(),
                  "approx_kl": (logp_old - logp).mean()}


def make_train_step(env, pcfg: pol.PolicyConfig, cfg: PPOConfig):
    """One jitted PPO iteration: collect -> GAE -> ``epochs`` updates.

    ``TrainState -> (TrainState, metrics)``; metrics also report the
    mean collected reward (the learning curve the smoke test asserts
    on).
    """
    collect = ro.make_collect_fn(env, pcfg, cfg.n_steps)
    opt = _optimizer(cfg)

    def train_step(ts: TrainState):
        key, k_roll = jax.random.split(ts.key)
        env_states, feats, traj, last_value = collect(
            ts.params, ts.env_states, ts.feats, k_roll)
        adv, ret = gae(traj.reward, traj.value, traj.done, last_value,
                       cfg.gamma, cfg.gae_lambda)

        def flat(x):
            return x.reshape((-1,) + x.shape[2:])

        batch = tuple(map(flat, (traj.feat, traj.u, traj.logp, adv, ret)))

        def epoch(_, carry):
            params, opt_state, _ = carry
            (_, metrics), grads = jax.value_and_grad(
                ppo_loss, has_aux=True)(params, pcfg, cfg, batch)
            params, opt_state, _ = opt.update(grads, opt_state, params)
            return params, opt_state, metrics

        _, metrics0 = ppo_loss(ts.params, pcfg, cfg, batch)
        params, opt_state, metrics = jax.lax.fori_loop(
            0, cfg.epochs, epoch, (ts.params, ts.opt_state, metrics0))
        metrics = dict(metrics, mean_reward=traj.reward.mean(),
                       mean_value=traj.value.mean())
        return TrainState(params=params, opt_state=opt_state,
                          env_states=env_states, feats=feats, key=key,
                          iteration=ts.iteration + 1), metrics

    return jax.jit(train_step)


def train(env, pcfg: pol.PolicyConfig, cfg: PPOConfig, iterations: int,
          seed: int = 0, ckpt_dir: str | None = None,
          ckpt_every: int = 0, log_every: int = 0):
    """Run (or resume) a PPO training loop; returns (TrainState, history).

    With ``ckpt_dir``, training resumes from the latest checkpoint if one
    exists and snapshots every ``ckpt_every`` iterations -- restore is
    bitwise (the whole :class:`TrainState` is the checkpoint), so a
    preempted run continues exactly where it stopped.
    """
    from repro.train import checkpoint

    ts = ppo_init(env, pcfg, cfg, seed)
    if ckpt_dir is not None:
        latest = checkpoint.latest_step(ckpt_dir)
        if latest is not None:
            ts, _ = checkpoint.restore(ckpt_dir, latest, ts)
    step_fn = make_train_step(env, pcfg, cfg)
    history = []
    start = int(ts.iteration)
    for it in range(start, iterations):
        ts, metrics = step_fn(ts)
        metrics = {k: float(v) for k, v in metrics.items()}
        history.append(metrics)
        if log_every and (it + 1) % log_every == 0:
            print(f"# ppo iter {it + 1}/{iterations} "
                  f"reward {metrics['mean_reward']:.4f} "
                  f"loss {metrics['loss']:.4f} "
                  f"kl {metrics['approx_kl']:.2e}")
        if ckpt_dir is not None and ckpt_every \
                and (it + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_dir, it + 1, ts)
    return ts, history


def evaluate_uplift(env, pcfg: pol.PolicyConfig, params, key,
                    n_steps: int = 8):
    """Served-throughput uplift of the learned plan over fixed power.

    Rolls the SAME seeds twice from reset -- once under the policy's
    deterministic mean action (features threaded step to step), once
    under the uniform fixed-power plan -- and compares total served
    bits (telemetry ground truth, not the shaped reward).  Returns
    ``(uplift_ratio, learned_mbits, fixed_mbits)``.
    """
    keys = jax.random.split(key, 4)[:1]       # one eval stream suffices

    @partial(jax.jit, static_argnums=(0,))
    def run(use_policy):
        state, obs = env.reset(keys[0])
        feat = pol.features(pcfg, obs)
        total = jnp.zeros(())

        def body(carry, _):
            state, feat, total = carry
            power, fair = pol.mean_action(pcfg, params, feat)
            if not use_policy:
                power, fair = env.uniform_action(), None
            state, obs, _, done, info = env.step(state, power, fair)
            rc = info["reward_components"]
            total = total + info["telemetry"].served_bits.sum()
            feat = pol.features(pcfg, obs, rc["cell_tput_mbps"],
                                rc["cell_granted_rb"])
            return (state, feat, total), None

        (state, feat, total), _ = jax.lax.scan(
            body, (state, feat, total), None, length=n_steps)
        return total

    learned = float(run(True)) / 1e6
    fixed = float(run(False)) / 1e6
    return learned / max(fixed, 1e-12), learned, fixed


def served_tput_reward(obs):
    """Mean delivered throughput in Mbit/s -- the bench's gated metric as
    the training signal (reward/metric alignment is what makes the tiny
    smoke budget learn a measurable uplift)."""
    return obs.tput.mean() / 1e6


def train_power_baseline(scenario: str = "dense_urban", *, n_ues: int = 12,
                         iterations: int = 60, eval_every: int = 5,
                         seed: int = 0, lr: float = 1e-2,
                         init_log_std: float = 0.0, n_envs: int = 4,
                         n_steps: int = 8, tti_per_step: int = 5,
                         episode_tti: int = 40,
                         arrival_rate_hz: float = 2000.0,
                         scenario_overrides: dict | None = None,
                         learn_fairness: bool = False,
                         ckpt_dir: str | None = None,
                         verbose: bool = False) -> dict:
    """Train a per-scenario power-control baseline with eval selection.

    The recipe behind ``benchmarks/BENCH_rl.json``: saturate the traffic
    (``arrival_rate_hz`` well past the serveable load, so throughput is
    interference-limited and the power plan has leverage), train PPO on
    the served-throughput reward, evaluate the deterministic policy
    every ``eval_every`` iterations against the uniform fixed-power
    plan, and keep the best iterate (PPO's late-run policy drift is
    real; baselines report the selected policy, as eval-selection
    protocols do).  Returns a result dict with ``best_uplift``,
    ``final_uplift``, ``best_params``, ``history``, and the env/config
    objects for reuse.
    """
    from repro.env import CrrmEnv

    ov = dict(n_ues=n_ues,
              traffic_params=dict(arrival_rate_hz=arrival_rate_hz,
                                  packet_size_bits=12_000.0))
    ov.update(scenario_overrides or {})
    env = CrrmEnv(scenario=scenario, scenario_overrides=ov,
                  episode_tti=episode_tti, tti_per_step=tti_per_step,
                  telemetry=True, reward_fn=served_tput_reward)
    pcfg = pol.PolicyConfig(n_cells=env.n_cells,
                            n_subbands=env.n_subbands,
                            power_W=env.max_cell_power_W,
                            learn_fairness=learn_fairness,
                            init_log_std=init_log_std)
    cfg = PPOConfig(n_envs=n_envs, n_steps=n_steps, lr=lr)
    step_fn = make_train_step(env, pcfg, cfg)
    ts = ppo_init(env, pcfg, cfg, seed)

    from repro.train import checkpoint
    if ckpt_dir is not None:
        latest = checkpoint.latest_step(ckpt_dir)
        if latest is not None:
            ts, _ = checkpoint.restore(ckpt_dir, latest, ts)

    eval_key = jax.random.PRNGKey(seed + 1)
    history, best = [], {"uplift": -float("inf"), "params": ts.params,
                         "iteration": 0}
    for it in range(int(ts.iteration), iterations):
        ts, metrics = step_fn(ts)
        rec = {k: float(v) for k, v in metrics.items()}
        if (it + 1) % eval_every == 0 or it + 1 == iterations:
            uplift, learned, fixed = evaluate_uplift(env, pcfg,
                                                     ts.params, eval_key)
            rec.update(uplift=uplift, learned_mbits=learned,
                       fixed_mbits=fixed)
            if uplift > best["uplift"]:
                best = {"uplift": uplift, "params": ts.params,
                        "iteration": it + 1}
            if verbose:
                print(f"# ppo[{scenario}] iter {it + 1}/{iterations}: "
                      f"reward {rec['mean_reward']:.3f} "
                      f"uplift x{uplift:.3f}")
            if ckpt_dir is not None:
                checkpoint.save(ckpt_dir, it + 1, ts)
        history.append(rec)
    evals = [r for r in history if "uplift" in r]
    if not evals:
        # resumed past the last iteration: nothing trained this call, so
        # score the restored params once to keep the result contract
        uplift, learned, fixed = evaluate_uplift(env, pcfg, ts.params,
                                                 eval_key)
        best = {"uplift": uplift, "params": ts.params,
                "iteration": int(ts.iteration)}
        evals = [{"uplift": uplift, "learned_mbits": learned,
                  "fixed_mbits": fixed}]
    return {"scenario": scenario, "env": env, "pcfg": pcfg, "cfg": cfg,
            "train_state": ts, "history": history,
            "best_uplift": best["uplift"], "best_params": best["params"],
            "best_iteration": best["iteration"],
            "final_uplift": evals[-1]["uplift"],
            "fixed_mbits": evals[-1].get("fixed_mbits")}


# ------------------------------------------------------------------ CLI
def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="PPO power-control baseline")
    ap.add_argument("--scenario", default="dense_urban")
    ap.add_argument("--n-ues", type=int, default=24)
    ap.add_argument("--iterations", type=int, default=80)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--learn-fairness", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + assertions (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.n_ues, args.iterations = 12, 45
    out = train_power_baseline(args.scenario, n_ues=args.n_ues,
                               iterations=args.iterations,
                               seed=args.seed, ckpt_dir=args.ckpt_dir,
                               learn_fairness=args.learn_fairness,
                               verbose=True)
    print(f"# ppo[{args.scenario}]: best uplift x{out['best_uplift']:.3f} "
          f"(iter {out['best_iteration']}), final "
          f"x{out['final_uplift']:.3f}")
    if args.smoke:
        assert all(jnp.isfinite(jnp.asarray(m["loss"])).item()
                   for m in out["history"]), "PPO smoke: non-finite loss"
        assert out["best_uplift"] > 1.0, (
            f"PPO smoke: learned policy never beat fixed power "
            f"(best x{out['best_uplift']:.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
