"""Self-healing harness: carry guards, watchdog policy, chaos drills.

The fault-injection layer (``sim.faults``) makes the *simulated network*
fail on purpose; this package makes the *serving process* survive failure
-- its own and the simulator's (DESIGN.md §Fault-injection-and-self-healing):

* :mod:`repro.robust.guard` -- a fused, jit-compiled invariant check over
  the episode carry (NaN-free, finite positions, non-negative queues/
  averages), with a host-side diagnostic that names what broke.
* :mod:`repro.robust.watchdog` -- the recovery policy pytree
  (:class:`~repro.robust.watchdog.WatchdogConfig`), the fault taxonomy
  (timeout / guard violation / terminal
  :class:`~repro.robust.watchdog.TwinServerDown`), and a thread-based
  chunk timeout.  ``twin.server.TwinServer`` consumes these to roll back
  to the last valid checkpoint and retry with exponential backoff.
* :mod:`repro.robust.chaos` -- the chaos drill CI runs: a twin under a
  cell-fault storm with an injected NaN, a forced chunk exception and a
  corrupted latest checkpoint, asserting the server recovers and the
  resumed trajectory is the uninterrupted one.
"""
from repro.robust.guard import carry_ok, carry_violations, tree_has_nan
from repro.robust.watchdog import (ChunkTimeout, GuardViolation,
                                   TwinServerDown, WatchdogConfig,
                                   run_with_timeout)

__all__ = [
    "carry_ok", "carry_violations", "tree_has_nan",
    "WatchdogConfig", "ChunkTimeout", "GuardViolation", "TwinServerDown",
    "run_with_timeout",
]
