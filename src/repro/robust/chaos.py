"""Chaos drill: prove the twin survives the failures we can script.

Chaos engineering's core claim is that recovery code you never exercise
is recovery code that does not work.  This module is the scripted drill
CI runs on every push (``python -m repro.robust.chaos --smoke``): a
:class:`~repro.twin.server.TwinServer` under the ``outage_storm`` cell
fault process and an armed watchdog is subjected to, in order,

1. **a poisoned carry** -- NaN written straight into the serving state's
   PF average between chunks (the guard must trip, the watchdog must
   roll back, and the resumed trajectory must be the uninterrupted one);
2. **a crashing chunk** -- the compiled chunk program replaced by one
   that raises (the forced-kernel-failure case: recovery must rebuild
   on the degraded ``xla`` route and keep serving);
3. **a corrupted latest checkpoint** -- bytes flipped in the newest
   step's leaf file (rollback must fall through to the previous valid
   step, not resurrect garbage).

The drill asserts the server recovers from all three, that the final KPI
summary is finite, and that the failure history recorded every injected
fault.  Exit code 0 + the ``CHAOS_OK`` line is the CI contract
(DESIGN.md §Fault-injection-and-self-healing).
"""
from __future__ import annotations

import math
import os
import tempfile

import jax.numpy as jnp

from repro.robust.watchdog import WatchdogConfig
from repro.train import checkpoint as ckpt


def _corrupt_latest(ckpt_dir: str) -> int:
    """Flip bytes in the newest step's first leaf; return that step."""
    step = ckpt.latest_step(ckpt_dir)
    leaf = os.path.join(ckpt_dir, f"step_{step:010d}", "00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(-8, os.SEEK_END)
        f.write(b"\xff" * 8)
    return step


def drill(ckpt_dir: str, n_ues: int = 64, n_cells: int = 7,
          chunk: int = 20, verbose: bool = True) -> dict:
    """Run the full injection sequence; return the final KPI summary.

    Asserts internally -- an exception means the drill failed.  Small by
    default (CI-sized); the injections scale with nothing, so a larger
    twin drills identically.
    """
    from repro.core.crrm import CRRM
    from repro.sim.faults import FaultConfig
    from repro.sim.mobility import ChurnConfig
    from repro.sim.scenarios import make_scenario
    from repro.twin.server import TwinServer

    say = print if verbose else (lambda *a: None)
    sim = CRRM(make_scenario(
        "outage_storm", n_ues=n_ues, n_cells=n_cells,
        faults=FaultConfig(outage_rate_hz=8.0, mean_outage_s=0.02,
                           sleep_rate_hz=8.0, mean_sleep_s=0.02)))
    churn = ChurnConfig(arrival_rate_hz=300.0, mean_lifetime_s=0.2,
                        max_arrivals_per_tti=4)
    srv = TwinServer(
        sim, churn, chunk_tti=chunk, ckpt_dir=ckpt_dir, keep_last=4,
        watchdog=WatchdogConfig(max_retries=3, backoff_s=0.01,
                                ckpt_every_chunks=1))

    k = srv.step_chunk()                       # healthy storm chunk
    assert k["mean_cells_down"] > 0.0, "fault storm produced no outages"
    say(f"[chaos] storm serving: t={srv.t} "
        f"mean_cells_down={k['mean_cells_down']:.2f} "
        f"reattach_events={k['reattach_events']:.0f}")

    # -- injection 1: poisoned carry ------------------------------------
    # NaN positions survive the chunk (mobility is an additive walk) and
    # spread through pathgain -> SINR -> throughput; every row is
    # poisoned so churn rebirths (which redraw a slot's position) cannot
    # heal the carry before the guard sees it
    t_before = srv.t
    srv.state = srv.state._replace(
        U=srv.state.U.at[:, 0].set(jnp.nan))
    k = srv.step_chunk()                       # guard -> rollback -> retry
    assert srv.t == t_before + chunk, "NaN recovery lost TTIs"
    assert any("GuardViolation" in line for line in srv.fault_history), \
        "guard never tripped on the injected NaN"
    assert all(math.isfinite(v) for v in k.values()), \
        "post-recovery KPIs not finite"
    say(f"[chaos] survived injected NaN: t={srv.t}, "
        f"{len(srv.fault_history)} history lines")

    # -- injection 2: crashing chunk program ----------------------------
    real_chunk, boom = srv._chunk, {"armed": True}

    def _exploding(static, state, power, fairness):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected kernel failure")
        return real_chunk(static, state, power, fairness)

    srv._chunk = _exploding
    t_before = srv.t
    k = srv.step_chunk()
    assert srv.t == t_before + chunk, "crash recovery lost TTIs"
    assert any("injected kernel failure" in line
               for line in srv.fault_history), "crash not recorded"
    say(f"[chaos] survived injected chunk crash: t={srv.t}")
    srv._chunk = real_chunk

    # -- injection 3: corrupted latest checkpoint -----------------------
    bad_step = _corrupt_latest(ckpt_dir)
    srv.state = srv.state._replace(
        U=srv.state.U.at[:, 0].set(jnp.nan))          # force a rollback
    k = srv.step_chunk()
    assert any("rolled back to t=" in line
               for line in srv.fault_history), "no rollback recorded"
    last_rb = [line for line in srv.fault_history if "rolled back" in line][-1]
    assert f"t={bad_step}" not in last_rb, \
        "rollback resurrected the corrupted checkpoint"
    assert all(math.isfinite(v) for v in k.values())
    say(f"[chaos] survived corrupt latest checkpoint "
        f"(step {bad_step} skipped): {last_rb}")

    # the drill must end able to serve cleanly
    k = srv.step_chunk()
    assert all(math.isfinite(v) for v in k.values())
    assert k["served_mbits"] > 0.0
    return k


def main(argv=None) -> None:
    import argparse

    from repro.obs.telemetry import format_summary

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny twin, full injection sequence")
    ap.add_argument("--ues", type=int, default=64)
    ap.add_argument("--cells", type=int, default=7)
    ap.add_argument("--chunk", type=int, default=20)
    args = ap.parse_args(argv)

    with tempfile.TemporaryDirectory() as td:
        kpis = drill(td, n_ues=args.ues, n_cells=args.cells,
                     chunk=args.chunk)
    print(format_summary(kpis))
    print("CHAOS_OK: twin survived NaN injection, chunk crash and "
          "checkpoint corruption")


if __name__ == "__main__":
    main()
