"""Fused invariant guard over the episode carry.

A digital twin that serves for hours will eventually meet a state its
authors never rolled: a pathological control update, a numerical edge of
the SINR chain, a bad checkpoint.  The failure mode that matters is the
*silent* one -- a NaN born in one TTI propagates through every EWMA and
backlog it touches and the twin keeps streaming garbage KPIs.  This module
is the tripwire: one jit-compiled, fused reduction over the whole
:class:`~repro.mac.engine.EpisodeState` that the twin server checks once
per chunk (one scalar readback, no per-leaf host sync).

Invariants checked (:func:`carry_ok`):

* no float leaf anywhere in the carry contains NaN;
* UE positions ``U`` are finite;
* the PF average ``pf_avg`` and pending HARQ bits ``harq_bits`` are
  finite and non-negative;
* ``backlog`` is non-negative -- ``+inf`` is *legal* there (the engine's
  full-buffer sentinel), which is why the guard is NaN-centric rather
  than a blanket ``isfinite``;
* the TTI counter ``t`` is non-negative.

:func:`carry_violations` is the host-side post-mortem: slow, per-leaf,
and it names exactly which invariant broke where -- what the watchdog
puts in the diagnostic when it gives up.  :func:`tree_has_nan` is the
checkpoint layer's pre-write refusal check for arbitrary pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _float_leaves(tree):
    return [x for x in jax.tree_util.tree_leaves(tree)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]


@jax.jit
def _any_nan(leaves) -> jax.Array:
    bad = jnp.bool_(False)
    for x in leaves:
        bad = bad | jnp.isnan(x).any()
    return bad


def tree_has_nan(tree) -> bool:
    """True iff any float leaf of ``tree`` contains NaN (host bool).

    ``+inf``/``-inf`` do NOT trip it: the engine uses ``+inf`` as the
    full-buffer backlog sentinel, so infinities can be legitimate state.
    """
    leaves = _float_leaves(tree)
    if not leaves:
        return False
    return bool(_any_nan(leaves))


@jax.jit
def carry_ok(state) -> jax.Array:
    """Scalar bool: the episode carry satisfies every engine invariant.

    Fused and jitted: one compiled program per carry treedef, one device
    scalar out.  Works on a vmapped (batched) carry too -- the ``.all()``
    reductions span every axis, so a single False anywhere fails the
    whole batch (a twin never serves a half-poisoned batch).
    """
    ok = ~_any_nan(_float_leaves(state))
    ok &= jnp.isfinite(state.U).all()
    ok &= jnp.isfinite(state.pf_avg).all() & (state.pf_avg >= 0).all()
    ok &= jnp.isfinite(state.harq_bits).all() & (state.harq_bits >= 0).all()
    ok &= (state.backlog >= 0).all()     # +inf legal: full-buffer sentinel
    ok &= (state.t >= 0).all()
    return ok


def carry_violations(state) -> list:
    """Host-side diagnostic: one human-readable line per broken invariant.

    The slow path -- pulls every leaf to host -- run only after
    :func:`carry_ok` already said the carry is bad, to build the
    watchdog's failure report.  Empty list means the carry is clean.
    """
    out = []
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    for path, leaf in flat:
        x = np.asarray(leaf)
        if np.issubdtype(x.dtype, np.floating) and np.isnan(x).any():
            out.append("%s: %d NaN values"
                       % (jax.tree_util.keystr(path), int(np.isnan(x).sum())))

    def check(name, cond, what):
        x = np.asarray(getattr(state, name))
        bad = ~cond(x)
        if bad.any():
            out.append("%s: %d values %s" % (name, int(bad.sum()), what))

    check("U", np.isfinite, "not finite")
    check("pf_avg", lambda x: np.isfinite(x) & (x >= 0),
          "not finite and non-negative")
    check("harq_bits", lambda x: np.isfinite(x) & (x >= 0),
          "not finite and non-negative")
    check("backlog", lambda x: ~np.isnan(x) & (x >= 0), "negative or NaN")
    check("t", lambda x: x >= 0, "negative")
    return out
