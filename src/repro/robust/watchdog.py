"""Watchdog policy: the twin server's recovery contract, as data.

Recovery behaviour belongs in a config pytree, not scattered constants:
the same :class:`WatchdogConfig` that a production twin runs with is what
the chaos drill (``repro.robust.chaos``) and the kill-mid-chunk tests
shrink for CI.  ``twin.server.TwinServer`` consumes it as the policy of
its guarded serving loop (DESIGN.md §Fault-injection-and-self-healing):

1. run one chunk (optionally under :func:`run_with_timeout`);
2. check the carry with ``robust.guard.carry_ok``;
3. on success, auto-checkpoint every ``ckpt_every_chunks`` chunks;
4. on *any* failure -- :class:`ChunkTimeout`, :class:`GuardViolation`,
   or a raised exception from the compiled chunk -- degrade the
   incremental backend if one is armed (``pallas -> xla``), roll back to
   the newest checkpoint that still validates
   (``train.checkpoint.restore_latest_valid``), sleep an exponentially
   backed-off delay, and retry;
5. after ``max_retries`` failed recoveries, stop gracefully with
   :class:`TwinServerDown` carrying the full failure history.

Rollback + the absolute-TTI PRNG folds mean a successful retry resumes
*bitwise* on the uninterrupted trajectory -- recovery never perturbs the
twin, it only re-runs lost work.
"""
from __future__ import annotations

import threading
from typing import NamedTuple, Optional


class WatchdogConfig(NamedTuple):
    """Recovery policy of a guarded :class:`~repro.twin.server.TwinServer`.

    ``max_retries`` bounds *consecutive* failed chunks: each successful
    chunk resets the budget.  ``backoff_s`` is the sleep before the first
    retry, multiplied by ``backoff_factor`` per subsequent attempt.
    ``chunk_timeout_s`` arms the wall-clock watchdog on each chunk (None
    = never time out).  ``ckpt_every_chunks`` is the auto-checkpoint
    cadence -- also the maximum work a rollback can lose.
    """

    max_retries: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    chunk_timeout_s: Optional[float] = None
    ckpt_every_chunks: int = 1


class TwinFault(RuntimeError):
    """Base of the recoverable per-chunk failures the watchdog handles."""


class ChunkTimeout(TwinFault):
    """A chunk exceeded ``WatchdogConfig.chunk_timeout_s`` wall-clock."""


class GuardViolation(TwinFault):
    """The post-chunk carry failed ``robust.guard.carry_ok``."""


class TwinServerDown(RuntimeError):
    """Terminal: recovery exhausted ``max_retries`` consecutive attempts.

    ``history`` is the chronological list of failure lines (one per
    failed attempt, including backend degradations and rollback targets)
    -- the diagnostic a graceful stop hands to the operator.
    """

    def __init__(self, message: str, history=None):
        super().__init__(message)
        self.history = list(history or [])

    def __str__(self):
        base = super().__str__()
        if not self.history:
            return base
        return base + "\nfailure history:\n" + "\n".join(
            "  " + line for line in self.history)


def run_with_timeout(fn, timeout_s: Optional[float]):
    """Run ``fn()``; raise :class:`ChunkTimeout` after ``timeout_s``.

    Thread-based: the work runs on a daemon worker joined with a timeout.
    A timed-out computation cannot be killed (XLA holds the GIL-released
    device work), so the worker is *abandoned* -- it finishes (or hangs)
    in the background while the watchdog proceeds to rollback.  That is
    the right trade for a serving loop: the rolled-back state is rebuilt
    from checkpointed host arrays, never from the abandoned attempt's
    donated buffers.  ``timeout_s=None`` calls ``fn`` inline (zero
    overhead, no extra thread).
    """
    if timeout_s is None:
        return fn()
    box = {}

    def _worker():
        try:
            box["value"] = fn()
        except BaseException as e:          # propagate to the caller thread
            box["error"] = e

    th = threading.Thread(target=_worker, daemon=True)
    th.start()
    th.join(timeout_s)
    if th.is_alive():
        raise ChunkTimeout(f"chunk exceeded {timeout_s:g}s wall-clock")
    if "error" in box:
        raise box["error"]
    return box["value"]
