"""Analytic FLOP/byte model for the roofline compute & memory terms.

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts a ``while`` loop body
ONCE, not times its trip count (verified experimentally -- see EXPERIMENTS.md
§Dry-run "loop-body caveat"); every production model here iterates layers
with ``lax.scan`` and attention with inner scans, so raw cost_analysis
undercounts by ~L x chunks.  We therefore compute executed FLOPs/bytes from
exact per-layer formulas that mirror the code in repro/models (every matmul
term accounted), and keep raw cost_analysis numbers in the artifact for
reference.  Collective traffic and peak memory ARE taken from the compiled
artifact (hlo.py applies trip-count multipliers to collectives).

Conventions: a matmul (m, k) @ (k, n) costs 2mkn FLOPs.  Chunked causal
attention computes every (q-block, kv-block) pair (masked), so the core cost
is the FULL T x S rectangle -- the known 2x overcompute is charged honestly
and is itself a hillclimb item.
"""
from __future__ import annotations

import math

from repro.models.config import ModelConfig
from repro.models.moe import expert_capacity
from repro.models.registry import SHAPES


# -- per-layer forward FLOPs ---------------------------------------------------
def attn_flops(cfg, T, S_ctx, *, d_in=None):
    d = d_in or cfg.d_model
    h, hd, kv = cfg.n_heads, cfg.head_dim, cfg.n_kv_heads
    qkv = 2 * T * d * (h * hd) + 2 * (2 * T * d * (kv * hd))
    core = 2 * T * S_ctx * h * hd * 2          # QK^T and PV
    out = 2 * T * (h * hd) * d
    return qkv + core + out


def mlp_flops(cfg, T, ff=None):
    return 3 * (2 * T * cfg.d_model * (ff or cfg.d_ff))


def moe_flops(cfg, T, seq_len):
    router = 2 * T * cfg.d_model * cfg.n_experts
    cap = expert_capacity(cfg, seq_len)
    batch_rows = max(1, T // seq_len)
    routed_tokens = batch_rows * cfg.n_experts * cap   # capacity-padded
    experts = 3 * (2 * routed_tokens * cfg.d_model * cfg.d_ff)
    shared = (mlp_flops(cfg, T, cfg.n_shared_experts * cfg.d_ff)
              if cfg.n_shared_experts else 0)
    return router + experts + shared


def mamba1_flops(cfg, T):
    d, din, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    k = cfg.ssm_conv
    proj = 2 * T * d * (2 * din)
    conv = 2 * T * din * k
    xproj = 2 * T * din * (r + 2 * n)
    dt = 2 * T * r * din
    scan = 4 * math.log2(max(cfg.ssm_chunk, 2)) * T * din * n \
        + 10 * T * din * n
    y = 2 * T * din * n
    out = 2 * T * din * d
    return proj + conv + xproj + dt + scan + y + out


def mamba2_flops(cfg, T):
    d, din, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    proj = 2 * T * d * (2 * din)
    conv = 2 * T * din * cfg.ssm_conv
    bc = 2 * (2 * T * d * n)
    dt = 2 * T * d * H
    if getattr(cfg, "ssm_impl", "scan") == "ssd":
        # dual form: (QxQ) score matmul + masked-decay combine + M@x
        # matmul + state update / inter-chunk einsums
        core = (2 * T * Q * n                 # C.B^T scores
                + 3 * T * Q * H               # decay/mask combine
                + 2 * T * Q * H * Pd          # M @ x
                + 6 * T * H * Pd * n)         # state update + inter + D
    else:
        core = (4 * math.log2(max(Q, 2)) * T * H * Pd * n
                + 10 * T * H * Pd * n + 2 * T * H * Pd * n)
    out = 2 * T * din * d
    return proj + conv + bc + dt + core + out


def shared_block_flops(cfg, T, S_ctx):
    inproj = 2 * T * (2 * cfg.d_model) * cfg.d_model
    return inproj + attn_flops(cfg, T, S_ctx) + mlp_flops(cfg, T)


def head_flops(cfg, T):
    return 2 * T * cfg.d_model * cfg.vocab_size


# -- whole-step forward FLOPs -----------------------------------------------------
def fwd_flops(cfg: ModelConfig, T: int, S_ctx: int, *, with_head_tokens=None):
    """One forward pass over T tokens with context length S_ctx."""
    L = cfg.n_layers
    if cfg.family in ("dense", "vlm"):
        per = attn_flops(cfg, T, S_ctx) + mlp_flops(cfg, T)
        total = L * per
        if cfg.family == "vlm":
            total += 2 * T * cfg.d_model * cfg.d_model  # adapter
    elif cfg.family == "moe":
        total = L * (attn_flops(cfg, T, S_ctx) + moe_flops(cfg, T, S_ctx))
    elif cfg.family == "ssm":
        f = mamba1_flops if cfg.ssm_variant == "mamba1" else mamba2_flops
        total = L * f(cfg, T)
    elif cfg.family == "hybrid":
        groups = -(-L // cfg.hybrid_attn_every)
        total = L * mamba2_flops(cfg, T) \
            + groups * shared_block_flops(cfg, T, S_ctx)
    elif cfg.family == "encdec":
        enc = cfg.n_encoder_layers * (attn_flops(cfg, T, S_ctx)
                                      + mlp_flops(cfg, T))
        # decoder: self attention + cross attention (ctx = encoder length)
        dec = cfg.n_layers * (attn_flops(cfg, T, S_ctx)
                              + attn_flops(cfg, T, S_ctx)
                              + mlp_flops(cfg, T))
        total = enc + dec
    else:
        raise ValueError(cfg.family)
    head_T = with_head_tokens if with_head_tokens is not None else T
    return total + head_flops(cfg, head_T)


def step_flops(cfg: ModelConfig, shape_name: str) -> dict:
    """Executed-FLOPs estimate for the dry-run cell (global, all chips)."""
    sh = SHAPES[shape_name]
    S, B = sh["seq_len"], sh["global_batch"]
    if sh["kind"] == "train":
        f = fwd_flops(cfg, B * S, S)
        # bwd = 2x fwd; two-level remat recomputes fwd twice (group +
        # per-layer checkpoints -- see transformer.scan_layers_remat)
        mult = 5.0 if cfg.remat else 3.0
        return {"fwd": f, "total": mult * f}
    if sh["kind"] == "prefill":
        f = fwd_flops(cfg, B * S, S, with_head_tokens=B)  # head on last tok
        return {"fwd": f, "total": f}
    # decode: T = B tokens, context = S
    f = fwd_flops(cfg, B, S)
    return {"fwd": f, "total": f}


# -- whole-step HBM bytes (napkin, documented) -------------------------------------
def step_bytes(cfg: ModelConfig, shape_name: str, n_params: float) -> dict:
    """HBM traffic estimate (global).  Terms:

    params: train = fwd read + bwd read + remat read (4B f32 each) + grad
            write (4B) + adafactor rw (~9B) ~= 25 B/param;
            serve = one read of every param (4B f32 as stored).
    acts:   K_rw passes of (T x d_model) bf16 per layer; K_rw = 12 train
            (write+read of ~3 fused groups, fwd+bwd), 4 serve.
    cache:  decode reads the whole KV/SSM cache once + writes one row;
            prefill writes it once.
    logits: chunked CE: write+read f32 chunks, ~3 passes train, 1 serve.
    """
    sh = SHAPES[shape_name]
    S, B = sh["seq_len"], sh["global_batch"]
    T = B * (1 if sh["kind"] == "decode" else S)
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size

    if sh["kind"] == "train":
        params = 25.0 * n_params
        acts = 12.0 * L * T * d * 2.0
        logits = 3.0 * T * V * 4.0  # chunked: full traffic, one chunk live
        cache = 0.0
    else:
        params = 4.0 * n_params
        acts = 4.0 * L * T * d * 2.0
        logits = 1.0 * (B * V * 4.0)
        if sh["kind"] == "decode":
            if cfg.family in ("dense", "moe", "vlm", "encdec"):
                kvh, hd = cfg.n_kv_heads, cfg.head_dim
                cache = L * B * S * kvh * hd * 2 * 2.0  # read k+v bf16
            elif cfg.family == "ssm":
                n_state = cfg.ssm_state
                cache = L * B * cfg.d_inner * n_state * 4.0 * 2
            else:  # hybrid
                groups = -(-L // cfg.hybrid_attn_every)
                cache = (groups * B * S * cfg.n_kv_heads * cfg.head_dim
                         * 2 * 2.0
                         + L * B * cfg.ssm_heads * cfg.ssm_head_dim
                         * cfg.ssm_state * 4.0 * 2)
        else:  # prefill writes the cache once
            if cfg.family in ("dense", "moe", "vlm", "encdec"):
                cache = L * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2.0
            else:
                cache = L * B * d * 4.0
    total = params + acts + logits + cache
    return {"params": params, "acts": acts, "logits": logits,
            "cache": cache, "total": total}
