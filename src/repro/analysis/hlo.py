"""HLO text analysis: collective-traffic extraction from compiled modules.

``compiled.as_text()`` is the post-SPMD-partitioning module, so every
cross-device transfer appears as an explicit collective op.  We parse each
op's result/operand shapes and replica groups and convert to *per-device
bytes on the wire* using ring-algorithm costs:

    all-reduce        2 * B * (n-1)/n
    all-gather        B * (n-1)/n          (B = result bytes)
    reduce-scatter    B_in * (n-1)/n       (B_in = operand bytes)
    all-to-all        B * (n-1)/n
    collective-permute B
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array shapes inside a type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dtype])
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    total_wire_bytes: float   # per-device bytes on the wire

    def __str__(self):
        parts = [f"{k}:{v} ({self.bytes_by_kind[k]/1e6:.1f} MB)"
                 for k, v in sorted(self.counts.items())]
        return (f"collectives[{', '.join(parts)}] "
                f"total {self.total_wire_bytes/1e9:.3f} GB/device")


def _group_size(line: str, default_n: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default_n


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?(?:condition=%?([\w.\-]+))[^\n]*?(?:body=%?([\w.\-]+))"
    r"|while\(.*?\)[^\n]*?(?:body=%?([\w.\-]+))[^\n]*?(?:condition=%?([\w.\-]+))")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def _computation_multipliers(hlo_text: str) -> dict:
    """Trip-count multiplier per computation: a collective inside a scan
    body executes (trip count) times, nested loops multiply.  XLA's own
    cost analysis counts loop bodies once (EXPERIMENTS.md caveat); this is
    the correction for collectives."""
    comp = None
    comp_lines: dict = {}
    whiles = []  # (parent_comp, cond, body)
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            comp = m.group(1)
            comp_lines.setdefault(comp, [])
            continue
        if comp is not None:
            comp_lines[comp].append(line)
        if "while(" in line and ("body=" in line or "condition=" in line):
            mc = re.search(r"condition=%?([\w.\-]+)", line)
            mb = re.search(r"body=%?([\w.\-]+)", line)
            if mc and mb and comp:
                whiles.append((comp, mc.group(1), mb.group(1)))

    def trips(cond_name: str) -> int:
        consts = []
        for line in comp_lines.get(cond_name, []):
            consts += [int(x) for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    mult = {name: 1.0 for name in comp_lines}
    # fixpoint propagation (nested whiles)
    for _ in range(8):
        changed = False
        for parent, cond, body in whiles:
            new = mult.get(parent, 1.0) * max(1, trips(cond))
            if body in mult and mult[body] != new:
                mult[body] = new
                changed = True
            elif body not in mult:
                mult[body] = new
                changed = True
        if not changed:
            break
    return mult


def collective_stats(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    counts: dict = {}
    by_kind: dict = {}
    total = 0.0
    mult = _computation_multipliers(hlo_text)
    comp = None
    for line in hlo_text.splitlines():
        mcomp = _COMP_RE.match(line)
        if mcomp and line.rstrip().endswith("{"):
            comp = mcomp.group(1)
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # counted at -start
        type_str, kind = m.group(1), m.group(2)
        k_mult = mult.get(comp, 1.0)
        n = _group_size(line, default_group)
        b_result = shape_bytes(type_str)
        # first operand type for reduce-scatter input volume
        if kind == "reduce-scatter":
            inner = line.split("(", 1)[1]
            b_in = shape_bytes(inner.split(")")[0]) or b_result * n
            wire = b_in * (n - 1) / max(n, 1)
        elif kind == "all-reduce":
            wire = 2.0 * b_result * (n - 1) / max(n, 1)
        elif kind == "collective-permute":
            wire = float(b_result)
        else:  # all-gather, all-to-all
            wire = b_result * (n - 1) / max(n, 1)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0.0) + wire * k_mult
        total += wire * k_mult
    return CollectiveStats(counts, by_kind, total)
