"""Roofline model: the three-term analysis over dry-run artifacts.

Hardware constants (TPU v5e-class target, per assignment):
    peak bf16 compute   197 TFLOP/s / chip
    HBM bandwidth       819 GB/s / chip
    ICI link bandwidth  ~50 GB/s / link

    compute term    = HLO_FLOPs / (chips * peak)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = wire_bytes_per_device / link_bw
                      (wire bytes are already per-device -- see hlo.py)

The dominant term is the projected step time's lower bound; the roofline
fraction we report for the hillclimb is useful_model_flops / (dominant_term *
chips * peak).  Run as a module to print the table from artifacts/dryrun:

    PYTHONPATH=src python -m repro.analysis.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOPs over what the chips could do in the bound
        time -- the score we hillclimb."""
        cap = self.bound_s * self.chips * PEAK_FLOPS
        return self.model_flops / cap if cap > 0 else 0.0


def model_flops_train(n_params_active: float, tokens: float) -> float:
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: float, tokens: float) -> float:
    return 2.0 * n_params_active * tokens


def from_artifact(art: dict) -> Roofline:
    """Prefers the analytic executed-FLOPs/bytes model (exact per-layer
    formulas; XLA cost_analysis counts loop bodies once -- see
    analysis/flops.py) and falls back to raw cost_analysis numbers."""
    chips = art["n_devices"]
    flops = art.get("analytic_flops") or art["hlo_flops"]
    bytes_ = art.get("analytic_bytes") or art["hlo_bytes"]
    return Roofline(
        compute_s=flops / (chips * PEAK_FLOPS),
        memory_s=bytes_ / (chips * HBM_BW),
        collective_s=art["collective_wire_bytes"] / ICI_BW,
        model_flops=art["model_flops"],
        hlo_flops=flops,
        chips=chips,
    )


def format_row(name: str, art: dict) -> str:
    r = from_artifact(art)
    return (f"| {name} | {r.compute_s*1e3:.1f} | {r.memory_s*1e3:.1f} | "
            f"{r.collective_s*1e3:.1f} | {r.dominant} | "
            f"{r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} |")


def main(art_dir: str = "artifacts/dryrun"):
    print("| cell | compute ms | memory ms | collective ms | dominant | "
          "useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|")
    for root, _, files in sorted(os.walk(art_dir)):
        for f in sorted(files):
            if not f.endswith(".json"):
                continue
            with open(os.path.join(root, f)) as fh:
                art = json.load(fh)
            if art.get("skipped"):
                name = os.path.relpath(os.path.join(root, f), art_dir)
                print(f"| {name} | - | - | - | skipped: "
                      f"{art['reason'][:40]} | - | - |")
                continue
            name = os.path.relpath(os.path.join(root, f),
                                   art_dir).replace(".json", "")
            print(format_row(name, art))


if __name__ == "__main__":
    import sys
    main(sys.argv[sys.argv.index("--dir") + 1]
         if "--dir" in sys.argv else "artifacts/dryrun")
