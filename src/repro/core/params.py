"""CRRM_parameters -- the single configuration object for a simulation.

Mirrors the paper's ``CRRM_parameters`` class: the pathloss model is selected
by *name* (strategy pattern); the main simulator binds the corresponding
``get_pathgain`` to a generic ``pathgain_function`` callable at init.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

BOLTZMANN = 1.380649e-23
T0_KELVIN = 290.0


def thermal_noise_W(bandwidth_hz: float, noise_figure_dB: float = 9.0) -> float:
    """kTB thermal noise power + UE noise figure, in watts."""
    return BOLTZMANN * T0_KELVIN * bandwidth_hz * 10 ** (noise_figure_dB / 10)


@dataclasses.dataclass
class CRRM_parameters:
    # topology -----------------------------------------------------------------
    n_ues: int = 100
    n_cells: Optional[int] = None          # derived from cell_positions if None
    ue_positions: Optional[Any] = None     # (n_ues, 3); random uniform if None
    cell_positions: Optional[Any] = None   # (n_cells, 3); hex grid if None
    extent_m: float = 3000.0               # square deployment region side
    h_ut_m: float = 1.5                    # default UE height
    h_bs_m: float = 25.0                   # default BS height (z of generated cells)

    # radio ----------------------------------------------------------------------
    pathloss_model_name: str = "UMa"       # key into sim.pathloss.PATHLOSS_MODELS
    pathloss_params: dict = dataclasses.field(default_factory=dict)
    fc_GHz: float = 3.5
    bandwidth_Hz: float = 20e6
    n_subbands: int = 1
    power_W: float = 1.0                   # per-cell tx power if power_matrix None
    power_matrix: Optional[Any] = None     # (n_cells, n_subbands) watts
    noise_power_W: Optional[float] = None  # sigma^2 over full band; kTB if None
    rayleigh_fading: bool = False
    #: associate on long-term (unfaded) RSRP -- what real cells do, and what
    #: the PPP analytic SIR result assumes (association ignores fast fading)
    attach_ignores_fading: bool = True

    # antennas ---------------------------------------------------------------------
    n_sectors: int = 1                     # 1 = omni, 3 = 3GPP tri-sector
    antenna_phi_3dB_deg: float = 65.0
    antenna_A_max_dB: float = 30.0

    # MAC / scheduling ----------------------------------------------------------------
    fairness_p: float = 0.0                # T_i = a * S_i^(1-p)
    n_tx: int = 1
    n_rx: int = 1
    #: mac.traffic.TRAFFIC_MODELS: "full_buffer" | "poisson" | "ftp3"
    traffic_model: str = "full_buffer"
    traffic_params: dict = dataclasses.field(default_factory=dict)
    #: mac.scheduler.SCHEDULER_POLICIES: "pf" | "rr" | "max_cqi"
    scheduler_policy: str = "pf"
    n_rb: int = 12                         # resource blocks per subband per TTI
    tti_s: float = 1e-3                    # TTI duration (1 ms numerology-0 slot)
    pf_ewma: float = 0.05                  # EWMA step of the PF average-rate state
    #: frequency-selective link adaptation: the ``n_rb`` RBs of each subband
    #: are split into this many CQI-reporting subbands, each scheduled
    #: independently (must divide ``n_rb``).  1 = wideband CQI (the legacy
    #: flat-fading chain); ``n_rb`` = fully per-RB link adaptation.
    n_rb_subbands: int = 1
    #: coherence bandwidth of the block-fading channel, in RBs: RBs within
    #: one coherence block share a Rayleigh draw (sim.fading)
    coherence_rb: int = 4
    #: CQI *reporting* resolution, decoupled from the fading resolution:
    #: "subband" reports one CQI per scheduling chunk (the legacy coupling,
    #: full frequency-selective link adaptation); "wideband" pools each
    #: power subband's ``n_rb_subbands`` chunks into one effective-SINR
    #: report, so the channel stays selective but MCS selection -- and the
    #: schedulers' frequency opportunism -- collapse to per-subband
    #: granularity.  A no-op at ``n_rb_subbands=1`` (tested).
    cqi_report: str = "subband"
    #: EESM calibration factor (linear SINR units) for wideband CQI
    #: pooling: gamma_eff = -beta * log(mean exp(-gamma/beta)).  Smaller =
    #: more pessimistic (worst-chunk dominated); per-MCS calibration is
    #: collapsed to one constant.
    cqi_eesm_beta: float = 1.0
    #: P(transport block lost) on the first HARQ attempt.  0 disables HARQ
    #: entirely (the engine compiles the HARQ-free fast path).
    harq_bler: float = 0.0
    #: stop-and-wait HARQ: max retransmissions per transport block before it
    #: is dropped (0 = no retx, plain Bernoulli thinning)
    harq_max_retx: int = 3
    #: soft-combining (Chase) SINR gain per retransmission, in dB.  In the
    #: Rayleigh outage regime P(fail) ~ theta/SNR, so each retx divides the
    #: conditional BLER by ``10^(gain/10)`` -- delivery probability is
    #: monotone in the retx count (tested).
    harq_comb_gain_db: float = 3.0
    #: baked-in mobility trajectory: per-TTI random-walk step bound in
    #: metres for *every* UE inside the episode engine (scenario presets
    #: with mobility, e.g. ``dense_urban_mobile``).  ``None``/``0`` = static
    #: geometry; an explicit ``mobility_step_m`` argument to
    #: ``run_episode``/``episode_fns`` overrides it (``0`` forces static).
    mobility_step_m: Optional[float] = None
    #: fraction of UEs taking a mobility step each TTI (the digital-twin
    #: regime: huge mostly-static UE fields where only a few move).  The
    #: engine selects exactly ``round(frac * n_ues)`` movers per TTI
    #: (``sim.mobility.window_movers``); ``None``/``1.0`` moves every UE
    #: (the legacy walk).  This is also the dirty-row budget of
    #: ``radio_mode="incremental"``.
    mobility_move_frac: Optional[float] = None
    #: execution mode of the radio chain inside the episode engine:
    #: "dense" recomputes the full D..SE chain whenever the channel is
    #: dynamic (legacy); "incremental" carries a ``radio.RadioState`` in
    #: the scan and recomputes only dirty UE rows (the paper's smart
    #: update, inside the compiled TTI engine -- DESIGN.md
    #: §Smart-update-in-scan).  Equivalent within 1e-5 (bit-exact in the
    #: non-handover regimes); incompatible with per-TTI fading.
    radio_mode: str = "dense"
    #: in-scan cell fault process (a ``sim.faults.FaultConfig``): each cell
    #: walks a per-TTI Markov outage/sleep chain inside the episode engine,
    #: masking its tx power while DOWN/SLEEPing (DESIGN.md
    #: §Fault-injection-and-self-healing).  ``None`` = no faults (the exact
    #: legacy program); an explicit ``faults`` argument to
    #: ``episode_fns``/``run_episode`` overrides it (``0`` forces off).
    faults: Optional[Any] = None
    #: A3-style handover inside the episode engine.  Disabled (False), the
    #: serving cell is the instantaneous strongest cell, recomputed per TTI
    #: when the channel is dynamic -- the legacy PR-1 behaviour.
    ho_enabled: bool = False
    ho_hysteresis_db: float = 3.0          # A3 entry margin over serving RSRP
    ho_ttt_tti: int = 4                    # time-to-trigger, in TTIs

    # engine -------------------------------------------------------------------------
    smart: bool = True                     # the compute-on-demand switch
    max_moves: Optional[int] = None        # cap on dirty-row bucket (None = n_ues)
    seed: int = 0
    dtype: Any = np.float32

    def __post_init__(self):
        if self.n_subbands < 1:
            raise ValueError("n_subbands must be >= 1")
        if not 0.0 <= self.fairness_p <= 1.0:
            raise ValueError("fairness_p must be in [0, 1]")
        from repro.mac.scheduler import SCHEDULER_POLICIES
        from repro.mac.traffic import TRAFFIC_MODELS
        if self.traffic_model not in TRAFFIC_MODELS:
            raise ValueError(f"traffic_model must be one of {TRAFFIC_MODELS}")
        if self.scheduler_policy not in SCHEDULER_POLICIES:
            raise ValueError(
                f"scheduler_policy must be one of {SCHEDULER_POLICIES}")
        if self.n_rb < 1:
            raise ValueError("n_rb must be >= 1")
        if not 0.0 < self.pf_ewma <= 1.0:
            raise ValueError("pf_ewma must be in (0, 1]")
        if not 0.0 <= self.harq_bler < 1.0:
            raise ValueError("harq_bler must be in [0, 1)")
        if self.n_rb_subbands < 1 or self.n_rb % self.n_rb_subbands:
            raise ValueError(
                f"n_rb_subbands must be a positive divisor of n_rb="
                f"{self.n_rb}; got {self.n_rb_subbands}")
        if self.coherence_rb < 1:
            raise ValueError("coherence_rb must be >= 1")
        if self.cqi_report not in ("subband", "wideband"):
            raise ValueError(
                f"cqi_report must be 'subband' or 'wideband'; "
                f"got {self.cqi_report!r}")
        if self.cqi_eesm_beta <= 0.0:
            raise ValueError("cqi_eesm_beta must be > 0")
        if self.harq_max_retx < 0:
            raise ValueError("harq_max_retx must be >= 0")
        if self.harq_comb_gain_db < 0.0:
            raise ValueError("harq_comb_gain_db must be >= 0")
        if self.mobility_step_m is not None and self.mobility_step_m < 0.0:
            raise ValueError("mobility_step_m must be >= 0 (or None)")
        if self.mobility_move_frac is not None and not (
                0.0 < self.mobility_move_frac <= 1.0):
            raise ValueError("mobility_move_frac must be in (0, 1] (or None)")
        if self.radio_mode not in ("dense", "incremental"):
            raise ValueError(
                f"radio_mode must be 'dense' or 'incremental'; "
                f"got {self.radio_mode!r}")
        if self.faults is not None:
            from repro.sim.faults import FaultConfig
            if not isinstance(self.faults, FaultConfig):
                raise ValueError(
                    f"faults must be a sim.faults.FaultConfig (or None); "
                    f"got {type(self.faults).__name__}")
            f = self.faults
            if f.outage_rate_hz < 0.0 or f.sleep_rate_hz < 0.0:
                raise ValueError("fault rates must be >= 0")
            if f.mean_outage_s <= 0.0 or f.mean_sleep_s <= 0.0:
                raise ValueError("fault dwell means must be > 0")
            for p in (f.outage_rate_hz * self.tti_s,
                      f.sleep_rate_hz * self.tti_s,
                      self.tti_s / f.mean_outage_s,
                      self.tti_s / f.mean_sleep_s):
                if p > 1.0:
                    raise ValueError(
                        "fault transition probability exceeds 1 per TTI: "
                        "lower the rate or raise the dwell mean "
                        f"(tti_s={self.tti_s})")
        if self.ho_hysteresis_db < 0.0:
            raise ValueError("ho_hysteresis_db must be >= 0")
        if self.ho_ttt_tti < 1:
            raise ValueError("ho_ttt_tti must be >= 1")
        if self.power_matrix is not None:
            pm = np.asarray(self.power_matrix)
            if pm.ndim != 2 or pm.shape[1] != self.n_subbands:
                raise ValueError(
                    f"power_matrix must be (n_cells, n_subbands); got {pm.shape}")
            if self.n_cells is None:
                self.n_cells = pm.shape[0]
        if self.cell_positions is not None:
            cp = np.asarray(self.cell_positions)
            if self.n_cells is None:
                self.n_cells = cp.shape[0]
            elif self.n_cells != cp.shape[0]:
                raise ValueError("n_cells inconsistent with cell_positions")
        if self.noise_power_W is None:
            self.noise_power_W = thermal_noise_W(self.bandwidth_Hz)

    @property
    def subband_bandwidth_Hz(self) -> float:
        return self.bandwidth_Hz / self.n_subbands

    @property
    def subband_noise_W(self) -> float:
        return self.noise_power_W / self.n_subbands

    # -- frequency-selective link-adaptation grid ------------------------------
    @property
    def n_freq(self) -> int:
        """Scheduling-frequency chunks: subbands x CQI subbands per subband.

        This is the trailing axis of every per-frequency tensor in the graph
        and the engine (SE, CQI, alloc, ...); ``n_rb_subbands=1`` collapses
        it to the legacy ``n_subbands`` axis.
        """
        return self.n_subbands * self.n_rb_subbands

    @property
    def rb_per_chunk(self) -> int:
        """Resource blocks owned by one scheduling-frequency chunk."""
        return self.n_rb // self.n_rb_subbands

    @property
    def chunk_bandwidth_Hz(self) -> float:
        return self.bandwidth_Hz / self.n_freq

    @property
    def chunk_noise_W(self) -> float:
        return self.noise_power_W / self.n_freq
