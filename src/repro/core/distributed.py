"""Distributed CRRM: the paper's engine sharded over a TPU mesh.

Two implementations (both shard UEs over the ``data`` mesh axes and cells over
``model``):

* :func:`make_materialized_step` -- paper-faithful: every Figure-1 block is
  materialised as a sharded matrix; interference and attachment reduce over
  the ``model`` axis with ``psum`` / ``all_gather``.  Memory O(N_loc x M_loc).

* :func:`make_streaming_step` -- TPU-native beyond-paper form: cell tiles are
  streamed through a ``lax.scan`` and per-UE interference / best-server state
  is accumulated online (flash-attention style), so no N x M intermediate ever
  exists.  Memory O(N_loc + M_loc).  This is the jnp twin of the
  ``kernels/fused_sinr`` Pallas kernel.

* :func:`make_incremental_rows_step` -- the smart update at scale: recompute
  only the moved UE rows (streaming over all cells) and patch the persistent
  O(N) state (w, u, a).  Cost O(m x M) instead of O(N x M).

All functions are mesh-agnostic: pass the relevant UE/cell axis names, which
may be tuples (e.g. UE axis ("pod", "data") on the multi-pod mesh).

The scan engine's UE x cell episode mesh (``episode_fns(cell_axis=...)``,
DESIGN.md §Million-UE-scaling) reuses :func:`_global_best` and
:func:`_axis_index` for its cross-cell-shard attachment and owning-shard
serving-row gathers, so the tie-break contract (lowest global cell index,
bitwise-equal to single-host ``jnp.argmax``) is defined once, here.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sim import phy

# shard_map was promoted out of jax.experimental and pvary introduced in
# newer jax; alias both so the module runs on the container's pinned version
# (where shard_map carries need no device-varying typing -- pvary is a no-op).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)
#: axis_size appeared alongside pvary; psum of 1 is the portable equivalent
_axis_size = getattr(jax.lax, "axis_size", lambda ax: jax.lax.psum(1, ax))


def _axis_index(axes) -> jnp.ndarray:
    """Linearised shard index over one or more mesh axes (row-major)."""
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * _axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def _pad_cells(C_loc, P_loc, tile: int):
    """Pad the local cell block to a tile multiple with zero-power cells."""
    m_loc = C_loc.shape[0]
    pad = (-m_loc) % tile
    if pad:
        C_loc = jnp.concatenate(
            [C_loc, jnp.full((pad, 3), 1e9, C_loc.dtype)], axis=0)
        P_loc = jnp.concatenate(
            [P_loc, jnp.zeros((pad, P_loc.shape[1]), P_loc.dtype)], axis=0)
    return C_loc, P_loc



def _global_best(loc_max, loc_arg, m_loc, cell_axis):
    """Combine per-cell-shard (max, argmax) into the global best server.

    Tie-break matches single-host jnp.argmax: lowest global cell index wins.
    Uses pmax/pmin/psum (replication-inferable) rather than all_gather.
    Returns (global_max, global_arg, mine) where ``mine`` marks rows whose
    winning cell lives on this shard.
    """
    gmax = jax.lax.pmax(loc_max, cell_axis)
    my = _axis_index(cell_axis)
    cand = jnp.where(loc_max >= gmax, my, jnp.int32(2 ** 30))
    win_shard = jax.lax.pmin(cand, cell_axis)
    mine = win_shard == my
    a = jax.lax.psum(
        jnp.where(mine, loc_arg + my * m_loc, 0).astype(jnp.int32), cell_axis)
    return gmax, a, mine


def _geometry(U, C):
    dx = U[:, None, 0] - C[None, :, 0]
    dy = U[:, None, 1] - C[None, :, 1]
    dz = U[:, None, 2] - C[None, :, 2]
    d2d = jnp.sqrt(dx * dx + dy * dy)
    d3d = jnp.sqrt(d2d * d2d + dz * dz)
    return d2d, d3d


def _throughput(se, a, n_cells, subband_bw, p, ue_axis):
    """Fairness allocation with cell loads reduced across UE shards."""
    active = se > 0.0
    wgt = jnp.where(active, jnp.power(jnp.maximum(se, 1e-12), -p), 0.0)
    denom = jnp.zeros((n_cells, se.shape[1]), se.dtype).at[a].add(wgt)
    denom = jax.lax.psum(denom, ue_axis)          # cell loads: global over UEs
    denom_i = denom[a]
    share = jnp.where(denom_i > 0.0, wgt / jnp.maximum(denom_i, 1e-30), 0.0)
    return share * subband_bw * se


def make_materialized_step(mesh, pathgain_fn: Callable, noise_w: float,
                           n_cells: int, subband_bw: float, fairness_p: float,
                           ue_axis=("data",), cell_axis=("model",)):
    """Paper-faithful distributed pipeline; returns jit-able f(U, C, Pw)."""
    ue_axis = tuple(ue_axis)
    cell_axis = tuple(cell_axis)

    def step(U_loc, C_loc, P_loc):
        # U_loc: (n_ue_loc, 3)  C_loc: (m_loc, 3)  P_loc: (m_loc, K)
        m_loc = C_loc.shape[0]
        d2d, d3d = _geometry(U_loc, C_loc)
        g = pathgain_fn(d2d, d3d, C_loc[None, :, 2], U_loc[:, None, 2])
        r = g[:, :, None] * P_loc[None, :, :]          # local RSRP block
        total = jax.lax.psum(r.sum(axis=1), cell_axis)  # (n_ue_loc, K)

        # global best server: per-shard (max, argmax) combined collectively
        wide = r.sum(axis=2)                            # (n_ue_loc, m_loc)
        loc_max = wide.max(axis=1)
        loc_arg = wide.argmax(axis=1).astype(jnp.int32)
        _, a, mine = _global_best(loc_max, loc_arg, m_loc, cell_axis)

        # wanted signal: owning shard contributes, others psum zeros
        my = _axis_index(cell_axis)
        local_col = jnp.clip(a - my * m_loc, 0, m_loc - 1)
        w_loc = jnp.take_along_axis(r, local_col[:, None, None], axis=1)[:, 0, :]
        w = jax.lax.psum(jnp.where(mine[:, None], w_loc, 0.0), cell_axis)

        u = total - w
        gamma = w / (noise_w + u)
        se = phy.spectral_efficiency(gamma)
        tput = _throughput(se, a, n_cells, subband_bw, fairness_p, ue_axis)
        return gamma, a, tput

    in_specs = (P(ue_axis, None), P(cell_axis, None), P(cell_axis, None))
    out_specs = (P(ue_axis, None), P(ue_axis), P(ue_axis, None))
    return _shard_map(step, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs)


def _stream_over_cells(U_loc, C_loc, P_loc, pathgain_fn, tile: int,
                       vary_axes=()):
    """Online accumulation over cell tiles: (total, best_val, best_idx, w_best).

    The running state is O(n_ue_loc); each tile's (n_ue_loc x tile) block
    lives only inside one scan iteration (VMEM-resident on TPU).
    """
    n_loc, k = P_loc.shape[0], P_loc.shape[1]
    n_tiles = max(1, n_loc // tile)
    C_t = C_loc[:n_tiles * tile].reshape(n_tiles, tile, 3)
    P_t = P_loc[:n_tiles * tile].reshape(n_tiles, tile, k)

    def body(carry, xs):
        total, best_val, best_idx, w_best = carry
        (c_tile, p_tile, t) = xs
        d2d, d3d = _geometry(U_loc, c_tile)
        g = pathgain_fn(d2d, d3d, c_tile[None, :, 2], U_loc[:, None, 2])
        r = g[:, :, None] * p_tile[None, :, :]       # (n_ue_loc, tile, K)
        total = total + r.sum(axis=1)
        wide = r.sum(axis=2)                          # (n_ue_loc, tile)
        t_max = wide.max(axis=1)
        t_arg = wide.argmax(axis=1).astype(jnp.int32) + t * tile
        t_w = jnp.take_along_axis(
            r, (t_arg - t * tile)[:, None, None], axis=1)[:, 0, :]
        better = t_max > best_val
        best_val = jnp.where(better, t_max, best_val)
        best_idx = jnp.where(better, t_arg, best_idx)
        w_best = jnp.where(better[:, None], t_w, w_best)
        return (total, best_val, best_idx, w_best), None

    n_ue_loc = U_loc.shape[0]
    init = (jnp.zeros((n_ue_loc, k)),
            jnp.full((n_ue_loc,), -jnp.inf),
            jnp.zeros((n_ue_loc,), jnp.int32),
            jnp.zeros((n_ue_loc, k)))
    if vary_axes:
        # inside shard_map the scan carry must be typed device-varying
        init = jax.tree_util.tree_map(
            lambda x: _pvary(x, tuple(vary_axes)), init)
    (total, best_val, best_idx, w_best), _ = jax.lax.scan(
        body, init, (C_t, P_t, jnp.arange(n_tiles)))
    return total, best_val, best_idx, w_best


def make_streaming_step(mesh, pathgain_fn: Callable, noise_w: float,
                        n_cells: int, subband_bw: float, fairness_p: float,
                        ue_axis=("data",), cell_axis=("model",),
                        cell_tile: int = 512):
    """O(N+M)-memory distributed pipeline (beyond-paper, TPU-native)."""
    ue_axis = tuple(ue_axis)
    cell_axis = tuple(cell_axis)

    def step(U_loc, C_loc, P_loc):
        m_loc = C_loc.shape[0]
        tile = min(cell_tile, m_loc)
        C_pad, P_pad = _pad_cells(C_loc, P_loc, tile)
        total, best_val, best_arg, w_best = _stream_over_cells(
            U_loc, C_pad, P_pad, pathgain_fn, tile, ue_axis + cell_axis)
        total = jax.lax.psum(total, cell_axis)

        _, a, mine = _global_best(best_val, best_arg, m_loc, cell_axis)
        w = jax.lax.psum(jnp.where(mine[:, None], w_best, 0.0), cell_axis)

        u = total - w
        gamma = w / (noise_w + u)
        se = phy.spectral_efficiency(gamma)
        tput = _throughput(se, a, n_cells, subband_bw, fairness_p, ue_axis)
        return gamma, a, tput

    in_specs = (P(ue_axis, None), P(cell_axis, None), P(cell_axis, None))
    out_specs = (P(ue_axis, None), P(ue_axis), P(ue_axis, None))
    return _shard_map(step, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs)


def make_incremental_rows_step(mesh, pathgain_fn: Callable, noise_w: float,
                               n_cells: int, subband_bw: float,
                               fairness_p: float, ue_axis=("data",),
                               cell_axis=("model",), cell_tile: int = 512):
    """Smart update at scale: recompute only moved rows against all cells.

    State (w, u, a, best_val) is O(N); the moved-row block (m x M_loc) streams
    through the same online accumulator.  Moved indices are replicated
    (every shard sees all moves; each patches the rows it owns).

    f(U, C, Pw, w, u, a, best_val, idx, new_pos) -> (U', w', u', a', best_val', tput)
    """
    ue_axis = tuple(ue_axis)
    cell_axis = tuple(cell_axis)

    def step(U_loc, C_loc, P_loc, w, u, a, best_val, idx, new_pos):
        n_ue_loc = U_loc.shape[0]
        m_loc = C_loc.shape[0]
        # which moved UEs live on this UE shard?
        ue_shard = _axis_index(ue_axis)
        lo = ue_shard * n_ue_loc
        local = (idx >= lo) & (idx < lo + n_ue_loc)
        # clamp foreign indices to row 0; mask their writes later
        li = jnp.where(local, idx - lo, 0)
        U_loc = U_loc.at[li].set(
            jnp.where(local[:, None], new_pos, U_loc[li]))

        moved = U_loc[li]                              # (m, 3)
        tile = min(cell_tile, m_loc)
        C_pad, P_pad = _pad_cells(C_loc, P_loc, tile)
        total, bval, barg, w_best = _stream_over_cells(
            moved, C_pad, P_pad, pathgain_fn, tile, ue_axis + cell_axis)
        total = jax.lax.psum(total, cell_axis)
        bv_rows, a_rows, mine = _global_best(bval, barg, m_loc, cell_axis)
        w_rows = jax.lax.psum(
            jnp.where(mine[:, None], w_best, 0.0), cell_axis)
        u_rows = total - w_rows

        # patch only locally owned rows
        def patch(buf, rows_new):
            old = buf[li]
            mask = local.reshape((-1,) + (1,) * (rows_new.ndim - 1))
            return buf.at[li].set(jnp.where(mask, rows_new, old))

        w = patch(w, w_rows)
        u = patch(u, u_rows)
        a = patch(a, a_rows)
        best_val = patch(best_val, bv_rows)

        gamma = w / (noise_w + u)
        se = phy.spectral_efficiency(gamma)
        tput = _throughput(se, a, n_cells, subband_bw, fairness_p, ue_axis)
        return U_loc, w, u, a, best_val, tput

    in_specs = (P(ue_axis, None), P(cell_axis, None), P(cell_axis, None),
                P(ue_axis, None), P(ue_axis, None), P(ue_axis),
                P(ue_axis), P(None), P(None, None))
    out_specs = (P(ue_axis, None), P(ue_axis, None), P(ue_axis, None),
                 P(ue_axis), P(ue_axis), P(ue_axis, None))
    return _shard_map(step, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs)
