"""The CRRM compute-on-demand dependency graph ("smart update").

This module reproduces the paper's ``_Node`` protocol exactly:

* every computational block is a node holding a device array (JAX, where the
  paper holds NumPy);
* ``watchers`` are downstream dependents, ``watchees`` upstream dependencies;
* mutating a root floods ``up_to_date = False`` downstream
  (:meth:`Node.flood_out_of_date`) -- the *invalidation phase*;
* requesting a terminal output walks ``update()`` upstream and recomputes only
  stale nodes -- the *recursive update phase*.

Beyond the boolean flag, nodes track *which UE rows* are dirty (the paper's
Figure-1 "red stripe").  A node that supports row-local recomputation patches
just those rows with one vectorised advanced-indexing operation; nodes whose
outputs are not row-local (e.g. per-cell resource allocation) override
:meth:`Node.propagate_rows` to widen the dirt to ``ALL``.

JAX adaptation (see DESIGN.md §2): XLA needs static shapes, so dirty row index
vectors are padded up to the next power of two with duplicate indices --
row recomputation is idempotent, so duplicated writes are harmless and each
power-of-two bucket compiles exactly once.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# THE dirtiness convention (repeated-valid-index padding, idempotent row
# recompute) lives in repro.sim.radio next to its traced twin
# ``dirty_indices`` -- the scan-compiled incremental path and these graph
# row buckets are two faces of one convention (DESIGN.md
# §Smart-update-in-scan).  Re-exported here for the node machinery.
from repro.sim.radio import pad_indices  # noqa: F401


class _AllRows:
    """Sentinel: every row is dirty (or row tracking is not applicable)."""

    def __repr__(self):  # pragma: no cover
        return "ALL"


ALL = _AllRows()


class Node:
    """Base class for all computational blocks (the paper's ``_Node``)."""

    #: subclasses that implement :meth:`update_rows` set this True
    supports_row_update = False

    def __init__(self, name: str):
        self.name = name
        self.watchers: list[Node] = []   # downstream dependents
        self.watchees: list[Node] = []   # upstream dependencies
        self.up_to_date = False
        self.dirty_rows: set | _AllRows = ALL
        self._data = None
        # instrumentation for the speed-up experiments
        self.n_full_updates = 0
        self.n_row_updates = 0

    # -- graph wiring --------------------------------------------------------
    def watch(self, *nodes: "Node") -> "Node":
        for n in nodes:
            self.watchees.append(n)
            n.watchers.append(self)
        return self

    # -- invalidation phase ---------------------------------------------------
    def flood_out_of_date(self, rows=ALL) -> None:
        """Mark this node and everything downstream stale (no math here)."""
        changed = False
        if rows is ALL:
            if self.dirty_rows is not ALL:
                self.dirty_rows = ALL
                changed = True
        elif self.dirty_rows is not ALL:
            new_rows = self.dirty_rows | set(rows)
            if len(new_rows) != len(self.dirty_rows):
                self.dirty_rows = new_rows
                changed = True
        if self.up_to_date:
            self.up_to_date = False
            changed = True
        if changed:
            prop = self.propagate_rows(self.dirty_rows)
            for w in self.watchers:
                w.flood_out_of_date(prop)

    def propagate_rows(self, rows):
        """How this node's dirt maps onto its dependents' rows.

        Default: row-local (a dirty UE row only dirties the same UE row
        downstream).  Nodes that mix rows (attachment-driven allocation)
        return ``ALL``.
        """
        return rows

    # -- recursive update phase ------------------------------------------------
    def update(self):
        """Bring this node up to date (recursively) and return its data."""
        if self.up_to_date:
            return self._data
        for w in self.watchees:
            w.update()
        rows = self.dirty_rows
        if (rows is ALL or self._data is None
                or not self.supports_row_update):
            self._data = self.update_data()
            self.n_full_updates += 1
        else:
            self._data = self.update_rows(pad_indices(rows))
            self.n_row_updates += 1
        self.up_to_date = True
        self.dirty_rows = set()
        return self._data

    # -- subclass hooks ---------------------------------------------------------
    def update_data(self):
        raise NotImplementedError(f"{self.name}.update_data")

    def update_rows(self, idx: np.ndarray):
        raise NotImplementedError(f"{self.name}.update_rows")

    # -- conveniences -------------------------------------------------------------
    @property
    def data(self):
        return self.update()

    def __repr__(self):  # pragma: no cover
        state = "fresh" if self.up_to_date else f"stale({self.dirty_rows})"
        return f"<{type(self).__name__} {self.name} {state}>"


class RootNode(Node):
    """An input node: its data is set from outside, never computed."""

    def __init__(self, name: str, value=None):
        super().__init__(name)
        if value is not None:
            self._data = jnp.asarray(value)
        self.up_to_date = self._data is not None
        self.dirty_rows = set()

    def set(self, value) -> None:
        """Replace the whole array -> flood ALL rows downstream."""
        self._data = jnp.asarray(value)
        self.up_to_date = True
        for w in self.watchers:
            w.flood_out_of_date(ALL)

    def set_at(self, idx, values) -> None:
        """Element/submatrix assignment: ``data.at[idx].set(values)``.

        ``idx`` is anything ``jnp.ndarray.at`` accepts (an index tuple, a
        slice, ...).  Floods ALL rows downstream: dirty-row locality is
        defined over the UE axis, and for non-UE roots (e.g. the per-cell
        power matrix ``P``) a partial write is a whole-array mutation as
        far as dependents are concerned.  Use :meth:`set_rows` for
        UE-row-local patches.
        """
        self._data = self._data.at[idx].set(jnp.asarray(values))
        self.up_to_date = True
        for w in self.watchers:
            w.flood_out_of_date(ALL)

    def set_rows(self, idx, values) -> None:
        """Patch selected rows -> flood only those rows downstream."""
        idx = np.asarray(idx, dtype=np.int32)
        self._data = self._data.at[jnp.asarray(idx)].set(jnp.asarray(values))
        rows = set(int(i) for i in idx)
        for w in self.watchers:
            w.flood_out_of_date(rows)

    def update(self):
        if self._data is None:
            raise RuntimeError(f"root node {self.name} was never set")
        return self._data

    def update_data(self):  # pragma: no cover - roots are never recomputed
        return self._data


class Graph:
    """Bookkeeping for a set of nodes + the global smart-update switch.

    ``smart=False`` reproduces the paper's control experiment: every
    invalidation is widened to ALL rows, forcing full recomputation of every
    stale node (numerically identical results, no lazy row reuse).
    """

    def __init__(self, smart: bool = True):
        self.smart = smart
        self.nodes: dict[str, Node] = {}

    def add(self, node: Node) -> Node:
        self.nodes[node.name] = node
        if not self.smart:
            # control experiment: no row locality anywhere -> every stale
            # node recomputes in full, downstream dirt always widens to ALL.
            node.propagate_rows = lambda rows: ALL  # type: ignore[assignment]
            node.supports_row_update = False
        return node

    def stats(self) -> dict[str, tuple[int, int]]:
        """{name: (full_updates, row_updates)} instrumentation snapshot."""
        return {k: (n.n_full_updates, n.n_row_updates)
                for k, n in self.nodes.items()}

    def invalidate_all(self) -> None:
        for n in self.nodes.values():
            if not isinstance(n, RootNode):
                n.up_to_date = False
                n.dirty_rows = ALL
