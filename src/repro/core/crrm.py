"""CRRM -- the main simulator class (the paper's public API).

Wires the Figure-1 dependency graph, binds the pluggable pathloss strategy,
and exposes the mutation / query API.  Queries trigger the recursive update
phase; mutations trigger the invalidation phase only.

>>> from repro.core.params import CRRM_parameters
>>> from repro.core.crrm import CRRM
>>> sim = CRRM(CRRM_parameters(n_ues=50, pathloss_model_name="UMa", seed=1))
>>> tput = sim.get_UE_throughputs()          # full evaluation
>>> sim.move_UE(3, (100.0, 200.0, 1.5))      # invalidates row 3 only
>>> tput2 = sim.get_UE_throughputs()         # row-local smart update
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks
from repro.core.graph import Graph, RootNode
from repro.core.params import CRRM_parameters
from repro.mac import traffic
from repro.sim import deploy, radio
from repro.sim.antenna import Antenna_gain, sector_boresights
from repro.sim.pathloss import make_pathloss


class CRRM:
    def __init__(self, params: CRRM_parameters):
        self.params = params
        p = params
        key = jax.random.PRNGKey(p.seed)
        k_ue, k_cell, k_fad = jax.random.split(key, 3)

        # -- topology roots -------------------------------------------------
        if p.ue_positions is not None:
            U0 = jnp.asarray(p.ue_positions, dtype=jnp.float32)
        else:
            xy = jax.random.uniform(k_ue, (p.n_ues, 2), minval=0.0,
                                    maxval=p.extent_m)
            U0 = jnp.concatenate(
                [xy, jnp.full((p.n_ues, 1), p.h_ut_m)], axis=1)
        if p.cell_positions is not None:
            C0 = jnp.asarray(p.cell_positions, dtype=jnp.float32)
        else:
            n_cells = p.n_cells or 7
            n_sites = max(1, n_cells // p.n_sectors)
            rings = 0
            while 1 + 3 * rings * (rings + 1) < n_sites:
                rings += 1
            sites = deploy.hex_sites(rings, isd_m=p.extent_m / (2 * rings + 1)
                                     if rings else p.extent_m, z=p.h_bs_m)
            sites = sites[:n_sites] + jnp.asarray(
                [p.extent_m / 2, p.extent_m / 2, 0.0])
            C0 = deploy.replicate_sectors(sites, p.n_sectors)
        self.n_cells = int(C0.shape[0])
        self.n_ues = int(U0.shape[0])

        # frequency grid: n_subbands power subbands x n_rb_subbands CQI
        # subbands each; every per-frequency tensor below has trailing axis
        # n_freq (== n_subbands in the legacy wideband configuration).
        self.n_freq = p.n_freq
        if p.power_matrix is not None:
            P0 = jnp.asarray(p.power_matrix, dtype=jnp.float32)
            if p.n_rb_subbands > 1:     # split each subband's power evenly
                P0 = jnp.repeat(P0, p.n_rb_subbands,
                                axis=1) / p.n_rb_subbands
        else:
            P0 = jnp.full((self.n_cells, self.n_freq),
                          p.power_W / self.n_freq, dtype=jnp.float32)

        bore0 = sector_boresights(self.n_cells // p.n_sectors, p.n_sectors)

        # the strategy pattern: model name -> class -> bound pathgain_function
        self.pathloss_model = make_pathloss(p.pathloss_model_name,
                                            **p.pathloss_params)
        self.pathgain_function = self.pathloss_model.get_pathgain
        antenna = Antenna_gain(phi_3dB_deg=p.antenna_phi_3dB_deg,
                               A_max_dB=p.antenna_A_max_dB)
        self.antenna = antenna
        #: the hashable pure-radio configuration (sim.radio) every
        #: consumer -- graph nodes, TTI engine, env resets -- derives from
        self._radio_cfg = radio.config_from_params(
            p, self.pathgain_function, antenna)

        if p.rayleigh_fading:
            F0 = radio.draw_fading(self._radio_cfg, k_fad, self.n_ues,
                                   self.n_cells)
        else:
            F0 = radio.unit_fading(self._radio_cfg, self.n_ues, self.n_cells)

        # -- graph ------------------------------------------------------------
        g = Graph(smart=p.smart)
        self.graph = g
        self.U = g.add(RootNode("U", U0))
        self.C = g.add(RootNode("C", C0))
        self.P = g.add(RootNode("P", P0))
        self.boresight = g.add(RootNode("boresight", bore0))
        self.fading = g.add(RootNode("fading", F0))

        self.D = g.add(blocks.DistanceNode(self.U, self.C))
        self.G = g.add(blocks.GainNode(
            self.D, self.U, self.C, self.boresight, self.fading,
            self.pathgain_function, antenna, p.n_sectors))
        self.R = g.add(blocks.RSRPNode(self.G, self.P))
        if p.rayleigh_fading and p.attach_ignores_fading:
            # association on the long-term mean: a parallel unfaded branch
            self.ones = g.add(RootNode(
                "ones", jnp.ones((self.n_ues, self.n_cells))))
            self.G_mean = g.add(blocks.GainNode(
                self.D, self.U, self.C, self.boresight, self.ones,
                self.pathgain_function, antenna, p.n_sectors))
            self.G_mean.name = "G_mean"
            self.R_mean = g.add(blocks.RSRPNode(self.G_mean, self.P))
            self.R_mean.name = "RSRP_mean"
            g.nodes["G_mean"] = g.nodes.pop("G")  # fix registry keys
            g.nodes["G"] = self.G
            g.nodes["RSRP_mean"] = g.nodes.pop("RSRP")
            g.nodes["RSRP"] = self.R
            self.a = g.add(blocks.AttachmentNode(self.R_mean))
        else:
            self.a = g.add(blocks.AttachmentNode(self.R))
        self.w = g.add(blocks.WantedNode(self.R, self.a))
        self.u = g.add(blocks.InterferenceNode(self.R, self.w))
        self.gamma = g.add(blocks.SINRNode(self.w, self.u, p.chunk_noise_W))
        self.cqi = g.add(blocks.CQINode(
            self.gamma, p.n_rb_subbands, p.cqi_report == "wideband",
            p.cqi_eesm_beta))
        self.mcs = g.add(blocks.MCSNode(self.cqi))
        self.se = g.add(blocks.SpectralEfficiencyNode(self.mcs, self.cqi))
        self.shannon = g.add(blocks.ShannonNode(
            self.gamma, p.chunk_bandwidth_Hz, p.n_tx, p.n_rx))
        self.throughput = g.add(blocks.ThroughputNode(
            self.se, self.a, self.n_cells, p.chunk_bandwidth_Hz,
            p.fairness_p))

        # -- MAC subsystem: traffic -> buffers -> scheduler -> served -------
        # The legacy ThroughputNode above is the full_buffer + fairness_p
        # special case of this chain (asserted in tests/test_mac.py).
        init_backlog, self._traffic_step = traffic.make_traffic(
            p.traffic_model, self.n_ues, p.tti_s, **p.traffic_params)
        self.buffer = g.add(blocks.BufferNode(init_backlog()))
        self.sched = g.add(blocks.ScheduleNode(
            self.se, self.cqi, self.a, self.buffer, self.n_cells,
            p.rb_per_chunk, p.scheduler_policy, p.fairness_p))
        self.served = g.add(blocks.ServedThroughputNode(
            self.sched, self.se, self.buffer,
            p.subband_bandwidth_Hz / p.n_rb, p.tti_s))

    # ---------------------------------------------------------------- mutations
    def move_UE(self, i: int, xyz) -> None:
        self.U.set_rows(np.asarray([i]), np.asarray(xyz, np.float32)[None, :])

    def move_UEs(self, idx, xyz) -> None:
        self.U.set_rows(np.asarray(idx), np.asarray(xyz, np.float32))

    def set_UE_positions(self, U) -> None:
        self.U.set(jnp.asarray(U, dtype=jnp.float32))

    def set_power_matrix(self, P) -> None:
        """Set per-cell/subband powers; accepts the documented
        (n_cells, n_subbands) shape (expanded onto the n_freq grid as in
        the constructor) or an already-expanded (n_cells, n_freq) one."""
        P = jnp.asarray(P, dtype=jnp.float32)
        p = self.params
        if p.n_rb_subbands > 1 and P.shape[1] == p.n_subbands:
            P = jnp.repeat(P, p.n_rb_subbands, axis=1) / p.n_rb_subbands
        if P.shape != (self.n_cells, self.n_freq):
            raise ValueError(
                f"power matrix must be (n_cells, n_subbands)="
                f"({self.n_cells}, {p.n_subbands}) or (n_cells, n_freq)="
                f"({self.n_cells}, {self.n_freq}); got {tuple(P.shape)}")
        self.P.set(P)

    def set_cell_power(self, j: int, k: int, watts: float) -> None:
        """Set cell ``j``'s power on *subband* ``k`` (spread evenly over
        the subband's CQI chunks when ``n_rb_subbands > 1``)."""
        s = self.params.n_rb_subbands
        cols = jnp.arange(k * s, (k + 1) * s)
        self.P.set_at((j, cols), watts / s)

    def resample_fading(self, key) -> None:
        """Redraw the fast-fading root via the ONE documented fading draw
        (``radio.draw_fading``) -- the same stream the episode engine's
        per-TTI redraw and the env's topology resets consume, so equal keys
        give bit-identical fading everywhere."""
        self.fading.set(radio.draw_fading(self._radio_cfg, key, self.n_ues,
                                          self.n_cells))

    def add_traffic(self, idx, bits) -> None:
        """Queue arrival bits onto selected UEs (row-local MAC flood)."""
        self.buffer.add_bits(idx, bits)

    def set_backlog(self, backlog) -> None:
        self.buffer.set(jnp.asarray(backlog, dtype=jnp.float32))

    def step_traffic(self, key, t: int = 0) -> None:
        """Draw one TTI of arrivals from the configured traffic model."""
        arrivals = self._traffic_step(key, t)
        self.buffer.set(self.buffer._data + arrivals)

    # ------------------------------------------------------------------- queries
    def get_distances(self):
        return self.D.update()

    def get_pathgains(self):
        return self.G.update()

    def get_RSRP(self):
        return self.R.update()

    def get_attachment(self):
        return self.a.update()

    def get_SINR(self):
        """(n_ue, n_freq) linear SINR (n_freq == n_subbands unless
        ``n_rb_subbands > 1`` splits the grid into CQI subbands)."""
        return self.gamma.update()

    def get_SINR_dB(self):
        return 10.0 * jnp.log10(jnp.maximum(self.get_SINR(), 1e-12))

    def get_CQI(self):
        return self.cqi.update()

    def get_MCS(self):
        return self.mcs.update()

    def get_spectral_efficiency(self):
        return self.se.update()

    def get_shannon_capacities(self):
        """(n_ue, n_freq) bits/s upper bound."""
        return self.shannon.update()

    def get_UE_throughputs(self):
        """(n_ue,) bits/s: fairness-weighted share summed over subbands."""
        return self.throughput.update().sum(axis=1)

    def get_backlog(self):
        """(n_ue,) bits queued (inf for full-buffer traffic)."""
        return self.buffer.update()

    def get_schedule(self):
        """(n_ue, n_freq) resource blocks granted this TTI
        (``rb_per_chunk`` RBs available per frequency chunk)."""
        return self.sched.update()

    def get_served_throughputs(self):
        """(n_ue,) bits/s through the MAC chain (grant capped by backlog)."""
        return self.served.update().sum(axis=1)

    # ---------------------------------------------------------------- pure radio
    def radio_config(self) -> "radio.RadioConfig":
        """The hashable pure-radio configuration bound to this simulator's
        pathloss/antenna closures (``repro.sim.radio``)."""
        return self._radio_cfg

    def radio_static(self) -> "radio.RadioStatic":
        """The :class:`~repro.sim.radio.RadioStatic` pytree for the current
        graph roots (cell positions, powers, boresights).  Pure data + a
        static config: hand it to ``radio.radio_forward`` to run the whole
        chain for arbitrary UE positions without touching the graph."""
        return radio.RadioStatic(C=self.C._data, P=self.P._data,
                                 bore=self.boresight._data,
                                 cfg=self._radio_cfg)

    # ------------------------------------------------------------------ episodes
    def init_episode_state(self, key=None):
        """Gather the full episode carry as an explicit ``EpisodeState``.

        Everything a MAC episode mutates -- buffers, PF EWMA, round-robin
        cursor, HARQ processes, serving cells / TTT counters, positions and
        the PRNG key -- in one pytree (DESIGN.md §Env-API).  Seeds the PF
        average from the single-shot graph's served throughput (the
        stationary alpha-fair point) and the serving cells from the current
        attachment, unless a previous ``sync_episode_state`` left state on
        the simulator.  ``key=None`` derives the legacy per-sim episode key
        from ``params.seed``.
        """
        from repro.mac.engine import EpisodeState
        if key is None:
            key = radio.episode_key(self.params.seed)
        n = self.n_ues
        avg0 = getattr(self, "_pf_avg", None)
        if avg0 is None:
            avg0 = self.get_served_throughputs()
        hbits0 = getattr(self, "_harq_bits", None)
        if hbits0 is None:
            hbits0 = jnp.zeros((n,), jnp.float32)
        hretx0 = getattr(self, "_harq_retx", None)
        if hretx0 is None:
            hretx0 = jnp.zeros((n,), jnp.int32)
        a0 = getattr(self, "_ho_serving", None)
        if a0 is None:
            a0 = self.get_attachment()
        ttt0 = getattr(self, "_ho_ttt", None)
        if ttt0 is None:
            ttt0 = jnp.zeros((n,), jnp.int32)
        return EpisodeState(
            U=self.U._data, backlog=self.buffer._data, pf_avg=avg0,
            rr_cursor=jnp.int32(self.sched.cursor), key=key,
            harq_bits=jnp.asarray(hbits0, jnp.float32),
            harq_retx=jnp.asarray(hretx0, jnp.int32),
            serving=jnp.asarray(a0, jnp.int32),
            ttt=jnp.asarray(ttt0, jnp.int32), t=jnp.int32(0))

    def episode_static(self):
        """Read the per-episode radio inputs (``EpisodeStatic``) off the
        graph: cached SE/CQI/attachment plus the C/P/boresight/fading
        roots.  Pure data -- safe to close over, jit, or vmap against."""
        from repro.mac.engine import EpisodeStatic
        return EpisodeStatic(
            se=self.get_spectral_efficiency(), cqi=self.get_CQI(),
            a=self.get_attachment(), C=self.C._data, P=self.P._data,
            bore=self.boresight._data, fad=self.fading._data)

    def episode_fns(self, mobility_step_m=None, per_tti_fading: bool = False,
                    use_harq=None, mesh=None, ue_axis=("ue",),
                    cell_axis=None, radio_mode=None,
                    mobility_move_frac=None, inc_backend=None,
                    telemetry: bool = False, churn=None, relax=None,
                    faults=None):
        """The pure ``(step, rollout)`` episode functions for this
        simulator's topology and MAC parameters (``EpisodeFns``), cached
        per trace-time switch combination.  Both are jit-compiled and
        vmap-compatible: N parallel episodes = ``vmap`` over the state
        (see ``repro.env.CrrmEnv``).  ``mesh`` shard_maps the rollout over
        the UE axis of a device mesh (``ue_axis`` names the mesh axes) for
        >100k-UE episodes; ``cell_axis`` additionally shards the cell
        dimension (a UE x cell mesh) so the per-cell radio leaves scale
        past a single device -- see DESIGN.md §Radio-fns and
        §Million-UE-scaling.
        ``radio_mode="incremental"`` recomputes only dirty UE rows of the
        radio chain inside the scan and ``mobility_move_frac`` bounds the
        per-TTI dirtiness (DESIGN.md §Smart-update-in-scan); both default
        to the corresponding ``CRRM_parameters`` fields.  ``inc_backend``
        selects the dirty-row compute path: ``"xla"`` (default),
        ``"pallas"`` (the fused VMEM-resident kernel; raises if the
        configuration cannot be expressed) or ``"auto"``.  ``telemetry``
        adds a per-TTI KPI pytree to both functions' returns
        (DESIGN.md §Observability); ``churn`` a
        ``sim.mobility.ChurnConfig`` enabling the birth-death UE process
        of the digital-twin serving layer (DESIGN.md
        §Digital-twin-serving); ``relax`` a ``sim.radio.RelaxConfig``
        softening the chain's non-differentiable points for
        gradient-based optimization (DESIGN.md §RL-and-differentiability);
        ``faults`` a ``sim.faults.FaultConfig`` in-scan cell fault
        process (DESIGN.md §Fault-injection-and-self-healing; defaults
        to ``params.faults``, ``0`` forces off) -- all off, the exact
        legacy program."""
        from repro.mac import engine as mac_engine
        return mac_engine.episode_fns_for(
            self, mobility_step_m=mobility_step_m,
            per_tti_fading=per_tti_fading, use_harq=use_harq,
            mesh=mesh, ue_axis=ue_axis, cell_axis=cell_axis,
            radio_mode=radio_mode,
            mobility_move_frac=mobility_move_frac,
            inc_backend=inc_backend, telemetry=telemetry,
            churn=churn, relax=relax, faults=faults)

    def sync_episode_state(self, state, positions: bool = False) -> None:
        """Write a final ``EpisodeState`` back into the graph (legacy
        mutate/query convenience -- functional callers thread the state
        instead).  ``positions`` also writes the UE positions root (only
        meaningful after a mobility episode)."""
        if positions:
            self.set_UE_positions(state.U)
        self.buffer.set(state.backlog)
        self._pf_avg = state.pf_avg
        self.sched.cursor = int(state.rr_cursor)
        self._harq_bits, self._harq_retx = state.harq_bits, state.harq_retx
        if self.params.ho_enabled:
            self._ho_serving, self._ho_ttt = state.serving, state.ttt

    def reset_episode_state(self) -> None:
        """Drop persisted episode state (PF EWMA, HARQ, serving cells) so
        the next ``init_episode_state`` re-seeds from the graph."""
        for attr in ("_pf_avg", "_harq_bits", "_harq_retx",
                     "_ho_serving", "_ho_ttt"):
            if hasattr(self, attr):
                delattr(self, attr)

    def run_episode(self, n_tti: int, key=None, mobility_step_m=None,
                    per_tti_fading: bool = False, sync_state: bool = True,
                    use_harq=None, radio_mode=None,
                    mobility_move_frac=None, telemetry: bool = False):
        """Roll ``n_tti`` TTIs as one ``lax.scan`` program.

        Returns (n_tti, n_ues) delivered throughput in bits/s -- or
        ``(tput, telem)`` with ``telemetry=True``, ``telem`` being the
        stacked per-TTI ``repro.obs.Telemetry`` KPI pytree.  A thin
        wrapper over the functional episode API: ``init_episode_state`` ->
        ``episode_fns().rollout`` -> ``sync_episode_state`` (the
        write-back runs unless ``sync_state=False``; new code should use
        the functional API and thread ``EpisodeState`` explicitly).
        ``use_harq`` overrides the ``harq_bler > 0`` auto-switch for the
        stop-and-wait HARQ machine (False selects the legacy Bernoulli
        HARQ-lite).
        """
        from repro.mac import engine as mac_engine
        return mac_engine.run_episode(
            self, n_tti, key=key, mobility_step_m=mobility_step_m,
            per_tti_fading=per_tti_fading, sync_state=sync_state,
            use_harq=use_harq, radio_mode=radio_mode,
            mobility_move_frac=mobility_move_frac, telemetry=telemetry)

    # -------------------------------------------------------------- introspection
    def update_counts(self):
        return self.graph.stats()
