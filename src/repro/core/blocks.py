"""Concrete CRRM computational blocks (the boxes of the paper's Figure 1).

Each node's full recompute and row-local patch are single jitted calls.  Row
patches write into the node's existing device buffer with ``donate_argnums``
so XLA updates in place -- without donation every row update would copy the
whole (n_ue, n_cell) matrix and erase the smart-update win.

Block list (paper §2): U, C, P roots -> D -> G -> R(SRP) -> a -> w, u ->
gamma (SINR) -> CQI -> MCS -> SE -> Shannon, and the allocation/throughput
terminal.

The *math* of every radio block lives in the pure-functional chain of
``repro.sim.radio`` (DESIGN.md §Radio-fns); this module owns only the
smart-update caching shell -- dirty-row bookkeeping, in-place row patches,
and the jit wrappers that bind the pure functions to node buffers.  The
graph, the scan-compiled TTI engine and the env therefore share one
implementation of the physics and stay bit-exact with each other.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import ALL, Node, RootNode
from repro.mac import scheduler as mac_sched
from repro.sim import radio
from repro.sim.antenna import Antenna_gain


# ---------------------------------------------------------------------------
# jitted wrappers over the pure radio functions.  These are radio.*_jit
# SHARED executables (module level in sim.radio), so the graph, an eager
# radio.radio_forward and any other consumer dispatch the same compiled
# programs -- which is what makes the graph-vs-radio_forward equivalence
# bit-exact rather than merely close (tests/test_radio_fns.py).
# ---------------------------------------------------------------------------
_geometry = radio.geometry_jit


@partial(jax.jit, donate_argnums=(3, 4, 5))
def _geometry_rows(U, C, idx, d2d, d3d, az):
    r2d, r3d, raz = _geometry(U[idx], C)
    return (d2d.at[idx].set(r2d), d3d.at[idx].set(r3d), az.at[idx].set(raz))


_rsrp = radio.rsrp_jit


@partial(jax.jit, donate_argnums=(3,))
def _rsrp_rows(G, P, idx, R):
    rows = G[idx] if G.ndim == 3 else G[idx][:, :, None]
    return R.at[idx].set(rows * P[None, :, :])


_attach = radio.attach_jit


@partial(jax.jit, donate_argnums=(2,))
def _attach_rows(R, idx, a):
    return a.at[idx].set(radio.attachment(R[idx]))


_wanted = radio.wanted_jit


@partial(jax.jit, donate_argnums=(3,))
def _wanted_rows(R, a, idx, w):
    return w.at[idx].set(radio.wanted(R[idx], a[idx]))


_interference = radio.interference_jit


@partial(jax.jit, donate_argnums=(3,))
def _interference_rows(R, w, idx, u):
    return u.at[idx].set(radio.interference(R[idx], w[idx]))


def _sinr_fn(noise_w):
    def f(w, u):
        return radio.sinr_jit(w, u, noise_w)

    @partial(jax.jit, donate_argnums=(3,))
    def f_rows(w, u, idx, g):
        return g.at[idx].set(radio.sinr_from_wu(w[idx], u[idx], noise_w))

    return f, f_rows


_cqi = radio.cqi_jit


@partial(jax.jit, donate_argnums=(2,))
def _cqi_rows(gamma, idx, cqi):
    return cqi.at[idx].set(radio.quantize_cqi(gamma[idx]))


#: back-compat alias -- the EESM pooling/reporting math moved to
#: repro.sim.radio (single source of truth for graph + engine + env)
_cqi_report = radio.cqi_report


_mcs = radio.mcs_jit


@partial(jax.jit, donate_argnums=(2,))
def _mcs_rows(cqi, idx, mcs):
    return mcs.at[idx].set(radio.mcs_of(cqi[idx]))


_se = radio.se_jit


@partial(jax.jit, donate_argnums=(3,))
def _se_rows(mcs, cqi, idx, se):
    return se.at[idx].set(_se(mcs[idx], cqi[idx]))


def _shannon_fn(subband_bw, streams):
    @jax.jit
    def f(gamma):
        return streams * subband_bw * jnp.log2(1.0 + jnp.maximum(gamma, 0.0))

    @partial(jax.jit, donate_argnums=(2,))
    def f_rows(gamma, idx, cap):
        return cap.at[idx].set(f(gamma[idx]))

    return f, f_rows


def _throughput_fn(n_cells, subband_bw, p):
    @jax.jit
    def f(se, a):
        """T_i = a_cell * S_i^(1-p), a_cell = B_k / sum_j S_j^-p (per subband).

        Equivalent to sharing each serving cell's subband airtime with weights
        S^-p: p=0 -> equal airtime (T proportional to S); p=1 -> equal T.
        """
        active = se > 0.0
        wgt = jnp.where(active, jnp.power(jnp.maximum(se, 1e-12), -p), 0.0)
        denom = jnp.zeros((n_cells, se.shape[1]), se.dtype).at[a].add(wgt)
        denom_i = denom[a]  # (n_ue, n_subbands)
        share = jnp.where(denom_i > 0.0, wgt / jnp.maximum(denom_i, 1e-30), 0.0)
        # bits/s on each subband = airtime share * bandwidth * spectral eff.
        return share * subband_bw * se

    return f


# ---------------------------------------------------------------------------
# node classes
# ---------------------------------------------------------------------------
class DistanceNode(Node):
    """D: 2-D/3-D distance matrices + bearing angles (one geometry pass)."""

    supports_row_update = True

    def __init__(self, U: RootNode, C: RootNode):
        super().__init__("D")
        self.watch(U, C)
        self.U, self.C = U, C

    def update_data(self):
        return _geometry(self.U._data, self.C._data)

    def update_rows(self, idx):
        d2d, d3d, az = self._data
        return _geometry_rows(self.U._data, self.C._data, jnp.asarray(idx),
                              d2d, d3d, az)


class GainNode(Node):
    """G = pathgain(D) * antenna(az) * fading; 0 <= G < 1 (pre-fading).

    The fading root is (n_ue, n_cell) for the flat wideband channel or
    (n_ue, n_cell, n_freq) when frequency selective (``n_rb_subbands > 1``);
    the gain tensor inherits the fading rank and RSRP broadcasts it against
    the per-frequency power matrix.
    """

    supports_row_update = True

    def __init__(self, D: DistanceNode, U: RootNode, C: RootNode,
                 boresight: RootNode, fading: RootNode,
                 pathgain_function, antenna: Antenna_gain, n_sectors: int):
        super().__init__("G")
        self.watch(D, boresight, fading)
        self.D, self.U, self.C = D, U, C
        self.boresight, self.fading = boresight, fading

        gain = radio.make_gain_fn(pathgain_function, antenna, n_sectors)

        self._full = partial(radio.gain_jit, pathgain_function, antenna,
                             n_sectors)
        self._rows = jax.jit(
            lambda U, C, d2d, d3d, az, bore, fad, idx, G:
            G.at[idx].set(gain(d2d[idx], d3d[idx], az[idx], U[idx, 2],
                               C[:, 2], bore, fad[idx])),
            donate_argnums=(8,))

    def update_data(self):
        d2d, d3d, az = self.D._data
        return self._full(self.U._data, self.C._data, d2d, d3d, az,
                          self.boresight._data, self.fading._data)

    def update_rows(self, idx):
        d2d, d3d, az = self.D._data
        return self._rows(self.U._data, self.C._data, d2d, d3d, az,
                          self.boresight._data, self.fading._data,
                          jnp.asarray(idx), self._data)


class RSRPNode(Node):
    supports_row_update = True

    def __init__(self, G: GainNode, P: RootNode):
        super().__init__("RSRP")
        self.watch(G, P)
        self.G, self.P = G, P

    def update_data(self):
        return _rsrp(self.G._data, self.P._data)

    def update_rows(self, idx):
        return _rsrp_rows(self.G._data, self.P._data, jnp.asarray(idx),
                          self._data)


class AttachmentNode(Node):
    """a: serving-cell index per UE (strongest wideband RSRP)."""

    supports_row_update = True

    def __init__(self, R: RSRPNode):
        super().__init__("a")
        self.watch(R)
        self.R = R

    def update_data(self):
        return _attach(self.R._data)

    def update_rows(self, idx):
        return _attach_rows(self.R._data, jnp.asarray(idx), self._data)


class WantedNode(Node):
    supports_row_update = True

    def __init__(self, R: RSRPNode, a: AttachmentNode):
        super().__init__("w")
        self.watch(R, a)
        self.R, self.a = R, a

    def update_data(self):
        return _wanted(self.R._data, self.a._data)

    def update_rows(self, idx):
        return _wanted_rows(self.R._data, self.a._data, jnp.asarray(idx),
                            self._data)


class InterferenceNode(Node):
    supports_row_update = True

    def __init__(self, R: RSRPNode, w: WantedNode):
        super().__init__("u")
        self.watch(R, w)
        self.R, self.w = R, w

    def update_data(self):
        return _interference(self.R._data, self.w._data)

    def update_rows(self, idx):
        return _interference_rows(self.R._data, self.w._data,
                                  jnp.asarray(idx), self._data)


class SINRNode(Node):
    supports_row_update = True

    def __init__(self, w: WantedNode, u: InterferenceNode, noise_w: float):
        super().__init__("gamma")
        self.watch(w, u)
        self.w, self.u = w, u
        self._full, self._rows = _sinr_fn(noise_w)

    def update_data(self):
        return self._full(self.w._data, self.u._data)

    def update_rows(self, idx):
        return self._rows(self.w._data, self.u._data, jnp.asarray(idx),
                          self._data)


class CQINode(Node):
    """CQI at the configured reporting resolution (``cqi_report`` knob).

    ``wideband=True`` pools each power subband's ``n_rb_subbands`` chunks
    to one effective-SINR report (``radio.pool_report``); the default is the
    legacy per-chunk quantisation (shared jitted helpers).
    """

    supports_row_update = True

    def __init__(self, gamma: SINRNode, n_rb_subbands: int = 1,
                 wideband: bool = False, eesm_beta: float = 1.0):
        super().__init__("CQI")
        self.watch(gamma)
        self.gamma = gamma
        if wideband and n_rb_subbands > 1:
            self._full = lambda g: radio.cqi_report_jit(
                g, n_rb_subbands, True, eesm_beta)
            self._rows = jax.jit(
                lambda g, idx, cqi: cqi.at[idx].set(
                    _cqi_report(g[idx], n_rb_subbands, True, eesm_beta)),
                donate_argnums=(2,))
        else:
            self._full, self._rows = _cqi, _cqi_rows

    def update_data(self):
        return self._full(self.gamma._data)

    def update_rows(self, idx):
        return self._rows(self.gamma._data, jnp.asarray(idx), self._data)


class MCSNode(Node):
    supports_row_update = True

    def __init__(self, cqi: CQINode):
        super().__init__("MCS")
        self.watch(cqi)
        self.cqi = cqi

    def update_data(self):
        return _mcs(self.cqi._data)

    def update_rows(self, idx):
        return _mcs_rows(self.cqi._data, jnp.asarray(idx), self._data)


class SpectralEfficiencyNode(Node):
    supports_row_update = True

    def __init__(self, mcs: MCSNode, cqi: CQINode):
        super().__init__("SE")
        self.watch(mcs, cqi)
        self.mcs, self.cqi = mcs, cqi

    def update_data(self):
        return _se(self.mcs._data, self.cqi._data)

    def update_rows(self, idx):
        return _se_rows(self.mcs._data, self.cqi._data, jnp.asarray(idx),
                        self._data)


class ShannonNode(Node):
    """Information-theoretic capacity bound (incl. MIMO multiplexing)."""

    supports_row_update = True

    def __init__(self, gamma: SINRNode, subband_bw: float, n_tx: int, n_rx: int):
        super().__init__("Shannon")
        self.watch(gamma)
        self.gamma = gamma
        self._full, self._rows = _shannon_fn(subband_bw, min(n_tx, n_rx))

    def update_data(self):
        return self._full(self.gamma._data)

    def update_rows(self, idx):
        return self._rows(self.gamma._data, jnp.asarray(idx), self._data)


class ThroughputNode(Node):
    """Terminal block: fairness-weighted airtime share x MCS rate.

    NOT row-local: one UE's move changes its serving cell's load and hence
    every co-served UE's throughput, so this node always recomputes in full
    (it is O(n_ue + n_cell) vector math -- cheap by design).
    """

    supports_row_update = False

    def __init__(self, se: SpectralEfficiencyNode, a: AttachmentNode,
                 n_cells: int, subband_bw: float, p: float):
        super().__init__("T")
        self.watch(se, a)
        self.se, self.a = se, a
        self._full = _throughput_fn(n_cells, subband_bw, p)

    def propagate_rows(self, rows):
        return ALL  # cell loads mix rows

    def update_data(self):
        return self._full(self.se._data, self.a._data)


# ---------------------------------------------------------------------------
# MAC subsystem nodes (traffic -> buffers -> scheduler -> served throughput)
# ---------------------------------------------------------------------------
class BufferNode(RootNode):
    """MAC backlog root: bits queued for each UE (``inf`` = full buffer).

    A root, not a computed node: its contents come from outside the radio
    graph (traffic arrivals / the episode engine's write-back).  Mutating a
    single UE's backlog floods only that row, and only into the MAC
    subgraph -- the radio chain (D..SE) does not watch it.
    """

    def __init__(self, backlog):
        super().__init__("buffer", jnp.asarray(backlog, dtype=jnp.float32))

    def add_bits(self, idx, bits) -> None:
        """Accumulate arrival bits onto selected UEs (row-local flood).

        Duplicate indices accumulate (summed on host first): a last-wins
        scatter of gather-then-add rows would silently drop offered bits.
        """
        idx = np.asarray(idx, dtype=np.int32)
        bits = np.broadcast_to(np.asarray(bits, dtype=np.float32),
                               idx.shape)
        uniq, inv = np.unique(idx, return_inverse=True)
        acc = np.zeros(uniq.shape, np.float32)
        np.add.at(acc, inv, bits)
        new = self._data[jnp.asarray(uniq)] + jnp.asarray(acc)
        self.set_rows(uniq, new)


def _schedule_fn(policy, n_cells, n_rb, fairness_p):
    """One jitted allocation pass; the policy is baked at trace time."""
    @jax.jit
    def f(se, cqi, a, backlog, cursor):
        active = (backlog[:, None] > 0.0) & (se > 0.0)
        # the single-shot graph uses the stationary alpha-fair PF weights
        # (se**-fairness_p) -- exactly the legacy ThroughputNode allocation.
        log_w = mac_sched.pf_log_weights_stationary(se, fairness_p)
        return mac_sched.allocate(policy, active, cqi, a, n_cells, n_rb,
                                  cursor, log_w)

    return f


class ScheduleNode(Node):
    """alloc[i, k]: resource blocks granted to UE i on subband k.

    NOT row-local: one UE's backlog or channel change redistributes its
    serving cell's whole grid, so this node recomputes in full (cheap
    vector math, like ThroughputNode).
    """

    supports_row_update = False

    def __init__(self, se: SpectralEfficiencyNode, cqi: CQINode,
                 a: AttachmentNode, buffer: BufferNode, n_cells: int,
                 n_rb: int, policy: str, fairness_p: float):
        super().__init__("alloc")
        self.watch(se, cqi, a, buffer)
        self.se, self.cqi, self.a, self.buffer = se, cqi, a, buffer
        self.cursor = 0  # round-robin rotation state (engine rotates per TTI)
        self._full = _schedule_fn(policy, n_cells, n_rb, fairness_p)

    def propagate_rows(self, rows):
        return ALL  # the grid split mixes rows within a cell

    def update_data(self):
        return self._full(self.se._data, self.cqi._data, self.a._data,
                          self.buffer._data, jnp.int32(self.cursor))


def _served_fn(rb_bw_hz, tti_s):
    @jax.jit
    def f(alloc, se, backlog):
        bits = mac_sched.served_bits(alloc, se, backlog, rb_bw_hz, tti_s)
        return bits / tti_s

    return f


class ServedThroughputNode(Node):
    """Terminal MAC block: served bits/s per (UE, subband).

    Grant capacity capped by backlog.  With ``traffic_model="full_buffer"``
    and ``scheduler_policy="pf"`` this reduces exactly to the legacy
    ``ThroughputNode`` (the grant is the stationary fairness-p share and
    the backlog cap never binds) -- asserted in tests/test_mac.py.
    """

    supports_row_update = False

    def __init__(self, sched: ScheduleNode, se: SpectralEfficiencyNode,
                 buffer: BufferNode, rb_bw_hz: float, tti_s: float):
        super().__init__("T_served")
        self.watch(sched, se, buffer)
        self.sched, self.se, self.buffer = sched, se, buffer
        self._full = _served_fn(rb_bw_hz, tti_s)

    def propagate_rows(self, rows):
        return ALL

    def update_data(self):
        return self._full(self.sched._data, self.se._data, self.buffer._data)
