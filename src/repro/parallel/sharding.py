"""Logical-axis sharding rules: param/cache/batch PartitionSpecs by path.

``infer_param_specs`` walks the parameter pytree and assigns a PartitionSpec
to every leaf based on its path (the param name carries the semantics) and
divisibility against the mesh -- a dimension is only sharded when its size
divides the axis size, with documented fallbacks (e.g. GQA K/V heads smaller
than the model axis fall back to sharding head_dim, then replication).

This is the 1000-node story: rules are mesh-shape agnostic, so the same
model code runs on (16,16), (2,16,16) or anything else.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel.mesh import axis_size, batch_axes, get_strategy, \
    tp_size


def _maybe(size: int, axis, mesh) -> Any:
    """Shard dim of ``size`` on ``axis`` only if divisible; else replicate."""
    if axis is None:
        return None
    if size % axis_size(mesh, axis) == 0:
        return axis
    return None


def _first_fit(shape, candidates, mesh):
    """'model' on the first listed dim that divides, then FSDP: the batch
    axes on the first *remaining* dim that divides (ZeRO-3 -- params, grads
    and moments all shard over data, gathered per layer inside the scan)."""
    spec = [None] * len(shape)
    if get_strategy() != "dp":
        for dim in candidates:
            if shape[dim] % axis_size(mesh, "model") == 0:
                spec[dim] = "model"
                break
    ba = batch_axes(mesh)
    dp = axis_size(mesh, ba)
    if dp > 1:
        order = [d for d in range(len(shape)) if spec[d] is None]
        # prefer dims listed as candidates, then any other dim
        order = ([d for d in candidates if spec[d] is None]
                 + [d for d in order if d not in candidates])
        for dim in order:
            if shape[dim] >= 1024 and shape[dim] % dp == 0:
                spec[dim] = ba
                break
    return spec


# rules keyed by the last path component (the param name); each returns a
# list of dim -> axis assignments given the *unstacked* shape.
def _param_rule(path: tuple, shape: tuple, mesh) -> P:
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    leaf = names[-1]
    # optimizer-state leaves: unfactored second moments ("v") shard exactly
    # like their parameter (parent path component); factored ones get a
    # generic first-fit.
    if leaf == "v" and len(names) >= 2:
        leaf = names[-2]
    stacked = "layers" in names or "encoder" in names or "decoder" in names
    core = shape[1:] if stacked else shape
    if names[-1] in ("vr", "vc"):
        spec = _first_fit(core, list(range(len(core))), mesh)
        return P(*(([None] + spec) if stacked else spec))

    def out(spec_core):
        spec = ([None] + list(spec_core)) if stacked else list(spec_core)
        return P(*spec)

    if leaf == "embedding":                       # (V, D)
        return out(_first_fit(core, [0, 1], mesh))
    if leaf == "kernel":                          # lm_head (D, V)
        return out(_first_fit(core, [1], mesh))
    if leaf == "wq":                              # (D, H, hd)
        return out(_first_fit(core, [1, 2], mesh))
    if leaf in ("wk", "wv"):                      # (D, KV, hd)
        return out(_first_fit(core, [1, 2], mesh))
    if leaf == "wo" and len(core) == 3:           # attn out (H, hd, D)
        return out(_first_fit(core, [0, 1], mesh))
    if leaf == "bq":                              # (H, hd)
        return out(_first_fit(core, [0, 1], mesh))
    if leaf in ("bk", "bv"):                      # (KV, hd)
        return out(_first_fit(core, [0, 1], mesh))
    if leaf in ("wi_gate", "wi_up"):
        if len(core) == 3:                        # moe experts (E, D, F)
            return out(_first_fit(core, [0], mesh))
        return out(_first_fit(core, [1], mesh))   # (D, F)
    if leaf == "wo" and len(core) == 2:           # mlp (F, D)
        return out(_first_fit(core, [0], mesh))
    if leaf == "router":                          # (D, E)
        return out(_first_fit(core, [1], mesh))
    if leaf == "in_proj":
        if len(core) == 2 and core[0] > core[1]:  # shared-attn (2D, D)
            return out([None, None])
        return out(_first_fit(core, [1], mesh))   # mamba (D, 2*din)
    if leaf == "out_proj":                        # mamba (din, D)
        return out(_first_fit(core, [0], mesh))
    if leaf == "x_proj":                          # (din, r+2n)
        return out(_first_fit(core, [0], mesh))
    if leaf == "dt_proj":                         # (r, din) | (D, H)
        return out(_first_fit(core, [1], mesh))
    if leaf in ("conv_w",):                       # (din, k)
        return out(_first_fit(core, [0], mesh))
    if leaf in ("conv_b", "dt_bias", "D"):        # (din,) | (H,)
        return out(_first_fit(core, [0], mesh))
    if leaf == "A_log":                           # (din, n) | (H,)
        return out(_first_fit(core, [0], mesh))
    if leaf in ("B_proj", "C_proj"):              # (D, n): n tiny, replicate
        return out([None, None])
    if leaf == "vision_adapter":                  # (D, D)
        return out([None, None])
    # scales, norms, anything unmatched: replicate
    return out([None] * len(core))


def infer_param_specs(params_shape, mesh):
    """PartitionSpec pytree matching a params pytree (of arrays/structs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [_param_rule(path, leaf.shape, mesh) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_shape, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        infer_param_specs(params_shape, mesh),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(cfg, batch_shape_tree, mesh):
    """Input batch PartitionSpecs; batch dim on the batch axes when it
    divides, otherwise sequence-sharded (batch-1 long-context)."""
    ba = batch_axes(mesh)
    dp = axis_size(mesh, ba)

    def spec_for(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        name = names[-1]
        shape = leaf.shape
        if name == "positions":                   # (3, B, S)
            b_ok = shape[1] % dp == 0
            return P(None, ba if b_ok else None, None)
        # (B, ...) leaves
        b_ok = shape[0] % dp == 0
        if b_ok:
            return P(ba, *([None] * (len(shape) - 1)))
        if len(shape) >= 2 and shape[1] % dp == 0 and shape[1] > 1:
            return P(None, ba, *([None] * (len(shape) - 2)))  # shard seq
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shape_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def cache_specs(cfg, cache_shape_tree, mesh):
    """KV/SSM cache PartitionSpecs.

    Priority: shard batch on the batch axes; shard heads/inner dims on
    'model'; for batch-1 long-context shard the *sequence* dim of KV caches
    on the batch axes (SP) so a 500k cache fits.
    """
    ba = batch_axes(mesh)
    dp = axis_size(mesh, ba)

    def spec_for(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        name = names[-1]
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv", "k_scale", "v_scale"):
            # (L, B, S, KV, hd) -- scale buffers share the layout
            # batch over the batch axes, SEQUENCE over 'model': decode
            # attention over a seq-sharded cache only communicates the
            # per-row softmax stats + a psum of the tiny context vector,
            # independent of GQA head divisibility.  (Sharding kv-heads or
            # head_dim instead forced per-layer gathers of the whole cache
            # -- 112-201 GB/device on deepseek-67b decode; see §Perf.)
            mp = axis_size(mesh, ("model",))
            b_ax = ba if shape[1] % dp == 0 else None
            if b_ax is not None and shape[3] % mp == 0:
                # kv heads divide the model axis: grouped decode attention
                # is then fully local -- the best case (no collectives)
                return P(None, b_ax, None, "model", None)
            if b_ax is None and shape[2] % (dp * mp) == 0:
                s_ax = (tuple(ba) + ("model",))   # batch-1 long context
            elif shape[2] % mp == 0:
                s_ax = "model"
            else:
                s_ax = None
            return P(None, b_ax, s_ax, None, None)
        if name == "h":                           # (L,B,din,n)|(L,B,H,P,n)
            b_ax = ba if shape[1] % dp == 0 else None
            inner = "model" if shape[2] % axis_size(mesh, "model") == 0 \
                else None
            rest = [None] * (len(shape) - 3)
            return P(None, b_ax, inner, *rest)
        if name == "conv":                        # (L, B, k-1, din)
            b_ax = ba if shape[1] % dp == 0 else None
            d_ax = "model" if shape[3] % axis_size(mesh, "model") == 0 \
                else None
            return P(None, b_ax, None, d_ax)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
