"""Activation sharding constraints (sequence parallelism for residuals).

The layer-scan carry (the residual stream) is saved once per layer for the
backward pass; unconstrained it is replicated along the ``model`` axis and
dominates HBM (e.g. deepseek-67b train_4k: 95 x 1 GiB/device).  Constraining
it to P(batch, "model", None) -- Megatron-style sequence parallelism -- lets
GSPMD store one seq-shard per device and insert the all-gather only where a
matmul actually needs the full sequence.

The registry is process-global and set by the step builders (train step,
dry-run, serve engine) before tracing; model code calls ``constrain`` with a
role name and is a no-op when no sharding is registered (CPU tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel.mesh import axis_size, batch_axes

_REGISTRY: dict = {}


def set_mesh_shardings(mesh) -> None:
    """Register default activation shardings for ``mesh`` (respects the
    active parallelism strategy -- see parallel.mesh.set_strategy)."""
    from repro.parallel.mesh import get_strategy, tp_size
    ba = batch_axes(mesh)
    _REGISTRY.clear()
    _REGISTRY["mesh"] = mesh
    _REGISTRY["strategy"] = get_strategy()
    if get_strategy() == "dp":
        _REGISTRY["residual"] = NamedSharding(mesh, P(ba, None, None))
        _REGISTRY["residual_b1"] = NamedSharding(mesh, P(None, None, None))
    else:
        _REGISTRY["residual"] = NamedSharding(mesh, P(ba, "model", None))
        _REGISTRY["residual_b1"] = NamedSharding(mesh,
                                                 P(None, "model", None))
    # SSM residuals: the time scan needs the whole (ordered) sequence per
    # shard, so sequence-sharding would force a gather per layer -- shard
    # batch only and let d_inner shard through the weights instead.
    _REGISTRY["residual_ssm"] = NamedSharding(mesh, P(ba, None, None))
    _REGISTRY["dp_size"] = axis_size(mesh, ba)
    _REGISTRY["mp_size"] = tp_size(mesh)


def clear() -> None:
    _REGISTRY.clear()


def constrain_heads(x):
    """(b, s, h, hd): batch on the batch axes, heads on 'model'."""
    if not _REGISTRY or x.ndim != 4:
        return x
    mesh = _REGISTRY.get("mesh")
    b, s, h, hd = x.shape
    dp = _REGISTRY.get("dp_size", 1)
    mp = _REGISTRY.get("mp_size", 1)
    b_ax = batch_axes(mesh) if b % dp == 0 else None
    h_ax = "model" if (mp > 1 and h % mp == 0) else None
    if h_ax is None and b_ax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, None, h_ax, None)))


def constrain_expert(x):
    """(b, E, C, d): batch on the batch axes, experts on 'model' (the
    expert-parallel all-to-all happens at this constraint)."""
    if not _REGISTRY or x.ndim != 4:
        return x
    mesh = _REGISTRY.get("mesh")
    b, e = x.shape[0], x.shape[1]
    b_ax = batch_axes(mesh) if b % _REGISTRY["dp_size"] == 0 else None
    e_ax = "model" if (_REGISTRY["mp_size"] > 1
                       and e % _REGISTRY["mp_size"] == 0) else None
    if b_ax is None and e_ax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, e_ax, None, None)))


def constrain_ec(x):
    """(b, E*C, d): expert-slot axis on 'model'.  Constraining the FLAT
    tensor right after the dispatch gather puts the all-to-all on the
    resharding edge itself, so the gather's backward scatter stays local
    (constraining after the reshape let GSPMD replicate dxe instead:
    +29 GB/device/layer of all-gather -- see EXPERIMENTS §Perf)."""
    if not _REGISTRY or x.ndim != 3:
        return x
    mesh = _REGISTRY.get("mesh")
    b, ec = x.shape[0], x.shape[1]
    b_ax = batch_axes(mesh) if b % _REGISTRY["dp_size"] == 0 else None
    e_ax = "model" if (_REGISTRY["mp_size"] > 1
                       and ec % _REGISTRY["mp_size"] == 0) else None
    if b_ax is None and e_ax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, e_ax, None)))


def constrain_tokens(x):
    """(b, T, d): data-parallel tokens (the return a2a of the MoE)."""
    if not _REGISTRY or x.ndim != 3:
        return x
    mesh = _REGISTRY.get("mesh")
    b_ax = batch_axes(mesh) if x.shape[0] % _REGISTRY["dp_size"] == 0 \
        else None
    if b_ax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, None, None)))


_F32_KEEP = {"dt_proj", "dt_bias", "A_log", "D", "router"}


def gather_layer_params(lp):
    """FSDP per-layer weight gather, in bf16, keeping 'model' dims sharded.

    Without this, GSPMD keeps the FSDP (data-axis) shard of each weight in
    the contraction and reduces the *activation-sized f32 output* over the
    data axis every layer (measured 1.5-2 GB/device/layer on the train
    cells).  Constraining the bf16-cast weights to their model-only layout
    inside the scan body makes the gather move only the weight's model
    shard (~W_layer/16) and keeps every contraction data-local -- the
    standard FSDP + tensor-parallel execution pattern.

    f32-critical leaves (SSM dt/A/D, router) keep their dtype; they are
    tiny and gathered as-is.
    """
    if not _REGISTRY:
        return lp
    mesh = _REGISTRY.get("mesh")
    from jax.sharding import PartitionSpec
    from repro.parallel import sharding as shd

    flat, tdef = jax.tree_util.tree_flatten_with_path(lp)
    out = []
    for path, w in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        spec = shd._param_rule(path, w.shape, mesh)
        model_only = PartitionSpec(
            *[a if a == "model" else None for a in spec])
        if w.dtype == jnp.float32 and name not in _F32_KEEP:
            w = w.astype(jnp.bfloat16)
        out.append(jax.lax.with_sharding_constraint(
            w, NamedSharding(mesh, model_only)))
    # the gather is loop-invariant; without a barrier XLA hoists it out of
    # the layer scan and materialises EVERY layer's gathered weights at
    # once (310 GiB/device on deepseek-moe -- §Perf).  The barrier pins one
    # layer's gather inside its scan iteration.
    out = list(jax.lax.optimization_barrier(tuple(out)))
    return jax.tree_util.tree_unflatten(tdef, out)


def constrain(x, role: str = "residual"):
    """Apply a registered sharding constraint if shapes allow it."""
    if not _REGISTRY or x.ndim != 3:
        return x
    b, s, _ = x.shape
    mp = _REGISTRY.get("mp_size", 1)
    dp = _REGISTRY.get("dp_size", 1)
    if role == "residual_ssm":
        if b % dp != 0:
            return x
        return jax.lax.with_sharding_constraint(
            x, _REGISTRY["residual_ssm"])
    if s % mp != 0 or s == 1:
        return x  # decode steps / indivisible seq: leave to GSPMD
    key = "residual" if b % dp == 0 else "residual_b1"
    sh = _REGISTRY.get(key)
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
