"""Mesh construction + axis conventions.

Logical axis convention (MaxText-flavoured):
  * ``batch``  -> all non-model mesh axes (("pod", "data") on the multi-pod
                  mesh, ("data",) on one pod) -- DP.
  * ``model``  -> tensor/expert parallel axis -- TP/EP.
  * sequence-sharding (SP) reuses the batch axes for batch-1 long-context.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))


_STRATEGY = {"mode": "2d"}


def set_strategy(mode: str) -> None:
    """Parallelism strategy: '2d' = DP(+FSDP) x TP (default);
    'dp' = ZeRO-3 data parallelism over ALL mesh axes (no tensor
    parallelism).  With 1M-token global batches the per-layer FSDP weight
    gather (bf16 W/layer) is far cheaper than TP's per-layer activation
    reshards -- see EXPERIMENTS §Perf."""
    assert mode in ("2d", "dp"), mode
    _STRATEGY["mode"] = mode


def get_strategy() -> str:
    return _STRATEGY["mode"]


def batch_axes(mesh) -> tuple:
    """All mesh axes that carry the batch."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    if _STRATEGY["mode"] == "dp":
        axes = axes + ("model",)
    return axes


def tp_size(mesh) -> int:
    """Tensor-parallel degree under the active strategy."""
    return 1 if _STRATEGY["mode"] == "dp" else mesh.shape["model"]


def axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
