"""Gym-style environment API over the CRRM episode engine.

``CrrmEnv`` (``crrm_env.py``) is the functional core: pure ``reset``/
``step`` over an explicit ``EpisodeState`` pytree, batched over seeds with
``jax.vmap`` so N parallel episodes compile to one program.  The optional
``gym_adapter`` wraps it in the stateful ``gymnasium.Env`` protocol for
off-the-shelf RL frameworks (import-gated: gymnasium is not a hard
dependency).  See DESIGN.md §Env-API.
"""
from repro.env.crrm_env import (CrrmEnv, EnvObs, TopoEnvState,  # noqa: F401
                                buffer_aware_reward)
