"""Optional gymnasium adapter for :class:`~repro.env.crrm_env.CrrmEnv`.

The functional env is the source of truth; this module wraps one episode
stream in the stateful ``gymnasium.Env`` protocol (``reset``/``step`` with
numpy i/o and Box spaces) so off-the-shelf RL frameworks can drive the
simulator unmodified.  gymnasium is NOT a hard dependency: importing this
module is cheap, and :func:`make_gym_env` raises a clear ``ImportError``
only when called without gymnasium installed.
"""
from __future__ import annotations

import numpy as np

from repro.env.crrm_env import CrrmEnv

#: stand-in for +inf in observation bounds (throughput, backlog are
#: unbounded above; full-buffer backlog is genuinely inf and is clamped)
_OBS_HIGH = np.float32(3.4e38)


def flatten_obs(obs) -> np.ndarray:
    """EnvObs -> flat (2 * n_ues,) float32 vector (backlog inf clamped)."""
    tput = np.asarray(obs.tput, np.float32)
    backlog = np.minimum(np.asarray(obs.backlog, np.float32), _OBS_HIGH)
    return np.concatenate([tput, backlog])


def make_gym_env(env: CrrmEnv, seed: int = 0):
    """Wrap a functional ``CrrmEnv`` in a ``gymnasium.Env``.

    Observation: ``Box(0, inf, (2 * n_ues,))`` -- per-UE delivered
    throughput then residual backlog.  Action: ``Box(0, power_W,
    (n_cells, n_subbands))`` transmit powers in watts.  Episode end is
    reported as ``truncated`` (a time horizon, not a terminal MDP state).

    A ``CrrmEnv(..., telemetry=True)`` surfaces its per-TTI KPI stream in
    the gymnasium info dict: ``info["telemetry"]`` is the raw
    ``repro.obs.Telemetry`` stack for the decision window and
    ``info["kpis"]`` its ``repro.obs.summarize`` reduction to plain
    floats (what RL loggers can emit directly) -- including ``mean_jain``
    and, under churn, ``mean_active_ues`` -- plus the per-cell/per-term
    reward decomposition under ``reward/...`` keys
    (``repro.env.crrm_env.reward_components``).
    """
    try:
        import gymnasium
        from gymnasium import spaces
    except ImportError as e:     # pragma: no cover - exercised without gym
        raise ImportError(
            "gymnasium is required for the adapter: pip install gymnasium "
            "(the functional CrrmEnv works without it)") from e

    import jax

    class GymCrrmEnv(gymnasium.Env):
        metadata = {"render_modes": []}

        def __init__(self, fenv: CrrmEnv, seed: int):
            self._env = fenv
            self._key = jax.random.PRNGKey(seed)
            self._state = None
            n = fenv.n_ues
            self.observation_space = spaces.Box(
                low=0.0, high=_OBS_HIGH, shape=(2 * n,), dtype=np.float32)
            self.action_space = spaces.Box(
                low=0.0, high=fenv.max_cell_power_W,
                shape=fenv.action_shape, dtype=np.float32)

        def reset(self, *, seed=None, options=None):
            # gymnasium contract: seed=None continues the RNG stream (a
            # fresh stochastic episode per reset); an explicit seed
            # restarts it reproducibly.
            super().reset(seed=seed)
            if seed is not None:
                self._key = jax.random.PRNGKey(seed)
            self._key, ep_key = jax.random.split(self._key)
            self._state, obs = self._env.reset(ep_key)
            return flatten_obs(obs), {}

        def step(self, action):
            action = np.clip(np.asarray(action, np.float32),
                             self.action_space.low, self.action_space.high)
            out = self._env.step(self._state, action)
            info = {}
            if self._env.telemetry:
                self._state, obs, reward, done, step_info = out
                from repro.obs import summarize
                telem = step_info["telemetry"]
                kpis = summarize(telem, tti_s=self._env.params.tti_s)
                # flatten the reward decomposition into the KPI dict:
                # scalars as floats, per-cell vectors as numpy arrays --
                # what RL loggers can emit directly
                for k, v in step_info["reward_components"].items():
                    v = np.asarray(v)
                    kpis[f"reward/{k}"] = (float(v) if v.ndim == 0 else v)
                info = {"telemetry": telem, "kpis": kpis}
            else:
                self._state, obs, reward, done = out
            return (flatten_obs(obs), float(reward),
                    False, bool(done), info)

    return GymCrrmEnv(env, seed)
