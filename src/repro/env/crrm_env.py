"""CrrmEnv: a functional, batched, gym-style environment over CRRM.

The RL integration the paper targets, as a *pure-functional* env (the
gymnasium adapter in ``repro.env.gym_adapter`` is a thin stateful shim):

* ``reset(key) -> (state, EnvObs)`` and
  ``step(state, action) -> (state, EnvObs, reward, done)`` are pure
  functions of their arguments -- no hidden attributes, so episodes can be
  checkpointed, replayed, or driven by any external RL loop;
* both ``vmap`` over the state (and action) axis: ``reset_batch`` /
  ``step_batch`` run N parallel episodes -- N seeds, N candidate actions --
  as ONE compiled program (one trace, one device launch), which is what
  makes population-based and evolutionary methods cheap
  (``benchmarks.paper_benches.env_episode`` gates the per-episode cost);
* the *action* is a per-cell/subband transmit-power matrix (the classic
  RRM control surface); each ``step`` holds it for ``tti_per_step`` TTIs
  of the scan-compiled MAC engine and observes the delivered throughput
  and residual backlog.

Two batching axes (DESIGN.md §Radio-fns):

* default (``resample_topology=False``): the radio topology (positions,
  cells, fading draw) is frozen at construction from the underlying
  ``CRRM`` graph -- batching is over *episode randomness* (traffic
  arrivals, HARQ outcomes, per-TTI fading), the Monte-Carlo axis RL
  training sweeps.  The threaded state is a bare ``EpisodeState``.
* ``resample_topology=True``: every ``reset`` redraws the UE field (the
  PPP-conditioned uniform draw of the deploy config) and the fading from
  its seed, recomputes the whole radio chain *inside reset* with the pure
  ``radio.radio_forward``, and threads the per-episode radio inputs
  alongside the MAC state as a :class:`TopoEnvState`.  ``reset_batch`` /
  ``step_batch`` then vmap over *topologies*: N seeds = N different UE
  fields, one compiled program.

Construct from explicit ``CRRM_parameters`` or a named preset of
``repro.sim.scenarios``:

>>> env = CrrmEnv(scenario="dense_urban", scenario_overrides=dict(n_ues=50))
>>> state, obs = env.reset(jax.random.PRNGKey(0))
>>> state, obs, reward, done = env.step(state, env.uniform_action())
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters
from repro.sim import radio


class EnvObs(NamedTuple):
    """What the agent sees after one decision step.

    ``tput`` is the mean delivered throughput over the decision window
    (bits/s per UE); ``backlog`` the residual queued bits at its end
    (``inf`` under full-buffer traffic).
    """

    tput: Any
    backlog: Any


class TopoEnvState(NamedTuple):
    """The threaded state of a topology-resampling episode.

    The mutable MAC carry (``ep``: an ``EpisodeState``) plus the episode's
    own radio inputs (``static``: an ``EpisodeStatic`` recomputed by
    ``reset`` for its topology draw).  A plain pytree, so batches of
    episodes -- each with its *own* UE field -- vmap as one program.
    """

    ep: Any
    static: Any


def expand_action(params, action):
    """(n_cells, n_subbands) watts -> the (n_cells, n_freq) power grid.

    The one action-conditioning convention, shared by :class:`CrrmEnv`
    and the differentiable optimizer (``repro.rl.diffopt``): clamp each
    cell's total to the ``power_W`` budget (soft -- rows under budget
    pass through, so the clamp is differentiable a.e.), then split each
    subband's power evenly over its ``n_rb_subbands`` CQI chunks.
    """
    action = jnp.asarray(action, jnp.float32)
    total = action.sum(axis=-1, keepdims=True)
    budget = params.power_W
    action = action * jnp.minimum(1.0, budget / jnp.maximum(total, 1e-30))
    s = params.n_rb_subbands
    if s > 1:
        action = jnp.repeat(action, s, axis=-1) / s
    return action


def reward_components(obs: EnvObs, telem, tti_s: float):
    """The per-cell / per-term decomposition of the default reward.

    Returns a flat dict of traced arrays (vmap-able, so ``step_batch``
    stacks each entry over the batch axis): the two scalar terms of
    :func:`buffer_aware_reward` (``goodput_term`` minus ``queue_penalty``
    IS the default reward) plus the per-cell credit assignment RL
    diagnostics want -- which cells' serving throughput and grant share
    moved under the candidate power plan.
    """
    goodput = jnp.log(jnp.maximum(obs.tput, 1e3)).mean()
    queued = jnp.where(jnp.isfinite(obs.backlog),
                       jnp.log1p(obs.backlog / 1e4), 0.0)
    n_tti = telem.served_bits.shape[0]
    return {
        "goodput_term": goodput,
        "queue_penalty": 0.05 * queued.mean(),
        # (n_cells,) mean delivered rate / granted RBs per serving cell
        "cell_tput_mbps": telem.served_bits.sum(axis=0)
                          / (n_tti * tti_s) / 1e6,
        "cell_granted_rb": telem.granted_rb.mean(axis=0),
    }


def buffer_aware_reward(obs: EnvObs):
    """Default reward: geometric-mean goodput minus a queueing penalty.

    The objective of the RL power-control example: log-throughput rewards
    cell-edge fairness, the ``log1p`` backlog term penalises queues the
    chosen power plan cannot drain.  Full-buffer UEs (infinite backlog by
    construction) are exempt from the queue term.
    """
    goodput = jnp.log(jnp.maximum(obs.tput, 1e3)).mean()
    queued = jnp.where(jnp.isfinite(obs.backlog),
                       jnp.log1p(obs.backlog / 1e4), 0.0)
    return goodput - 0.05 * queued.mean()


class CrrmEnv:
    """Batched gym-style environment over the scan-compiled MAC engine.

    Parameters
    ----------
    params:
        Explicit ``CRRM_parameters`` (mutually exclusive with ``scenario``).
    scenario, scenario_overrides:
        A named preset from ``repro.sim.scenarios`` plus per-field
        overrides -- the reproducible way to define an RL task.
    episode_tti:
        Episode horizon; ``done`` once the state's TTI counter reaches it.
    tti_per_step:
        MAC TTIs rolled (as one ``lax.scan``) per ``step`` call -- the
        agent's decision interval.
    per_tti_fading:
        Redraw fast fading every TTI inside the scan (otherwise the
        construction-time draw stays frozen).
    resample_topology:
        Redraw the UE field + fading per ``reset`` seed and recompute the
        radio chain inside ``reset`` (``radio.radio_forward``): batching
        over *topologies*, not just episode randomness.  The threaded
        state becomes a :class:`TopoEnvState`.
    reward_fn:
        ``EnvObs -> scalar``; defaults to :func:`buffer_aware_reward`.
    radio_mode:
        Radio execution mode inside the scan (``"dense"`` |
        ``"incremental"``; ``None`` defers to ``params.radio_mode``).
        ``"incremental"`` removes the action step's radio-recompute tax:
        the action is held constant over the ``tti_per_step`` scan, so
        its radio chain is computed ONCE per ``step`` (the prepare-time
        ``radio_init``) instead of every TTI -- asserted cheaper than the
        dense recompute in ``benchmarks/BENCH_env.json``.  (The remaining
        action-vs-passive gap is the schedulers' per-cell scatters over
        *per-episode* attachment indices under ``vmap`` -- a MAC cost,
        not a radio one; see DESIGN.md §Smart-update-in-scan.)
    telemetry:
        Stream per-TTI KPIs (``repro.obs.Telemetry``) out of the scan:
        ``step`` then returns a fifth element, an info dict with a
        ``"telemetry"`` entry stacked to (tti_per_step, ...) plus the
        ``"reward_components"`` decomposition RL logging wants
        (DESIGN.md §Observability).  A trace-time switch -- the
        trajectory is bit-identical either way, and off (the default)
        compiles the exact legacy program.
    churn:
        A ``sim.mobility.ChurnConfig``: the birth-death UE process runs
        inside every decision window's scan (the capacity-padded
        ``active`` mask rides the threaded state), and the telemetry
        KPIs gain ``mean_active_ues`` (DESIGN.md §Digital-twin-serving).
        Incompatible with ``resample_topology``.
    faults:
        A ``sim.faults.FaultConfig``: the in-scan cell fault process
        (DESIGN.md §Fault-injection-and-self-healing) -- cells drop in
        and out of outage inside every decision window, and the
        telemetry KPIs gain ``mean_cells_down`` / ``reattach_events``.
        Defaults to ``params.faults`` (the ``outage_storm`` preset
        bakes one in); pass ``0`` to force the fault-free program.
    mesh, ue_axis:
        Shard the UE axis of the episode engine over a device mesh
        (``episode_fns(mesh=)``).  The sharded program spans the
        devices, so the ``vmap`` batch surfaces (``reset_batch`` /
        ``step_batch`` / ``step_autoreset_batch``) raise -- batch over
        seeds OR shard over UEs, not both.
    """

    def __init__(self, params: Optional[CRRM_parameters] = None, *,
                 scenario: Optional[str] = None,
                 scenario_overrides: Optional[dict] = None,
                 episode_tti: int = 200, tti_per_step: int = 20,
                 per_tti_fading: bool = False,
                 resample_topology: bool = False, reward_fn=None,
                 radio_mode: Optional[str] = None,
                 telemetry: bool = False, churn=None, faults=None,
                 mesh=None, ue_axis=("ue",)):
        if (params is None) == (scenario is None):
            raise ValueError("pass exactly one of params= or scenario=")
        if scenario is not None:
            from repro.sim.scenarios import make_scenario
            params = make_scenario(scenario, **(scenario_overrides or {}))
        elif scenario_overrides:
            raise ValueError("scenario_overrides requires scenario=")
        if episode_tti < 1 or tti_per_step < 1:
            raise ValueError("episode_tti and tti_per_step must be >= 1")
        if churn is not None and resample_topology:
            raise ValueError(
                "churn= is incompatible with resample_topology=True: a "
                "resampled reset rebuilds EpisodeStatic per topology draw "
                "while churn carries its fading leaf in the state; run "
                "churn on the fixed construction-time topology")
        self.scenario = scenario
        self.episode_tti = int(episode_tti)
        self.tti_per_step = int(tti_per_step)
        self.resample_topology = bool(resample_topology)
        self.sim = CRRM(params)
        self.params = self.sim.params
        self.n_ues, self.n_cells = self.sim.n_ues, self.sim.n_cells
        self.n_subbands = self.params.n_subbands
        self._reward_fn = reward_fn or buffer_aware_reward
        self.telemetry = bool(telemetry)
        self.churn = churn
        self.faults = faults
        self.mesh = mesh
        self._fns = self.sim.episode_fns(per_tti_fading=per_tti_fading,
                                         radio_mode=radio_mode,
                                         telemetry=self.telemetry,
                                         churn=churn, faults=faults,
                                         mesh=mesh, ue_axis=ue_axis)
        self._static = self.sim.episode_static()
        self._radio_static = self.sim.radio_static()
        # the reset template: PF EWMA seeded at the stationary alpha-fair
        # point, empty HARQ processes, attachment-serving, t=0
        self._state0 = self.sim.init_episode_state()
        if churn is not None:
            from repro.mac.engine import seed_churn_state
            self._state0 = seed_churn_state(
                self._state0, self._static, self.params,
                per_tti_fading=per_tti_fading)
        self._batched = {}          # cached jit(vmap(...)) wrappers

    # ------------------------------------------------------------- actions
    @property
    def action_shape(self) -> tuple:
        """(n_cells, n_subbands): per-cell/subband tx power in watts."""
        return (self.n_cells, self.n_subbands)

    @property
    def max_cell_power_W(self) -> float:
        """Per-cell power budget in watts.  Also the per-(cell, subband)
        action bound: a cell may concentrate its whole budget on one
        subband, and :meth:`step` scales down any action whose per-cell
        total exceeds the budget, so rewards are always comparable across
        candidate plans."""
        return float(self.params.power_W)

    def uniform_action(self):
        """The baseline plan: every cell splits its budget evenly."""
        return jnp.full(self.action_shape,
                        self.params.power_W / self.n_subbands, jnp.float32)

    def _expand_action(self, action):
        """(n_cells, n_subbands) watts -> the (n_cells, n_freq) grid the
        engine schedules on.  Enforces the per-cell power budget (rows
        whose total exceeds ``power_W`` are scaled down -- actions are
        *requests*, the cell amplifier is the constraint), then splits
        each subband's power evenly over its CQI chunks (same convention
        as ``CRRM.set_power_matrix``)."""
        return expand_action(self.params, action)

    # ---------------------------------------------------------- pure core
    def _resampled_reset(self, key):
        """Draw a topology from ``key`` and run the radio chain on it.

        The key convention (``radio.reset_keys``) splits the seed into
        (topology, fading, episode) streams; the UE field is the same
        PPP-conditioned uniform draw the ``CRRM`` constructor uses, the
        fading comes from the ONE documented draw (``radio.draw_fading``),
        and the chain itself is one pure ``radio.radio_forward`` call --
        no graph, so the whole reset jits and vmaps.
        """
        p = self.params
        k_topo, k_fad, k_ep = radio.reset_keys(key)
        from repro.sim.deploy import ppp_points
        U = ppp_points(k_topo, self.n_ues, p.extent_m, z=p.h_ut_m)
        cfg = self._radio_static.cfg
        if p.rayleigh_fading:
            fad = radio.draw_fading(cfg, k_fad, self.n_ues, self.n_cells)
        else:
            fad = radio.unit_fading(cfg, self.n_ues, self.n_cells)
        out = radio.radio_forward(self._radio_static, U, fad=fad)
        static = self._static._replace(se=out.se, cqi=out.cqi, a=out.a,
                                       fad=fad)
        # seed the PF EWMA at this topology's stationary alpha-fair point
        # (the pure twin of what init_episode_state reads off the graph)
        from repro.mac.engine import stationary_served_tput
        pf0 = stationary_served_tput(p, self.n_cells, out.se, out.cqi,
                                     out.a, self._state0.backlog)
        ep = self._state0._replace(U=U, key=k_ep, pf_avg=pf0, serving=out.a)
        return TopoEnvState(ep=ep, static=static)

    def reset(self, key):
        """Start one episode: ``(state, EnvObs)`` for this seed.

        Pure.  Default: the template state is frozen at construction and
        only the PRNG key (traffic, HARQ, per-TTI fading randomness)
        varies per episode.  With ``resample_topology=True`` the UE field
        and fading are redrawn from the seed and the radio chain is
        recomputed here (one ``radio.radio_forward``), so
        ``jax.vmap(env.reset)(keys)`` batches over *topologies*.
        """
        if self.resample_topology:
            state = self._resampled_reset(key)
            backlog = state.ep.backlog
        else:
            state = self._state0._replace(key=key)
            backlog = state.backlog
        obs = EnvObs(tput=jnp.zeros((self.n_ues,), jnp.float32),
                     backlog=backlog)
        return state, obs

    def step(self, state, action=None, fairness_p=None):
        """Hold ``action`` for ``tti_per_step`` TTIs; observe and score.

        ``action`` is a (n_cells, n_subbands) power matrix (None keeps the
        construction-time power plan -- a pure traffic simulation step);
        ``fairness_p`` a traced scalar overriding the PF alpha-fairness
        exponent for the window (None keeps ``params.fairness_p``) -- the
        second control surface PPO policies steer.
        Returns ``(state, EnvObs, reward, done)``; pure and vmap-able over
        ``(state, action, fairness_p)``.  Constructed with
        ``telemetry=True`` a fifth element is appended:
        ``{"telemetry": Telemetry, "reward_components": dict}`` with each
        KPI leaf stacked to (tti_per_step, ...) and the reward decomposed
        per term and per cell (:func:`reward_components`).
        """
        if self.resample_topology:
            ep, static = state.ep, state.static
        else:
            ep, static = state, self._static
        power = None if action is None else self._expand_action(action)
        telem = None
        if self.telemetry:
            ep, tput, telem = self._fns.rollout(static, ep,
                                                self.tti_per_step, power,
                                                fairness_p)
        else:
            ep, tput = self._fns.rollout(static, ep, self.tti_per_step,
                                         power, fairness_p)
        obs = EnvObs(tput=tput.mean(axis=0), backlog=ep.backlog)
        reward = self._reward_fn(obs)
        done = ep.t >= self.episode_tti
        if self.resample_topology:
            state = TopoEnvState(ep=ep, static=static)
        else:
            state = ep
        if self.telemetry:
            info = {"telemetry": telem,
                    "reward_components": reward_components(
                        obs, telem, self.params.tti_s)}
            return state, obs, reward, done, info
        return state, obs, reward, done

    def step_autoreset(self, state, action=None, reset_key=None,
                       fairness_p=None):
        """:meth:`step`, restarting finished episodes from ``reset_key``.

        The continuous-rollout primitive PPO collection scans over: when
        the stepped episode reports ``done``, every leaf of the returned
        state is swapped (``jnp.where`` select, both branches computed --
        no control flow, so the function stays pure, jit- and vmap-able)
        for a fresh :meth:`reset` of ``reset_key``.  The *returned*
        ``obs``/``reward``/``done``/info are the pre-reset ones -- the
        terminal transition stays visible to GAE bootstrapping; only the
        carried state jumps.  Requires ``resample_topology=False`` (a
        resampled reset runs the radio chain per boundary -- pay for that
        explicitly via :meth:`reset` if you want it).
        """
        if self.resample_topology:
            raise ValueError(
                "step_autoreset requires resample_topology=False: the "
                "in-scan reset would recompute the radio chain at every "
                "episode boundary; drive resampled episodes with explicit "
                "reset() calls instead")
        if reset_key is None:
            raise ValueError("step_autoreset needs reset_key= (the seed "
                             "of the replacement episode)")
        out = self.step(state, action, fairness_p)
        state, obs, reward, done = out[:4]
        fresh, _ = self.reset(reset_key)
        # done is a scalar here (vmap maps this whole function per
        # episode), so one where() selects every leaf regardless of rank
        state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(done, new, old), fresh, state)
        return (state, obs, reward, done) + out[4:]

    # ------------------------------------------------------------- batched
    def _vmapped(self, name):
        """jit(vmap(...)) wrappers, traced once per (name, batch shape)."""
        if self.mesh is not None:
            raise ValueError(
                "batched env surfaces (reset_batch/step_batch/"
                "step_autoreset_batch) are unsupported under mesh=: the "
                "UE-sharded program already spans the devices; batch over "
                "seeds OR shard over UEs, not both")
        if name not in self._batched:
            fn = {"reset": self.reset,
                  "step": self.step,
                  "step_fair": self.step,
                  "step_auto": lambda s: self.step(s, None),
                  "step_ar": lambda s, a, k: self.step_autoreset(s, a, k),
                  "step_ar_fair": self.step_autoreset,
                  }[name]
            self._batched[name] = jax.jit(jax.vmap(fn))
        return self._batched[name]

    def reset_batch(self, keys):
        """N parallel episodes from N seeds: one compiled program.  With
        ``resample_topology`` each seed owns its own UE field -- the batch
        axis runs over topologies."""
        return self._vmapped("reset")(keys)

    def step_batch(self, states, actions=None, fairness_p=None):
        """Advance N episodes (optionally under N candidate actions /
        alpha-fairness scalars) as one compiled program -- the batch axis
        is free parallelism."""
        if actions is None:
            return self._vmapped("step_auto")(states)
        if fairness_p is None:
            return self._vmapped("step")(states, actions)
        return self._vmapped("step_fair")(states, actions, fairness_p)

    def step_autoreset_batch(self, states, actions, reset_keys,
                             fairness_p=None):
        """Batched :meth:`step_autoreset`: N episodes stepped under N
        actions, each restarting from its own ``reset_keys`` row when it
        finishes -- the PPO rollout-collection kernel."""
        if fairness_p is None:
            return self._vmapped("step_ar")(states, actions, reset_keys)
        return self._vmapped("step_ar_fair")(states, actions, reset_keys,
                                             fairness_p)
