"""The jitted, sharded train/serve steps.

``make_train_step`` builds one jit-compiled function:
    state {params, opt, step} , batch -> state', metrics
with explicit in/out shardings from the logical-axis rules, donated state
(in-place optimizer update), optional microbatch gradient accumulation
(lax.scan over grad microbatches -- the activation-memory lever), and the
MoE aux loss where applicable.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as shd
from repro.train.loss import chunked_cross_entropy, cross_entropy


def make_loss_fn(arch, *, loss_chunk: int = 512):
    """Backbone features + sequence-chunked CE: the full (b, s, vocab)
    logits tensor never materialises (see loss.chunked_cross_entropy)."""
    def loss_fn(params, batch):
        feats = arch.forward_features(params, batch)
        return chunked_cross_entropy(
            lambda x: arch.head(params, x), feats, batch["labels"],
            chunk=loss_chunk, mask=batch.get("mask"))
    return loss_fn


def make_train_step(arch, optimizer, *, accum_steps: int = 1):
    """Returns f(state, batch) -> (state, metrics); pure, jit-able."""
    loss_fn = make_loss_fn(arch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def step(state, batch):
        params, opt_state = state["params"], state["opt"]
        if accum_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            # microbatch accumulation: batch dims reshaped (A, B/A, ...);
            # M-RoPE position ids carry batch at dim 1 ((3, B, S))
            def to_micro(path, x):
                name = str(getattr(path[-1], "key", path[-1]))
                if name == "positions":
                    y = x.reshape(x.shape[:1]
                                  + (accum_steps, x.shape[1] // accum_steps)
                                  + x.shape[2:])
                    return jnp.moveaxis(y, 1, 0)
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])

            micro = jax.tree_util.tree_map_with_path(to_micro, batch)

            def acc(carry, mb):
                g, _ = grads_of(params, mb)
                return jax.tree_util.tree_map(jnp.add, carry, g), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, _ = jax.lax.scan(acc, zeros, micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            _, metrics = grads_of(params, jax.tree_util.tree_map(
                lambda x: x[0], micro))  # metrics on first microbatch
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = dict(metrics, **opt_metrics)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def state_specs(arch, optimizer, mesh):
    """PartitionSpec tree for the full train state, via eval_shape (no
    allocation).  Optimizer moments reuse the param rules (their tree mirrors
    the params tree, so path-based rules apply unchanged)."""
    params_shape = jax.eval_shape(lambda: arch.init(jax.random.PRNGKey(0)))
    opt_shape = jax.eval_shape(lambda: optimizer.init(params_shape))
    specs = {
        "params": shd.infer_param_specs(params_shape, mesh),
        "opt": shd.infer_param_specs(opt_shape, mesh),
        "step": P(),
    }
    shapes = {"params": params_shape, "opt": opt_shape,
              "step": jax.ShapeDtypeStruct((), jnp.int32)}
    return shapes, specs


def init_state(arch, optimizer, mesh, seed: int = 0):
    """Materialise the sharded train state directly on the mesh."""
    shapes, specs = state_specs(arch, optimizer, mesh)
    out_shardings = shd.named(mesh, specs)

    def build():
        params = arch.init(jax.random.PRNGKey(seed))
        return {"params": params, "opt": optimizer.init(params),
                "step": jnp.zeros((), jnp.int32)}

    return jax.jit(build, out_shardings=out_shardings)()


def jit_train_step(arch, optimizer, mesh, batch_shapes, *,
                   accum_steps: int = 1):
    """jit with explicit shardings + donated state; also returns the
    (lowerable) function and shardings for the dry-run."""
    from repro.parallel import act_sharding
    act_sharding.set_mesh_shardings(mesh)
    step = make_train_step(arch, optimizer, accum_steps=accum_steps)
    shapes, specs = state_specs(arch, optimizer, mesh)
    b_specs = shd.batch_specs(arch.cfg, batch_shapes, mesh)
    state_sh = shd.named(mesh, specs)
    batch_sh = shd.named(mesh, b_specs)
    fn = jax.jit(step,
                 in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None),
                 donate_argnums=(0,))
    return fn, shapes, state_sh, batch_sh
