"""Training losses: cross entropy with z-loss, MoE aux loss hook."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, *, z_loss: float = 1e-4, mask=None):
    """Token-level CE in f32 with an optional z-loss regulariser.

    logits: (b, s, V) f32; labels: (b, s) int32.  Returns (loss, metrics).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc,
                  "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}


def chunked_cross_entropy(head_fn, features, labels, *, chunk: int = 512,
                          z_loss: float = 1e-4, mask=None):
    """CE over sequence chunks so the (b, s, vocab) logits never materialise.

    ``head_fn(x_chunk) -> logits_chunk``; the scan body is checkpointed, so
    the backward pass recomputes each chunk's logits instead of storing them
    -- peak memory is one (b, chunk, vocab) block.  This is what makes the
    big-vocab train cells (qwen 152k vocab at 1M tokens/step) fit HBM.
    """
    b, s, d = features.shape
    c = min(chunk, s)
    n = s // c
    assert s % c == 0, (s, c)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    feat_c = jnp.moveaxis(features.reshape(b, n, c, d), 1, 0)
    lab_c = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    mask_c = jnp.moveaxis(mask.reshape(b, n, c), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, acc_sum, cnt = carry
        f, lab, m = xs
        logits = head_fn(f).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        m = m.astype(jnp.float32)
        nll_sum = nll_sum + (nll * m).sum()
        acc_sum = acc_sum + ((jnp.argmax(logits, -1) == lab) * m).sum()
        return (nll_sum, acc_sum, cnt + m.sum()), None

    (nll_sum, acc_sum, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)),
        (feat_c, lab_c, mask_c))
    denom = jnp.maximum(cnt, 1.0)
    loss = nll_sum / denom
    return loss, {"loss": loss, "accuracy": acc_sum / denom,
                  "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}
