"""Data pipeline: deterministic synthetic LM stream + memmap shard reader,
with background prefetch.

Determinism contract (fault tolerance): batch content is a pure function of
(seed, step), so resuming from a checkpoint replays the exact stream --
nothing about the pipeline needs checkpointing beyond the step counter.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Markov-ish token stream: deterministic per (seed, step)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab, self.batch, self.seq = vocab_size, batch, seq_len
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        # low-entropy structure so the loss visibly decreases
        base = rng.integers(0, self.vocab, (self.batch, 1), dtype=np.int32)
        drift = rng.integers(0, 7, (self.batch, self.seq), dtype=np.int32)
        tokens = (base + np.cumsum(drift, axis=1)) % self.vocab
        return {"tokens": tokens.astype(np.int32),
                "labels": np.roll(tokens, -1, axis=1).astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLM:
    """Token shards on disk: flat int32 .bin files, strided per host."""

    def __init__(self, path: str, batch: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.batch, self.seq = batch, seq_len
        self.host_id, self.n_hosts = host_id, n_hosts
        self.per_step = batch * (seq_len + 1)

    def batch_at(self, step: int) -> dict:
        n = self.tokens.shape[0]
        start = (step * self.n_hosts + self.host_id) * self.per_step % \
            max(1, n - self.per_step)
        flat = np.asarray(self.tokens[start:start + self.per_step])
        flat = flat.reshape(self.batch, self.seq + 1)
        return {"tokens": flat[:, :-1].copy(),
                "labels": flat[:, 1:].copy()}


class Prefetcher:
    """Runs ``source.batch_at`` in a thread, ``depth`` batches ahead."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self.thread.start()

    def _fill(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
