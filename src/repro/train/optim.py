"""Optimizers + LR schedules, built from scratch (no optax in this stack).

Each optimizer is an (init, update) pair over pytrees; states shard exactly
like their parameters (the dry-run's memory analysis includes them).

* ``adamw``     -- the default; f32 moments.
* ``adafactor`` -- factored second moment: O(n+m) state per (n, m) matrix
                   instead of O(n*m); the memory lever for the biggest cells.
* ``sgdm``      -- baseline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


# -- schedules ----------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant_lr(lr_value: float):
    return lambda step: jnp.asarray(lr_value, jnp.float32)


# -- grad utilities ----------------------------------------------------------------
def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


# -- AdamW ----------------------------------------------------------------------------
def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          grad_clip=1.0):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree_util.tree_map(zeros, params),
                "nu": jax.tree_util.tree_map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        c = state["count"] + 1
        lr = lr_fn(c)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"],
                                     params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_m, "nu": new_v, "count": c}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


# -- Adafactor (factored second moments) ----------------------------------------------
def adafactor(lr_fn, decay=0.8, eps=1e-30, grad_clip=1.0,
              weight_decay=0.0, min_dim_size_to_factor=64):
    def _factored(shape):
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def state_for(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"m": jax.tree_util.tree_map(state_for, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        c = state["count"] + 1
        lr = lr_fn(c)
        beta = 1.0 - (c.astype(jnp.float32)) ** -decay

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                r = vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
                denom = jnp.sqrt(r[..., None] * vc[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                denom = jnp.sqrt(v)
                new_s = {"v": v}
            step = g / jnp.maximum(denom, 1e-30)
            # relative step-size clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(step * step))
            step = step / jnp.maximum(1.0, rms)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_s

        # state leaves are dicts -> flatten state only down to grads' leaves
        g_flat, tdef = jax.tree_util.tree_flatten(grads)
        s_flat = tdef.flatten_up_to(state["m"])
        p_flat = jax.tree_util.tree_leaves(params)
        pairs = [upd(g, s, p) for g, s, p in zip(g_flat, s_flat, p_flat)]
        new_p = jax.tree_util.tree_unflatten(tdef, [t[0] for t in pairs])
        new_m = jax.tree_util.tree_unflatten(tdef, [t[1] for t in pairs])
        return new_p, {"m": new_m, "count": c}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


# -- SGD + momentum -------------------------------------------------------------------
def sgdm(lr_fn, momentum=0.9, grad_clip=1.0):
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        c = state["count"] + 1
        lr = lr_fn(c)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_m, "count": c}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}
