"""The training loop: resume, preemption-safe checkpoints, async saves.

Fault-tolerance contract (1000-node posture):
  * checkpoints are atomic + keep-k (see checkpoint.py), written every
    ``ckpt_every`` steps and on SIGTERM/SIGINT (preemption hook);
  * the data stream is a pure function of (seed, step) so restart resumes
    the exact batch sequence;
  * restore reshards onto the *current* mesh -- elastic across restarts;
  * step metrics stream to stdout as CSV for the harness to scrape.
"""
from __future__ import annotations

import signal
import sys
import time
from typing import Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher


def train(arch, optimizer, mesh, data_source, *, steps: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
          keep_last: int = 3, accum_steps: int = 1, log_every: int = 10,
          seed: int = 0, resume: bool = True):
    from repro.train.step import init_state, jit_train_step

    batch0 = data_source.batch_at(0)
    batch_shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0)
    step_fn, shapes, state_sh, batch_sh = jit_train_step(
        arch, optimizer, mesh, batch_shapes, accum_steps=accum_steps)

    start_step = 0
    state = None
    if ckpt_dir and resume:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            shapes_tree = {"params": shapes["params"], "opt": shapes["opt"],
                           "step": shapes["step"]}
            state, extra = ckpt.restore(ckpt_dir, last, shapes_tree,
                                        state_sh)
            start_step = int(extra.get("train_step", last))
            print(f"# resumed from {ckpt_dir} step {start_step}",
                  flush=True)
    if state is None:
        state = init_state(arch, optimizer, mesh, seed)

    stop = {"now": False}

    def _preempt(signum, frame):
        stop["now"] = True

    old_term = signal.signal(signal.SIGTERM, _preempt)

    prefetch = Prefetcher(data_source, start_step=start_step)
    print("step,loss,accuracy,grad_norm,lr,tokens_per_s", flush=True)
    t_last, tok_count = time.perf_counter(), 0
    history = []
    pending_save = None
    try:
        for i in range(start_step, steps):
            step_no, batch = prefetch.next()
            dev_batch = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), batch, batch_sh)
            state, metrics = step_fn(state, dev_batch)
            tok_count += int(np.prod(batch["tokens"].shape))
            if (i + 1) % log_every == 0 or i + 1 == steps:
                m = jax.tree_util.tree_map(float, metrics)
                dt = time.perf_counter() - t_last
                tps = tok_count / max(dt, 1e-9)
                print(f"{i+1},{m['loss']:.4f},{m['accuracy']:.4f},"
                      f"{m['grad_norm']:.3f},{m['lr']:.2e},{tps:.0f}",
                      flush=True)
                history.append(m["loss"])
                t_last, tok_count = time.perf_counter(), 0
            if ckpt_dir and ((i + 1) % ckpt_every == 0 or stop["now"]
                             or i + 1 == steps):
                pending_save = ckpt.save_async(
                    ckpt_dir, i + 1, state, keep_last,
                    extra={"train_step": i + 1})
            if stop["now"]:
                print(f"# preempted at step {i+1}; checkpoint queued",
                      flush=True)
                break
    finally:
        prefetch.close()
        if pending_save is not None:
            pending_save.join(timeout=300)   # durability before return
        signal.signal(signal.SIGTERM, old_term)
    return state, history
