"""Checkpointing: atomic, keep-last-k, async, elastic.

Layout:  <dir>/step_<n>/ {manifest.msgpack, <leaf_id>.npy ...}

* atomic     -- written to ``step_<n>.tmp`` then ``os.replace``d, so a crash
                mid-write can never produce a half checkpoint that restore
                would pick up.
* keep-k     -- old steps garbage-collected after a successful write.
* async      -- ``save_async`` snapshots to host memory synchronously (cheap)
                and writes in a daemon thread off the training critical path.
* elastic    -- leaves are stored *unsharded*; restore re-device_puts onto
                whatever mesh/sharding the resumed job uses, so the cluster
                size can change across restarts.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any, keep_last: int = 3,
         extra: Optional[dict] = None) -> str:
    keys, leaves, _ = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    return _write(ckpt_dir, step, keys, host, keep_last, extra or {})


_save_lock = threading.Lock()


def save_async(ckpt_dir: str, step: int, tree: Any, keep_last: int = 3,
               extra: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host now; write to disk in the background."""
    keys, leaves, _ = _flatten(tree)
    host = [np.asarray(x) for x in leaves]   # sync point, off-device copy

    t = threading.Thread(
        target=_write, args=(ckpt_dir, step, keys, host, keep_last,
                             extra or {}), daemon=True)
    t.start()
    return t


def _write(ckpt_dir, step, keys, host_leaves, keep_last, extra):
    with _save_lock:
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "keys": keys, "extra": extra,
                    "dtypes": [str(x.dtype) for x in host_leaves],
                    "shapes": [list(x.shape) for x in host_leaves]}
        for i, x in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"{i:05d}.npy"), x)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep_last)
        return final


def _gc(ckpt_dir, keep_last):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the *structure* of target_tree, resharding onto
    ``shardings`` (a matching pytree of NamedSharding) if given."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    keys, leaves, treedef = _flatten(target_tree)
    assert keys == manifest["keys"], "checkpoint/model structure mismatch"
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(leaves))
    out = []
    for i, (tgt, shd) in enumerate(zip(leaves, shard_flat)):
        arr = np.load(os.path.join(path, f"{i:05d}.npy"))
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
