"""Checkpointing: atomic, keep-last-k, async, elastic, self-validating.

Layout:  <dir>/step_<n>/ {manifest.msgpack, <leaf_id>.npy ...}

* atomic     -- written to ``step_<n>.tmp`` then ``os.replace``d, so a crash
                mid-write can never produce a half checkpoint that restore
                would pick up.
* keep-k     -- old steps garbage-collected after a successful write, so a
                bad latest step never costs the good ones behind it.
* async      -- ``save_async`` snapshots to host memory synchronously (cheap)
                and writes in a daemon thread off the training critical path.
* elastic    -- leaves are stored *unsharded*; restore re-device_puts onto
                whatever mesh/sharding the resumed job uses, so the cluster
                size can change across restarts.
* validating -- the manifest records a CRC-32 per leaf; :func:`restore`
                verifies bytes, dtype and shape and raises
                :class:`CheckpointCorrupt` on any mismatch (or unreadable
                file), and :func:`restore_latest_valid` walks steps newest-
                first past corrupt ones to the newest that still validates.
                The save path refuses to persist a tree containing NaN
                (``ValueError`` before any byte is written), so a poisoned
                state can never overwrite a good checkpoint inside the
                keep-k window.  ``+inf`` is allowed -- the engine's legal
                full-buffer sentinel (``robust.guard.tree_has_nan``).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import msgpack
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A checkpoint step failed validation: missing/truncated files, a
    CRC/dtype/shape mismatch against its manifest, or a manifest that does
    not match the target tree's structure."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def _refuse_nan(keys, host_leaves):
    """Never persist NaN: a corrupt tree must not enter the keep-k window.

    Checked on the host snapshot (already off-device), leaf-by-leaf so
    the error names the poisoned leaves.  NaN-only by design -- ``+inf``
    is legitimate state (full-buffer backlog sentinel).
    """
    bad = [k for k, x in zip(keys, host_leaves)
           if np.issubdtype(x.dtype, np.floating) and np.isnan(x).any()]
    if bad:
        raise ValueError(
            "refusing to checkpoint a tree containing NaN "
            f"(leaves: {', '.join(bad)}); a corrupt snapshot must never "
            "displace a valid one -- roll back instead")


def save(ckpt_dir: str, step: int, tree: Any, keep_last: int = 3,
         extra: Optional[dict] = None) -> str:
    keys, leaves, _ = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    _refuse_nan(keys, host)
    return _write(ckpt_dir, step, keys, host, keep_last, extra or {})


_save_lock = threading.Lock()


def save_async(ckpt_dir: str, step: int, tree: Any, keep_last: int = 3,
               extra: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host now; write to disk in the background.

    The NaN refusal also happens *now*, on the calling thread -- the
    caller must learn synchronously that its state is poisoned, not from
    a daemon thread's lost exception.
    """
    keys, leaves, _ = _flatten(tree)
    host = [np.asarray(x) for x in leaves]   # sync point, off-device copy
    _refuse_nan(keys, host)

    t = threading.Thread(
        target=_write, args=(ckpt_dir, step, keys, host, keep_last,
                             extra or {}), daemon=True)
    t.start()
    return t


def _crc(x: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(x).tobytes())


def _write(ckpt_dir, step, keys, host_leaves, keep_last, extra):
    with _save_lock:
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "keys": keys, "extra": extra,
                    "dtypes": [str(x.dtype) for x in host_leaves],
                    "shapes": [list(x.shape) for x in host_leaves],
                    "crc": [_crc(x) for x in host_leaves]}
        for i, x in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"{i:05d}.npy"), x)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep_last)
        return final


def _gc(ckpt_dir, keep_last):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the *structure* of target_tree, resharding onto
    ``shardings`` (a matching pytree of NamedSharding) if given.

    Validates every leaf against the manifest -- CRC-32 over the raw
    bytes, dtype, shape -- and raises :class:`CheckpointCorrupt` if the
    step is unreadable, truncated or tampered with.  Checkpoints written
    before CRCs existed (no ``crc`` manifest entry) restore with dtype/
    shape checks only.
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    try:
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
    except (OSError, msgpack.UnpackException, ValueError) as e:
        raise CheckpointCorrupt(
            f"step {step}: unreadable manifest ({e})") from e
    keys, leaves, treedef = _flatten(target_tree)
    if keys != manifest.get("keys"):
        raise CheckpointCorrupt(
            f"step {step}: checkpoint/model structure mismatch")
    crcs = manifest.get("crc") or [None] * len(leaves)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(leaves))
    out = []
    for i, (tgt, shd) in enumerate(zip(leaves, shard_flat)):
        leaf_path = os.path.join(path, f"{i:05d}.npy")
        try:
            arr = np.load(leaf_path)
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorrupt(
                f"step {step}: leaf {manifest['keys'][i]} "
                f"({os.path.basename(leaf_path)}) unreadable ({e})") from e
        if (str(arr.dtype) != manifest["dtypes"][i]
                or list(arr.shape) != manifest["shapes"][i]):
            raise CheckpointCorrupt(
                f"step {step}: leaf {manifest['keys'][i]} is "
                f"{arr.dtype}{arr.shape}, manifest says "
                f"{manifest['dtypes'][i]}{tuple(manifest['shapes'][i])}")
        if crcs[i] is not None and _crc(arr) != crcs[i]:
            raise CheckpointCorrupt(
                f"step {step}: leaf {manifest['keys'][i]} CRC mismatch "
                "(bytes corrupted on disk)")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore_latest_valid(ckpt_dir: str, target_tree: Any,
                         shardings: Any = None) -> tuple[Any, dict, int]:
    """Restore the newest step that passes validation.

    Walks ``all_steps`` newest-first, skipping any step whose manifest,
    bytes, dtypes or shapes fail :func:`restore`'s checks -- the recovery
    primitive behind the twin server's rollback (a truncated or corrupt
    latest step silently falls back to the previous good one).  Returns
    ``(tree, extra, step)``; raises :class:`CheckpointCorrupt` when no
    step validates (including an empty directory).
    """
    failures = []
    for step in reversed(all_steps(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, target_tree, shardings)
            return tree, extra, step
        except CheckpointCorrupt as e:
            failures.append(str(e))
    detail = ("; ".join(failures)) if failures else "no step_* directories"
    raise CheckpointCorrupt(
        f"no valid checkpoint under {ckpt_dir}: {detail}")
