"""Pallas TPU kernel: tiled pairwise UE-cell distances (the D block).

MXU formulation: ||u - c||^2 = ||u||^2 + ||c||^2 - 2 u.c, so the O(N*M*3)
subtraction grid becomes one (bn x 3) @ (3 x bm) matmul per tile plus rank-1
corrections -- the contraction runs on the MXU and the (bn, bm, 3) broadcast
intermediate never exists.

Grid: (N/bn, M/bm), both parallel.  VMEM per step: bn*3 + bm*3 + 2*bn*bm
floats; defaults (256, 512) use ~1 MiB, comfortably inside the ~16 MiB/core
budget while keeping the lane dimension 128-aligned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(u_ref, c_ref, d2d_ref, d3d_ref):
    u = u_ref[...]                     # (bn, 3)
    c = c_ref[...]                     # (bm, 3)
    # planar (x, y) and full (x, y, z) squared norms
    dot3 = jax.lax.dot_general(u, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    u2 = u[:, 2:3]
    c2 = c[:, 2:3]
    dotz = u2 * c2.T                   # (bn, bm) rank-1 z contribution
    un3 = jnp.sum(u * u, axis=1, keepdims=True)      # (bn, 1)
    cn3 = jnp.sum(c * c, axis=1, keepdims=True).T    # (1, bm)
    unz = u2 * u2
    cnz = (c2 * c2).T
    sq3 = jnp.maximum(un3 + cn3 - 2.0 * dot3, 0.0)
    sq2 = jnp.maximum(sq3 - (unz + cnz - 2.0 * dotz), 0.0)
    d3d_ref[...] = jnp.sqrt(sq3)
    d2d_ref[...] = jnp.sqrt(sq2)


@partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def pairwise_dist(U, C, *, bn: int = 256, bm: int = 512,
                  interpret: bool = False):
    """(d2d, d3d) distance matrices via the tiled Pallas kernel.

    N and M must be multiples of bn / bm (ops.py pads).
    """
    n, m = U.shape[0], C.shape[0]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    out_shape = [jax.ShapeDtypeStruct((n, m), jnp.float32),
                 jax.ShapeDtypeStruct((n, m), jnp.float32)]
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 3), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(U, C)
