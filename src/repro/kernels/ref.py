"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_dist_ref(U, C):
    """(d2d, d3d) for UE rows x cell columns; plain broadcasting."""
    dx = U[:, None, 0] - C[None, :, 0]
    dy = U[:, None, 1] - C[None, :, 1]
    dz = U[:, None, 2] - C[None, :, 2]
    d2d = jnp.sqrt(dx * dx + dy * dy)
    d3d = jnp.sqrt(d2d * d2d + dz * dz)
    return d2d, d3d


def fused_sinr_ref(U, C, Pw, pathgain_fn, noise_w):
    """Materialised reference for the fused pipeline.

    Returns (gamma, a, w, u): per-UE-per-subband SINR, serving cell,
    wanted and unwanted power.  Attachment = argmax of wideband RSRP,
    ties broken toward the lowest cell index (matches jnp.argmax).
    """
    d2d, d3d = pairwise_dist_ref(U, C)
    g = pathgain_fn(d2d, d3d, C[None, :, 2], U[:, None, 2])
    r = g[:, :, None] * Pw[None, :, :]            # (N, M, K)
    total = r.sum(axis=1)                          # (N, K)
    wide = r.sum(axis=2)                           # (N, M)
    a = jnp.argmax(wide, axis=1).astype(jnp.int32)
    w = jnp.take_along_axis(r, a[:, None, None], axis=1)[:, 0, :]
    u = total - w
    gamma = w / (noise_w + u)
    return gamma, a, w, u
