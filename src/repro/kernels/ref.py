"""Pure-jnp oracles for the Pallas kernels (the correctness references).

Since PR 5 these are thin delegations into the pure radio chain of
``repro.sim.radio`` -- the same functions the smart-update graph, the scan
engine and ``radio_forward`` execute -- instead of a third hand-rolled copy
of the pathgain/RSRP math.  A kernel-vs-reference check therefore also
cross-validates the kernel against every other consumer of the chain
(tests/test_kernel_vs_crrm.py runs the fused kernel against
``radio_forward`` across all registry scenarios).
"""
from __future__ import annotations

from repro.sim import radio


def pairwise_dist_ref(U, C):
    """(d2d, d3d) for UE rows x cell columns (``radio.compute_distances``)."""
    d2d, d3d, _ = radio.compute_distances(U, C)
    return d2d, d3d


def fused_sinr_ref(U, C, Pw, pathgain_fn, noise_w):
    """Materialised reference for the fused pipeline.

    Returns (gamma, a, w, u): per-UE-per-subband SINR, serving cell,
    wanted and unwanted power -- the radio chain's unfaded
    D -> G -> RSRP -> a -> w/u -> gamma composition.  Attachment = argmax
    of wideband RSRP, ties broken toward the lowest cell index (matches
    ``jnp.argmax``, and the kernel's tie-break).
    """
    d2d, d3d, _ = radio.compute_distances(U, C)
    g = pathgain_fn(d2d, d3d, C[None, :, 2], U[:, None, 2])
    r = radio.rsrp(g, Pw)                          # (N, M, K)
    a = radio.attachment(r)
    gamma, w, u = radio.sinr(r, a, noise_w)
    return gamma, a, w, u
