"""Pallas TPU kernel: the fused CRRM pipeline D -> G -> RSRP -> w/u (+argmax).

The paper materialises every Figure-1 matrix in HBM; on TPU that makes the
whole pipeline HBM-bandwidth bound (arithmetic intensity < 1 FLOP/byte for
the elementwise blocks).  This kernel streams cell tiles through VMEM and
accumulates, flash-attention style, the only O(N) state the downstream blocks
need:

  * total[i, k]   -- sum_j p_jk g_ij      (interference + wanted)
  * best_val[i]   -- running max_j of wideband RSRP
  * best_idx[i]   -- its argmax (the attachment vector a)
  * w_best[i, k]  -- RSRP row of the current best server

so the (N, M) distance/gain/RSRP matrices never touch HBM.  Tie-break matches
``jnp.argmax`` (lowest cell index wins).

Per-link fading streams through the same tile pipeline: a ``fading`` tile --
``(bn, bm)`` wideband or ``(bn, bm, K)`` per-RB -- multiplies the gain tile
exactly as ``radio.apply_fading`` does, and ``attach_on_mean`` reproduces the
``attach_ignores_fading`` regime by ranking servers on the *unfaded* RSRP row
sum while still reporting the faded serving row.

Grid: (UE tiles, cell tiles); the cell dimension is `arbitrary` (sequential)
because every step read-modify-writes the same output block.  The pathloss
strategy is traced *into* the kernel as pure jnp (any 38.901 model works).

VMEM per step (defaults bn=256, bm=512, K<=8): the (bn, bm) gain tile +
(bn, bm, K) RSRP tile + optional (bn, bm[, K]) fading tile ~= 0.5 + 4 + 4 MiB
-- inside budget; the MXU computes the distance contraction as in
pairwise_dist.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: renamed TPUCompilerParams -> CompilerParams in newer jax; accept both so
#: the kernel builds against the container's pinned version too
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

_NEG = -3.4e38  # python float: jnp constants would be captured consts


def _make_kernel(pathgain_fn, n_sectors: int, bm: int, mxu: bool = True,
                 fading: str | None = None, attach_on_mean: bool = False):
    def kernel(*refs):
        if fading is None:
            (u_ref, c_ref, p_ref, bore_ref,
             total_ref, bval_ref, barg_ref, wbest_ref) = refs
            fad_ref = None
        else:
            (u_ref, c_ref, p_ref, bore_ref, fad_ref,
             total_ref, bval_ref, barg_ref, wbest_ref) = refs
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            total_ref[...] = jnp.zeros_like(total_ref)
            bval_ref[...] = jnp.full_like(bval_ref, _NEG)
            barg_ref[...] = jnp.zeros_like(barg_ref)
            wbest_ref[...] = jnp.zeros_like(wbest_ref)

        u = u_ref[...]                    # (bn, 3)
        c = c_ref[...]                    # (bm, 3)
        p = p_ref[...]                    # (bm, K)

        if mxu:
            # MXU decomposition: fast, ~1e-5 relative error from the
            # catastrophic cancellation in |u|^2+|c|^2-2u.c (documented).
            dot3 = jax.lax.dot_general(u, c, (((1,), (1,)), ((), ())),
                                       preferred_element_type=jnp.float32)
            un3 = jnp.sum(u * u, axis=1, keepdims=True)
            cn3 = jnp.sum(c * c, axis=1, keepdims=True).T
            uz, cz = u[:, 2:3], c[:, 2:3]
            dz2 = uz * uz + (cz * cz).T - 2.0 * (uz * cz.T)
            sq3 = jnp.maximum(un3 + cn3 - 2.0 * dot3, 0.0)
            d3d = jnp.sqrt(sq3)
            d2d = jnp.sqrt(jnp.maximum(sq3 - dz2, 0.0))
        else:
            # VPU broadcast-difference mirroring radio.compute_distances
            # operation for operation (d3d built FROM d2d, not from the raw
            # squared sum) so the kernel is bit-identical to the reference
            dx = u[:, None, 0] - c[None, :, 0]
            dy = u[:, None, 1] - c[None, :, 1]
            dz = u[:, None, 2] - c[None, :, 2]
            d2d = jnp.sqrt(dx * dx + dy * dy)
            d3d = jnp.sqrt(d2d * d2d + dz * dz)

        # -- G: pluggable pathloss strategy (traced jnp) -------------------
        g = pathgain_fn(d2d, d3d, c[:, 2][None, :], u[:, 2][:, None])
        if n_sectors > 1:
            # 3GPP horizontal pattern, inlined for fusion
            dx = u[:, 0:1] - c[:, 0].reshape(1, -1)
            dy = u[:, 1:2] - c[:, 1].reshape(1, -1)
            az = jnp.arctan2(dy, dx)
            off = az - bore_ref[...][:, 0][None, :]
            off = jnp.arctan2(jnp.sin(off), jnp.cos(off))
            phi3 = 1.1344640137963142  # 65 deg in radians
            att = jnp.minimum(12.0 * (off / phi3) ** 2, 30.0)
            g = g * jnp.power(10.0, -0.1 * att)

        # -- RSRP + online reductions ---------------------------------------
        if fading is None:
            r = g[:, :, None] * p[None, :, :]        # (bn, bm, K)
            meas = r.sum(axis=2)
        elif fading == "wide":
            gf = g * fad_ref[...]                    # apply_fading, 2-D
            r = gf[:, :, None] * p[None, :, :]
            meas = (g[:, :, None] * p[None, :, :]).sum(axis=2) \
                if attach_on_mean else r.sum(axis=2)
        else:                                        # "rb": per-RB fading
            g3 = g[:, :, None] * fad_ref[...]        # apply_fading, 3-D
            r = g3 * p[None, :, :]
            meas = (g[:, :, None] * p[None, :, :]).sum(axis=2) \
                if attach_on_mean else r.sum(axis=2)
        total_ref[...] += r.sum(axis=1)
        t_max = meas.max(axis=1)
        t_arg = jnp.argmax(meas, axis=1)
        t_w = jnp.take_along_axis(r, t_arg[:, None, None], axis=1)[:, 0, :]
        prev = bval_ref[...][:, 0]
        better = t_max > prev
        bval_ref[...] = jnp.where(better, t_max, prev)[:, None]
        barg_ref[...] = jnp.where(
            better, t_arg.astype(jnp.int32) + j * bm,
            barg_ref[...][:, 0])[:, None]
        wbest_ref[...] = jnp.where(better[:, None], t_w, wbest_ref[...])

    return kernel


@partial(jax.jit,
         static_argnames=("pathgain_fn", "n_sectors", "bn", "bm", "interpret",
                          "mxu", "attach_on_mean"))
def fused_sinr_accumulate(U, C, Pw, boresight, fad=None, *, pathgain_fn,
                          n_sectors: int = 1, bn: int = 256, bm: int = 512,
                          interpret: bool = False, mxu: bool = False,
                          attach_on_mean: bool = False):
    """Run the fused accumulator.  Returns (total, best_val, best_idx, w_best).

    Shapes: U (N, 3), C (M, 3), Pw (M, K), boresight (M, 1), fad None /
    (N, M) wideband / (N, M, K) per-RB.  N % bn == 0 and M % bm == 0
    (ops.py pads; padded cells need power 0 and padded fading 0).
    ``attach_on_mean`` ranks servers on the unfaded RSRP row sum
    (``attach_ignores_fading``); it requires ``fad``.
    """
    n, m, k = U.shape[0], C.shape[0], Pw.shape[1]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    if fad is None:
        fading = None
        assert not attach_on_mean, "attach_on_mean requires a fading tensor"
    elif fad.ndim == 2:
        fading = "wide"
        assert fad.shape == (n, m), (fad.shape, n, m)
    else:
        fading = "rb"
        assert fad.shape == (n, m, k), (fad.shape, n, m, k)
    grid = (n // bn, m // bm)
    kernel = _make_kernel(pathgain_fn, n_sectors, bm, mxu, fading,
                          attach_on_mean)
    in_specs = [
        pl.BlockSpec((bn, 3), lambda i, j: (i, 0)),
        pl.BlockSpec((bm, 3), lambda i, j: (j, 0)),
        pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
        pl.BlockSpec((bm, 1), lambda i, j: (j, 0)),
    ]
    operands = [U, C, Pw, boresight]
    if fading == "wide":
        in_specs.append(pl.BlockSpec((bn, bm), lambda i, j: (i, j)))
        operands.append(fad)
    elif fading == "rb":
        in_specs.append(pl.BlockSpec((bn, bm, k), lambda i, j: (i, j, 0)))
        operands.append(fad)
    out_shape = [
        jax.ShapeDtypeStruct((n, k), jnp.float32),   # total
        jax.ShapeDtypeStruct((n, 1), jnp.float32),   # best_val
        jax.ShapeDtypeStruct((n, 1), jnp.int32),     # best_idx
        jax.ShapeDtypeStruct((n, k), jnp.float32),   # w_best
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
        ],
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
