"""Pallas TPU kernel: the fused CRRM pipeline D -> G -> RSRP -> w/u (+argmax).

The paper materialises every Figure-1 matrix in HBM; on TPU that makes the
whole pipeline HBM-bandwidth bound (arithmetic intensity < 1 FLOP/byte for
the elementwise blocks).  This kernel streams cell tiles through VMEM and
accumulates, flash-attention style, the only O(N) state the downstream blocks
need:

  * total[i, k]   -- sum_j p_jk g_ij      (interference + wanted)
  * best_val[i]   -- running max_j of wideband RSRP
  * best_idx[i]   -- its argmax (the attachment vector a)
  * w_best[i, k]  -- RSRP row of the current best server

so the (N, M) distance/gain/RSRP matrices never touch HBM.  Tie-break matches
``jnp.argmax`` (lowest cell index wins).

Grid: (UE tiles, cell tiles); the cell dimension is `arbitrary` (sequential)
because every step read-modify-writes the same output block.  The pathloss
strategy is traced *into* the kernel as pure jnp (any 38.901 model works).

VMEM per step (defaults bn=256, bm=512, K<=8): the (bn, bm) gain tile +
(bn, bm, K) RSRP tile ~= 0.5 + 4 MiB -- inside budget; the MXU computes the
distance contraction as in pairwise_dist.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: renamed TPUCompilerParams -> CompilerParams in newer jax; accept both so
#: the kernel builds against the container's pinned version too
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

_NEG = -3.4e38  # python float: jnp constants would be captured consts


def _make_kernel(pathgain_fn, n_sectors: int, bm: int, mxu: bool = True):
    def kernel(u_ref, c_ref, p_ref, bore_ref,
               total_ref, bval_ref, barg_ref, wbest_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            total_ref[...] = jnp.zeros_like(total_ref)
            bval_ref[...] = jnp.full_like(bval_ref, _NEG)
            barg_ref[...] = jnp.zeros_like(barg_ref)
            wbest_ref[...] = jnp.zeros_like(wbest_ref)

        u = u_ref[...]                    # (bn, 3)
        c = c_ref[...]                    # (bm, 3)
        p = p_ref[...]                    # (bm, K)

        if mxu:
            # MXU decomposition: fast, ~1e-5 relative error from the
            # catastrophic cancellation in |u|^2+|c|^2-2u.c (documented).
            dot3 = jax.lax.dot_general(u, c, (((1,), (1,)), ((), ())),
                                       preferred_element_type=jnp.float32)
            un3 = jnp.sum(u * u, axis=1, keepdims=True)
            cn3 = jnp.sum(c * c, axis=1, keepdims=True).T
            uz, cz = u[:, 2:3], c[:, 2:3]
            dz2 = uz * uz + (cz * cz).T - 2.0 * (uz * cz.T)
            sq3 = jnp.maximum(un3 + cn3 - 2.0 * dot3, 0.0)
            d3d = jnp.sqrt(sq3)
            d2d = jnp.sqrt(jnp.maximum(sq3 - dz2, 0.0))
        else:
            # VPU broadcast-difference: exact-as-reference, no MXU
            dxy = u[:, None, :2] - c[None, :, :2]
            dzz = u[:, None, 2] - c[None, :, 2]
            sq2 = jnp.sum(dxy * dxy, axis=2)
            d2d = jnp.sqrt(sq2)
            d3d = jnp.sqrt(sq2 + dzz * dzz)

        # -- G: pluggable pathloss strategy (traced jnp) -------------------
        g = pathgain_fn(d2d, d3d, c[:, 2][None, :], u[:, 2][:, None])
        if n_sectors > 1:
            # 3GPP horizontal pattern, inlined for fusion
            dx = u[:, 0:1] - c[:, 0].reshape(1, -1)
            dy = u[:, 1:2] - c[:, 1].reshape(1, -1)
            az = jnp.arctan2(dy, dx)
            off = az - bore_ref[...][:, 0][None, :]
            off = jnp.arctan2(jnp.sin(off), jnp.cos(off))
            phi3 = 1.1344640137963142  # 65 deg in radians
            att = jnp.minimum(12.0 * (off / phi3) ** 2, 30.0)
            g = g * jnp.power(10.0, -0.1 * att)

        # -- RSRP + online reductions ---------------------------------------
        r = g[:, :, None] * p[None, :, :]            # (bn, bm, K)
        total_ref[...] += r.sum(axis=1)
        wide = g * p.sum(axis=1)[None, :]            # sum_k p_jk * g_ij
        t_max = wide.max(axis=1)
        t_arg = jnp.argmax(wide, axis=1)
        t_w = jnp.take_along_axis(r, t_arg[:, None, None], axis=1)[:, 0, :]
        prev = bval_ref[...][:, 0]
        better = t_max > prev
        bval_ref[...] = jnp.where(better, t_max, prev)[:, None]
        barg_ref[...] = jnp.where(
            better, t_arg.astype(jnp.int32) + j * bm,
            barg_ref[...][:, 0])[:, None]
        wbest_ref[...] = jnp.where(better[:, None], t_w, wbest_ref[...])

    return kernel


@partial(jax.jit,
         static_argnames=("pathgain_fn", "n_sectors", "bn", "bm", "interpret",
                          "mxu"))
def fused_sinr_accumulate(U, C, Pw, boresight, *, pathgain_fn,
                          n_sectors: int = 1, bn: int = 256, bm: int = 512,
                          interpret: bool = False, mxu: bool = False):
    """Run the fused accumulator.  Returns (total, best_val, best_idx, w_best).

    Shapes: U (N, 3), C (M, 3), Pw (M, K), boresight (M, 1).
    N % bn == 0 and M % bm == 0 (ops.py pads; padded cells need power 0).
    """
    n, m, k = U.shape[0], C.shape[0], Pw.shape[1]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    kernel = _make_kernel(pathgain_fn, n_sectors, bm, mxu)
    out_shape = [
        jax.ShapeDtypeStruct((n, k), jnp.float32),   # total
        jax.ShapeDtypeStruct((n, 1), jnp.float32),   # best_val
        jax.ShapeDtypeStruct((n, 1), jnp.int32),     # best_idx
        jax.ShapeDtypeStruct((n, k), jnp.float32),   # w_best
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
        ],
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(U, C, Pw, boresight)
