"""Public jit'd wrappers for the Pallas kernels.

These handle padding to tile multiples, interpret-mode fallback on CPU (the
container has no TPU; ``interpret=True`` executes the kernel body in Python
for correctness validation), and the final cheap SINR math on the
kernel-accumulated O(N) state.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import fused_sinr as _fused
from repro.kernels import pairwise_dist as _dist


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(x, mult, axis=0, fill=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    shape = list(x.shape)
    shape[axis] = pad
    return jnp.concatenate(
        [x, jnp.full(tuple(shape), fill, x.dtype)], axis=axis)


def _pad_rows(x, mult, fill=0.0):
    return _pad_axis(x, mult, axis=0, fill=fill)


def pairwise_dist(U, C, *, bn: int = 256, bm: int = 512, interpret=None):
    """(d2d, d3d) via the tiled MXU kernel; pads then slices."""
    if interpret is None:
        interpret = _on_cpu()
    n, m = U.shape[0], C.shape[0]
    bn = min(bn, max(8, n))
    bm = min(bm, max(8, m))
    Up = _pad_rows(U, bn)
    Cp = _pad_rows(C, bm)
    d2d, d3d = _dist.pairwise_dist(Up, Cp, bn=bn, bm=bm, interpret=interpret)
    return d2d[:n, :m], d3d[:n, :m]


def fused_sinr(U, C, Pw, *, pathgain_fn, noise_w: float, boresight=None,
               fad=None, attach_on_mean: bool = False,
               n_sectors: int = 1, bn: int = 256, bm: int = 512,
               interpret=None, mxu: bool = False):
    """Fused D->G->RSRP->w/u->SINR pipeline.

    Returns (gamma, a, w, u) exactly like ``ref.fused_sinr_ref`` but with
    O(N) HBM traffic.  Padded cells get zero power and a far position, so
    they can never win the attachment argmax or contribute interference.

    ``fad`` streams per-link fading through the tile pipeline -- ``(N, M)``
    wideband or ``(N, M, K)`` per-RB, multiplied onto the gain tile exactly
    as ``radio.apply_fading``.  ``attach_on_mean`` attaches on the unfaded
    RSRP row sum (the ``attach_ignores_fading`` regime).  The same entry
    point serves the dirty-row incremental backend: callers gather the
    dirty UE slab (rows of U and fad) and scatter the returned rows back
    (``radio.radio_update_rows_fused``).
    """
    if interpret is None:
        interpret = _on_cpu()
    n, m = U.shape[0], C.shape[0]
    bn = min(bn, max(8, n))
    bm = min(bm, max(8, m))
    Up = _pad_rows(U, bn)
    Cp = _pad_rows(C, bm, fill=1e9)
    Pp = _pad_rows(Pw, bm, fill=0.0)
    if boresight is None:
        bore = jnp.zeros((Cp.shape[0], 1), jnp.float32)
    else:
        bore = _pad_rows(boresight.reshape(-1, 1), bm)
    if fad is not None:
        fad = _pad_axis(_pad_axis(fad, bn, axis=0), bm, axis=1)
    total, bval, barg, wbest = _fused.fused_sinr_accumulate(
        Up, Cp, Pp, bore, fad, pathgain_fn=pathgain_fn, n_sectors=n_sectors,
        bn=bn, bm=bm, interpret=interpret, mxu=mxu,
        attach_on_mean=attach_on_mean)
    total, barg, wbest = total[:n], barg[:n, 0], wbest[:n]
    u = total - wbest
    gamma = wbest / (noise_w + u)
    return gamma, barg, wbest, u
