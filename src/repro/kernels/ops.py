"""Public jit'd wrappers for the Pallas kernels.

These handle padding to tile multiples, interpret-mode fallback on CPU (the
container has no TPU; ``interpret=True`` executes the kernel body in Python
for correctness validation), and the final cheap SINR math on the
kernel-accumulated O(N) state.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import fused_sinr as _fused
from repro.kernels import pairwise_dist as _dist


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_rows(x, mult, fill=0.0):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)


def pairwise_dist(U, C, *, bn: int = 256, bm: int = 512, interpret=None):
    """(d2d, d3d) via the tiled MXU kernel; pads then slices."""
    if interpret is None:
        interpret = _on_cpu()
    n, m = U.shape[0], C.shape[0]
    bn = min(bn, max(8, n))
    bm = min(bm, max(8, m))
    Up = _pad_rows(U, bn)
    Cp = _pad_rows(C, bm)
    d2d, d3d = _dist.pairwise_dist(Up, Cp, bn=bn, bm=bm, interpret=interpret)
    return d2d[:n, :m], d3d[:n, :m]


def fused_sinr(U, C, Pw, *, pathgain_fn, noise_w: float, boresight=None,
               n_sectors: int = 1, bn: int = 256, bm: int = 512,
               interpret=None, mxu: bool = False):
    """Fused D->G->RSRP->w/u->SINR pipeline.

    Returns (gamma, a, w, u) exactly like ``ref.fused_sinr_ref`` but with
    O(N) HBM traffic.  Padded cells get zero power and a far position, so
    they can never win the attachment argmax or contribute interference.
    """
    if interpret is None:
        interpret = _on_cpu()
    n, m = U.shape[0], C.shape[0]
    bn = min(bn, max(8, n))
    bm = min(bm, max(8, m))
    Up = _pad_rows(U, bn)
    Cp = _pad_rows(C, bm, fill=1e9)
    Pp = _pad_rows(Pw, bm, fill=0.0)
    if boresight is None:
        bore = jnp.zeros((Cp.shape[0], 1), jnp.float32)
    else:
        bore = _pad_rows(boresight.reshape(-1, 1), bm)
    total, bval, barg, wbest = _fused.fused_sinr_accumulate(
        Up, Cp, Pp, bore, pathgain_fn=pathgain_fn, n_sectors=n_sectors,
        bn=bn, bm=bm, interpret=interpret, mxu=mxu)
    total, barg, wbest = total[:n], barg[:n, 0], wbest[:n]
    u = total - wbest
    gamma = wbest / (noise_w + u)
    return gamma, barg, wbest, u
