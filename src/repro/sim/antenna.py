"""3GPP horizontal antenna pattern (TR 36.814 / 38.901 style).

A(phi) = -min(12 (phi/phi_3dB)^2, A_max) dB, phi_3dB = 65 deg, A_max = 30 dB.

CRRM models a sectored site as co-located cells whose boresights differ; the
``Antenna_gain`` class returns the per-(UE, cell) gain in dB given the bearing
from each cell to each UE.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def wrap_angle(phi):
    """Wrap angle to (-pi, pi]."""
    return jnp.arctan2(jnp.sin(phi), jnp.cos(phi))


@dataclasses.dataclass(frozen=True)
class Antenna_gain:
    """3GPP horizontal pattern, one boresight per cell."""

    phi_3dB_deg: float = 65.0
    A_max_dB: float = 30.0
    max_gain_dBi: float = 0.0  # peak element gain added on boresight

    def pattern_dB(self, phi_off_boresight):
        """phi in radians, relative to boresight."""
        phi_3db = jnp.deg2rad(self.phi_3dB_deg)
        att = jnp.minimum(12.0 * (phi_off_boresight / phi_3db) ** 2,
                          self.A_max_dB)
        return self.max_gain_dBi - att

    def gain_dB(self, azimuth_ue, boresight):
        """azimuth_ue: (n_ue, n_cell) bearing cell->UE; boresight: (n_cell,)."""
        off = wrap_angle(azimuth_ue - boresight[None, :])
        return self.pattern_dB(off)

    def gain_linear(self, azimuth_ue, boresight):
        return jnp.power(10.0, 0.1 * self.gain_dB(azimuth_ue, boresight))


def sector_boresights(n_sites: int, n_sectors: int):
    """Boresight angles for ``n_sites`` sites of ``n_sectors`` cells each.

    Sector s of every site points at s * 2*pi/n_sectors.  For n_sectors == 1
    the pattern is treated as omnidirectional by the simulator (gain 0 dB).
    Returns (n_sites * n_sectors,) radians, cell j = site j//n_sectors,
    sector j % n_sectors.
    """
    sector = jnp.arange(n_sites * n_sectors) % n_sectors
    return sector.astype(jnp.float32) * (2.0 * jnp.pi / n_sectors)
