"""Network deployment generators: PPP fields and hexagonal site grids."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ppp_points(key, n_points: int, extent_m: float, z: float = 0.0):
    """n_points uniform in a square [0, extent)^2 at height z.

    A homogeneous PPP conditioned on its count is uniform, so fixing the count
    gives reproducible shapes while matching PPP statistics for interference
    seen from points well inside the region.
    """
    xy = jax.random.uniform(key, (n_points, 2), minval=0.0, maxval=extent_m)
    zcol = jnp.full((n_points, 1), z)
    return jnp.concatenate([xy, zcol], axis=1)


def hex_sites(rings: int, isd_m: float, z: float = 25.0):
    """Hexagonal grid of sites: centre + ``rings`` rings, inter-site ``isd_m``.

    Returns (n_sites, 3).  n_sites = 1 + 3*rings*(rings+1).
    """
    pts = []
    R = rings
    for q in range(-R, R + 1):
        for r in range(max(-R, -q - R), min(R, -q + R) + 1):
            x = isd_m * (q + r / 2.0)
            y = isd_m * r * 0.8660254037844386  # sqrt(3)/2
            pts.append((x, y))
    arr = jnp.asarray(pts, dtype=jnp.float32)
    assert arr.shape[0] == 1 + 3 * rings * (rings + 1)
    z_col = jnp.full((arr.shape[0], 1), z, dtype=jnp.float32)
    return jnp.concatenate([arr, z_col], axis=1)


def replicate_sectors(sites_xyz, n_sectors: int):
    """Cells = sites repeated per sector (co-located, different boresights)."""
    return jnp.repeat(sites_xyz, n_sectors, axis=0)
