"""PHY abstractions: SINR -> CQI -> MCS -> spectral efficiency, Shannon bound.

CQI thresholds follow the widely used link-level mapping for the 3GPP TS
38.214 CQI Table 5.2.2.1-2 (QPSK..64QAM); MCS is the paper's "scaled version
of CQI" in [0, 28], mapped onto the TS 38.214 Table 5.1.3.1-1 spectral
efficiencies.  The Shannon block is the information-theoretic upper bound
(including a MIMO multiplexing factor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# SINR (dB) above which CQI index i (1..15) is usable; CQI 0 = out of range.
# Derived so the *mapped MCS* is decodable: threshold(i) =
# 10*log10(2**SE(MCS(i)) - 1) + 2 dB implementation margin -- this keeps the
# scheduler's rate below Shannon capacity at every operating point (asserted
# as a system invariant in tests/test_property_system.py).
CQI_SINR_THRESHOLDS_DB = jnp.array(
    [-3.25, -0.86, 1.22, 2.16, 3.78, 4.51, 6.42, 8.34, 8.92, 10.55, 12.49, 13.45, 15.42, 17.27, 18.63], dtype=jnp.float32)

# TS 38.214 Table 5.2.2.1-2 CQI spectral efficiencies (CQI 0..15).
CQI_EFFICIENCY = jnp.array(
    [0.0, 0.1523, 0.2344, 0.3770, 0.6016, 0.8770, 1.1758, 1.4766,
     1.9141, 2.4063, 2.7305, 3.3223, 3.9023, 4.5234, 5.1152, 5.5547],
    dtype=jnp.float32)

# TS 38.214 Table 5.1.3.1-1 (64QAM) spectral efficiencies, MCS 0..28.
MCS_EFFICIENCY = jnp.array(
    [0.2344, 0.3066, 0.3770, 0.4902, 0.6016, 0.7402, 0.8770, 1.0273,
     1.1758, 1.3262, 1.3281, 1.4766, 1.6953, 1.9141, 2.1602, 2.4063,
     2.5703, 2.5664, 2.7305, 3.0293, 3.3223, 3.6094, 3.9023, 4.2129,
     4.5234, 4.8164, 5.1152, 5.3320, 5.5547], dtype=jnp.float32)


def sinr_to_db(sinr_linear):
    return 10.0 * jnp.log10(jnp.maximum(sinr_linear, 1e-12))


def sinr_db_to_cqi(sinr_db):
    """CQI in [0, 15]: number of thresholds passed (look-up table)."""
    return jnp.sum(sinr_db[..., None] >= CQI_SINR_THRESHOLDS_DB,
                   axis=-1).astype(jnp.int32)


def cqi_to_mcs(cqi):
    """The paper: MCS is a scaled version of CQI, values in [0, 28]."""
    return jnp.clip(jnp.round(cqi.astype(jnp.float32) * 28.0 / 15.0),
                    0, 28).astype(jnp.int32)


def mcs_to_efficiency(mcs):
    """bits/s/Hz for each MCS index (3GPP tables)."""
    return MCS_EFFICIENCY[jnp.clip(mcs, 0, 28)]


def spectral_efficiency(sinr_linear):
    """Full chain SINR -> CQI -> MCS -> spectral efficiency, zeroed at CQI 0."""
    cqi = sinr_db_to_cqi(sinr_to_db(sinr_linear))
    se = mcs_to_efficiency(cqi_to_mcs(cqi))
    return jnp.where(cqi > 0, se, 0.0)


def soft_spectral_efficiency(sinr_linear, sharpness_per_db=2.0):
    """Smooth surrogate of :func:`spectral_efficiency` (differentiable CRRM).

    The hard chain is a staircase: SE jumps by ``eff(i) - eff(i-1)`` each
    time the SINR crosses ``CQI_SINR_THRESHOLDS_DB[i-1]``.  The surrogate
    replaces every step with a sigmoid of slope ``sharpness_per_db`` (per
    dB), so the function is C-infinity, monotone, agrees with the hard
    staircase at plateau centres, and its gradient w.r.t. SINR (hence
    w.r.t. upstream powers) is finite everywhere -- including below the
    CQI-1 cutoff, where the hard chain is identically zero.  As
    ``sharpness_per_db`` -> inf it converges pointwise to the staircase.
    """
    levels = jnp.where(jnp.arange(16) > 0,
                       mcs_to_efficiency(cqi_to_mcs(jnp.arange(16))), 0.0)
    deltas = levels[1:] - levels[:-1]                        # (15,)
    g_db = sinr_to_db(sinr_linear)
    steps = jax.nn.sigmoid(
        sharpness_per_db * (g_db[..., None] - CQI_SINR_THRESHOLDS_DB))
    return jnp.sum(deltas * steps, axis=-1)


def shannon_capacity(sinr_linear, bandwidth_hz, n_tx=1, n_rx=1):
    """Shannon bound with an ideal spatial-multiplexing MIMO factor."""
    streams = min(int(n_tx), int(n_rx))
    return streams * bandwidth_hz * jnp.log2(1.0 + jnp.maximum(sinr_linear, 0.0))
