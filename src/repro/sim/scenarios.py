"""Named scenario presets: reproducible 3GPP-flavoured configurations.

Calibrated-scenario simulators (Boeira et al.) and the digital-twin survey
(Manalastas et al.) both show that *named, reproducible* presets are what
make a system-level simulator usable for ML research at scale: an RL paper
can say "trained on ``dense_urban``" and anyone can reconstruct the exact
``CRRM_parameters``.  Each preset is a registry entry mapping a name to the
keyword arguments of :class:`~repro.core.params.CRRM_parameters`; callers
override any field (e.g. shrink ``n_ues`` for CI) without losing the
preset's identity:

>>> from repro.sim.scenarios import make_scenario
>>> from repro.core.crrm import CRRM
>>> sim = CRRM(make_scenario("dense_urban", n_ues=50))

The presets follow the 3GPP TR 38.901 deployment archetypes in spirit
(carrier, cell density, BS height, traffic mix), not to the letter -- they
are scaled so every preset runs in seconds on a laptop while keeping the
regime's qualitative behaviour (interference-limited urban, noise-limited
rural, LOS-dominated indoor, mobility-driven handover churn).  The
benchmark suite sweeps them (``benchmarks.paper_benches.env_episode``) and
``repro.env.CrrmEnv`` accepts a scenario name directly.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.params import CRRM_parameters
from repro.sim.faults import FaultConfig

#: name -> (description, factory(**overrides) -> CRRM_parameters)
_REGISTRY: Dict[str, tuple] = {}


def register_scenario(name: str, description: str,
                      factory: Callable[..., CRRM_parameters],
                      overwrite: bool = False) -> None:
    """Register a named scenario.  ``factory(**overrides)`` must return a
    fresh ``CRRM_parameters``; user code can extend the registry with its
    own presets (``overwrite=True`` to replace a stock one)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = (description, factory)


def _preset(name: str, description: str, **base):
    """Register a dict-based preset; overrides shallow-merge over ``base``."""
    def factory(**overrides) -> CRRM_parameters:
        kw = dict(base)
        kw.update(overrides)
        return CRRM_parameters(**kw)

    register_scenario(name, description, factory)


def scenario_names() -> tuple:
    """Registered preset names, sorted."""
    return tuple(sorted(_REGISTRY))


def scenario_description(name: str) -> str:
    return _get(name)[0]


def _get(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"have {list(scenario_names())}") from None


def make_scenario(name: str, **overrides) -> CRRM_parameters:
    """Construct the named preset's ``CRRM_parameters``.

    ``overrides`` replace any preset field (validated by
    ``CRRM_parameters.__post_init__`` as usual), so shrinking a scenario
    for CI keeps its identity: ``make_scenario("rural_macro", n_ues=20)``.
    """
    return _get(name)[1](**overrides)


# ---------------------------------------------------------------------------
# stock presets
# ---------------------------------------------------------------------------
_preset(
    "dense_urban",
    "Interference-limited street-canyon microcells: 3-sector UMi sites at "
    "3.5 GHz, frequency-selective fading with per-RB CQI, heavy Poisson "
    "load on a PF scheduler.",
    n_ues=200, n_cells=21, n_sectors=3, extent_m=1200.0,
    pathloss_model_name="UMi", fc_GHz=3.5, h_bs_m=10.0,
    power_W=6.3,                       # 38 dBm micro BS
    rayleigh_fading=True, n_rb_subbands=4, coherence_rb=3,
    scheduler_policy="pf", fairness_p=0.5,
    traffic_model="poisson",
    traffic_params=dict(arrival_rate_hz=400.0, packet_size_bits=12_000.0),
    harq_bler=0.1, seed=0)

_preset(
    "dense_urban_mobile",
    "dense_urban with a baked-in mobility trajectory: every UE takes a "
    "bounded random-walk step each TTI (time-compressed vehicular churn), "
    "with A3 handover armed so episodes exercise mobility-driven serving-"
    "cell dynamics out of the box (mobility_step_m rides in the preset -- "
    "run_episode/CrrmEnv pick it up without extra arguments).",
    n_ues=200, n_cells=21, n_sectors=3, extent_m=1200.0,
    pathloss_model_name="UMi", fc_GHz=3.5, h_bs_m=10.0,
    power_W=6.3,
    rayleigh_fading=True, n_rb_subbands=4, coherence_rb=3,
    attach_ignores_fading=True,
    mobility_step_m=5.0,               # ~city-block drift per episode
    ho_enabled=True, ho_hysteresis_db=3.0, ho_ttt_tti=4,
    scheduler_policy="pf", fairness_p=0.5,
    traffic_model="poisson",
    traffic_params=dict(arrival_rate_hz=400.0, packet_size_bits=12_000.0),
    harq_bler=0.1, seed=0)

_preset(
    "dense_urban_twin",
    "The digital-twin regime of dense_urban_mobile: a mostly-static UE "
    "field where only 10% of UEs move per TTI (mobility_move_frac), with "
    "the radio chain running in the incremental (smart-update-in-scan) "
    "mode -- only the movers' rows re-run D..SE inside the compiled "
    "engine.  The preset that demonstrates the paper's compute-on-demand "
    "contribution at episode scale (benchmarks/BENCH_smart_update.json).",
    n_ues=200, n_cells=21, n_sectors=3, extent_m=1200.0,
    pathloss_model_name="UMi", fc_GHz=3.5, h_bs_m=10.0,
    power_W=6.3,
    rayleigh_fading=True, n_rb_subbands=4, coherence_rb=3,
    attach_ignores_fading=True,
    mobility_step_m=5.0, mobility_move_frac=0.1,
    radio_mode="incremental",
    ho_enabled=True, ho_hysteresis_db=3.0, ho_ttt_tti=4,
    scheduler_policy="pf", fairness_p=0.5,
    traffic_model="poisson",
    traffic_params=dict(arrival_rate_hz=400.0, packet_size_bits=12_000.0),
    harq_bler=0.1, seed=0)

_preset(
    "rural_macro",
    "Noise-limited wide-area coverage: RMa macro sites at 700 MHz over an "
    "8 km extent, bursty FTP-3 file downloads, round-robin airtime.",
    n_ues=120, n_cells=7, n_sectors=1, extent_m=8000.0,
    pathloss_model_name="RMa", fc_GHz=0.7, h_bs_m=35.0,
    power_W=40.0,                      # 46 dBm macro BS
    scheduler_policy="rr",
    traffic_model="ftp3",
    traffic_params=dict(file_rate_hz=0.5, file_size_bits=4_000_000.0),
    seed=0)

_preset(
    "indoor_hotspot",
    "LOS-dominated office floor: InH ceiling cells at 3.5 GHz over a "
    "120 m extent, full-buffer UEs on an opportunistic max-CQI scheduler "
    "riding per-RB fading peaks.",
    n_ues=40, n_cells=4, n_sectors=1, extent_m=120.0,
    pathloss_model_name="InH", fc_GHz=3.5, h_bs_m=3.0, h_ut_m=1.0,
    power_W=0.25,                      # 24 dBm pico BS
    rayleigh_fading=True, n_rb_subbands=6, coherence_rb=1,
    scheduler_policy="max_cqi", traffic_model="full_buffer", seed=0)

_preset(
    "outage_storm",
    "Resilience what-if: the handover_stress deployment under a cell "
    "fault storm -- every cell walks a Markov outage/sleep chain inside "
    "the compiled scan (sim.faults), so dark cells appear and recover "
    "mid-episode and A3 reattachment compensates through the unmodified "
    "radio chain.  Mobility keeps the A3 machine hot; the fault rates "
    "put ~13%% of cells in outage at stationarity (DESIGN.md "
    "§Fault-injection-and-self-healing; benchmarks/BENCH_faults.json "
    "gates the storm's overhead vs the fault-free twin).",
    n_ues=150, n_cells=19, n_sectors=1, extent_m=1500.0,
    pathloss_model_name="UMa", fc_GHz=3.5, h_bs_m=25.0, power_W=10.0,
    rayleigh_fading=True, attach_ignores_fading=True,
    mobility_step_m=5.0,
    ho_enabled=True, ho_hysteresis_db=3.0, ho_ttt_tti=4,
    faults=FaultConfig(outage_rate_hz=5.0, mean_outage_s=0.03,
                       sleep_rate_hz=5.0, mean_sleep_s=0.02,
                       sleep_atten_db=10.0),
    harq_bler=0.1, scheduler_policy="pf",
    traffic_model="poisson",
    traffic_params=dict(arrival_rate_hz=300.0, packet_size_bits=12_000.0),
    seed=0)

_preset(
    "handover_stress",
    "Mobility-driven handover churn: dense UMa grid with A3 handover "
    "(3 dB hysteresis, 4-TTI time-to-trigger) and HARQ; roll episodes "
    "with mobility_step_m set to exercise the A3 state machine.",
    n_ues=150, n_cells=19, n_sectors=1, extent_m=1500.0,
    pathloss_model_name="UMa", fc_GHz=3.5, h_bs_m=25.0, power_W=10.0,
    rayleigh_fading=True, attach_ignores_fading=True,
    ho_enabled=True, ho_hysteresis_db=3.0, ho_ttt_tti=4,
    harq_bler=0.1, scheduler_policy="pf",
    traffic_model="poisson",
    traffic_params=dict(arrival_rate_hz=300.0, packet_size_bits=12_000.0),
    seed=0)
