"""Large-scale fading: 3GPP TR 38.901 LOS probabilities + shadow fading.

* LOS probability per scenario (Table 7.4.2-1): distance-dependent Bernoulli
  state per (UE, cell) link; the simulator then mixes the LOS and NLOS
  pathloss formulas per link.
* Shadow fading: log-normal with the scenario's sigma_SF (LOS/NLOS
  variants), spatially correlated per site via a shared site component
  (links to co-sited sectors see the same shadowing).

Both integrate as multiplicative factors on the gain matrix, so they slot
into the dependency graph as root-adjacent state, exactly like Rayleigh
fading.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# sigma_SF in dB per (scenario, LOS?) -- TR 38.901 Table 7.4.1-1
SIGMA_SF_DB = {
    ("RMa", True): 4.0, ("RMa", False): 8.0,
    ("UMa", True): 4.0, ("UMa", False): 6.0,
    ("UMi", True): 4.0, ("UMi", False): 7.82,
    ("InH", True): 3.0, ("InH", False): 8.03,
}


def los_probability(scenario: str, d2d):
    """P(LOS) as a function of 2-D distance (TR 38.901 Table 7.4.2-1,
    h_UT <= 13 m forms)."""
    d = jnp.maximum(d2d, 1e-3)
    if scenario == "RMa":
        p = jnp.exp(-(d - 10.0) / 1000.0)
        return jnp.where(d <= 10.0, 1.0, p)
    if scenario == "UMa":
        p = (18.0 / d + jnp.exp(-d / 63.0) * (1.0 - 18.0 / d))
        return jnp.where(d <= 18.0, 1.0, p)
    if scenario == "UMi":
        p = (18.0 / d + jnp.exp(-d / 36.0) * (1.0 - 18.0 / d))
        return jnp.where(d <= 18.0, 1.0, p)
    if scenario == "InH":
        p = jnp.where(d <= 1.2, 1.0,
                      jnp.where(d <= 6.5, jnp.exp(-(d - 1.2) / 4.7),
                                jnp.exp(-(d - 6.5) / 32.9) * 0.32))
        return p
    raise ValueError(scenario)


def sample_los(key, scenario: str, d2d):
    """Bernoulli LOS state per link, (n_ue, n_cell) bool."""
    return jax.random.uniform(key, d2d.shape) < los_probability(scenario,
                                                                d2d)


def shadow_fading_gain(key, scenario: str, los_mask, n_sectors: int = 1,
                       site_corr: float = 0.5):
    """Log-normal shadow fading as a linear gain multiplier.

    ``site_corr`` of the variance is shared across a site's sectors
    (co-sited antennas see the same obstructions); the rest is per link.
    los_mask: (n_ue, n_cell) bool.
    """
    n_ue, n_cell = los_mask.shape
    n_sites = n_cell // max(n_sectors, 1)
    k1, k2 = jax.random.split(key)
    per_site = jax.random.normal(k1, (n_ue, n_sites))
    per_site = jnp.repeat(per_site, max(n_sectors, 1), axis=1)[:, :n_cell]
    per_link = jax.random.normal(k2, (n_ue, n_cell))
    z = (jnp.sqrt(site_corr) * per_site
         + jnp.sqrt(1.0 - site_corr) * per_link)
    sigma = jnp.where(los_mask, SIGMA_SF_DB[(scenario, True)],
                      SIGMA_SF_DB[(scenario, False)])
    return jnp.power(10.0, -0.1 * sigma * z * 0.1 * 10)  # 10^(-(sigma*z)/10)


def mixed_pathgain(los_model, nlos_model, los_mask, d2d, d3d, h_bs, h_ut):
    """Per-link LOS/NLOS mixture of two pathloss strategies."""
    g_los = los_model.get_pathgain(d2d, d3d, h_bs, h_ut)
    g_nlos = nlos_model.get_pathgain(d2d, d3d, h_bs, h_ut)
    return jnp.where(los_mask, g_los, g_nlos)
