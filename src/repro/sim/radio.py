"""The pure-functional radio chain: D -> G -> RSRP -> a -> SINR -> CQI -> SE.

This module is the single source of truth for the paper's Figure-1 physics.
Every consumer is a thin view over it:

* the smart-update graph (``core/blocks.py``) keeps its dirty-row caching
  machinery but delegates the *math* of each node to the functions here;
* the scan-compiled TTI engine (``mac/engine.py``) calls the same functions
  inside ``lax.scan`` (and inside ``shard_map`` on a device mesh);
* the batched env (``env/crrm_env.py``) calls :func:`radio_forward` inside
  ``reset`` to recompute the chain for a freshly drawn topology, which is
  what makes batching over *topologies* (not just seeds) possible.

Everything here is pure and jit/vmap/shard_map-compatible along the UE axis:
no hidden state, no Python mutation, arrays in -> arrays out.  The split
follows Sionna's differentiable-by-construction layers (PAPERS.md): physics
as stateless functions, caching as a wrapper.

Two data types:

* :class:`RadioConfig` -- the hashable trace-time configuration (pathloss /
  antenna closures, noise, frequency grid, fading + reporting knobs).  It is
  a NamedTuple of hashables, so it can ride ``jax.jit`` static arguments and
  key trace caches.
* :class:`RadioStatic` -- the per-deployment pytree: cell positions, the
  power matrix and sector boresights as *leaves* (traced, vmap-able) with a
  ``RadioConfig`` as static aux data.  ``CRRM.radio_static()`` builds one
  from the live graph roots.

PRNG key conventions (THE single documented convention -- ``CRRM``,
the episode engine and the env all draw through these helpers):

* :func:`episode_key` -- the per-simulation episode key is
  ``fold_in(PRNGKey(seed), 0x6d6163)`` ("mac");
* :func:`tti_keys` -- TTI ``t`` of an episode consumes four streams
  ``fold_in(key, 4 * t + i)`` for ``i`` = mobility, fading, traffic, HARQ
  (in that order);
* :func:`reset_keys` -- a topology-resampling env reset splits its seed into
  ``(topology, fading, episode)`` with one ``jax.random.split(key, 3)``;
* :func:`draw_fading` -- the one fading draw (wideband or per-RB subband
  block fading), shared by ``CRRM.resample_fading`` and the engine's
  per-TTI redraw so both consume identical streams from equal keys.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.sim import fading as fading_mod
from repro.sim import phy
from repro.sim.antenna import Antenna_gain


class RadioConfig(NamedTuple):
    """Hashable trace-time configuration of the radio chain.

    ``pathgain_fn`` and ``antenna`` are bound methods / frozen dataclasses
    (hashable, comparable), so a ``RadioConfig`` can sit in jit caches and
    in the static aux data of a :class:`RadioStatic` pytree.
    """

    pathgain_fn: Callable    # (d2d, d3d, h_bs, h_ut) -> linear gain
    antenna: Antenna_gain    # sector pattern (ignored when n_sectors == 1)
    n_sectors: int
    noise_w: float           # noise power per frequency chunk (watts)
    n_subbands: int          # power subbands
    n_rb: int                # physical RBs per subband
    n_rb_subbands: int       # CQI subbands per power subband (1 = wideband)
    coherence_rb: int        # block-fading coherence bandwidth, in RBs
    rayleigh_fading: bool
    attach_ignores_fading: bool   # associate on the long-term mean RSRP
    cqi_wideband: bool       # EESM-pool CQI reports per power subband
    eesm_beta: float

    @property
    def n_freq(self) -> int:
        """Scheduling-frequency chunks (trailing axis of SE/CQI/RSRP)."""
        return self.n_subbands * self.n_rb_subbands


def config_from_params(params, pathgain_fn, antenna) -> RadioConfig:
    """Bind a ``CRRM_parameters`` to concrete pathloss/antenna closures."""
    p = params
    return RadioConfig(
        pathgain_fn=pathgain_fn, antenna=antenna, n_sectors=p.n_sectors,
        noise_w=p.chunk_noise_W, n_subbands=p.n_subbands, n_rb=p.n_rb,
        n_rb_subbands=p.n_rb_subbands, coherence_rb=p.coherence_rb,
        rayleigh_fading=p.rayleigh_fading,
        attach_ignores_fading=p.attach_ignores_fading,
        cqi_wideband=(p.cqi_report == "wideband"),
        eesm_beta=p.cqi_eesm_beta)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RadioStatic:
    """Per-deployment radio inputs: array leaves + a static config.

    A pytree whose leaves (cell positions ``C``, power matrix ``P``, sector
    boresights ``bore``) trace through jit/vmap/shard_map while the
    :class:`RadioConfig` rides as static aux data -- so a jitted consumer
    re-specialises per *configuration* but not per *deployment*.
    """

    C: Any                   # (n_cells, 3)
    P: Any                   # (n_cells, n_freq) watts
    bore: Any                # (n_cells,) sector boresights, radians
    cfg: RadioConfig

    def tree_flatten(self):
        return (self.C, self.P, self.bore), self.cfg

    @classmethod
    def tree_unflatten(cls, cfg, children):
        C, P, bore = children
        return cls(C, P, bore, cfg)


class RadioOutputs(NamedTuple):
    """Everything :func:`radio_forward` derives for one set of positions."""

    G: Any                   # faded gain (n_ue, n_cell[, n_freq])
    rsrp: Any                # (n_ue, n_cell, n_freq)
    a: Any                   # (n_ue,) i32 serving-cell attachment
    gamma: Any               # (n_ue, n_freq) linear SINR
    cqi: Any                 # (n_ue, n_freq) at reporting resolution
    mcs: Any                 # (n_ue, n_freq)
    se: Any                  # (n_ue, n_freq) bits/s/Hz


# ---------------------------------------------------------------------------
# composable pure functions (the Figure-1 boxes)
# ---------------------------------------------------------------------------
def compute_distances(U, C):
    """(d2d, d3d, az): 2-D/3-D distances and the cell->UE bearing."""
    dx = U[:, None, 0] - C[None, :, 0]
    dy = U[:, None, 1] - C[None, :, 1]
    dz = U[:, None, 2] - C[None, :, 2]
    d2d = jnp.sqrt(dx * dx + dy * dy)
    d3d = jnp.sqrt(d2d * d2d + dz * dz)
    az = jnp.arctan2(dy, dx)
    return d2d, d3d, az


def make_gain_fn(pathgain_fn, antenna: Antenna_gain, n_sectors: int):
    """The link-gain closure: pathloss x sector pattern x fading.

    Shared verbatim by the graph's ``GainNode`` and :func:`pathgains`, so
    both paths are bit-exact by construction.  The fading factor may carry
    one extra trailing frequency axis (per-RB block fading); the gain then
    inherits that rank.
    """
    def gain(d2d, d3d, az, h_ut, h_bs, bore, fad):
        g = pathgain_fn(d2d, d3d, h_bs[None, :], h_ut[:, None])
        if n_sectors > 1:
            g = g * antenna.gain_linear(az, bore)
        if fad.ndim == g.ndim + 1:        # frequency-selective fading
            g = g[..., None]
        return g * fad

    return gain


def pathgains(cfg: RadioConfig, U, C, bore, geom=None):
    """Unfaded linear gain (n_ue, n_cell): pathloss x sector pattern.

    ``geom`` lets a caller reuse a :func:`compute_distances` result.
    """
    d2d, d3d, az = compute_distances(U, C) if geom is None else geom
    gain = make_gain_fn(cfg.pathgain_fn, cfg.antenna, cfg.n_sectors)
    ones = jnp.ones((U.shape[0], C.shape[0]), d2d.dtype)
    return gain(d2d, d3d, az, U[:, 2], C[:, 2], bore, ones)


def apply_fading(G0, fad):
    """Broadcast a fading factor onto an unfaded gain (rank-polymorphic)."""
    if fad.ndim == G0.ndim + 1:
        return G0[..., None] * fad
    return G0 * fad


def rsrp(G, P):
    """R[i, j, k] = p_jk * G_ijk (stacked per-frequency blocks of Fig. 1).

    ``G`` is (n_ue, n_cell) for the flat wideband channel or (n_ue, n_cell,
    n_freq) when fading is frequency selective; resolved at trace time.
    """
    if G.ndim == 3:
        return G * P[None, :, :]
    return G[:, :, None] * P[None, :, :]


def attachment(R):
    """Serve each UE from the cell with the largest wideband RSRP."""
    return jnp.argmax(R.sum(axis=2), axis=1).astype(jnp.int32)


def wanted(R, a):
    """w[i, k]: the serving cell's RSRP per frequency chunk."""
    return jnp.take_along_axis(R, a[:, None, None], axis=1)[:, 0, :]


def interference(R, w):
    """u[i, k] = sum_j R[i, j, k] - w[i, k]."""
    return R.sum(axis=1) - w


def sinr_from_wu(w, u, noise_w: float):
    """gamma = w / (noise + u), linear."""
    return w / (noise_w + u)


def sinr(R, a, noise_w: float):
    """(gamma, w, u) for serving assignment ``a``."""
    w = wanted(R, a)
    u = interference(R, w)
    return sinr_from_wu(w, u, noise_w), w, u


def quantize_cqi(gamma):
    """Per-chunk CQI quantisation of a linear SINR tensor."""
    return phy.sinr_db_to_cqi(phy.sinr_to_db(gamma))


def pool_report(gamma, n_rb_subbands: int, eesm_beta: float = 1.0):
    """Effective SINR at per-power-subband *reporting* resolution (EESM).

    Pools each power subband's ``n_rb_subbands`` CQI chunks with the
    exponential effective-SINR map (EESM, the standard link-abstraction
    for wideband CQI feedback on a selective channel):

        gamma_eff = -beta * log( mean_k exp(-gamma_k / beta) )

    which is dominated by the *faded* chunks -- a single wideband MCS must
    survive the whole allocation, so the report is conservative (a linear
    mean would Jensen-inflate it and wideband reporting would spuriously
    *beat* subband reporting).  Computed via logsumexp for stability at
    the large linear SINRs the chain produces; broadcast back onto the
    full frequency grid so downstream shapes are unchanged.
    Rank-polymorphic over leading axes (works on the (n_ue, n_freq) chain
    and the engine's tabulated (n_ue, n_cell, n_freq) tensors alike).
    """
    s = n_rb_subbands
    shp = gamma.shape
    g = gamma.reshape(shp[:-1] + (shp[-1] // s, s))
    eff = -eesm_beta * (jax.scipy.special.logsumexp(-g / eesm_beta, axis=-1)
                        - jnp.log(float(s)))
    return jnp.broadcast_to(eff[..., None], eff.shape + (s,)).reshape(shp)


def cqi_report(gamma, n_rb_subbands: int, wideband: bool,
               eesm_beta: float = 1.0):
    """CQI at the configured reporting resolution (``cqi_report`` knob).

    ``wideband`` decouples reporting from fading resolution: the SINR is
    EESM-pooled per power subband before quantisation, so every chunk of
    a subband reports the same CQI.  At ``n_rb_subbands=1`` (or subband
    reporting) this is exactly the legacy per-chunk :func:`quantize_cqi`.
    """
    if wideband and n_rb_subbands > 1:
        return quantize_cqi(pool_report(gamma, n_rb_subbands, eesm_beta))
    return quantize_cqi(gamma)


def cqi_of(cfg: RadioConfig, gamma):
    """:func:`cqi_report` with the knobs read off a :class:`RadioConfig`."""
    return cqi_report(gamma, cfg.n_rb_subbands, cfg.cqi_wideband,
                      cfg.eesm_beta)


def mcs_of(cqi):
    return phy.cqi_to_mcs(cqi)


def se_of(mcs, cqi):
    """Spectral efficiency of the selected MCS, zeroed at CQI 0."""
    return jnp.where(cqi > 0, phy.mcs_to_efficiency(mcs), 0.0)


def se_chain(cfg: RadioConfig, gamma):
    """(se, cqi) from a linear SINR tensor, at reporting resolution."""
    cqi = cqi_of(cfg, gamma)
    return se_of(mcs_of(cqi), cqi), cqi


# ---------------------------------------------------------------------------
# differentiable relaxations (DESIGN.md §RL-and-differentiability)
# ---------------------------------------------------------------------------
class RelaxConfig(NamedTuple):
    """Trace-time flags selecting soft relaxations of the MAC chain.

    The forward chain has three non-differentiable points: argmax
    attachment, the CQI quantisation staircase, and the max_cqi
    scheduler's winner-take-all.  Each gets an independently flag-gated
    relaxation; ``relax=None`` everywhere compiles the *exact* legacy
    program (trace-time switch, bitwise pin in tests/test_rl.py).  A
    NamedTuple of hashable scalars, so it rides jit static arguments and
    the ``episode_fns_for`` cache key like :class:`RadioConfig`.

    * ``soft_attach`` -- replace argmax attachment in the SINR chain by a
      temperature-``attach_tau`` softmax over per-cell wideband RSRP (in
      log domain, so the temperature is scale-free).  The *scheduling*
      attachment stays the hard argmax (an i32 index must index arrays);
      only the wanted/interference split softens, which is where the
      gradient w.r.t. per-cell powers flows.
    * ``cqi_mode`` -- ``"soft"``: SE from
      :func:`phy.soft_spectral_efficiency` (a C-inf sigmoid-staircase;
      the mode finite-difference checks validate); ``"ste"``:
      straight-through -- hard SE forward, soft-surrogate gradient
      (``soft + stop_gradient(hard - soft)``); ``"hard"``: quantised
      staircase (zero gradient almost everywhere).
    * ``soft_sched`` -- max_cqi's winner-take-all becomes a
      temperature-``sched_tau`` softmax share over each cell's active
      UEs (pf/rr are unaffected: pf is already smooth, rr is
      CQI-independent).
    """

    soft_attach: bool = True
    attach_tau: float = 0.1       # log-RSRP softmax temperature
    cqi_mode: str = "soft"        # "soft" | "ste" | "hard"
    se_sharpness: float = 2.0     # sigmoid slope of the soft staircase, /dB
    soft_sched: bool = True
    sched_tau: float = 1.0        # SE-softmax temperature (bits/s/Hz scale)


def soft_attach_sinr(R, meas, tau: float, noise_w: float):
    """Soft wanted/interference split: gamma under softmax attachment.

    ``meas`` is the (n_ue, n_cell) wideband association measurement (the
    same tensor the hard argmax reads).  Attachment weights are
    ``softmax(log meas / tau)`` per UE; the wanted power is the weighted
    combination of per-cell RSRP rows and everything else interferes:

        w[i, k] = sum_j p_ij R[i, j, k],   u[i, k] = sum_j R[i, j, k] - w

    As ``tau -> 0`` the weights collapse onto the argmax cell and this
    reduces to :func:`sinr`.  Differentiable w.r.t. ``R`` *and* ``meas``
    (so power changes can re-rank cells with a smooth effect).
    """
    logits = jnp.log(jnp.maximum(meas, 1e-30)) / tau
    p = jax.nn.softmax(logits, axis=1)                     # (n_ue, n_cell)
    w = jnp.einsum("uc,ucf->uf", p, R)
    u = R.sum(axis=1) - w
    return sinr_from_wu(w, u, noise_w)


def se_chain_relaxed(cfg: RadioConfig, gamma, relax: "RelaxConfig | None"):
    """(se, cqi): :func:`se_chain` with the CQI staircase optionally relaxed.

    ``relax=None`` / ``cqi_mode="hard"`` is byte-for-byte :func:`se_chain`.
    The reported ``cqi`` stays hard-quantised i32 in every mode (consumers
    index tables with it); only the SE value softens.
    """
    if relax is None or relax.cqi_mode == "hard":
        return se_chain(cfg, gamma)
    if cfg.cqi_wideband and cfg.n_rb_subbands > 1:
        gamma = pool_report(gamma, cfg.n_rb_subbands, cfg.eesm_beta)
    cqi = quantize_cqi(gamma)
    soft = phy.soft_spectral_efficiency(gamma, relax.se_sharpness)
    if relax.cqi_mode == "ste":
        hard = se_of(mcs_of(cqi), cqi)
        return soft + jax.lax.stop_gradient(hard - soft), cqi
    return soft, cqi


# ---------------------------------------------------------------------------
# THE dirtiness convention (DESIGN.md §Smart-update-in-scan)
# ---------------------------------------------------------------------------
# Both smart-update surfaces -- the host-driven graph (core/graph.py row
# buckets) and the scan-compiled incremental path below -- speak one
# convention: a dirty-row set becomes a *fixed-size index vector padded with
# a repeated valid row index*.  Row recomputation is idempotent (same inputs
# -> bit-identical outputs), so padded rows recompute and scatter their own
# unchanged values; no masking, no `where`, no out-of-bounds clamping.  The
# host side pads to power-of-two buckets (logarithmic jit specialisations);
# the traced side compacts a boolean mask to a static budget (one
# specialisation per budget), which is what survives `lax.scan`, `vmap`
# batching and `shard_map` sharding unchanged.
def pad_indices(rows) -> "np.ndarray":
    """Pad a host-side dirty-row index set to the next power-of-two bucket.

    Padding repeats the first index, which keeps the padded recompute
    idempotent while bounding the number of distinct jit specialisations
    logarithmically in the row count.  (Re-exported by ``core.graph`` --
    the graph's row buckets and the scan's :func:`dirty_indices` are two
    faces of this one convention.)
    """
    import numpy as np
    idx = np.asarray(sorted(rows), dtype=np.int32)
    n = len(idx)
    bucket = 1 << max(0, (n - 1).bit_length())
    if bucket > n:
        idx = np.concatenate([idx, np.full(bucket - n, idx[0], np.int32)])
    return idx


def dirty_indices(mask, budget: int):
    """Compact a traced boolean dirty mask to a ``budget``-sized index vector.

    The traced twin of :func:`pad_indices`: the indices of the True entries
    in ascending order, padded with row 0 -- a *valid* row, so the padded
    recompute is idempotent exactly like the graph's repeated-first-index
    buckets.  ``budget`` must be a static upper bound on the dirty count
    (dirt beyond the budget would be silently dropped -- callers derive the
    bound from the mover count).  Pure gather/scatter shapes: composes with
    ``vmap`` and ``shard_map`` (each shard compacts its local mask against
    the same budget).

    Implemented as an O(n log budget) ``top_k`` over a rank score instead
    of the full ``jnp.nonzero`` compaction (a sort-based cumsum+scatter
    that measured 14 ms/TTI at 100k UEs): True rows score ``n - i`` (so
    the top-k of the score IS the ascending True index set), False rows
    score 0 and their slots are rewritten to the row-0 pad.  Callers with
    *known* dirty counts skip even this -- the window-mover regimes
    enumerate their rows in O(n_move) via :func:`window_indices`.
    """
    n = mask.shape[0]
    k = min(budget, n)
    score = jnp.where(mask, n - jnp.arange(n, dtype=jnp.int32), 0)
    vals, idx = jax.lax.top_k(score, k)
    idx = jnp.where(vals > 0, idx, 0).astype(jnp.int32)
    if budget > n:                       # degenerate: pad beyond the axis
        idx = jnp.concatenate(
            [idx, jnp.zeros((budget - n,), jnp.int32)])
    return idx


def window_indices(start, n_move: int, n: int, *, offset=0, n_loc=None):
    """Exact-count dirty rows of a circular mover window, in O(n_move).

    The window movers (``sim.mobility.window_movers``) are *contiguous*
    global indices ``[start, start + n_move) mod n``, so each of the
    ``n_move`` window slots maps straight to a row -- no mask, no
    compaction.  ``offset``/``n_loc`` restrict to a shard's contiguous
    local block (global row ``g`` -> local row ``g - offset``); rows
    outside the block pad with row 0, THE idempotent valid-index padding
    of the dirtiness convention.  When the window covers the block
    (``n_move >= n_loc``) every local row recomputes.

    Returns ``(idx, count)``: the padded local index vector plus the
    number of genuinely dirty local rows (the telemetry ``dirty_rows``
    counter; psums to the global ``n_move`` under a mesh).
    """
    n_loc = n if n_loc is None else n_loc
    if n_move >= n_loc:
        return jnp.arange(n_loc, dtype=jnp.int32), jnp.int32(n_loc)
    g = (start + jnp.arange(n_move, dtype=jnp.int32)) % n
    local = g - offset
    valid = (local >= 0) & (local < n_loc)
    return (jnp.where(valid, local, 0).astype(jnp.int32),
            valid.sum().astype(jnp.int32))


# ---------------------------------------------------------------------------
# the incremental (smart-update-in-scan) path
# ---------------------------------------------------------------------------
class RadioState(NamedTuple):
    """The carried radio tensors of the incremental path.

    Everything the MAC needs per TTI plus what a dirty-row patch must
    scatter into.  A plain pytree, so it rides a ``lax.scan`` carry, a
    ``vmap`` batch axis, or a ``shard_map`` UE shard like any other
    per-UE state.  Optional leaves are ``None`` when the regime doesn't
    need them (trace-time constant treedef):

    * ``se``/``cqi``/``a`` -- the serving-chain outputs at the
      instantaneous attachment (non-handover regimes; the O(n_ue) carry,
      attachment being row-local);
    * ``meas`` + ``se_all``/``cqi_all`` -- the (n_ue, n_cell) wideband
      measurement and (n_ue, n_cell, n_freq) per-candidate-cell tables
      (handover regimes, where the serving cell is *carried* MAC state
      and any UE may switch cells without its radio row dirtying -- A3
      reads the full measurement matrix every TTI);
    * ``G``/``G0`` -- the faded / long-term gain matrices, kept only when
      per-cell power deltas must be applied without re-running
      geometry+pathloss (:func:`radio_update_cells`).

    Leaves that a regime doesn't read are ``None`` rather than dead
    weight: an (n_ue, n_cell) leaf in a scan carry costs a scatter *and*
    a carry copy per TTI, which at 100k UEs x 57 cells is most of the
    incremental path's budget.
    """

    meas: Any        # (n_ue, n_cell) wideband measurement RSRP | None
    a: Any           # (n_ue,) i32 attachment (argmax of meas rows) | None
    se: Any          # (n_ue, n_freq) | None
    cqi: Any         # (n_ue, n_freq) | None
    se_all: Any      # (n_ue, n_cell, n_freq) | None
    cqi_all: Any     # (n_ue, n_cell, n_freq) | None
    G: Any           # faded gain (n_ue, n_cell[, n_freq]) | None
    G0: Any          # unfaded gain (n_ue, n_cell) | None


def _chain_rows(cfg: RadioConfig, U_rows, C, bore, fad_rows, P, *,
                with_tables: bool, with_gain: bool,
                cell_axis=None) -> RadioState:
    """The D→G→RSRP→a→SINR→CQI→SE chain for a slab of UE rows.

    Row-local by construction: every output row depends only on its own
    position/fading row (plus the replicated cell state), which is what
    makes the scatter-patch exact.  Called at full width by
    :func:`radio_init` and on gathered dirty rows by
    :func:`radio_update_rows` -- ONE implementation, so the incremental
    path is bit-exact with its own init (and matches the dense engine
    recompute, which composes the same pure functions).

    ``cell_axis`` names the mesh axes the *cell* dimension is sharded
    over (UE×cell meshes): ``C``/``bore``/``P`` and the fading columns
    are then local shards, the interference total psums across shards,
    and attachment runs through the cross-shard argmax
    (``core.distributed._global_best`` -- lowest global cell index wins
    ties, exactly like ``jnp.argmax``).  ``None`` compiles the verbatim
    single-shard chain.
    """
    geom = compute_distances(U_rows, C)
    G0 = pathgains(cfg, U_rows, C, bore, geom=geom)
    # fad_rows=None: the unfaded channel (skip the gather and the *1.0 --
    # G0 * ones is bitwise G0, so this is a pure elision)
    G = G0 if fad_rows is None else apply_fading(G0, fad_rows)
    R = rsrp(G, P)
    if cfg.rayleigh_fading and cfg.attach_ignores_fading:
        meas = rsrp(G0, P).sum(axis=2)      # long-term association (L3)
    else:
        meas = R.sum(axis=2)
    if cell_axis is None:
        a = jnp.argmax(meas, axis=1).astype(jnp.int32)
        mine = my = m_loc = None
    else:
        from repro.core.distributed import _axis_index, _global_best
        m_loc = C.shape[0]
        _, a, mine = _global_best(meas.max(axis=1),
                                  meas.argmax(axis=1).astype(jnp.int32),
                                  m_loc, cell_axis)
        my = _axis_index(cell_axis)
    se = cqi = se_all = cqi_all = None
    if with_tables:
        # the serving cell is carried MAC state (A3): tabulate the SINR
        # chain for every candidate cell so a later handover is a gather
        total = R.sum(axis=1)
        if cell_axis is not None:
            total = jax.lax.psum(total, cell_axis)
        gamma_all = R / (cfg.noise_w + (total[:, None, :] - R))
        se_all, cqi_all = se_chain(cfg, gamma_all)
    else:
        if cell_axis is None:
            gamma, _, _ = sinr(R, a, cfg.noise_w)
        else:
            # owning-shard gather of the serving row, then the psummed
            # interference split (total reorders the per-cell sum across
            # shards: 1e-5-class, the documented mesh contract)
            local_col = jnp.clip(a - my * m_loc, 0, m_loc - 1)
            w_loc = jnp.take_along_axis(
                R, local_col[:, None, None], axis=1)[:, 0, :]
            w = jax.lax.psum(
                jnp.where(mine[:, None], w_loc, 0.0), cell_axis)
            total = jax.lax.psum(R.sum(axis=1), cell_axis)
            gamma = sinr_from_wu(w, total - w, cfg.noise_w)
        se, cqi = se_chain(cfg, gamma)
    return RadioState(meas=meas if with_tables else None,
                      a=None if with_tables else a, se=se,
                      cqi=cqi, se_all=se_all, cqi_all=cqi_all,
                      G=G if with_gain else None,
                      G0=G0 if (with_gain and cfg.rayleigh_fading
                                and cfg.attach_ignores_fading) else None)


def radio_init(cfg: RadioConfig, U, C, bore, fad, P, *,
               with_tables: bool = False,
               with_gain: bool = False, cell_axis=None) -> RadioState:
    """Full-width :class:`RadioState`: the everything-dirty base case.

    Exactly :func:`_chain_rows` over all rows, so a subsequent
    :func:`radio_update_rows` patch scatters values that are bitwise
    consistent with what a full recompute would produce.
    """
    return _chain_rows(cfg, U, C, bore, fad, P, with_tables=with_tables,
                       with_gain=with_gain, cell_axis=cell_axis)


def _scatter(old, idx, new_rows):
    return None if old is None else old.at[idx].set(new_rows)


def radio_update_rows(cfg: RadioConfig, state: RadioState, U, C, bore,
                      fad, P, idx, *, cell_axis=None) -> RadioState:
    """Recompute the chain for UE rows ``idx`` and scatter them in place.

    ``idx`` follows THE dirtiness convention (:func:`dirty_indices` /
    :func:`pad_indices`): a fixed-size vector of dirty rows padded with
    repeated valid indices, so duplicate writes are idempotent and no
    validity mask is needed.  Cost is O(|idx| * n_cell) instead of the
    dense O(n_ue * n_cell) -- the smart-update win, inside jit.
    ``fad=None`` selects the unfaded chain (no gather, no multiply).
    ``cell_axis`` shards the cell dimension (see :func:`_chain_rows`);
    the scatter stays local (per-UE leaves are identical on every cell
    shard after the psums, so patched rows agree across shards).
    """
    fad_rows = None if fad is None else fad[idx]
    rows = _chain_rows(cfg, U[idx], C, bore, fad_rows, P,
                       with_tables=state.se_all is not None,
                       with_gain=state.G is not None, cell_axis=cell_axis)
    return RadioState(*(_scatter(o, idx, n)
                        for o, n in zip(state, rows)))


def radio_update_rows_fused(cfg: RadioConfig, state: RadioState, U, C, bore,
                            fad, P, idx, *, interpret=None) -> RadioState:
    """:func:`radio_update_rows` through the fused Pallas pipeline.

    The dirty-row kernel variant: gather the dirty UE slab (positions +
    fading rows) with XLA, stream it through ``kernels.ops.fused_sinr``
    (gain recomputed inside VMEM tiles against *all* cells -- the
    (|idx|, n_cell) matrices never touch HBM), scatter the patched
    a/se/cqi rows back.  Covers the O(n_ue)-carry regimes only: handover
    tables (``se_all``) and carried gains (``G``) need O(n_cell)-per-row
    outputs the streaming accumulator never materialises, so those
    regimes raise and stay on the XLA row recompute.  Same dirtiness
    convention, same idempotent padded scatter; parity vs the XLA rows
    is asserted across every registry scenario in
    tests/test_smart_update_scan.py.
    """
    if state.se_all is not None or state.G is not None:
        raise ValueError(
            "the fused dirty-row backend carries only the O(n_ue) "
            "RadioState (a/se/cqi); handover tables (se_all) and carried "
            "gains (G) need the XLA row recompute (radio_update_rows)")
    from repro.kernels import ops
    fad_rows = None if fad is None else fad[idx]
    gamma, a_rows, _, _ = ops.fused_sinr(
        U[idx], C, P, pathgain_fn=cfg.pathgain_fn, noise_w=cfg.noise_w,
        boresight=bore, fad=fad_rows,
        attach_on_mean=(fad_rows is not None and cfg.rayleigh_fading
                        and cfg.attach_ignores_fading),
        n_sectors=cfg.n_sectors, interpret=interpret)
    se_rows, cqi_rows = se_chain(cfg, gamma)
    rows = RadioState(meas=None, a=a_rows, se=se_rows, cqi=cqi_rows,
                      se_all=None, cqi_all=None, G=None, G0=None)
    return RadioState(*(_scatter(o, idx, n)
                        for o, n in zip(state, rows)))


def radio_update_cells(cfg: RadioConfig, state: RadioState, P,
                       dirty_cell_mask, *, cell_axis=None) -> RadioState:
    """Apply a per-cell power delta from the carried gain matrices.

    A dirty cell column changes *every* UE's interference sum, so all
    per-UE outputs recompute -- but from the carried ``G``/``G0`` (kept
    with ``with_gain=True``), skipping geometry and pathloss, the
    expensive transcendental half of the chain.  Branch-free: the new
    tensors are computed unconditionally and ``jnp.where``-selected
    against the carried ones on ``dirty_cell_mask.any()``, so the call
    composes with ``vmap``/``shard_map`` (no data-dependent control
    flow).  In the episode engine the power plan is scan-constant, so
    cell dirt collapses into the prepare-time :func:`radio_init`; this
    entry point serves callers that mutate ``P`` mid-stream -- the
    in-scan cell fault process (``sim.faults``) above all, whose
    outage mask changes ``P`` at fault transitions.

    ``cell_axis`` shards the cell dimension exactly as in
    :func:`_chain_rows`: the carried gains and ``P`` are local cell
    blocks, attachment runs through the cross-shard argmax and the
    interference totals psum.  ``dirty_cell_mask`` may be global or
    local -- only its ``any()`` is read, and the fault process computes
    it replicated on every shard.
    """
    R = rsrp(state.G, P)
    if cfg.rayleigh_fading and cfg.attach_ignores_fading:
        meas = rsrp(state.G0, P).sum(axis=2)
    else:
        meas = R.sum(axis=2)
    if cell_axis is None:
        a = jnp.argmax(meas, axis=1).astype(jnp.int32)
        mine = my = m_loc = None
    else:
        from repro.core.distributed import _axis_index, _global_best
        m_loc = meas.shape[1]
        _, a, mine = _global_best(meas.max(axis=1),
                                  meas.argmax(axis=1).astype(jnp.int32),
                                  m_loc, cell_axis)
        my = _axis_index(cell_axis)
    se = cqi = se_all = cqi_all = None
    if state.se_all is not None:
        total = R.sum(axis=1)
        if cell_axis is not None:
            total = jax.lax.psum(total, cell_axis)
        gamma_all = R / (cfg.noise_w + (total[:, None, :] - R))
        se_all, cqi_all = se_chain(cfg, gamma_all)
        a = None
    else:
        if cell_axis is None:
            gamma, _, _ = sinr(R, a, cfg.noise_w)
        else:
            local_col = jnp.clip(a - my * m_loc, 0, m_loc - 1)
            w_loc = jnp.take_along_axis(
                R, local_col[:, None, None], axis=1)[:, 0, :]
            w = jax.lax.psum(
                jnp.where(mine[:, None], w_loc, 0.0), cell_axis)
            total = jax.lax.psum(R.sum(axis=1), cell_axis)
            gamma = sinr_from_wu(w, total - w, cfg.noise_w)
        se, cqi = se_chain(cfg, gamma)
    new = RadioState(meas=meas, a=a, se=se, cqi=cqi, se_all=se_all,
                     cqi_all=cqi_all, G=state.G, G0=state.G0)
    any_dirty = jnp.any(dirty_cell_mask)
    pick = lambda n, o: (None if o is None
                         else jnp.where(any_dirty, n, o))
    return RadioState(*(pick(n, o) for n, o in zip(new, state)))


def radio_update(static: RadioStatic, state: RadioState, U,
                 dirty_ue_mask, dirty_cell_mask=None, *, budget: int,
                 fad=None, P=None, window=None) -> RadioState:
    """One smart update: dirty UE rows + (optionally) dirty cell columns.

    The mask-level façade over :func:`radio_update_rows` /
    :func:`radio_update_cells`: ``dirty_ue_mask`` is compacted to a
    ``budget``-sized index vector (:func:`dirty_indices`) and patched
    row-locally; a non-None ``dirty_cell_mask`` then re-derives the
    per-UE outputs from the carried gains under the (possibly new) power
    matrix ``P``.  Everything is branch-free and shape-static, so the
    call drops into ``lax.scan`` bodies, ``vmap`` batches and
    ``shard_map`` shards unchanged (each shard passes its local mask and
    rows).

    ``window=(start, n)`` declares the dirty rows to be the circular
    index window ``[start, start + n) mod n_ue`` (the window-mover
    mobility regime): the index vector is then *enumerated* in O(n)
    (:func:`window_indices`) instead of compacted from the mask, and
    ``dirty_ue_mask`` may be ``None``.  ``budget`` still bounds the
    vector (``n <= budget`` is required).
    """
    cfg = static.cfg
    P = static.P if P is None else P
    if window is not None:
        start, n_win = window
        if n_win > budget:
            raise ValueError(f"window size {n_win} exceeds budget {budget}")
        idx, _ = window_indices(start, n_win, U.shape[0])
        if n_win < budget:               # same static shape as the mask path
            idx = jnp.concatenate(
                [idx, jnp.zeros((budget - n_win,), jnp.int32)])
    else:
        idx = dirty_indices(dirty_ue_mask, budget)
    state = radio_update_rows(cfg, state, U, static.C, static.bore,
                              fad, P, idx)
    if dirty_cell_mask is not None:
        state = radio_update_cells(cfg, state, P, dirty_cell_mask)
    return state


# ---------------------------------------------------------------------------
# fading + PRNG key conventions (DESIGN.md §Radio-fns)
# ---------------------------------------------------------------------------
#: fold_in tag deriving the per-simulation episode key from params.seed
EPISODE_KEY_TAG = 0x6d6163   # "mac"


def episode_key(seed: int):
    """The legacy per-sim episode key: fold ``EPISODE_KEY_TAG`` into the
    simulation seed (what ``CRRM.init_episode_state(key=None)`` uses)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), EPISODE_KEY_TAG)


def tti_keys(key, t):
    """The four per-TTI streams: (mobility, fading, traffic, HARQ).

    Stream ``i`` of TTI ``t`` is ``fold_in(key, 4 * t + i)`` -- one flat
    fold per (TTI, purpose) pair, so episodes of any length never collide
    streams and a single TTI is reproducible in isolation.
    """
    return tuple(jax.random.fold_in(key, 4 * t + i) for i in range(4))


def reset_keys(key):
    """A topology-resampling reset's streams: (topology, fading, episode)."""
    return jax.random.split(key, 3)


#: fold_in tag deriving the birth-death churn key lineage from the episode
#: key -- a SEPARATE lineage from the flat 4t+i folds of :func:`tti_keys`,
#: so enabling churn cannot perturb the four legacy per-TTI streams (every
#: pre-churn trajectory stays bitwise intact).
CHURN_KEY_TAG = 0x636872   # "chr"


def churn_keys(key, t):
    """The four per-TTI birth-death streams: (birth, death, position, fading).

    Stream ``i`` of TTI ``t`` is ``fold_in(fold_in(key, CHURN_KEY_TAG),
    4 * t + i)`` -- the same flat per-(TTI, purpose) layout as
    :func:`tti_keys`, hung off its own tag so the two lineages never
    collide.  Depends only on the episode key and the *absolute* TTI
    index, which is what makes chunked digital-twin serving (and
    checkpoint/restore at any chunk boundary) bitwise reproduce an
    uninterrupted run.
    """
    k = jax.random.fold_in(key, CHURN_KEY_TAG)
    return tuple(jax.random.fold_in(k, 4 * t + i) for i in range(4))


#: fold_in tag deriving the cell-fault key lineage from the episode key --
#: its own lineage like :data:`CHURN_KEY_TAG`, so enabling the fault
#: process cannot perturb the four legacy per-TTI streams or the churn
#: streams (every fault-free trajectory stays bitwise intact).
FAULT_KEY_TAG = 0x666c74   # "flt"


def fault_keys(key, t):
    """The per-TTI cell-fault transition key.

    ``fold_in(fold_in(key, FAULT_KEY_TAG), t)`` -- one stream per TTI,
    hung off its own tag (see :func:`churn_keys` for the lineage
    discipline).  Depends only on the episode key and the *absolute*
    TTI index, so chunked digital-twin serving and checkpoint/restore
    at any chunk boundary bitwise reproduce an uninterrupted run.
    """
    return jax.random.fold_in(jax.random.fold_in(key, FAULT_KEY_TAG), t)


def draw_fading(cfg: RadioConfig, key, n_ues: int, n_cells: int,
                dtype=jnp.float32):
    """THE fading draw: wideband Rayleigh or per-RB subband block fading.

    Single source for ``CRRM.resample_fading`` (graph root refresh), the
    engine's per-TTI redraw and the env's topology-resampling reset: equal
    keys yield bit-identical tensors everywhere.  Returns (n_ues, n_cells)
    wideband or (n_ues, n_cells, n_freq) when ``n_rb_subbands > 1``.
    """
    if cfg.n_rb_subbands > 1:
        return fading_mod.subband_rayleigh_power(
            key, n_ues, n_cells, cfg.n_subbands * cfg.n_rb,
            cfg.coherence_rb, cfg.n_freq, dtype)
    return fading_mod.rayleigh_power(key, (n_ues, n_cells), dtype)


def unit_fading(cfg: RadioConfig, n_ues: int, n_cells: int,
                dtype=jnp.float32):
    """The no-fading factor (all ones) at the configured resolution."""
    return jnp.ones((n_ues, n_cells), dtype)


# ---------------------------------------------------------------------------
# shared jitted wrappers
# ---------------------------------------------------------------------------
# The graph nodes (core/blocks.py) and :func:`radio_forward` both dispatch
# THESE jitted callables, so an eager ``radio_forward`` reuses the exact
# executables the graph compiled (or vice versa) and the two are bit-exact
# -- not merely close: separate fusions of the same math can differ by an
# ulp, shared executables cannot.  Static arguments (the pathloss/antenna
# closures, noise, reporting knobs) are hashables, so compilations are also
# shared across simulator instances with equal configurations.
geometry_jit = jax.jit(compute_distances)


@partial(jax.jit, static_argnums=(0, 1, 2))
def gain_jit(pathgain_fn, antenna, n_sectors, U, C, d2d, d3d, az, bore, fad):
    """Jitted :func:`make_gain_fn` application (the ``GainNode`` program)."""
    return make_gain_fn(pathgain_fn, antenna, n_sectors)(
        d2d, d3d, az, U[:, 2], C[:, 2], bore, fad)


rsrp_jit = jax.jit(rsrp)
attach_jit = jax.jit(attachment)
wanted_jit = jax.jit(wanted)
interference_jit = jax.jit(interference)
sinr_jit = jax.jit(sinr_from_wu, static_argnums=(2,))
cqi_jit = jax.jit(quantize_cqi)
cqi_report_jit = jax.jit(cqi_report, static_argnums=(1, 2, 3))
mcs_jit = jax.jit(mcs_of)
se_jit = jax.jit(se_of)


# ---------------------------------------------------------------------------
# the one-call forward pass (dense backends: fused Pallas pipeline | XLA)
# ---------------------------------------------------------------------------
#: cached result of the one-time Pallas capability probe (None = not probed)
_PALLAS_PROBE = None


def pallas_available() -> bool:
    """One-time capability probe for the fused Pallas backend.

    True iff a compiled (non-interpret) ``fused_sinr_accumulate`` builds
    and runs on the default backend -- i.e. a real TPU (or compatible
    Pallas lowering) is present.  On CPU containers this is False and
    ``backend="auto"`` stays on XLA; an *explicit* ``backend="pallas"``
    still runs there through the kernel's interpret mode (bit-faithful,
    Python-speed -- the correctness path CI exercises).
    """
    global _PALLAS_PROBE
    if _PALLAS_PROBE is None:
        try:
            from repro.kernels import ops
            if jax.default_backend() == "cpu":
                _PALLAS_PROBE = False
            else:
                ops.fused_sinr(
                    jnp.zeros((8, 3)), jnp.ones((8, 3)),
                    jnp.ones((8, 1)),
                    pathgain_fn=lambda d2, d3, hb, hu: 1.0 / (1.0 + d3),
                    noise_w=1e-12, interpret=False)
                _PALLAS_PROBE = True
        except Exception:                      # pragma: no cover - no TPU
            _PALLAS_PROBE = False
    return _PALLAS_PROBE


def pallas_supported(cfg: RadioConfig, fad) -> bool:
    """Can the fused kernel express this configuration?

    Per-link fading (wideband or per-RB, including the
    ``attach_ignores_fading`` long-term-association regime) streams
    through the kernel's tile pipeline since the incremental backend
    landed, so ``fad`` no longer disqualifies.  The one remaining gap is
    a *non-stock* sector pattern: the kernel inlines the 3GPP 65-deg /
    30-dB horizontal pattern for fusion, so antennas with other
    ``phi_3dB_deg`` / ``A_max_dB`` / ``max_gain_dBi`` values fall back
    to XLA under ``backend="auto"`` (and raise under an explicit
    ``backend="pallas"`` with a diagnostic naming the offending knob).
    """
    return pallas_unsupported_reason(cfg, fad) is None


def pallas_unsupported_reason(cfg: RadioConfig, fad) -> "str | None":
    """``None`` when the fused kernel covers the configuration, else a
    precise human-readable diagnostic (the ``backend="pallas"`` error)."""
    del fad                     # every fading layout is kernel-expressible
    if cfg.n_sectors > 1:
        a = cfg.antenna
        stock = {"phi_3dB_deg": 65.0, "A_max_dB": 30.0, "max_gain_dBi": 0.0}
        for knob, want in stock.items():
            have = getattr(a, knob, want)
            if abs(have - want) > 1e-6:
                return (f"non-stock sector pattern: antenna.{knob}={have!r} "
                        f"(the kernel inlines the stock 3GPP pattern, "
                        f"{knob}={want}); use the XLA backend")
    return None


def _forward_pallas(static: RadioStatic, positions, P, fad=None,
                    interpret=None) -> RadioOutputs:
    """Dense chain through the fused Pallas pipeline (kernels/fused_sinr).

    The (n_ue, n_cell) distance/gain/RSRP matrices never materialise:
    the kernel accumulates the O(N) state (total power, best server, its
    RSRP row) and the CQI/SE tail runs on that.  A ``fad`` tensor streams
    through the tile pipeline (it *is* materialised -- the caller drew
    it -- but the gain/RSRP products stay in VMEM).  ``G``/``rsrp`` are
    ``None`` in the returned :class:`RadioOutputs` -- callers that need
    the full matrices want the XLA backend.
    """
    from repro.kernels import ops
    cfg = static.cfg
    gamma, a, w, u = ops.fused_sinr(
        positions, static.C, P, pathgain_fn=cfg.pathgain_fn,
        noise_w=cfg.noise_w, boresight=static.bore, fad=fad,
        attach_on_mean=(fad is not None and cfg.rayleigh_fading
                        and cfg.attach_ignores_fading),
        n_sectors=cfg.n_sectors, interpret=interpret)
    cqi = cqi_report_jit(gamma, cfg.n_rb_subbands, cfg.cqi_wideband,
                         cfg.eesm_beta)
    mcs = mcs_jit(cqi)
    se = se_jit(mcs, cqi)
    return RadioOutputs(G=None, rsrp=None, a=a, gamma=gamma, cqi=cqi,
                        mcs=mcs, se=se)


def radio_forward(static: RadioStatic, positions, fad=None,
                  fading_key=None, P=None, backend=None) -> RadioOutputs:
    """The whole radio chain as one pure call.

    ``positions`` is (n_ue, 3); the fading factor comes from ``fad`` (an
    explicit tensor), from ``fading_key`` (a fresh :func:`draw_fading`,
    honouring ``cfg.rayleigh_fading``) or defaults to no fading.  ``P``
    overrides the static power matrix (the RL power-control hook).

    ``backend`` selects the dense execution path: ``None``/``"xla"``
    (the materialised chain below -- the default, and the branch every
    bit-exactness claim below refers to), ``"pallas"`` (the fused
    ``kernels/fused_sinr`` pipeline -- O(N) HBM traffic, interpret-mode
    on CPU, ``G``/``rsrp`` returned as ``None`` since they are never
    materialised, outputs within 1e-4 of XLA) or ``"auto"`` (Pallas iff
    the capability probe and :func:`pallas_supported` both pass, else
    XLA).  The flip is opt-in -- ``None`` never dispatches the kernel,
    so existing callers keep materialised, bit-exact outputs on every
    platform.  Both branches are parity-tested across every registry
    scenario (tests/test_kernel_vs_crrm.py).

    Bit-exact with the smart-update graph's node queries for the same
    inputs (asserted in tests/test_radio_fns.py): the chain below mirrors
    the graph node-for-node through the shared jitted wrappers above, so
    both paths execute the same compiled programs.  jit-, vmap- (batch
    topologies by vmapping over ``positions``/``fad``) and
    shard_map-compatible along the UE axis; under an outer trace the
    nested jits inline.
    """
    cfg = static.cfg
    P = static.P if P is None else P
    if backend not in (None, "auto", "xla", "pallas"):
        raise ValueError(f"backend must be 'auto', 'xla' or 'pallas'; "
                         f"got {backend!r}")
    n_ue, n_cell = positions.shape[0], static.C.shape[0]
    use_pallas = False
    if backend == "pallas":
        reason = pallas_unsupported_reason(cfg, fad)
        if reason is not None:
            raise ValueError(
                f"backend='pallas' cannot express this configuration: "
                f"{reason}")
        use_pallas = True
    elif backend == "auto":
        use_pallas = pallas_supported(cfg, fad) and pallas_available()
    if use_pallas:
        if fad is None and fading_key is not None and cfg.rayleigh_fading:
            fad = draw_fading(cfg, fading_key, n_ue, n_cell)
        return _forward_pallas(static, positions, P, fad=fad)
    if fad is None:
        if fading_key is not None and cfg.rayleigh_fading:
            fad = draw_fading(cfg, fading_key, n_ue, n_cell)
        else:
            fad = unit_fading(cfg, n_ue, n_cell)
    d2d, d3d, az = geometry_jit(positions, static.C)
    G = gain_jit(cfg.pathgain_fn, cfg.antenna, cfg.n_sectors, positions,
                 static.C, d2d, d3d, az, static.bore, fad)
    R = rsrp_jit(G, P)
    if cfg.rayleigh_fading and cfg.attach_ignores_fading:
        # association on the long-term mean (the graph's parallel branch)
        G0 = gain_jit(cfg.pathgain_fn, cfg.antenna, cfg.n_sectors,
                      positions, static.C, d2d, d3d, az, static.bore,
                      unit_fading(cfg, n_ue, n_cell))
        a = attach_jit(rsrp_jit(G0, P))
    else:
        a = attach_jit(R)
    w = wanted_jit(R, a)
    u = interference_jit(R, w)
    gamma = sinr_jit(w, u, cfg.noise_w)
    cqi = cqi_report_jit(gamma, cfg.n_rb_subbands, cfg.cqi_wideband,
                         cfg.eesm_beta)
    mcs = mcs_jit(cqi)
    se = se_jit(mcs, cqi)
    return RadioOutputs(G=G, rsrp=R, a=a, gamma=gamma, cqi=cqi,
                        mcs=mcs, se=se)
