"""In-scan cell fault model: a per-cell Markov outage/sleep process.

Digital twins exist to answer resilience what-ifs -- "which UEs lose
service when site 7 goes dark, and how fast does A3 compensation pick
them up?" (the simulators-to-digital-twins survey; Ericsson's calibrated
simulator names fault scenarios as first-class test inputs).  This module
is the *process*: each cell walks a three-state Markov chain

    UP --outage_rate_hz--> DOWN --1/mean_outage_s--> UP
    UP --sleep_rate_hz--> SLEEP --1/mean_sleep_s--> UP

evaluated once per TTI *inside* the compiled scan (``mac.engine``).  A
DOWN cell transmits nothing (tx power column masked to exactly 0.0, so
its RSRP column is an exact linear zero: no UE attaches to it and an
attached UE's serving SINR collapses, driving A3 reattachment through
the unmodified radio/MAC chain).  A SLEEP cell is a soft degradation:
its tx power is attenuated by ``sleep_atten_db`` (energy-saving milli-
sleep), shrinking but not killing its footprint.

Design rules (the same discipline as ``sim.mobility.ChurnConfig``):

* :class:`FaultConfig` is a hashable NamedTuple of python floats -- a
  trace-time switch.  ``faults=None`` in the engine compiles the exact
  legacy program (the fault-free bitwise pin of tests/test_faults.py).
* The per-TTI transition draw comes from its own PRNG lineage
  (``radio.fault_keys``, tag ``FAULT_KEY_TAG``), never from the four
  legacy ``radio.tti_keys`` streams or the churn lineage -- enabling
  faults cannot perturb mobility/fading/traffic/HARQ/churn randomness,
  and the fold is on the *absolute* TTI index, so chunked serving and
  checkpoint/restore stay bitwise (DESIGN.md
  §Fault-injection-and-self-healing).
* All transition probabilities are trace-time constants; the step is one
  uniform draw + selects -- branch-free, so it composes with ``vmap``,
  ``lax.scan`` and ``shard_map`` (every shard draws the identical
  replicated transition from the replicated key).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

#: cell fault states (i32 codes carried in ``EpisodeState.cell_state``)
UP, SLEEP, DOWN = 0, 1, 2


class FaultConfig(NamedTuple):
    """The per-cell Markov fault process parameters (trace-time switch).

    Rates are per-cell Poisson intensities in events/second; dwell times
    are means of the geometric (per-TTI) holding distribution.  With
    ``tti_s`` the engine's TTI length, the per-TTI transition
    probabilities are ``rate * tti_s`` (entry) and ``tti_s / mean_s``
    (exit) -- keep both well under 1.  The stationary DOWN occupancy of
    one cell is ``r*m / (1 + r*m)`` for ``r = outage_rate_hz``,
    ``m = mean_outage_s``.
    """

    #: UP -> DOWN transition intensity per cell (events/s); 0 = no outages
    outage_rate_hz: float = 0.0
    #: mean DOWN dwell (s) before the cell is repaired back to UP
    mean_outage_s: float = 0.05
    #: UP -> SLEEP transition intensity per cell (events/s); 0 = no sleeps
    sleep_rate_hz: float = 0.0
    #: mean SLEEP dwell (s) before the cell wakes back to UP
    mean_sleep_s: float = 0.05
    #: tx power attenuation while SLEEPing, in dB (soft degradation)
    sleep_atten_db: float = 10.0


def init_cell_state(n_cells: int):
    """The all-UP initial per-cell fault state (i32 codes)."""
    return jnp.zeros((n_cells,), jnp.int32)


def fault_step(key, cell_state, tti_s: float, cfg: FaultConfig):
    """One TTI of every cell's Markov chain: ``(new_state, changed)``.

    One (n_cells,) uniform draw decides all transitions; the thresholds
    are trace-time constants, so the step is a handful of selects --
    branch-free, shape-static, replicated-identical on every shard of a
    mesh (the draw comes from the replicated episode key).  ``changed``
    flags cells whose state moved this TTI -- what the engine's
    incremental path uses as its dirty-cell mask.
    """
    p_down = cfg.outage_rate_hz * tti_s
    p_sleep = cfg.sleep_rate_hz * tti_s
    p_repair = tti_s / cfg.mean_outage_s if cfg.mean_outage_s > 0 else 1.0
    p_wake = tti_s / cfg.mean_sleep_s if cfg.mean_sleep_s > 0 else 1.0
    u = jax.random.uniform(key, cell_state.shape)
    from_up = jnp.where(u < p_down, DOWN,
                        jnp.where(u < p_down + p_sleep, SLEEP, UP))
    from_down = jnp.where(u < p_repair, UP, DOWN)
    from_sleep = jnp.where(u < p_wake, UP, SLEEP)
    new = jnp.where(cell_state == DOWN, from_down,
                    jnp.where(cell_state == SLEEP, from_sleep, from_up))
    new = new.astype(jnp.int32)
    return new, new != cell_state


def tx_multiplier(cell_state, cfg: FaultConfig):
    """Per-cell linear tx-power multiplier for the current fault state.

    UP -> 1.0 (bitwise: ``P * 1.0 == P``), SLEEP -> the linear
    ``sleep_atten_db`` attenuation, DOWN -> exactly 0.0 (a zeroed RSRP
    column: no attachment, no interference -- the cell is dark).
    """
    atten = 10.0 ** (-cfg.sleep_atten_db / 10.0)
    return jnp.where(cell_state == DOWN, 0.0,
                     jnp.where(cell_state == SLEEP, atten, 1.0)
                     ).astype(jnp.float32)
