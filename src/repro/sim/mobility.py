"""UE mobility: random-waypoint-style displacements for a subset of UEs.

The paper's example 13 moves a fraction (10%) of UEs randomly each step; the
smart-update mechanism then only recomputes the dirtied rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def random_moves(key, n_ues: int, n_move: int, extent_m: float):
    """Pick ``n_move`` distinct UEs and new positions for them.

    Returns (idx (n_move,), new_xyz (n_move, 3)).  Positions are fresh uniform
    draws -- teleport mobility by design (the paper's stress test), so there
    is no step-size parameter; use ``random_walk`` for incremental,
    ``step_m``-bounded displacement.
    """
    k1, k2 = jax.random.split(key)
    idx = jax.random.choice(k1, n_ues, (n_move,), replace=False)
    xy = jax.random.uniform(k2, (n_move, 2), minval=0.0, maxval=extent_m)
    z = jnp.full((n_move, 1), 1.5)
    return idx, jnp.concatenate([xy, z], axis=1)


def walk_steps(key, n: int, step_m: float):
    """Draw ``n`` uniform random-walk displacements in [-step_m, step_m)^2.

    Split from :func:`apply_walk` so the episode engine can draw at
    *global* UE count and slice the local shard's rows (its sharded-PRNG
    convention) while sharing this one walk implementation.
    """
    return jax.random.uniform(key, (n, 2), minval=-step_m, maxval=step_m)


def apply_walk(positions, d, extent_m: float):
    """Displace every position by ``d``, clamped at the region borders."""
    new_xy = jnp.clip(positions[:, :2] + d, 0.0, extent_m)
    return jnp.concatenate([new_xy, positions[:, 2:3]], axis=1)


def random_walk(key, positions, idx, step_m: float, extent_m: float):
    """Displace the selected UEs by a uniform step, clamped at borders."""
    d = walk_steps(key, idx.shape[0], step_m)
    return apply_walk(positions[idx], d, extent_m)
