"""UE mobility: random-waypoint-style displacements for a subset of UEs.

The paper's example 13 moves a fraction (10%) of UEs randomly each step; the
smart-update mechanism then only recomputes the dirtied rows.

Also home to the birth-death UE process of the digital-twin serving layer
(DESIGN.md §Digital-twin-serving): :class:`ChurnConfig` is the hashable
trace-time switch and :func:`birth_death_step` the pure per-TTI transition
over a capacity-padded active mask -- UEs arrive (Poisson) into free
capacity slots and depart (exponential lifetimes) inside the compiled scan,
no retracing.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ChurnConfig(NamedTuple):
    """The birth-death process parameters (hashable -- a trace-time switch).

    The UE axis is *capacity-padded*: ``n_ues`` is the slot capacity, the
    live population is the ``active`` mask's popcount.  Stationary mean
    occupancy is ``arrival_rate_hz * mean_lifetime_s`` (M/M/inf), so size
    the capacity comfortably above it -- arrivals beyond free capacity are
    blocked (dropped), which is the standard finite-capacity truncation.
    """

    arrival_rate_hz: float        # Poisson arrival intensity, UEs/second
    mean_lifetime_s: float        # exponential lifetime -> per-TTI departure
    max_arrivals_per_tti: int     # static cap = the birth dirty-row budget
    newborn_backlog_bits: float = 0.0   # seed backlog (inf = full buffer)


def birth_death_step(k_birth, k_death, active, tti_s: float,
                     churn: ChurnConfig):
    """One TTI of the birth-death process over the capacity-padded mask.

    Departures first (each active UE leaves with probability
    ``tti_s / mean_lifetime_s`` -- the exponential lifetime discretised at
    TTI resolution), then arrivals: ``min(Poisson(rate * tti_s),
    max_arrivals_per_tti, free slots)`` newborns occupy the lowest-index
    free slots (slot ids carry no physical meaning -- position and fading
    are freshly drawn per newborn, so any free slot is exchangeable).

    Returns ``(active, born, n_born)``: the updated mask, the newborn
    boolean mask and its popcount.  Pure and shape-static: drops into
    ``lax.scan`` bodies and ``vmap`` batches unchanged.
    """
    n = active.shape[0]
    p_dep = min(1.0, tti_s / churn.mean_lifetime_s)
    depart = jax.random.bernoulli(k_death, p_dep, (n,)) & active
    active = active & ~depart
    lam = churn.arrival_rate_hz * tti_s
    n_arrive = jnp.minimum(
        jax.random.poisson(k_birth, lam, ()),
        churn.max_arrivals_per_tti).astype(jnp.int32)
    free = ~active
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1   # rank among free
    born = free & (free_rank < n_arrive)
    return active | born, born, born.sum().astype(jnp.int32)


def random_moves(key, n_ues: int, n_move: int, extent_m: float):
    """Pick ``n_move`` distinct UEs and new positions for them.

    Returns (idx (n_move,), new_xyz (n_move, 3)).  Positions are fresh uniform
    draws -- teleport mobility by design (the paper's stress test), so there
    is no step-size parameter; use ``random_walk`` for incremental,
    ``step_m``-bounded displacement.
    """
    k1, k2 = jax.random.split(key)
    idx = jax.random.choice(k1, n_ues, (n_move,), replace=False)
    xy = jax.random.uniform(k2, (n_move, 2), minval=0.0, maxval=extent_m)
    z = jnp.full((n_move, 1), 1.5)
    return idx, jnp.concatenate([xy, z], axis=1)


def walk_steps(key, n: int, step_m: float):
    """Draw ``n`` uniform random-walk displacements in [-step_m, step_m)^2.

    Split from :func:`apply_walk` so the episode engine can draw at
    *global* UE count and slice the local shard's rows (its sharded-PRNG
    convention) while sharing this one walk implementation.
    """
    return jax.random.uniform(key, (n, 2), minval=-step_m, maxval=step_m)


def apply_walk(positions, d, extent_m: float):
    """Displace every position by ``d``, clamped at the region borders."""
    new_xy = jnp.clip(positions[:, :2] + d, 0.0, extent_m)
    return jnp.concatenate([new_xy, positions[:, 2:3]], axis=1)


def window_movers(key, n: int, n_move: int, step_m: float):
    """Exact-count mover selection: a random-offset circular index window.

    The digital-twin mobility regime (``mobility_move_frac``): exactly
    ``n_move`` of the ``n`` UEs take a walk step this TTI.  Movers are the
    circular window ``[start, start + n_move) mod n`` at a uniformly random
    ``start`` -- UE indices carry no spatial meaning (positions are i.i.d.
    draws), so a random index window IS a uniform random subset spatially,
    selected in O(n_move) with *no* permutation sort, and each UE's
    marginal move probability per TTI is ``n_move / n``.  The exact static
    count is what gives the incremental radio path its dirty-row budget.
    Returns ``(start, d)`` with ``d`` the (n_move, 2) displacement draws
    (global shapes -- the engine's global-draw-then-slice convention).
    """
    k_off, k_step = jax.random.split(key)
    start = jax.random.randint(k_off, (), 0, n)
    return start, walk_steps(k_step, n_move, step_m)


def window_displacements(start, d, rows, n: int):
    """Per-row displacement + mover mask for the window-mover convention.

    ``rows`` are global UE indices (a shard passes its own block); row r
    is a mover iff ``(r - start) mod n < n_move`` and then takes draw
    ``d[(r - start) mod n]`` -- so every shard reconstructs exactly the
    rows it owns from the same global draw, and a dense all-rows caller
    gets a zero displacement for non-movers (branch-free).
    """
    n_move = d.shape[0]
    j = (rows - start) % n
    moved = j < n_move
    dj = d[jnp.clip(j, 0, n_move - 1)]
    return jnp.where(moved[:, None], dj, 0.0), moved


def random_walk(key, positions, idx, step_m: float, extent_m: float):
    """Displace the selected UEs by a uniform step, clamped at borders."""
    d = walk_steps(key, idx.shape[0], step_m)
    return apply_walk(positions[idx], d, extent_m)
