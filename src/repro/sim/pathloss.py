"""3GPP TR 38.901 pathloss models (RMa, UMa, UMi, InH) + power-law.

Faithful to CRRM's pluggable physics engine: every model is a class with a
``get_pathloss_dB(d2d, d3d, h_bs, h_ut)`` and ``get_pathgain(...)`` interface
(strategy pattern).  All math is vectorised jnp so a model can be applied to a
full (n_ue, n_cell) distance matrix, a dirty-row slice, or inside shard_map.

Three RMa variants reproduce the paper's engineering-trade-off case study:

* ``RMa_pathloss``                 -- full dynamic calculation, any heights.
* ``RMa_pathloss_constant_height`` -- heights frozen at construction; the
  height-dependent coefficients become Python floats baked into the jitted
  computation.
* ``RMa_pathloss_discretised``     -- (A, B, d_bp, pl1_bp) coefficient lookup
  table over discretised UE heights; paper reports 0.16 dB NLOS RMSE.

Formulas: 3GPP TR 38.901 Table 7.4.1-1 (Release 19 numbering as cited by the
paper).  Gains are linear power gains, 0 <= G < 1.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

C_LIGHT = 299_792_458.0  # m/s


def db_to_gain(pl_db):
    """Linear power gain from a pathloss in dB (positive pl_db = loss)."""
    return jnp.power(10.0, -0.1 * pl_db)


def _log10(x):
    return jnp.log10(jnp.maximum(x, 1e-9))


@dataclasses.dataclass(frozen=True)
class PathlossBase:
    """Common interface.  fc_GHz is carrier frequency in GHz."""

    fc_GHz: float = 3.5
    LOS: bool = False  # True -> line-of-sight formulas

    # -- public API (the pluggable ``pathgain_function`` of the paper) -------
    def get_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        raise NotImplementedError

    def get_pathgain(self, d2d, d3d, h_bs, h_ut):
        return db_to_gain(self.get_pathloss_dB(d2d, d3d, h_bs, h_ut))

    def __call__(self, d2d, d3d, h_bs, h_ut):
        return self.get_pathgain(d2d, d3d, h_bs, h_ut)


# ---------------------------------------------------------------------------
# RMa -- Rural Macrocell
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RMa_pathloss(PathlossBase):
    """TR 38.901 RMa.  Defaults: h_BS=35 m, h_UT=1.5 m, W=20 m, h=5 m."""

    W: float = 20.0  # average street width, m
    h: float = 5.0   # average building height, m

    def _d_bp(self, h_bs, h_ut):
        fc_hz = self.fc_GHz * 1e9
        return 2.0 * jnp.pi * h_bs * h_ut * fc_hz / C_LIGHT

    def _pl1(self, d3d):
        # PL1, valid 10 m <= d2D <= d_BP
        h = self.h
        fc = self.fc_GHz
        a = jnp.minimum(0.03 * h ** 1.72, 10.0)
        b = jnp.minimum(0.044 * h ** 1.72, 14.77)
        return (20.0 * _log10(40.0 * jnp.pi * d3d * fc / 3.0)
                + a * _log10(d3d) - b + 0.002 * _log10(h) * d3d)

    def los_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        d_bp = self._d_bp(h_bs, h_ut)
        pl1 = self._pl1(d3d)
        pl2 = self._pl1(d_bp) + 40.0 * _log10(d3d / jnp.maximum(d_bp, 1.0))
        return jnp.where(d2d <= d_bp, pl1, pl2)

    def nlos_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        W, h, fc = self.W, self.h, self.fc_GHz
        pl_nlos = (161.04 - 7.1 * _log10(W) + 7.5 * _log10(h)
                   - (24.37 - 3.7 * (h / h_bs) ** 2) * _log10(h_bs)
                   + (43.42 - 3.1 * _log10(h_bs)) * (_log10(d3d) - 3.0)
                   + 20.0 * _log10(fc)
                   - (3.2 * _log10(11.75 * h_ut) ** 2 - 4.97))
        return jnp.maximum(self.los_pathloss_dB(d2d, d3d, h_bs, h_ut), pl_nlos)

    def get_pathloss_dB(self, d2d, d3d, h_bs=35.0, h_ut=1.5):
        if self.LOS:
            return self.los_pathloss_dB(d2d, d3d, h_bs, h_ut)
        return self.nlos_pathloss_dB(d2d, d3d, h_bs, h_ut)


@dataclasses.dataclass(frozen=True)
class RMa_pathloss_constant_height(RMa_pathloss):
    """RMa with heights fixed at construction time.

    The height-dependent coefficients fold into Python constants, so the
    jitted expression has fewer transcendental ops per element.
    """

    h_bs: float = 35.0
    h_ut: float = 1.5

    def get_pathloss_dB(self, d2d, d3d, h_bs=None, h_ut=None):
        # heights are baked in; arguments accepted (and ignored) for interface
        # compatibility with the dynamic model.
        return super().get_pathloss_dB(d2d, d3d, self.h_bs, self.h_ut)


class RMa_pathloss_discretised:
    """RMa via a pre-computed coefficient LUT over discrete UE heights.

    NLOS RMa pathloss is affine in log10(d3d) once heights are fixed:
        PL = A(h_bs, h_ut) + B(h_bs) * log10(d3d)      (NLOS branch)
    and the LOS branch is piecewise with the breakpoint.  We tabulate
    (A, B) plus the LOS pieces per discretised h_ut bin and pick the nearest
    bin at query time.  With 0.25 m bins the RMSE vs the full model is well
    inside the paper's reported 0.16 dB.
    """

    def __init__(self, fc_GHz=3.5, LOS=False, W=20.0, h=5.0, h_bs=35.0,
                 h_ut_min=1.0, h_ut_max=2.5, h_ut_step=0.25):
        self.fc_GHz, self.LOS = fc_GHz, LOS
        self.h_bs = h_bs
        self.full = RMa_pathloss(fc_GHz=fc_GHz, LOS=LOS, W=W, h=h)
        self.h_ut_min = h_ut_min
        self.h_ut_step = h_ut_step
        hs = jnp.arange(h_ut_min, h_ut_max + 1e-9, h_ut_step)
        self.h_grid = hs
        # NLOS affine coefficients per height bin: PL_nlos = A + B*log10(d3d)
        B = 43.42 - 3.1 * _log10(jnp.asarray(h_bs))
        A = (161.04 - 7.1 * _log10(jnp.asarray(W)) + 7.5 * _log10(jnp.asarray(h))
             - (24.37 - 3.7 * (h / h_bs) ** 2) * _log10(jnp.asarray(h_bs))
             - 3.0 * B
             + 20.0 * _log10(jnp.asarray(fc_GHz))
             - (3.2 * _log10(11.75 * hs) ** 2 - 4.97))
        self.A_lut = A                      # (H,)
        self.B = B                          # scalar
        self.d_bp_lut = self.full._d_bp(h_bs, hs)            # (H,)
        self.pl1_at_bp_lut = self.full._pl1(self.d_bp_lut)   # (H,)

    def _bin(self, h_ut):
        idx = jnp.round((h_ut - self.h_ut_min) / self.h_ut_step).astype(jnp.int32)
        return jnp.clip(idx, 0, self.h_grid.shape[0] - 1)

    def get_pathloss_dB(self, d2d, d3d, h_bs=None, h_ut=1.5):
        h_ut = jnp.asarray(h_ut)
        k = self._bin(h_ut)
        d_bp = self.d_bp_lut[k]
        pl1 = self.full._pl1(d3d)
        pl2 = self.pl1_at_bp_lut[k] + 40.0 * _log10(d3d / jnp.maximum(d_bp, 1.0))
        pl_los = jnp.where(d2d <= d_bp, pl1, pl2)
        if self.LOS:
            return pl_los
        pl_nlos = self.A_lut[k] + self.B * _log10(d3d)
        return jnp.maximum(pl_los, pl_nlos)

    def get_pathgain(self, d2d, d3d, h_bs=None, h_ut=1.5):
        return db_to_gain(self.get_pathloss_dB(d2d, d3d, h_bs, h_ut))

    def __call__(self, d2d, d3d, h_bs=None, h_ut=1.5):
        return self.get_pathgain(d2d, d3d, h_bs, h_ut)


# ---------------------------------------------------------------------------
# UMa -- Urban Macrocell (h_BS = 25 m)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class UMa_pathloss(PathlossBase):
    def _d_bp_eff(self, h_bs, h_ut):
        # effective environment height h_E = 1 m (h_UT < 13 m case)
        h_e = 1.0
        fc_hz = self.fc_GHz * 1e9
        return 4.0 * (h_bs - h_e) * (h_ut - h_e) * fc_hz / C_LIGHT

    def los_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        fc = self.fc_GHz
        d_bp = self._d_bp_eff(h_bs, h_ut)
        pl1 = 28.0 + 22.0 * _log10(d3d) + 20.0 * _log10(fc)
        pl2 = (28.0 + 40.0 * _log10(d3d) + 20.0 * _log10(fc)
               - 9.0 * _log10(d_bp ** 2 + (h_bs - h_ut) ** 2))
        return jnp.where(d2d <= d_bp, pl1, pl2)

    def nlos_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        fc = self.fc_GHz
        pl_nlos = (13.54 + 39.08 * _log10(d3d) + 20.0 * _log10(fc)
                   - 0.6 * (h_ut - 1.5))
        return jnp.maximum(self.los_pathloss_dB(d2d, d3d, h_bs, h_ut), pl_nlos)

    def get_pathloss_dB(self, d2d, d3d, h_bs=25.0, h_ut=1.5):
        if self.LOS:
            return self.los_pathloss_dB(d2d, d3d, h_bs, h_ut)
        return self.nlos_pathloss_dB(d2d, d3d, h_bs, h_ut)


# ---------------------------------------------------------------------------
# UMi -- Urban Microcell, street canyon (h_BS = 10 m)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class UMi_pathloss(PathlossBase):
    def _d_bp_eff(self, h_bs, h_ut):
        h_e = 1.0
        fc_hz = self.fc_GHz * 1e9
        return 4.0 * (h_bs - h_e) * (h_ut - h_e) * fc_hz / C_LIGHT

    def los_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        fc = self.fc_GHz
        d_bp = self._d_bp_eff(h_bs, h_ut)
        pl1 = 32.4 + 21.0 * _log10(d3d) + 20.0 * _log10(fc)
        pl2 = (32.4 + 40.0 * _log10(d3d) + 20.0 * _log10(fc)
               - 9.5 * _log10(d_bp ** 2 + (h_bs - h_ut) ** 2))
        return jnp.where(d2d <= d_bp, pl1, pl2)

    def nlos_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        fc = self.fc_GHz
        pl_nlos = (35.3 * _log10(d3d) + 22.4 + 21.3 * _log10(fc)
                   - 0.3 * (h_ut - 1.5))
        return jnp.maximum(self.los_pathloss_dB(d2d, d3d, h_bs, h_ut), pl_nlos)

    def get_pathloss_dB(self, d2d, d3d, h_bs=10.0, h_ut=1.5):
        if self.LOS:
            return self.los_pathloss_dB(d2d, d3d, h_bs, h_ut)
        return self.nlos_pathloss_dB(d2d, d3d, h_bs, h_ut)


# ---------------------------------------------------------------------------
# InH -- Indoor Hotspot (office)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InH_pathloss(PathlossBase):
    def los_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        return 32.4 + 17.3 * _log10(d3d) + 20.0 * _log10(self.fc_GHz)

    def nlos_pathloss_dB(self, d2d, d3d, h_bs, h_ut):
        pl_nlos = 38.3 * _log10(d3d) + 17.30 + 24.9 * _log10(self.fc_GHz)
        return jnp.maximum(self.los_pathloss_dB(d2d, d3d, h_bs, h_ut), pl_nlos)

    def get_pathloss_dB(self, d2d, d3d, h_bs=3.0, h_ut=1.0):
        if self.LOS:
            return self.los_pathloss_dB(d2d, d3d, h_bs, h_ut)
        return self.nlos_pathloss_dB(d2d, d3d, h_bs, h_ut)


# ---------------------------------------------------------------------------
# Power-law -- g(d) = (d/d0)^(-alpha), used by the PPP validation (example 12)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PowerLaw_pathloss(PathlossBase):
    alpha: float = 3.5
    d0: float = 1.0  # reference distance, m

    def get_pathloss_dB(self, d2d, d3d, h_bs=None, h_ut=None):
        return 10.0 * self.alpha * _log10(d3d / self.d0)

    def get_pathgain(self, d2d, d3d, h_bs=None, h_ut=None):
        # exact power law, avoids the dB round-trip
        return jnp.power(jnp.maximum(d3d / self.d0, 1e-9), -self.alpha)


PATHLOSS_MODELS = {
    "RMa": RMa_pathloss,
    "RMa_constant_height": RMa_pathloss_constant_height,
    "RMa_discretised": RMa_pathloss_discretised,
    "UMa": UMa_pathloss,
    "UMi": UMi_pathloss,
    "InH": InH_pathloss,
    "power_law": PowerLaw_pathloss,
}


def make_pathloss(name: str, **kwargs):
    """Strategy-pattern factory: the paper's CRRM_parameters takes the model
    name as a string and the simulator binds ``get_pathgain`` to a generic
    ``pathgain_function`` callable."""
    try:
        cls = PATHLOSS_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown pathloss model {name!r}; have {sorted(PATHLOSS_MODELS)}")
    return cls(**kwargs)
