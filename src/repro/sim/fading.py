"""Fast fading models.

Rayleigh fading on the *power* gain: |h|^2 ~ Exp(1), mean 1, which is what the
PPP analytic SIR distribution (Haenggi) assumes.

Two frequency regimes:

* wideband -- one draw per (UE, cell) link (:func:`rayleigh_power`), the
  flat-fading assumption of the original CRRM chain;
* frequency-selective -- one draw per *coherence block* of consecutive
  resource blocks (:func:`block_rayleigh_power`), the block-fading
  approximation of a tapped-delay-line channel: RBs closer than the
  coherence bandwidth see the same fade, RBs further apart fade
  independently.  :func:`pool_rb_subbands` reduces the per-RB tensor to the
  link-adaptation resolution (mean power per reported subband), which is
  what per-RB CQI feedback quantises in a real gNB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rayleigh_power(key, shape, dtype=jnp.float32):
    """IID exponential(1) power fading coefficients."""
    return jax.random.exponential(key, shape, dtype=dtype)


def apply_rayleigh(key, gain):
    """Multiply a linear power-gain array by fresh Rayleigh fading."""
    return gain * rayleigh_power(key, gain.shape, gain.dtype)


def block_rayleigh_power(key, n_ues, n_cells, n_rb, coherence_rb,
                         dtype=jnp.float32):
    """Frequency-selective block fading: (n_ues, n_cells, n_rb) Exp(1) power.

    The ``n_rb`` resource blocks are partitioned into coherence blocks of
    ``coherence_rb`` consecutive RBs; every RB inside one block shares a
    single Rayleigh draw, blocks are independent.  ``coherence_rb=1`` is
    fully selective (IID per RB); ``coherence_rb >= n_rb`` degenerates to
    wideband flat fading.  All sizes are static, so the function traces
    inside ``jax.lax.scan``.
    """
    n_blocks = -(-n_rb // coherence_rb)          # ceil division
    draw = jax.random.exponential(key, (n_ues, n_cells, n_blocks),
                                  dtype=dtype)
    return jnp.repeat(draw, coherence_rb, axis=2)[:, :, :n_rb]


def pool_rb_subbands(fad_rb, n_rb_subbands):
    """Pool a per-RB tensor (..., n_rb) to (..., n_rb_subbands).

    Mean *power* over each reported subband's RBs -- the effective-channel
    abstraction behind subband CQI feedback.  ``n_rb_subbands`` must divide
    the trailing RB axis.
    """
    n_rb = fad_rb.shape[-1]
    if n_rb % n_rb_subbands:
        raise ValueError(
            f"n_rb_subbands={n_rb_subbands} must divide n_rb={n_rb}")
    shape = fad_rb.shape[:-1] + (n_rb_subbands, n_rb // n_rb_subbands)
    return fad_rb.reshape(shape).mean(axis=-1)


def subband_rayleigh_power(key, n_ues, n_cells, n_rb, coherence_rb,
                           n_rb_subbands, dtype=jnp.float32):
    """Block fading drawn per RB, reported at link-adaptation resolution.

    Returns (n_ues, n_cells, n_rb_subbands): the per-RB coherence-block
    tensor of :func:`block_rayleigh_power` pooled to the CQI subband grid.
    """
    fad = block_rayleigh_power(key, n_ues, n_cells, n_rb, coherence_rb,
                               dtype)
    return pool_rb_subbands(fad, n_rb_subbands)
