"""Fast fading models.

Rayleigh fading on the *power* gain: |h|^2 ~ Exp(1), mean 1, which is what the
PPP analytic SIR distribution (Haenggi) assumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rayleigh_power(key, shape, dtype=jnp.float32):
    """IID exponential(1) power fading coefficients."""
    return jax.random.exponential(key, shape, dtype=dtype)


def apply_rayleigh(key, gain):
    """Multiply a linear power-gain array by fresh Rayleigh fading."""
    return gain * rayleigh_power(key, gain.shape, gain.dtype)
