"""Flash attention with a custom VJP (memory-exact backward).

JAX autodiff through the online-softmax scan saves every block's probability
matrix for the backward -- reintroducing the O(S^2) memory that chunking was
supposed to remove (observed directly in the deepseek-67b dry-run: stacked
f32[q_blocks, ..., cq, ckv] buffers dominated HBM).  This module implements
the standard flash-attention gradient: save only (q, k, v, out, lse), and
recompute score blocks inside the backward loops.

All tensors are (b, s, h, hd) with kv heads already repeated to h and head
sharding applied by the caller.  Layout inside: (b, h, s, hd).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _blocks(x, n, c):
    # (b, h, s, hd) -> (n, b, h, c, hd)
    b, h, s, hd = x.shape
    return jnp.moveaxis(x.reshape(b, h, n, c, hd), 2, 0)


def _mask_for(qpos, kpos, kv_valid, causal):
    m = kv_valid[None, :]
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    return m[None, None]                      # (1, 1, cq, ckv)


def _fwd_impl(q, k, v, causal, cq, ckv, q_offset, skv_valid):
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    nq, nkv = sq // cq, skv // ckv
    scale = hd ** -0.5
    qb = _blocks(q, nq, cq)
    kb = _blocks(k, nkv, ckv)
    vb = _blocks(v, nkv, ckv)
    q_pos = (q_offset + jnp.arange(nq * cq)).reshape(nq, cq)
    kv_pos = jnp.arange(nkv * ckv).reshape(nkv, ckv)
    kv_ok = (jnp.arange(nkv * ckv) < skv_valid).reshape(nkv, ckv)

    def per_q(qi, qpos):
        def body(carry, xs):
            m, l, acc = carry
            kj, vj, kpos, ok = xs
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask_for(qpos, kpos, ok, causal), s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (kb, vb, kv_pos, kv_ok))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return out, lse

    outs, lses = jax.lax.map(lambda xs: per_q(*xs), (qb, q_pos))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, sq, hd)
    lse = jnp.moveaxis(lses, 0, 2).reshape(b, h, sq)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, cq, ckv, q_offset, skv_valid):
    out, _ = _fwd_impl(q, k, v, causal, cq, ckv, q_offset, skv_valid)
    return out


def _flash_fwd(q, k, v, causal, cq, ckv, q_offset, skv_valid):
    out, lse = _fwd_impl(q, k, v, causal, cq, ckv, q_offset, skv_valid)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, cq, ckv, q_offset, skv_valid, res, dout):
    q, k, v, out, lse = res
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    nq, nkv = sq // cq, skv // ckv
    scale = hd ** -0.5
    qb = _blocks(q, nq, cq)
    dob = _blocks(dout.astype(jnp.float32), nq, cq)
    lseb = jnp.moveaxis(lse.reshape(b, h, nq, cq), 2, 0)
    delta = jnp.einsum("bhqd,bhqd->bhq", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    deltab = jnp.moveaxis(delta.reshape(b, h, nq, cq), 2, 0)
    kb = _blocks(k, nkv, ckv)
    vb = _blocks(v, nkv, ckv)
    q_pos = (q_offset + jnp.arange(nq * cq)).reshape(nq, cq)
    kv_pos = jnp.arange(nkv * ckv).reshape(nkv, ckv)
    kv_ok = (jnp.arange(nkv * ckv) < skv_valid).reshape(nkv, ckv)

    def per_q(carry, xs):
        dk_acc, dv_acc = carry                  # (b, h, skv, hd) f32
        qi, doi, lsei, di, qpos = xs

        def inner(c2, xs2):
            dq_acc, dk_acc, dv_acc, j = c2
            kj, vj, kpos, ok = xs2
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask_for(qpos, kpos, ok, causal), s, NEG_INF)
            p = jnp.exp(s - lsei[..., None])    # (b, h, cq, ckv)
            dv_blk = jnp.einsum("bhqk,bhqd->bhkd", p, doi)
            dp = jnp.einsum("bhqd,bhkd->bhqk", doi, vj.astype(jnp.float32))
            ds = p * (dp - di[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhqk,bhkd->bhqd", ds,
                                         kj.astype(jnp.float32))
            dk_blk = jnp.einsum("bhqk,bhqd->bhkd", ds, qi.astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, j * ckv, ckv, 2)
                + dk_blk, j * ckv, axis=2)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, j * ckv, ckv, 2)
                + dv_blk, j * ckv, axis=2)
            return (dq_acc, dk_acc, dv_acc, j + 1), None

        dq0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (dqi, dk_acc, dv_acc, _), _ = jax.lax.scan(
            inner, (dq0, dk_acc, dv_acc, jnp.int32(0)),
            (kb, vb, kv_pos, kv_ok))
        return (dk_acc, dv_acc), dqi

    dk0 = jnp.zeros((b, h, skv, hd), jnp.float32)
    dv0 = jnp.zeros((b, h, skv, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(per_q, (dk0, dv0),
                                 (qb, dob, lseb, deltab, q_pos))
    dq = jnp.moveaxis(dqs, 0, 2).reshape(b, h, sq, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool, chunk_q: int, chunk_kv: int,
                    q_offset: int = 0):
    """Public API, (b, s, h, hd) layout, kv heads may be < h (repeated
    here).  Pads s to chunk multiples; invalid kv masked out."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    if k.shape[2] != h:
        rep = h // k.shape[2]
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    from repro.parallel.act_sharding import constrain_heads
    q = constrain_heads(q)
    k = constrain_heads(k)
    v = constrain_heads(v)
    cq = min(chunk_q, sq)
    ckv = min(chunk_kv, skv)
    nq, nkv = -(-sq // cq), -(-skv // ckv)
    pq, pkv = nq * cq - sq, nkv * ckv - skv
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    out = _flash(qt, kt, vt, causal, cq, ckv, q_offset, skv)
    out = jnp.moveaxis(out, 1, 2)[:, :sq]
    return out
