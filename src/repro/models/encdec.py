"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend is a stub per the assignment: ``batch["src_embeds"]``
carries precomputed speech-frame embeddings (b, s_src, d_model).  The text
decoder is a standard causal transformer with cross-attention; its KV caches
split into self-attention caches (grow during decode) and cross-attention
K/V (computed once from the encoder output -- the CRRM analogy: the encoder
is an up-to-date upstream node that decode steps never dirty).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.models.config import ModelConfig
from repro.models.transformer import _cdt, _pdt, scan_layers_remat
from repro.parallel.act_sharding import constrain, gather_layer_params


def _enc_layer_init(key, cfg, pdt):
    ks = jax.random.split(key, 2)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model, pdt),
        "attn": attention.attention_init(ks[0], cfg, pdt),
        "ln2": layers.rmsnorm_init(cfg.d_model, pdt),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, pdt),
    }


def _dec_layer_init(key, cfg, pdt):
    ks = jax.random.split(key, 3)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model, pdt),
        "self_attn": attention.attention_init(ks[0], cfg, pdt),
        "ln_x": layers.rmsnorm_init(cfg.d_model, pdt),
        "cross_attn": attention.attention_init(ks[1], cfg, pdt),
        "ln2": layers.rmsnorm_init(cfg.d_model, pdt),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, pdt),
    }


def init_params(key, cfg: ModelConfig):
    pdt = _pdt(cfg)
    k_enc, k_dec, k_emb, k_head = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg, pdt))(enc_keys),
        "enc_norm": layers.rmsnorm_init(cfg.d_model, pdt),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg, pdt))(dec_keys),
        "final_norm": layers.rmsnorm_init(cfg.d_model, pdt),
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model, pdt),
        "lm_head": layers.lm_head_init(k_head, cfg.d_model, cfg.vocab_size,
                                       pdt),
    }


def encode(params, src_embeds, cfg: ModelConfig):
    """Bidirectional encoder over stub frame embeddings."""
    cdt = _cdt(cfg)
    x = src_embeds.astype(cdt)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    def body(h, lp):
        h = constrain(h)
        lp = gather_layer_params(lp)
        z = layers.rmsnorm(lp["ln1"], h, cfg.norm_eps)
        q, k, v = attention.qkv_project(lp["attn"], z, z, cfg, cdt)
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        ctx = attention.chunked_attention(
            q, k, v, causal=False, chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv)
        h = h + attention.attn_output(lp["attn"], ctx.astype(cdt), cdt)
        z = layers.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        return h + layers.mlp(lp["mlp"], z, cdt)

    x = scan_layers_remat(body, x, params["encoder"], cfg)
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(lp, h, enc_out, cfg, cdt, positions, *, self_cache=None,
               cross_kv=None, pos=None):
    # self attention (causal)
    z = layers.rmsnorm(lp["ln1"], h, cfg.norm_eps)
    q, k, v = attention.qkv_project(lp["self_attn"], z, z, cfg, cdt)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if self_cache is None:
        ctx = attention.chunked_attention(
            q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv)
    else:
        kc, vc = self_cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
        if q.shape[1] == 1:
            ctx = attention.decode_attention(q, kc, vc, pos + 1)
        else:
            ctx = attention.chunked_attention(
                q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
                chunk_kv=cfg.attn_chunk_kv)
        new_cache = (kc, vc)
    h = h + attention.attn_output(lp["self_attn"], ctx.astype(cdt), cdt)

    # cross attention (not causal, encoder length fixed)
    z = layers.rmsnorm(lp["ln_x"], h, cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", z,
                    lp["cross_attn"]["wq"].astype(cdt))
    if cross_kv is None:
        kx = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["cross_attn"]["wk"].astype(cdt))
        vx = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["cross_attn"]["wv"].astype(cdt))
    else:
        kx, vx = cross_kv
    ctx = attention.chunked_attention(
        qx, kx, vx, causal=False, chunk_q=cfg.attn_chunk_q,
        chunk_kv=cfg.attn_chunk_kv)
    h = h + attention.attn_output(lp["cross_attn"], ctx.astype(cdt), cdt)

    z = layers.rmsnorm(lp["ln2"], h, cfg.norm_eps)
    h = h + layers.mlp(lp["mlp"], z, cdt)
    return h, new_cache


def forward_features(params, batch, cfg: ModelConfig):
    cdt = _cdt(cfg)
    enc_out = encode(params, batch["src_embeds"], cfg)
    x = layers.embed(params["embed"], batch["tokens"], cdt)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    def body(h, lp):
        h = constrain(h)
        lp = gather_layer_params(lp)
        h, _ = _dec_block(lp, h, enc_out, cfg, cdt, positions)
        return h

    x = scan_layers_remat(body, x, params["decoder"], cfg)
    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def head(params, x, cfg: ModelConfig):
    return layers.lm_head(params["lm_head"], x)


def forward(params, batch, cfg: ModelConfig):
    """Training: batch = {src_embeds (b, ss, d), tokens (b, st)} -> logits."""
    return head(params, forward_features(params, batch, cfg), cfg)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, enc_len: int):
    cdt = _cdt(cfg)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch_size, max_len, kvh, hd), cdt),
        "v": jnp.zeros((L, batch_size, max_len, kvh, hd), cdt),
        "xk": jnp.zeros((L, batch_size, enc_len, kvh, hd), cdt),
        "xv": jnp.zeros((L, batch_size, enc_len, kvh, hd), cdt),
    }


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Encode + decoder prompt pass.  Returns (last logits, caches)."""
    cdt = _cdt(cfg)
    enc_out = encode(params, batch["src_embeds"], cfg)
    x = layers.embed(params["embed"], batch["tokens"], cdt)
    b, st = x.shape[0], x.shape[1]
    caches = init_cache(cfg, b, max_len, enc_out.shape[1])

    def body(h, xs):
        lp, kc, vc = xs
        h = constrain(h)
        kx = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["cross_attn"]["wk"].astype(cdt))
        vx = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["cross_attn"]["wv"].astype(cdt))
        positions = jnp.broadcast_to(jnp.arange(st)[None], (b, st))
        h, (kc, vc) = _dec_block(lp, h, enc_out, cfg, cdt, positions,
                                 self_cache=(kc, vc), cross_kv=(kx, vx),
                                 pos=0)
        return h, (kc, vc, kx.astype(cdt), vx.astype(cdt))

    x, (kn, vn, xk, xv) = jax.lax.scan(
        body, x, (params["decoder"], caches["k"], caches["v"]))
    caches = {"k": kn, "v": vn, "xk": xk, "xv": xv}
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.lm_head(params["lm_head"], x[:, -1:]), caches


def decode_step(params, batch, caches, pos, cfg: ModelConfig):
    cdt = _cdt(cfg)
    x = layers.embed(params["embed"], batch["tokens"], cdt)
    positions = jnp.broadcast_to(
        jnp.asarray(pos)[None, None], x.shape[:2]).astype(jnp.int32)

    def body(h, xs):
        lp, kc, vc, kx, vx = xs
        h, (kc, vc) = _dec_block(lp, h, None, cfg, cdt, positions,
                                 self_cache=(kc, vc), cross_kv=(kx, vx),
                                 pos=pos)
        return h, (kc, vc)

    x, (kn, vn) = jax.lax.scan(
        body, x, (params["decoder"], caches["k"], caches["v"],
                  caches["xk"], caches["xv"]))
    caches = dict(caches, k=kn, v=vn)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return layers.lm_head(params["lm_head"], x), caches
