"""Mixture-of-Experts: top-k routing with capacity-bounded scatter dispatch.

Design (MaxText/Switch-style, adapted for pure pjit):

* router logits -> top-k experts per token, probs renormalised over the k;
* position_in_expert via a cumulative sum per (batch-row, expert) with a
  capacity bound C = ceil(S * k / E * capacity_factor): overflow tokens drop
  (their combine weight is zero) -- standard capacity dropping, recorded;
* dispatch: scatter tokens into an (b, E, C, d) buffer.  Under the sharding
  rules b maps to the data axes and E to `model`, so the scatter IS the
  all-to-all of classic expert parallelism -- GSPMD inserts it;
* expert compute: one einsum over stacked expert weights (E, d, ff);
* combine: gather back with the routing probs as weights.

Shared experts (DeepSeekMoE) are a plain dense SwiGLU over all tokens, added
to the routed output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.act_sharding import (constrain_ec, constrain_expert,
                                          constrain_tokens)


def moe_init(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (d, e), d, jnp.float32),
        "wi_gate": layers.dense_init(ks[1], (e, d, f), d, dtype),
        "wi_up": layers.dense_init(ks[2], (e, d, f), d, dtype),
        "wo": layers.dense_init(ks[3], (e, f, d), f, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.mlp_init(
            ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def expert_capacity(cfg, seq_len: int) -> int:
    c = int(seq_len * cfg.n_experts_per_token * cfg.moe_capacity_factor
            / cfg.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_layer(params, x, cfg, compute_dtype):
    """x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    cap = expert_capacity(cfg, s)

    logits = x.astype(jnp.float32) @ params["router"]          # (b, s, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (b, s, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position_in_expert: sequential cumsum over the k choices then tokens
    # one-hot per choice: (b, s, k, E)
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)
    # tokens fill expert slots in (choice-major, token-minor) order
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (b, s*k, E)
    pos = (pos * flat).sum(-1).reshape(b, s, k)                 # slot per choice
    expert = top_e                                              # (b, s, k)
    keep = (pos < cap) & (top_p > 0.0)
    pos_c = jnp.minimum(pos, cap - 1)

    # dispatch -- gather formulation.  A scatter-add of (b, s, k, d) token
    # vectors onto the model-sharded (b, E, C, d) buffer makes GSPMD
    # replicate + all-reduce the whole buffer (measured 105 GB/device/layer
    # on deepseek-moe -- see EXPERIMENTS §Perf).  Instead we scatter only
    # int32 *indices* (tiny), gather tokens data-locally, and cross the
    # data->expert axis with one explicit resharding (the all-to-all).
    slot = expert * cap + pos_c                                  # (b, s, k)
    slot = jnp.where(keep, slot, e * cap)                        # drop bucket
    # which flat token (s * k) fills each expert slot
    src_of_slot = jnp.full((b, e * cap + 1), s * k, jnp.int32)
    flat_tok = jnp.broadcast_to(
        jnp.arange(s * k, dtype=jnp.int32).reshape(1, s, k), (b, s, k))
    src_of_slot = src_of_slot.at[
        jnp.arange(b)[:, None, None], slot].set(flat_tok)
    src_of_slot = src_of_slot[:, :e * cap]                       # (b, E*C)

    x_flat = jnp.repeat(x.astype(compute_dtype), k, axis=1)      # (b, s*k, d)
    x_flat = jnp.concatenate(
        [x_flat, jnp.zeros((b, 1, d), compute_dtype)], axis=1)   # pad row
    xe = jnp.take_along_axis(x_flat, src_of_slot[..., None], axis=1)
    xe = constrain_ec(xe)                                        # a2a here
    xe = xe.reshape(b, e, cap, d)

    # expert FFN (SwiGLU) over stacked weights
    h = jax.nn.silu(jnp.einsum(
        "becd,edf->becf", xe, params["wi_gate"].astype(compute_dtype)))
    h = h * jnp.einsum(
        "becd,edf->becf", xe, params["wi_up"].astype(compute_dtype))
    ye = jnp.einsum(
        "becf,efd->becd", h, params["wo"].astype(compute_dtype))

    # combine: reshard back (a2a), then gather each token's k outputs
    ye = constrain_tokens(ye.reshape(b, e * cap, d))
    ye = jnp.concatenate(
        [ye, jnp.zeros((b, 1, d), compute_dtype)], axis=1)
    slot_flat = slot.reshape(b, s * k)
    yk = jnp.take_along_axis(ye, slot_flat[..., None], axis=1)
    yk = yk.reshape(b, s, k, d)
    wk = jnp.where(keep, top_p, 0.0).astype(compute_dtype)
    y = (yk * wk[..., None]).sum(axis=2)

    if "shared" in params:
        y = y + layers.mlp(params["shared"], x, compute_dtype)
    return y


def load_balancing_loss(router_logits, top_e, n_experts):
    """Switch-style aux loss: mean_frac_tokens * mean_router_prob per expert."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    density = jax.nn.one_hot(top_e[..., 0], n_experts).mean(axis=(0, 1))
    router_mean = probs.mean(axis=(0, 1))
    return n_experts * jnp.sum(density * router_mean)
