"""Attention: GQA with flash-style chunked softmax, KV caches, M-RoPE.

The chunked implementation (``chunked_attention``) is the default for
training and prefill: queries are processed in blocks with an online-softmax
accumulator scanned over KV blocks, so the (S x S) score matrix never
materialises -- required for the 32k-seq dry-run cells to fit HBM.

Decode (``decode_attention``) scores one new token against the whole cache;
with batch-1 long-context the cache is sequence-sharded and combined with the
partial-softmax trick in ``repro.parallel.collectives``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.act_sharding import constrain_heads

NEG_INF = -2.0e38


def attention_init(key, cfg, dtype=jnp.float32, d_kv_model: int | None = None):
    """QKV/O projection params.  d_kv_model: source dim for K/V (cross-attn)."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dk = d_kv_model or d
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": layers.dense_init(ks[1], (dk, kv, hd), dk, dtype),
        "wv": layers.dense_init(ks[2], (dk, kv, hd), dk, dtype),
        "wo": layers.dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def qkv_project(params, x, x_kv, cfg, compute_dtype):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, params["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, params["wv"].astype(compute_dtype))
    if "bq" in params:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    return q, k, v


def _repeat_kv(k, n_heads):
    """Broadcast kv heads up to n_heads for grouped-query attention."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def chunked_attention(q, k, v, *, causal: bool, chunk_q: int, chunk_kv: int,
                      q_offset: int = 0):
    """Flash attention with a memory-exact custom-VJP backward
    (repro.models.flash); O(S) residuals instead of stacked score blocks."""
    from repro.models.flash import flash_attention
    return flash_attention(q, k, v, causal=causal, chunk_q=chunk_q,
                           chunk_kv=chunk_kv, q_offset=q_offset)


def chunked_attention_naive_grad(q, k, v, *, causal: bool, chunk_q: int,
                                 chunk_kv: int, q_offset: int = 0):
    """The pre-flash implementation (autodiff saves score blocks); kept as
    the oracle for flash-gradient tests and for ablation."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    # explicit head parallelism: after the GQA repeat every tensor has
    # n_heads heads, so sharding them over 'model' keeps the whole score/
    # context computation local (no K/V resharding inside the scan).
    q = constrain_heads(q)
    k = constrain_heads(k)
    v = constrain_heads(v)
    scale = hd ** -0.5

    cq = min(chunk_q, sq)
    ckv = min(chunk_kv, skv)
    nq, nkv = -(-sq // cq), -(-skv // ckv)
    pad_q, pad_kv = nq * cq - sq, nkv * ckv - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    # (nq, b, h, cq, hd) query blocks; scan over kv blocks inside
    qb = jnp.moveaxis(q.reshape(b, nq, cq, h, hd), (1, 3), (0, 2))
    kb = jnp.moveaxis(k.reshape(b, nkv, ckv, h, hd), (1, 3), (0, 2))
    vb = jnp.moveaxis(v.reshape(b, nkv, ckv, h, hd), (1, 3), (0, 2))

    q_pos = (q_offset + jnp.arange(nq * cq)).reshape(nq, cq)
    kv_pos = jnp.arange(nkv * ckv).reshape(nkv, ckv)
    kv_valid = (jnp.arange(nkv * ckv) < skv).reshape(nkv, ckv)

    def per_qblock(qi, qpos):
        # online softmax over kv blocks
        def body(carry, xs):
            m, l, acc = carry
            kj, vj, kpos, valid = xs
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = valid[None, None, None, :]
            if causal:
                mask = mask & (kpos[None, None, None, :]
                               <= qpos[None, None, :, None])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (kb, vb, kv_pos, kv_valid))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = jax.lax.map(lambda xs: per_qblock(*xs), (qb, q_pos))
    out = jnp.moveaxis(out, (0, 2), (1, 3)).reshape(b, nq * cq, h, hd)
    return out[:, :sq]


def naive_attention(q, k, v, *, causal: bool, q_offset: int = 0):
    """Reference O(S^2)-memory attention (tests/small shapes only)."""
    h = q.shape[2]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * (q.shape[-1] ** -0.5)
    if causal:
        qp = q_offset + jnp.arange(q.shape[1])
        kp = jnp.arange(k.shape[1])
        s = jnp.where(kp[None, None, None, :] <= qp[None, None, :, None],
                      s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def decode_attention(q, k_cache, v_cache, cache_len):
    """One-token decode: q (b, 1, h, hd) vs cache (b, S, kvh, hd).

    GQA is computed *grouped* -- the cache is never repeated to h heads.
    Repeating would reshard the multi-TB cache across the model axis every
    layer (the dry-run showed 201 GB/device of all-gather on deepseek-67b
    decode); grouped einsums keep the cache in place and only the (b, h, S)
    score tensor crosses shards (psum over the contracted head_dim).

    ``cache_len``: number of valid cache entries (the new token's K/V must
    already be written at position cache_len - 1).
    """
    b, _, h, hd = q.shape
    S, g = k_cache.shape[1], k_cache.shape[2]
    rep = h // g
    qg = q.reshape(b, g, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = jnp.arange(S)[None, None, None, :] < cache_len
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache)
    return ctx.reshape(b, 1, h, hd)


def attn_output(params, ctx, compute_dtype):
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(compute_dtype))
