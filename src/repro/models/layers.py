"""Shared neural blocks: norms, rotary embeddings, projections, MLPs.

Parameters are plain nested dicts of jnp arrays (no framework dependency).
Init functions return pytrees; apply functions are pure.  Weight layouts are
chosen so the logical-axis sharding rules in ``repro.parallel.sharding`` can
map them by path name (see that module).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    """Truncated-normal fan-in init (the MaxText/T5 default)."""
    std = 1.0 / math.sqrt(in_axis_size)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


# -- RMSNorm ------------------------------------------------------------------
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# -- Rotary position embeddings ---------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (b, s, h, hd); positions: (b, s) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    positions3: (3, b, s) -- temporal / height / width position ids.
    ``sections`` (e.g. (16, 24, 24), summing to head_dim/2) assigns rotary
    frequency channels to the three components.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # per-frequency-channel component selector: 0=t, 1=h, 2=w
    sel = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])   # (hd/2,)
    pos = positions3[sel]                                # (hd/2, b, s)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)   # (b, s, hd/2)
    angles = pos * freqs                                 # (b, s, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -- dense MLP (SwiGLU) ---------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), d_model, dtype),
        "wo": dense_init(k3, (d_ff, d_model), d_ff, dtype),
    }


def mlp(params, x, compute_dtype):
    h = jax.nn.silu(x @ params["wi_gate"].astype(compute_dtype))
    h = h * (x @ params["wi_up"].astype(compute_dtype))
    return h @ params["wo"].astype(compute_dtype)


# -- embeddings --------------------------------------------------------------------
def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return {"embedding": (jax.random.normal(key, (vocab, d_model)) * 0.02
                          ).astype(dtype)}


def embed(params, tokens, compute_dtype):
    return params["embedding"].astype(compute_dtype)[tokens]


def unembed(params, x):
    """Logits in float32 for a stable softmax/loss."""
    return x.astype(jnp.float32) @ params["embedding"].astype(jnp.float32).T


def lm_head_init(key, d_model, vocab, dtype=jnp.float32):
    return {"kernel": dense_init(key, (d_model, vocab), d_model, dtype)}


def lm_head(params, x):
    return x.astype(jnp.float32) @ params["kernel"].astype(jnp.float32)
