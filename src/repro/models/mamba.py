"""Selective state-space layers: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Training/prefill uses a *chunked* scan: ``lax.scan`` over sequence chunks
carrying the (b, ..., state) SSM state, with an associative scan *inside*
each chunk.  The recurrence h_t = a_t * h_{t-1} + b_t is associative under
  (a1, b1) . (a2, b2) = (a1 * a2, a2 * b1 + b2)
so within-chunk latency is log(Q) while memory stays O(b * Q * d * n) per
chunk -- this is the TPU-friendly middle ground between the sequential scan
(too slow) and materialising the full (b, S, d, n) state (too big).

Decode is a single O(1) state update -- the CRRM "smart update" analogue:
one dirty row (the new token) instead of the full recompute.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def _ssm_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _chunk_split(x, n_chunks, Q):
    """(B, S, ...) -> (n_chunks, B, Q, ...) with zero right-padding."""
    B, S = x.shape[0], x.shape[1]
    pad = n_chunks * Q - S
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
    return jnp.moveaxis(x.reshape((B, n_chunks, Q) + x.shape[2:]), 1, 0)


def _ssm_scan_chunks(make_chunk, outputs_of, S, Q, h0, xs_chunks):
    """Sequential scan over sequence chunks carrying the SSM state.

    ``make_chunk(chunk_inputs) -> (a_q, b_q)`` builds the state-expanded
    decay/input tensors for ONE chunk only, and ``outputs_of(h, chunk_inputs)
    -> y_q`` contracts the state back to activations -- so the (B, Q, d, n)
    expansion only ever exists transiently inside one (checkpointed) chunk
    body.  This is what keeps Mamba training memory O(B*S*d) instead of
    O(B*S*d*n) (the dry-run census showed 300+ GiB/device without it).
    """
    @jax.checkpoint
    def body(h_prev, chunk_inputs):
        a_q, b_q = make_chunk(chunk_inputs)       # (B, Q, ...) expanded
        a_cum, h_in = jax.lax.associative_scan(_ssm_combine, (a_q, b_q),
                                               axis=1)
        h = h_in + a_cum * h_prev[:, None]
        y_q = outputs_of(h, chunk_inputs)
        return h[:, -1], y_q

    h_last, ys = jax.lax.scan(body, h0, xs_chunks)
    # (n_chunks, B, Q, ...) -> (B, S, ...)
    B = ys.shape[1]
    y = jnp.moveaxis(ys, 0, 1).reshape((B, -1) + ys.shape[3:])
    return y[:, :S], h_last


def _causal_conv(x, w, bias):
    """Depthwise causal conv: x (b, s, d), w (d, k) -> (b, s, d)."""
    k = w.shape[1]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[None, None, :, i]
    return out + bias[None, None, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------
def mamba1_init(key, cfg, dtype=jnp.float32):
    d, din, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    ks = jax.random.split(key, 7)
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[5], (din,),
                                   minval=math.log(1e-3),
                                   maxval=math.log(1e-1)))))
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * din), d, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (din, cfg.ssm_conv))
                   ).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": layers.dense_init(ks[2], (din, r + 2 * n), din, dtype),
        "dt_proj": layers.dense_init(ks[3], (r, din), r, jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (din, 1))),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], (din, d), din, dtype),
    }


def mamba1_forward(params, x, cfg, compute_dtype, h0=None, conv0=None,
                   return_state: bool = False):
    """x: (b, s, d).  h0: (b, din, n) initial state; conv0: (b, k-1, din)."""
    b, s, d = x.shape
    din, n, r = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    xz = x @ params["in_proj"].astype(compute_dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)

    if conv0 is not None:
        x_cat = jnp.concatenate([conv0.astype(compute_dtype), x_in], axis=1)
        x_conv = _causal_conv(x_cat, params["conv_w"].astype(compute_dtype),
                              params["conv_b"].astype(compute_dtype))
        x_conv = x_conv[:, conv0.shape[1]:]
    else:
        x_conv = _causal_conv(x_in, params["conv_w"].astype(compute_dtype),
                              params["conv_b"].astype(compute_dtype))
    x_c = jax.nn.silu(x_conv)

    proj = x_c @ params["x_proj"].astype(compute_dtype)
    dt_raw = proj[..., :r].astype(jnp.float32)
    Bm = proj[..., r:r + n].astype(jnp.float32)          # (b, s, n)
    Cm = proj[..., r + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw @ params["dt_proj"] + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                        # (din, n)

    if h0 is None:
        h0 = jnp.zeros((b, din, n), jnp.float32)
    Q = min(cfg.ssm_chunk, s)
    n_chunks = -(-s // Q)
    xs = (_chunk_split(dt, n_chunks, Q),
          _chunk_split(Bm, n_chunks, Q),
          _chunk_split(Cm, n_chunks, Q),
          _chunk_split(x_c.astype(jnp.float32), n_chunks, Q))

    def make_chunk(ci):
        dt_q, B_q, _, x_q = ci
        da = jnp.exp(dt_q[..., None] * A[None, None])    # (b, Q, din, n)
        dbx = (dt_q * x_q)[..., None] * B_q[:, :, None, :]
        return da, dbx

    def outputs_of(h, ci):
        _, _, C_q, x_q = ci
        return jnp.einsum("bqdn,bqn->bqd", h, C_q) + params["D"] * x_q

    y, h_last = _ssm_scan_chunks(make_chunk, outputs_of, s, Q, h0, xs)
    y = y.astype(compute_dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(compute_dtype)
    if return_state:
        k = cfg.ssm_conv
        conv_state = jnp.concatenate(
            [conv0, x_in], axis=1)[:, -(k - 1):] if conv0 is not None \
            else jnp.pad(x_in, ((0, 0), (k - 1 - min(s, k - 1), 0),
                                (0, 0)))[:, -(k - 1):]
        return out, h_last, conv_state.astype(compute_dtype)
    return out


def mamba1_decode(params, x, cfg, compute_dtype, h, conv_state):
    """One-token step.  x: (b, 1, d); h: (b, din, n); conv: (b, k-1, din)."""
    out, h_new, conv_new = mamba1_forward(
        params, x, cfg, compute_dtype, h0=h, conv0=conv_state,
        return_state=True)
    return out, h_new, conv_new


# ---------------------------------------------------------------------------
# Mamba-2 (scalar-per-head decay; SSD recurrence form)
# ---------------------------------------------------------------------------
def mamba2_init(key, cfg, dtype=jnp.float32):
    d, din, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    ks = jax.random.split(key, 7)
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * din), d, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (din, cfg.ssm_conv))
                   ).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "B_proj": layers.dense_init(ks[2], (d, n), d, dtype),
        "C_proj": layers.dense_init(ks[3], (d, n), d, dtype),
        "dt_proj": layers.dense_init(ks[4], (d, H), d, jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": layers.dense_init(ks[5], (din, d), din, dtype),
    }


def mamba2_forward(params, x, cfg, compute_dtype, h0=None, conv0=None,
                   return_state: bool = False):
    """x: (b, s, d).  State h: (b, H, P, n)."""
    b, s, d = x.shape
    din, n = cfg.d_inner, cfg.ssm_state
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    xz = x @ params["in_proj"].astype(compute_dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    if conv0 is not None:
        x_cat = jnp.concatenate([conv0.astype(compute_dtype), x_in], axis=1)
        x_conv = _causal_conv(x_cat, params["conv_w"].astype(compute_dtype),
                              params["conv_b"].astype(compute_dtype))
        x_conv = x_conv[:, conv0.shape[1]:]
    else:
        x_conv = _causal_conv(x_in, params["conv_w"].astype(compute_dtype),
                              params["conv_b"].astype(compute_dtype))
    x_c = jax.nn.silu(x_conv)

    Bm = (x @ params["B_proj"].astype(compute_dtype)).astype(jnp.float32)
    Cm = (x @ params["C_proj"].astype(compute_dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(
        x.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                       # (H,)

    xh = x_c.astype(jnp.float32).reshape(b, s, H, Pd)
    if h0 is None:
        h0 = jnp.zeros((b, H, Pd, n), jnp.float32)
    Q = min(cfg.ssm_chunk, s)
    if getattr(cfg, "ssm_impl", "scan") == "ssd" and s > 1:
        y, h_last = _mamba2_ssd_chunks(dt, Bm, Cm, xh, A, h0, Q, s,
                                       params["D"])
    else:
        n_chunks = -(-s // Q)
        xs = (_chunk_split(dt, n_chunks, Q),
              _chunk_split(Bm, n_chunks, Q),
              _chunk_split(Cm, n_chunks, Q),
              _chunk_split(xh, n_chunks, Q))

        def make_chunk(ci):
            dt_q, B_q, _, x_q = ci
            a_q = jnp.exp(dt_q * A[None, None, :])       # (b, Q, H)
            dbx = (dt_q[..., None] * x_q)[..., None] \
                * B_q[:, :, None, None, :]
            return a_q[..., None, None], dbx             # (b, Q, H, P, n)

        def outputs_of(hh, ci):
            _, _, C_q, x_q = ci
            return (jnp.einsum("bqhpn,bqn->bqhp", hh, C_q)
                    + params["D"][None, None, :, None] * x_q)

        y, h_last = _ssm_scan_chunks(make_chunk, outputs_of, s, Q, h0, xs)
    y = y.reshape(b, s, din).astype(compute_dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(compute_dtype)
    if return_state:
        k = cfg.ssm_conv
        conv_state = jnp.concatenate(
            [conv0, x_in], axis=1)[:, -(k - 1):] if conv0 is not None \
            else jnp.pad(x_in, ((0, 0), (k - 1 - min(s, k - 1), 0),
                                (0, 0)))[:, -(k - 1):]
        return out, h_last, conv_state.astype(compute_dtype)
    return out


def _mamba2_ssd_chunks(dt, Bm, Cm, xh, A, h0, Q, S, D_skip):
    """Mamba-2 SSD dual form: chunked MATMUL processing (MXU-native).

    Within a chunk the recurrence unrolls to
        y[t] = C_t . h_prev * alpha_t                       (inter-chunk)
              + sum_{s<=t} (alpha_t/alpha_s) dt_s (C_t.B_s) x_s   (intra)
    with alpha the within-chunk cumulative decay -- the intra term is two
    (Q x Q) matmuls per head instead of the associative scan's elementwise
    (b, Q, H, P, n) state expansion.  Ratios alpha_t/alpha_s are <= 1
    (decay), so the masked-decay matrix is numerically safe.

    Shapes: dt (b,S,H), Bm/Cm (b,S,n), xh (b,S,H,P), h0 (b,H,P,n).
    Returns (y (b,S,H,P), h_last).
    """
    b, _, H = dt.shape
    n_chunks = -(-S // Q)
    xs = (_chunk_split(dt, n_chunks, Q), _chunk_split(Bm, n_chunks, Q),
          _chunk_split(Cm, n_chunks, Q), _chunk_split(xh, n_chunks, Q))

    @jax.checkpoint
    def body(h_prev, ci):
        dt_q, B_q, C_q, x_q = ci                      # (b,Q,H) (b,Q,n) ...
        loga = dt_q * A[None, None, :]                # log decay, <= 0
        cum = jnp.cumsum(loga, axis=1)                # (b, Q, H)
        alpha = jnp.exp(cum)
        # intra-chunk: scores shared across heads, decay per head
        scores = jnp.einsum("btn,bsn->bts", C_q, B_q)       # (b, Q, Q)
        t_idx = jnp.arange(dt_q.shape[1])
        causal = (t_idx[:, None] >= t_idx[None, :])[None, :, :, None]
        # mask INSIDE the exp: t<s entries would be exp(+large) = inf and
        # poison the backward through the where (inf * 0 -> NaN)
        diff = jnp.where(causal, cum[:, :, None, :] - cum[:, None, :, :],
                         -jnp.inf)
        M = scores[:, :, :, None] * jnp.exp(diff) \
            * dt_q[:, None, :, :]                           # (b,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", M, x_q)
        # inter-chunk contribution
        y = y + alpha[..., None] * jnp.einsum("btn,bhpn->bthp", C_q, h_prev)
        # state update: h_new = alpha_Q h_prev + sum_s (alpha_Q/alpha_s) ...
        aQ = alpha[:, -1]                                    # (b, H)
        w = jnp.exp(cum[:, -1:, :] - cum) * dt_q             # (b, Q, H)
        h_new = (aQ[:, :, None, None] * h_prev
                 + jnp.einsum("bshp,bsn->bhpn", x_q * w[..., None], B_q))
        y = y + D_skip[None, None, :, None] * x_q
        return h_new, y

    h_last, ys = jax.lax.scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape((b, n_chunks * Q) + ys.shape[3:])
    return y[:, :S], h_last


def mamba2_decode(params, x, cfg, compute_dtype, h, conv_state):
    out, h_new, conv_new = mamba2_forward(
        params, x, cfg, compute_dtype, h0=h, conv0=conv_state,
        return_state=True)
    return out, h_new, conv_new
