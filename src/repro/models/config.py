"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: Optional[int] = None
    head_dim: Optional[int] = None

    # MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25

    # SSM ---------------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # mamba2 head width
    ssm_dt_rank: Optional[int] = None
    ssm_variant: str = "mamba1"    # mamba1 | mamba2
    ssm_impl: str = "scan"         # scan (associative) | ssd (matmul dual)
    hybrid_attn_every: int = 0     # zamba2: shared attn block cadence

    # enc-dec -------------------------------------------------------------
    n_encoder_layers: int = 0

    # misc ----------------------------------------------------------------
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    qkv_bias: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_inputs: bool = True      # False: stub frontend feeds embeddings
    kv_cache_dtype: str = "compute"   # compute dtype | "int8" (quantized)
    attn_chunk_q: int = 512        # flash-style chunk sizes (train/prefill)
    attn_chunk_kv: int = 1024
    ssm_chunk: int = 128
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True

    def __post_init__(self):
        if self.n_kv_heads is None:
            object.__setattr__(self, "n_kv_heads", self.n_heads)
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_dt_rank is None and self.ssm_state:
            object.__setattr__(self, "ssm_dt_rank",
                               max(1, self.d_model // 16))

    # convenience ------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM/hybrid) -> long_500k runs."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4 if self.hybrid_attn_every else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, (self.n_kv_heads or 4) * 4
                                  // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_experts_per_token=(min(self.n_experts_per_token, 2)
                                 if self.n_experts_per_token else 0),
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_dt_rank=8 if self.ssm_state else None,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            mrope_sections=((4, 6, 6) if self.mrope_sections is not None
                            else None),
            dtype="float32",
            param_dtype="float32",
            name=self.name + "-reduced",
        )
        small.update(over)
        return dataclasses.replace(self, **small)
