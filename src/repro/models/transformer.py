"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

Structure follows MaxText: layers are *stacked* (every leaf gains a leading
L axis) and iterated with ``lax.scan`` + ``jax.checkpoint`` so the HLO stays
O(1) in depth and activation memory is one layer boundary per layer.

Three entry points:
  * ``forward``      -- training: full-sequence logits.
  * ``prefill``      -- serving: full-sequence pass that also returns caches.
  * ``decode_step``  -- serving: one token against the caches (the smart
                        update of the LM world: only the dirty row computes).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, moe
from repro.models.config import ModelConfig
from repro.parallel.act_sharding import constrain, gather_layer_params


def _cdt(cfg):
    return layers._dtype(cfg.dtype)


def _pdt(cfg):
    return layers._dtype(cfg.param_dtype)


def _auto_group(L: int) -> int:
    """Largest divisor of L that is <= 8 (group size for two-level remat)."""
    for g in range(min(8, L), 0, -1):
        if L % g == 0:
            return g
    return 1


def scan_layers_remat(body, x, stacked, cfg):
    """Two-level layer traversal: outer scan over groups of layers with a
    checkpoint around each group, inner scan over the group's layers with a
    per-layer checkpoint.

    Memory: only L/group carries are saved across the whole stack (the
    barrier also stops XLA from storing them upcast to f32); the inner
    per-layer stack exists transiently during one group's backward.  Compute:
    one extra forward per group + per-layer remat (flops model: x5 total).
    """
    L = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    g = _auto_group(L)
    G = L // g
    xs_g = jax.tree_util.tree_map(
        lambda a: a.reshape((G, g) + a.shape[1:]), stacked)

    inner_body = jax.checkpoint(lambda h, lp: (body(h, lp), None))

    @jax.checkpoint
    def group_body(h, gxs):
        h = jax.lax.optimization_barrier(h)   # keep saved carry in bf16
        h, _ = jax.lax.scan(inner_body, h, gxs)
        return h, None

    x, _ = jax.lax.scan(group_body, x, xs_g)
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _layer_init(key, cfg, pdt):
    """One transformer block's params (unstacked)."""
    p = {}
    ks = jax.random.split(key, 8)
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        p["ln1"] = layers.rmsnorm_init(cfg.d_model, pdt)
        p["attn"] = attention.attention_init(ks[0], cfg, pdt)
        p["ln2"] = layers.rmsnorm_init(cfg.d_model, pdt)
        if cfg.family == "moe":
            p["moe"] = moe.moe_init(ks[1], cfg, pdt)
        else:
            p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, pdt)
    elif cfg.family == "ssm":
        p["ln1"] = layers.rmsnorm_init(cfg.d_model, pdt)
        p["ssm"] = (mamba.mamba1_init(ks[0], cfg, pdt)
                    if cfg.ssm_variant == "mamba1"
                    else mamba.mamba2_init(ks[0], cfg, pdt))
    elif cfg.family == "hybrid":
        p["ln1"] = layers.rmsnorm_init(cfg.d_model, pdt)
        p["ssm"] = mamba.mamba2_init(ks[0], cfg, pdt)
    else:
        raise ValueError(cfg.family)
    return p


def _shared_attn_init(key, cfg, pdt):
    """Zamba2-style shared attention+MLP block (weights reused at each
    invocation).  Input is concat([x, x_embed]) -> d_model projection."""
    ks = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(ks[0], (2 * cfg.d_model, cfg.d_model),
                                     2 * cfg.d_model, pdt),
        "ln1": layers.rmsnorm_init(cfg.d_model, pdt),
        "attn": attention.attention_init(ks[1], cfg, pdt),
        "ln2": layers.rmsnorm_init(cfg.d_model, pdt),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, pdt),
    }


def init_params(key, cfg: ModelConfig):
    pdt = _pdt(cfg)
    k_emb, k_layers, k_head, k_shared, k_norm = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, pdt))(layer_keys)
    params = {
        "layers": stacked,
        "final_norm": layers.rmsnorm_init(cfg.d_model, pdt),
    }
    if cfg.embed_inputs or cfg.tie_embeddings:
        params["embed"] = layers.embed_init(k_emb, cfg.vocab_size,
                                            cfg.d_model, pdt)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.lm_head_init(k_head, cfg.d_model,
                                                cfg.vocab_size, pdt)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        params["shared_attn"] = _shared_attn_init(k_shared, cfg, pdt)
    if cfg.family == "vlm":
        # stub frontend adapter: maps provided patch embeddings to d_model
        params["vision_adapter"] = layers.dense_init(
            k_shared, (cfg.d_model, cfg.d_model), cfg.d_model, pdt)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _quantize_kv(x):
    """(b, s, kv, hd) -> int8 values + per-(position, kv-head) scale."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(x / jnp.maximum(scale, 1e-8)), -127, 127)
    return q.astype(jnp.int8), scale.astype(x.dtype)


def _dequantize_kv(q, scale, dtype):
    return q.astype(dtype) * scale.astype(dtype)


def _attn_mlp_block(p, x, cfg, cdt, positions, *, cache=None, pos=None,
                    use_moe=False):
    """Pre-norm attention + MLP/MoE.  cache: (k, v) -> updated in decode."""
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = attention.qkv_project(p["attn"], h, h, cfg, cdt)
    if cfg.mrope_sections is not None:
        q = layers.apply_mrope(q, positions, cfg.rope_theta,
                               cfg.mrope_sections)
        k = layers.apply_mrope(k, positions, cfg.rope_theta,
                               cfg.mrope_sections)
    else:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        ctx = attention.chunked_attention(
            q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv)
    elif len(cache) == 4:                     # int8-quantized cache
        kc, vc, ks, vs = cache
        kq, ksc = _quantize_kv(k)
        vq, vsc = _quantize_kv(v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kq, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vq, pos, axis=1)
        ks = jax.lax.dynamic_update_slice_in_dim(
            ks, ksc.astype(ks.dtype), pos, axis=1)
        vs = jax.lax.dynamic_update_slice_in_dim(
            vs, vsc.astype(vs.dtype), pos, axis=1)
        if q.shape[1] == 1:
            ctx = attention.decode_attention(
                q, _dequantize_kv(kc, ks, cdt),
                _dequantize_kv(vc, vs, cdt), pos + 1)
        else:
            ctx = attention.chunked_attention(
                q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
                chunk_kv=cfg.attn_chunk_kv)
        new_cache = (kc, vc, ks, vs)
    else:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos,
                                                 axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos,
                                                 axis=1)
        if q.shape[1] == 1:
            ctx = attention.decode_attention(q, kc, vc, pos + 1)
        else:
            # prefill: queries attend causally within the prompt only
            ctx = attention.chunked_attention(
                q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
                chunk_kv=cfg.attn_chunk_kv)
        new_cache = (kc, vc)
    x = x + attention.attn_output(p["attn"], ctx.astype(cdt), cdt)

    h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        x = x + moe.moe_layer(p["moe"], h, cfg, cdt)
    else:
        x = x + layers.mlp(p["mlp"], h, cdt)
    return x, new_cache


def _ssm_block(p, x, cfg, cdt, *, state=None, want_state=False):
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    fwd = (mamba.mamba1_forward if cfg.ssm_variant == "mamba1"
           else mamba.mamba2_forward)
    if state is None and not want_state:
        return x + fwd(p["ssm"], h, cfg, cdt), None
    h0, conv0 = state if state is not None else (None, None)
    y, h_new, conv_new = fwd(p["ssm"], h, cfg, cdt, h0=h0, conv0=conv0,
                             return_state=True)
    return x + y, (h_new, conv_new)


def _shared_block(p, x, x0, cfg, cdt, positions, *, cache=None, pos=None):
    """Zamba2 shared attention block on concat([x, x0])."""
    inp = jnp.concatenate([x, x0], axis=-1) @ p["in_proj"].astype(cdt)
    h = layers.rmsnorm(p["ln1"], inp, cfg.norm_eps)
    q, k, v = attention.qkv_project(p["attn"], h, h, cfg, cdt)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is None:
        ctx = attention.chunked_attention(
            q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv)
    else:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
        if q.shape[1] == 1:
            ctx = attention.decode_attention(q, kc, vc, pos + 1)
        else:
            ctx = attention.chunked_attention(
                q, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
                chunk_kv=cfg.attn_chunk_kv)
        new_cache = (kc, vc)
    y = attention.attn_output(p["attn"], ctx.astype(cdt), cdt)
    y = y + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], y, cfg.norm_eps),
                       cdt)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# backbone traversal
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg, cdt):
    if cfg.family == "vlm":
        x = batch["embeds"].astype(cdt) @ params["vision_adapter"].astype(cdt)
        positions = batch["positions"]          # (3, b, s) M-RoPE ids
    else:
        x = layers.embed(params["embed"], batch["tokens"], cdt)
        s = x.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
    return x, positions


def _run_layers(params, x, cfg, cdt, positions, caches=None, pos=None):
    """Iterate the stacked layers.  caches=None -> training (no cache IO);
    otherwise a dict of stacked caches that is read and rewritten."""
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    if cfg.family in ("dense", "moe", "vlm"):
        use_moe = cfg.family == "moe"

        if caches is None:
            def body(h, lp):
                h = constrain(h)
                lp = gather_layer_params(lp)
                h, _ = _attn_mlp_block(lp, h, cfg, cdt, positions,
                                       use_moe=use_moe)
                return h

            return scan_layers_remat(body, x, params["layers"], cfg), None

        names = (["k", "v", "k_scale", "v_scale"]
                 if "k_scale" in caches else ["k", "v"])

        if x.shape[1] == 1:
            # decode: carry the FULL stacked cache and update each layer's
            # slice in place -- scanning caches as xs/ys double-buffers the
            # whole multi-TB cache (input stack + output stack), which blew
            # the 16 GiB budget on the 32k-decode cells.
            def body(carry, lp):
                h, bufs, li = carry
                layer_cache = tuple(
                    jax.lax.dynamic_index_in_dim(b, li, 0, keepdims=False)
                    for b in bufs)
                h, new_lc = _attn_mlp_block(lp, h, cfg, cdt, positions,
                                            cache=layer_cache, pos=pos,
                                            use_moe=use_moe)
                bufs = tuple(
                    jax.lax.dynamic_update_index_in_dim(b, c, li, 0)
                    for b, c in zip(bufs, new_lc))
                return (h, bufs, li + 1), None

            bufs0 = tuple(caches[n] for n in names)
            (x, bufs, _), _ = jax.lax.scan(
                body, (x, bufs0, jnp.int32(0)), params["layers"])
            return x, dict(zip(names, bufs))

        def body(h, xs):
            lp, layer_cache = xs[0], tuple(xs[1:])
            h = constrain(h)
            h, new_lc = _attn_mlp_block(lp, h, cfg, cdt, positions,
                                        cache=layer_cache, pos=pos,
                                        use_moe=use_moe)
            return h, new_lc

        x, news = jax.lax.scan(
            body, x, tuple([params["layers"]] + [caches[n] for n in names]))
        return x, dict(zip(names, news))

    if cfg.family == "ssm":
        if caches is None:
            def body(h, lp):
                h = constrain(h)
                lp = gather_layer_params(lp)
                h, _ = _ssm_block(lp, h, cfg, cdt)
                return h

            return scan_layers_remat(body, x, params["layers"], cfg), None

        def body(h, xs):
            lp, hs, cs = xs
            lp = gather_layer_params(lp)
            h = constrain(h)
            h, (hs, cs) = _ssm_block(lp, h, cfg, cdt, state=(hs, cs))
            return h, (hs, cs)

        x, (hnew, cnew) = jax.lax.scan(
            body, x, (params["layers"], caches["h"], caches["conv"]))
        return x, {"h": hnew, "conv": cnew}

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every or cfg.n_layers + 1
        n_groups = -(-cfg.n_layers // every)
        x0 = x
        new_caches = {"h": [], "conv": [], "k": [], "v": []} \
            if caches is not None else None
        li = 0
        for g in range(n_groups):
            size = min(every, cfg.n_layers - g * every)
            gp = jax.tree_util.tree_map(
                lambda a: jax.lax.slice_in_dim(a, li, li + size, axis=0),
                params["layers"])
            if caches is None:
                def body(h, lp):
                    h = constrain(h)
                    h, _ = _ssm_block(lp, h, cfg, cdt)
                    return h

                x = scan_layers_remat(body, x, gp, cfg)
            else:
                def body(h, xs):
                    lp, hs, cs = xs
                    h = constrain(h)
                    h, (hs, cs) = _ssm_block(lp, h, cfg, cdt,
                                             state=(hs, cs))
                    return h, (hs, cs)

                gh = jax.lax.slice_in_dim(caches["h"], li, li + size,
                                          axis=0)
                gc = jax.lax.slice_in_dim(caches["conv"], li, li + size,
                                          axis=0)
                x, (hnew, cnew) = jax.lax.scan(body, x, (gp, gh, gc))
                new_caches["h"].append(hnew)
                new_caches["conv"].append(cnew)
            li += size
            # shared attention block after each group (rematted: its
            # flash residuals would otherwise persist per invocation)
            if caches is None:
                x = jax.checkpoint(
                    lambda h, h0, p: _shared_block(p, h, h0, cfg, cdt,
                                                   positions)[0])(
                    x, x0, params["shared_attn"])
            else:
                kc = caches["k"][g]
                vc = caches["v"][g]
                x, (kc, vc) = _shared_block(params["shared_attn"], x, x0,
                                            cfg, cdt, positions,
                                            cache=(kc, vc), pos=pos)
                new_caches["k"].append(kc)
                new_caches["v"].append(vc)
        if caches is None:
            return x, None
        return x, {
            "h": jnp.concatenate(new_caches["h"], axis=0),
            "conv": jnp.concatenate(new_caches["conv"], axis=0),
            "k": jnp.stack(new_caches["k"], axis=0),
            "v": jnp.stack(new_caches["v"], axis=0),
        }

    raise ValueError(cfg.family)


def _logits(params, x, cfg):
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x)
    return layers.lm_head(params["lm_head"], x)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def forward_features(params, batch, cfg: ModelConfig):
    """Backbone pass: final-normed features (b, s, d) -- the training loss
    applies the LM head in sequence chunks to avoid materialising the full
    (b, s, vocab) logits (see train.loss.chunked_cross_entropy)."""
    cdt = _cdt(cfg)
    x, positions = _embed_inputs(params, batch, cfg, cdt)
    x, _ = _run_layers(params, x, cfg, cdt, positions)
    return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def head(params, x, cfg: ModelConfig):
    return _logits(params, x, cfg)


def forward(params, batch, cfg: ModelConfig):
    """Training forward pass: full-sequence logits (b, s, vocab) in f32."""
    return _logits(params, forward_features(params, batch, cfg), cfg)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=None) -> dict:
    """Allocate decode caches (stacked over layers)."""
    dtype = dtype or _cdt(cfg)
    kvh, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm") \
            and cfg.kv_cache_dtype == "int8":
        # quantized serving cache: halves the dominant decode memory term
        return {
            "k": jnp.zeros((L, batch_size, max_len, kvh, hd), jnp.int8),
            "v": jnp.zeros((L, batch_size, max_len, kvh, hd), jnp.int8),
            "k_scale": jnp.zeros((L, batch_size, max_len, kvh, 1), dtype),
            "v_scale": jnp.zeros((L, batch_size, max_len, kvh, 1), dtype),
        }
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        return {
            "k": jnp.zeros((L, batch_size, max_len, kvh, hd), dtype),
            "v": jnp.zeros((L, batch_size, max_len, kvh, hd), dtype),
        }
    if cfg.family == "ssm":
        din, n = cfg.d_inner, cfg.ssm_state
        shp = ((L, batch_size, din, n) if cfg.ssm_variant == "mamba1"
               else (L, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, n))
        return {
            "h": jnp.zeros(shp, jnp.float32),
            "conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, cfg.d_inner),
                              dtype),
        }
    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = -(-cfg.n_layers // every)
        return {
            "h": jnp.zeros((L, batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, cfg.d_inner),
                              dtype),
            "k": jnp.zeros((n_groups, batch_size, max_len, kvh, hd), dtype),
            "v": jnp.zeros((n_groups, batch_size, max_len, kvh, hd), dtype),
        }
    raise ValueError(cfg.family)


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Process the prompt; returns (last_token_logits, caches)."""
    cdt = _cdt(cfg)
    x, positions = _embed_inputs(params, batch, cfg, cdt)
    b, s = x.shape[0], x.shape[1]
    caches = init_cache(cfg, b, max_len)
    x, caches = _run_layers(params, x, cfg, cdt, positions,
                            caches=caches, pos=0)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x[:, -1:], cfg), caches


def decode_step(params, batch, caches, pos, cfg: ModelConfig):
    """One decode step.  batch carries tokens (b, 1) (or embeds for vlm);
    ``pos`` is the scalar write position (= current cache length)."""
    cdt = _cdt(cfg)
    if cfg.family == "vlm":
        x = batch["embeds"].astype(cdt) @ params["vision_adapter"].astype(cdt)
        positions = batch["positions"]
    else:
        x = layers.embed(params["embed"], batch["tokens"], cdt)
        positions = jnp.broadcast_to(
            jnp.asarray(pos)[None, None], x.shape[:2]).astype(jnp.int32)
    x, caches = _run_layers(params, x, cfg, cdt, positions, caches=caches,
                            pos=pos)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), caches
