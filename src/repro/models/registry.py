"""Architecture registry: arch-id -> (config, model functions, input specs)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Arch:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    forward_features: Callable
    head: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def make_arch(cfg: ModelConfig) -> Arch:
    if cfg.family == "encdec":
        return Arch(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            forward_features=lambda p, b: encdec.forward_features(p, b, cfg),
            head=lambda p, x: encdec.head(p, x, cfg),
            prefill=lambda p, b, max_len: encdec.prefill(p, b, cfg, max_len),
            decode_step=lambda p, b, c, pos: encdec.decode_step(
                p, b, c, pos, cfg),
            init_cache=lambda bsz, max_len, enc_len=None: encdec.init_cache(
                cfg, bsz, max_len, enc_len or max_len),
        )
    return Arch(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        forward=lambda p, b: transformer.forward(p, b, cfg),
        forward_features=lambda p, b: transformer.forward_features(p, b, cfg),
        head=lambda p, x: transformer.head(p, x, cfg),
        prefill=lambda p, b, max_len: transformer.prefill(p, b, cfg, max_len),
        decode_step=lambda p, b, c, pos: transformer.decode_step(
            p, b, c, pos, cfg),
        init_cache=lambda bsz, max_len, enc_len=None: transformer.init_cache(
            cfg, bsz, max_len),
    )


# ---------------------------------------------------------------------------
# assigned input shapes (seq_len, global_batch) and applicability rules
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (DESIGN.md
    §Arch-applicability); every assigned arch has a decoder."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention at 524288 tokens; "
                       "arch has no sub-quadratic variant -- skipped "
                       "per assignment rules")
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    Returns (batch_specs, extra) where extra carries cache specs for decode
    kinds.  No device memory is allocated.
    """
    sh = SHAPES[shape_name]
    S, B = sh["seq_len"], sh["global_batch"]
    f = jax.ShapeDtypeStruct
    emb_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def token_batch(seq):
        if cfg.family == "vlm":
            return {"embeds": f((B, seq, cfg.d_model), emb_dt),
                    "positions": f((3, B, seq), jnp.int32)}
        if cfg.family == "encdec":
            return {"src_embeds": f((B, seq, cfg.d_model), emb_dt),
                    "tokens": f((B, seq), jnp.int32)}
        return {"tokens": f((B, seq), jnp.int32)}

    if sh["kind"] == "train":
        batch = token_batch(S)
        batch["labels"] = f((B, S), jnp.int32)
        return batch, None
    if sh["kind"] == "prefill":
        return token_batch(S), None
    # decode: one new token against a full cache of length S
    if cfg.family == "vlm":
        batch = {"embeds": f((B, 1, cfg.d_model), emb_dt),
                 "positions": f((3, B, 1), jnp.int32)}
    elif cfg.family == "encdec":
        batch = {"tokens": f((B, 1), jnp.int32)}
    else:
        batch = {"tokens": f((B, 1), jnp.int32)}
    arch = make_arch(cfg)
    # eval_shape: build cache *specs* without allocating terabytes
    if cfg.family == "encdec":
        cache_specs = jax.eval_shape(lambda: arch.init_cache(B, S, S))
    else:
        cache_specs = jax.eval_shape(lambda: arch.init_cache(B, S))
    return batch, cache_specs
