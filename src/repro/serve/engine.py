"""Batched serving engine: prefill + decode with slot-based batching.

The decode step is the framework's "smart update": one token row computes
against the cached state instead of re-running the whole sequence (DESIGN.md
§Arch-applicability).  Requests are packed into fixed batch slots; finished
slots are refilled from the queue (continuous-batching-lite -- slots decode
in lockstep, which is the right trade for TPU shapes).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import sharding as shd
from repro.parallel.mesh import batch_axes


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (s,) int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, arch, mesh, *, batch_slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 seed: int = 0):
        self.arch, self.mesh = arch, mesh
        self.B, self.S = batch_slots, max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        params_shape = jax.eval_shape(
            lambda: arch.init(jax.random.PRNGKey(0)))
        self.param_sh = shd.named(mesh,
                                  shd.infer_param_specs(params_shape, mesh))
        self.params = jax.jit(
            lambda: arch.init(jax.random.PRNGKey(seed)),
            out_shardings=self.param_sh)()

        cache_shape = jax.eval_shape(lambda: arch.init_cache(self.B, self.S))
        self.cache_sh = shd.named(
            mesh, shd.cache_specs(arch.cfg, cache_shape, mesh))

        def _decode(params, batch, caches, pos):
            return arch.decode_step(params, batch, caches, pos)

        self._decode = jax.jit(_decode,
                               in_shardings=(self.param_sh, None,
                                             self.cache_sh, None),
                               out_shardings=(None, self.cache_sh),
                               donate_argnums=(2,))
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * self.B

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> Request:
        req = Request(rid=len(self.queue), prompt=np.asarray(prompt,
                                                             np.int32),
                      max_new_tokens=max_new_tokens)
        self.queue.append(req)
        return req

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits[:, -1] / self.temperature)

    def run(self, progress: bool = False) -> dict:
        """Drain the queue; returns {rid: generated token list}."""
        results, t0, n_tokens = {}, time.perf_counter(), 0
        while self.queue or any(s is not None for s in self.slots):
            # (re)fill slots; pad the batch with a dummy request if needed
            batch_reqs = []
            for i in range(self.B):
                if self.slots[i] is None and self.queue:
                    self.slots[i] = self.queue.popleft()
                batch_reqs.append(self.slots[i])
            active = [r for r in batch_reqs if r is not None]
            if not active:
                break
            max_prompt = max(len(r.prompt) for r in active)
            prompts = np.zeros((self.B, max_prompt), np.int32)
            for i, r in enumerate(batch_reqs):
                if r is not None:
                    prompts[i, -len(r.prompt):] = r.prompt  # left-pad
            # prefill the whole batch (lockstep) then decode
            last, caches = self.arch.prefill(self.params,
                                             {"tokens": jnp.asarray(prompts)},
                                             self.S)
            pos = max_prompt
            tok = self._sample(last)
            steps = max(r.max_new_tokens for r in active)
            for j in range(steps):
                for i, r in enumerate(batch_reqs):
                    if r is not None and len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(tok[i]))
                        n_tokens += 1
                if j == steps - 1:
                    break
                logits, caches = self._decode(
                    self.params, {"tokens": tok[:, None].astype(jnp.int32)},
                    caches, pos)
                pos += 1
                tok = self._sample(logits)
            for i, r in enumerate(batch_reqs):
                if r is not None:
                    results[r.rid] = r.out_tokens
                    r.done = True
                    self.slots[i] = None
        dt = time.perf_counter() - t0
        return {"results": results,
                "tokens_per_s": n_tokens / max(dt, 1e-9),
                "n_tokens": n_tokens}
