"""Per-cell resource-block allocation policies (pure ``jnp``).

A cell owns ``n_rb`` resource blocks per frequency chunk per TTI.  A policy
maps the radio state produced by the CRRM graph (spectral efficiency ``se``,
``cqi``, attachment ``a``) plus MAC state (backlog-derived ``active`` mask,
PF average-rate EWMA, round-robin cursor) to an allocation matrix

    ``alloc[i, k]`` = resource blocks granted to UE ``i`` on chunk ``k``.

The frequency axis ``k`` is whatever the caller resolves the grid at: the
legacy power subbands (wideband CQI, ``n_rb`` RBs per chunk) or the
frequency-selective CQI subbands of ``n_rb_subbands > 1`` (``rb_per_chunk``
RBs per chunk, so max-CQI and PF pick *which* RBs a UE gets, not just how
many).  All policies are shape-polymorphic in ``k``.

Invariant (property-tested in tests/test_mac_properties.py):
``sum_i alloc[i, k] [a_i == j] == n_rb`` for every cell ``j`` with at least
one active attached UE on chunk ``k``, and 0 for every other cell.

Policies:

* ``rr``       -- round-robin: active attached UEs split the grid evenly,
  the integer remainder rotates with a per-TTI cursor;
* ``max_cqi``  -- opportunistic: the active UE with the best CQI takes the
  cell's whole subband grid (winner-take-all);
* ``pf``       -- proportional fair: RBs split in proportion to the
  alpha-fair weight ``rate / avg**alpha`` with ``alpha = (1+p)/(1-p)``
  derived from ``fairness_p``.  The stationary solution of that control
  law is the paper's fairness-weighted share ``se**-p`` (the legacy
  ``ThroughputNode``), which is what the single-shot graph node uses; the
  episode engine feeds the true EWMA state instead.

All functions are shape-polymorphic pure ``jnp`` and traceable, so they run
both as smart-update graph nodes and inside ``jax.lax.scan``.

Mesh-sharded operation (DESIGN.md §Radio-fns): every policy accepts an
optional ``ue_axis`` -- mesh axis name(s) the UE dimension is sharded over
inside ``shard_map``.  A cell's RB grid mixes *all* of its attached UEs, so
the per-cell reductions (active counts, PF weight sums, the max-CQI winner)
become collectives: ``psum``/``pmax`` over the UE axis plus the cross-shard
argmax of ``core.distributed._global_best`` (tie-break = lowest global UE
index, matching single-device ``jnp.argmax``).  ``ue_axis=None`` (the
default) compiles the exact legacy single-device program.

On the UE x cell episode mesh (DESIGN.md §Million-UE-scaling) the scheduler
is *deliberately not* cell-sharded: its per-cell bins are O(n_cells x K)
scalars -- tiny next to the radio leaves -- so every shard keeps the full
``n_cells`` bin range, attachment indices stay global, and the policies
need only the UE-axis collectives above.  The engine replicates ``se`` /
``cqi`` / ``a`` along the cell axes before calling ``allocate``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mac import segments

SCHEDULER_POLICIES = ("rr", "max_cqi", "pf")

#: fairness_p -> alpha-fair exponent is singular at p=1 (max-min fairness);
#: cap keeps the exponent finite while remaining far steeper than any
#: realistic rate spread needs.
_ALPHA_MAX = 63.0

#: finite stand-in for -inf on the differentiable scheduler paths: deep
#: enough that exp(_NEG - anything) underflows to exactly 0.0 (bitwise the
#: -inf forward), finite so reverse-mode never forms inf - inf = nan.
_NEG = -1e30


def _cell_mask(active, a, n_cells):
    """M[i, j, k] = UE i is active on subband k and attached to cell j."""
    onehot = (a[:, None] == jnp.arange(n_cells)[None, :])
    return active[:, None, :] & onehot[:, :, None]


def allocate_rr(active, a, n_cells, n_rb, cursor, ue_axis=None,
                differentiable=False):
    """Round-robin: even integer split, remainder rotated by ``cursor``.

    A UE's within-cell rank (its position in the cell's active roster) is
    computed by segment rank -- one stable sort by cell plus O(n_ue x K)
    prefix sums -- instead of the O(n_ue x n_cell x K) within-cell rank
    cumsum (the measured 52 ms/TTI MAC bottleneck at 100k UE x 57 cells;
    ROADMAP).  Stable sort keeps each cell's UEs in original-index order,
    so the rank (and therefore the allocation) is bitwise identical to
    the cumsum formulation -- asserted against a mask-cumsum oracle in
    tests/test_twin.py.

    Sharded (``ue_axis``): a UE's within-cell rank is its local rank plus
    the active counts of all lower shards (the global UE order is
    shard-major, i.e. contiguous blocks), and the per-cell active totals
    are psummed.
    """
    act_i = active.astype(jnp.int32)                   # (n_ue, K)
    counts = segments.segment_sum(act_i, a, n_cells,   # (n_cells, K) local
                                  differentiable=differentiable)
    order = jnp.argsort(a)                 # stable: in-cell order preserved
    csum = jnp.cumsum(act_i[order], axis=0)            # actives at pos <= s
    offs = jnp.cumsum(counts, axis=0) - counts         # actives in cells < j
    rank_sorted = csum - 1 - offs[a[order]]            # (n_ue, K)
    rank = jnp.empty_like(rank_sorted).at[order].set(rank_sorted)
    if ue_axis is None:
        n_active = counts[a]
    else:
        from repro.core.distributed import _axis_index
        gathered = jax.lax.all_gather(counts, ue_axis)  # (n_shards, ...)
        my = _axis_index(ue_axis)
        shard = jnp.arange(gathered.shape[0])[:, None, None]
        before = jnp.where(shard < my, gathered, 0).sum(axis=0)
        rank = rank + before[a]                        # global within-cell
        n_active = gathered.sum(axis=0)[a]
    n_act = jnp.maximum(n_active, 1)
    base = n_rb // n_act
    extra = ((rank - cursor) % n_act) < (n_rb % n_act)
    return jnp.where(active, (base + extra).astype(jnp.float32), 0.0)


def allocate_max_cqi(active, cqi, a, n_cells, n_rb, ue_axis=None):
    """Winner-take-all: the best-CQI active UE gets the cell's whole grid.

    Sharded (``ue_axis``): the per-cell winner is the cross-shard argmax
    of ``core.distributed._global_best`` (ties to the lowest global UE
    index, exactly like single-device ``jnp.argmax``).
    """
    M = _cell_mask(active, a, n_cells)
    score = jnp.where(M, cqi[:, None, :], -1)          # (n_ue, n_cells, K)
    if ue_axis is None:
        winner = jnp.argmax(score, axis=0)             # (n_cells, K)
        i = jnp.arange(active.shape[0])[:, None]
    else:
        from repro.core.distributed import _axis_index, _global_best
        n_loc = active.shape[0]
        _, winner, _ = _global_best(
            score.max(axis=0), score.argmax(axis=0).astype(jnp.int32),
            n_loc, ue_axis)
        i = (_axis_index(ue_axis) * n_loc + jnp.arange(n_loc))[:, None]
    mine = winner[a]                                   # (n_ue, K)
    return jnp.where(active & (mine == i), float(n_rb), 0.0)


def allocate_max_cqi_soft(active, se, a, n_cells, n_rb, tau):
    """Soft max_cqi: a temperature-``tau`` softmax share of the grid.

    The differentiable relaxation of :func:`allocate_max_cqi`
    (``RelaxConfig.soft_sched``): each cell's active UEs split its
    ``n_rb`` RBs in proportion to ``softmax(se / tau)`` instead of
    winner-take-all.  Scoring on the (smoothly relaxed) spectral
    efficiency rather than the i32 CQI is what lets the gradient flow
    from the allocation back into powers; as ``tau -> 0`` the share
    collapses onto the best-SE UE and this reduces to the hard policy
    (up to argmax tie-breaking).  Structurally the same log-space
    segment-reduction program as :func:`allocate_pf`.  Single-device
    only -- the relaxed engine path rejects meshes.
    """
    logits = jnp.where(active, se / tau, _NEG)
    cell_max = segments.segment_max(logits, a, n_cells, fill=_NEG,
                                    differentiable=True)
    w = jnp.exp(logits - cell_max[a])
    w = jnp.where(active, w, 0.0)
    denom = segments.segment_sum(w, a, n_cells, differentiable=True)
    # 1e-15 floor: the VJP squares the denominator (see served_bits)
    share = jnp.where(denom[a] > 0.0, w / jnp.maximum(denom[a], 1e-15), 0.0)
    return n_rb * share


def allocate_pf(active, log_w, a, n_cells, n_rb, ue_axis=None,
                differentiable=False):
    """Weight-proportional split of the grid (log-space for stability).

    Sharded (``ue_axis``): the per-cell weight maximum (the log-space
    stabiliser) and the weight sums reduce over the UE axis with
    ``pmax``/``psum``.  ``differentiable`` selects the plain-scatter
    segment reductions (autodiff-traceable; the relaxed engine path).
    """
    # the idle sentinel: -inf is exact but poisons reverse-mode autodiff
    # (-inf - -inf = nan in the exp's argument; the nan survives the
    # where-mask's zero cotangent), so the differentiable path uses a
    # finite sentinel -- exp(-1e30 - m) underflows to the same 0.0
    # forward, with a clean zero gradient
    neg = _NEG if differentiable else -jnp.inf
    log_w = jnp.where(active, log_w, neg)
    # segment reductions: unbatched these ARE the .at[a].max/.at[a].add
    # scatters (bit-exact); under vmap their custom rule avoids the slow
    # rank-2 batched scatter (repro.mac.segments)
    cell_max = segments.segment_max(log_w, a, n_cells, fill=neg,
                                    differentiable=differentiable)
    if ue_axis is not None:
        cell_max = jax.lax.pmax(cell_max, ue_axis)
    w = jnp.exp(log_w - cell_max[a])                   # in (0, 1], 0 if idle
    w = jnp.where(active, w, 0.0)
    denom = segments.segment_sum(w, a, n_cells,
                                 differentiable=differentiable)
    if differentiable:
        # the VJP squares the denominator; keep the square normal-range
        return n_rb * jnp.where(denom[a] > 0.0,
                                w / jnp.maximum(denom[a], 1e-15), 0.0)
    if ue_axis is not None:
        denom = jax.lax.psum(denom, ue_axis)
    share = jnp.where(denom[a] > 0.0, w / jnp.maximum(denom[a], 1e-30), 0.0)
    return n_rb * share


def allocate(policy, active, cqi, a, n_cells, n_rb, cursor, log_w,
             ue_axis=None, differentiable=False):
    """Dispatch to a policy; single entry point for graph node and engine.

    ``log_w`` carries the PF weights (stationary from the single-shot
    graph, EWMA-temporal from the episode engine); the other policies
    ignore it.  ``ue_axis`` names the mesh axes the UE dimension is
    sharded over inside ``shard_map`` (None = single device).
    ``differentiable`` routes the segment reductions around their
    ``custom_vmap`` wrapper (no autodiff rule) -- set by the engine's
    relaxed path, a trace-time switch with a bitwise-identical primal.
    """
    if policy == "rr":
        return allocate_rr(active, a, n_cells, n_rb, cursor, ue_axis,
                           differentiable)
    if policy == "max_cqi":
        return allocate_max_cqi(active, cqi, a, n_cells, n_rb, ue_axis)
    if policy == "pf":
        return allocate_pf(active, log_w, a, n_cells, n_rb, ue_axis,
                           differentiable)
    raise ValueError(
        f"unknown scheduler policy {policy!r}; choose from "
        f"{SCHEDULER_POLICIES}")


def pf_log_weights_stationary(se, fairness_p):
    """log(se**-p): the alpha-fair stationary weights (legacy allocation)."""
    return -fairness_p * jnp.log(jnp.maximum(se, 1e-12))


def pf_log_weights_ewma(rate, avg, fairness_p):
    """log(rate / avg**alpha): the temporal PF metric over EWMA throughput."""
    alpha = jnp.minimum((1.0 + fairness_p) / jnp.maximum(1.0 - fairness_p,
                                                         1e-6), _ALPHA_MAX)
    return (jnp.log(jnp.maximum(rate, 1e-12))
            - alpha * jnp.log(jnp.maximum(avg, 1e-3)))


def served_bits(alloc, se, backlog, rb_bw_hz, tti_s, floor=1e-30):
    """Bits actually drained per (UE, subband) in one TTI.

    Capacity of the grant, capped by the UE's total backlog (a UE cannot
    transmit bits it does not have); the cap scales every subband of the
    grant uniformly.

    ``floor`` guards the backlog/grant ratio.  The 1e-30 default is
    forward-exact; the relaxed engine path raises it to 1e-6 bits because
    reverse-mode forms ``tot**2`` in the division's VJP and a soft-SE
    grant total of ~1e-25 bits underflows that square to 0.0 -> nan.  At
    1e-6 the square stays normal; grants below a millionth of a bit are
    physically nothing, so the relaxed forward is unchanged to f32.
    """
    cap = alloc * rb_bw_hz * se * tti_s                # (n_ue, K) bits
    tot = cap.sum(axis=-1)
    scale = jnp.where(tot > 0.0,
                      jnp.minimum(backlog / jnp.maximum(tot, floor), 1.0),
                      0.0)
    return cap * scale[:, None]
