"""Segment reductions that lower well under ``vmap``.

The schedulers and the telemetry reducers aggregate per-UE rows into
per-cell bins with scatter ops (``zeros.at[a].add(w)``,
``full.at[a].max(log_w)``).  Unbatched, XLA lowers those to a single
1-D scatter -- cheap.  Under ``vmap`` with a *batched* index vector
(every episode of a batch owns its own attachment ``a``), the batching
rule turns them into a rank-2 scatter over (batch, segment) coordinate
tuples, which lowers ~10x slower than the unbatched op -- the measured
remaining cost of batched action steps (PR 5's diagnosis, ROADMAP).

These helpers keep the *exact* unbatched op as the primal (the engine's
bit-exactness claims ride on it -- the sharded 1e-5 gate, the telemetry
structural no-op) and attach a ``jax.custom_batching.custom_vmap`` rule
that flattens the batch axis into the segment ids:

    ids[b, i] = seg[b, i] + n_seg * b

one flat 1-D scatter over ``batch * n_seg`` bins instead of a rank-2
scatter -- the same lowering the unbatched op gets.  Within one batch
element the updates keep their row order, so per-element results match
the unbatched scatter bitwise (asserted in tests/test_twin.py).

``n_seg`` (and the ``fill`` value for :func:`segment_max`) are
trace-time constants; the decorated callables are cached per value so
repeated traces reuse one ``custom_vmap`` object.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap


def _broadcast_unbatched(axis_size, in_batched, *args):
    """Give every argument a leading batch axis of ``axis_size``."""
    out = []
    for batched, x in zip(in_batched, args):
        out.append(x if batched
                   else jnp.broadcast_to(x[None], (axis_size,) + x.shape))
    return out


def _flat_ids(seg, n_seg):
    """Fold the batch coordinate into the segment ids: one 1-D id space."""
    b = jnp.arange(seg.shape[0], dtype=seg.dtype)[:, None]
    return (seg + n_seg * b).reshape(-1)


@lru_cache(maxsize=None)
def _segment_sum_fn(n_seg: int):
    @custom_vmap
    def seg_sum(data, seg):
        # THE primal: exactly the scatter-add the callers used to inline.
        shape = (n_seg,) + data.shape[1:]
        return jnp.zeros(shape, data.dtype).at[seg].add(data)

    @seg_sum.def_vmap
    def seg_sum_vmap(axis_size, in_batched, data, seg):
        data, seg = _broadcast_unbatched(axis_size, in_batched, data, seg)
        b, n = data.shape[:2]
        flat = data.reshape((b * n,) + data.shape[2:])
        out = jnp.zeros((b * n_seg,) + flat.shape[1:], flat.dtype)
        out = out.at[_flat_ids(seg, n_seg)].add(flat)
        return out.reshape((b, n_seg) + flat.shape[1:]), True

    return seg_sum


@lru_cache(maxsize=None)
def _segment_max_fn(n_seg: int, fill: float):
    @custom_vmap
    def seg_max(data, seg):
        shape = (n_seg,) + data.shape[1:]
        return jnp.full(shape, fill, data.dtype).at[seg].max(data)

    @seg_max.def_vmap
    def seg_max_vmap(axis_size, in_batched, data, seg):
        data, seg = _broadcast_unbatched(axis_size, in_batched, data, seg)
        b, n = data.shape[:2]
        flat = data.reshape((b * n,) + data.shape[2:])
        out = jnp.full((b * n_seg,) + flat.shape[1:], fill, flat.dtype)
        out = out.at[_flat_ids(seg, n_seg)].max(flat)
        return out.reshape((b, n_seg) + flat.shape[1:]), True

    return seg_max


def segment_sum(data, seg, n_seg: int, *, differentiable: bool = False):
    """``out[j] = sum_{i: seg[i] == j} data[i]`` over ``data``'s axis 0.

    ``data`` is (n, ...), ``seg`` (n,) int; returns (n_seg, ...).
    Unbatched this IS ``zeros.at[seg].add(data)`` (bit-exact); under
    ``vmap`` the custom rule scatters into a flattened (batch * n_seg)
    id space instead of a rank-2 scatter.

    ``differentiable=True`` skips the ``custom_vmap`` wrapper and issues
    the plain scatter directly: ``custom_vmap`` carries no JVP/transpose
    rule, so any autodiff trace through the wrapped op fails to
    linearize.  The primal is the identical scatter either way (bitwise
    equal results); only the vmap lowering differs -- callers on the
    differentiable-CRRM path (``RelaxConfig``) trade the batched-scatter
    optimisation for a gradient.
    """
    if differentiable:
        shape = (int(n_seg),) + data.shape[1:]
        return jnp.zeros(shape, data.dtype).at[seg].add(data)
    return _segment_sum_fn(int(n_seg))(data, seg)


def segment_max(data, seg, n_seg: int, fill=-jnp.inf, *,
                differentiable: bool = False):
    """``out[j] = max(fill, max_{i: seg[i] == j} data[i])`` over axis 0.

    Same contract as :func:`segment_sum` with a max combiner; ``fill``
    seeds empty segments (trace-time constant).  ``differentiable=True``
    as in :func:`segment_sum` (scatter-max has an autodiff rule; the
    ``custom_vmap`` wrapper does not).
    """
    if differentiable:
        shape = (int(n_seg),) + data.shape[1:]
        return jnp.full(shape, float(fill), data.dtype).at[seg].max(data)
    return _segment_max_fn(int(n_seg), float(fill))(data, seg)
