"""MAC layer: traffic sources, resource-block scheduling, TTI episode engine.

The paper's CRRM stops at a single-shot fairness-weighted throughput split;
this package adds the time dimension: offered load (``traffic``), per-cell
resource-block allocation (``scheduler``) and a ``lax.scan``-compiled
multi-TTI driver (``engine``) so a whole episode runs as one compiled
program.  The engine also carries the link-adaptation state machines --
frequency-selective per-RB CQI (``n_rb_subbands``), stop-and-wait HARQ
with soft combining (``harq_bler``/``harq_max_retx``) and A3 handover
with hysteresis + time-to-trigger (``ho_enabled``) -- see DESIGN.md
§Link-adaptation.  Everything is pure ``jnp`` so it composes with the
smart-update graph (single-shot nodes in ``core.blocks``) and with
``jax.lax.scan`` (the episode engine) alike.
"""
from repro.mac import scheduler, traffic  # noqa: F401

# NOTE: repro.mac.engine is imported lazily (by repro.core.crrm) rather than
# here: it depends on repro.core.blocks, which itself uses the pure policy
# functions above -- eager import would create a cycle.

