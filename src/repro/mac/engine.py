"""The scan-compiled TTI engine: a whole episode as ONE compiled program.

The smart-update graph is built for sparse, event-driven mutation (move a
few UEs, re-query).  Time-stepped MAC simulation is the opposite regime:
*every* TTI touches *every* UE's buffer, so per-TTI Python dispatch over the
node graph would dominate.  This module re-expresses one TTI as a pure
function of a small carry

    (positions, backlog_bits, pf_avg_rate, rr_cursor)

and rolls N TTIs with ``jax.lax.scan``: one trace, one XLA program, zero
per-TTI Python (DESIGN.md §TTI-engine).  A 1000-UE x 1000-TTI episode is a
single device launch.

Two channel regimes:

* static (no mobility, no per-TTI fading): the radio chain (se, cqi, a) is
  read once from the graph's cached nodes and passed in -- the scan body
  is MAC-only math;
* dynamic (``mobility_step_m`` set and/or ``per_tti_fading``): the radio
  chain is recomputed inside the scan from the same jitted block helpers
  the graph nodes use, so both paths share one implementation.

All mutable simulator state (positions, powers, fading, radio outputs)
enters the compiled episode as *arguments*, never as baked-in constants, so
mutating the graph between episodes behaves correctly.  After the episode
the final carry is written back into the graph roots so subsequent
single-shot queries (and further episodes) continue from the episode's end
state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import blocks
from repro.mac import scheduler as mac_sched
from repro.mac.traffic import make_traffic
from repro.sim import fading as fading_mod
from repro.sim import mobility


def build_episode(sim, n_tti: int, mobility_step_m=None,
                  per_tti_fading: bool = False):
    """Trace an episode runner for ``sim``'s topology and MAC parameters.

    Returns a jitted function

        ``fn(carry0, radio_in) -> (carry, tput)``

    with ``carry = (U, backlog, pf_avg, cursor, key)`` and ``radio_in =
    (se, cqi, a, C, P, bore, fad)``; ``tput`` is the (n_tti, n_ues) per-TTI
    served throughput in bits/s.  The traced function is cached on the
    simulator keyed by ``(n_tti, mobility_step_m, per_tti_fading)`` so
    repeat episodes reuse the compilation.
    """
    p = sim.params
    cache_key = (n_tti, mobility_step_m, per_tti_fading)
    cache = sim.__dict__.setdefault("_episode_cache", {})
    if cache_key in cache:
        return cache[cache_key]

    n_ues, n_cells = sim.n_ues, sim.n_cells
    n_rb, tti_s, beta = p.n_rb, p.tti_s, p.pf_ewma
    rb_bw = p.subband_bandwidth_Hz / p.n_rb
    policy, bler = p.scheduler_policy, p.harq_bler
    noise_w = p.subband_noise_W
    gain_full = sim.G._full          # jitted closure over pathloss + antenna
    attach_on_mean = hasattr(sim, "R_mean")
    _, traffic_step = make_traffic(p.traffic_model, n_ues, tti_s,
                                   **p.traffic_params)

    def unfaded_gain(U, C, bore):
        d2d, d3d, az = blocks._geometry(U, C)
        return gain_full(U, C, d2d, d3d, az, bore,
                         jnp.ones((n_ues, n_cells), jnp.float32))

    def sinr_chain(R, a):
        w = blocks._wanted(R, a)
        u = blocks._interference(R, w)
        gamma = w / (noise_w + u)
        cqi = blocks._cqi(gamma)
        se = blocks._se(blocks._mcs(cqi), cqi)
        return se, cqi, a

    def radio(U, C, P, bore, fad):
        """Pure (se, cqi, a), mirroring the graph's D..SE chain."""
        G0 = unfaded_gain(U, C, bore)           # pathgain * antenna
        R = blocks._rsrp(G0 * fad, P)
        a = (blocks._attach(blocks._rsrp(G0, P)) if attach_on_mean
             else blocks._attach(R))
        return sinr_chain(R, a)

    def allocate(se, cqi, a, buf, avg, cursor):
        active = (buf[:, None] > 0.0) & (se > 0.0)
        log_w = mac_sched.pf_log_weights_ewma(rb_bw * se, avg[:, None],
                                              p.fairness_p)
        return mac_sched.allocate(policy, active, cqi, a, n_cells, n_rb,
                                  cursor, log_w)

    @jax.jit
    def episode(carry0, radio_in):
        se0, cqi0, a0, C, P, bore, fad0 = radio_in
        if per_tti_fading and mobility_step_m is None:
            # static geometry: one unfaded gain/attachment pass, hoisted
            # out of the scan; only the fading factor varies per TTI.
            G_static = unfaded_gain(carry0[0], C, bore)
            a_static = (blocks._attach(blocks._rsrp(G_static, P))
                        if attach_on_mean else None)

        def step(carry, t):
            U, buf, avg, cursor, key = carry
            k_mob, k_fad, k_tr, k_harq = (jax.random.fold_in(key, 4 * t + i)
                                          for i in range(4))
            if mobility_step_m is not None:
                idx = jnp.arange(n_ues)
                U = U.at[idx].set(mobility.random_walk(
                    k_mob, U, idx, mobility_step_m, p.extent_m))
                fad = (fading_mod.rayleigh_power(k_fad, (n_ues, n_cells))
                       if per_tti_fading else fad0)
                se, cqi, a = radio(U, C, P, bore, fad)
            elif per_tti_fading:
                fad = fading_mod.rayleigh_power(k_fad, (n_ues, n_cells))
                R = blocks._rsrp(G_static * fad, P)
                a = a_static if attach_on_mean else blocks._attach(R)
                se, cqi, a = sinr_chain(R, a)
            else:
                se, cqi, a = se0, cqi0, a0
            buf = buf + traffic_step(k_tr, t)
            alloc = allocate(se, cqi, a, buf, avg, cursor)
            bits = mac_sched.served_bits(alloc, se, buf, rb_bw, tti_s).sum(1)
            if bler > 0.0:   # HARQ-lite: lost blocks stay queued -> retx
                bits = bits * jax.random.bernoulli(
                    k_harq, 1.0 - bler, (n_ues,)).astype(bits.dtype)
            # clamp: served_bits <= backlog only up to float rounding
            buf = jnp.maximum(buf - bits, 0.0)
            tput = bits / tti_s
            avg = (1.0 - beta) * avg + beta * tput
            return (U, buf, avg, cursor + n_rb, key), tput

        return jax.lax.scan(step, carry0, jnp.arange(n_tti))

    cache[cache_key] = episode
    return episode


def run_episode(sim, n_tti: int, key=None, mobility_step_m=None,
                per_tti_fading: bool = False, sync_state: bool = True):
    """Run ``n_tti`` TTIs; returns (n_tti, n_ues) served throughput (bits/s).

    The PF average-rate state is seeded from the single-shot graph's served
    throughput (the stationary alpha-fair point), so a full-buffer PF
    episode starts -- and, with a static channel, stays -- at the legacy
    ``ThroughputNode`` fixed point.
    """
    if key is None:
        key = jax.random.fold_in(jax.random.PRNGKey(sim.params.seed),
                                 0x6d6163)   # "mac"
    episode = build_episode(sim, n_tti, mobility_step_m, per_tti_fading)
    avg0 = getattr(sim, "_pf_avg", None)
    if avg0 is None:
        avg0 = sim.get_served_throughputs()
    carry0 = (sim.U._data, sim.buffer._data, avg0,
              jnp.int32(sim.sched.cursor), key)
    radio_in = (sim.get_spectral_efficiency(), sim.get_CQI(),
                sim.get_attachment(), sim.C._data, sim.P._data,
                sim.boresight._data, sim.fading._data)
    (U, buf, avg, cursor, _), tput = episode(carry0, radio_in)
    if sync_state:
        if mobility_step_m is not None:
            sim.set_UE_positions(U)
        sim.buffer.set(buf)
        sim._pf_avg = avg
        sim.sched.cursor = int(cursor)
    return tput
