"""The scan-compiled TTI engine: a whole episode as ONE compiled program.

The smart-update graph is built for sparse, event-driven mutation (move a
few UEs, re-query).  Time-stepped MAC simulation is the opposite regime:
*every* TTI touches *every* UE's buffer, so per-TTI Python dispatch over the
node graph would dominate.  This module re-expresses one TTI as a pure
function of a small carry

    (positions, backlog_bits, pf_avg_rate, rr_cursor, key,
     harq_bits, harq_retx, serving_cell, ttt)

and rolls N TTIs with ``jax.lax.scan``: one trace, one XLA program, zero
per-TTI Python (DESIGN.md §TTI-engine).  A 1000-UE x 1000-TTI episode is a
single device launch.

Three orthogonal feature axes, each a trace-time (Python) switch so the
disabled configuration compiles to exactly the legacy program:

* frequency-selective link adaptation (``n_rb_subbands > 1``): the fading
  factor is a per-RB block-fading tensor pooled to CQI-subband resolution,
  so SE/CQI/alloc carry a (n_ues, n_freq) frequency axis and the schedulers
  pick *which* RBs each UE gets.  ``n_rb_subbands=1`` is the wideband path.
* stop-and-wait HARQ (``harq_bler > 0``): per-UE process state (pending TB
  bits, retx count) rides in the carry; failed TBs retransmit with a
  soft-combining SINR gain per attempt until ``harq_max_retx`` is exhausted.
  ``harq_bler=0`` compiles the HARQ-free fast path (bit-exact legacy).
* A3 handover (``ho_enabled``): the serving-cell vector ``a`` is carried
  state, updated when a neighbour beats the serving cell by
  ``ho_hysteresis_db`` for ``ho_ttt_tti`` consecutive TTIs.  Disabled, the
  serving cell is the instantaneous argmax (legacy).

Two channel regimes:

* static (no mobility, no per-TTI fading): the radio chain (se, cqi, a) is
  read once from the graph's cached nodes and passed in -- the scan body
  is MAC-only math;
* dynamic (``mobility_step_m`` set and/or ``per_tti_fading``): the radio
  chain is recomputed inside the scan from the same jitted block helpers
  the graph nodes use, so both paths share one implementation.

All mutable simulator state (positions, powers, fading, radio outputs)
enters the compiled episode as *arguments*, never as baked-in constants, so
mutating the graph between episodes behaves correctly.  After the episode
the final carry is written back into the graph roots so subsequent
single-shot queries (and further episodes) continue from the episode's end
state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import blocks
from repro.mac import scheduler as mac_sched
from repro.sim import fading as fading_mod
from repro.sim import mobility


def harq_fail_prob(bler, comb_gain_db, retx):
    """Conditional failure probability of HARQ attempt number ``retx``.

    ``retx`` prior (failed) copies are soft-combined, boosting effective
    SINR by ``comb_gain_db`` dB each; in the Rayleigh outage regime
    P(fail) ~ theta/SNR, so the conditional BLER divides by the linear gain
    per retransmission: ``bler / 10^(retx * gain_db / 10)``.  Monotone
    non-increasing in ``retx`` (tested in tests/test_mac_engine.py).
    """
    gain = 10.0 ** (comb_gain_db / 10.0)
    return jnp.clip(bler * gain ** (-retx.astype(jnp.float32)), 0.0, 1.0)


def a3_handover(a, ttt, rsrp_wb, hyst_db, ttt_tti):
    """One TTI of the A3 trigger: (serving, time-to-trigger) -> updated.

    Event A3 enters when the best neighbour's wideband RSRP exceeds the
    serving cell's by ``hyst_db``; the counter must stay entered for
    ``ttt_tti`` consecutive TTIs before the UE hands over to that
    neighbour.  Leaving the condition resets the counter (3GPP 38.331
    semantics, collapsed to one measurement per TTI).
    """
    serving = jnp.take_along_axis(rsrp_wb, a[:, None], axis=1)[:, 0]
    best = jnp.argmax(rsrp_wb, axis=1).astype(a.dtype)
    best_val = rsrp_wb.max(axis=1)
    hyst = 10.0 ** (hyst_db / 10.0)
    entered = (best_val > serving * hyst) & (best != a)
    ttt = jnp.where(entered, ttt + 1, 0)
    fire = ttt >= ttt_tti
    a = jnp.where(fire, best, a)
    ttt = jnp.where(fire, 0, ttt)
    return a, ttt


def build_episode(sim, n_tti: int, mobility_step_m=None,
                  per_tti_fading: bool = False, use_harq=None):
    """Trace an episode runner for ``sim``'s topology and MAC parameters.

    Returns a jitted function

        ``fn(carry0, radio_in) -> (carry, tput)``

    with ``carry = (U, backlog, pf_avg, cursor, key, harq_bits, harq_retx,
    a_serving, ttt)`` and ``radio_in = (se, cqi, a, C, P, bore, fad)``;
    ``tput`` is the (n_tti, n_ues) per-TTI *delivered* throughput in
    bits/s.  ``use_harq`` forces the HARQ state machine on/off regardless
    of ``harq_bler`` (None = auto: on iff ``harq_bler > 0``); forcing it on
    at ``harq_bler=0`` is the equivalence-testing hook -- the machine must
    then reproduce the fast path bit-exactly.  The traced function is
    cached on the simulator keyed by ``(n_tti, mobility_step_m,
    per_tti_fading, use_harq)`` so repeat episodes reuse the compilation.
    """
    p = sim.params
    cache_key = (n_tti, mobility_step_m, per_tti_fading, use_harq)
    cache = sim.__dict__.setdefault("_episode_cache", {})
    if cache_key in cache:
        return cache[cache_key]

    n_ues, n_cells = sim.n_ues, sim.n_cells
    tti_s, beta = p.tti_s, p.pf_ewma
    n_freq, rb_chunk = p.n_freq, p.rb_per_chunk
    rb_bw = p.subband_bandwidth_Hz / p.n_rb     # physical RB bandwidth
    policy, bler = p.scheduler_policy, p.harq_bler
    harq_on = bler > 0.0 if use_harq is None else bool(use_harq)
    max_retx, comb_db = p.harq_max_retx, p.harq_comb_gain_db
    ho_on = p.ho_enabled
    hyst_db, ttt_tti = p.ho_hysteresis_db, p.ho_ttt_tti
    per_rb = p.n_rb_subbands > 1
    noise_w = p.chunk_noise_W
    gain_full = sim.G._full          # jitted closure over pathloss + antenna
    attach_on_mean = hasattr(sim, "R_mean")
    traffic_step = sim._traffic_step   # the closure CRRM already built

    def unfaded_gain(U, C, bore):
        d2d, d3d, az = blocks._geometry(U, C)
        return gain_full(U, C, d2d, d3d, az, bore,
                         jnp.ones((n_ues, n_cells), jnp.float32))

    def draw_fading(key):
        """Fresh per-TTI fading at the engine's frequency resolution."""
        if per_rb:
            return fading_mod.subband_rayleigh_power(
                key, n_ues, n_cells, p.n_subbands * p.n_rb, p.coherence_rb,
                n_freq)
        return fading_mod.rayleigh_power(key, (n_ues, n_cells))

    def faded_rsrp(G0, P, fad):
        """RSRP from unfaded gain: broadcasts wideband or per-RB fading."""
        G = G0[..., None] * fad if fad.ndim == 3 else G0 * fad
        return blocks._rsrp(G, P)

    def sinr_chain(R, a):
        """(se, cqi, a) for serving assignment ``a``."""
        w = blocks._wanted(R, a)
        u = blocks._interference(R, w)
        gamma = w / (noise_w + u)
        cqi = blocks._cqi(gamma)
        se = blocks._se(blocks._mcs(cqi), cqi)
        return se, cqi, a

    def allocate(se, cqi, a, buf, avg, cursor, harq_pending):
        demand = (buf[:, None] > 0.0) | harq_pending[:, None]
        active = demand & (se > 0.0)
        log_w = mac_sched.pf_log_weights_ewma(rb_bw * se, avg[:, None],
                                              p.fairness_p)
        return mac_sched.allocate(policy, active, cqi, a, n_cells, rb_chunk,
                                  cursor, log_w)

    def harq_step(k_harq, tb_new, hbits, hretx, granted):
        """One TTI of every UE's stop-and-wait process.

        Pending UEs retransmit their stored TB (no new buffer drain) --
        but only when the scheduler actually granted them RBs this TTI
        (``granted``); an ungranted pending TB waits, state unchanged.
        Fresh TBs enter the machine on failure and drop after
        ``max_retx`` retransmissions.  The retx TB is delivered at its
        stored size (real HARQ retransmits the same TB; the grant-size
        mismatch is absorbed by the soft-combining abstraction).
        """
        pending = hbits > 0.0
        tb = jnp.where(pending, hbits, tb_new)
        attempting = granted & (tb > 0.0)
        attempt = jnp.where(pending, hretx, 0)
        p_fail = harq_fail_prob(bler, comb_db, attempt)
        u = jax.random.uniform(k_harq, (n_ues,))
        ok = (u >= p_fail) & attempting
        fail = ~ok & attempting
        n_fail = attempt + 1
        keep = (fail & (n_fail <= max_retx)) | (pending & ~granted)
        delivered = jnp.where(ok, tb, 0.0)
        hbits = jnp.where(keep, tb, 0.0)
        hretx = jnp.where(keep, jnp.where(fail, n_fail, hretx), 0)
        return delivered, pending, hbits, hretx

    @jax.jit
    def episode(carry0, radio_in):
        se0, cqi0, a0, C, P, bore, fad0 = radio_in
        static_geom = mobility_step_m is None
        if static_geom and (per_tti_fading or ho_on):
            # static geometry: one unfaded gain/attachment pass, hoisted
            # out of the scan; only the fading factor varies per TTI.
            G_static = unfaded_gain(carry0[0], C, bore)
            R_mean_static = blocks._rsrp(G_static, P)
            a_static = (blocks._attach(R_mean_static)
                        if attach_on_mean else None)
            R_static_faded = faded_rsrp(G_static, P, fad0)
            # A3 measures long-term RSRP iff association does (same
            # convention as the dynamic paths' R_meas)
            meas_wb_static = (R_mean_static if attach_on_mean
                              else R_static_faded).sum(axis=-1)
            if ho_on:
                # static channel + evolving serving cell: tabulate the SINR
                # chain for EVERY candidate cell once, outside the scan --
                # per TTI the chain is then two gathers on (n_ue, n_freq)
                # instead of an (n_ue, n_cell, n_freq) reduction.
                total_static = R_static_faded.sum(axis=1)
                gamma_all = R_static_faded / (
                    noise_w + (total_static[:, None, :] - R_static_faded))
                cqi_all = blocks._cqi(gamma_all)
                se_all = blocks._se(blocks._mcs(cqi_all), cqi_all)

        def step(carry, t):
            U, buf, avg, cursor, key, hbits, hretx, a_srv, ttt = carry
            k_mob, k_fad, k_tr, k_harq = (jax.random.fold_in(key, 4 * t + i)
                                          for i in range(4))
            # -- channel: (R, R_meas) per TTI, or the hoisted constants ----
            if mobility_step_m is not None:
                idx = jnp.arange(n_ues)
                U = U.at[idx].set(mobility.random_walk(
                    k_mob, U, idx, mobility_step_m, p.extent_m))
                G0 = unfaded_gain(U, C, bore)
                fad = draw_fading(k_fad) if per_tti_fading else fad0
                R = faded_rsrp(G0, P, fad)
                R_meas = blocks._rsrp(G0, P) if attach_on_mean else R
                a_inst = blocks._attach(R_meas)
            elif per_tti_fading:
                fad = draw_fading(k_fad)
                R = faded_rsrp(G_static, P, fad)
                R_meas = R_mean_static if attach_on_mean else R
                a_inst = a_static if attach_on_mean else blocks._attach(R)
            else:
                R = R_meas = a_inst = None   # fully static radio chain

            # -- serving cell: A3 carried state, or instantaneous argmax --
            if ho_on:
                meas_wb = (R_meas.sum(axis=-1) if R_meas is not None
                           else meas_wb_static)
                a_srv, ttt = a3_handover(a_srv, ttt, meas_wb,
                                         hyst_db, ttt_tti)
                a_use = a_srv
                if R is not None:
                    se, cqi, _ = sinr_chain(R, a_use)
                else:
                    # static channel, evolving attachment: gather from the
                    # hoisted all-cells SINR-chain tables
                    sel = a_use[:, None, None]
                    se = jnp.take_along_axis(se_all, sel, axis=1)[:, 0]
                    cqi = jnp.take_along_axis(cqi_all, sel, axis=1)[:, 0]
            elif R is not None:
                se, cqi, a_use = sinr_chain(R, a_inst)
            else:
                se, cqi, a_use = se0, cqi0, a0

            # -- MAC: traffic -> grant -> HARQ -> drain --------------------
            buf = buf + traffic_step(k_tr, t)
            harq_pending = (hbits > 0.0) if harq_on else \
                jnp.zeros((n_ues,), bool)
            alloc = allocate(se, cqi, a_use, buf, avg, cursor, harq_pending)
            drainable = jnp.where(harq_pending, 0.0, buf)
            tb_new = mac_sched.served_bits(alloc, se, drainable, rb_bw,
                                           tti_s).sum(1)
            if harq_on:
                bits, _, hbits, hretx = harq_step(
                    k_harq, tb_new, hbits, hretx, alloc.sum(axis=1) > 0.0)
            elif bler > 0.0:   # HARQ-lite: lost blocks stay queued -> retx
                bits = tb_new * jax.random.bernoulli(
                    k_harq, 1.0 - bler, (n_ues,)).astype(tb_new.dtype)
            else:
                bits = tb_new
            # clamp: served_bits <= backlog only up to float rounding
            if harq_on:
                buf = jnp.maximum(buf - tb_new, 0.0)  # drain on first tx
            else:
                buf = jnp.maximum(buf - bits, 0.0)
            tput = bits / tti_s
            avg = (1.0 - beta) * avg + beta * tput
            return (U, buf, avg, cursor + rb_chunk, key, hbits, hretx,
                    a_srv, ttt), tput

        return jax.lax.scan(step, carry0, jnp.arange(n_tti))

    cache[cache_key] = episode
    return episode


def run_episode(sim, n_tti: int, key=None, mobility_step_m=None,
                per_tti_fading: bool = False, sync_state: bool = True,
                use_harq=None):
    """Run ``n_tti`` TTIs; returns (n_tti, n_ues) delivered throughput
    (bits/s).

    The PF average-rate state is seeded from the single-shot graph's served
    throughput (the stationary alpha-fair point), so a full-buffer PF
    episode starts -- and, with a static channel, stays -- at the legacy
    ``ThroughputNode`` fixed point.  HARQ process state and the A3 serving
    cell / time-to-trigger counters persist across episodes on the
    simulator (``sim._harq_bits``/``_harq_retx``/``_ho_serving``/
    ``_ho_ttt``) when ``sync_state`` is set.
    """
    if key is None:
        key = jax.random.fold_in(jax.random.PRNGKey(sim.params.seed),
                                 0x6d6163)   # "mac"
    episode = build_episode(sim, n_tti, mobility_step_m, per_tti_fading,
                            use_harq)
    avg0 = getattr(sim, "_pf_avg", None)
    if avg0 is None:
        avg0 = sim.get_served_throughputs()
    n = sim.n_ues
    hbits0 = getattr(sim, "_harq_bits", None)
    if hbits0 is None:
        hbits0 = jnp.zeros((n,), jnp.float32)
    hretx0 = getattr(sim, "_harq_retx", None)
    if hretx0 is None:
        hretx0 = jnp.zeros((n,), jnp.int32)
    a0 = getattr(sim, "_ho_serving", None)
    if a0 is None:
        a0 = sim.get_attachment()
    ttt0 = getattr(sim, "_ho_ttt", None)
    if ttt0 is None:
        ttt0 = jnp.zeros((n,), jnp.int32)
    carry0 = (sim.U._data, sim.buffer._data, avg0,
              jnp.int32(sim.sched.cursor), key,
              jnp.asarray(hbits0, jnp.float32),
              jnp.asarray(hretx0, jnp.int32),
              jnp.asarray(a0, jnp.int32), jnp.asarray(ttt0, jnp.int32))
    radio_in = (sim.get_spectral_efficiency(), sim.get_CQI(),
                sim.get_attachment(), sim.C._data, sim.P._data,
                sim.boresight._data, sim.fading._data)
    (U, buf, avg, cursor, _, hbits, hretx, a_srv, ttt), tput = episode(
        carry0, radio_in)
    if sync_state:
        if mobility_step_m is not None:
            sim.set_UE_positions(U)
        sim.buffer.set(buf)
        sim._pf_avg = avg
        sim.sched.cursor = int(cursor)
        sim._harq_bits, sim._harq_retx = hbits, hretx
        if sim.params.ho_enabled:
            sim._ho_serving, sim._ho_ttt = a_srv, ttt
    return tput
