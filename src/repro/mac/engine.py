"""The scan-compiled TTI engine: a whole episode as ONE compiled program.

The smart-update graph is built for sparse, event-driven mutation (move a
few UEs, re-query).  Time-stepped MAC simulation is the opposite regime:
*every* TTI touches *every* UE's buffer, so per-TTI Python dispatch over the
node graph would dominate.  This module re-expresses one TTI as a pure
function of an explicit :class:`EpisodeState` pytree

    (positions, backlog_bits, pf_avg_rate, rr_cursor, key,
     harq_bits, harq_retx, serving_cell, ttt, t)

and rolls N TTIs with ``jax.lax.scan``: one trace, one XLA program, zero
per-TTI Python (DESIGN.md §TTI-engine, §Env-API).  A 1000-UE x 1000-TTI
episode is a single device launch.

The radio *math* inside the scan is not the engine's: every D/G/RSRP/SINR/
CQI/SE evaluation delegates to the pure chain of ``repro.sim.radio``
(DESIGN.md §Radio-fns), the same functions the smart-update graph nodes
wrap -- one implementation, bit-exact across graph, engine and env.

The episode API is pure-functional (DESIGN.md §Env-API):

* :class:`EpisodeState` -- everything the scan carry needs, as a pytree.
  ``CRRM.init_episode_state(key)`` gathers it from the graph;
* :class:`EpisodeStatic` -- the per-episode radio inputs (cached SE/CQI/
  attachment plus the C/P/boresight/fading roots).  ``CRRM.episode_static()``
  reads them off the graph;
* :func:`make_episode_fns` -- builds ``step(static, state, action)`` and
  ``rollout(static, state, n_tti, action)``, both jit- and vmap-compatible:
  batching N episodes over seeds is ``jax.vmap`` over ``state`` (and
  ``action``), and compiles to one program (``src/repro/env``).

``run_episode`` is a thin wrapper: init state -> rollout -> (optionally)
write the final state back into the graph.  The write-back (``sync_state``)
is retained for the paper's mutate/query workflow but is a legacy
convenience: functional callers thread :class:`EpisodeState` explicitly and
never touch simulator attributes.

Three orthogonal feature axes, each a trace-time (Python) switch so the
disabled configuration compiles to exactly the legacy program:

* frequency-selective link adaptation (``n_rb_subbands > 1``): the fading
  factor is a per-RB block-fading tensor pooled to CQI-subband resolution,
  so SE/CQI/alloc carry a (n_ues, n_freq) frequency axis and the schedulers
  pick *which* RBs each UE gets.  ``n_rb_subbands=1`` is the wideband path.
  ``cqi_report="wideband"`` decouples *reporting* from fading resolution:
  the channel stays selective but CQI/MCS collapse to one report per power
  subband (radio.pool_report).
* stop-and-wait HARQ (``harq_bler > 0``): per-UE process state (pending TB
  bits, retx count) rides in the carry; failed TBs retransmit with a
  soft-combining SINR gain per attempt until ``harq_max_retx`` is exhausted.
  ``harq_bler=0`` compiles the HARQ-free fast path (bit-exact legacy).
* A3 handover (``ho_enabled``): the serving-cell vector ``a`` is carried
  state, updated when a neighbour beats the serving cell by
  ``ho_hysteresis_db`` for ``ho_ttt_tti`` consecutive TTIs.  Disabled, the
  serving cell is the instantaneous argmax (legacy).

Channel regimes:

* static (no mobility, no per-TTI fading, no power action): the radio chain
  (se, cqi, a) is read once from ``EpisodeStatic`` -- the scan body is
  MAC-only math;
* dynamic (``mobility_step_m`` set -- explicitly or via
  ``params.mobility_step_m`` (scenario presets with a baked-in mobility
  trajectory), ``per_tti_fading``, or a power ``action``): the radio chain
  is recomputed inside the scan from the pure ``sim.radio`` functions, so
  both paths share one implementation.  A non-None ``action`` is a
  per-episode (n_cells, n_freq) power matrix overriding ``static.P`` -- the
  RL power-control hook.

Mesh sharding (``mesh=``): the rollout runs under ``shard_map`` with the UE
axis of every per-UE tensor sharded over the named mesh axes (cells are
replicated).  The per-UE MAC math is embarrassingly parallel; the only
cross-shard traffic is the scheduler's per-cell reductions
(``mac.scheduler`` with ``ue_axis=``, reusing the mesh helpers and
cross-shard argmax of ``core.distributed``).  Per-UE PRNG draws are taken
from the *global* stream and sliced to the local block, so a sharded
episode matches the single-device rollout (asserted in
tests/test_radio_fns.py and gated in ``benchmarks/BENCH_sharded.json``):
*bitwise* for the integer-exact schedulers (rr, max_cqi) and to 1e-5 for
pf, whose cross-shard ``psum`` reorders a float reduction.  (Under bursty
traffic, pf's ulp-level residues can flip backlog-active masks and the
trajectories then diverge chaotically -- inherent to any reduction
reordering, not a sharding bug; the equivalence suite pins the
non-chaotic regimes.)

All mutable simulator state (positions, powers, fading, radio outputs)
enters the compiled episode as *arguments*, never as baked-in constants, so
mutating the graph between episodes behaves correctly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PSpec

from repro.core.distributed import (_axis_index, _global_best, _pvary,
                                    _shard_map)
from repro.mac import scheduler as mac_sched
from repro.obs.telemetry import Telemetry, tti_telemetry
from repro.sim import deploy, faults as sim_faults, mobility, radio


class EpisodeState(NamedTuple):
    """The full mutable state of a MAC episode, as an explicit pytree.

    Every field is a per-simulation array (no Python state), so the whole
    tuple can ride a ``lax.scan`` carry, be ``jax.vmap``ed over a batch
    axis (N parallel episodes), checkpointed, or handed to an external RL
    loop.  Constructed by ``CRRM.init_episode_state``; advanced by the pure
    ``step``/``rollout`` functions of :func:`make_episode_fns`.

    The two trailing leaves exist only under a birth-death churn process
    (``make_episode_fns(..., churn=ChurnConfig(...))`` -- DESIGN.md
    §Digital-twin-serving) and default to ``None`` otherwise, so legacy
    states keep their treedef (and every positional 10-argument
    construction site stays valid): ``active`` is the capacity-padded
    live-UE mask; ``fad`` the *carried* fading factor, needed because
    newborn UEs redraw their fading rows in-scan (``radio.churn_keys``)
    -- with churn off (or per-TTI fading on) fading stays in
    :class:`EpisodeStatic` exactly as before.  Seed both leaves with
    :func:`seed_churn_state`.

    ``cell_state`` exists only under the in-scan cell fault process
    (``make_episode_fns(..., faults=FaultConfig(...))`` -- DESIGN.md
    §Fault-injection-and-self-healing) and defaults to ``None``
    otherwise, same trace-time-treedef discipline.  It auto-seeds to
    all-UP at the jit boundary (``step``/``rollout`` attach it when the
    engine needs it), so legacy callers never touch it; seed a custom
    initial fault pattern with :func:`seed_fault_state`.
    """

    U: Any           # (n_ues, 3) positions
    backlog: Any     # (n_ues,) queued bits (inf = full buffer)
    pf_avg: Any      # (n_ues,) PF EWMA average delivered rate, bits/s
    rr_cursor: Any   # i32 scalar: round-robin rotation state
    key: Any         # PRNG key; per-TTI streams fold via radio.tti_keys
    harq_bits: Any   # (n_ues,) f32 pending transport-block bits (0 = idle)
    harq_retx: Any   # (n_ues,) i32 retransmission count of the pending TB
    serving: Any     # (n_ues,) i32 serving-cell index (A3 carried state)
    ttt: Any         # (n_ues,) i32 A3 time-to-trigger counters
    t: Any           # i32 scalar: TTI index (drives PRNG folds + traffic)
    active: Any = None   # (n_ues,) bool live-UE mask | None (no churn)
    fad: Any = None      # carried fading factor | None (no churn)
    cell_state: Any = None   # (n_cells,) i32 fault codes | None (no faults)


class EpisodeStatic(NamedTuple):
    """Per-episode radio inputs: everything the step reads but never writes.

    The cached single-shot radio chain (``se``/``cqi``/``a`` -- used
    verbatim in the fully-static regime) plus the graph roots the dynamic
    regimes recompute from.  Read off the graph by ``CRRM.episode_static()``
    or rebuilt purely (per topology draw) by ``CrrmEnv.reset`` via
    ``radio.radio_forward``.
    """

    se: Any          # (n_ues, n_freq) spectral efficiency
    cqi: Any         # (n_ues, n_freq)
    a: Any           # (n_ues,) i32 attachment
    C: Any           # (n_cells, 3) cell positions
    P: Any           # (n_cells, n_freq) tx power
    bore: Any        # (n_cells,) sector boresights
    fad: Any         # (n_ues, n_cells[, n_freq]) fading factor


class EpisodeFns(NamedTuple):
    """The pure episode API for one engine configuration (jit-compiled).

    ``step(static, state, action=None) -> (state, tput)`` advances one TTI;
    ``rollout(static, state, n_tti, action=None) -> (state, tput)`` scans
    ``n_tti`` TTIs (``tput`` stacked to (n_tti, n_ues)).  ``action`` is an
    optional (n_cells, n_freq) power matrix overriding ``static.P`` (a
    trace-time switch: None compiles the legacy program).  Both functions
    are pure and vmap over ``state``/``action`` for batched episodes
    (single-device configurations; a mesh-sharded bundle spans the devices
    instead of vmapping).

    Built with ``telemetry=True`` both functions return one extra value --
    a :class:`repro.obs.telemetry.Telemetry` of per-TTI KPIs (stacked to
    (n_tti, ...) by ``rollout``): ``step -> (state, tput, telem)``,
    ``rollout -> (state, tput, telem)``.  Telemetry rides the scan as an
    *output*, never a carry, and is computed purely from intermediates the
    step already produced, so the trajectory is bit-identical either way.

    ``rollout_donated`` is the same rollout compiled with the *state*
    buffers donated (``jit(..., donate_argnums=)``): at million-UE scale
    the :class:`EpisodeState` carry is gigabytes, and donation lets XLA
    reuse the input buffers for the output state instead of holding both
    alive across the scan.  Same program, same jit cache discipline (the
    CompileCounter no-retrace gate covers it); the one behavioural
    difference is that the passed ``state`` is consumed -- callers that
    re-time the same state across reps (the benches' default) must keep
    using ``rollout``, and chained callers thread the returned state:
    ``state, tput = fns.rollout_donated(static, state, n)``.
    """

    step: Any
    rollout: Any
    rollout_donated: Any = None


def harq_fail_prob(bler, comb_gain_db, retx):
    """Conditional failure probability of HARQ attempt number ``retx``.

    ``retx`` prior (failed) copies are soft-combined, boosting effective
    SINR by ``comb_gain_db`` dB each; in the Rayleigh outage regime
    P(fail) ~ theta/SNR, so the conditional BLER divides by the linear gain
    per retransmission: ``bler / 10^(retx * gain_db / 10)``.  Monotone
    non-increasing in ``retx`` (tested in tests/test_mac_engine.py).
    """
    gain = 10.0 ** (comb_gain_db / 10.0)
    return jnp.clip(bler * gain ** (-retx.astype(jnp.float32)), 0.0, 1.0)


def a3_handover(a, ttt, rsrp_wb, hyst_db, ttt_tti):
    """One TTI of the A3 trigger: (serving, time-to-trigger) -> updated.

    Event A3 enters when the best neighbour's wideband RSRP exceeds the
    serving cell's by ``hyst_db``; the counter must stay entered for
    ``ttt_tti`` consecutive TTIs before the UE hands over to that
    neighbour.  Leaving the condition resets the counter (3GPP 38.331
    semantics, collapsed to one measurement per TTI).
    """
    serving = jnp.take_along_axis(rsrp_wb, a[:, None], axis=1)[:, 0]
    best = jnp.argmax(rsrp_wb, axis=1).astype(a.dtype)
    best_val = rsrp_wb.max(axis=1)
    hyst = 10.0 ** (hyst_db / 10.0)
    entered = (best_val > serving * hyst) & (best != a)
    ttt = jnp.where(entered, ttt + 1, 0)
    fire = ttt >= ttt_tti
    a = jnp.where(fire, best, a)
    ttt = jnp.where(fire, 0, ttt)
    return a, ttt


def stationary_served_tput(params, n_cells: int, se, cqi, a, backlog):
    """Pure twin of the graph's Schedule -> ServedThroughput chain.

    The single-shot served throughput at the stationary alpha-fair point
    -- what ``CRRM.init_episode_state`` seeds the PF EWMA with by querying
    the graph.  This function computes the same numbers from explicit
    arrays, so a topology-resampling env ``reset`` can seed the PF state
    inside jit/vmap without a graph (tested identical in
    tests/test_radio_fns.py).
    """
    p = params
    active = (backlog[:, None] > 0.0) & (se > 0.0)
    log_w = mac_sched.pf_log_weights_stationary(se, p.fairness_p)
    alloc = mac_sched.allocate(p.scheduler_policy, active, cqi, a, n_cells,
                               p.rb_per_chunk, jnp.int32(0), log_w)
    bits = mac_sched.served_bits(alloc, se, backlog,
                                 p.subband_bandwidth_Hz / p.n_rb, p.tti_s)
    return (bits / p.tti_s).sum(axis=1)


def scatter_born(dst, idx, fresh, n_born):
    """Scatter per-newborn *fresh* rows at the padded born-index vector.

    Unlike the idempotent-recompute scatters of the dirtiness convention,
    these write NEW values, so the row-0 padding of ``radio.dirty_indices``
    would corrupt row 0 whenever it is not itself a newborn.  Every padded
    slot is therefore re-aimed at ``idx[0]`` and writes exactly what slot 0
    writes there (``fresh[0]`` when any birth happened; the row's current
    value when none) -- all duplicate writes are identical, so the scatter
    is deterministic, and a zero-birth TTI is a bitwise no-op.
    """
    k = idx.shape[0]
    sel = jnp.arange(k, dtype=jnp.int32) < n_born
    idx = jnp.where(sel, idx, idx[0])
    base = jnp.where(n_born > 0, fresh[0], dst[idx[0]])
    write = jnp.where(sel.reshape((k,) + (1,) * (fresh.ndim - 1)),
                      fresh, base)
    return dst.at[idx].set(write)


def seed_churn_state(state, static, params, *, per_tti_fading: bool = False,
                     active=None) -> EpisodeState:
    """Attach the churn leaves to a legacy :class:`EpisodeState`.

    ``active`` seeds the live-UE mask (default: every capacity slot live;
    the birth-death process then relaxes toward its M/M/inf stationary
    occupancy).  The carried-fading leaf is seeded from ``static.fad``
    exactly when the engine will carry it (Rayleigh on, per-TTI fading
    off) -- the same trace-time rule ``make_episode_fns`` applies, so the
    treedefs agree.
    """
    n = state.U.shape[0]
    if active is None:
        active = jnp.ones((n,), bool)
    fad = (static.fad
           if params.rayleigh_fading and not per_tti_fading else None)
    return state._replace(active=active, fad=fad)


def seed_fault_state(state, n_cells: int = None,
                     cell_state=None) -> EpisodeState:
    """Attach the fault leaf to a legacy :class:`EpisodeState`.

    ``cell_state`` seeds the per-cell fault codes (``sim.faults.UP`` /
    ``SLEEP`` / ``DOWN``); default all-UP.  Only needed for a *custom*
    initial fault pattern (e.g. a test seeding a dark cell): a ``None``
    leaf auto-seeds to all-UP inside ``step``/``rollout``.
    """
    if cell_state is None:
        cell_state = sim_faults.init_cell_state(n_cells)
    return state._replace(cell_state=jnp.asarray(cell_state, jnp.int32))


def make_episode_fns(params, n_ues: int, n_cells: int,
                     radio_cfg: "radio.RadioConfig", traffic_step, *,
                     mobility_step_m=None, per_tti_fading: bool = False,
                     use_harq=None, mesh=None, ue_axis=("ue",),
                     cell_axis=None, radio_mode: str = "dense",
                     mobility_move_frac=None, inc_backend=None,
                     telemetry: bool = False, churn=None,
                     relax=None, faults=None) -> EpisodeFns:
    """Build the pure ``step``/``rollout`` functions for one configuration.

    ``params`` is a ``CRRM_parameters``; ``radio_cfg`` the hashable pure-
    radio configuration (``radio.config_from_params``) and ``traffic_step``
    the traffic model's arrival function -- both pure, so the returned
    functions are too.  ``use_harq`` forces the HARQ state machine on/off
    regardless of ``harq_bler`` (None = auto: on iff ``harq_bler > 0``);
    forcing it on at ``harq_bler=0`` is the equivalence-testing hook -- the
    machine must then reproduce the fast path bit-exactly.

    ``mesh`` runs both functions under ``shard_map`` with the UE axis of
    every per-UE array sharded over the ``ue_axis`` mesh axes (``n_ues``
    must divide evenly).  Callers pass *global* arrays exactly as in the
    single-device case; sharding is an execution detail.

    ``cell_axis`` (requires ``mesh``) additionally shards the *cell*
    dimension over the named mesh axes -- the UE×cell mesh of DESIGN.md
    §Million-UE-scaling.  ``RadioStatic``-shaped leaves (``C``/``P``/
    ``bore`` and the cell columns of ``fad``) become per-shard blocks of
    ``n_cells // m_shards`` cells; the dense interference total psums
    across cell shards, attachment and A3 run through the cross-shard
    argmax (``core.distributed._global_best`` -- lowest global index
    wins ties, exactly ``jnp.argmax``), and the serving row is an
    owning-shard gather + psum.  Per-UE leaves stay replicated along the
    cell axes, so the scheduler's per-cell reductions (global
    ``n_cells``-sized bins keyed by the global attachment) are untouched.
    Equivalence contract vs single device: attachment/serving/positions
    bitwise, float outputs to 1e-5 (the psum reorders the per-cell
    interference sum) -- the same contract the UE-only mesh carries for
    pf (tests/test_smart_update_scan.py, subprocess case).

    ``inc_backend`` routes the incremental mode's dirty-row recompute:
    ``None``/``"xla"`` is the legacy ``radio.radio_update_rows``;
    ``"pallas"`` streams the gathered dirty slab through the fused
    kernel (``radio.radio_update_rows_fused`` -- VMEM-resident
    gain/RSRP, interpret mode on CPU) and raises where the kernel
    cannot express the regime (handover tables, cell-sharded meshes,
    non-stock sector patterns); ``"auto"`` picks Pallas exactly when
    expressible and a real accelerator passed the capability probe,
    else XLA.

    The trace-time feature switches (mobility / per-TTI fading / HARQ /
    handover / per-RB grid / ``radio_mode`` / ``mobility_move_frac``) are
    baked here; ``n_tti`` and the presence of an ``action`` specialise via
    the jit cache on the returned functions.

    ``radio_mode="incremental"`` carries a ``radio.RadioState`` alongside
    the MAC carry and recomputes only the *dirty* UE rows of the radio
    chain per TTI (DESIGN.md §Smart-update-in-scan): with
    ``mobility_move_frac`` set, exactly that fraction of UEs walks per TTI
    (``sim.mobility.window_movers``) and only their rows re-run
    D→G→RSRP→SINR→CQI→SE; a power ``action`` is scan-constant, so its
    cell dirt collapses into one prepare-time ``radio.radio_init`` and
    the scan body is then MAC-only.  Equivalent to ``"dense"`` within the
    sharded gate's 1e-5 (bit-exact in the non-handover regimes);
    incompatible with ``per_tti_fading`` (every row dirty every TTI --
    dense IS the smart update there).

    ``mobility_move_frac`` also applies to the dense mode (the control
    arm of the smart-update benchmark): the same window-mover draw, with
    the full chain recomputed -- so dense and incremental trajectories
    are comparable at identical dirtiness.

    ``telemetry`` is a fourth trace-time switch: True adds a per-TTI
    :class:`repro.obs.telemetry.Telemetry` scan *output* to both returned
    functions (see :class:`EpisodeFns`); False (the default) compiles the
    exact legacy program -- telemetry touches no carry slot and draws no
    PRNG, so the trajectory is bit-identical either way (gated in
    tests/test_telemetry.py).  Under a mesh every KPI is psum-reduced
    inside the shard_map body, so each shard returns global numbers.

    ``churn`` (a ``sim.mobility.ChurnConfig``) is the digital-twin
    birth-death switch (DESIGN.md §Digital-twin-serving): the UE axis
    becomes *capacity-padded* -- ``state.active`` masks the live
    population, UEs arrive (Poisson, fresh positions and fading rows
    drawn from the dedicated ``radio.churn_keys`` streams) and depart
    inside the compiled scan with no retracing.  Inactive rows are
    structurally idle: their demand is masked out of every scheduler, so
    they draw zero RBs and zero throughput, and their MAC state is zeroed
    on departure.  Geometry is then dynamic even without mobility (births
    move rows), so the radio chain recomputes per TTI (dense) or patches
    newborn rows through the carried ``radio.RadioState`` (incremental).
    Churn is single-host (``mesh`` raises) -- the twin serves unsharded.

    Both returned functions also accept ``fairness_p=None``: a traced
    scalar overriding ``params.fairness_p`` in the PF weight law -- the
    twin server's live scheduler-control knob (None compiles the baked
    constant, i.e. the legacy program).

    ``relax`` (a ``radio.RelaxConfig``) is the differentiable-CRRM switch
    (DESIGN.md §RL-and-differentiability): the recomputed radio/MAC chain
    softens its three non-differentiable points (argmax attachment, the
    CQI staircase, max_cqi winner-take-all) so ``jax.grad`` through
    ``rollout`` w.r.t. a power ``action`` is exact for the relaxed
    program.  A trace-time switch like every other axis: ``relax=None``
    compiles the bitwise legacy program (pinned in tests/test_rl.py).
    The relaxations only reach the chain that is *recomputed* per TTI,
    i.e. they are meaningful with a power ``action`` (or per-TTI fading /
    mobility); single-device dense mode only -- ``mesh``, ``churn`` and
    ``radio_mode="incremental"`` raise.

    ``faults`` (a ``sim.faults.FaultConfig``) is the in-scan cell fault
    switch (DESIGN.md §Fault-injection-and-self-healing): each cell
    walks a per-TTI Markov outage/sleep chain (its own PRNG lineage,
    ``radio.fault_keys``, so fault-free trajectories stay bitwise) and
    the per-TTI tx power is masked by the per-cell fault multiplier --
    a DOWN cell's RSRP column is an exact zero, so attachment, A3 and
    SINR route around it through the unmodified radio chain.  The
    per-cell codes ride the carry as ``EpisodeState.cell_state``
    (auto-seeded all-UP; :func:`seed_fault_state` for custom patterns).
    Composes with churn, ``vmap``, handover, both radio modes and the
    UE×cell mesh; in incremental mode fault transitions re-derive the
    per-UE outputs from the carried gain matrices
    (``radio.radio_update_cells``) under a real ``lax.cond`` -- a
    fault-free TTI pays only the transition draw.  Incompatible with
    ``relax`` (the outage mask is a hard discontinuity) and with the
    fused Pallas backend (which never materialises the carried gains).
    """
    p = params
    cfg = radio_cfg
    tti_s, beta = p.tti_s, p.pf_ewma
    n_freq, rb_chunk = p.n_freq, p.rb_per_chunk
    rb_bw = p.subband_bandwidth_Hz / p.n_rb     # physical RB bandwidth
    policy, bler = p.scheduler_policy, p.harq_bler
    harq_on = bler > 0.0 if use_harq is None else bool(use_harq)
    max_retx, comb_db = p.harq_max_retx, p.harq_comb_gain_db
    ho_on = p.ho_enabled
    hyst_db, ttt_tti = p.ho_hysteresis_db, p.ho_ttt_tti
    noise_w = p.chunk_noise_W
    attach_on_mean = p.rayleigh_fading and p.attach_ignores_fading
    static_geom = mobility_step_m is None
    if radio_mode not in ("dense", "incremental"):
        raise ValueError(f"radio_mode must be 'dense' or 'incremental'; "
                         f"got {radio_mode!r}")
    incremental = radio_mode == "incremental"
    if incremental and per_tti_fading:
        raise ValueError(
            "radio_mode='incremental' is incompatible with per_tti_fading: "
            "a per-TTI fading redraw dirties every UE row every TTI, so "
            "the dense recompute IS the minimal update")
    frac_on = (mobility_step_m is not None and mobility_move_frac is not None
               and mobility_move_frac < 1.0)
    n_move = (max(1, int(round(mobility_move_frac * n_ues))) if frac_on
              else n_ues)
    churn_on = churn is not None
    faults_on = faults is not None
    if faults_on and relax is not None:
        raise ValueError(
            "faults= is incompatible with relax=: the outage tx mask is a "
            "hard discontinuity (a dark cell's RSRP column is exactly "
            "zero), so there is no useful gradient through a fault "
            "transition; differentiate a fault-free configuration instead")
    if churn_on and mesh is not None:
        raise ValueError(
            "episode_fns(mesh=..., churn=...) is unsupported: birth-death "
            "churn is single-host because newborn UEs scatter fresh "
            "position/fading rows into the capacity-padded active mask, "
            "and that scatter does not cross shard boundaries "
            "(sim.mobility.birth_death_step draws global rows; a shard "
            "cannot write a newborn born on another shard's block -- "
            "ROADMAP 'mesh-sharded churn' tracks the cross-shard newborn "
            "scatter).  Either drop mesh= and serve the twin unsharded, "
            "or pass churn=None for a mesh-sharded fixed population.")
    if relax is not None:
        if mesh is not None:
            raise ValueError(
                "relax= (differentiable relaxations) is single-device: "
                "the soft allocator shares pf's segment reductions but "
                "has no cross-shard collectives; drop mesh= (shrink the "
                "problem) or relax=None")
        if churn_on:
            raise ValueError(
                "relax= is incompatible with churn=: the birth-death "
                "scatter writes discrete rows (no gradient path through "
                "births); differentiate a fixed population instead")
        if radio_mode == "incremental":
            raise ValueError(
                "relax= requires radio_mode='dense': the incremental "
                "path carries hard argmax attachment in its RadioState "
                "(the dirty-row patching is integer gather/scatter); "
                "pass radio_mode='dense' when differentiating")
    # the fading factor is *carried* state exactly when newborns must
    # redraw their rows into an otherwise-static fading tensor
    fad_carried = churn_on and p.rayleigh_fading and not per_tti_fading
    max_birth = churn.max_arrivals_per_tti if churn_on else 0
    nb_backlog = churn.newborn_backlog_bits if churn_on else 0.0

    def use_rs(power_act: bool) -> bool:
        """Does this specialisation run on a RadioState?  Incremental mode
        with something to update: in-scan mobility dirt, birth-death row
        churn, or a power action whose chain is initialised once at
        prepare time.  The state is *carried* only when the scan mutates
        it (mobility or churn); a static-geometry action chain is
        loop-invariant and rides the hoisted constants instead (a
        pass-through carry would defeat XLA's loop-invariant hoisting
        of the downstream MAC subexpressions -- measured 2x per TTI).
        Fault transitions mutate the state too (radio_update_cells), so
        faults always carry it."""
        return incremental and (not static_geom or power_act or churn_on
                                or faults_on)

    # -- mesh layout (None = single device, the exact legacy program) ------
    if mesh is not None:
        ue_axes = (ue_axis,) if isinstance(ue_axis, str) else tuple(ue_axis)
        n_shards = 1
        for ax in ue_axes:
            n_shards *= mesh.shape[ax]
        if n_ues % n_shards:
            raise ValueError(
                f"n_ues={n_ues} must divide evenly over the {n_shards} "
                f"shards of mesh axes {ue_axes}")
    else:
        ue_axes, n_shards = None, 1
        if cell_axis is not None:
            raise ValueError("cell_axis= requires mesh= (the cell dimension "
                             "shards over named mesh axes)")
    if cell_axis is not None:
        cell_axes = ((cell_axis,) if isinstance(cell_axis, str)
                     else tuple(cell_axis))
        m_shards = 1
        for ax in cell_axes:
            m_shards *= mesh.shape[ax]
        if n_cells % m_shards:
            raise ValueError(
                f"n_cells={n_cells} must divide evenly over the {m_shards} "
                f"shards of mesh axes {cell_axes}")
    else:
        cell_axes, m_shards = None, 1
    m_loc = n_cells // m_shards      # cells owned by one shard

    # -- incremental dirty-row backend (trace-time route) ------------------
    if inc_backend not in (None, "auto", "xla", "pallas"):
        raise ValueError(f"inc_backend must be None, 'auto', 'xla' or "
                         f"'pallas'; got {inc_backend!r}")
    inc_fused = False
    if incremental and inc_backend in ("auto", "pallas"):
        if ho_on:
            reason = ("handover regimes carry per-candidate-cell tables "
                      "(se_all) the streaming kernel never materialises")
        elif faults_on:
            reason = ("cell fault transitions re-derive per-UE outputs "
                      "from carried gain matrices (G) the streaming "
                      "kernel never materialises")
        elif cell_axes is not None:
            reason = ("the fused kernel's attachment argmax spans all "
                      "cells, but a cell-sharded shard holds only its "
                      "cell block")
        else:
            reason = radio.pallas_unsupported_reason(cfg, None)
        if inc_backend == "pallas":
            if reason is not None:
                raise ValueError(
                    f"inc_backend='pallas' cannot express this "
                    f"configuration: {reason}")
            inc_fused = True
        else:
            inc_fused = reason is None and radio.pallas_available()

    n_loc = n_ues // n_shards        # rows owned by one shard (= n_ues unsharded)

    def local_offset():
        """Global UE index of this shard's first row (0 unsharded)."""
        return 0 if ue_axes is None else _axis_index(ue_axes) * n_loc

    def local_rows(x):
        """Slice a global-UE-axis array to this shard's contiguous block.

        Per-UE randomness is always drawn at *global* shape from the
        episode's key stream and then sliced, so shard s consumes exactly
        the rows it would own on a single device -- this is what makes the
        sharded rollout match the single-device one.  Identity when
        unsharded.
        """
        if ue_axes is None:
            return x
        return jax.lax.dynamic_slice_in_dim(x, local_offset(), n_loc, axis=0)

    def unfaded_gain(U, C, bore):
        return radio.pathgains(cfg, U, C, bore)

    def local_cols(x, axis=1):
        """Slice a global-cell-axis array to this shard's cell block
        (identity without cell sharding)."""
        if cell_axes is None:
            return x
        return jax.lax.dynamic_slice_in_dim(
            x, _axis_index(cell_axes) * m_loc, m_loc, axis=axis)

    def draw_fading(key):
        """Fresh per-TTI fading (global draw, local row/col slice when
        sharded -- shard (s, c) consumes exactly the block it would own
        on a single device, which is what keeps the mesh bit-equivalent)."""
        return local_cols(local_rows(
            radio.draw_fading(cfg, key, n_ues, n_cells)))

    def faded_rsrp(G0, P, fad):
        return radio.rsrp(radio.apply_fading(G0, fad), P)

    def attach(R_like):
        """``radio.attachment`` on a (possibly cell-sharded) RSRP tensor:
        the global argmax cell index, cross-shard via ``_global_best``
        (lowest global index wins ties, exactly ``jnp.argmax``)."""
        if cell_axes is None:
            return radio.attachment(R_like)
        meas = R_like.sum(axis=2)
        _, a, _ = _global_best(meas.max(axis=1),
                               meas.argmax(axis=1).astype(jnp.int32),
                               m_loc, cell_axes)
        return a

    def cell_take_rows(X, a):
        """Serving-cell row ``X[i, a_i, ...]`` under a *global* ``a``.

        Cell-sharded: the owning shard gathers its local column, every
        other shard contributes an exact zero, and a psum re-replicates
        the row -- bitwise the single-device ``take_along_axis`` (zeros
        add exactly).  Identity-shaped gather when unsharded.
        """
        if cell_axes is None:
            sel = a.reshape((-1, 1) + (1,) * (X.ndim - 2))
            return jnp.take_along_axis(X, sel, axis=1)[:, 0]
        my = _axis_index(cell_axes)
        col = jnp.clip(a - my * m_loc, 0, m_loc - 1)
        sel = col.reshape((-1, 1) + (1,) * (X.ndim - 2))
        rows = jnp.take_along_axis(X, sel, axis=1)[:, 0]
        mine = (a >= my * m_loc) & (a < (my + 1) * m_loc)
        mask = mine.reshape((-1,) + (1,) * (X.ndim - 2))
        return jax.lax.psum(jnp.where(mask, rows, jnp.zeros_like(rows)),
                            cell_axes)

    def a3_step(a, ttt, meas_wb):
        """:func:`a3_handover` on a (possibly cell-sharded) wideband
        measurement matrix.  Serving value via owning-shard gather + psum
        (exact), best neighbour via the cross-shard argmax -- the A3
        decisions are bitwise the single-device ones."""
        if cell_axes is None:
            return a3_handover(a, ttt, meas_wb, hyst_db, ttt_tti)
        serving = cell_take_rows(meas_wb[:, :, None], a)[:, 0]
        best_val, best, _ = _global_best(
            meas_wb.max(axis=1), meas_wb.argmax(axis=1).astype(a.dtype),
            m_loc, cell_axes)
        hyst = 10.0 ** (hyst_db / 10.0)
        entered = (best_val > serving * hyst) & (best != a)
        ttt = jnp.where(entered, ttt + 1, 0)
        fire = ttt >= ttt_tti
        a = jnp.where(fire, best, a)
        ttt = jnp.where(fire, 0, ttt)
        return a, ttt

    def sinr_chain(R, a, meas=None):
        """(se, cqi, a) for serving assignment ``a``.

        With ``relax.soft_attach`` the wanted/interference split softens
        to the temperature-softmax combination over per-cell RSRP
        (``radio.soft_attach_sinr``, fed the same ``meas`` matrix the
        hard argmax ranks); the returned ``a`` stays the hard i32 index
        either way -- schedulers gather with it.  ``relax=None`` is the
        bitwise legacy chain (``se_chain_relaxed`` degenerates to
        ``se_chain``).  Cell-sharded: owning-shard wanted gather + the
        psummed interference total (1e-5-class float reorder, the
        documented mesh contract).
        """
        if relax is not None and relax.soft_attach:
            m = meas if meas is not None else R.sum(axis=-1)
            gamma = radio.soft_attach_sinr(R, m, relax.attach_tau, noise_w)
        elif cell_axes is not None:
            w = cell_take_rows(R, a)
            total = jax.lax.psum(R.sum(axis=1), cell_axes)
            gamma = radio.sinr_from_wu(w, total - w, noise_w)
        else:
            gamma, _, _ = radio.sinr(R, a, noise_w)
        se, cqi = radio.se_chain_relaxed(cfg, gamma, relax)
        return se, cqi, a

    def gather_serving(se_all, cqi_all, a):
        """(se, cqi) rows of the per-candidate-cell tables at serving
        ``a`` -- the two-gather handover read shared by the hoisted dense
        tables and the incremental RadioState (owning-shard gather + psum
        when the tables are cell-sharded)."""
        return cell_take_rows(se_all, a), cell_take_rows(cqi_all, a)

    # -- incremental (smart-update-in-scan) helpers ------------------------
    def inc_fad(static):
        """The fading tensor the incremental chain consumes: ``None`` on
        the unfaded channel (``G0 * ones == G0`` bitwise; eliding the
        ones gather/multiply is pure profit on the 100k-row hot path)."""
        return static.fad if p.rayleigh_fading else None

    def init_rs(static, U, action, fad=None, pmul=None):
        """Prepare-time ``radio.RadioState``: the everything-dirty base
        case, computed once outside the scan.  A power ``action`` is
        scan-constant, so this is also where its cell dirt is absorbed
        (the scan body then only patches mobility rows).  ``fad``
        overrides the static fading tensor (the churn regimes' carried
        leaf); ``pmul`` the *seeded* fault multiplier (a custom-seeded
        dark cell must be dark from TTI 0, before its first
        transition).  Fault regimes keep the gain matrices
        (``with_gain``) so a fault transition can re-derive every
        per-UE output without re-running geometry+pathloss."""
        P = static.P if action is None else action
        if pmul is not None:
            P = P * local_cols(pmul, axis=0)[:, None]
        f = fad if fad is not None else inc_fad(static)
        return radio.radio_init(cfg, U, static.C, static.bore,
                                f, P, with_tables=ho_on,
                                with_gain=faults_on, cell_axis=cell_axes)

    def walk_displacements(k_mob):
        """This TTI's per-row displacement + the window start (local rows).

        ``mobility_move_frac`` set: the exact-count window-mover draw
        (global draw, per-shard reconstruction).  Unset: the legacy
        every-UE walk (start None = all rows dirty) -- the PR-4 stream,
        bit-untouched.
        """
        if frac_on:
            start, d = mobility.window_movers(k_mob, n_ues, n_move,
                                              mobility_step_m)
            rows = local_offset() + jnp.arange(n_loc)
            d_loc, _ = mobility.window_displacements(start, d, rows, n_ues)
            return d_loc, start
        d = local_rows(mobility.walk_steps(k_mob, n_ues, mobility_step_m))
        return d, None

    def window_dirty_indices(start):
        """The mover window's local dirty rows, enumerated in O(n_move).

        Delegates to ``radio.window_indices`` -- the shared exact-count
        enumeration that also backs ``radio.radio_update(window=...)`` --
        with this shard's contiguous block as the (offset, n_loc)
        restriction.  Returns ``(idx, count)``: the padded local index
        vector plus the number of genuinely dirty local rows (the
        telemetry ``dirty_rows`` counter; psums to the global ``n_move``
        under a mesh).
        """
        return radio.window_indices(start, n_move, n_ues,
                                    offset=local_offset(), n_loc=n_loc)

    def inc_channel(static, rs, U, P, k_mob, fad):
        """One incremental TTI of the radio chain: move, patch, read.

        Only the moved rows re-run D→G→RSRP→SINR→CQI→SE
        (``radio.radio_update_rows`` -- or its fused-kernel twin under
        ``inc_backend`` -- under THE dirtiness convention); everything
        else is a carried value that a dense recompute would reproduce
        bit-identically.  Returns the updated ``(U, rs)`` plus the local
        dirty-row count (dead code unless telemetry is on).
        """
        n_dirty = jnp.int32(0)
        if mobility_step_m is not None:
            d, start = walk_displacements(k_mob)
            U = mobility.apply_walk(U, d, p.extent_m)
            if start is None:
                idx = jnp.arange(n_loc, dtype=jnp.int32)
                n_dirty = jnp.int32(n_loc)
            else:
                idx, n_dirty = window_dirty_indices(start)
            if inc_fused:
                rs = radio.radio_update_rows_fused(
                    cfg, rs, U, static.C, static.bore, fad, P, idx)
            else:
                rs = radio.radio_update_rows(cfg, rs, U, static.C,
                                             static.bore, fad, P, idx,
                                             cell_axis=cell_axes)
        return U, rs, n_dirty

    def allocate(se, cqi, a, buf, avg, cursor, harq_pending, act, fair):
        demand = (buf[:, None] > 0.0) | harq_pending[:, None]
        if act is not None:
            # churn: inactive capacity slots are structurally idle -- no
            # policy ever grants them an RB, whatever their stale state
            demand = demand & act[:, None]
        active = demand & (se > 0.0)
        fp = p.fairness_p if fair is None else fair
        if relax is not None and relax.soft_sched and policy == "max_cqi":
            # winner-take-all softened to a temperature softmax over the
            # (relaxed) SE -- the third RelaxConfig gate; pf is already
            # smooth and rr is CQI-independent, so they pass through
            return mac_sched.allocate_max_cqi_soft(active, se, a, n_cells,
                                                   rb_chunk, relax.sched_tau)
        log_w = mac_sched.pf_log_weights_ewma(rb_bw * se, avg[:, None], fp)
        return mac_sched.allocate(policy, active, cqi, a, n_cells, rb_chunk,
                                  cursor, log_w, ue_axes,
                                  differentiable=relax is not None)

    def harq_step(k_harq, tb_new, hbits, hretx, granted):
        """One TTI of every UE's stop-and-wait process.

        Pending UEs retransmit their stored TB (no new buffer drain) --
        but only when the scheduler actually granted them RBs this TTI
        (``granted``); an ungranted pending TB waits, state unchanged.
        Fresh TBs enter the machine on failure and drop after
        ``max_retx`` retransmissions.  The retx TB is delivered at its
        stored size (real HARQ retransmits the same TB; the grant-size
        mismatch is absorbed by the soft-combining abstraction).

        The fifth return is the TTI's KPI tuple
        ``(acks, nacks, retx, dropped_bits)`` -- computed from the masks
        the machine already holds, so it is dead code (XLA DCE) unless
        telemetry consumes it.
        """
        pending = hbits > 0.0
        tb = jnp.where(pending, hbits, tb_new)
        attempting = granted & (tb > 0.0)
        attempt = jnp.where(pending, hretx, 0)
        p_fail = harq_fail_prob(bler, comb_db, attempt)
        u = local_rows(jax.random.uniform(k_harq, (n_ues,)))
        ok = (u >= p_fail) & attempting
        fail = ~ok & attempting
        n_fail = attempt + 1
        keep = (fail & (n_fail <= max_retx)) | (pending & ~granted)
        delivered = jnp.where(ok, tb, 0.0)
        stats = (ok.sum().astype(jnp.int32),
                 fail.sum().astype(jnp.int32),
                 (pending & attempting).sum().astype(jnp.int32),
                 jnp.where(fail & (n_fail > max_retx), tb, 0.0).sum())
        hbits = jnp.where(keep, tb, 0.0)
        hretx = jnp.where(keep, jnp.where(fail, n_fail, hretx), 0)
        return delivered, pending, hbits, hretx, stats

    def prepare(static, U, power_act: bool):
        """Hoistable constants of the static-geometry regime.

        Everything here is loop-invariant: ``rollout`` evaluates it once,
        outside the scan.  With a power ``action`` the P-dependent tables
        are skipped (the per-TTI chain recomputes from the action); only
        the unfaded gain -- pure geometry -- survives hoisting.
        """
        h = {}
        if use_rs(power_act):
            # the incremental path hoists through its RadioState instead
            return h
        if churn_on:
            # births move rows: nothing U-dependent is loop-invariant
            return h
        if static_geom and (per_tti_fading or ho_on or power_act
                            or faults_on):
            # static geometry: one unfaded gain/attachment pass, hoisted
            # out of the scan; only the fading factor varies per TTI.
            # Fault regimes hoist the gain too, but the P-dependent
            # tables cannot hoist: the fault mask changes P per TTI.
            h["G"] = unfaded_gain(U, static.C, static.bore)
            if not power_act and not faults_on:
                R_mean = radio.rsrp(h["G"], static.P)
                h["R_mean"] = R_mean
                h["a"] = attach(R_mean) if attach_on_mean else None
                R_faded = faded_rsrp(h["G"], static.P, static.fad)
                # A3 measures long-term RSRP iff association does (same
                # convention as the dynamic paths' R_meas); cell-sharded
                # it stays a local block -- a3_step gathers across shards
                h["meas_wb"] = (R_mean if attach_on_mean
                                else R_faded).sum(axis=-1)
                if ho_on:
                    # static channel + evolving serving cell: tabulate the
                    # SINR chain for EVERY candidate cell once, outside the
                    # scan -- per TTI the chain is then two gathers on
                    # (n_ue, n_freq) instead of an (n_ue, n_cell, n_freq)
                    # reduction.
                    total = R_faded.sum(axis=1)
                    if cell_axes is not None:
                        total = jax.lax.psum(total, cell_axes)
                    gamma_all = R_faded / (
                        noise_w + (total[:, None, :] - R_faded))
                    se_all, cqi_all = radio.se_chain(cfg, gamma_all)
                    h["cqi_all"], h["se_all"] = cqi_all, se_all
        return h

    def tti_step(h, static, state, action, rs=None, fair=None):
        """One pure TTI: (hoisted, static, state, action, radio-state) ->
        (state, tput, radio-state, telemetry).  ``rs`` is the incremental
        path's carried ``radio.RadioState`` (None on the dense paths,
        threaded unchanged); ``fair`` the traced fairness override (None =
        the baked constant); telemetry is None unless built with
        ``telemetry=True``."""
        power_act = action is not None
        U, buf, avg = state.U, state.backlog, state.pf_avg
        cursor, key = state.rr_cursor, state.key
        hbits, hretx, a_srv, ttt, t = (state.harq_bits, state.harq_retx,
                                       state.serving, state.ttt, state.t)
        prev_srv = a_srv
        P = action if power_act else static.P
        k_mob, k_fad, k_tr, k_harq = radio.tti_keys(key, t)
        n_dirty = jnp.int32(0) if incremental else None
        # -- birth-death churn: departures idle out, newborns take free
        # slots with fresh positions and fading rows (radio.churn_keys --
        # a separate stream lineage, so churn-off trajectories are
        # bit-untouched) ---------------------------------------------------
        act, fad_c, born = state.active, state.fad, None
        n_born = jnp.int32(0)
        if churn_on:
            k_birth, k_death, k_pos, k_fadc = radio.churn_keys(key, t)
            act, born, n_born = mobility.birth_death_step(
                k_birth, k_death, act, tti_s, churn)
            # departed rows idle out; reborn slots then reset fresh (a
            # slot can depart and be re-occupied within one TTI)
            buf = jnp.where(act, buf, 0.0)
            avg = jnp.where(act, avg, 0.0)
            hbits = jnp.where(act, hbits, 0.0)
            hretx = jnp.where(act, hretx, 0)
            ttt = jnp.where(act, ttt, 0)
            buf = jnp.where(born, nb_backlog, buf)
            avg = jnp.where(born, 0.0, avg)
            hbits = jnp.where(born, 0.0, hbits)
            hretx = jnp.where(born, 0, hretx)
            ttt = jnp.where(born, 0, ttt)
            born_idx = radio.dirty_indices(born, max_birth)
            U = scatter_born(
                U, born_idx,
                deploy.ppp_points(k_pos, max_birth, p.extent_m, z=p.h_ut_m),
                n_born)
            if fad_carried:
                fad_c = scatter_born(
                    fad_c, born_idx,
                    radio.draw_fading(cfg, k_fadc, max_birth, n_cells),
                    n_born)
        # -- cell faults: one Markov transition per TTI (radio.fault_keys
        # -- its own stream lineage, so fault-free trajectories are
        # bit-untouched), then the per-cell tx mask.  The draw is global
        # and replicated (every shard folds the same key), so cell_state
        # agrees across a mesh; only the P columns are local.
        cs, changed = state.cell_state, None
        if faults_on:
            cs, changed = sim_faults.fault_step(
                radio.fault_keys(key, t), cs, tti_s, faults)
            pmul = sim_faults.tx_multiplier(cs, faults)
            P = P * local_cols(pmul, axis=0)[:, None]
        # -- channel: incremental state (carried or hoisted), per-TTI
        # recompute, or the hoisted dense constants -------------------------
        r = rs if rs is not None else h.get("rs")
        if r is not None:
            f_inc = fad_c if fad_carried else inc_fad(static)
            if rs is not None:              # carried: mobility dirties rows
                U, r, n_dirty = inc_channel(static, r, U, P, k_mob, f_inc)
                if churn_on:
                    # patch the newborn rows (idempotent row recompute, so
                    # the row-0 padding of dirty_indices is safe here)
                    r = radio.radio_update_rows(cfg, r, U, static.C,
                                                static.bore, f_inc, P,
                                                born_idx)
                    n_dirty = n_dirty + n_born
                if faults_on:
                    # a fault transition re-prices every UE against the
                    # masked P from the carried gains -- no geometry, no
                    # pathloss.  Single device: a real lax.cond, so a
                    # fault-free TTI pays only the transition draw (the
                    # predicate is a replicated scalar; under vmap the
                    # cond lowers to a select).  Mesh: call branch-free
                    # (radio_update_cells where-selects internally) --
                    # collectives inside a cond branch are avoided.
                    def cell_upd(s):
                        return radio.radio_update_cells(
                            cfg, s, P, changed, cell_axis=cell_axes)
                    if mesh is None:
                        r = jax.lax.cond(jnp.any(changed), cell_upd,
                                         lambda s: s, r)
                    else:
                        r = cell_upd(r)
                rs = r
            if ho_on:
                if churn_on:
                    # newborns attach instantaneously to their best cell
                    a_srv = jnp.where(
                        born, jnp.argmax(r.meas, axis=1).astype(a_srv.dtype),
                        a_srv)
                a_srv, ttt = a3_step(a_srv, ttt, r.meas)
                a_use = a_srv
                se, cqi = gather_serving(r.se_all, r.cqi_all, a_use)
            else:
                se, cqi, a_use = r.se, r.cqi, r.a
        elif mobility_step_m is not None or churn_on:
            # random-walk displacement, clamped at the region border
            # (global draw, local slice when sharded); with churn alone
            # the geometry still changes per TTI (births move rows), so
            # the full chain recomputes from the current U
            if mobility_step_m is not None:
                d, _ = walk_displacements(k_mob)
                U = mobility.apply_walk(U, d, p.extent_m)
            G0 = unfaded_gain(U, static.C, static.bore)
            fad = (draw_fading(k_fad) if per_tti_fading
                   else (fad_c if fad_carried else static.fad))
            R = faded_rsrp(G0, P, fad)
            R_meas = radio.rsrp(G0, P) if attach_on_mean else R
            a_inst = attach(R_meas)
        elif per_tti_fading or power_act or faults_on:
            fad = draw_fading(k_fad) if per_tti_fading else static.fad
            R = faded_rsrp(h["G"], P, fad)
            if power_act or faults_on:
                # the fault mask (like a power action) changes P per
                # TTI, so measurement and attachment recompute from the
                # hoisted gain
                R_meas = radio.rsrp(h["G"], P) if attach_on_mean else R
                a_inst = attach(R_meas)
            else:
                R_meas = h["R_mean"] if attach_on_mean else R
                a_inst = h["a"] if attach_on_mean else attach(R)
        else:
            R = R_meas = a_inst = None   # fully static radio chain

        # -- serving cell: A3 carried state, or instantaneous argmax ------
        # (the incremental branch above already resolved se/cqi/a_use)
        if r is None:
            if ho_on:
                meas_wb = (R_meas.sum(axis=-1) if R_meas is not None
                           else h["meas_wb"])
                if churn_on:
                    a_srv = jnp.where(
                        born,
                        jnp.argmax(meas_wb, axis=1).astype(a_srv.dtype),
                        a_srv)
                a_srv, ttt = a3_step(a_srv, ttt, meas_wb)
                a_use = a_srv
                if R is not None:
                    se, cqi, _ = sinr_chain(R, a_use, meas=meas_wb)
                else:
                    # static channel, evolving attachment: gather from the
                    # hoisted all-cells SINR-chain tables
                    se, cqi = gather_serving(h["se_all"], h["cqi_all"],
                                             a_use)
            elif R is not None:
                se, cqi, a_use = sinr_chain(R, a_inst,
                                            meas=R_meas.sum(axis=-1))
            else:
                se, cqi, a_use = static.se, static.cqi, static.a
        if faults_on and not ho_on:
            # track the instantaneous attachment in the serving leaf so
            # outage-driven reattachment is observable (telemetry's
            # reattach_events) and survives chunk boundaries
            a_srv = a_use

        # -- MAC: traffic -> grant -> HARQ -> drain ------------------------
        arrivals = local_rows(traffic_step(k_tr, t))
        if churn_on:
            arrivals = jnp.where(act, arrivals, 0.0)
        buf = buf + arrivals
        harq_pending = (hbits > 0.0) if harq_on else \
            jnp.zeros_like(buf, dtype=bool)
        alloc = allocate(se, cqi, a_use, buf, avg, cursor, harq_pending,
                         act, fair)
        drainable = jnp.where(harq_pending, 0.0, buf)
        tb_new = mac_sched.served_bits(
            alloc, se, drainable, rb_bw, tti_s,
            floor=1e-6 if relax is not None else 1e-30).sum(1)
        hstats = None
        if harq_on:
            bits, _, hbits, hretx, hstats = harq_step(
                k_harq, tb_new, hbits, hretx, alloc.sum(axis=1) > 0.0)
        elif bler > 0.0:   # HARQ-lite: lost blocks stay queued -> retx
            bits = tb_new * local_rows(jax.random.bernoulli(
                k_harq, 1.0 - bler, (n_ues,))).astype(tb_new.dtype)
        else:
            bits = tb_new
        # clamp: served_bits <= backlog only up to float rounding
        if harq_on:
            buf = jnp.maximum(buf - tb_new, 0.0)  # drain on first tx
        else:
            buf = jnp.maximum(buf - bits, 0.0)
        tput = bits / tti_s
        avg = (1.0 - beta) * avg + beta * tput
        state = EpisodeState(U, buf, avg, cursor + rb_chunk, key,
                             hbits, hretx, a_srv, ttt, t + 1,
                             active=act, fad=fad_c, cell_state=cs)
        telem = None
        if telemetry:
            # KPIs only from values computed above: no PRNG, no carry.
            if hstats is None:
                acks = (bits > 0.0).sum().astype(jnp.int32)
                nacks = (((tb_new > 0.0) & (bits == 0.0)).sum()
                         .astype(jnp.int32) if bler > 0.0 else jnp.int32(0))
                hstats = (acks, nacks, jnp.int32(0), jnp.float32(0.0))
            ho_fired = ((a_srv != prev_srv).sum().astype(jnp.int32)
                        if ho_on else jnp.int32(0))
            n_act = act.sum().astype(jnp.int32) if churn_on else None
            # cells_down is computed from the *replicated* cell_state --
            # identical on every shard, so tti_telemetry must not psum
            # it; reattach_events is a per-UE count (psums over ue_axes)
            n_down = ((cs == sim_faults.DOWN).sum().astype(jnp.int32)
                      if faults_on else None)
            reatt = ((a_srv != prev_srv).sum().astype(jnp.int32)
                     if faults_on else None)
            telem = tti_telemetry(n_cells, n_ues, a_use, alloc, bits, tput,
                                  buf, hstats, ho_fired, n_dirty, ue_axes,
                                  n_act, cells_down=n_down,
                                  reattached=reatt)
        return state, tput, rs, telem

    def setup(static, state, action):
        """(hoisted constants, carried RadioState) for one specialisation.

        The incremental modes split on loop-variance: a mobility (or
        churn) episode's RadioState mutates per TTI (scan carry ``rs0``);
        a static-geometry action chain is computed once and *closed over*
        (``h["rs"]``) so XLA hoists every downstream loop-invariant
        subexpression exactly as it does for the dense hoisted tables.
        """
        h = prepare(static, state.U, action is not None)
        rs0 = None
        if use_rs(action is not None):
            if static_geom and not churn_on and not faults_on:
                h["rs"] = init_rs(static, state.U, action)
            else:
                pmul0 = (sim_faults.tx_multiplier(state.cell_state, faults)
                         if faults_on else None)
                rs0 = init_rs(static, state.U, action,
                              fad=state.fad if fad_carried else None,
                              pmul=pmul0)
        return h, rs0

    def norm_state(state):
        """Auto-seed the fault leaf at the jit boundary: a fault-enabled
        engine fed a legacy state (``cell_state=None``) starts all-UP --
        trace-time, so legacy treedefs keep compiling the legacy program
        and callers never thread the leaf by hand."""
        if faults_on and state.cell_state is None:
            return state._replace(
                cell_state=sim_faults.init_cell_state(n_cells))
        return state

    # ------------------------------------------------------- single device
    if mesh is None:
        def step(static, state, action=None, fairness_p=None):
            state = norm_state(state)
            h, rs0 = setup(static, state, action)
            state, tput, _, telem = tti_step(h, static, state, action, rs0,
                                             fairness_p)
            return (state, tput, telem) if telemetry else (state, tput)

        def rollout(static, state, n_tti, action=None, fairness_p=None):
            state = norm_state(state)
            h, rs0 = setup(static, state, action)

            def body(carry, _):
                s, rs = carry
                s, tput, rs, telem = tti_step(h, static, s, action, rs,
                                              fairness_p)
                return (s, rs), ((tput, telem) if telemetry else tput)

            (state, _), ys = jax.lax.scan(body, (state, rs0), None,
                                          length=n_tti)
            if telemetry:
                tput, telem = ys
                return state, tput, telem
            return state, ys

        return EpisodeFns(
            step=jax.jit(step),
            rollout=jax.jit(rollout, static_argnums=(2,)),
            rollout_donated=jax.jit(rollout, static_argnums=(2,),
                                    donate_argnums=(1,)))

    # ------------------------------------------------------- mesh sharded
    # pytree-structured PartitionSpecs: UE axes shard every per-UE leaf;
    # cell axes (when named) shard the RadioStatic-shaped leaves, else the
    # cells are replicated (cell_axes=None leaves the specs verbatim)
    ue = PSpec(ue_axes)
    mesh_axes = ue_axes if cell_axes is None else ue_axes + cell_axes
    fad_spec = (PSpec(ue_axes, cell_axes, None)
                if p.rayleigh_fading and p.n_rb_subbands > 1
                else PSpec(ue_axes, cell_axes))
    static_specs = EpisodeStatic(
        se=PSpec(ue_axes, None), cqi=PSpec(ue_axes, None), a=ue,
        C=PSpec(cell_axes, None), P=PSpec(cell_axes, None),
        bore=PSpec(cell_axes), fad=fad_spec)
    state_specs = EpisodeState(
        U=PSpec(ue_axes, None), backlog=ue, pf_avg=ue, rr_cursor=PSpec(),
        key=PSpec(None), harq_bits=ue, harq_retx=ue, serving=ue, ttt=ue,
        t=PSpec(),
        # the fault codes are replicated (every shard draws the identical
        # transition from the replicated key); None leaves stay None --
        # shard_map matches treedefs exactly
        cell_state=PSpec(None) if faults_on else None)
    # telemetry leaves leave the shard_map fully replicated: every KPI is
    # psum-reduced inside tti_telemetry, so each shard holds the global
    # value.  The None leaf (dirty_rows outside incremental mode) must be
    # None in the spec tree too -- shard_map matches treedefs exactly.
    telem_specs = Telemetry(
        served_bits=PSpec(None), granted_rb=PSpec(None),
        harq_acks=PSpec(), harq_nacks=PSpec(), harq_retx=PSpec(),
        dropped_bits=PSpec(), ho_events=PSpec(), buffer_bits=PSpec(),
        jain=PSpec(), dirty_rows=PSpec() if incremental else None,
        cells_down=PSpec() if faults_on else None,
        reattach_events=PSpec() if faults_on else None)
    # stacked (n_tti, ...) variant for the rollout's scan output
    telem_stack_specs = Telemetry(
        served_bits=PSpec(None, None), granted_rb=PSpec(None, None),
        harq_acks=PSpec(None), harq_nacks=PSpec(None),
        harq_retx=PSpec(None), dropped_bits=PSpec(None),
        ho_events=PSpec(None), buffer_bits=PSpec(None),
        jain=PSpec(None), dirty_rows=PSpec(None) if incremental else None,
        cells_down=PSpec(None) if faults_on else None,
        reattach_events=PSpec(None) if faults_on else None)

    def revar(state):
        """Re-establish the claimed replication of the scalar carry slots.

        The scan carry is typed device-varying as a whole (``pvary``), but
        the scalar slots (cursor, key, t) evolve identically on every
        shard; a ``pmax`` both proves and restores their replication so
        they can leave the shard_map under a replicated out-spec.  No-ops
        on jax versions without varying-type tracking.
        """
        fix = lambda x: jax.lax.pmax(x, mesh_axes)
        out = state._replace(rr_cursor=fix(state.rr_cursor),
                             key=fix(state.key), t=fix(state.t))
        if faults_on:   # identical on every shard, same as the scalars
            out = out._replace(cell_state=fix(out.cell_state))
        return out

    def sharded(fn, in_specs, out_specs):
        # replication checking must be off: the traffic models' poisson
        # sampler carries a while_loop, for which jax's rep-checker has no
        # rule.  The kwarg spelling differs across jax versions.
        for kw in ({"check_rep": False}, {"check_vma": False}, {}):
            try:
                return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kw)
            except TypeError:       # pragma: no cover - version dependent
                continue

    def extra_layout(action, fairness_p):
        """(specs, args) for the optional trailing shard_map inputs: the
        power action (replicated (n_cells, n_freq)) then the fairness
        scalar (replicated) -- each present iff passed, so the disabled
        combination compiles the exact legacy program."""
        specs, args = (), ()
        if action is not None:
            specs, args = specs + (PSpec(None, None),), args + (action,)
        if fairness_p is not None:
            specs, args = specs + (PSpec(),), args + (fairness_p,)
        return specs, args

    def split_extra(has_act, extra):
        act = extra[0] if has_act else None
        fp = extra[-1] if len(extra) > int(has_act) else None
        return act, fp

    def step(static, state, action=None, fairness_p=None):
        has_act = action is not None

        def one(static, state, *extra):
            act, fp = split_extra(has_act, extra)
            state = jax.tree_util.tree_map(
                lambda x: _pvary(x, mesh_axes), state)
            h, rs0 = setup(static, state, act)
            state, tput, _, telem = tti_step(h, static, state, act, rs0, fp)
            if telemetry:
                return revar(state), tput, telem
            return revar(state), tput

        extra_specs, extra_args = extra_layout(action, fairness_p)
        out_specs = ((state_specs, ue, telem_specs) if telemetry
                     else (state_specs, ue))
        f = sharded(one, (static_specs, state_specs) + extra_specs,
                    out_specs)
        return f(static, norm_state(state), *extra_args)

    def rollout(static, state, n_tti, action=None, fairness_p=None):
        has_act = action is not None

        def roll(static, state, *extra):
            act, fp = split_extra(has_act, extra)
            init = jax.tree_util.tree_map(
                lambda x: _pvary(x, mesh_axes), state)
            h, rs0 = setup(static, init, act)

            def body(carry, _):
                s, rs = carry
                s, tput, rs, telem = tti_step(h, static, s, act, rs, fp)
                return (s, rs), ((tput, telem) if telemetry else tput)

            (state, _), ys = jax.lax.scan(body, (init, rs0), None,
                                          length=n_tti)
            if telemetry:
                tput, telem = ys
                return revar(state), tput, telem
            return revar(state), ys

        extra_specs, extra_args = extra_layout(action, fairness_p)
        out_specs = ((state_specs, PSpec(None, ue_axes), telem_stack_specs)
                     if telemetry else (state_specs, PSpec(None, ue_axes)))
        f = sharded(roll, (static_specs, state_specs) + extra_specs,
                    out_specs)
        return f(static, norm_state(state), *extra_args)

    return EpisodeFns(
        step=jax.jit(step),
        rollout=jax.jit(rollout, static_argnums=(2,)),
        rollout_donated=jax.jit(rollout, static_argnums=(2,),
                                donate_argnums=(1,)))


def episode_fns_for(sim, *, mobility_step_m=None, per_tti_fading=False,
                    use_harq=None, mesh=None, ue_axis=("ue",),
                    cell_axis=None, radio_mode=None,
                    mobility_move_frac=None, inc_backend=None,
                    telemetry: bool = False, churn=None,
                    relax=None, faults=None) -> EpisodeFns:
    """The :func:`make_episode_fns` bundle for ``sim``, cached on it.

    Keyed by the trace-time switches only -- ``n_tti`` and the presence of
    a power action specialise through the jit cache of the returned
    functions, so repeat episodes of any length reuse one ``EpisodeFns``.
    ``mobility_step_m=None`` falls back to the simulator's
    ``params.mobility_step_m`` (scenario presets with a baked-in mobility
    trajectory); pass ``0`` to force the static-geometry program.
    ``radio_mode``/``mobility_move_frac``/``faults`` fall back to the
    corresponding ``CRRM_parameters`` fields the same way (``faults=0``
    forces the fault-free program on a faulted preset).
    """
    if mobility_step_m is None:
        mobility_step_m = getattr(sim.params, "mobility_step_m", None)
    if not mobility_step_m:          # 0 / None -> static geometry
        mobility_step_m = None
    if radio_mode is None:
        radio_mode = getattr(sim.params, "radio_mode", "dense")
    if mobility_move_frac is None:
        mobility_move_frac = getattr(sim.params, "mobility_move_frac", None)
    if faults is None:
        faults = getattr(sim.params, "faults", None)
    if not faults:                   # 0 / False -> fault-free program
        faults = None
    ue_axis = (ue_axis,) if isinstance(ue_axis, str) else tuple(ue_axis)
    if isinstance(cell_axis, str):
        cell_axis = (cell_axis,)
    elif cell_axis is not None:
        cell_axis = tuple(cell_axis)
    cache_key = (mobility_step_m, per_tti_fading, use_harq, mesh, ue_axis,
                 cell_axis, radio_mode, mobility_move_frac, inc_backend,
                 telemetry, churn, relax, faults)
    cache = sim.__dict__.setdefault("_episode_fns_cache", {})
    if cache_key not in cache:
        cache[cache_key] = make_episode_fns(
            sim.params, sim.n_ues, sim.n_cells, sim.radio_config(),
            sim._traffic_step, mobility_step_m=mobility_step_m,
            per_tti_fading=per_tti_fading, use_harq=use_harq,
            mesh=mesh, ue_axis=ue_axis, cell_axis=cell_axis,
            radio_mode=radio_mode, mobility_move_frac=mobility_move_frac,
            inc_backend=inc_backend, telemetry=telemetry,
            churn=churn, relax=relax, faults=faults)
    return cache[cache_key]


def run_episode(sim, n_tti: int, key=None, mobility_step_m=None,
                per_tti_fading: bool = False, sync_state: bool = True,
                use_harq=None, mesh=None, radio_mode=None,
                mobility_move_frac=None, telemetry: bool = False,
                churn=None, faults=None):
    """Run ``n_tti`` TTIs; returns (n_tti, n_ues) delivered throughput
    (bits/s) -- or ``(tput, telem)`` with ``telemetry=True``, where
    ``telem`` is the stacked per-TTI :class:`repro.obs.telemetry.Telemetry`
    (``repro.obs.summarize`` reduces it to a KPI dict).

    A thin wrapper over the functional API: ``sim.init_episode_state(key)``
    -> ``rollout`` -> ``sim.sync_episode_state``.  The PF average-rate
    state is seeded from the single-shot graph's served throughput (the
    stationary alpha-fair point), so a full-buffer PF episode starts --
    and, with a static channel, stays -- at the legacy ``ThroughputNode``
    fixed point.  ``sync_state`` (legacy; functional callers thread
    :class:`EpisodeState` instead) writes the final buffers / PF state /
    positions / HARQ processes / serving cells back into the graph so
    subsequent single-shot queries and episodes continue from the episode's
    end state.  ``mesh`` runs the rollout shard_mapped over the UE axis.
    """
    fns = episode_fns_for(sim, mobility_step_m=mobility_step_m,
                          per_tti_fading=per_tti_fading, use_harq=use_harq,
                          mesh=mesh, radio_mode=radio_mode,
                          mobility_move_frac=mobility_move_frac,
                          telemetry=telemetry, churn=churn, faults=faults)
    state = sim.init_episode_state(key)
    static = sim.episode_static()
    if churn is not None:
        state = seed_churn_state(state, static, sim.params,
                                 per_tti_fading=per_tti_fading)
    telem = None
    if telemetry:
        state, tput, telem = fns.rollout(static, state, n_tti)
    else:
        state, tput = fns.rollout(static, state, n_tti)
    if mobility_step_m is None:
        mobility_step_m = getattr(sim.params, "mobility_step_m", None)
    if sync_state:
        sim.sync_episode_state(state, positions=bool(mobility_step_m))
    return (tput, telem) if telemetry else tput
