"""The scan-compiled TTI engine: a whole episode as ONE compiled program.

The smart-update graph is built for sparse, event-driven mutation (move a
few UEs, re-query).  Time-stepped MAC simulation is the opposite regime:
*every* TTI touches *every* UE's buffer, so per-TTI Python dispatch over the
node graph would dominate.  This module re-expresses one TTI as a pure
function of an explicit :class:`EpisodeState` pytree

    (positions, backlog_bits, pf_avg_rate, rr_cursor, key,
     harq_bits, harq_retx, serving_cell, ttt, t)

and rolls N TTIs with ``jax.lax.scan``: one trace, one XLA program, zero
per-TTI Python (DESIGN.md §TTI-engine, §Env-API).  A 1000-UE x 1000-TTI
episode is a single device launch.

The radio *math* inside the scan is not the engine's: every D/G/RSRP/SINR/
CQI/SE evaluation delegates to the pure chain of ``repro.sim.radio``
(DESIGN.md §Radio-fns), the same functions the smart-update graph nodes
wrap -- one implementation, bit-exact across graph, engine and env.

The episode API is pure-functional (DESIGN.md §Env-API):

* :class:`EpisodeState` -- everything the scan carry needs, as a pytree.
  ``CRRM.init_episode_state(key)`` gathers it from the graph;
* :class:`EpisodeStatic` -- the per-episode radio inputs (cached SE/CQI/
  attachment plus the C/P/boresight/fading roots).  ``CRRM.episode_static()``
  reads them off the graph;
* :func:`make_episode_fns` -- builds ``step(static, state, action)`` and
  ``rollout(static, state, n_tti, action)``, both jit- and vmap-compatible:
  batching N episodes over seeds is ``jax.vmap`` over ``state`` (and
  ``action``), and compiles to one program (``src/repro/env``).

``run_episode`` is a thin wrapper: init state -> rollout -> (optionally)
write the final state back into the graph.  The write-back (``sync_state``)
is retained for the paper's mutate/query workflow but is a legacy
convenience: functional callers thread :class:`EpisodeState` explicitly and
never touch simulator attributes.

Three orthogonal feature axes, each a trace-time (Python) switch so the
disabled configuration compiles to exactly the legacy program:

* frequency-selective link adaptation (``n_rb_subbands > 1``): the fading
  factor is a per-RB block-fading tensor pooled to CQI-subband resolution,
  so SE/CQI/alloc carry a (n_ues, n_freq) frequency axis and the schedulers
  pick *which* RBs each UE gets.  ``n_rb_subbands=1`` is the wideband path.
  ``cqi_report="wideband"`` decouples *reporting* from fading resolution:
  the channel stays selective but CQI/MCS collapse to one report per power
  subband (radio.pool_report).
* stop-and-wait HARQ (``harq_bler > 0``): per-UE process state (pending TB
  bits, retx count) rides in the carry; failed TBs retransmit with a
  soft-combining SINR gain per attempt until ``harq_max_retx`` is exhausted.
  ``harq_bler=0`` compiles the HARQ-free fast path (bit-exact legacy).
* A3 handover (``ho_enabled``): the serving-cell vector ``a`` is carried
  state, updated when a neighbour beats the serving cell by
  ``ho_hysteresis_db`` for ``ho_ttt_tti`` consecutive TTIs.  Disabled, the
  serving cell is the instantaneous argmax (legacy).

Channel regimes:

* static (no mobility, no per-TTI fading, no power action): the radio chain
  (se, cqi, a) is read once from ``EpisodeStatic`` -- the scan body is
  MAC-only math;
* dynamic (``mobility_step_m`` set -- explicitly or via
  ``params.mobility_step_m`` (scenario presets with a baked-in mobility
  trajectory), ``per_tti_fading``, or a power ``action``): the radio chain
  is recomputed inside the scan from the pure ``sim.radio`` functions, so
  both paths share one implementation.  A non-None ``action`` is a
  per-episode (n_cells, n_freq) power matrix overriding ``static.P`` -- the
  RL power-control hook.

Mesh sharding (``mesh=``): the rollout runs under ``shard_map`` with the UE
axis of every per-UE tensor sharded over the named mesh axes (cells are
replicated).  The per-UE MAC math is embarrassingly parallel; the only
cross-shard traffic is the scheduler's per-cell reductions
(``mac.scheduler`` with ``ue_axis=``, reusing the mesh helpers and
cross-shard argmax of ``core.distributed``).  Per-UE PRNG draws are taken
from the *global* stream and sliced to the local block, so a sharded
episode matches the single-device rollout (asserted in
tests/test_radio_fns.py and gated in ``benchmarks/BENCH_sharded.json``):
*bitwise* for the integer-exact schedulers (rr, max_cqi) and to 1e-5 for
pf, whose cross-shard ``psum`` reorders a float reduction.  (Under bursty
traffic, pf's ulp-level residues can flip backlog-active masks and the
trajectories then diverge chaotically -- inherent to any reduction
reordering, not a sharding bug; the equivalence suite pins the
non-chaotic regimes.)

All mutable simulator state (positions, powers, fading, radio outputs)
enters the compiled episode as *arguments*, never as baked-in constants, so
mutating the graph between episodes behaves correctly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PSpec

from repro.core.distributed import _axis_index, _pvary, _shard_map
from repro.mac import scheduler as mac_sched
from repro.obs.telemetry import Telemetry, tti_telemetry
from repro.sim import mobility, radio


class EpisodeState(NamedTuple):
    """The full mutable state of a MAC episode, as an explicit pytree.

    Every field is a per-simulation array (no Python state), so the whole
    tuple can ride a ``lax.scan`` carry, be ``jax.vmap``ed over a batch
    axis (N parallel episodes), checkpointed, or handed to an external RL
    loop.  Constructed by ``CRRM.init_episode_state``; advanced by the pure
    ``step``/``rollout`` functions of :func:`make_episode_fns`.
    """

    U: Any           # (n_ues, 3) positions
    backlog: Any     # (n_ues,) queued bits (inf = full buffer)
    pf_avg: Any      # (n_ues,) PF EWMA average delivered rate, bits/s
    rr_cursor: Any   # i32 scalar: round-robin rotation state
    key: Any         # PRNG key; per-TTI streams fold via radio.tti_keys
    harq_bits: Any   # (n_ues,) f32 pending transport-block bits (0 = idle)
    harq_retx: Any   # (n_ues,) i32 retransmission count of the pending TB
    serving: Any     # (n_ues,) i32 serving-cell index (A3 carried state)
    ttt: Any         # (n_ues,) i32 A3 time-to-trigger counters
    t: Any           # i32 scalar: TTI index (drives PRNG folds + traffic)


class EpisodeStatic(NamedTuple):
    """Per-episode radio inputs: everything the step reads but never writes.

    The cached single-shot radio chain (``se``/``cqi``/``a`` -- used
    verbatim in the fully-static regime) plus the graph roots the dynamic
    regimes recompute from.  Read off the graph by ``CRRM.episode_static()``
    or rebuilt purely (per topology draw) by ``CrrmEnv.reset`` via
    ``radio.radio_forward``.
    """

    se: Any          # (n_ues, n_freq) spectral efficiency
    cqi: Any         # (n_ues, n_freq)
    a: Any           # (n_ues,) i32 attachment
    C: Any           # (n_cells, 3) cell positions
    P: Any           # (n_cells, n_freq) tx power
    bore: Any        # (n_cells,) sector boresights
    fad: Any         # (n_ues, n_cells[, n_freq]) fading factor


class EpisodeFns(NamedTuple):
    """The pure episode API for one engine configuration (jit-compiled).

    ``step(static, state, action=None) -> (state, tput)`` advances one TTI;
    ``rollout(static, state, n_tti, action=None) -> (state, tput)`` scans
    ``n_tti`` TTIs (``tput`` stacked to (n_tti, n_ues)).  ``action`` is an
    optional (n_cells, n_freq) power matrix overriding ``static.P`` (a
    trace-time switch: None compiles the legacy program).  Both functions
    are pure and vmap over ``state``/``action`` for batched episodes
    (single-device configurations; a mesh-sharded bundle spans the devices
    instead of vmapping).

    Built with ``telemetry=True`` both functions return one extra value --
    a :class:`repro.obs.telemetry.Telemetry` of per-TTI KPIs (stacked to
    (n_tti, ...) by ``rollout``): ``step -> (state, tput, telem)``,
    ``rollout -> (state, tput, telem)``.  Telemetry rides the scan as an
    *output*, never a carry, and is computed purely from intermediates the
    step already produced, so the trajectory is bit-identical either way.
    """

    step: Any
    rollout: Any


def harq_fail_prob(bler, comb_gain_db, retx):
    """Conditional failure probability of HARQ attempt number ``retx``.

    ``retx`` prior (failed) copies are soft-combined, boosting effective
    SINR by ``comb_gain_db`` dB each; in the Rayleigh outage regime
    P(fail) ~ theta/SNR, so the conditional BLER divides by the linear gain
    per retransmission: ``bler / 10^(retx * gain_db / 10)``.  Monotone
    non-increasing in ``retx`` (tested in tests/test_mac_engine.py).
    """
    gain = 10.0 ** (comb_gain_db / 10.0)
    return jnp.clip(bler * gain ** (-retx.astype(jnp.float32)), 0.0, 1.0)


def a3_handover(a, ttt, rsrp_wb, hyst_db, ttt_tti):
    """One TTI of the A3 trigger: (serving, time-to-trigger) -> updated.

    Event A3 enters when the best neighbour's wideband RSRP exceeds the
    serving cell's by ``hyst_db``; the counter must stay entered for
    ``ttt_tti`` consecutive TTIs before the UE hands over to that
    neighbour.  Leaving the condition resets the counter (3GPP 38.331
    semantics, collapsed to one measurement per TTI).
    """
    serving = jnp.take_along_axis(rsrp_wb, a[:, None], axis=1)[:, 0]
    best = jnp.argmax(rsrp_wb, axis=1).astype(a.dtype)
    best_val = rsrp_wb.max(axis=1)
    hyst = 10.0 ** (hyst_db / 10.0)
    entered = (best_val > serving * hyst) & (best != a)
    ttt = jnp.where(entered, ttt + 1, 0)
    fire = ttt >= ttt_tti
    a = jnp.where(fire, best, a)
    ttt = jnp.where(fire, 0, ttt)
    return a, ttt


def stationary_served_tput(params, n_cells: int, se, cqi, a, backlog):
    """Pure twin of the graph's Schedule -> ServedThroughput chain.

    The single-shot served throughput at the stationary alpha-fair point
    -- what ``CRRM.init_episode_state`` seeds the PF EWMA with by querying
    the graph.  This function computes the same numbers from explicit
    arrays, so a topology-resampling env ``reset`` can seed the PF state
    inside jit/vmap without a graph (tested identical in
    tests/test_radio_fns.py).
    """
    p = params
    active = (backlog[:, None] > 0.0) & (se > 0.0)
    log_w = mac_sched.pf_log_weights_stationary(se, p.fairness_p)
    alloc = mac_sched.allocate(p.scheduler_policy, active, cqi, a, n_cells,
                               p.rb_per_chunk, jnp.int32(0), log_w)
    bits = mac_sched.served_bits(alloc, se, backlog,
                                 p.subband_bandwidth_Hz / p.n_rb, p.tti_s)
    return (bits / p.tti_s).sum(axis=1)


def make_episode_fns(params, n_ues: int, n_cells: int,
                     radio_cfg: "radio.RadioConfig", traffic_step, *,
                     mobility_step_m=None, per_tti_fading: bool = False,
                     use_harq=None, mesh=None, ue_axis=("ue",),
                     radio_mode: str = "dense",
                     mobility_move_frac=None,
                     telemetry: bool = False) -> EpisodeFns:
    """Build the pure ``step``/``rollout`` functions for one configuration.

    ``params`` is a ``CRRM_parameters``; ``radio_cfg`` the hashable pure-
    radio configuration (``radio.config_from_params``) and ``traffic_step``
    the traffic model's arrival function -- both pure, so the returned
    functions are too.  ``use_harq`` forces the HARQ state machine on/off
    regardless of ``harq_bler`` (None = auto: on iff ``harq_bler > 0``);
    forcing it on at ``harq_bler=0`` is the equivalence-testing hook -- the
    machine must then reproduce the fast path bit-exactly.

    ``mesh`` runs both functions under ``shard_map`` with the UE axis of
    every per-UE array sharded over the ``ue_axis`` mesh axes (``n_ues``
    must divide evenly).  Callers pass *global* arrays exactly as in the
    single-device case; sharding is an execution detail.

    The trace-time feature switches (mobility / per-TTI fading / HARQ /
    handover / per-RB grid / ``radio_mode`` / ``mobility_move_frac``) are
    baked here; ``n_tti`` and the presence of an ``action`` specialise via
    the jit cache on the returned functions.

    ``radio_mode="incremental"`` carries a ``radio.RadioState`` alongside
    the MAC carry and recomputes only the *dirty* UE rows of the radio
    chain per TTI (DESIGN.md §Smart-update-in-scan): with
    ``mobility_move_frac`` set, exactly that fraction of UEs walks per TTI
    (``sim.mobility.window_movers``) and only their rows re-run
    D→G→RSRP→SINR→CQI→SE; a power ``action`` is scan-constant, so its
    cell dirt collapses into one prepare-time ``radio.radio_init`` and
    the scan body is then MAC-only.  Equivalent to ``"dense"`` within the
    sharded gate's 1e-5 (bit-exact in the non-handover regimes);
    incompatible with ``per_tti_fading`` (every row dirty every TTI --
    dense IS the smart update there).

    ``mobility_move_frac`` also applies to the dense mode (the control
    arm of the smart-update benchmark): the same window-mover draw, with
    the full chain recomputed -- so dense and incremental trajectories
    are comparable at identical dirtiness.

    ``telemetry`` is a fourth trace-time switch: True adds a per-TTI
    :class:`repro.obs.telemetry.Telemetry` scan *output* to both returned
    functions (see :class:`EpisodeFns`); False (the default) compiles the
    exact legacy program -- telemetry touches no carry slot and draws no
    PRNG, so the trajectory is bit-identical either way (gated in
    tests/test_telemetry.py).  Under a mesh every KPI is psum-reduced
    inside the shard_map body, so each shard returns global numbers.
    """
    p = params
    cfg = radio_cfg
    tti_s, beta = p.tti_s, p.pf_ewma
    n_freq, rb_chunk = p.n_freq, p.rb_per_chunk
    rb_bw = p.subband_bandwidth_Hz / p.n_rb     # physical RB bandwidth
    policy, bler = p.scheduler_policy, p.harq_bler
    harq_on = bler > 0.0 if use_harq is None else bool(use_harq)
    max_retx, comb_db = p.harq_max_retx, p.harq_comb_gain_db
    ho_on = p.ho_enabled
    hyst_db, ttt_tti = p.ho_hysteresis_db, p.ho_ttt_tti
    noise_w = p.chunk_noise_W
    attach_on_mean = p.rayleigh_fading and p.attach_ignores_fading
    static_geom = mobility_step_m is None
    if radio_mode not in ("dense", "incremental"):
        raise ValueError(f"radio_mode must be 'dense' or 'incremental'; "
                         f"got {radio_mode!r}")
    incremental = radio_mode == "incremental"
    if incremental and per_tti_fading:
        raise ValueError(
            "radio_mode='incremental' is incompatible with per_tti_fading: "
            "a per-TTI fading redraw dirties every UE row every TTI, so "
            "the dense recompute IS the minimal update")
    frac_on = (mobility_step_m is not None and mobility_move_frac is not None
               and mobility_move_frac < 1.0)
    n_move = (max(1, int(round(mobility_move_frac * n_ues))) if frac_on
              else n_ues)

    def use_rs(power_act: bool) -> bool:
        """Does this specialisation run on a RadioState?  Incremental mode
        with something to update: in-scan mobility dirt, or a power action
        whose chain is initialised once at prepare time.  The state is
        *carried* only when mobility mutates it; a static-geometry action
        chain is loop-invariant and rides the hoisted constants instead
        (a pass-through carry would defeat XLA's loop-invariant hoisting
        of the downstream MAC subexpressions -- measured 2x per TTI)."""
        return incremental and (not static_geom or power_act)

    # -- mesh layout (None = single device, the exact legacy program) ------
    if mesh is not None:
        ue_axes = (ue_axis,) if isinstance(ue_axis, str) else tuple(ue_axis)
        n_shards = 1
        for ax in ue_axes:
            n_shards *= mesh.shape[ax]
        if n_ues % n_shards:
            raise ValueError(
                f"n_ues={n_ues} must divide evenly over the {n_shards} "
                f"shards of mesh axes {ue_axes}")
    else:
        ue_axes, n_shards = None, 1

    n_loc = n_ues // n_shards        # rows owned by one shard (= n_ues unsharded)

    def local_offset():
        """Global UE index of this shard's first row (0 unsharded)."""
        return 0 if ue_axes is None else _axis_index(ue_axes) * n_loc

    def local_rows(x):
        """Slice a global-UE-axis array to this shard's contiguous block.

        Per-UE randomness is always drawn at *global* shape from the
        episode's key stream and then sliced, so shard s consumes exactly
        the rows it would own on a single device -- this is what makes the
        sharded rollout match the single-device one.  Identity when
        unsharded.
        """
        if ue_axes is None:
            return x
        return jax.lax.dynamic_slice_in_dim(x, local_offset(), n_loc, axis=0)

    def unfaded_gain(U, C, bore):
        return radio.pathgains(cfg, U, C, bore)

    def draw_fading(key):
        """Fresh per-TTI fading (global draw, local slice when sharded)."""
        return local_rows(radio.draw_fading(cfg, key, n_ues, n_cells))

    def faded_rsrp(G0, P, fad):
        return radio.rsrp(radio.apply_fading(G0, fad), P)

    def sinr_chain(R, a):
        """(se, cqi, a) for serving assignment ``a``."""
        gamma, _, _ = radio.sinr(R, a, noise_w)
        se, cqi = radio.se_chain(cfg, gamma)
        return se, cqi, a

    def gather_serving(se_all, cqi_all, a):
        """(se, cqi) rows of the per-candidate-cell tables at serving
        ``a`` -- the two-gather handover read shared by the hoisted dense
        tables and the incremental RadioState."""
        sel = a[:, None, None]
        return (jnp.take_along_axis(se_all, sel, axis=1)[:, 0],
                jnp.take_along_axis(cqi_all, sel, axis=1)[:, 0])

    # -- incremental (smart-update-in-scan) helpers ------------------------
    def inc_fad(static):
        """The fading tensor the incremental chain consumes: ``None`` on
        the unfaded channel (``G0 * ones == G0`` bitwise; eliding the
        ones gather/multiply is pure profit on the 100k-row hot path)."""
        return static.fad if p.rayleigh_fading else None

    def init_rs(static, U, action):
        """Prepare-time ``radio.RadioState``: the everything-dirty base
        case, computed once outside the scan.  A power ``action`` is
        scan-constant, so this is also where its cell dirt is absorbed
        (the scan body then only patches mobility rows)."""
        P = static.P if action is None else action
        return radio.radio_init(cfg, U, static.C, static.bore,
                                inc_fad(static), P, with_tables=ho_on)

    def walk_displacements(k_mob):
        """This TTI's per-row displacement + the window start (local rows).

        ``mobility_move_frac`` set: the exact-count window-mover draw
        (global draw, per-shard reconstruction).  Unset: the legacy
        every-UE walk (start None = all rows dirty) -- the PR-4 stream,
        bit-untouched.
        """
        if frac_on:
            start, d = mobility.window_movers(k_mob, n_ues, n_move,
                                              mobility_step_m)
            rows = local_offset() + jnp.arange(n_loc)
            d_loc, _ = mobility.window_displacements(start, d, rows, n_ues)
            return d_loc, start
        d = local_rows(mobility.walk_steps(k_mob, n_ues, mobility_step_m))
        return d, None

    def window_dirty_indices(start):
        """The mover window's local dirty rows, enumerated in O(n_move).

        The generic mask path (``radio.dirty_indices``) pays an O(n_ues)
        compaction per TTI -- measurably the incremental path's largest
        fixed cost at 100k UEs.  The window movers are *contiguous* global
        indices, so each of the ``n_move`` window slots maps straight to a
        local row: out-of-shard slots pad with row 0, THE idempotent
        valid-index padding of the dirtiness convention.  When the window
        covers the shard (n_move >= n_loc) every local row recomputes.

        Returns ``(idx, count)``: the padded local index vector plus the
        number of genuinely dirty local rows (= distinct recomputed rows;
        the telemetry ``dirty_rows`` counter, psummed to the global
        ``n_move`` under a mesh).
        """
        if n_move >= n_loc:
            return (jnp.arange(n_loc, dtype=jnp.int32),
                    jnp.int32(n_loc))
        g = (start + jnp.arange(n_move, dtype=jnp.int32)) % n_ues
        local = g - local_offset()
        valid = (local >= 0) & (local < n_loc)
        return (jnp.where(valid, local, 0).astype(jnp.int32),
                valid.sum().astype(jnp.int32))

    def inc_channel(static, rs, U, P, k_mob):
        """One incremental TTI of the radio chain: move, patch, read.

        Only the moved rows re-run D→G→RSRP→SINR→CQI→SE
        (``radio.radio_update_rows`` under THE dirtiness convention);
        everything else is a carried value that a dense recompute would
        reproduce bit-identically.  Returns the updated ``(U, rs)`` plus
        the local dirty-row count (dead code unless telemetry is on).
        """
        n_dirty = jnp.int32(0)
        if mobility_step_m is not None:
            d, start = walk_displacements(k_mob)
            U = mobility.apply_walk(U, d, p.extent_m)
            if start is None:
                idx = jnp.arange(n_loc, dtype=jnp.int32)
                n_dirty = jnp.int32(n_loc)
            else:
                idx, n_dirty = window_dirty_indices(start)
            rs = radio.radio_update_rows(cfg, rs, U, static.C, static.bore,
                                         inc_fad(static), P, idx)
        return U, rs, n_dirty

    def allocate(se, cqi, a, buf, avg, cursor, harq_pending):
        demand = (buf[:, None] > 0.0) | harq_pending[:, None]
        active = demand & (se > 0.0)
        log_w = mac_sched.pf_log_weights_ewma(rb_bw * se, avg[:, None],
                                              p.fairness_p)
        return mac_sched.allocate(policy, active, cqi, a, n_cells, rb_chunk,
                                  cursor, log_w, ue_axes)

    def harq_step(k_harq, tb_new, hbits, hretx, granted):
        """One TTI of every UE's stop-and-wait process.

        Pending UEs retransmit their stored TB (no new buffer drain) --
        but only when the scheduler actually granted them RBs this TTI
        (``granted``); an ungranted pending TB waits, state unchanged.
        Fresh TBs enter the machine on failure and drop after
        ``max_retx`` retransmissions.  The retx TB is delivered at its
        stored size (real HARQ retransmits the same TB; the grant-size
        mismatch is absorbed by the soft-combining abstraction).

        The fifth return is the TTI's KPI tuple
        ``(acks, nacks, retx, dropped_bits)`` -- computed from the masks
        the machine already holds, so it is dead code (XLA DCE) unless
        telemetry consumes it.
        """
        pending = hbits > 0.0
        tb = jnp.where(pending, hbits, tb_new)
        attempting = granted & (tb > 0.0)
        attempt = jnp.where(pending, hretx, 0)
        p_fail = harq_fail_prob(bler, comb_db, attempt)
        u = local_rows(jax.random.uniform(k_harq, (n_ues,)))
        ok = (u >= p_fail) & attempting
        fail = ~ok & attempting
        n_fail = attempt + 1
        keep = (fail & (n_fail <= max_retx)) | (pending & ~granted)
        delivered = jnp.where(ok, tb, 0.0)
        stats = (ok.sum().astype(jnp.int32),
                 fail.sum().astype(jnp.int32),
                 (pending & attempting).sum().astype(jnp.int32),
                 jnp.where(fail & (n_fail > max_retx), tb, 0.0).sum())
        hbits = jnp.where(keep, tb, 0.0)
        hretx = jnp.where(keep, jnp.where(fail, n_fail, hretx), 0)
        return delivered, pending, hbits, hretx, stats

    def prepare(static, U, power_act: bool):
        """Hoistable constants of the static-geometry regime.

        Everything here is loop-invariant: ``rollout`` evaluates it once,
        outside the scan.  With a power ``action`` the P-dependent tables
        are skipped (the per-TTI chain recomputes from the action); only
        the unfaded gain -- pure geometry -- survives hoisting.
        """
        h = {}
        if use_rs(power_act):
            # the incremental path hoists through its RadioState instead
            return h
        if static_geom and (per_tti_fading or ho_on or power_act):
            # static geometry: one unfaded gain/attachment pass, hoisted
            # out of the scan; only the fading factor varies per TTI.
            h["G"] = unfaded_gain(U, static.C, static.bore)
            if not power_act:
                R_mean = radio.rsrp(h["G"], static.P)
                h["R_mean"] = R_mean
                h["a"] = radio.attachment(R_mean) if attach_on_mean else None
                R_faded = faded_rsrp(h["G"], static.P, static.fad)
                # A3 measures long-term RSRP iff association does (same
                # convention as the dynamic paths' R_meas)
                h["meas_wb"] = (R_mean if attach_on_mean
                                else R_faded).sum(axis=-1)
                if ho_on:
                    # static channel + evolving serving cell: tabulate the
                    # SINR chain for EVERY candidate cell once, outside the
                    # scan -- per TTI the chain is then two gathers on
                    # (n_ue, n_freq) instead of an (n_ue, n_cell, n_freq)
                    # reduction.
                    total = R_faded.sum(axis=1)
                    gamma_all = R_faded / (
                        noise_w + (total[:, None, :] - R_faded))
                    se_all, cqi_all = radio.se_chain(cfg, gamma_all)
                    h["cqi_all"], h["se_all"] = cqi_all, se_all
        return h

    def tti_step(h, static, state, action, rs=None):
        """One pure TTI: (hoisted, static, state, action, radio-state) ->
        (state, tput, radio-state, telemetry).  ``rs`` is the incremental
        path's carried ``radio.RadioState`` (None on the dense paths,
        threaded unchanged); telemetry is None unless built with
        ``telemetry=True``."""
        power_act = action is not None
        U, buf, avg = state.U, state.backlog, state.pf_avg
        cursor, key = state.rr_cursor, state.key
        hbits, hretx, a_srv, ttt, t = (state.harq_bits, state.harq_retx,
                                       state.serving, state.ttt, state.t)
        prev_srv = a_srv
        P = action if power_act else static.P
        k_mob, k_fad, k_tr, k_harq = radio.tti_keys(key, t)
        n_dirty = jnp.int32(0) if incremental else None
        # -- channel: incremental state (carried or hoisted), per-TTI
        # recompute, or the hoisted dense constants -------------------------
        r = rs if rs is not None else h.get("rs")
        if r is not None:
            if rs is not None:              # carried: mobility dirties rows
                U, r, n_dirty = inc_channel(static, r, U, P, k_mob)
                rs = r
            if ho_on:
                a_srv, ttt = a3_handover(a_srv, ttt, r.meas, hyst_db,
                                         ttt_tti)
                a_use = a_srv
                se, cqi = gather_serving(r.se_all, r.cqi_all, a_use)
            else:
                se, cqi, a_use = r.se, r.cqi, r.a
        elif mobility_step_m is not None:
            # random-walk displacement, clamped at the region border
            # (global draw, local slice when sharded)
            d, _ = walk_displacements(k_mob)
            U = mobility.apply_walk(U, d, p.extent_m)
            G0 = unfaded_gain(U, static.C, static.bore)
            fad = draw_fading(k_fad) if per_tti_fading else static.fad
            R = faded_rsrp(G0, P, fad)
            R_meas = radio.rsrp(G0, P) if attach_on_mean else R
            a_inst = radio.attachment(R_meas)
        elif per_tti_fading or power_act:
            fad = draw_fading(k_fad) if per_tti_fading else static.fad
            R = faded_rsrp(h["G"], P, fad)
            if power_act:
                R_meas = radio.rsrp(h["G"], P) if attach_on_mean else R
                a_inst = radio.attachment(R_meas)
            else:
                R_meas = h["R_mean"] if attach_on_mean else R
                a_inst = h["a"] if attach_on_mean else radio.attachment(R)
        else:
            R = R_meas = a_inst = None   # fully static radio chain

        # -- serving cell: A3 carried state, or instantaneous argmax ------
        # (the incremental branch above already resolved se/cqi/a_use)
        if r is None:
            if ho_on:
                meas_wb = (R_meas.sum(axis=-1) if R_meas is not None
                           else h["meas_wb"])
                a_srv, ttt = a3_handover(a_srv, ttt, meas_wb, hyst_db,
                                         ttt_tti)
                a_use = a_srv
                if R is not None:
                    se, cqi, _ = sinr_chain(R, a_use)
                else:
                    # static channel, evolving attachment: gather from the
                    # hoisted all-cells SINR-chain tables
                    se, cqi = gather_serving(h["se_all"], h["cqi_all"],
                                             a_use)
            elif R is not None:
                se, cqi, a_use = sinr_chain(R, a_inst)
            else:
                se, cqi, a_use = static.se, static.cqi, static.a

        # -- MAC: traffic -> grant -> HARQ -> drain ------------------------
        buf = buf + local_rows(traffic_step(k_tr, t))
        harq_pending = (hbits > 0.0) if harq_on else \
            jnp.zeros_like(buf, dtype=bool)
        alloc = allocate(se, cqi, a_use, buf, avg, cursor, harq_pending)
        drainable = jnp.where(harq_pending, 0.0, buf)
        tb_new = mac_sched.served_bits(alloc, se, drainable, rb_bw,
                                       tti_s).sum(1)
        hstats = None
        if harq_on:
            bits, _, hbits, hretx, hstats = harq_step(
                k_harq, tb_new, hbits, hretx, alloc.sum(axis=1) > 0.0)
        elif bler > 0.0:   # HARQ-lite: lost blocks stay queued -> retx
            bits = tb_new * local_rows(jax.random.bernoulli(
                k_harq, 1.0 - bler, (n_ues,))).astype(tb_new.dtype)
        else:
            bits = tb_new
        # clamp: served_bits <= backlog only up to float rounding
        if harq_on:
            buf = jnp.maximum(buf - tb_new, 0.0)  # drain on first tx
        else:
            buf = jnp.maximum(buf - bits, 0.0)
        tput = bits / tti_s
        avg = (1.0 - beta) * avg + beta * tput
        state = EpisodeState(U, buf, avg, cursor + rb_chunk, key,
                             hbits, hretx, a_srv, ttt, t + 1)
        telem = None
        if telemetry:
            # KPIs only from values computed above: no PRNG, no carry.
            if hstats is None:
                acks = (bits > 0.0).sum().astype(jnp.int32)
                nacks = (((tb_new > 0.0) & (bits == 0.0)).sum()
                         .astype(jnp.int32) if bler > 0.0 else jnp.int32(0))
                hstats = (acks, nacks, jnp.int32(0), jnp.float32(0.0))
            ho_fired = ((a_srv != prev_srv).sum().astype(jnp.int32)
                        if ho_on else jnp.int32(0))
            telem = tti_telemetry(n_cells, n_ues, a_use, alloc, bits, tput,
                                  buf, hstats, ho_fired, n_dirty, ue_axes)
        return state, tput, rs, telem

    def setup(static, U, action):
        """(hoisted constants, carried RadioState) for one specialisation.

        The incremental modes split on loop-variance: a mobility episode's
        RadioState mutates per TTI (scan carry ``rs0``); a static-geometry
        action chain is computed once and *closed over* (``h["rs"]``) so
        XLA hoists every downstream loop-invariant subexpression exactly
        as it does for the dense hoisted tables.
        """
        h = prepare(static, U, action is not None)
        rs0 = None
        if use_rs(action is not None):
            if static_geom:
                h["rs"] = init_rs(static, U, action)
            else:
                rs0 = init_rs(static, U, action)
        return h, rs0

    # ------------------------------------------------------- single device
    if mesh is None:
        def step(static, state, action=None):
            h, rs0 = setup(static, state.U, action)
            state, tput, _, telem = tti_step(h, static, state, action, rs0)
            return (state, tput, telem) if telemetry else (state, tput)

        def rollout(static, state, n_tti, action=None):
            h, rs0 = setup(static, state.U, action)

            def body(carry, _):
                s, rs = carry
                s, tput, rs, telem = tti_step(h, static, s, action, rs)
                return (s, rs), ((tput, telem) if telemetry else tput)

            (state, _), ys = jax.lax.scan(body, (state, rs0), None,
                                          length=n_tti)
            if telemetry:
                tput, telem = ys
                return state, tput, telem
            return state, ys

        return EpisodeFns(step=jax.jit(step),
                          rollout=jax.jit(rollout, static_argnums=(2,)))

    # ------------------------------------------------------- mesh sharded
    # pytree-structured PartitionSpecs: UE axes sharded, cells replicated
    ue = PSpec(ue_axes)
    fad_spec = (PSpec(ue_axes, None, None)
                if p.rayleigh_fading and p.n_rb_subbands > 1
                else PSpec(ue_axes, None))
    static_specs = EpisodeStatic(
        se=PSpec(ue_axes, None), cqi=PSpec(ue_axes, None), a=ue,
        C=PSpec(None, None), P=PSpec(None, None), bore=PSpec(None),
        fad=fad_spec)
    state_specs = EpisodeState(
        U=PSpec(ue_axes, None), backlog=ue, pf_avg=ue, rr_cursor=PSpec(),
        key=PSpec(None), harq_bits=ue, harq_retx=ue, serving=ue, ttt=ue,
        t=PSpec())
    # telemetry leaves leave the shard_map fully replicated: every KPI is
    # psum-reduced inside tti_telemetry, so each shard holds the global
    # value.  The None leaf (dirty_rows outside incremental mode) must be
    # None in the spec tree too -- shard_map matches treedefs exactly.
    telem_specs = Telemetry(
        served_bits=PSpec(None), granted_rb=PSpec(None),
        harq_acks=PSpec(), harq_nacks=PSpec(), harq_retx=PSpec(),
        dropped_bits=PSpec(), ho_events=PSpec(), buffer_bits=PSpec(),
        jain=PSpec(), dirty_rows=PSpec() if incremental else None)
    # stacked (n_tti, ...) variant for the rollout's scan output
    telem_stack_specs = Telemetry(
        served_bits=PSpec(None, None), granted_rb=PSpec(None, None),
        harq_acks=PSpec(None), harq_nacks=PSpec(None),
        harq_retx=PSpec(None), dropped_bits=PSpec(None),
        ho_events=PSpec(None), buffer_bits=PSpec(None),
        jain=PSpec(None), dirty_rows=PSpec(None) if incremental else None)

    def revar(state):
        """Re-establish the claimed replication of the scalar carry slots.

        The scan carry is typed device-varying as a whole (``pvary``), but
        the scalar slots (cursor, key, t) evolve identically on every
        shard; a ``pmax`` both proves and restores their replication so
        they can leave the shard_map under a replicated out-spec.  No-ops
        on jax versions without varying-type tracking.
        """
        fix = lambda x: jax.lax.pmax(x, ue_axes)
        return state._replace(rr_cursor=fix(state.rr_cursor),
                              key=fix(state.key), t=fix(state.t))

    def sharded(fn, in_specs, out_specs):
        # replication checking must be off: the traffic models' poisson
        # sampler carries a while_loop, for which jax's rep-checker has no
        # rule.  The kwarg spelling differs across jax versions.
        for kw in ({"check_rep": False}, {"check_vma": False}, {}):
            try:
                return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kw)
            except TypeError:       # pragma: no cover - version dependent
                continue

    def step(static, state, action=None):
        def one(static, state, *act):
            state = jax.tree_util.tree_map(
                lambda x: _pvary(x, ue_axes), state)
            h, rs0 = setup(static, state.U, act[0] if act else None)
            state, tput, _, telem = tti_step(h, static, state,
                                             act[0] if act else None, rs0)
            if telemetry:
                return revar(state), tput, telem
            return revar(state), tput

        act_spec = () if action is None else (PSpec(None, None),)
        out_specs = ((state_specs, ue, telem_specs) if telemetry
                     else (state_specs, ue))
        f = sharded(one, (static_specs, state_specs) + act_spec, out_specs)
        args = (static, state) if action is None else (static, state, action)
        return f(*args)

    def rollout(static, state, n_tti, action=None):
        def roll(static, state, *act):
            init = jax.tree_util.tree_map(
                lambda x: _pvary(x, ue_axes), state)
            h, rs0 = setup(static, init.U, act[0] if act else None)

            def body(carry, _):
                s, rs = carry
                s, tput, rs, telem = tti_step(h, static, s,
                                              act[0] if act else None, rs)
                return (s, rs), ((tput, telem) if telemetry else tput)

            (state, _), ys = jax.lax.scan(body, (init, rs0), None,
                                          length=n_tti)
            if telemetry:
                tput, telem = ys
                return revar(state), tput, telem
            return revar(state), ys

        act_spec = () if action is None else (PSpec(None, None),)
        out_specs = ((state_specs, PSpec(None, ue_axes), telem_stack_specs)
                     if telemetry else (state_specs, PSpec(None, ue_axes)))
        f = sharded(roll, (static_specs, state_specs) + act_spec, out_specs)
        args = (static, state) if action is None else (static, state, action)
        return f(*args)

    return EpisodeFns(step=jax.jit(step),
                      rollout=jax.jit(rollout, static_argnums=(2,)))


def episode_fns_for(sim, *, mobility_step_m=None, per_tti_fading=False,
                    use_harq=None, mesh=None, ue_axis=("ue",),
                    radio_mode=None, mobility_move_frac=None,
                    telemetry: bool = False) -> EpisodeFns:
    """The :func:`make_episode_fns` bundle for ``sim``, cached on it.

    Keyed by the trace-time switches only -- ``n_tti`` and the presence of
    a power action specialise through the jit cache of the returned
    functions, so repeat episodes of any length reuse one ``EpisodeFns``.
    ``mobility_step_m=None`` falls back to the simulator's
    ``params.mobility_step_m`` (scenario presets with a baked-in mobility
    trajectory); pass ``0`` to force the static-geometry program.
    ``radio_mode``/``mobility_move_frac`` fall back to the corresponding
    ``CRRM_parameters`` fields the same way.
    """
    if mobility_step_m is None:
        mobility_step_m = getattr(sim.params, "mobility_step_m", None)
    if not mobility_step_m:          # 0 / None -> static geometry
        mobility_step_m = None
    if radio_mode is None:
        radio_mode = getattr(sim.params, "radio_mode", "dense")
    if mobility_move_frac is None:
        mobility_move_frac = getattr(sim.params, "mobility_move_frac", None)
    ue_axis = (ue_axis,) if isinstance(ue_axis, str) else tuple(ue_axis)
    cache_key = (mobility_step_m, per_tti_fading, use_harq, mesh, ue_axis,
                 radio_mode, mobility_move_frac, telemetry)
    cache = sim.__dict__.setdefault("_episode_fns_cache", {})
    if cache_key not in cache:
        cache[cache_key] = make_episode_fns(
            sim.params, sim.n_ues, sim.n_cells, sim.radio_config(),
            sim._traffic_step, mobility_step_m=mobility_step_m,
            per_tti_fading=per_tti_fading, use_harq=use_harq,
            mesh=mesh, ue_axis=ue_axis, radio_mode=radio_mode,
            mobility_move_frac=mobility_move_frac, telemetry=telemetry)
    return cache[cache_key]


def run_episode(sim, n_tti: int, key=None, mobility_step_m=None,
                per_tti_fading: bool = False, sync_state: bool = True,
                use_harq=None, mesh=None, radio_mode=None,
                mobility_move_frac=None, telemetry: bool = False):
    """Run ``n_tti`` TTIs; returns (n_tti, n_ues) delivered throughput
    (bits/s) -- or ``(tput, telem)`` with ``telemetry=True``, where
    ``telem`` is the stacked per-TTI :class:`repro.obs.telemetry.Telemetry`
    (``repro.obs.summarize`` reduces it to a KPI dict).

    A thin wrapper over the functional API: ``sim.init_episode_state(key)``
    -> ``rollout`` -> ``sim.sync_episode_state``.  The PF average-rate
    state is seeded from the single-shot graph's served throughput (the
    stationary alpha-fair point), so a full-buffer PF episode starts --
    and, with a static channel, stays -- at the legacy ``ThroughputNode``
    fixed point.  ``sync_state`` (legacy; functional callers thread
    :class:`EpisodeState` instead) writes the final buffers / PF state /
    positions / HARQ processes / serving cells back into the graph so
    subsequent single-shot queries and episodes continue from the episode's
    end state.  ``mesh`` runs the rollout shard_mapped over the UE axis.
    """
    fns = episode_fns_for(sim, mobility_step_m=mobility_step_m,
                          per_tti_fading=per_tti_fading, use_harq=use_harq,
                          mesh=mesh, radio_mode=radio_mode,
                          mobility_move_frac=mobility_move_frac,
                          telemetry=telemetry)
    state = sim.init_episode_state(key)
    static = sim.episode_static()
    telem = None
    if telemetry:
        state, tput, telem = fns.rollout(static, state, n_tti)
    else:
        state, tput = fns.rollout(static, state, n_tti)
    if mobility_step_m is None:
        mobility_step_m = getattr(sim.params, "mobility_step_m", None)
    if sync_state:
        sim.sync_episode_state(state, positions=bool(mobility_step_m))
    return (tput, telem) if telemetry else tput
