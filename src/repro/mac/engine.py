"""The scan-compiled TTI engine: a whole episode as ONE compiled program.

The smart-update graph is built for sparse, event-driven mutation (move a
few UEs, re-query).  Time-stepped MAC simulation is the opposite regime:
*every* TTI touches *every* UE's buffer, so per-TTI Python dispatch over the
node graph would dominate.  This module re-expresses one TTI as a pure
function of an explicit :class:`EpisodeState` pytree

    (positions, backlog_bits, pf_avg_rate, rr_cursor, key,
     harq_bits, harq_retx, serving_cell, ttt, t)

and rolls N TTIs with ``jax.lax.scan``: one trace, one XLA program, zero
per-TTI Python (DESIGN.md §TTI-engine, §Env-API).  A 1000-UE x 1000-TTI
episode is a single device launch.

The episode API is pure-functional (DESIGN.md §Env-API):

* :class:`EpisodeState` -- everything the scan carry needs, as a pytree.
  ``CRRM.init_episode_state(key)`` gathers it from the graph;
* :class:`EpisodeStatic` -- the per-episode radio inputs (cached SE/CQI/
  attachment plus the C/P/boresight/fading roots).  ``CRRM.episode_static()``
  reads them off the graph;
* :func:`make_episode_fns` -- builds ``step(static, state, action)`` and
  ``rollout(static, state, n_tti, action)``, both jit- and vmap-compatible:
  batching N episodes over seeds is ``jax.vmap`` over ``state`` (and
  ``action``), and compiles to one program (``src/repro/env``).

``run_episode`` is a thin wrapper: init state -> rollout -> (optionally)
write the final state back into the graph.  The write-back (``sync_state``)
is retained for the paper's mutate/query workflow but is a legacy
convenience: functional callers thread :class:`EpisodeState` explicitly and
never touch simulator attributes.

Three orthogonal feature axes, each a trace-time (Python) switch so the
disabled configuration compiles to exactly the legacy program:

* frequency-selective link adaptation (``n_rb_subbands > 1``): the fading
  factor is a per-RB block-fading tensor pooled to CQI-subband resolution,
  so SE/CQI/alloc carry a (n_ues, n_freq) frequency axis and the schedulers
  pick *which* RBs each UE gets.  ``n_rb_subbands=1`` is the wideband path.
  ``cqi_report="wideband"`` decouples *reporting* from fading resolution:
  the channel stays selective but CQI/MCS collapse to one report per power
  subband (blocks._pool_report).
* stop-and-wait HARQ (``harq_bler > 0``): per-UE process state (pending TB
  bits, retx count) rides in the carry; failed TBs retransmit with a
  soft-combining SINR gain per attempt until ``harq_max_retx`` is exhausted.
  ``harq_bler=0`` compiles the HARQ-free fast path (bit-exact legacy).
* A3 handover (``ho_enabled``): the serving-cell vector ``a`` is carried
  state, updated when a neighbour beats the serving cell by
  ``ho_hysteresis_db`` for ``ho_ttt_tti`` consecutive TTIs.  Disabled, the
  serving cell is the instantaneous argmax (legacy).

Channel regimes:

* static (no mobility, no per-TTI fading, no power action): the radio chain
  (se, cqi, a) is read once from ``EpisodeStatic`` -- the scan body is
  MAC-only math;
* dynamic (``mobility_step_m`` set, ``per_tti_fading``, or a power
  ``action``): the radio chain is recomputed inside the scan from the same
  jitted block helpers the graph nodes use, so both paths share one
  implementation.  A non-None ``action`` is a per-episode (n_cells, n_freq)
  power matrix overriding ``static.P`` -- the RL power-control hook.

All mutable simulator state (positions, powers, fading, radio outputs)
enters the compiled episode as *arguments*, never as baked-in constants, so
mutating the graph between episodes behaves correctly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import blocks
from repro.mac import scheduler as mac_sched
from repro.sim import fading as fading_mod
from repro.sim import mobility


class EpisodeState(NamedTuple):
    """The full mutable state of a MAC episode, as an explicit pytree.

    Every field is a per-simulation array (no Python state), so the whole
    tuple can ride a ``lax.scan`` carry, be ``jax.vmap``ed over a batch
    axis (N parallel episodes), checkpointed, or handed to an external RL
    loop.  Constructed by ``CRRM.init_episode_state``; advanced by the pure
    ``step``/``rollout`` functions of :func:`make_episode_fns`.
    """

    U: Any           # (n_ues, 3) positions
    backlog: Any     # (n_ues,) queued bits (inf = full buffer)
    pf_avg: Any      # (n_ues,) PF EWMA average delivered rate, bits/s
    rr_cursor: Any   # i32 scalar: round-robin rotation state
    key: Any         # PRNG key; per-TTI streams are folded from (key, t)
    harq_bits: Any   # (n_ues,) f32 pending transport-block bits (0 = idle)
    harq_retx: Any   # (n_ues,) i32 retransmission count of the pending TB
    serving: Any     # (n_ues,) i32 serving-cell index (A3 carried state)
    ttt: Any         # (n_ues,) i32 A3 time-to-trigger counters
    t: Any           # i32 scalar: TTI index (drives PRNG folds + traffic)


class EpisodeStatic(NamedTuple):
    """Per-episode radio inputs: everything the step reads but never writes.

    The cached single-shot radio chain (``se``/``cqi``/``a`` -- used
    verbatim in the fully-static regime) plus the graph roots the dynamic
    regimes recompute from.  Read off the graph by ``CRRM.episode_static()``.
    """

    se: Any          # (n_ues, n_freq) spectral efficiency
    cqi: Any         # (n_ues, n_freq)
    a: Any           # (n_ues,) i32 attachment
    C: Any           # (n_cells, 3) cell positions
    P: Any           # (n_cells, n_freq) tx power
    bore: Any        # (n_cells,) sector boresights
    fad: Any         # (n_ues, n_cells[, n_freq]) fading factor


class EpisodeFns(NamedTuple):
    """The pure episode API for one engine configuration (jit-compiled).

    ``step(static, state, action=None) -> (state, tput)`` advances one TTI;
    ``rollout(static, state, n_tti, action=None) -> (state, tput)`` scans
    ``n_tti`` TTIs (``tput`` stacked to (n_tti, n_ues)).  ``action`` is an
    optional (n_cells, n_freq) power matrix overriding ``static.P`` (a
    trace-time switch: None compiles the legacy program).  Both functions
    are pure and vmap over ``state``/``action`` for batched episodes.
    """

    step: Any
    rollout: Any


def harq_fail_prob(bler, comb_gain_db, retx):
    """Conditional failure probability of HARQ attempt number ``retx``.

    ``retx`` prior (failed) copies are soft-combined, boosting effective
    SINR by ``comb_gain_db`` dB each; in the Rayleigh outage regime
    P(fail) ~ theta/SNR, so the conditional BLER divides by the linear gain
    per retransmission: ``bler / 10^(retx * gain_db / 10)``.  Monotone
    non-increasing in ``retx`` (tested in tests/test_mac_engine.py).
    """
    gain = 10.0 ** (comb_gain_db / 10.0)
    return jnp.clip(bler * gain ** (-retx.astype(jnp.float32)), 0.0, 1.0)


def a3_handover(a, ttt, rsrp_wb, hyst_db, ttt_tti):
    """One TTI of the A3 trigger: (serving, time-to-trigger) -> updated.

    Event A3 enters when the best neighbour's wideband RSRP exceeds the
    serving cell's by ``hyst_db``; the counter must stay entered for
    ``ttt_tti`` consecutive TTIs before the UE hands over to that
    neighbour.  Leaving the condition resets the counter (3GPP 38.331
    semantics, collapsed to one measurement per TTI).
    """
    serving = jnp.take_along_axis(rsrp_wb, a[:, None], axis=1)[:, 0]
    best = jnp.argmax(rsrp_wb, axis=1).astype(a.dtype)
    best_val = rsrp_wb.max(axis=1)
    hyst = 10.0 ** (hyst_db / 10.0)
    entered = (best_val > serving * hyst) & (best != a)
    ttt = jnp.where(entered, ttt + 1, 0)
    fire = ttt >= ttt_tti
    a = jnp.where(fire, best, a)
    ttt = jnp.where(fire, 0, ttt)
    return a, ttt


def make_episode_fns(params, n_ues: int, n_cells: int, gain_full,
                     traffic_step, *, mobility_step_m=None,
                     per_tti_fading: bool = False,
                     use_harq=None) -> EpisodeFns:
    """Build the pure ``step``/``rollout`` functions for one configuration.

    ``params`` is a ``CRRM_parameters``; ``gain_full`` the jitted unfaded
    gain closure (``GainNode._full``) and ``traffic_step`` the traffic
    model's arrival function -- both pure, so the returned functions are
    too.  ``use_harq`` forces the HARQ state machine on/off regardless of
    ``harq_bler`` (None = auto: on iff ``harq_bler > 0``); forcing it on at
    ``harq_bler=0`` is the equivalence-testing hook -- the machine must
    then reproduce the fast path bit-exactly.

    The trace-time feature switches (mobility / per-TTI fading / HARQ /
    handover / per-RB grid) are baked here; ``n_tti`` and the presence of
    an ``action`` specialise via the jit cache on the returned functions.
    """
    p = params
    tti_s, beta = p.tti_s, p.pf_ewma
    n_freq, rb_chunk = p.n_freq, p.rb_per_chunk
    rb_bw = p.subband_bandwidth_Hz / p.n_rb     # physical RB bandwidth
    policy, bler = p.scheduler_policy, p.harq_bler
    harq_on = bler > 0.0 if use_harq is None else bool(use_harq)
    max_retx, comb_db = p.harq_max_retx, p.harq_comb_gain_db
    ho_on = p.ho_enabled
    hyst_db, ttt_tti = p.ho_hysteresis_db, p.ho_ttt_tti
    noise_w = p.chunk_noise_W
    attach_on_mean = p.rayleigh_fading and p.attach_ignores_fading
    report_wb = p.cqi_report == "wideband"
    n_rb_sb = p.n_rb_subbands
    static_geom = mobility_step_m is None

    def cqi_of(gamma):
        """CQI at the configured reporting resolution (DESIGN.md)."""
        return blocks._cqi_report(gamma, n_rb_sb, report_wb,
                                  p.cqi_eesm_beta)

    def unfaded_gain(U, C, bore):
        d2d, d3d, az = blocks._geometry(U, C)
        return gain_full(U, C, d2d, d3d, az, bore,
                         jnp.ones((n_ues, n_cells), jnp.float32))

    def draw_fading(key):
        """Fresh per-TTI fading at the engine's frequency resolution."""
        if n_rb_sb > 1:
            return fading_mod.subband_rayleigh_power(
                key, n_ues, n_cells, p.n_subbands * p.n_rb, p.coherence_rb,
                n_freq)
        return fading_mod.rayleigh_power(key, (n_ues, n_cells))

    def faded_rsrp(G0, P, fad):
        """RSRP from unfaded gain: broadcasts wideband or per-RB fading."""
        G = G0[..., None] * fad if fad.ndim == 3 else G0 * fad
        return blocks._rsrp(G, P)

    def sinr_chain(R, a):
        """(se, cqi, a) for serving assignment ``a``."""
        w = blocks._wanted(R, a)
        u = blocks._interference(R, w)
        gamma = w / (noise_w + u)
        cqi = cqi_of(gamma)
        se = blocks._se(blocks._mcs(cqi), cqi)
        return se, cqi, a

    def allocate(se, cqi, a, buf, avg, cursor, harq_pending):
        demand = (buf[:, None] > 0.0) | harq_pending[:, None]
        active = demand & (se > 0.0)
        log_w = mac_sched.pf_log_weights_ewma(rb_bw * se, avg[:, None],
                                              p.fairness_p)
        return mac_sched.allocate(policy, active, cqi, a, n_cells, rb_chunk,
                                  cursor, log_w)

    def harq_step(k_harq, tb_new, hbits, hretx, granted):
        """One TTI of every UE's stop-and-wait process.

        Pending UEs retransmit their stored TB (no new buffer drain) --
        but only when the scheduler actually granted them RBs this TTI
        (``granted``); an ungranted pending TB waits, state unchanged.
        Fresh TBs enter the machine on failure and drop after
        ``max_retx`` retransmissions.  The retx TB is delivered at its
        stored size (real HARQ retransmits the same TB; the grant-size
        mismatch is absorbed by the soft-combining abstraction).
        """
        pending = hbits > 0.0
        tb = jnp.where(pending, hbits, tb_new)
        attempting = granted & (tb > 0.0)
        attempt = jnp.where(pending, hretx, 0)
        p_fail = harq_fail_prob(bler, comb_db, attempt)
        u = jax.random.uniform(k_harq, (n_ues,))
        ok = (u >= p_fail) & attempting
        fail = ~ok & attempting
        n_fail = attempt + 1
        keep = (fail & (n_fail <= max_retx)) | (pending & ~granted)
        delivered = jnp.where(ok, tb, 0.0)
        hbits = jnp.where(keep, tb, 0.0)
        hretx = jnp.where(keep, jnp.where(fail, n_fail, hretx), 0)
        return delivered, pending, hbits, hretx

    def prepare(static, U, power_act: bool):
        """Hoistable constants of the static-geometry regime.

        Everything here is loop-invariant: ``rollout`` evaluates it once,
        outside the scan.  With a power ``action`` the P-dependent tables
        are skipped (the per-TTI chain recomputes from the action); only
        the unfaded gain -- pure geometry -- survives hoisting.
        """
        h = {}
        if static_geom and (per_tti_fading or ho_on or power_act):
            # static geometry: one unfaded gain/attachment pass, hoisted
            # out of the scan; only the fading factor varies per TTI.
            h["G"] = unfaded_gain(U, static.C, static.bore)
            if not power_act:
                R_mean = blocks._rsrp(h["G"], static.P)
                h["R_mean"] = R_mean
                h["a"] = blocks._attach(R_mean) if attach_on_mean else None
                R_faded = faded_rsrp(h["G"], static.P, static.fad)
                # A3 measures long-term RSRP iff association does (same
                # convention as the dynamic paths' R_meas)
                h["meas_wb"] = (R_mean if attach_on_mean
                                else R_faded).sum(axis=-1)
                if ho_on:
                    # static channel + evolving serving cell: tabulate the
                    # SINR chain for EVERY candidate cell once, outside the
                    # scan -- per TTI the chain is then two gathers on
                    # (n_ue, n_freq) instead of an (n_ue, n_cell, n_freq)
                    # reduction.
                    total = R_faded.sum(axis=1)
                    gamma_all = R_faded / (
                        noise_w + (total[:, None, :] - R_faded))
                    h["cqi_all"] = cqi_of(gamma_all)
                    h["se_all"] = blocks._se(blocks._mcs(h["cqi_all"]),
                                             h["cqi_all"])
        return h

    def tti_step(h, static, state, action):
        """One pure TTI: (hoisted, static, state, action) -> (state, tput)."""
        power_act = action is not None
        U, buf, avg = state.U, state.backlog, state.pf_avg
        cursor, key = state.rr_cursor, state.key
        hbits, hretx, a_srv, ttt, t = (state.harq_bits, state.harq_retx,
                                       state.serving, state.ttt, state.t)
        P = action if power_act else static.P
        k_mob, k_fad, k_tr, k_harq = (jax.random.fold_in(key, 4 * t + i)
                                      for i in range(4))
        # -- channel: (R, R_meas) per TTI, or the hoisted constants --------
        if mobility_step_m is not None:
            idx = jnp.arange(n_ues)
            U = U.at[idx].set(mobility.random_walk(
                k_mob, U, idx, mobility_step_m, p.extent_m))
            G0 = unfaded_gain(U, static.C, static.bore)
            fad = draw_fading(k_fad) if per_tti_fading else static.fad
            R = faded_rsrp(G0, P, fad)
            R_meas = blocks._rsrp(G0, P) if attach_on_mean else R
            a_inst = blocks._attach(R_meas)
        elif per_tti_fading or power_act:
            fad = draw_fading(k_fad) if per_tti_fading else static.fad
            R = faded_rsrp(h["G"], P, fad)
            if power_act:
                R_meas = blocks._rsrp(h["G"], P) if attach_on_mean else R
                a_inst = blocks._attach(R_meas)
            else:
                R_meas = h["R_mean"] if attach_on_mean else R
                a_inst = h["a"] if attach_on_mean else blocks._attach(R)
        else:
            R = R_meas = a_inst = None   # fully static radio chain

        # -- serving cell: A3 carried state, or instantaneous argmax ------
        if ho_on:
            meas_wb = (R_meas.sum(axis=-1) if R_meas is not None
                       else h["meas_wb"])
            a_srv, ttt = a3_handover(a_srv, ttt, meas_wb, hyst_db, ttt_tti)
            a_use = a_srv
            if R is not None:
                se, cqi, _ = sinr_chain(R, a_use)
            else:
                # static channel, evolving attachment: gather from the
                # hoisted all-cells SINR-chain tables
                sel = a_use[:, None, None]
                se = jnp.take_along_axis(h["se_all"], sel, axis=1)[:, 0]
                cqi = jnp.take_along_axis(h["cqi_all"], sel, axis=1)[:, 0]
        elif R is not None:
            se, cqi, a_use = sinr_chain(R, a_inst)
        else:
            se, cqi, a_use = static.se, static.cqi, static.a

        # -- MAC: traffic -> grant -> HARQ -> drain ------------------------
        buf = buf + traffic_step(k_tr, t)
        harq_pending = (hbits > 0.0) if harq_on else \
            jnp.zeros((n_ues,), bool)
        alloc = allocate(se, cqi, a_use, buf, avg, cursor, harq_pending)
        drainable = jnp.where(harq_pending, 0.0, buf)
        tb_new = mac_sched.served_bits(alloc, se, drainable, rb_bw,
                                       tti_s).sum(1)
        if harq_on:
            bits, _, hbits, hretx = harq_step(
                k_harq, tb_new, hbits, hretx, alloc.sum(axis=1) > 0.0)
        elif bler > 0.0:   # HARQ-lite: lost blocks stay queued -> retx
            bits = tb_new * jax.random.bernoulli(
                k_harq, 1.0 - bler, (n_ues,)).astype(tb_new.dtype)
        else:
            bits = tb_new
        # clamp: served_bits <= backlog only up to float rounding
        if harq_on:
            buf = jnp.maximum(buf - tb_new, 0.0)  # drain on first tx
        else:
            buf = jnp.maximum(buf - bits, 0.0)
        tput = bits / tti_s
        avg = (1.0 - beta) * avg + beta * tput
        state = EpisodeState(U, buf, avg, cursor + rb_chunk, key,
                             hbits, hretx, a_srv, ttt, t + 1)
        return state, tput

    def step(static, state, action=None):
        h = prepare(static, state.U, action is not None)
        return tti_step(h, static, state, action)

    def rollout(static, state, n_tti, action=None):
        h = prepare(static, state.U, action is not None)

        def body(s, _):
            return tti_step(h, static, s, action)

        return jax.lax.scan(body, state, None, length=n_tti)

    return EpisodeFns(step=jax.jit(step),
                      rollout=jax.jit(rollout, static_argnums=(2,)))


def episode_fns_for(sim, *, mobility_step_m=None, per_tti_fading=False,
                    use_harq=None) -> EpisodeFns:
    """The :func:`make_episode_fns` bundle for ``sim``, cached on it.

    Keyed by the trace-time switches only -- ``n_tti`` and the presence of
    a power action specialise through the jit cache of the returned
    functions, so repeat episodes of any length reuse one ``EpisodeFns``.
    """
    cache_key = (mobility_step_m, per_tti_fading, use_harq)
    cache = sim.__dict__.setdefault("_episode_fns_cache", {})
    if cache_key not in cache:
        cache[cache_key] = make_episode_fns(
            sim.params, sim.n_ues, sim.n_cells, sim.G._full,
            sim._traffic_step, mobility_step_m=mobility_step_m,
            per_tti_fading=per_tti_fading, use_harq=use_harq)
    return cache[cache_key]


def run_episode(sim, n_tti: int, key=None, mobility_step_m=None,
                per_tti_fading: bool = False, sync_state: bool = True,
                use_harq=None):
    """Run ``n_tti`` TTIs; returns (n_tti, n_ues) delivered throughput
    (bits/s).

    A thin wrapper over the functional API: ``sim.init_episode_state(key)``
    -> ``rollout`` -> ``sim.sync_episode_state``.  The PF average-rate
    state is seeded from the single-shot graph's served throughput (the
    stationary alpha-fair point), so a full-buffer PF episode starts --
    and, with a static channel, stays -- at the legacy ``ThroughputNode``
    fixed point.  ``sync_state`` (legacy; functional callers thread
    :class:`EpisodeState` instead) writes the final buffers / PF state /
    positions / HARQ processes / serving cells back into the graph so
    subsequent single-shot queries and episodes continue from the episode's
    end state.
    """
    fns = episode_fns_for(sim, mobility_step_m=mobility_step_m,
                          per_tti_fading=per_tti_fading, use_harq=use_harq)
    state = sim.init_episode_state(key)
    static = sim.episode_static()
    state, tput = fns.rollout(static, state, n_tti)
    if sync_state:
        sim.sync_episode_state(state,
                               positions=mobility_step_m is not None)
    return tput
