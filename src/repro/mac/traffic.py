"""Vectorised traffic sources: offered load per UE per TTI.

Each model is a pair ``(init_backlog, step)`` of pure functions:

* ``init_backlog(n_ues) -> (n_ues,) float32`` -- the t=0 buffer contents in
  bits (``inf`` for full-buffer);
* ``step(key, t) -> (n_ues,) float32`` -- fresh arrival bits for one TTI,
  drawn from the PRNG key.  ``step`` is traceable, so it can run inside
  ``jax.lax.scan`` with zero per-TTI Python dispatch.

Models (3GPP TR 36.814-flavoured):

* ``full_buffer``   -- infinite backlog, no arrivals (the paper's implicit
  assumption; reproduces the legacy ``ThroughputNode`` regime);
* ``poisson``       -- independent Poisson packet arrivals per UE
  (small packets at a configurable mean rate);
* ``ftp3``          -- FTP model 3: Poisson *file* arrivals of a fixed
  (large) file size, the standard bursty-load benchmark.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TRAFFIC_MODELS = ("full_buffer", "poisson", "ftp3")


def make_traffic(name: str, n_ues: int, tti_s: float, *,
                 arrival_rate_hz: float = 200.0,
                 packet_size_bits: float = 12_000.0,
                 file_rate_hz: float = 0.5,
                 file_size_bits: float = 4_000_000.0):
    """Return ``(init_backlog, step)`` for the named model.

    ``poisson`` and ``ftp3`` share the Poisson-count x payload-size
    mechanic and differ in scale: many small packets vs few large files.
    """
    if name == "full_buffer":
        def init_backlog():
            return jnp.full((n_ues,), jnp.inf, dtype=jnp.float32)

        def step(key, t):
            return jnp.zeros((n_ues,), dtype=jnp.float32)

        return init_backlog, step

    if name == "poisson":
        lam, size = arrival_rate_hz * tti_s, packet_size_bits
    elif name == "ftp3":
        lam, size = file_rate_hz * tti_s, file_size_bits
    else:
        raise ValueError(
            f"unknown traffic model {name!r}; choose from {TRAFFIC_MODELS}")

    def init_backlog():
        return jnp.zeros((n_ues,), dtype=jnp.float32)

    def step(key, t):
        k = jax.random.fold_in(key, t)
        counts = jax.random.poisson(k, lam, (n_ues,))
        return counts.astype(jnp.float32) * size

    return init_backlog, step
