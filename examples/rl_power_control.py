"""RL-style power control against CRRM -- the paper's raison d'etre.

A small policy (pure JAX) controls each cell's per-subband transmit power;
REINFORCE maximises the env's *buffer-aware* MAC objective: each candidate
power plan is held for one episode of the scan-compiled TTI engine (Poisson
traffic, proportional-fair scheduling) and scored on the geometric-mean
served throughput minus a queueing penalty on the residual backlog.

Since the functional env API (DESIGN.md §Env-API) this is a pure-functional
loop: ``CrrmEnv.reset(key)`` returns an explicit episode-state pytree (no
private simulator attributes to reset by hand), and the whole REINFORCE
population -- all ``batch`` perturbed candidates -- is evaluated by ONE
``step_batch`` call: ``vmap`` turns the batch into a single compiled
program, so a training iteration is a single device launch.

Run:  PYTHONPATH=src python examples/rl_power_control.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import CRRM_parameters
from repro.env import CrrmEnv

N_UE, N_CELL, K, N_TTI = 60, 12, 2, 30
params = CRRM_parameters(n_ues=N_UE, n_cells=N_CELL, n_subbands=K,
                         pathloss_model_name="UMa", power_W=20.0, seed=3,
                         fairness_p=0.0, scheduler_policy="pf",
                         traffic_model="poisson",
                         traffic_params=dict(arrival_rate_hz=300.0,
                                             packet_size_bits=12_000.0))
# one env.step == one whole episode: the decision interval is the horizon
env = CrrmEnv(params, episode_tti=N_TTI, tti_per_step=N_TTI)
EP_KEY = jax.random.PRNGKey(7)          # frozen episode noise -> low variance
batch = 8
EP_KEYS = jnp.stack([EP_KEY] * batch)   # same episode for every candidate


def reward(power_matrix) -> float:
    """Roll one episode under the candidate power plan and score it."""
    state, _ = env.reset(EP_KEY)
    _, _, r, _ = env.step(state, power_matrix)
    return float(r)


def reward_batch(power_matrices):
    """All candidates at once: vmap compiles the batch to one program."""
    states, _ = env.reset_batch(EP_KEYS)
    _, _, rs, _ = env.step_batch(states, power_matrices)
    return np.asarray(rs)


base_pw = env.uniform_action()
r0 = reward(base_pw)
print(f"baseline buffer-aware reward (uniform power): {r0:+.3f}")


# policy: per (cell, subband) logits -> power levels via softmax budget split
def sample(key, theta, temp=0.3):
    noise = jax.random.normal(key, theta.shape) * temp
    logits = theta + noise
    alloc = jax.nn.softmax(logits.reshape(-1)).reshape(theta.shape)
    return 20.0 * N_CELL * alloc, noise


theta = jnp.zeros((N_CELL, K))
key = jax.random.PRNGKey(0)
lr = 2.0
r_base = r0
for it in range(25):
    key, *ks = jax.random.split(key, batch + 1)
    pws, noises = zip(*(sample(k, theta) for k in ks))
    rs = reward_batch(jnp.stack(pws))            # one launch, 8 episodes
    adv = jnp.asarray(rs) - r_base               # REINFORCE
    theta = theta + lr * (adv[:, None, None] * jnp.stack(noises)).mean(0)
    r_base = 0.9 * r_base + 0.1 * float(np.mean(rs))
    if (it + 1) % 5 == 0:
        pw, _ = sample(jax.random.PRNGKey(99), theta, temp=0.0)
        print(f"iter {it+1:3d}: mean episode reward {np.mean(rs):+.3f}  "
              f"greedy reward {reward(pw):+.3f}")

pw, _ = sample(jax.random.PRNGKey(99), theta, temp=0.0)
print(f"learned power plan improves buffer-aware reward "
      f"{r0:+.3f} -> {reward(pw):+.3f}")
