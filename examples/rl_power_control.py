"""RL-style power control against CRRM -- the paper's raison d'etre.

A small policy network (pure JAX) controls each cell's per-subband transmit
power; REINFORCE maximises the geometric-mean UE throughput (proportional
fairness objective).  Demonstrates the direct simulator <-> AI-framework
integration the paper targets: CRRM is differentiable-framework-adjacent,
lives in the same process, and its smart update makes per-episode
re-evaluation cheap.

Run:  PYTHONPATH=src python examples/rl_power_control.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

N_UE, N_CELL, K = 60, 12, 2
params = CRRM_parameters(n_ues=N_UE, n_cells=N_CELL, n_subbands=K,
                         pathloss_model_name="UMa", power_W=20.0, seed=3,
                         fairness_p=0.0)
sim = CRRM(params)
base = np.asarray(sim.get_UE_throughputs())
print(f"baseline geo-mean throughput: "
      f"{np.exp(np.log(np.maximum(base, 1e3)).mean())/1e6:.2f} Mb/s")


def reward(power_matrix) -> float:
    sim.set_power_matrix(power_matrix)
    t = np.asarray(sim.get_UE_throughputs())
    return float(np.log(np.maximum(t, 1e3)).mean())


# policy: per (cell, subband) logits -> power levels via softmax budget split
def sample(key, theta, temp=0.3):
    noise = jax.random.normal(key, theta.shape) * temp
    logits = theta + noise
    alloc = jax.nn.softmax(logits.reshape(-1)).reshape(theta.shape)
    return 20.0 * N_CELL * alloc, noise


theta = jnp.zeros((N_CELL, K))
key = jax.random.PRNGKey(0)
lr, batch = 2.0, 8
r_base = reward(np.full((N_CELL, K), 20.0 / K))
for it in range(25):
    grads, rs = jnp.zeros_like(theta), []
    for b in range(batch):
        key, k = jax.random.split(key)
        pw, noise = sample(k, theta)
        r = reward(np.asarray(pw))
        rs.append(r)
        grads = grads + (r - r_base) * noise   # REINFORCE
    theta = theta + lr * grads / batch
    r_base = 0.9 * r_base + 0.1 * float(np.mean(rs))
    if (it + 1) % 5 == 0:
        pw, _ = sample(jax.random.PRNGKey(99), theta, temp=0.0)
        print(f"iter {it+1:3d}: mean episode reward {np.mean(rs):+.3f}  "
              f"greedy geo-mean "
              f"{np.exp(reward(np.asarray(pw)))/1e6:.2f} Mb/s")

pw, _ = sample(jax.random.PRNGKey(99), theta, temp=0.0)
final = np.exp(reward(np.asarray(pw)))
print(f"learned power plan improves geo-mean throughput "
      f"{np.exp(np.log(np.maximum(base,1e3)).mean())/1e6:.2f} -> "
      f"{final/1e6:.2f} Mb/s")
