"""PPO power control against CRRM -- the paper's raison d'etre.

An MLP actor-critic (``repro.rl``) controls each cell's per-subband
transmit power.  PPO replaces the original REINFORCE loop of this
example: rollout collection is ONE compiled program (``jit(vmap)`` over
``n_envs`` auto-resetting episode streams of the scan-compiled TTI
engine), advantages come from GAE, and the update is the clipped
surrogate -- the full recipe behind ``benchmarks/BENCH_rl.json``.

The traffic is deliberately saturated (arrivals well past the serveable
load) so throughput is interference-limited: the policy has to learn
which cells' power to cut.  Every ``eval_every`` iterations the
deterministic (mean-action) policy is scored against the uniform
fixed-power plan on held-out seeds -- the uplift the bench gates.

Run:  PYTHONPATH=src python examples/rl_power_control.py
"""
import jax

from repro.rl import policy as pol
from repro.rl import ppo

out = ppo.train_power_baseline(
    "dense_urban",
    n_ues=12,                # sparse UEs, 21 cells: empty cells only jam
    arrival_rate_hz=2000.0,  # saturate -> power plan moves throughput
    iterations=45, eval_every=5, seed=0, verbose=True)

print(f"\nbest learned policy (iteration {out['best_iteration']}): "
      f"x{out['best_uplift']:.3f} served-throughput uplift over uniform "
      f"fixed power")

# what did it learn?  The deterministic plan for a fresh episode start.
env, pcfg = out["env"], out["pcfg"]
state, obs = env.reset(jax.random.PRNGKey(123))
power, _ = pol.mean_action(pcfg, out["best_params"],
                           pol.features(pcfg, obs))
print(f"\nper-cell learned power (W; uniform budget is "
      f"{env.max_cell_power_W:.2f} W/cell):")
print("  " + " ".join(f"{float(p):.2f}" for p in power.sum(-1)))
