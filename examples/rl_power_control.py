"""RL-style power control against CRRM -- the paper's raison d'etre.

A small policy network (pure JAX) controls each cell's per-subband transmit
power; REINFORCE maximises a *buffer-aware* MAC objective: each candidate
power plan is rolled through the scan-compiled TTI engine (Poisson traffic,
proportional-fair scheduling) and scored on the geometric-mean served
throughput minus a queueing penalty on the residual backlog.  Demonstrates
the direct simulator <-> AI-framework integration the paper targets: the
whole episode (traffic -> buffers -> scheduler -> HARQ-lite serving) is ONE
compiled program, so per-candidate evaluation is a single device launch.

Run:  PYTHONPATH=src python examples/rl_power_control.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crrm import CRRM
from repro.core.params import CRRM_parameters

N_UE, N_CELL, K, N_TTI = 60, 12, 2, 30
params = CRRM_parameters(n_ues=N_UE, n_cells=N_CELL, n_subbands=K,
                         pathloss_model_name="UMa", power_W=20.0, seed=3,
                         fairness_p=0.0, scheduler_policy="pf",
                         traffic_model="poisson",
                         traffic_params=dict(arrival_rate_hz=300.0,
                                             packet_size_bits=12_000.0))
sim = CRRM(params)
EP_KEY = jax.random.PRNGKey(7)          # frozen episode noise -> low variance


def reward(power_matrix) -> float:
    """Roll one MAC episode under the candidate power plan and score it."""
    sim.set_power_matrix(power_matrix)
    sim.set_backlog(np.zeros(N_UE, np.float32))   # comparable episodes
    sim._pf_avg = None                            # reset PF scheduler state
    tput = sim.run_episode(n_tti=N_TTI, key=EP_KEY)
    served = np.asarray(tput).mean(axis=0)                  # bits/s per UE
    backlog = np.asarray(sim.get_backlog())                 # queued bits
    goodput = np.log(np.maximum(served, 1e3)).mean()
    queue_penalty = 0.05 * np.log1p(backlog / 1e4).mean()
    return float(goodput - queue_penalty)


base_pw = np.full((N_CELL, K), 20.0 / K)
r0 = reward(base_pw)
print(f"baseline buffer-aware reward (uniform power): {r0:+.3f}")


# policy: per (cell, subband) logits -> power levels via softmax budget split
def sample(key, theta, temp=0.3):
    noise = jax.random.normal(key, theta.shape) * temp
    logits = theta + noise
    alloc = jax.nn.softmax(logits.reshape(-1)).reshape(theta.shape)
    return 20.0 * N_CELL * alloc, noise


theta = jnp.zeros((N_CELL, K))
key = jax.random.PRNGKey(0)
lr, batch = 2.0, 8
r_base = r0
for it in range(25):
    grads, rs = jnp.zeros_like(theta), []
    for b in range(batch):
        key, k = jax.random.split(key)
        pw, noise = sample(k, theta)
        r = reward(np.asarray(pw))
        rs.append(r)
        grads = grads + (r - r_base) * noise   # REINFORCE
    theta = theta + lr * grads / batch
    r_base = 0.9 * r_base + 0.1 * float(np.mean(rs))
    if (it + 1) % 5 == 0:
        pw, _ = sample(jax.random.PRNGKey(99), theta, temp=0.0)
        print(f"iter {it+1:3d}: mean episode reward {np.mean(rs):+.3f}  "
              f"greedy reward {reward(np.asarray(pw)):+.3f}")

pw, _ = sample(jax.random.PRNGKey(99), theta, temp=0.0)
print(f"learned power plan improves buffer-aware reward "
      f"{r0:+.3f} -> {reward(np.asarray(pw)):+.3f}")
