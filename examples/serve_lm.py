"""Batched serving example: slot-based continuous batching over a small LM.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.registry import make_arch  # noqa: E402
from repro.parallel.mesh import make_host_mesh  # noqa: E402
from repro.serve.engine import ServeEngine  # noqa: E402

cfg = get_config("qwen1.5-0.5b", reduced=True)
arch = make_arch(cfg)
engine = ServeEngine(arch, make_host_mesh(1, 1), batch_slots=4, max_len=96)

rng = np.random.default_rng(7)
requests = []
for i in range(10):
    prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 32))
    requests.append(engine.submit(prompt, max_new_tokens=12))

out = engine.run()
print(f"served {len(out['results'])} requests | {out['n_tokens']} tokens | "
      f"{out['tokens_per_s']:.1f} tok/s")
for rid in sorted(out["results"])[:3]:
    print(f"  request {rid} -> {out['results'][rid]}")
print("decode reuses the KV cache per step -- the LM-side smart update "
      "(one dirty row instead of a full recompute).")
