"""Quickstart: the gym-style CrrmEnv and the named scenario registry.

Three ways to drive the simulator as an RL environment:

1. the pure-functional core (explicit state, jit/vmap-friendly);
2. a vmapped batch -- N seeds, one compiled program;
3. the optional gymnasium adapter (numpy i/o, Box spaces), if gymnasium
   is installed.

Run:  PYTHONPATH=src python examples/gym_env.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.env import CrrmEnv
from repro.sim.scenarios import scenario_description, scenario_names

print("available scenarios:")
for name in scenario_names():
    print(f"  {name:16s} {scenario_description(name)[:60]}...")

# -- 1. functional: explicit state, no hidden attributes ---------------------
env = CrrmEnv(scenario="dense_urban",
              scenario_overrides=dict(n_ues=40, n_cells=7, seed=0),
              episode_tti=60, tti_per_step=20)
state, obs = env.reset(jax.random.PRNGKey(0))
while True:
    state, obs, reward, done = env.step(state, env.uniform_action())
    print(f"t={int(state.t):3d}  reward={float(reward):+.3f}  "
          f"mean tput={float(obs.tput.mean())/1e6:.2f} Mbit/s")
    if bool(done):
        break

# -- 2. batched: 8 seeds as ONE compiled program -----------------------------
keys = jax.random.split(jax.random.PRNGKey(1), 8)
states, _ = env.reset_batch(keys)
actions = jnp.stack([env.uniform_action()] * 8)
states, obs, rewards, dones = env.step_batch(states, actions)
print("batched rewards:", np.asarray(rewards).round(3))

# -- 3. gymnasium adapter (optional dependency) ------------------------------
try:
    from repro.env.gym_adapter import make_gym_env
    genv = make_gym_env(env, seed=0)
    o, _ = genv.reset()
    o, r, term, trunc, _ = genv.step(genv.action_space.sample())
    print(f"gymnasium step: obs {o.shape}, reward {r:+.3f}")
except ImportError as e:
    print(f"(gymnasium not installed -- adapter skipped: {e})")
